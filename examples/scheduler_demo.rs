//! Visualize the three-stream scheduler: an ASCII Gantt timeline of one
//! PAHQ edge evaluation on the simulated H20, for each of Tab. 4's four
//! stream configurations — showing exactly how the weight-transfer
//! latency gets masked (or not).
//!
//! Run: `cargo run --release --example scheduler_demo -- [--arch gpt2]`

use anyhow::Result;
use pahq::gpu_sim::memory::MethodKind;
use pahq::gpu_sim::{CostModel, RealArch};
use pahq::report::mmss;
use pahq::scheduler::{per_edge_us, predict_run, StreamConfig};
use pahq::util::cli::Args;

fn gantt(sim: &pahq::gpu_sim::Sim, width: usize) -> String {
    let names = ["S_load", "S_low ", "S_high"];
    let span = sim.makespan().max(1e-9);
    let mut rows = vec![vec![' '; width]; 3];
    for (start, finish, stream, _) in sim.timeline() {
        let a = ((start / span) * (width - 1) as f64) as usize;
        let b = ((finish / span) * (width - 1) as f64) as usize;
        for c in a..=b.min(width - 1) {
            rows[stream][c] = if rows[stream][c] == ' ' { '#' } else { '#' };
        }
    }
    let mut out = String::new();
    for (i, row) in rows.iter().enumerate() {
        out.push_str(&format!("  {} |{}|\n", names[i], row.iter().collect::<String>()));
    }
    out.push_str(&format!("  span: {:.2} ms\n", span / 1000.0));
    out
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let arch = RealArch::by_name(args.get_or("arch", "gpt2")).expect("unknown arch");
    let cost = CostModel::default();

    println!("== PAHQ three-stream scheduler on simulated H20 ({}) ==", arch.name);
    println!("{} edges to evaluate; one edge eval shown per config\n", arch.n_edges());

    for (label, cfg) in [
        ("full scheduler (load + split)", StreamConfig::FULL),
        ("load stream only", StreamConfig::LOAD_ONLY),
        ("split compute only", StreamConfig::SPLIT_ONLY),
        ("no streams (serial)", StreamConfig::NONE),
    ] {
        let (steady, sim) = per_edge_us(&arch, &cost, MethodKind::Pahq, cfg);
        let pred = predict_run(&arch, &cost, MethodKind::Pahq, cfg);
        println!("-- {label}: steady-state {:.1} ms/edge, full run {} (m:s)",
                 steady / 1000.0, mmss(pred.total_minutes));
        print!("{}", gantt(&sim, 72));
        println!();
    }
    println!("paper Tab. 4 ordering: full < load-only < split-only < none");
    println!("(the weight-loading stream matters more than the compute split:");
    println!(" staging one head's FP32 rows is a strided gather, slower than");
    println!(" the high-precision compute it feeds — see gpu_sim::cost docs)");
    Ok(())
}
