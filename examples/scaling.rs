//! Scalability walk (paper Appendix C): run PAHQ-accelerated ACDC on the
//! scale-series models (gpt2m/l/xl-sim) with batched edge evaluation,
//! compare the discovered circuit's KL against an equal-size EAP circuit,
//! and report the simulated-H20 runtime growth.
//!
//! Run: `cargo run --release --example scaling -- [--models gpt2m-sim,...]`

use anyhow::Result;
use pahq::acdc::{self, AcdcConfig};
use pahq::baselines::eap;
use pahq::experiments::complement_mask;
use pahq::gpu_sim::memory::MethodKind;
use pahq::gpu_sim::{CostModel, RealArch};
use pahq::metrics::Objective;
use pahq::patching::{PatchedForward, Policy};
use pahq::quant::FP8_E4M3;
use pahq::report::{mmss, Table};
use pahq::scheduler::{predict_run, StreamConfig};
use pahq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let models = args
        .list("models")
        .unwrap_or_else(|| vec!["gpt2m-sim".into(), "gpt2l-sim".into(), "gpt2xl-sim".into()]);
    let cost = CostModel::default();

    let mut table = Table::new(
        "Scaling (paper Tab. 7 shape): PAHQ vs EAP on IOI, tau=0.01",
        &["model", "edges", "batch", "KL (PAHQ)", "KL (EAP)", "sim PAHQ (m:s)", "real (s)"],
    );
    for model in &models {
        let mut engine = match PatchedForward::new(model, "ioi") {
            Ok(e) => e,
            Err(e) => {
                eprintln!("skipping {model}: {e}");
                continue;
            }
        };
        engine.set_session(Policy::pahq(FP8_E4M3))?;
        let t0 = std::time::Instant::now();
        let res = acdc::run(&mut engine, &AcdcConfig::new(0.01, Objective::Kl))?;
        let wall = t0.elapsed();
        engine.set_session(Policy::fp32())?;
        let kl_pahq = engine.damage(&res.removed, None, Objective::Kl)?;

        let scores = eap::scores(&mut engine, Objective::Kl)?;
        let mut order: Vec<usize> = (0..scores.len()).collect();
        order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
        let mut kept = vec![false; scores.len()];
        for &i in order.iter().take(res.n_kept) {
            kept[i] = true;
        }
        let kl_eap = engine.damage(&complement_mask(&engine, &kept), None, Objective::Kl)?;

        let arch = RealArch::by_name(model).unwrap();
        let sim = predict_run(&arch, &cost, MethodKind::Pahq, StreamConfig::FULL);
        table.row(vec![
            model.clone(),
            engine.graph.n_edges().to_string(),
            engine.manifest.batch.to_string(),
            format!("{kl_pahq:.2}"),
            format!("{kl_eap:.2}"),
            mmss(sim.total_minutes),
            format!("{:.0}", wall.as_secs_f64()),
        ]);
    }
    table.print();
    println!("(paper shape: PAHQ KL stays flat and well below EAP as models grow)");
    Ok(())
}
