//! A complete `pahq serve` client — the README "Serving" example and
//! the CI serve-smoke driver.
//!
//! Speaks the framed wire protocol from `docs/serve_protocol.md` using
//! the same [`pahq::serve::protocol`] codec the daemon uses: handshake
//! (`hello` / `hello_ack`), one quick synthetic-substrate submission,
//! then the streamed `progress` / `record` frames until the job's
//! terminal `done`. Every received record is parsed back through
//! [`RunRecord::from_json`], which enforces the record schema version.
//!
//! Modes (after the server address):
//! - *(default)* submit one `submit_run` spec and stream it to `done`
//! - `--matrix`  submit a two-task synthetic matrix (several cells)
//! - `--cancel`  submit the matrix, then immediately `cancel` it and
//!   report how many queued cells the server dropped
//! - `--shutdown` ask the daemon to drain and exit
//! - `--json PATH` additionally log every frame payload (sent and
//!   received) as JSONL for `scripts/check_schema.py --serve-frames`
//!
//! Run: `pahq serve --addr 127.0.0.1:7341 &` then
//! `cargo run --release --example serve_client -- 127.0.0.1:7341`

use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};
use pahq::api::{MatrixSpec, RunSpec, Substrate};
use pahq::discovery::RunRecord;
use pahq::serve::protocol::{encode, Message, PROTOCOL_VERSION};
use pahq::serve::{FrameReader, ReadEvent};
use pahq::util::json::Json;

/// Sent/received frame payloads, mirrored to `--json PATH` as JSONL so
/// CI can schema-validate a live conversation.
struct FrameLog {
    lines: Vec<String>,
    path: Option<String>,
}

impl FrameLog {
    fn log(&mut self, direction: &str, msg: &Message) {
        // direction is a comment for humans reading the file; the
        // schema checker validates the `frame` payload
        self.lines.push(
            Json::Obj(
                [
                    ("direction".to_string(), Json::from(direction)),
                    ("frame".to_string(), msg.to_json()),
                ]
                .into_iter()
                .collect(),
            )
            .dump(),
        );
    }

    fn flush(&self) -> Result<()> {
        if let Some(path) = &self.path {
            std::fs::write(path, self.lines.join("\n") + "\n")
                .with_context(|| format!("writing frame log {path}"))?;
            println!("frame log: {path} ({} frames)", self.lines.len());
        }
        Ok(())
    }
}

struct Client {
    stream: TcpStream,
    reader: FrameReader,
    log: FrameLog,
}

impl Client {
    fn connect(addr: &str, log_path: Option<String>) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_read_timeout(Some(Duration::from_millis(200)))?;
        Ok(Client {
            stream,
            reader: FrameReader::new(),
            log: FrameLog { lines: Vec::new(), path: log_path },
        })
    }

    fn send(&mut self, msg: &Message) -> Result<()> {
        self.log.log("client->server", msg);
        self.stream.write_all(&encode(msg)?)?;
        Ok(())
    }

    /// Block until the next frame (tolerating read timeouts).
    fn recv(&mut self) -> Result<Message> {
        loop {
            match self.reader.next(&mut self.stream)? {
                ReadEvent::Frame(msg) => {
                    self.log.log("server->client", &msg);
                    return Ok(msg);
                }
                ReadEvent::Pending => {}
                ReadEvent::Eof => bail!("server closed the connection"),
            }
        }
    }

    fn handshake(&mut self) -> Result<()> {
        self.send(&Message::Hello { protocol: PROTOCOL_VERSION })?;
        match self.recv()? {
            Message::HelloAck { protocol, record_schema } => {
                println!("connected: protocol v{protocol}, record schema v{record_schema}");
                Ok(())
            }
            other => bail!("expected hello_ack, got '{}'", other.kind()),
        }
    }

    /// Stream one job's frames to its terminal `done`, validating every
    /// record through the schema-versioned parser. Returns the records.
    fn stream_job(&mut self, job_id: u64) -> Result<Vec<RunRecord>> {
        let mut records = Vec::new();
        loop {
            match self.recv()? {
                Message::Progress { done, total, cell, coalesced, .. } => {
                    let note = if coalesced > 0 {
                        format!(" (+{coalesced} coalesced)")
                    } else {
                        String::new()
                    };
                    println!("  progress {done}/{total}: {cell}{note}");
                }
                Message::Record { cell, record, .. } => {
                    let rec = RunRecord::from_json(&record)
                        .with_context(|| format!("cell {cell}: invalid record"))?;
                    println!(
                        "  record {cell}: kept {}/{} edges, hash {}",
                        rec.n_kept, rec.n_edges, rec.kept_hash
                    );
                    records.push(rec);
                }
                Message::CellError { cell, error, .. } => {
                    println!("  cell {cell} FAILED: {error}");
                }
                Message::CancelAck { dropped, .. } => {
                    println!("  cancel acknowledged: {dropped} queued cell(s) dropped");
                }
                Message::Done { ok, failed, cancelled, .. } => {
                    println!(
                        "done: job {job_id} — {ok} ok, {failed} failed, {cancelled} cancelled"
                    );
                    return Ok(records);
                }
                Message::Error { code, message } => {
                    bail!("server error {:?}: {message}", code)
                }
                other => bail!("unexpected frame '{}'", other.kind()),
            }
        }
    }

    fn submit(&mut self, msg: &Message) -> Result<(u64, usize)> {
        self.send(msg)?;
        match self.recv()? {
            Message::Accepted { job_id, cells } => {
                println!("accepted: job {job_id}, {cells} cell(s)");
                Ok((job_id, cells))
            }
            Message::Error { code, message } => bail!("submission refused {:?}: {message}", code),
            other => bail!("expected accepted, got '{}'", other.kind()),
        }
    }
}

/// A quick spec the daemon can run anywhere: the synthetic substrate
/// needs no engine artifacts, so this works in CI and on a laptop.
fn quick_run_spec() -> Result<RunSpec> {
    RunSpec::builder("redwood2l-sim", "ioi")
        .method("pahq".parse()?)
        .tau(0.01)
        .substrate(Substrate::Synthetic)
        .build()
}

/// A small two-task matrix (several cells) for the cancel/matrix modes.
fn quick_matrix_spec() -> Result<MatrixSpec> {
    MatrixSpec::from_wire(&Json::parse(
        r#"{"models": ["redwood2l-sim"], "tasks": ["ioi", "greater_than"],
            "methods": ["acdc", "eap"], "policies": ["fp32", "pahq"]}"#,
    )?)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let addr = args.first().context(
        "usage: serve_client <ADDR> [--matrix | --cancel | --shutdown] [--json PATH]",
    )?;
    let mode = args.iter().find(|a| matches!(a.as_str(), "--matrix" | "--cancel" | "--shutdown"));
    let log_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let mut client = Client::connect(addr, log_path)?;
    client.handshake()?;

    match mode.map(String::as_str) {
        None => {
            let spec = quick_run_spec()?;
            let (job_id, _) = client.submit(&Message::SubmitRun { spec })?;
            let records = client.stream_job(job_id)?;
            if records.len() != 1 {
                bail!("expected exactly one record, got {}", records.len());
            }
        }
        Some("--matrix") => {
            let spec = quick_matrix_spec()?;
            let (job_id, cells) = client.submit(&Message::SubmitMatrix { spec })?;
            let records = client.stream_job(job_id)?;
            if records.len() != cells {
                bail!("expected {cells} records, got {}", records.len());
            }
        }
        Some("--cancel") => {
            let spec = quick_matrix_spec()?;
            let (job_id, cells) = client.submit(&Message::SubmitMatrix { spec })?;
            client.send(&Message::Cancel { job_id })?;
            let records = client.stream_job(job_id)?;
            println!(
                "cancelled after {} of {cells} cell(s) completed (in-flight cells finish)",
                records.len()
            );
        }
        Some("--shutdown") => {
            client.send(&Message::Shutdown)?;
            match client.recv()? {
                Message::ShutdownAck => println!("server acknowledged shutdown"),
                other => bail!("expected shutdown_ack, got '{}'", other.kind()),
            }
        }
        Some(other) => bail!("unknown mode {other}"),
    }

    client.log.flush()
}
