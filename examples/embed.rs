//! Embedding PAHQ as a library — the README "Library use" example.
//!
//! Builds a validated [`RunSpec`] with the typed builder, launches it
//! through the one public entry point ([`pahq::api::run`]), and reads
//! the discovered circuit + faithfulness back from the returned
//! [`RunRecord`] — no CLI, no `util::cli`, no string plumbing.
//!
//! With `make artifacts` built this drives the real engine; without
//! artifacts (e.g. CI) the spec's `Substrate::Auto` resolves to the
//! deterministic synthetic surface, so the example still runs end to
//! end and still emits a schema-valid record.
//!
//! The second leg repeats the run against a temporary *disk* artifact
//! store ([`StoreSpec::Disk`]): the first pass publishes its artifacts,
//! the second pulls them back — the same durable store `pahq matrix
//! --store disk` seeds, so an embedder and a grid can share work.
//!
//! Run: `cargo run --release --example embed [-- RECORD.json]`

use anyhow::Result;
use pahq::api::{self, OutputSink, RunSpec, StoreSpec};

fn main() -> Result<()> {
    let out = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "rust/results/embed_record.json".to_string());

    // A typed, validated run: EAP attribution ordering, verified through
    // the shared sweep under the PAHQ 8-bit policy, scored against the
    // FP32 ground truth when the real substrate is available.
    let spec = RunSpec::builder("redwood2l-sim", "ioi")
        .method("eap".parse()?)
        .bits(8)
        .tau(0.01)
        .objective("kl".parse()?)
        .seed(0)
        .faithfulness(Some(true))
        .sink(OutputSink::Path(out.clone().into()))
        .build()?;

    println!(
        "embed: {} / {} / {} under {} (tau={})",
        spec.model, spec.task, spec.method, spec.policy, spec.tau
    );

    let rec = api::run(&spec)?;

    println!(
        "discovered circuit: {} of {} edges kept ({} evals, {:.2}s wall)",
        rec.n_kept, rec.n_edges, rec.n_evals, rec.wall_seconds
    );
    println!("kept-set hash: {} (objective {})", rec.kept_hash, rec.objective);
    match &rec.faithfulness {
        Some(f) => {
            println!(
                "faithfulness vs FP32 ground truth: TPR={:.3} FPR={:.3} acc={:.3}{}",
                f.tpr,
                f.fpr,
                f.accuracy,
                f.normalized.map(|n| format!(" normalized={n:.2}")).unwrap_or_default()
            );
        }
        None => println!("faithfulness: not available on this substrate"),
    }
    println!("record: {out}");

    // Same spec, durable artifact store: run twice against a temp disk
    // root — the first pass publishes the artifacts, the second starts
    // cold and reuses them, with a bit-identical kept set.
    let store_root = std::env::temp_dir().join(format!("pahq-embed-store-{}", std::process::id()));
    let disk = StoreSpec::Disk { root: store_root.clone(), gc_horizon: None };
    let disk_spec = RunSpec::builder("redwood2l-sim", "ioi")
        .method("eap".parse()?)
        .bits(8)
        .tau(0.01)
        .objective("kl".parse()?)
        .seed(0)
        .store(disk)
        .build()?;
    let cold = api::run(&disk_spec)?;
    let warm = api::run(&disk_spec)?;
    assert_eq!(cold.kept_hash, warm.kept_hash, "disk-store reuse changed the circuit");
    println!(
        "disk store at {}: second run reused the published artifacts (cache: {})",
        store_root.display(),
        warm.cache.is_some()
    );
    std::fs::remove_dir_all(&store_root).ok();
    Ok(())
}
