//! Quickstart — the end-to-end driver (DESIGN.md: "end-to-end validation").
//!
//! Loads the GPT-2-family sim model, runs PAHQ-accelerated ACDC on the
//! IOI task through the full three-layer stack (Rust coordinator ->
//! PJRT-compiled per-layer HLOs -> Pallas-kernel attention), and reports:
//!   - the discovered circuit and its size,
//!   - faithfulness against the FP32 ground-truth circuit (TPR/FPR/AUC
//!     ingredients),
//!   - runtime (wall, PJRT share, per-eval) and the simulated-H20
//!     runtime/memory the paper's Tab. 3 is about.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

use anyhow::Result;
use pahq::acdc::{self, AcdcConfig};
use pahq::eval;
use pahq::gpu_sim::memory::{memory_model, MethodKind};
use pahq::gpu_sim::{CostModel, RealArch};
use pahq::metrics::{confusion, Objective};
use pahq::patching::{PatchedForward, Policy};
use pahq::quant::FP8_E4M3;
use pahq::report::mmss;
use pahq::scheduler::{predict_run, StreamConfig};

fn main() -> Result<()> {
    let (model, task, tau) = ("gpt2s-sim", "ioi", 0.01f32);
    println!("== PAHQ quickstart: {model} / {task} / tau={tau} ==\n");

    // 1. Bring up the engine: manifest + weights + PJRT executables.
    let t0 = std::time::Instant::now();
    let mut engine = PatchedForward::new(model, task)?;
    println!(
        "engine up in {:.1}s: {} params, {} nodes, {} edges, batch {}",
        t0.elapsed().as_secs_f64(),
        engine.manifest.n_params,
        engine.graph.n_nodes(),
        engine.graph.n_edges(),
        engine.manifest.batch,
    );

    // 2. FP32 ground truth (cached after first run).
    let gt = eval::ground_truth(&mut engine, model, task, Objective::Kl)?;
    println!(
        "FP32 ground-truth circuit: {} / {} edges (tau* = {:.5})\n",
        gt.n_members(),
        gt.delta.len(),
        gt.tau_star
    );

    // 3. PAHQ-accelerated ACDC.
    engine.set_session(Policy::pahq(FP8_E4M3))?;
    let t1 = std::time::Instant::now();
    let res = acdc::run(&mut engine, &AcdcConfig::new(tau, Objective::Kl))?;
    let wall = t1.elapsed();
    let p = confusion(&res.kept, &gt.member);
    println!("PAHQ-ACDC: kept {} edges in {:.1}s ({} evals, {:.2} ms/eval)",
             res.n_kept, wall.as_secs_f64(), res.n_evals,
             wall.as_secs_f64() * 1e3 / res.n_evals as f64);
    println!("vs ground truth: TPR={:.3} FPR={:.3}", p.tpr, p.fpr);
    println!("PJRT share of wall: {:.0}%",
             100.0 * engine.pjrt_time().as_secs_f64() / wall.as_secs_f64());

    println!("\ndiscovered circuit (top of the kept list):");
    for label in acdc::kept_edge_labels(&engine, &res).iter().take(16) {
        println!("  {label}");
    }

    // 4. The paper's headline numbers at the paper's scale (simulated H20).
    println!("\nsimulated H20 at GPT-2-small scale (paper Tab. 3):");
    let arch = RealArch::by_name("gpt2").unwrap();
    let cost = CostModel::default();
    for (name, kind, cfg) in [
        ("ACDC ", MethodKind::AcdcFp32, StreamConfig::NONE),
        ("RTN-Q", MethodKind::RtnQ, StreamConfig::NONE),
        ("PAHQ ", MethodKind::Pahq, StreamConfig::FULL),
    ] {
        let pr = predict_run(&arch, &cost, kind, cfg);
        let mem = memory_model(&arch, kind);
        println!("  {name}  {:>7} (m:s)   {:.2} GB", mmss(pr.total_minutes), mem.total_gb());
    }
    let acdc_t = predict_run(&arch, &cost, MethodKind::AcdcFp32, StreamConfig::NONE).total_minutes;
    let pahq_t = predict_run(&arch, &cost, MethodKind::Pahq, StreamConfig::FULL).total_minutes;
    println!("  runtime cut: {:.0}% (paper: ~80%)", 100.0 * (1.0 - pahq_t / acdc_t));
    Ok(())
}
