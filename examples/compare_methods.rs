//! Compare every circuit-discovery method on one task — a Table-1-style
//! row computed live: ACDC (FP32), RTN-Q, PAHQ, EAP, HISP, SP.
//!
//! Run: `cargo run --release --example compare_methods -- [--model M] [--task T]`

use anyhow::Result;
use pahq::baselines::{eap, hisp, sp};
use pahq::eval;
use pahq::metrics::Objective;
use pahq::patching::{PatchedForward, Policy};
use pahq::quant::FP8_E4M3;
use pahq::report::Table;
use pahq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.get_or("model", "redwood2l-sim");
    let task = args.get_or("task", "ioi");
    // a light threshold grid keeps this example interactive
    let taus: Vec<f32> = pahq::acdc::paper_thresholds().into_iter().step_by(3).collect();

    println!("comparing methods on {model}/{task} ({} thresholds)", taus.len());
    let mut table = Table::new(
        &format!("AUC-ROC on {model}/{task}"),
        &["method", "KL div", "Task", "evals/exec"],
    );

    for method in ["acdc", "rtn-q", "pahq", "eap", "hisp", "sp"] {
        let mut aucs = Vec::new();
        let mut execs = String::new();
        for obj in [Objective::Kl, Objective::LogitDiff] {
            let mut engine = PatchedForward::new(model, task)?;
            let gt = eval::ground_truth(&mut engine, model, task, obj)?;
            let before = engine.forward_count;
            let auc = match method {
                "acdc" => eval::sweep_acdc(&mut engine, Policy::fp32(), obj, &gt, &taus)?.auc,
                "rtn-q" => {
                    eval::sweep_acdc(&mut engine, Policy::rtn(FP8_E4M3), obj, &gt, &taus)?.auc
                }
                "pahq" => {
                    eval::sweep_acdc(&mut engine, Policy::pahq(FP8_E4M3), obj, &gt, &taus)?.auc
                }
                "eap" => eval::sweep_scores(&eap::scores(&mut engine, obj)?, &gt).auc,
                "hisp" => eval::sweep_scores(&hisp::scores(&mut engine, obj)?, &gt).auc,
                _ => {
                    let cfg = sp::SpConfig { steps: 50, ..Default::default() };
                    eval::sweep_scores(&sp::scores(&mut engine, &cfg)?, &gt).auc
                }
            };
            aucs.push(format!("{auc:.2}"));
            execs = format!("{}", engine.forward_count - before);
        }
        table.row(vec![method.into(), aucs[0].clone(), aucs[1].clone(), execs]);
    }
    table.print();
    println!("(expected shape: acdc ≈ pahq >> rtn-q; eap/hisp/sp in between — paper Tab. 1)");
    Ok(())
}
