#!/usr/bin/env python3
"""End-to-end smoke test for the `pahq load` harness (stdlib-only).

Boots a `pahq serve` daemon on an ephemeral loopback port, drives it
with the smoke scenario in wire mode, and then closes the loop on the
whole measurement pipeline:

1. `pahq load --scenario smoke --addr ... --json ... --shutdown` must
   exit 0 and drain the daemon, which must itself exit 0 — the load
   run's --shutdown is the only shutdown request sent;
2. the emitted ``load_snapshot.json`` validates against
   ``docs/load_snapshot.schema.json`` plus the cross-field invariants
   (``check_schema.py --load``);
3. ``bench_gate.py --load`` passes against the committed
   ``BENCH_baseline.json`` floors;
4. the gate's failure path is demonstrably live: re-gating the same
   snapshot against a temporary baseline with an impossible 1 us p99
   ceiling must exit nonzero. A gate that cannot fail gates nothing.

Usage:
    python scripts/load_smoke.py PAHQ_BIN [OUT_DIR]
    (e.g. target/release/pahq load-logs)
"""

import json
import os
import socket
import subprocess
import sys
import tempfile
import time

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
SCHEMA = os.path.join(REPO, "docs", "load_snapshot.schema.json")
BASELINE = os.path.join(REPO, "BENCH_baseline.json")

LOAD_TIMEOUT = 120  # the whole smoke scenario run, seconds
SHUTDOWN_TIMEOUT = 60  # daemon exit after the load run's shutdown, seconds

sys.path.insert(0, HERE)
from check_schema import SchemaError, check_load  # noqa: E402


def free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def wait_listening(addr, proc, deadline):
    host, port = addr.split(":")
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            sys.exit(f"daemon exited early with code {proc.returncode}")
        try:
            with socket.create_connection((host, int(port)), timeout=1):
                return
        except OSError:
            time.sleep(0.1)
    sys.exit("daemon never started listening")


def main(argv):
    if len(argv) not in (2, 3):
        print(__doc__)
        return 2
    pahq = argv[1]
    out_dir = argv[2] if len(argv) == 3 else tempfile.mkdtemp(prefix="load_smoke_")
    os.makedirs(out_dir, exist_ok=True)
    snapshot = os.path.join(out_dir, "load_snapshot.json")

    port = free_port()
    addr = f"127.0.0.1:{port}"
    daemon = subprocess.Popen([pahq, "serve", "--addr", addr, "--workers", "2"])
    try:
        wait_listening(addr, daemon, time.monotonic() + 30)
        print(f"daemon up on {addr}")

        # 1. the smoke scenario end to end, draining the daemon on exit
        subprocess.run(
            [
                pahq,
                "load",
                "--scenario",
                "smoke",
                "--addr",
                addr,
                "--json",
                snapshot,
                "--shutdown",
            ],
            check=True,
            timeout=LOAD_TIMEOUT,
        )
        code = daemon.wait(timeout=SHUTDOWN_TIMEOUT)
        if code != 0:
            sys.exit(f"daemon exited {code} after the load run's shutdown")
        print("load run completed and daemon drained to exit 0")
    finally:
        if daemon.poll() is None:
            daemon.kill()
            daemon.wait()

    # 2. schema + cross-field invariants
    with open(SCHEMA) as f:
        schema = json.load(f)
    with open(snapshot) as f:
        doc = json.load(f)
    try:
        submitted, completed = check_load(doc, schema)
    except SchemaError as e:
        sys.exit(f"schema check FAILED for {snapshot}: {e}")
    print(f"snapshot schema-valid: {submitted} submitted, {completed} latency samples")

    # 3. the committed floors must pass on a healthy run
    gate = [sys.executable, os.path.join(HERE, "bench_gate.py")]
    subprocess.run(gate + [BASELINE, snapshot, "--load"], check=True)
    print("load gate OK against the committed baseline")

    # 4. and the gate must actually be able to fail: an impossible p99
    # ceiling on the very same snapshot has to exit nonzero
    with open(BASELINE) as f:
        base = json.load(f)
    base.setdefault("load", {}).setdefault("smoke", {})["max_p99_us"] = 1.0
    tight = os.path.join(out_dir, "baseline_tight.json")
    with open(tight, "w") as f:
        json.dump(base, f)
    bad = subprocess.run(gate + [tight, snapshot, "--load"])
    if bad.returncode == 0:
        sys.exit("load gate accepted an impossible 1 us p99 ceiling — the gate is dead")
    print(f"load gate correctly fails on an impossible floor (exit {bad.returncode})")

    print("load smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
