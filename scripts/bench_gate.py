#!/usr/bin/env python3
"""Perf gate: diff a fresh `pahq bench --json` snapshot against the
committed baseline and fail CI on regressions of the sweep hot path.

Usage:
    python scripts/bench_gate.py BENCH_baseline.json bench.json \
        [--max-wall-regress 0.25] [--max-mem-regress 0.10]
    python scripts/bench_gate.py BENCH_baseline.json matrix.json --matrix \
        [--max-wall-regress 0.25]
    python scripts/bench_gate.py BENCH_baseline.json load_snapshot.json --load

Checks (stdlib only):

1. **Wall time** — the serial sweep's *normalized per-eval cost*
   (`wall_seconds / n_evals / calibration_seconds`). The calibration
   term is the same fixed spin loop the synthetic scorer runs, measured
   in the same process, so machine speed cancels and the ratio isolates
   the sweep engine's own overhead. Fails when it exceeds the baseline
   by more than --max-wall-regress (default 25%).
2. **Measured memory** — `memory.measured_total_bytes`, the real packed
   payload bytes of a PAHQ-shaped session (fp8 + bf16 planes + fp32
   cache). Deterministic; fails beyond --max-mem-regress (default 10%).
3. **Correctness** — every sweep mode in the snapshot reports the same
   kept-set hash (batched bit-identity), and batched modes do not
   inflate evaluations beyond the speculation model's bound.
4. **Packed-kernel throughput** — `packed_kernels.{fp8,fp4}_bytes_per_sec`
   (the word-parallel fused decode-accumulate kernels) against the
   baseline floors with the same tolerance applied downward, plus the
   machine-independent wide-vs-scalar speedup ratio against
   `packed_kernels.min_speedup` (the PR 7 acceptance floor, 2x).

With --matrix the current artifact is a `pahq matrix` manifest instead:

5. **Cache effectiveness floor** — cross-run reuse must be real: the
   gate fails when the quick grid reports zero corrupt-cache hits (or
   zero attribution-score hits), so the matrix's reuse cannot silently
   regress to N isolated runs.
6. **matrix_quick_wall** — the grid's `wall_seconds_total` against the
   baseline's `matrix_quick_wall` field, same regress bound as the
   sweep wall gate.

With --load the current artifact is the `load_snapshot.json` a
`pahq load --json` run emits (see docs/load_snapshot.schema.json):

7. **Correctness floor (always on)** — any failed request, protocol
   error frame, or cell error fails the gate regardless of baseline
   values: a load run against a healthy daemon completes everything
   it submits.
8. **Latency / throughput floors** — per-scenario bounds from the
   baseline's `load` section, keyed by scenario name: `max_p99_us`
   (overall p99 must stay under it) and `min_records_per_sec`
   (streamed-record throughput must stay above it). A scenario the
   baseline does not know is reported and skipped, so exploratory
   runs of new presets do not fail CI.

A baseline field set to null skips its check (used to stage new fields
before the first trustworthy baseline lands).
"""

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if doc.get("kind") != "bench_snapshot":
        sys.exit(f"{path}: not a bench_snapshot")
    return doc


def serial_row(doc, path):
    for row in doc.get("sweep_hot_path", []):
        if row.get("mode") == "serial":
            return row
    sys.exit(f"{path}: no serial row in sweep_hot_path")


def gate_matrix(base, current_path, max_wall_regress):
    """Matrix-manifest mode: cache-effectiveness floor + quick-grid wall."""
    with open(current_path) as f:
        cur = json.load(f)
    if cur.get("kind") != "matrix_manifest":
        sys.exit(f"{current_path}: not a matrix_manifest")
    agg = cur.get("aggregate", {})
    failures = []

    if agg.get("n_error", 0):
        failures.append(f"{agg['n_error']} matrix cell(s) failed")
    corrupt = agg.get("corrupt_cache_hits", 0)
    scores = agg.get("scores_cache_hits", 0)
    status = "FAIL" if corrupt == 0 or scores == 0 else "ok"
    print(f"reuse [{status}]: corrupt-cache hits {corrupt}, score-cache hits {scores}")
    if corrupt == 0:
        failures.append("corrupt-cache hit rate across the grid is 0 — cross-run reuse regressed")
    if scores == 0:
        failures.append("attribution-score cache hit rate is 0 — cross-run reuse regressed")

    base_wall = base.get("matrix_quick_wall")
    cur_wall = agg.get("wall_seconds_total")
    if base_wall is None:
        print("matrix wall gate skipped: baseline matrix_quick_wall is null")
    elif not cur.get("quick"):
        # the baseline is the --quick grid's wall; a full grid is
        # legitimately slower and must not trip the quick gate
        print("matrix wall gate skipped: manifest is not a --quick grid")
    elif cur_wall is None:
        failures.append("manifest has no aggregate.wall_seconds_total to gate")
    else:
        limit = base_wall * (1 + max_wall_regress)
        status = "FAIL" if cur_wall > limit else "ok"
        print(
            f"mwall [{status}]: matrix quick grid {cur_wall:.2f}s vs baseline "
            f"{base_wall:.2f}s (limit {limit:.2f}s)"
        )
        if cur_wall > limit:
            failures.append(f"matrix quick grid wall regressed: {cur_wall:.2f} > {limit:.2f}")

    if failures:
        print("\nperf gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nperf gate OK")
    return 0


def gate_load(base, current_path):
    """Load-snapshot mode: hard correctness floor + per-scenario
    latency/throughput floors from the baseline's `load` section."""
    with open(current_path) as f:
        cur = json.load(f)
    if cur.get("kind") != "load_snapshot":
        sys.exit(f"{current_path}: not a load_snapshot")
    failures = []

    # 7. correctness floor: always on, no baseline needed
    req = cur.get("requests", {})
    frames = cur.get("frames", {})
    for what, count in (
        ("failed request(s)", req.get("failed", 0)),
        ("protocol error frame(s)", frames.get("errors", 0)),
        ("cell error(s)", frames.get("cell_errors", 0)),
    ):
        if count:
            failures.append(f"{count} {what} in the load run")
    status = "FAIL" if failures else "ok"
    print(
        f"loadc [{status}]: {req.get('submitted', 0)} submitted, "
        f"{req.get('ok', 0)} ok, {req.get('failed', 0)} failed, "
        f"{frames.get('errors', 0)} error frames, "
        f"{frames.get('cell_errors', 0)} cell errors"
    )

    # 8. per-scenario floors from the baseline `load` section
    scenario = cur.get("scenario", {}).get("name")
    floors = (base.get("load") or {}).get(scenario)
    if floors is None:
        print(f"load floors skipped: baseline has no load.{scenario} section")
    else:
        p99 = cur.get("latency_us", {}).get("p99")
        max_p99 = floors.get("max_p99_us")
        if max_p99 is None:
            print("p99   gate skipped: baseline max_p99_us is null")
        elif p99 is None:
            failures.append("snapshot has no latency_us.p99 to gate")
        else:
            status = "FAIL" if p99 > max_p99 else "ok"
            print(
                f"p99   [{status}]: {p99 / 1000.0:.1f} ms vs ceiling "
                f"{max_p99 / 1000.0:.1f} ms ({scenario})"
            )
            if p99 > max_p99:
                failures.append(f"{scenario} p99 regressed: {p99} > {max_p99} us")
        rps = cur.get("throughput", {}).get("records_per_sec")
        min_rps = floors.get("min_records_per_sec")
        if min_rps is None:
            print("rps   gate skipped: baseline min_records_per_sec is null")
        elif rps is None:
            failures.append("snapshot has no throughput.records_per_sec to gate")
        else:
            status = "FAIL" if rps < min_rps else "ok"
            print(
                f"rps   [{status}]: {rps:.2f} records/s vs floor "
                f"{min_rps:.2f} ({scenario})"
            )
            if rps < min_rps:
                failures.append(
                    f"{scenario} record throughput below floor: {rps:.2f} < {min_rps:.2f}"
                )

    if failures:
        print("\nperf gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nperf gate OK")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--max-wall-regress", type=float, default=0.25)
    ap.add_argument("--max-mem-regress", type=float, default=0.10)
    ap.add_argument(
        "--matrix",
        action="store_true",
        help="current is a pahq matrix manifest: gate cache effectiveness + quick wall",
    )
    ap.add_argument(
        "--load",
        action="store_true",
        help="current is a pahq load snapshot: gate correctness + p99/throughput floors",
    )
    args = ap.parse_args()

    base = load(args.baseline)
    if args.matrix:
        return gate_matrix(base, args.current, args.max_wall_regress)
    if args.load:
        return gate_load(base, args.current)
    cur = load(args.current)
    failures = []

    # 3. internal consistency of the current snapshot first: batched
    #    sweeps must land on the serial kept set
    rows = cur.get("sweep_hot_path", [])
    hashes = {row.get("kept_hash") for row in rows}
    if len(hashes) != 1:
        failures.append(f"kept-set hashes diverge across sweep modes: {sorted(hashes)}")
    cur_serial = serial_row(cur, args.current)
    for row in rows:
        if row is cur_serial:
            continue
        window = 2 * int(row.get("workers", 1))  # SPEC_OVERSUB * workers
        bound = 1 + (cur_serial["n_evals"] - 1) * window
        if row["n_evals"] > bound:
            failures.append(
                f"{row['mode']}: {row['n_evals']} evals exceeds the misprediction "
                f"bound {bound} (serial {cur_serial['n_evals']})"
            )

    # 1. normalized per-eval wall time on the serial hot path
    base_serial = serial_row(base, args.baseline)
    base_norm = base_serial.get("normalized_per_eval")
    cur_norm = cur_serial.get("normalized_per_eval")
    if base_norm is None:
        print("wall gate skipped: baseline normalized_per_eval is null")
    else:
        limit = base_norm * (1 + args.max_wall_regress)
        status = "FAIL" if cur_norm > limit else "ok"
        print(
            f"wall  [{status}]: normalized per-eval {cur_norm:.3f} vs baseline "
            f"{base_norm:.3f} (limit {limit:.3f})"
        )
        if cur_norm > limit:
            failures.append(
                f"serial sweep per-eval cost regressed: {cur_norm:.3f} > {limit:.3f}"
            )

    # 2. measured packed memory
    base_mem = base.get("memory", {}).get("measured_total_bytes")
    cur_mem = cur.get("memory", {}).get("measured_total_bytes")
    if base_mem is None:
        print("memory gate skipped: baseline measured_total_bytes is null")
    else:
        limit = base_mem * (1 + args.max_mem_regress)
        status = "FAIL" if cur_mem > limit else "ok"
        print(
            f"mem   [{status}]: measured {cur_mem} B vs baseline {base_mem} B "
            f"(limit {limit:.0f} B)"
        )
        if cur_mem > limit:
            failures.append(f"measured packed memory regressed: {cur_mem} > {limit:.0f}")

    # 4. word-parallel packed-kernel throughput: absolute bytes/sec
    #    floors (same tolerance, applied downward: slower than
    #    baseline*(1-tol) fails) and the machine-independent
    #    wide-vs-scalar speedup floor
    base_pk = base.get("packed_kernels") or {}
    cur_pk = cur.get("packed_kernels") or {}
    min_speedup = base_pk.get("min_speedup")
    for fmt in ("fp8", "fp4"):
        base_bps = base_pk.get(f"{fmt}_bytes_per_sec")
        cur_bps = cur_pk.get(f"{fmt}_bytes_per_sec")
        if base_bps is None:
            print(f"kern  gate skipped: baseline {fmt}_bytes_per_sec is null")
        elif cur_bps is None:
            failures.append(f"snapshot has no packed_kernels.{fmt}_bytes_per_sec to gate")
        else:
            limit = base_bps * (1 - args.max_wall_regress)
            status = "FAIL" if cur_bps < limit else "ok"
            print(
                f"kern  [{status}]: {fmt} fused kernel {cur_bps / 1e9:.2f} GB/s vs "
                f"baseline {base_bps / 1e9:.2f} GB/s (floor {limit / 1e9:.2f})"
            )
            if cur_bps < limit:
                failures.append(
                    f"{fmt} packed kernel throughput regressed: {cur_bps:.3e} < {limit:.3e} B/s"
                )
        cur_speedup = cur_pk.get(f"{fmt}_speedup")
        if min_speedup is None:
            print(f"spdup gate skipped for {fmt}: baseline packed_kernels.min_speedup is null")
        elif cur_speedup is None:
            failures.append(f"snapshot has no packed_kernels.{fmt}_speedup to gate")
        else:
            status = "FAIL" if cur_speedup < min_speedup else "ok"
            print(
                f"spdup [{status}]: {fmt} wide-vs-scalar {cur_speedup:.2f}x "
                f"(floor {min_speedup:.1f}x)"
            )
            if cur_speedup < min_speedup:
                failures.append(
                    f"{fmt} word-parallel speedup below floor: "
                    f"{cur_speedup:.2f}x < {min_speedup:.1f}x"
                )

    if failures:
        print("\nperf gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nperf gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
