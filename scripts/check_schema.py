#!/usr/bin/env python3
"""Validate RunRecord artifacts against docs/run_record.schema.json.

Stdlib-only subset of JSON Schema: type, properties, required, items,
enum, minimum, pattern. That subset is the contract — if the schema file
grows a keyword this script does not know, validation fails loudly
rather than silently passing.

Usage:
    python scripts/check_schema.py docs/run_record.schema.json ARTIFACT.json

ARTIFACT.json is either a bare RunRecord (kind == "run_record") or a
bench snapshot (kind == "bench_snapshot") whose "records" array holds
RunRecords; every record found is validated.
"""

import json
import re
import sys

KNOWN_KEYWORDS = {
    "$comment",
    "type",
    "properties",
    "required",
    "items",
    "enum",
    "minimum",
    "pattern",
}

TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
    "null": type(None),
}


class SchemaError(Exception):
    pass


def check(value, schema, path="$"):
    unknown = set(schema) - KNOWN_KEYWORDS
    if unknown:
        raise SchemaError(f"{path}: schema uses unsupported keywords {sorted(unknown)}")

    if "enum" in schema:
        if value not in schema["enum"]:
            raise SchemaError(f"{path}: {value!r} not in enum {schema['enum']}")
        return

    t = schema.get("type")
    if t == "number":
        if not isinstance(value, (int, float)) or isinstance(value, bool):
            raise SchemaError(f"{path}: expected number, got {type(value).__name__}")
    elif t == "integer":
        ok = isinstance(value, int) and not isinstance(value, bool)
        # JSON emitters may write 3 as 3.0; accept integral floats
        ok = ok or (isinstance(value, float) and value.is_integer())
        if not ok:
            raise SchemaError(f"{path}: expected integer, got {value!r}")
    elif t is not None:
        py = TYPES.get(t)
        if py is None:
            raise SchemaError(f"{path}: unsupported type {t!r} in schema")
        if not isinstance(value, py):
            raise SchemaError(f"{path}: expected {t}, got {type(value).__name__}")

    if "minimum" in schema and isinstance(value, (int, float)) and not isinstance(value, bool):
        if value < schema["minimum"]:
            raise SchemaError(f"{path}: {value} < minimum {schema['minimum']}")

    if "pattern" in schema and isinstance(value, str):
        if not re.search(schema["pattern"], value):
            raise SchemaError(f"{path}: {value!r} does not match /{schema['pattern']}/")

    if isinstance(value, dict):
        for key in schema.get("required", []):
            if key not in value:
                raise SchemaError(f"{path}: missing required key {key!r}")
        for key, sub in schema.get("properties", {}).items():
            if key in value:
                check(value[key], sub, f"{path}.{key}")

    if isinstance(value, list) and "items" in schema:
        for i, item in enumerate(value):
            check(item, schema["items"], f"{path}[{i}]")


def extract_records(doc):
    kind = doc.get("kind") if isinstance(doc, dict) else None
    if kind == "run_record":
        return [doc]
    if kind == "bench_snapshot":
        records = doc.get("records", [])
        if not isinstance(records, list):
            raise SchemaError("bench_snapshot.records is not an array")
        return records
    raise SchemaError(f"unrecognized artifact kind {kind!r}")


def main(argv):
    if len(argv) != 3:
        print(__doc__)
        return 2
    with open(argv[1]) as f:
        schema = json.load(f)
    with open(argv[2]) as f:
        doc = json.load(f)
    try:
        records = extract_records(doc)
        if not records:
            raise SchemaError("artifact contains no RunRecords to validate")
        for i, rec in enumerate(records):
            check(rec, schema, f"records[{i}]")
    except SchemaError as e:
        print(f"schema check FAILED: {e}")
        return 1
    print(f"schema check OK: {len(records)} record(s) valid against v{schema['properties']['schema_version']['enum'][0]}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
