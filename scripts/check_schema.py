#!/usr/bin/env python3
"""Validate RunRecord / matrix-manifest artifacts against their schemas.

Stdlib-only subset of JSON Schema: type, properties, required, items,
enum, minimum, pattern. That subset is the contract — if the schema file
grows a keyword this script does not know, validation fails loudly
rather than silently passing.

Usage:
    python scripts/check_schema.py docs/run_record.schema.json ARTIFACT.json
    python scripts/check_schema.py docs/matrix.schema.json matrix.json \
        [--records docs/run_record.schema.json]
    python scripts/check_schema.py docs/serve_protocol.schema.json FRAMES.jsonl \
        --serve-frames [--records docs/run_record.schema.json]
    python scripts/check_schema.py docs/load_snapshot.schema.json \
        load_snapshot.json --load
    python scripts/check_schema.py docs/lint_findings.schema.json \
        lint_findings.json --lint

The ARTIFACT argument may be a glob (quote it so the shell does not
expand it). Zero matching input files is always a failure with a
one-line summary — a glob typo must not pass vacuously.

ARTIFACT.json is a bare RunRecord (kind == "run_record"), a bench
snapshot (kind == "bench_snapshot") whose "records" array holds
RunRecords, a matrix manifest (kind == "matrix_manifest"), or a durable
artifact-store manifest (kind == "store_manifest", validated against
docs/store_manifest.schema.json). For a matrix manifest the gate
additionally asserts that every cell completed (status ok/cached) with
nonzero evals, and — with --records — loads each cell's RunRecord file
(manifest-relative path) and validates it against the record schema.
For a store manifest the gate additionally asserts the generation
invariants (created <= last_used <= generation) and unique addresses.

With --completed, bare records (and bench-snapshot records) must also
pass the cell-completion gate: nonzero evals and n_kept <= n_edges.
CI uses this on the record `examples/embed.rs` emits, so the embedding
example is gated on actually *running* a discovery, not just compiling.

With --serve-frames, the artifact is instead the JSONL frame log that
`examples/serve_client.rs --json PATH` writes from a live `pahq serve`
conversation: one {"direction", "frame"} object per line. Every frame
payload is validated against the schema entry its "type" discriminator
selects (docs/serve_protocol.schema.json `messages` map; unknown types
fail). With --records, each `record` frame's embedded RunRecord payload
is additionally validated against the record schema and the completion
gate — the CI serve-smoke job uses this to pin that the daemon streams
real, schema-valid discovery results, not just well-shaped envelopes.

With --lint, the artifact is the findings JSON a `pahq lint --json`
run emits (docs/lint_findings.schema.json). Beyond the schema subset,
the gate asserts the summary block agrees with the findings array
(total / unsuppressed-error / suppressed counts), that every
suppressed finding carries its pragma justification, and that the
ratchet rows' regression and stale counts match the summary. It does
NOT fail on errors or regressions — that verdict is `pahq lint`'s own
exit code; this check pins that the artifact CI uploads is internally
consistent either way.

With --load, the artifact is the `load_snapshot.json` a `pahq load
--json` run emits. Beyond the schema subset, the gate asserts the
cross-field invariants the validator cannot express: the latency
quantiles are monotone (p50 <= p90 <= p99 <= max when any request
completed), every submitted request is accounted for
(submitted == ok + failed + cancelled), the per-stage array matches
the scenario's stage count, and the log2 histogram's bucket counts
sum to the overall latency count. The CI load-gate job runs this on
the smoke-scenario snapshot before the perf floors in bench_gate.py
--load are applied.
"""

import glob
import json
import os
import re
import sys

KNOWN_KEYWORDS = {
    "$comment",
    "type",
    "properties",
    "required",
    "items",
    "enum",
    "minimum",
    "pattern",
}

TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
    "null": type(None),
}


class SchemaError(Exception):
    pass


def check(value, schema, path="$"):
    unknown = set(schema) - KNOWN_KEYWORDS
    if unknown:
        raise SchemaError(f"{path}: schema uses unsupported keywords {sorted(unknown)}")

    if "enum" in schema:
        if value not in schema["enum"]:
            raise SchemaError(f"{path}: {value!r} not in enum {schema['enum']}")
        return

    t = schema.get("type")
    if t == "number":
        if not isinstance(value, (int, float)) or isinstance(value, bool):
            raise SchemaError(f"{path}: expected number, got {type(value).__name__}")
    elif t == "integer":
        ok = isinstance(value, int) and not isinstance(value, bool)
        # JSON emitters may write 3 as 3.0; accept integral floats
        ok = ok or (isinstance(value, float) and value.is_integer())
        if not ok:
            raise SchemaError(f"{path}: expected integer, got {value!r}")
    elif t is not None:
        py = TYPES.get(t)
        if py is None:
            raise SchemaError(f"{path}: unsupported type {t!r} in schema")
        if not isinstance(value, py):
            raise SchemaError(f"{path}: expected {t}, got {type(value).__name__}")

    if "minimum" in schema and isinstance(value, (int, float)) and not isinstance(value, bool):
        if value < schema["minimum"]:
            raise SchemaError(f"{path}: {value} < minimum {schema['minimum']}")

    if "pattern" in schema and isinstance(value, str):
        if not re.search(schema["pattern"], value):
            raise SchemaError(f"{path}: {value!r} does not match /{schema['pattern']}/")

    if isinstance(value, dict):
        for key in schema.get("required", []):
            if key not in value:
                raise SchemaError(f"{path}: missing required key {key!r}")
        for key, sub in schema.get("properties", {}).items():
            if key in value:
                check(value[key], sub, f"{path}.{key}")

    if isinstance(value, list) and "items" in schema:
        for i, item in enumerate(value):
            check(item, schema["items"], f"{path}[{i}]")


def extract_records(doc):
    kind = doc.get("kind") if isinstance(doc, dict) else None
    if kind == "run_record":
        return [doc]
    if kind == "bench_snapshot":
        records = doc.get("records", [])
        if not isinstance(records, list):
            raise SchemaError("bench_snapshot.records is not an array")
        return records
    raise SchemaError(f"unrecognized artifact kind {kind!r}")


def check_matrix(doc, schema, manifest_path, records_schema, completed=False):
    """Validate a matrix manifest, its completion gate, and (optionally)
    every cell's RunRecord file against the record schema. With
    ``completed``, each loaded cell record additionally passes the
    bare-record completion gate (the per-cell n_evals check always
    runs regardless)."""
    check(doc, schema, "$")
    cells = doc.get("cells", [])
    if not cells:
        raise SchemaError("matrix manifest has no cells")
    n_records = 0
    base = os.path.dirname(manifest_path)
    for i, cell in enumerate(cells):
        where = f"$.cells[{i}]"
        if cell.get("status") not in ("ok", "cached"):
            raise SchemaError(
                f"{where}: status {cell.get('status')!r} "
                f"({cell.get('error', 'no error message')})"
            )
        if not cell.get("n_evals"):
            raise SchemaError(f"{where}: cell completed with zero evals")
        if records_schema is not None:
            rel = cell.get("record")
            if not rel:
                raise SchemaError(f"{where}: completed cell has no record path")
            rec_path = os.path.join(base, rel)
            try:
                with open(rec_path) as f:
                    rec = json.load(f)
            except OSError as e:
                raise SchemaError(f"{where}: cannot read record {rel!r}: {e}")
            check(rec, records_schema, f"{where}.record")
            if not rec.get("n_evals"):
                raise SchemaError(f"{where}: record {rel!r} reports zero evals")
            if completed:
                check_completed(rec, f"{where}.record")
            n_records += 1
    return len(cells), n_records


def check_store(doc, schema):
    """Validate a durable-store manifest plus the generation invariants
    the subset validator cannot express."""
    check(doc, schema, "$")
    generation = doc["generation"]
    seen = set()
    for i, entry in enumerate(doc.get("entries", [])):
        where = f"$.entries[{i}]"
        addr = entry["address"]
        if addr in seen:
            raise SchemaError(f"{where}: duplicate address {addr!r}")
        seen.add(addr)
        if not entry["created"] <= entry["last_used"] <= generation:
            raise SchemaError(
                f"{where}: created {entry['created']} <= last_used "
                f"{entry['last_used']} <= generation {generation} violated"
            )
    return len(seen)


DIRECTIONS = ("client->server", "server->client")


def check_serve_frames(path, schema, records_schema):
    """Validate every frame of a serve conversation log against the
    per-type message schemas, returning per-type frame counts."""
    if schema.get("kind") != "serve_protocol":
        raise SchemaError(f"schema kind {schema.get('kind')!r} is not 'serve_protocol'")
    messages = schema.get("messages")
    if not isinstance(messages, dict) or not messages:
        raise SchemaError("serve_protocol schema has no `messages` map")
    counts = {}
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            where = f"{os.path.basename(path)}:{lineno}"
            try:
                entry = json.loads(line)
            except ValueError as e:
                raise SchemaError(f"{where}: not JSON: {e}")
            if not isinstance(entry, dict):
                raise SchemaError(f"{where}: expected a {{direction, frame}} object")
            if entry.get("direction") not in DIRECTIONS:
                raise SchemaError(f"{where}: direction {entry.get('direction')!r} invalid")
            frame = entry.get("frame")
            if not isinstance(frame, dict):
                raise SchemaError(f"{where}: missing `frame` object")
            kind = frame.get("type")
            msg_schema = messages.get(kind)
            if msg_schema is None:
                raise SchemaError(f"{where}: unknown frame type {kind!r}")
            check(frame, msg_schema, f"{where}.frame")
            if kind == "record" and records_schema is not None:
                check(frame["record"], records_schema, f"{where}.frame.record")
                check_completed(frame["record"], f"{where}.frame.record")
            counts[kind] = counts.get(kind, 0) + 1
    if not counts:
        raise SchemaError(f"{path}: frame log is empty")
    return counts


def check_load(doc, schema):
    """Validate a load snapshot plus the cross-field invariants the
    subset validator cannot express."""
    if doc.get("kind") != "load_snapshot":
        raise SchemaError(f"artifact kind {doc.get('kind')!r} is not 'load_snapshot'")
    check(doc, schema, "$")

    lat = doc["latency_us"]
    if lat["count"] > 0:
        if not lat["p50"] <= lat["p90"] <= lat["p99"] <= lat["max"]:
            raise SchemaError(
                f"$.latency_us: quantiles not monotone: p50 {lat['p50']} / "
                f"p90 {lat['p90']} / p99 {lat['p99']} / max {lat['max']}"
            )

    req = doc["requests"]
    if req["submitted"] != req["ok"] + req["failed"] + req["cancelled"]:
        raise SchemaError(
            f"$.requests: submitted {req['submitted']} != "
            f"ok {req['ok']} + failed {req['failed']} + cancelled {req['cancelled']}"
        )

    stages = doc["stages"]
    want = doc["scenario"]["stages"]
    if not stages:
        raise SchemaError("$.stages: empty — a load run always has >= 1 stage")
    if len(stages) != want:
        raise SchemaError(
            f"$.stages: {len(stages)} stage row(s) but scenario.stages is {want}"
        )
    for i, st in enumerate(stages):
        slat = st["latency_us"]
        if slat["count"] > 0 and not slat["p50"] <= slat["p99"] <= slat["max"]:
            raise SchemaError(f"$.stages[{i}].latency_us: quantiles not monotone")

    hist_total = sum(doc["histogram"]["counts"])
    if hist_total != lat["count"]:
        raise SchemaError(
            f"$.histogram: bucket counts sum to {hist_total} but "
            f"latency_us.count is {lat['count']}"
        )
    return req["submitted"], lat["count"]


def check_lint(doc, schema):
    """Validate a `pahq lint --json` findings artifact plus the
    cross-field invariants the subset validator cannot express."""
    if doc.get("kind") != "lint_findings":
        raise SchemaError(f"artifact kind {doc.get('kind')!r} is not 'lint_findings'")
    check(doc, schema, "$")

    summary = doc["summary"]
    findings = doc["findings"]
    if summary["findings"] != len(findings):
        raise SchemaError(
            f"$.summary.findings is {summary['findings']} but the findings "
            f"array has {len(findings)} entries"
        )
    errors = sum(1 for f in findings if f["severity"] == "error" and not f["suppressed"])
    if summary["errors"] != errors:
        raise SchemaError(
            f"$.summary.errors is {summary['errors']} but {errors} unsuppressed "
            f"error finding(s) are listed"
        )
    suppressed = sum(1 for f in findings if f["suppressed"])
    if summary["suppressed"] != suppressed:
        raise SchemaError(
            f"$.summary.suppressed is {summary['suppressed']} but {suppressed} "
            f"finding(s) are marked suppressed"
        )
    for i, f in enumerate(findings):
        if f["suppressed"] and not f.get("justification"):
            raise SchemaError(
                f"$.findings[{i}]: suppressed without a justification — the "
                f"pragma contract requires one"
            )
    regressions = sum(1 for r in doc["ratchet"] if r["count"] > r["baseline"])
    if summary["regressions"] != regressions:
        raise SchemaError(
            f"$.summary.regressions is {summary['regressions']} but the ratchet "
            f"rows show {regressions} regression(s)"
        )
    stale = sum(1 for r in doc["ratchet"] if r["count"] < r["baseline"])
    if summary["stale_baseline"] != stale:
        raise SchemaError(
            f"$.summary.stale_baseline is {summary['stale_baseline']} but the "
            f"ratchet rows show {stale} stale row(s)"
        )
    return len(findings), errors, regressions


def expand_artifacts(arg):
    """The artifact paths an argument names: a glob expansion when it
    contains glob metacharacters, else the literal path if it exists.
    Empty means zero inputs — the caller must fail, not pass."""
    if any(ch in arg for ch in "*?["):
        return sorted(glob.glob(arg))
    return [arg] if os.path.exists(arg) else []


def check_completed(rec, where):
    """The cell-completion gate, applied to a bare record."""
    if not rec.get("n_evals"):
        raise SchemaError(f"{where}: record reports zero evals")
    if rec.get("n_kept", 0) > rec.get("n_edges", 0):
        raise SchemaError(
            f"{where}: n_kept {rec.get('n_kept')} exceeds n_edges {rec.get('n_edges')}"
        )


def check_one(path, schema, records_schema, completed, serve_frames, load_snapshot, lint):
    if serve_frames:
        counts = check_serve_frames(path, schema, records_schema)
        total = sum(counts.values())
        breakdown = ", ".join(f"{k}:{v}" for k, v in sorted(counts.items()))
        print(f"schema check OK: {total} serve frame(s) valid ({breakdown})")
        return
    try:
        with open(path) as f:
            doc = json.load(f)
    except OSError as e:
        raise SchemaError(f"cannot read artifact {path!r}: {e}")
    if lint:
        n_findings, errors, regressions = check_lint(doc, schema)
        print(
            f"schema check OK: lint findings artifact consistent "
            f"({n_findings} finding(s), {errors} error(s), "
            f"{regressions} regression(s))"
        )
        return
    if load_snapshot:
        submitted, completed_reqs = check_load(doc, schema)
        print(
            f"schema check OK: load snapshot "
            f"({doc['scenario']['spec']}, mode {doc['mode']}): "
            f"{submitted} request(s) submitted, {completed_reqs} latency sample(s)"
        )
        return
    if isinstance(doc, dict) and doc.get("kind") == "store_manifest":
        n_entries = check_store(doc, schema)
        print(
            f"schema check OK: store manifest at generation "
            f"{doc['generation']} with {n_entries} entr(y/ies)"
        )
        return
    if isinstance(doc, dict) and doc.get("kind") == "matrix_manifest":
        n_cells, n_records = check_matrix(doc, schema, path, records_schema, completed)
        print(
            f"schema check OK: matrix manifest with {n_cells} completed cell(s)"
            + (f", {n_records} record(s) valid" if records_schema else "")
        )
        return
    records = extract_records(doc)
    if not records:
        raise SchemaError("artifact contains no RunRecords to validate")
    for i, rec in enumerate(records):
        check(rec, schema, f"records[{i}]")
        if completed:
            check_completed(rec, f"records[{i}]")
    version = schema["properties"]["schema_version"]["enum"][0]
    print(f"schema check OK: {len(records)} record(s) valid against v{version}")


def main(argv):
    records_schema_path = None
    completed = False
    serve_frames = False
    load_snapshot = False
    lint = False
    if "--completed" in argv:
        completed = True
        argv = [a for a in argv if a != "--completed"]
    if "--serve-frames" in argv:
        serve_frames = True
        argv = [a for a in argv if a != "--serve-frames"]
    if "--load" in argv:
        load_snapshot = True
        argv = [a for a in argv if a != "--load"]
    if "--lint" in argv:
        lint = True
        argv = [a for a in argv if a != "--lint"]
    if "--records" in argv:
        i = argv.index("--records")
        if i + 1 >= len(argv):
            print(__doc__)
            return 2
        records_schema_path = argv[i + 1]
        argv = argv[:i] + argv[i + 2 :]
    if len(argv) != 3:
        print(__doc__)
        return 2
    with open(argv[1]) as f:
        schema = json.load(f)
    records_schema = None
    if records_schema_path is not None:
        with open(records_schema_path) as f:
            records_schema = json.load(f)
    artifacts = expand_artifacts(argv[2])
    if not artifacts:
        print(
            f"schema check FAILED: zero input files for {argv[2]!r} "
            f"(glob typo? an empty input set never passes)"
        )
        return 1
    for path in artifacts:
        try:
            check_one(
                path, schema, records_schema, completed, serve_frames, load_snapshot, lint
            )
        except SchemaError as e:
            print(f"schema check FAILED: {path}: {e}")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
