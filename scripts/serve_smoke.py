#!/usr/bin/env python3
"""End-to-end smoke test for the `pahq serve` daemon (stdlib-only).

Boots the daemon on an ephemeral loopback port, then drives it with the
real wire client (``examples/serve_client.rs``) exactly the way the
protocol doc promises it works:

1. two *concurrent* clients — one single-run submission, one matrix
   submission — stream their jobs to ``done`` at the same time through
   the shared worker pool and artifact store;
2. a third client submits the matrix and immediately cancels it,
   exercising the cancel path (in-flight cells finish, queued cells
   drop, the terminal ``done`` still accounts for every cell);
3. every frame of every conversation is schema-validated against
   ``docs/serve_protocol.schema.json`` (and each streamed RunRecord
   against ``docs/run_record.schema.json`` plus the completion gate)
   via ``check_schema.py --serve-frames``;
4. a ``shutdown`` request drains the daemon, which must exit 0 within
   the timeout — no orphaned threads, no hung sockets.

Usage:
    python scripts/serve_smoke.py PAHQ_BIN SERVE_CLIENT_BIN [LOG_DIR]
    (e.g. target/release/pahq target/release/examples/serve_client)

LOG_DIR is where the per-conversation frame logs land (created if
missing); CI passes a workspace path so the logs upload as artifacts
even when a step fails. Without it, a fresh temp dir is used.
"""

import json
import os
import socket
import subprocess
import sys
import tempfile
import time

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
SCHEMA = os.path.join(REPO, "docs", "serve_protocol.schema.json")
RECORD_SCHEMA = os.path.join(REPO, "docs", "run_record.schema.json")

CLIENT_TIMEOUT = 120  # per client conversation, seconds
SHUTDOWN_TIMEOUT = 60  # daemon exit after shutdown_ack, seconds

sys.path.insert(0, HERE)
from check_schema import SchemaError, check_serve_frames  # noqa: E402


def free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def wait_listening(addr, proc, deadline):
    host, port = addr.split(":")
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            sys.exit(f"daemon exited early with code {proc.returncode}")
        try:
            with socket.create_connection((host, int(port)), timeout=1):
                return
        except OSError:
            time.sleep(0.1)
    sys.exit("daemon never started listening")


def frames(log_path):
    with open(log_path) as f:
        return [json.loads(line)["frame"] for line in f if line.strip()]


def validate(log_path):
    try:
        with open(SCHEMA) as f:
            schema = json.load(f)
        with open(RECORD_SCHEMA) as f:
            record_schema = json.load(f)
        counts = check_serve_frames(log_path, schema, record_schema)
    except SchemaError as e:
        sys.exit(f"schema check FAILED for {log_path}: {e}")
    print(f"  {os.path.basename(log_path)}: {sum(counts.values())} frames schema-valid")
    return counts


def check_accounted(log_path, expect_records=None):
    """The per-job bookkeeping invariant: done accounts for every
    accepted cell, and nothing failed."""
    fs = frames(log_path)
    accepted = [f for f in fs if f["type"] == "accepted"]
    done = [f for f in fs if f["type"] == "done"]
    records = [f for f in fs if f["type"] == "record"]
    if len(accepted) != 1 or len(done) != 1:
        sys.exit(f"{log_path}: expected one accepted and one done frame")
    cells = accepted[0]["cells"]
    d = done[0]
    if d["ok"] + d["failed"] + d["cancelled"] != cells:
        sys.exit(f"{log_path}: done {d} does not account for {cells} cells")
    if d["failed"]:
        sys.exit(f"{log_path}: {d['failed']} cell(s) failed")
    if d["ok"] != len(records):
        sys.exit(f"{log_path}: done.ok {d['ok']} != {len(records)} streamed records")
    if expect_records is not None and len(records) != expect_records:
        sys.exit(f"{log_path}: expected {expect_records} records, got {len(records)}")
    return d


def main(argv):
    if len(argv) not in (3, 4):
        print(__doc__)
        return 2
    pahq, client = argv[1], argv[2]
    port = free_port()
    addr = f"127.0.0.1:{port}"
    if len(argv) == 4:
        tmp = argv[3]
        os.makedirs(tmp, exist_ok=True)
    else:
        tmp = tempfile.mkdtemp(prefix="serve_smoke_")
    logs = {name: os.path.join(tmp, f"{name}.jsonl") for name in ("run", "matrix", "cancel")}

    daemon = subprocess.Popen([pahq, "serve", "--addr", addr, "--workers", "2"])
    try:
        wait_listening(addr, daemon, time.monotonic() + 30)
        print(f"daemon up on {addr}")

        # 1. two clients, genuinely concurrent: both conversations are
        # in flight at once, drained by the same shared worker pool
        a = subprocess.Popen([client, addr, "--json", logs["run"]])
        b = subprocess.Popen([client, addr, "--matrix", "--json", logs["matrix"]])
        for name, proc in (("run client", a), ("matrix client", b)):
            if proc.wait(timeout=CLIENT_TIMEOUT) != 0:
                sys.exit(f"{name} failed with code {proc.returncode}")
        print("concurrent run + matrix clients OK")

        # 2. submit-then-cancel: the client asserts the stream stays
        # coherent; we assert the terminal accounting below
        subprocess.run(
            [client, addr, "--cancel", "--json", logs["cancel"]],
            check=True,
            timeout=CLIENT_TIMEOUT,
        )
        print("cancel client OK")

        # 3. every frame of every conversation against the schema
        for log in logs.values():
            validate(log)
        check_accounted(logs["run"], expect_records=1)
        d = check_accounted(logs["matrix"], expect_records=8)
        print(f"matrix job accounted: {d['ok']} ok")
        d = check_accounted(logs["cancel"])
        print(f"cancel job accounted: {d['ok']} ok, {d['cancelled']} cancelled")
        if not any(f["type"] == "cancel_ack" for f in frames(logs["cancel"])):
            sys.exit("cancel conversation has no cancel_ack frame")

        # 4. clean shutdown within the timeout
        subprocess.run([client, addr, "--shutdown"], check=True, timeout=CLIENT_TIMEOUT)
        code = daemon.wait(timeout=SHUTDOWN_TIMEOUT)
        if code != 0:
            sys.exit(f"daemon exited {code} after shutdown")
        print("daemon drained and exited 0")
    finally:
        if daemon.poll() is None:
            daemon.kill()
            daemon.wait()
    print("serve smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
