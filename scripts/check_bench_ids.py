#!/usr/bin/env python3
"""Lint the criterion bench suites for ID hygiene (stdlib-only).

Walks every ``rust/benches/*.rs`` file and enforces three rules the
compiler cannot:

1. **No duplicate bench IDs.** Criterion silently lets two
   ``bench_function`` calls share a name; the second one's results then
   overwrite the first in reports and the bench-smoke logs become
   ambiguous. Duplicates are checked per group (``group/id``) and
   across bare (group-less) ``c.bench_function`` calls.
2. **No duplicate group names.** Two ``benchmark_group("x")`` scopes —
   in the same file or across files — would interleave their results
   under one heading.
3. **CI timing discipline.** Every ``benchmark_group`` must configure
   the 300 ms warm-up / 1 s measurement / 30 samples discipline the CI
   bench-smoke job budget assumes (see .github/workflows/ci.yml): a
   group that omits it silently runs criterion's defaults (3 s + 5 s,
   100 samples) and blows the job budget ~10x.

The scan is textual, not a Rust parse: ``benchmark_group("name")``
opens a scope that the next ``.finish()`` closes, and bench IDs are
collected from ``bench_function("lit"`` string literals and
``BenchmarkId::new(<expr>, <param>)`` first arguments (kept as the
source expression — two identical expressions with different params
are fine, identical expression+scope twice is what we catch via the
literal form). Dynamic IDs built from ``format!`` are recorded by
their source text, which still catches copy-paste duplicates.

Usage:
    python scripts/check_bench_ids.py [BENCH_DIR]
"""

import os
import re
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_DIR = os.path.join(os.path.dirname(HERE), "rust", "benches")

DISCIPLINE = [
    "warm_up_time(Duration::from_millis(300))",
    "measurement_time(Duration::from_secs(1))",
    "sample_size(30)",
]

GROUP_RE = re.compile(r'benchmark_group\(\s*"([^"]+)"\s*\)')
LIT_ID_RE = re.compile(r'bench_function\(\s*"([^"]+)"')
BENCHMARK_ID_RE = re.compile(r"BenchmarkId::new\(\s*([^,]+?)\s*,")
FINISH_RE = re.compile(r"\.finish\(\)")


def strip_comments(text):
    """Drop // line comments so commented-out benches don't count."""
    return "\n".join(line.split("//", 1)[0] for line in text.splitlines())


def lint_file(path, groups_seen, bare_ids_seen):
    """Scan one bench source; returns a list of problem strings."""
    with open(path) as f:
        text = strip_comments(f.read())
    name = os.path.basename(path)
    problems = []

    # Split the file into group scopes: benchmark_group(..) .. .finish()
    # with everything outside a scope treated as bare-Criterion territory.
    events = []
    for m in GROUP_RE.finditer(text):
        events.append((m.start(), "open", m.group(1)))
    for m in FINISH_RE.finditer(text):
        events.append((m.start(), "close", None))
    events.sort()

    current = None  # (group_name, scope_start)
    scopes = []  # (group_name, start, end)
    bare_ranges = []
    last_end = 0
    for pos, kind, gname in events:
        if kind == "open":
            if current is not None:
                problems.append(
                    f"{name}: group '{current[0]}' is never .finish()ed "
                    f"before group '{gname}' opens"
                )
                scopes.append((current[0], current[1], pos))
            bare_ranges.append((last_end, pos))
            current = (gname, pos)
        else:
            if current is None:
                continue  # .finish() on something else (no open group)
            scopes.append((current[0], current[1], pos))
            last_end = pos
            current = None
    if current is not None:
        problems.append(f"{name}: group '{current[0]}' is never .finish()ed")
        scopes.append((current[0], current[1], len(text)))
        last_end = len(text)
    bare_ranges.append((last_end, len(text)))

    for gname, start, end in scopes:
        if gname in groups_seen:
            problems.append(
                f"{name}: duplicate group name '{gname}' (also in {groups_seen[gname]})"
            )
        else:
            groups_seen[gname] = name
        body = text[start:end]
        for call in DISCIPLINE:
            if call not in body:
                problems.append(
                    f"{name}: group '{gname}' is missing the CI timing "
                    f"discipline call .{call}"
                )
        ids = {}
        for m in LIT_ID_RE.finditer(body):
            ids.setdefault(m.group(1), 0)
            ids[m.group(1)] += 1
        for m in BENCHMARK_ID_RE.finditer(body):
            # parameterized IDs: the (expr, param) pair disambiguates,
            # so only flag a *literal* expression repeated verbatim
            # when it is a plain string literal (same id, same scope)
            expr = m.group(1)
            if expr.startswith('"') and expr.endswith('"'):
                ids.setdefault(expr, 0)
        dupes = sorted(k for k, n in ids.items() if n > 1)
        for d in dupes:
            problems.append(f"{name}: duplicate bench id '{gname}/{d}'")

    for start, end in bare_ranges:
        for m in LIT_ID_RE.finditer(text[start:end]):
            bid = m.group(1)
            if bid in bare_ids_seen:
                problems.append(
                    f"{name}: duplicate bare bench id '{bid}' "
                    f"(also in {bare_ids_seen[bid]})"
                )
            else:
                bare_ids_seen[bid] = name
    return problems


def main(argv):
    bench_dir = argv[1] if len(argv) > 1 else DEFAULT_DIR
    try:
        names = os.listdir(bench_dir)
    except OSError:
        print(
            f"bench-id lint FAILED: zero input files — {bench_dir!r} is not "
            f"a readable directory (path typo? an empty input set never passes)"
        )
        return 1
    files = sorted(os.path.join(bench_dir, f) for f in names if f.endswith(".rs"))
    if not files:
        print(
            f"bench-id lint FAILED: zero input files — no .rs files under "
            f"{bench_dir!r} (path typo? an empty input set never passes)"
        )
        return 1
    groups_seen = {}
    bare_ids_seen = {}
    problems = []
    for path in files:
        problems.extend(lint_file(path, groups_seen, bare_ids_seen))
    if problems:
        print("bench-id lint FAILED:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(
        f"bench-id lint OK: {len(files)} file(s), {len(groups_seen)} group(s), "
        f"{len(bare_ids_seen)} bare id(s), discipline present everywhere"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
