//! Vendored minimal `anyhow` — this box has no crates.io access, so the
//! subset of the anyhow 1.x API the workspace uses is implemented
//! in-repo: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros, and the [`Context`] extension trait for `Result`
//! and `Option`.
//!
//! Differences from upstream (deliberate, to stay tiny):
//! - the source-error chain is flattened into the message at conversion
//!   time instead of being retained as `dyn Error` links;
//! - no backtrace capture;
//! - `Error` implements `Display`/`Debug` but not `std::error::Error`
//!   (matching upstream, which relies on that to keep the blanket
//!   `From<E: Error>` impl coherent).

use std::fmt;

/// A flattened, context-prefixed error message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prefix the error with higher-level context.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_prefixes() {
        let e: Result<()> = Err(anyhow!("inner {}", 7));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 7");
        let n: Option<u32> = None;
        let e = n.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).is_err());
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
    }
}
