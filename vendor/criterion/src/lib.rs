//! Vendored minimal `criterion` — an offline, API-compatible subset of
//! criterion 0.5 covering what the `pahq` bench targets use:
//!
//! - [`Criterion`] with [`criterion_group!`] / [`criterion_main!`];
//! - [`Criterion::bench_function`] and [`Criterion::benchmark_group`]
//!   with per-group `warm_up_time` / `measurement_time` / `sample_size`;
//! - [`Bencher::iter`], [`black_box`], [`BenchmarkId`];
//! - a CLI filter (first free argument, as `cargo bench -- <filter>`
//!   passes it) so CI can run a single short smoke group.
//!
//! Measurement: after a warm-up phase, the iteration count per sample is
//! calibrated so one sample lasts ~`measurement_time / sample_size`,
//! then `sample_size` samples are timed and summarized as
//! `[min median max]` per-iteration times — the same headline triple
//! criterion prints. No plotting, no statistics beyond that.

use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// Benchmark identifier; `BenchmarkId::new("fn", param)` formats as
/// `fn/param` like upstream.
pub struct BenchmarkId(String);

impl BenchmarkId {
    pub fn new<P: std::fmt::Display>(function_name: &str, parameter: P) -> BenchmarkId {
        BenchmarkId(format!("{function_name}/{parameter}"))
    }

    pub fn from_parameter<P: std::fmt::Display>(parameter: P) -> BenchmarkId {
        BenchmarkId(parameter.to_string())
    }
}

impl From<BenchmarkId> for String {
    fn from(id: BenchmarkId) -> String {
        id.0
    }
}

impl From<&str> for BenchmarkId {
    fn from(s: &str) -> BenchmarkId {
        BenchmarkId(s.to_string())
    }
}

impl From<String> for BenchmarkId {
    fn from(s: String) -> BenchmarkId {
        BenchmarkId(s)
    }
}

#[derive(Clone)]
struct Settings {
    warm_up: Duration,
    measurement: Duration,
    sample_size: usize,
}

impl Default for Settings {
    fn default() -> Settings {
        Settings {
            warm_up: Duration::from_millis(500),
            measurement: Duration::from_secs(2),
            sample_size: 50,
        }
    }
}

pub struct Criterion {
    settings: Settings,
    filter: Option<String>,
    ran: usize,
}

impl Default for Criterion {
    fn default() -> Criterion {
        Criterion { settings: Settings::default(), filter: None, ran: 0 }
    }
}

impl Criterion {
    /// Pick up the benchmark-name filter from the command line. Harness
    /// flags cargo forwards (`--bench`, `--nocapture`, ...) are ignored;
    /// the first free argument becomes the substring filter.
    pub fn configure_from_args(mut self) -> Criterion {
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            match a.as_str() {
                "--bench" | "--test" | "--nocapture" | "--quiet" | "--verbose" | "--exact" => {}
                "--sample-size" | "--warm-up-time" | "--measurement-time" | "--profile-time" => {
                    let _ = args.next();
                }
                s if s.starts_with("--") => {}
                s => {
                    self.filter = Some(s.to_string());
                }
            }
        }
        self
    }

    pub fn warm_up_time(&mut self, d: Duration) -> &mut Criterion {
        self.settings.warm_up = d;
        self
    }

    pub fn measurement_time(&mut self, d: Duration) -> &mut Criterion {
        self.settings.measurement = d;
        self
    }

    pub fn sample_size(&mut self, n: usize) -> &mut Criterion {
        self.settings.sample_size = n.max(2);
        self
    }

    pub fn bench_function<F>(&mut self, id: impl Into<BenchmarkId>, f: F) -> &mut Criterion
    where
        F: FnMut(&mut Bencher),
    {
        let name = String::from(id.into());
        let settings = self.settings.clone();
        self.run_one(&name, settings, f);
        self
    }

    pub fn benchmark_group(&mut self, name: impl Into<String>) -> BenchmarkGroup<'_> {
        BenchmarkGroup { parent: self, name: name.into(), settings: None }
    }

    /// Print a one-line run summary (called by [`criterion_main!`]).
    pub fn final_summary(&self) {
        println!("\n{} benchmark(s) run", self.ran);
    }

    fn run_one<F: FnMut(&mut Bencher)>(&mut self, name: &str, settings: Settings, mut f: F) {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) {
                return;
            }
        }
        // Calibration pass: one iteration, to size the samples.
        let mut b = Bencher { iters: 1, elapsed: Duration::ZERO };
        f(&mut b);
        let once_ns = (b.elapsed.as_nanos().max(1) as u64).max(1);

        // Warm-up.
        let warm_end = Instant::now() + settings.warm_up;
        while Instant::now() < warm_end {
            f(&mut b);
        }

        // Timed samples.
        let per_sample_ns =
            (settings.measurement.as_nanos() as u64 / settings.sample_size as u64).max(1);
        let iters = (per_sample_ns / once_ns).clamp(1, 10_000_000);
        let mut samples: Vec<f64> = Vec::with_capacity(settings.sample_size);
        for _ in 0..settings.sample_size {
            let mut sb = Bencher { iters, elapsed: Duration::ZERO };
            f(&mut sb);
            samples.push(sb.elapsed.as_nanos() as f64 / iters as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        println!(
            "{:<52} time:   [{} {} {}]  ({} samples x {} iters)",
            name,
            fmt_ns(samples[0]),
            fmt_ns(median),
            fmt_ns(*samples.last().unwrap()),
            samples.len(),
            iters
        );
        self.ran += 1;
    }
}

pub struct BenchmarkGroup<'a> {
    parent: &'a mut Criterion,
    name: String,
    settings: Option<Settings>,
}

impl BenchmarkGroup<'_> {
    fn settings_mut(&mut self) -> &mut Settings {
        self.settings.get_or_insert_with(|| self.parent.settings.clone())
    }

    pub fn warm_up_time(&mut self, d: Duration) -> &mut Self {
        self.settings_mut().warm_up = d;
        self
    }

    pub fn measurement_time(&mut self, d: Duration) -> &mut Self {
        self.settings_mut().measurement = d;
        self
    }

    pub fn sample_size(&mut self, n: usize) -> &mut Self {
        self.settings_mut().sample_size = n.max(2);
        self
    }

    pub fn bench_function<F>(&mut self, id: impl Into<BenchmarkId>, f: F) -> &mut Self
    where
        F: FnMut(&mut Bencher),
    {
        let name = format!("{}/{}", self.name, String::from(id.into()));
        let settings = self.settings.clone().unwrap_or_else(|| self.parent.settings.clone());
        self.parent.run_one(&name, settings, f);
        self
    }

    pub fn finish(self) {}
}

pub struct Bencher {
    iters: u64,
    elapsed: Duration,
}

impl Bencher {
    pub fn iter<O, F: FnMut() -> O>(&mut self, mut f: F) {
        let t0 = Instant::now();
        for _ in 0..self.iters {
            black_box(f());
        }
        self.elapsed = t0.elapsed();
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Collect benchmark functions into a group runner, criterion-style.
#[macro_export]
macro_rules! criterion_group {
    ($name:ident, $($target:path),+ $(,)?) => {
        pub fn $name(c: &mut $crate::Criterion) {
            $( $target(c); )+
        }
    };
}

/// Entry point running every group, criterion-style.
#[macro_export]
macro_rules! criterion_main {
    ($($group:path),+ $(,)?) => {
        fn main() {
            let mut c = $crate::Criterion::default().configure_from_args();
            $( $group(&mut c); )+
            c.final_summary();
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_function_measures() {
        let mut c = Criterion::default();
        c.measurement_time(Duration::from_millis(40))
            .warm_up_time(Duration::from_millis(5))
            .sample_size(5);
        c.bench_function("sum", |b| b.iter(|| (0..100u64).sum::<u64>()));
        assert_eq!(c.ran, 1);
    }

    #[test]
    fn filter_skips_nonmatching() {
        let mut c = Criterion { filter: Some("nope".into()), ..Criterion::default() };
        c.sample_size(2)
            .measurement_time(Duration::from_millis(5))
            .warm_up_time(Duration::from_millis(1));
        c.bench_function("sum", |b| b.iter(|| 1u32));
        assert_eq!(c.ran, 0);
    }

    #[test]
    fn group_overrides_settings() {
        let mut c = Criterion::default();
        let mut g = c.benchmark_group("g");
        g.warm_up_time(Duration::from_millis(1))
            .measurement_time(Duration::from_millis(10))
            .sample_size(3);
        g.bench_function(BenchmarkId::new("f", 4), |b| b.iter(|| black_box(2 + 2)));
        g.finish();
        assert_eq!(c.ran, 1);
    }
}
