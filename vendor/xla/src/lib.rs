//! Stub of the `xla` (xla_extension 0.5.x) PJRT bindings.
//!
//! The real bindings need the xla_extension C++ runtime, which is not
//! vendorable here. This stub keeps the exact API surface
//! `pahq::runtime` consumes so the workspace builds and tests run on any
//! machine: every entry point that would touch PJRT returns
//! [`Error::unavailable`], which `pahq` surfaces as "artifacts not
//! built" and the artifact-driven tests skip on — the same graceful
//! degradation path as a checkout without `make artifacts`.
//!
//! Swapping in the real bindings is a one-line change in the workspace
//! manifest (point the `xla` dependency at the real crate); no source
//! change in `pahq` is needed.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(pub String);

impl Error {
    fn unavailable() -> Error {
        Error(
            "xla_extension runtime is not vendored in this build; \
             PJRT execution is disabled"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// Host-side literal (stub: constructible, but device round-trips fail).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e}").contains("not vendored"));
        assert!(format!("{e:?}").contains("not vendored"));
    }

    #[test]
    fn literals_construct_but_do_not_execute() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_ok());
        assert!(lit.array_shape().is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
