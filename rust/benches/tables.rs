//! `cargo bench` target that regenerates reduced-size versions of every
//! table and figure in the paper (DESIGN.md §5) and times each one.
//! Full-size artifacts: `pahq all` (or `pahq table N` / `pahq figure N`).
//!
//! Each step runs in a fresh `pahq` subprocess: XLA's compile-time arenas
//! for the large gradient artifacts (Tab. 7's scale models) are only
//! returned to the OS at process exit, and sharing one process across
//! all eleven steps can trip the OOM killer. Falls back to in-process
//! execution if the binary isn't built.

use std::path::PathBuf;
use std::time::Instant;

fn pahq_bin() -> Option<PathBuf> {
    // the workspace target dir lives at the repo root; a package-local
    // target/ is also checked for non-workspace checkouts
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    [manifest.join("../target/release/pahq"), manifest.join("target/release/pahq")]
        .into_iter()
        .find(|p| p.exists())
}

fn main() {
    // sweep-heavy: use the value-identical pure-jnp attention build
    // (the Pallas build is validated separately; see aot.py)
    if std::env::var("PAHQ_ATTN").is_err() {
        std::env::set_var("PAHQ_ATTN", "ref");
    }
    let steps: &[(&str, &str, &str, fn(bool) -> anyhow::Result<()>)] = &[
        ("figure1 (ROC curves)", "figure", "1", pahq::experiments::figure1),
        ("table1 (AUC-ROC all methods)", "table", "1", pahq::experiments::table1),
        ("table2 (accuracy grid)", "table", "2", pahq::experiments::table2),
        ("table3 (runtime/memory)", "table", "3", pahq::experiments::table3),
        ("table4 (scheduler ablation)", "table", "4", pahq::experiments::table4),
        ("table5 (precision ablation)", "table", "5", pahq::experiments::table5),
        ("table6 (faithfulness)", "table", "6", pahq::experiments::table6),
        ("table7 (scaling)", "table", "7", pahq::experiments::table7),
        ("table8 (edge pruning)", "table", "8", pahq::experiments::table8),
        ("figure3 (edge curve)", "figure", "3", pahq::experiments::figure3),
        ("figure4 (quant strategy)", "figure", "4", pahq::experiments::figure4),
    ];
    let bin = pahq_bin();
    let mut failures = 0;
    for (name, kind, arg, f) in steps {
        let t0 = Instant::now();
        let ok = match &bin {
            Some(bin) => std::process::Command::new(bin)
                .args([kind, arg, "--quick"])
                .env("PAHQ_ATTN", std::env::var("PAHQ_ATTN").unwrap_or_default())
                .status()
                .map(|s| s.success())
                .unwrap_or(false),
            None => f(true).map_err(|e| eprintln!("{name}: {e}")).is_ok(),
        };
        if ok {
            println!("\n[bench-tables] {name}: {:.1}s\n", t0.elapsed().as_secs_f64());
        } else {
            failures += 1;
            eprintln!("\n[bench-tables] {name} FAILED\n");
        }
    }
    if failures > 0 {
        eprintln!("[bench-tables] {failures} step(s) failed");
        std::process::exit(1);
    }
}
