//! Micro-benchmarks of the L3 hot path (criterion harness; the vendored
//! shim in `vendor/criterion` provides the same API offline).
//!
//! Covers: residual assembly primitives (plain f32 and fused
//! packed-decode), quant codecs, quantized accumulation, the DES edge
//! simulation, manifest JSON parsing, the
//! serial-vs-batched sweep engine (the headline group: wall-clock win of
//! `acdc::sweep` at 2/4/8 workers on a synthetic damage surface with a
//! realistic per-eval cost floor), and — when artifacts are built — the
//! full patched forward. Results feed EXPERIMENTS.md §Perf.
//!
//! CI smoke: `cargo bench --bench hot_paths -- sweep` and
//! `cargo bench --bench hot_paths -- packed_assembly` each run one short
//! group (300 ms warm-up, 1 s measurement, 30 samples).

use std::time::Duration;

use criterion::{black_box, criterion_group, criterion_main, BenchmarkId, Criterion};

use pahq::acdc::sweep::{self, SyntheticSurface};
use pahq::acdc::{Candidate, FnScorer, SweepMode};
use pahq::gpu_sim::memory::MethodKind;
use pahq::gpu_sim::{CostModel, RealArch};
use pahq::metrics::Objective;
use pahq::model::Graph;
use pahq::patching::{PatchMask, PatchedForward, Policy};
use pahq::quant::{self, BF16, FP4_E2M1, FP8_E4M3};
use pahq::tensor::{self, QTensor};
use pahq::util::json::Json;
use pahq::util::rng::Rng;

fn bench_assembly(c: &mut Criterion) {
    let mut rng = Rng::new(42);
    let mut g = c.benchmark_group("assembly");
    g.warm_up_time(Duration::from_millis(300))
        .measurement_time(Duration::from_secs(1))
        .sample_size(30);
    for n in [20_480usize, 163_840] {
        let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut dst = a.clone();
        g.bench_function(BenchmarkId::new("add_assign", n), |bch| {
            bch.iter(|| tensor::add_assign(black_box(&mut dst), black_box(&b)))
        });
        let mut dst2 = a.clone();
        g.bench_function(BenchmarkId::new("add_sub_assign", n), |bch| {
            bch.iter(|| {
                tensor::add_sub_assign(black_box(&mut dst2), black_box(&a), black_box(&b))
            })
        });
    }
    g.finish();
}

/// Residual assembly against *packed* storage: the word-parallel fused
/// decode-accumulate kernels vs (a) the plain f32 add they replace and
/// (b) the retained scalar decode path (`decode_range_into_scalar` +
/// f32 add) that PR 7 vectorized away. At fp8 the fused kernel touches
/// 1/4 of the bytes per source operand; the `scalar_ref_*` entries make
/// the scalar-vs-word-parallel speedup visible in one run
/// (EXPERIMENTS.md §Perf; CI smoke runs this group with the same
/// 300 ms / 1 s / 30-sample discipline as `sweep`).
fn bench_packed_assembly(c: &mut Criterion) {
    let mut rng = Rng::new(43);
    let n = 163_840usize;
    let src: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let base: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let mut g = c.benchmark_group("packed_assembly");
    g.warm_up_time(Duration::from_millis(300))
        .measurement_time(Duration::from_secs(1))
        .sample_size(30);
    let mut dst = base.clone();
    g.bench_function(BenchmarkId::new("add_assign_f32", n), |bch| {
        bch.iter(|| tensor::add_assign(black_box(&mut dst), black_box(&src)))
    });
    for (label, fmt) in [("fp8_e4m3", FP8_E4M3), ("fp4_e2m1", FP4_E2M1), ("bf16", BF16)] {
        let qt = QTensor::from_slice(&[n], &src, fmt);
        let mut dstq = base.clone();
        g.bench_function(BenchmarkId::new(&format!("add_assign_packed_{label}"), n), |bch| {
            bch.iter(|| tensor::add_assign_packed(black_box(&mut dstq), black_box(&qt)))
        });
        let mut dsts = base.clone();
        let mut scratch = vec![0.0f32; n];
        g.bench_function(BenchmarkId::new(&format!("scalar_ref_{label}"), n), |bch| {
            bch.iter(|| {
                qt.decode_range_into_scalar(0, black_box(&mut scratch));
                tensor::add_assign(black_box(&mut dsts), black_box(&scratch));
            })
        });
    }
    let qt = QTensor::from_slice(&[n], &src, FP8_E4M3);
    let mut dstp = base.clone();
    g.bench_function(BenchmarkId::new("add_sub_assign_packed_fp8_e4m3", n), |bch| {
        bch.iter(|| {
            tensor::add_sub_assign_packed(black_box(&mut dstp), black_box(&qt), black_box(&src))
        })
    });
    g.finish();
}

fn bench_quant(c: &mut Criterion) {
    let mut rng = Rng::new(42);
    let xs: Vec<f32> = (0..65_536).map(|_| rng.normal() * 8.0).collect();
    let mut buf = xs.clone();
    let mut g = c.benchmark_group("quant");
    g.warm_up_time(Duration::from_millis(300))
        .measurement_time(Duration::from_secs(1))
        .sample_size(30);
    g.bench_function("fq_slice_64k_e4m3", |bch| {
        bch.iter(|| {
            buf.copy_from_slice(&xs);
            quant::fq_slice(black_box(&mut buf), FP8_E4M3);
        })
    });
    let mut acc = vec![0.0f32; 20_480];
    let src: Vec<f32> = (0..20_480).map(|_| rng.normal()).collect();
    g.bench_function("accumulate_quantized_20k_e4m3", |bch| {
        bch.iter(|| quant::accumulate_quantized(black_box(&mut acc), black_box(&src), FP8_E4M3))
    });
    g.finish();
}

/// The headline group: the batched sweep engine against its serial
/// reference on an attn-4l-shaped graph. The scorer is the deterministic
/// synthetic surface plus a fixed spin emulating the per-eval cost of a
/// patched forward, so the threading win is measured against a realistic
/// work grain; τ = 0.9 removes ~90% of edges, the regime the chain
/// (predict-remove) speculation is built for.
fn bench_sweep(c: &mut Criterion) {
    let graph = Graph { n_layer: 4, n_head: 8, has_mlp: true };
    let channels = graph.channels();
    let n_channels = channels.len();
    let mut plan: Vec<Vec<Candidate>> = Vec::new();
    let mut order = channels.clone();
    order.reverse();
    for ch in order {
        let ci = channels.iter().position(|c2| *c2 == ch).unwrap();
        let mut srcs = graph.sources(ch);
        srcs.reverse();
        plan.push(
            srcs.into_iter()
                .map(|src| Candidate { chan: ci, src, hi: Some(src) })
                .collect(),
        );
    }
    let surface = SyntheticSurface::new(7, 0.001);
    let score = |m: &PatchMask, cand: Option<&Candidate>| {
        let d = surface.damage(m, cand);
        // deterministic spin (~tens of µs): the simulated PJRT call
        let mut x = d + 1.0f32;
        for _ in 0..100_000u32 {
            x = x * 1.000_000_1 + 1e-7;
        }
        // black_box(x) - x is exactly 0.0 but keeps the spin alive
        d + (black_box(x) - x)
    };

    let mut g = c.benchmark_group("sweep");
    g.warm_up_time(Duration::from_millis(300))
        .measurement_time(Duration::from_secs(1))
        .sample_size(30);
    for workers in [1usize, 2, 4, 8] {
        let mode =
            if workers == 1 { SweepMode::Serial } else { SweepMode::Batched { workers } };
        g.bench_function(BenchmarkId::new("workers", workers), |bch| {
            bch.iter(|| {
                let mut scorer = FnScorer { score, workers };
                sweep::sweep(&mut scorer, n_channels, &plan, 0.9, false, mode).unwrap()
            })
        });
    }
    g.finish();
}

fn bench_des(c: &mut Criterion) {
    let arch = RealArch::by_name("gpt2").unwrap();
    let cost = CostModel::default();
    c.bench_function("des/per_edge_pahq_full", |bch| {
        bch.iter(|| {
            pahq::scheduler::per_edge_us(
                &arch,
                &cost,
                MethodKind::Pahq,
                pahq::scheduler::StreamConfig::FULL,
            )
        })
    });
}

fn bench_json(c: &mut Criterion) {
    let manifest_path = pahq::artifacts_root().join("gpt2s-sim/manifest.json");
    if let Ok(text) = std::fs::read_to_string(&manifest_path) {
        c.bench_function("json/parse_gpt2s_manifest", |bch| {
            bch.iter(|| Json::parse(black_box(&text)).unwrap())
        });
    } else {
        eprintln!("skipping json bench: {} not built", manifest_path.display());
    }
}

fn bench_engine(c: &mut Criterion) {
    // End-to-end patched forward; needs `make artifacts`.
    match PatchedForward::new("gpt2s-sim", "ioi") {
        Ok(mut engine) => {
            let patches = engine.empty_patches();
            c.bench_function("engine/forward_fp32", |bch| {
                bch.iter(|| engine.forward(&patches, None).unwrap())
            });
            c.bench_function("engine/damage_kl", |bch| {
                bch.iter(|| engine.damage(&patches, None, Objective::Kl).unwrap())
            });
            engine.set_session(Policy::pahq(FP8_E4M3)).unwrap();
            let hi = Some(engine.graph.head_node(1, 3));
            c.bench_function("engine/forward_pahq_hi_head", |bch| {
                bch.iter(|| engine.forward(&patches, hi).unwrap())
            });
            engine.set_session(Policy::rtn(FP8_E4M3)).unwrap();
            c.bench_function("engine/forward_rtn_fp8_resid", |bch| {
                bch.iter(|| engine.forward(&patches, None).unwrap())
            });
            // where does the time go?
            let stats = engine.runtime_stats();
            let mut keys: Vec<_> = stats.keys().collect();
            keys.sort();
            println!("\nper-artifact PJRT totals this bench run:");
            for k in keys {
                let s = &stats[k];
                println!(
                    "  {:<24} {:>8} calls  {:>9.3} s total  {:>7.1} µs/call",
                    k,
                    s.calls,
                    s.total.as_secs_f64(),
                    s.total.as_secs_f64() * 1e6 / s.calls.max(1) as f64
                );
            }
        }
        Err(e) => eprintln!("skipping engine benches: {e}"),
    }
}

/// The load harness's latency accounting: recording into and merging
/// the fixed-bucket log2 histogram (the per-request hot path of
/// `pahq load`), plus expanding a saturate schedule. All three must be
/// cheap enough to never perturb the latencies being measured.
fn bench_load_hist(c: &mut Criterion) {
    let mut rng = Rng::new(7);
    let samples: Vec<u64> = (0..4096).map(|_| rng.below(1 << 24) as u64).collect();
    let mut g = c.benchmark_group("load_hist");
    g.warm_up_time(Duration::from_millis(300))
        .measurement_time(Duration::from_secs(1))
        .sample_size(30);
    g.bench_function("record_4k", |bch| {
        bch.iter(|| {
            let mut h = pahq::load::Histogram::new();
            for &v in black_box(&samples) {
                h.record_us(v);
            }
            black_box(h.quantile_us(0.99))
        })
    });
    let mut base = pahq::load::Histogram::new();
    for &v in &samples {
        base.record_us(v);
    }
    g.bench_function("merge_pair", |bch| {
        bch.iter(|| {
            let mut a = base.clone();
            a.merge(black_box(&base));
            black_box(a.count())
        })
    });
    let scenario: pahq::load::Scenario = "saturate".parse().unwrap();
    g.bench_function("schedule_saturate", |bch| {
        bch.iter(|| black_box(&scenario).schedule().len())
    });
    g.finish();
}

criterion_group!(
    benches,
    bench_assembly,
    bench_packed_assembly,
    bench_quant,
    bench_sweep,
    bench_des,
    bench_json,
    bench_load_hist,
    bench_engine
);
criterion_main!(benches);
