//! Micro-benchmarks of the L3 hot path (custom harness; criterion is not
//! available offline — see util::bench).
//!
//! Covers: residual assembly primitives, quant codecs, quantized
//! accumulation, PJRT per-layer dispatch, the full patched forward, the
//! DES edge simulation, and manifest JSON parsing. Results feed
//! EXPERIMENTS.md §Perf.

use std::time::Duration;

use pahq::metrics::Objective;
use pahq::patching::{PatchedForward, Policy};
use pahq::quant::{self, FP8_E4M3};
use pahq::tensor;
use pahq::util::bench::{bench, black_box};
use pahq::util::rng::Rng;

fn main() {
    let budget = Duration::from_millis(400);
    let mut rng = Rng::new(42);

    // --- residual assembly primitives -----------------------------------
    for n in [20_480usize, 163_840] {
        let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut dst = a.clone();
        let r = bench(&format!("add_assign {n} f32"), budget, || {
            tensor::add_assign(black_box(&mut dst), black_box(&b));
        });
        println!("    -> {:.2} GB/s effective", (n * 8) as f64 / r.median_ns);
        let mut dst2 = a.clone();
        bench(&format!("add_sub_assign {n} f32 (patch swap)"), budget, || {
            tensor::add_sub_assign(black_box(&mut dst2), black_box(&a), black_box(&b));
        });
    }

    // --- quant codecs -----------------------------------------------------
    let xs: Vec<f32> = (0..65_536).map(|_| rng.normal() * 8.0).collect();
    let mut buf = xs.clone();
    bench("fq_slice 64k e4m3", budget, || {
        buf.copy_from_slice(&xs);
        quant::fq_slice(black_box(&mut buf), FP8_E4M3);
    });
    let mut acc = vec![0.0f32; 20_480];
    let src: Vec<f32> = (0..20_480).map(|_| rng.normal()).collect();
    bench("accumulate_quantized 20k e4m3 (RTN resid)", budget, || {
        quant::accumulate_quantized(black_box(&mut acc), black_box(&src), FP8_E4M3);
    });

    // --- DES --------------------------------------------------------------
    let arch = pahq::gpu_sim::RealArch::by_name("gpt2").unwrap();
    let cost = pahq::gpu_sim::CostModel::default();
    bench("DES per-edge simulation (gpt2, PAHQ full)", budget, || {
        black_box(pahq::scheduler::per_edge_us(
            &arch,
            &cost,
            pahq::gpu_sim::memory::MethodKind::Pahq,
            pahq::scheduler::StreamConfig::FULL,
        ));
    });

    // --- JSON substrate ----------------------------------------------------
    let manifest_path = pahq::artifacts_root().join("gpt2s-sim/manifest.json");
    if let Ok(text) = std::fs::read_to_string(&manifest_path) {
        bench("JSON parse gpt2s-sim manifest", budget, || {
            black_box(pahq::util::json::Json::parse(black_box(&text)).unwrap());
        });
    }

    // --- end-to-end patched forward (needs artifacts) ----------------------
    match PatchedForward::new("gpt2s-sim", "ioi") {
        Ok(mut engine) => {
            let patches = engine.empty_patches();
            bench("patched forward gpt2s-sim fp32 (9 PJRT calls)", Duration::from_secs(3), || {
                black_box(engine.forward(black_box(&patches), None).unwrap());
            });
            bench("damage() incl. KL metric", Duration::from_secs(2), || {
                black_box(engine.damage(black_box(&patches), None, Objective::Kl).unwrap());
            });
            engine.set_session(Policy::pahq(FP8_E4M3)).unwrap();
            let hi = Some(engine.graph.head_node(1, 3));
            bench("patched forward gpt2s-sim PAHQ (hi head)", Duration::from_secs(3), || {
                black_box(engine.forward(black_box(&patches), hi).unwrap());
            });
            engine.set_session(Policy::rtn(FP8_E4M3)).unwrap();
            bench("patched forward gpt2s-sim RTN (fp8 resid)", Duration::from_secs(3), || {
                black_box(engine.forward(black_box(&patches), None).unwrap());
            });
            // where does the time go?
            let stats = engine.runtime_stats();
            let mut keys: Vec<_> = stats.keys().collect();
            keys.sort();
            println!("\nper-artifact PJRT totals this bench run:");
            for k in keys {
                let s = &stats[k];
                println!(
                    "  {:<24} {:>8} calls  {:>9.3} s total  {:>7.1} µs/call",
                    k,
                    s.calls,
                    s.total.as_secs_f64(),
                    s.total.as_secs_f64() * 1e6 / s.calls.max(1) as f64
                );
            }
        }
        Err(e) => eprintln!("skipping engine benches: {e}"),
    }
}
