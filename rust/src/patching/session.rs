//! The patched-forward engine: session state (policies, references,
//! caches), the chained per-layer executable loop, and the damage
//! scoring entry points the ACDC sweeps drive.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::gpu_sim::memory::MeasuredFootprint;
use crate::model::{Channel, Dataset, Example, Graph, Manifest, NodeId, WeightStore};
use crate::quant::{self, Format};
use crate::runtime::{Engine, Input, OwnedInput};
use crate::tensor::{QTensor, Tensor};

use super::assembly::{Assembler, PatchMask};
use super::policy::Policy;

pub struct PatchedForward {
    pub manifest: Manifest,
    pub graph: Graph,
    pub channels: Vec<Channel>,
    chan_idx: HashMap<Channel, usize>,
    pub ws: WeightStore,
    rt: Engine,
    pub examples: Vec<Example>,
    onehot_clean: Vec<f32>,
    onehot_corrupt: Vec<f32>,

    // session state (see `set_session`)
    session: Policy,
    /// Corrupted-run node outputs, packed at the session's cache format
    /// ([`Policy::cache_format`]): FP32 words for PAHQ/ACDC, native
    /// low-precision bytes for RTN-Q — so [`QTensor::bytes`] sums to the
    /// cache's measured footprint.
    pub corrupt_cache: Vec<QTensor>,
    pub ref_probs: Vec<f32>, // clean-run answer distribution
    pub ref_logit_diff: f32,
    pub clean_logits: Tensor,
    /// per-`hi` clean references (paper Appendix F runs the clean
    /// inference with the SAME h* at FP32 as the patched inference, so
    /// the precision switch cancels out of ΔL; memoized lazily — one
    /// extra forward per distinct source node per session)
    ref_by_hi: HashMap<NodeId, (Vec<f32>, f32)>,

    /// source groups, per-group corrupt bases, scratch pool
    asm: Assembler,
    node_out: Vec<Tensor>,
    pub forward_count: u64,
    /// Fig. 4 experiment: explicit per-head precision (len = L*H,
    /// layer-major), overriding the session policy's head precision.
    headwise: Option<Vec<Format>>,
    /// attention artifact: "attn_layer.hlo.txt" (Pallas, default) or
    /// "attn_layer_ref.hlo.txt" (pure jnp; select with PAHQ_ATTN=ref for
    /// sweep-heavy runs on CPU PJRT — value-identical, see aot.py)
    attn_artifact: &'static str,
}

impl PatchedForward {
    pub fn new(model: &str, task: &str) -> Result<PatchedForward> {
        let manifest = Manifest::by_name(model)?;
        let ds = Dataset::by_task(task)?;
        let examples = ds.batch(manifest.batch)?.to_vec();
        Self::with_examples(manifest, examples)
    }

    pub fn with_examples(manifest: Manifest, examples: Vec<Example>) -> Result<PatchedForward> {
        if examples.len() != manifest.batch {
            bail!(
                "engine needs exactly batch={} examples, got {}",
                manifest.batch,
                examples.len()
            );
        }
        let graph = Graph::from_manifest(&manifest);
        if graph.n_nodes() > 128 {
            bail!("graph has {} nodes; PatchMask supports up to 128", graph.n_nodes());
        }
        let channels = graph.channels();
        let chan_idx: HashMap<Channel, usize> =
            channels.iter().enumerate().map(|(i, c)| (*c, i)).collect();
        let asm = Assembler::new(&manifest, &graph, &channels);

        let ws = WeightStore::load(&manifest)?;
        let rt = Engine::new()?;
        let (b, s, v) = (manifest.batch, manifest.seq_len, manifest.vocab);
        let onehot_clean = Dataset::onehot(&examples, false, v);
        let onehot_corrupt = Dataset::onehot(&examples, true, v);

        let node_out = (0..graph.n_nodes())
            .map(|_| Tensor::zeros(&[b, s, manifest.d_model]))
            .collect();

        let mut engine = PatchedForward {
            manifest,
            graph,
            channels,
            chan_idx,
            ws,
            rt,
            examples,
            onehot_clean,
            onehot_corrupt,
            session: Policy::fp32(),
            corrupt_cache: Vec::new(),
            ref_probs: Vec::new(),
            ref_logit_diff: 0.0,
            clean_logits: Tensor::zeros(&[1]),
            ref_by_hi: HashMap::new(),
            asm,
            node_out,
            forward_count: 0,
            headwise: None,
            attn_artifact: match std::env::var("PAHQ_ATTN").as_deref() {
                Ok("ref") => "attn_layer_ref.hlo.txt",
                _ => "attn_layer.hlo.txt",
            },
        };
        engine.set_session(Policy::fp32())?;
        Ok(engine)
    }

    pub fn chan_index(&self, ch: Channel) -> usize {
        self.chan_idx[&ch]
    }

    pub fn n_channels(&self) -> usize {
        self.channels.len()
    }

    pub fn empty_patches(&self) -> PatchMask {
        PatchMask::empty(self.channels.len())
    }

    pub fn session(&self) -> &Policy {
        &self.session
    }

    /// Select the attention executable: Pallas build (default) or the
    /// value-identical pure-jnp reference build (faster under CPU PJRT —
    /// interpret-mode Pallas lowers to an XLA while loop).
    pub fn set_attn_artifact(&mut self, use_ref: bool) {
        self.attn_artifact = if use_ref { "attn_layer_ref.hlo.txt" } else { "attn_layer.hlo.txt" };
    }

    /// Switch the discovery session to a policy: materializes the packed
    /// weight planes the policy actually reads (passthrough planes alias
    /// the FP32 master — nothing to materialize), recomputes the
    /// corrupted-activation cache (packed at [`Policy::cache_format`])
    /// and the clean reference (at FP32 for hi-fidelity policies, at the
    /// session precision for RTN-Q), and precomputes per-group corrupt
    /// base sums.
    pub fn set_session(&mut self, policy: Policy) -> Result<()> {
        self.set_session_inner(policy, None)
    }

    /// Corrupt-cache handoff between sessions: switch to `policy` but
    /// install a pre-built corrupted-activation cache instead of
    /// re-running the corrupted forward. The cache must be exactly what
    /// this session would compute — same model, same examples, packed at
    /// the policy's [`Policy::cache_format`] — which the matrix
    /// orchestrator guarantees by keying its store on those inputs;
    /// shape and format are validated here, bit content is the caller's
    /// contract (property-tested in this module and `tests/matrix.rs`).
    pub fn set_session_with_cache(&mut self, policy: Policy, cache: &[QTensor]) -> Result<()> {
        self.set_session_inner(policy, Some(cache))
    }

    /// Policy switch with an *optional* pre-built cache — the engine half
    /// of the [`crate::discovery::Handoff`] contract. The cache is
    /// installed only when its packed format matches the policy's
    /// [`Policy::cache_format`] (a PAHQ cell cannot read an RTN-Q
    /// lattice); any mismatch falls back to re-running the corrupted
    /// forward. Returns whether the handoff applied.
    pub fn set_session_handoff(
        &mut self,
        policy: Policy,
        cache: Option<&[QTensor]>,
    ) -> Result<bool> {
        match cache {
            Some(cc) if cc.first().map(|t| t.format()) == Some(policy.cache_format()) => {
                self.set_session_inner(policy, Some(cc))?;
                Ok(true)
            }
            _ => {
                self.set_session_inner(policy, None)?;
                Ok(false)
            }
        }
    }

    fn set_session_inner(&mut self, policy: Policy, cache: Option<&[QTensor]>) -> Result<()> {
        self.ws.ensure_plane(Policy::plane_name(policy.attn_low), policy.attn_low);
        self.ws.ensure_plane(Policy::plane_name(policy.other), policy.other);
        self.session = policy.clone();
        self.ref_by_hi.clear();

        let cache_policy = if policy.hi_fidelity_refs { Policy::fp32() } else { policy.clone() };

        // corrupted run -> cache node outputs, packed at the cache
        // format. For PAHQ/ACDC the cache is FP32: the patched-in
        // activation a_u^(high) is exactly what the paper keeps at high
        // precision (Eq. 2). RTN-Q's cache lives on the low lattice its
        // accumulation re-quantizes to anyway (fq is idempotent, so
        // packing changes no bits downstream).
        let cache_fmt = policy.cache_format();
        match cache {
            Some(cc) => {
                if cc.len() != self.graph.n_nodes() {
                    bail!(
                        "corrupt-cache handoff: {} node tensors, graph has {}",
                        cc.len(),
                        self.graph.n_nodes()
                    );
                }
                let m = &self.manifest;
                let elems = m.batch * m.seq_len * m.d_model;
                if let Some(t) = cc.iter().find(|t| t.format() != cache_fmt || t.len() != elems) {
                    bail!(
                        "corrupt-cache handoff: tensor is {} elems at {:?}, session needs \
                         {} at {:?}",
                        t.len(),
                        t.format(),
                        elems,
                        cache_fmt
                    );
                }
                self.corrupt_cache = cc.to_vec();
            }
            None => {
                let empty = self.empty_patches();
                let _ = self.forward_inner(&cache_policy, &empty, None, true)?;
                self.corrupt_cache =
                    self.node_out.iter().map(|t| QTensor::from_tensor(t, cache_fmt)).collect();
            }
        }

        // clean run -> reference distribution + logits, computed under the
        // *session* policy (the paper's L(E_G(z)) flows through the same
        // quantized pipeline as the patched runs, so the systematic
        // quantization bias cancels in ΔL; only the patched activations
        // themselves are held at FP32).
        let empty = self.empty_patches();
        let logits = self.forward_inner(&policy, &empty, None, false)?;
        self.ref_probs = crate::metrics::probs_at_positions(&logits, &self.examples);
        self.ref_logit_diff = crate::metrics::logit_diff(&logits, &self.examples);
        self.clean_logits = logits;

        // per-group corrupt base sums (static for the session)
        self.asm.rebuild_corrupt_base(&self.corrupt_cache);
        Ok(())
    }

    /// Run the patched forward under the session policy with node `hi`
    /// (the investigated edge's source) held at FP32. Returns logits.
    pub fn forward(&mut self, patches: &PatchMask, hi: Option<NodeId>) -> Result<Tensor> {
        let policy = self.session.clone();
        self.forward_inner(&policy, patches, hi, false)
    }

    /// Fig. 4's incremental-quantization forward: every attention head
    /// runs at its own explicit format (`head_fmts[l*H + h]`); everything
    /// else follows the session policy. Requires the planes for the used
    /// formats to exist (ensure by `set_session` on a policy that uses
    /// them, or call after `Policy::pahq` sessions).
    pub fn forward_headwise(
        &mut self,
        head_fmts: &[Format],
        patches: &PatchMask,
    ) -> Result<Tensor> {
        assert_eq!(head_fmts.len(), self.manifest.n_layer * self.manifest.n_head);
        for f in head_fmts {
            self.ws.ensure_plane(Policy::plane_name(*f), *f);
        }
        self.headwise = Some(head_fmts.to_vec());
        let policy = self.session.clone();
        let out = self.forward_inner(&policy, patches, None, false);
        self.headwise = None;
        out
    }

    /// Metric damage of a patched run vs the clean reference *computed
    /// under the same `hi` override* (paper Appendix F: the clean
    /// inference carries the same h* at FP32 as the patched one, so the
    /// precision switch cancels out of ΔL). References are memoized per
    /// source node; ACDC visits each node as a source many times.
    pub fn damage(
        &mut self,
        patches: &PatchMask,
        hi: Option<NodeId>,
        obj: crate::metrics::Objective,
    ) -> Result<f32> {
        let (ref_probs, ref_ld) = match hi {
            None => (self.ref_probs.clone(), self.ref_logit_diff),
            Some(node) => {
                if !self.ref_by_hi.contains_key(&node) {
                    let empty = self.empty_patches();
                    let logits = self.forward(&empty, hi)?;
                    let probs = crate::metrics::probs_at_positions(&logits, &self.examples);
                    let ld = crate::metrics::logit_diff(&logits, &self.examples);
                    self.ref_by_hi.insert(node, (probs, ld));
                }
                self.ref_by_hi[&node].clone()
            }
        };
        let logits = self.forward(patches, hi)?;
        Ok(obj.damage(&logits, &self.examples, &ref_probs, ref_ld))
    }

    /// Score a batch of speculative candidates: each candidate's edge is
    /// patched on top of `patches` *individually* and its damage
    /// computed. This is the single-engine entry point of the batched
    /// sweep (`acdc::sweep`): the working mask is cloned once per batch
    /// rather than once per candidate, and the per-`hi` clean-reference
    /// memoization warms across the whole batch — the "shared
    /// patched-forward setup" that makes batch scoring cheaper than a
    /// sequence of independent `damage` calls even before threading.
    pub fn damage_batch(
        &mut self,
        patches: &PatchMask,
        cands: &[crate::acdc::sweep::Candidate],
        obj: crate::metrics::Objective,
    ) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(cands.len());
        let mut work = patches.clone();
        for c in cands {
            work.set(c.chan, c.src, true);
            out.push(self.damage(&work, c.hi, obj)?);
            work.set(c.chan, c.src, false);
        }
        Ok(out)
    }

    /// Chain-speculative counterpart of [`Self::damage_batch`]: candidate
    /// `j` is scored with candidates `0..=j` patched in (each assumes all
    /// earlier ones in the batch were removed) — the "predict-remove"
    /// direction of `acdc::sweep`'s branch-predicted batching.
    pub fn damage_chain(
        &mut self,
        patches: &PatchMask,
        cands: &[crate::acdc::sweep::Candidate],
        obj: crate::metrics::Objective,
    ) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(cands.len());
        let mut work = patches.clone();
        for c in cands {
            work.set(c.chan, c.src, true);
            out.push(self.damage(&work, c.hi, obj)?);
        }
        Ok(out)
    }

    /// Clone of the current run's node outputs (for callers building
    /// caches, e.g. SP / Edge-Pruning baselines).
    pub fn node_outputs(&self) -> Vec<Tensor> {
        self.node_out.clone()
    }

    /// Measured bytes this session actually holds resident: the packed
    /// weight planes its policy reads plus the packed corrupted-
    /// activation cache. Printed side by side with the simulated
    /// `gpu_sim::memory` model by `pahq run` / `pahq sweep`.
    pub fn measured_footprint(&self) -> MeasuredFootprint {
        let mut plane_names = vec![self.session.attn_plane()];
        if !plane_names.contains(&self.session.other_plane()) {
            plane_names.push(self.session.other_plane());
        }
        MeasuredFootprint {
            method: self.session.name.clone(),
            weight_planes: plane_names
                .into_iter()
                .map(|p| (p.to_string(), self.ws.resident_bytes(p)))
                .collect(),
            act_cache: self.corrupt_cache.iter().map(|t| t.bytes()).sum(),
        }
    }

    /// The ACDC-fp32 footprint of the *same* session shape (full-width
    /// weights, full-width cache) — the measured baseline the packed
    /// footprint is compared against.
    pub fn measured_fp32_footprint(&self) -> MeasuredFootprint {
        let cache_elems: usize = self.corrupt_cache.iter().map(|t| t.len()).sum();
        MeasuredFootprint {
            method: "acdc-fp32".into(),
            weight_planes: vec![("p32".into(), self.ws.n_params() * 4)],
            act_cache: cache_elems * 4,
        }
    }

    pub fn pjrt_time(&self) -> std::time::Duration {
        self.rt.total_exec_time()
    }

    pub fn runtime_stats(&self) -> HashMap<String, crate::runtime::ExeStats> {
        self.rt.stats()
    }

    /// Run the gradient artifact (EAP/HISP). Returns the full output tuple.
    pub fn run_grads(&mut self, corrupt_input: bool, sel_logit_diff: bool) -> Result<Vec<Tensor>> {
        self.run_grad_artifact("grads.hlo.txt", corrupt_input, sel_logit_diff, &[])
    }

    /// Shared driver for the gradient artifacts (`grads` / `gate_grads` /
    /// `edge_mask_grads`). Input order is always
    /// (onehot, pos, ans, dis, ref_probs, sel, <extras...>, weights...);
    /// weights come from the FP32 master — the gradient baselines run at
    /// full precision, exactly as the paper runs EAP / SP / Edge Pruning.
    pub fn run_grad_artifact(
        &mut self,
        artifact: &str,
        corrupt_input: bool,
        sel_logit_diff: bool,
        extras: &[Input],
    ) -> Result<Vec<Tensor>> {
        let m = &self.manifest;
        let (b, s, v) = (m.batch, m.seq_len, m.vocab);
        let onehot = if corrupt_input { &self.onehot_corrupt } else { &self.onehot_clean };
        let pos = Dataset::pos_onehot(&self.examples, s);
        let ans = Dataset::dist(&self.examples, v, false);
        let dis = Dataset::dist(&self.examples, v, true);
        let sel = OwnedInput::scalar(if sel_logit_diff { 1.0 } else { 0.0 });
        let (sh_bsv, sh_bs, sh_bv) = ([b, s, v], [b, s], [b, v]);
        let mut inputs = vec![
            Input::new(&sh_bsv, onehot),
            Input::new(&sh_bs, &pos),
            Input::new(&sh_bv, &ans),
            Input::new(&sh_bv, &dis),
            Input::new(&sh_bv, &self.ref_probs),
        ];
        inputs.push(sel.as_input());
        for e in extras {
            inputs.push(Input::new(e.shape, e.data));
        }
        let params = self.manifest.params.clone();
        for p in &params {
            inputs.push(Input::new(&p.shape, self.ws.master_param(&p.name)?));
        }
        let path = self.manifest.hlo_path(artifact);
        self.rt.run(&path, &inputs)
    }

    /// Swap the evaluation batch (Edge-Pruning's dataset-size sweep
    /// rotates batches through the fixed-shape executables). Rebuilds the
    /// one-hots and re-runs `set_session` to refresh caches/references.
    pub fn set_examples(&mut self, examples: Vec<Example>) -> Result<()> {
        if examples.len() != self.manifest.batch {
            bail!("need exactly batch={} examples", self.manifest.batch);
        }
        let v = self.manifest.vocab;
        self.onehot_clean = Dataset::onehot(&examples, false, v);
        self.onehot_corrupt = Dataset::onehot(&examples, true, v);
        self.examples = examples;
        let session = self.session.clone();
        self.set_session(session)
    }

    // -----------------------------------------------------------------------

    fn forward_inner(
        &mut self,
        policy: &Policy,
        patches: &PatchMask,
        hi: Option<NodeId>,
        corrupt_input: bool,
    ) -> Result<Tensor> {
        self.forward_count += 1;
        let m = self.manifest.clone();
        let (b, s, v, d, h, k) = (m.batch, m.seq_len, m.vocab, m.d_model, m.n_head, m.d_head);
        let bsd = b * s * d;
        let attn_plane = policy.attn_plane();
        let other_plane = policy.other_plane();

        // ---- embed -----------------------------------------------------
        {
            let hi_embed = hi == Some(Graph::EMBED);
            let sc = &mut self.asm.scratch;
            let wte = self.ws.param_at("wte", other_plane, hi_embed, &mut sc.wte)?;
            let wpe = self.ws.param_at("wpe", other_plane, hi_embed, &mut sc.wpe)?;
            let onehot = if corrupt_input { &self.onehot_corrupt } else { &self.onehot_clean };
            let outs = self.rt.run(
                &m.hlo_path("embed.hlo.txt"),
                &[
                    Input::new(&[b, s, v], onehot),
                    Input::new(&[v, d], wte),
                    Input::new(&[s, d], wpe),
                ],
            )?;
            let mut emb = outs.into_iter().next().context("embed output")?;
            if !policy.other.is_passthrough() && !hi_embed {
                quant::fq_slice(&mut emb.data, policy.other);
            }
            self.node_out[Graph::EMBED].copy_from(&emb);
        }

        // ---- layers ------------------------------------------------------
        for l in 0..m.n_layer {
            // channel inputs for all heads/components of this layer
            let head_ch = Channel::Head { layer: l, head: 0, comp: 0 };
            let head_gid = self.asm.group_of(self.chan_idx[&head_ch]);
            self.asm.compute_group_base(head_gid, policy, &self.node_out);
            // Assemble each distinct patch mask once — all of them in a
            // single cache-blocked pass (each packed corrupt plane is
            // decoded once per tile for every distinct mask, see
            // `Assembler::assemble_channels`) — then memcpy for the
            // duplicates. Within a layer, most of the 3*H channels share
            // the same mask (usually the empty one). This matters most for
            // the RTN session, whose sequential quantized accumulation is
            // the expensive faithful path (EXPERIMENTS.md §Perf).
            let mut owners: Vec<(u128, u8, usize, usize)> = Vec::new(); // (mask, comp, head, ci)
            let mut owner_of = vec![0usize; 3 * h]; // [comp * h + head] -> owners index
            for comp in 0..3u8 {
                for head in 0..h {
                    let ci = self.chan_idx[&Channel::Head { layer: l, head, comp }];
                    debug_assert_eq!(self.asm.group_of(ci), head_gid);
                    let mask = patches.mask(ci);
                    let idx = owners.iter().position(|&(m, ..)| m == mask).unwrap_or_else(|| {
                        owners.push((mask, comp, head, ci));
                        owners.len() - 1
                    });
                    owner_of[comp as usize * h + head] = idx;
                }
            }
            let mut qkv_bufs = [0, 1, 2].map(|c| std::mem::take(&mut self.asm.scratch.qkv[c]));
            {
                let mut parts: Vec<Vec<Option<&mut [f32]>>> =
                    qkv_bufs.iter_mut().map(|b| b.chunks_mut(bsd).map(Some).collect()).collect();
                let cis: Vec<usize> = owners.iter().map(|&(.., ci)| ci).collect();
                let mut dsts: Vec<&mut [f32]> = owners
                    .iter()
                    .map(|&(_, comp, head, _)| {
                        parts[comp as usize][head].take().expect("distinct owner slot")
                    })
                    .collect();
                self.asm.assemble_channels(
                    &cis,
                    patches,
                    policy,
                    &self.node_out,
                    &self.corrupt_cache,
                    &mut dsts,
                );
            }
            for comp in 0..3usize {
                for head in 0..h {
                    let (_, oc, oh, _) = owners[owner_of[comp * h + head]];
                    let oc = oc as usize;
                    if (oc, oh) == (comp, head) {
                        continue;
                    }
                    if oc == comp {
                        qkv_bufs[comp].copy_within(oh * bsd..(oh + 1) * bsd, head * bsd);
                    } else {
                        let (lo, hi) = qkv_bufs.split_at_mut(comp.max(oc));
                        let (src_buf, dst_buf) =
                            if oc < comp { (&lo[oc], &mut hi[0]) } else { (&hi[0], &mut lo[comp]) };
                        dst_buf[head * bsd..(head + 1) * bsd]
                            .copy_from_slice(&src_buf[oh * bsd..(oh + 1) * bsd]);
                    }
                }
            }
            for (c, buf) in qkv_bufs.into_iter().enumerate() {
                self.asm.scratch.qkv[c] = buf;
            }

            // mixed-precision weights + qp rows
            let hi_head = match hi.map(|n| self.graph.node_kind(n)) {
                Some(crate::model::graph::NodeKind::Head { layer, head }) if layer == l => {
                    Some(head)
                }
                _ => None,
            };
            if let Some(head_fmts) = &self.headwise {
                // Fig. 4 path: explicit per-head formats
                let fmts = &head_fmts[l * h..(l + 1) * h];
                let planes: Vec<&str> = fmts
                    .iter()
                    .map(|f| if f.is_passthrough() { "master" } else { Policy::plane_name(*f) })
                    .collect();
                let sc = &mut self.asm.scratch;
                for (name, buf) in [
                    ("wq", &mut sc.wq), ("bq", &mut sc.bq), ("wk", &mut sc.wk),
                    ("bk", &mut sc.bk), ("wv", &mut sc.wv), ("bv", &mut sc.bv),
                    ("wo", &mut sc.wo),
                ] {
                    self.ws.assemble_heads(&format!("l{l}.{name}"), &planes, buf)?;
                }
                for head in 0..h {
                    sc.qp[head * 3..head * 3 + 3].copy_from_slice(&fmts[head].as_qp());
                }
            } else {
                let sc = &mut self.asm.scratch;
                self.ws.mixed_head_param(&format!("l{l}.wq"), attn_plane, hi_head, &mut sc.wq)?;
                self.ws.mixed_head_param(&format!("l{l}.bq"), attn_plane, hi_head, &mut sc.bq)?;
                self.ws.mixed_head_param(&format!("l{l}.wk"), attn_plane, hi_head, &mut sc.wk)?;
                self.ws.mixed_head_param(&format!("l{l}.bk"), attn_plane, hi_head, &mut sc.bk)?;
                self.ws.mixed_head_param(&format!("l{l}.wv"), attn_plane, hi_head, &mut sc.wv)?;
                self.ws.mixed_head_param(&format!("l{l}.bv"), attn_plane, hi_head, &mut sc.bv)?;
                self.ws.mixed_head_param(&format!("l{l}.wo"), attn_plane, hi_head, &mut sc.wo)?;
                for head in 0..h {
                    let fmt = if hi_head == Some(head) { quant::FP32 } else { policy.attn_low };
                    sc.qp[head * 3..head * 3 + 3].copy_from_slice(&fmt.as_qp());
                }
            }

            let ln1 = self.ws.master_param(&format!("l{l}.ln1_g"))?;
            let sh4 = [h, b, s, d];
            let sc = &self.asm.scratch;
            let outs = self.rt.run(
                &m.hlo_path(self.attn_artifact),
                &[
                    Input::new(&sh4, &sc.qkv[0]),
                    Input::new(&sh4, &sc.qkv[1]),
                    Input::new(&sh4, &sc.qkv[2]),
                    Input::new(&[d], ln1),
                    Input::new(&[h, d, k], &sc.wq),
                    Input::new(&[h, k], &sc.bq),
                    Input::new(&[h, d, k], &sc.wk),
                    Input::new(&[h, k], &sc.bk),
                    Input::new(&[h, d, k], &sc.wv),
                    Input::new(&[h, k], &sc.bv),
                    Input::new(&[h, k, d], &sc.wo),
                    Input::new(&[h, 3], &sc.qp),
                ],
            )?;
            let houts = outs.into_iter().next().context("attn output")?;
            debug_assert_eq!(houts.shape, vec![h, b, s, d]);
            for head in 0..h {
                let node = self.graph.head_node(l, head);
                self.node_out[node]
                    .data
                    .copy_from_slice(&houts.data[head * bsd..(head + 1) * bsd]);
            }

            // ---- MLP ----------------------------------------------------
            if m.has_mlp() {
                let ch = Channel::Mlp { layer: l };
                let ci = self.chan_idx[&ch];
                let gid = self.asm.group_of(ci);
                self.asm.compute_group_base(gid, policy, &self.node_out);
                let mut chan_in = std::mem::take(&mut self.asm.scratch.chan_in);
                self.asm.assemble_channel(
                    ci,
                    patches,
                    policy,
                    &self.node_out,
                    &self.corrupt_cache,
                    &mut chan_in,
                );
                let hi_mlp = hi == Some(self.graph.mlp_node(l));
                let f = m.d_mlp;
                let qp3 = if hi_mlp { quant::FP32.as_qp() } else { policy.other.as_qp() };
                let sc = &mut self.asm.scratch;
                let w1 = self.ws.param_at(&format!("l{l}.w1"), other_plane, hi_mlp, &mut sc.w1)?;
                let b1 = self.ws.param_at(&format!("l{l}.b1"), other_plane, hi_mlp, &mut sc.b1)?;
                let w2 = self.ws.param_at(&format!("l{l}.w2"), other_plane, hi_mlp, &mut sc.w2)?;
                let b2 = self.ws.param_at(&format!("l{l}.b2"), other_plane, hi_mlp, &mut sc.b2)?;
                let ln2 = self.ws.master_param(&format!("l{l}.ln2_g"))?;
                let outs = self.rt.run(
                    &m.hlo_path("mlp_layer.hlo.txt"),
                    &[
                        Input::new(&[b, s, d], &chan_in),
                        Input::new(&[d], ln2),
                        Input::new(&[d, f], w1),
                        Input::new(&[f], b1),
                        Input::new(&[f, d], w2),
                        Input::new(&[d], b2),
                        Input::new(&[3], &qp3),
                    ],
                )?;
                let mout = outs.into_iter().next().context("mlp output")?;
                self.node_out[self.graph.mlp_node(l)].copy_from(&mout);
                self.asm.scratch.chan_in = chan_in;
            }
        }

        // ---- final / unembed ---------------------------------------------
        let ci = self.chan_idx[&Channel::Final];
        let gid = self.asm.group_of(ci);
        self.asm.compute_group_base(gid, policy, &self.node_out);
        let mut chan_in = std::mem::take(&mut self.asm.scratch.chan_in);
        self.asm.assemble_channel(
            ci,
            patches,
            policy,
            &self.node_out,
            &self.corrupt_cache,
            &mut chan_in,
        );
        let sc = &mut self.asm.scratch;
        let wu = self.ws.param_at("wu", other_plane, false, &mut sc.wu)?;
        let lnf = self.ws.master_param("lnf_g")?;
        let outs = self.rt.run(
            &m.hlo_path("unembed.hlo.txt"),
            &[
                Input::new(&[b, s, d], &chan_in),
                Input::new(&[d], lnf),
                Input::new(&[d, v], wu),
            ],
        )?;
        self.asm.scratch.chan_in = chan_in;
        let mut logits = outs.into_iter().next().context("unembed output")?;
        if policy.quantize_logits && !policy.other.is_passthrough() {
            quant::fq_slice(&mut logits.data, policy.other);
        }
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Objective;
    use crate::model::Edge;
    use crate::tensor::max_abs_diff;

    fn engine(model: &str, task: &str) -> Option<PatchedForward> {
        match PatchedForward::new(model, task) {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("skipping: {e}");
                None
            }
        }
    }

    fn expected_logits(m: &Manifest, task: &str, tag: &str) -> Option<Vec<f32>> {
        let path = m.dir.join("expected").join(format!("{task}_{tag}_logits.bin"));
        let bytes = std::fs::read(path).ok()?;
        Some(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    #[test]
    fn fp32_forward_matches_python_reference() {
        // Pins the whole L1+L2+runtime+L3 composition: the chained
        // per-layer executables plus Rust residual assembly must equal the
        // monolithic python reference forward.
        for model in ["redwood2l-sim", "gpt2s-sim"] {
            for task in ["ioi", "docstring"] {
                let Some(mut e) = engine(model, task) else { return };
                let patches = e.empty_patches();
                let logits = e.forward(&patches, None).unwrap();
                let want = expected_logits(&e.manifest, task, "clean").unwrap();
                let diff = max_abs_diff(&logits.data, &want);
                assert!(diff < 5e-3, "{model}/{task}: clean logits diff {diff}");
                // and the corrupted input path
                let empty = e.empty_patches();
                let logits_c = e.forward_inner(&Policy::fp32(), &empty, None, true).unwrap();
                let want_c = expected_logits(&e.manifest, task, "corrupt").unwrap();
                let diff = max_abs_diff(&logits_c.data, &want_c);
                assert!(diff < 5e-3, "{model}/{task}: corrupt logits diff {diff}");
            }
        }
    }

    #[test]
    fn patch_all_equals_corrupt_run() {
        let Some(mut e) = engine("redwood2l-sim", "ioi") else { return };
        let mut patches = e.empty_patches();
        for edge in e.graph.edges() {
            let ci = e.chan_index(edge.dst);
            patches.set(ci, edge.src, true);
        }
        let logits = e.forward(&patches, None).unwrap();
        let want = expected_logits(&e.manifest, "ioi", "corrupt").unwrap();
        // patching every edge (including embed->*) feeds every channel the
        // corrupted-run activations — output must equal the corrupted run
        let diff = max_abs_diff(&logits.data, &want);
        assert!(diff < 5e-3, "diff {diff}");
    }

    #[test]
    fn empty_patch_is_identity_and_deterministic() {
        let Some(mut e) = engine("attn4l-sim", "greater_than") else { return };
        let patches = e.empty_patches();
        let a = e.forward(&patches, None).unwrap();
        let b = e.forward(&patches, None).unwrap();
        assert_eq!(a.data, b.data, "bitwise deterministic");
        let d = e.damage(&patches, None, Objective::Kl).unwrap();
        assert!(d.abs() < 1e-5, "no patch, no damage (KL {d})");
    }

    #[test]
    fn single_edge_patch_changes_output() {
        let Some(mut e) = engine("redwood2l-sim", "ioi") else { return };
        // Note: patching embed->final is a no-op at the answer position
        // (the corruption lives at an earlier token, and embeddings are
        // positionwise) — heads are what move corrupted info to the
        // answer. Some head->final edge must therefore carry damage.
        let ci = e.chan_index(Channel::Final);
        let mut worst = 0.0f32;
        for l in 0..e.graph.n_layer {
            for h in 0..e.graph.n_head {
                let mut patches = e.empty_patches();
                patches.set(ci, e.graph.head_node(l, h), true);
                worst = worst.max(e.damage(&patches, None, Objective::Kl).unwrap());
            }
        }
        assert!(worst > 1e-3, "some head->final patch must hurt (max KL {worst})");
        // ...and the embed->final patch really is a no-op at the answer
        let mut patches = e.empty_patches();
        patches.set(ci, Graph::EMBED, true);
        let d = e.damage(&patches, None, Objective::Kl).unwrap();
        assert!(d < 1e-5, "embed->final patch is position-local (KL {d})");
    }

    #[test]
    fn hi_head_override_is_noop_at_fp32() {
        let Some(mut e) = engine("redwood2l-sim", "ioi") else { return };
        let patches = e.empty_patches();
        let plain = e.forward(&patches, None).unwrap();
        let hi = e.forward(&patches, Some(e.graph.head_node(1, 2))).unwrap();
        // session is fp32: the "high precision" override changes nothing
        assert_eq!(plain.data, hi.data);
    }

    #[test]
    fn pahq_session_preserves_edge_deltas() {
        // The paper's core claim (Eq. 2): with the investigated edge's
        // source at FP32, PAHQ's ΔL(e) tracks the FP32 ΔL(e); RTN-Q's does
        // not. Checked in eval::tests at scale; here a smoke version.
        let Some(mut e) = engine("redwood2l-sim", "ioi") else { return };
        let edge = Edge {
            src: e.graph.head_node(0, 1),
            dst: Channel::Head { layer: 1, head: 2, comp: 2 },
        };
        assert!(e.graph.is_edge(&edge));
        let ci = e.chan_index(edge.dst);
        let mut patches = e.empty_patches();
        patches.set(ci, edge.src, true);

        let d32 = e.damage(&patches, None, Objective::Kl).unwrap();

        e.set_session(Policy::pahq(quant::FP8_E4M3)).unwrap();
        let dq = e.damage(&patches, Some(edge.src), Objective::Kl).unwrap();
        // PAHQ ΔL within a modest relative envelope of FP32 ΔL
        let err = (dq - d32).abs();
        assert!(
            err <= 0.35 * d32.abs() + 2e-3,
            "PAHQ ΔL {dq} strays from FP32 ΔL {d32}"
        );
    }

    #[test]
    fn rtn_session_cache_is_packed_on_lattice() {
        let Some(mut e) = engine("redwood2l-sim", "ioi") else { return };
        e.set_session(Policy::rtn(quant::FP8_E4M3)).unwrap();
        // corrupt cache was rebuilt under the RTN session and packed at
        // the session's fp8 lattice: one real byte per element, decoding
        // to E4M3 fixed points.
        let emb = &e.corrupt_cache[Graph::EMBED];
        assert_eq!(emb.bytes(), emb.len(), "fp8 cache holds one byte per element");
        let dec = emb.to_tensor();
        for &v in dec.data.iter().take(200) {
            assert_eq!(v, quant::fq(v, quant::FP8_E4M3));
        }
    }

    #[test]
    fn measured_footprint_pahq_below_fp32() {
        let Some(mut e) = engine("redwood2l-sim", "ioi") else { return };
        // the fp32 session's measured footprint equals its own baseline
        let fp32 = e.measured_footprint();
        assert_eq!(fp32.total(), e.measured_fp32_footprint().total());
        e.set_session(Policy::pahq(quant::FP8_E4M3)).unwrap();
        let pahq = e.measured_footprint();
        let acdc = e.measured_fp32_footprint();
        // fp8 + bf16 planes (3 bytes/param) beat the 4-byte fp32 copy;
        // the FP32 corrupt cache is identical on both sides
        assert!(pahq.weights() < acdc.weights(), "{} vs {}", pahq.weights(), acdc.weights());
        assert_eq!(pahq.act_cache, acdc.act_cache);
        assert!(pahq.total() < acdc.total());
        // RTN packs the cache too
        e.set_session(Policy::rtn(quant::FP8_E4M3)).unwrap();
        let rtn = e.measured_footprint();
        assert!(rtn.act_cache < acdc.act_cache / 3);
    }

    #[test]
    fn corrupt_cache_handoff_is_bit_identical() {
        // A session given a pre-built corrupt cache (matrix handoff) must
        // behave bit-for-bit like one that computed its own.
        let Some(mut a) = engine("redwood2l-sim", "ioi") else { return };
        a.set_session(Policy::pahq(quant::FP8_E4M3)).unwrap();
        let cache = a.corrupt_cache.clone();
        let Some(mut b) = engine("redwood2l-sim", "ioi") else { return };
        b.set_session_with_cache(Policy::pahq(quant::FP8_E4M3), &cache).unwrap();
        assert_eq!(a.ref_probs, b.ref_probs, "clean references agree");
        let mut patches = a.empty_patches();
        let ci = a.chan_index(Channel::Final);
        patches.set(ci, a.graph.head_node(1, 2), true);
        let hi = Some(a.graph.head_node(1, 2));
        let da = a.damage(&patches, hi, Objective::Kl).unwrap();
        let db = b.damage(&patches, hi, Objective::Kl).unwrap();
        assert_eq!(da.to_bits(), db.to_bits(), "damage bit-identical");
        // shape/format mismatches are rejected loudly
        assert!(b.set_session_with_cache(Policy::rtn(quant::FP8_E4M3), &cache).is_err());
        assert!(b
            .set_session_with_cache(Policy::pahq(quant::FP8_E4M3), &cache[1..])
            .is_err());
    }

    #[test]
    fn pallas_and_ref_attn_artifacts_agree() {
        // The Pallas kernel build and the pure-jnp build of the attention
        // executable must be value-identical on a quantized mixed-
        // precision forward (they share the exact fq lattice).
        let Some(mut e) = engine("gpt2s-sim", "ioi") else { return };
        e.set_session(Policy::pahq(quant::FP8_E4M3)).unwrap();
        let patches = e.empty_patches();
        let hi = Some(e.graph.head_node(2, 5));
        e.set_attn_artifact(false);
        let pallas = e.forward(&patches, hi).unwrap();
        e.set_attn_artifact(true);
        let refv = e.forward(&patches, hi).unwrap();
        let diff = max_abs_diff(&pallas.data, &refv.data);
        assert!(diff < 1e-4, "pallas vs ref logits diff {diff}");
    }

    #[test]
    fn grads_artifact_runs() {
        let Some(mut e) = engine("redwood2l-sim", "ioi") else { return };
        let outs = e.run_grads(false, true).unwrap();
        // metric, embed, attn, gq, gk, gv, ghout, gfinal (attn-only model)
        assert_eq!(outs.len(), 8);
        let m = &e.manifest;
        assert_eq!(outs[0].shape, Vec::<usize>::new());
        assert_eq!(outs[1].shape, vec![m.batch, m.seq_len, m.d_model]);
        assert_eq!(
            outs[2].shape,
            vec![m.n_layer, m.n_head, m.batch, m.seq_len, m.d_model]
        );
        // gradients are not all zero
        assert!(outs[3].data.iter().any(|&v| v != 0.0));
    }
}
