//! Discovery-session precision policies (paper Eq. 3 plus the bf16 rule
//! for non-attention components): which weight plane each component
//! reads, the residual accumulation format, and the fidelity of the
//! session's reference runs and caches.

use crate::quant::{self, Format};

/// A discovery-session precision policy (paper Eq. 3 plus the bf16 rule
/// for non-attention components). `PartialEq` compares the full
/// configuration, not the name — two formats of the same nominal width
/// (fp8_e4m3 vs fp8_e5m2) share a name but are different policies.
#[derive(Clone, Debug, PartialEq)]
pub struct Policy {
    pub name: String,
    /// precision of attention heads that are NOT under investigation
    pub attn_low: Format,
    /// precision of non-attention components (embed/MLP/unembed), the
    /// paper's bf16 rule
    pub other: Format,
    /// residual-stream accumulation format (RTN-Q's downfall)
    pub resid: Format,
    /// keep the corrupted-activation cache and the clean reference
    /// distribution at FP32 (PAHQ/ACDC) or at this policy's precision
    /// (RTN-Q quantizes its whole pipeline)
    pub hi_fidelity_refs: bool,
    /// naive whole-pipeline quantization also quantizes the unembed *output*
    /// (RTN-Q). This is where the paper's section-2 underflow bites
    /// hardest: the FP8 quantum at logit magnitude ~16 is 2.0, so metric
    /// differences below it are truncated to zero and ACDC prunes real
    /// edges. PAHQ/ACDC unify outputs at FP32 (paper Eq. 10).
    pub quantize_logits: bool,
}

impl Policy {
    /// Unquantized ACDC.
    pub fn fp32() -> Policy {
        Policy {
            name: "acdc-fp32".into(),
            attn_low: quant::FP32,
            other: quant::FP32,
            resid: quant::FP32,
            hi_fidelity_refs: true,
            quantize_logits: false,
        }
    }

    /// RTN-Q: everything at the low format, including the residual stream
    /// and the reference runs (paper section 2's failing baseline).
    pub fn rtn(fmt: Format) -> Policy {
        Policy {
            name: format!("rtn-q-{}b", nominal_bits(fmt)),
            attn_low: fmt,
            other: fmt,
            resid: fmt,
            hi_fidelity_refs: false,
            quantize_logits: true,
        }
    }

    /// PAHQ: non-investigated heads at `fmt`, non-attention at bf16,
    /// residual stream unified to FP32 (paper Eq. 10), investigated head
    /// at FP32 via the per-call `hi` override.
    pub fn pahq(fmt: Format) -> Policy {
        Policy {
            name: format!("pahq-{}b", nominal_bits(fmt)),
            attn_low: fmt,
            other: quant::BF16,
            resid: quant::FP32,
            hi_fidelity_refs: true,
            quantize_logits: false,
        }
    }

    pub(crate) fn plane_name(fmt: Format) -> &'static str {
        match nominal_bits(fmt) {
            4 => "p4",
            8 => "p8",
            16 => "p16",
            _ => "p32",
        }
    }

    pub fn attn_plane(&self) -> &'static str {
        Self::plane_name(self.attn_low)
    }

    pub fn other_plane(&self) -> &'static str {
        Self::plane_name(self.other)
    }

    /// Does this policy hold the investigated edge's source at FP32
    /// (the PAHQ per-call `hi` override)? Discovery methods consult this
    /// when building their candidate plans.
    pub fn is_pahq(&self) -> bool {
        self.name.starts_with("pahq")
    }

    /// Storage format of the session's corrupted-activation cache: FP32
    /// for hi-fidelity policies (the patched-in activation is exactly
    /// what the paper keeps at high precision, Eq. 2), the residual
    /// format for RTN-Q (its whole pipeline lives on the low lattice).
    pub fn cache_format(&self) -> Format {
        if self.hi_fidelity_refs { quant::FP32 } else { self.resid }
    }

    /// The policy-family spellings the CLI accepts (a family plus
    /// `--bits`, or a full canonical name like `pahq-4b`).
    pub const FAMILIES: [&'static str; 3] = ["fp32", "rtn", "pahq"];

    /// Resolve a policy spelling at an explicit nominal bit width:
    /// family names (`fp32` | `rtn` | `rtn-q` | `pahq`) take `bits`;
    /// full canonical names (`pahq-4b`, `rtn-q-8b`, `acdc-fp32`) carry
    /// their own width and ignore it.
    pub fn by_name(name: &str, bits: u32) -> anyhow::Result<Policy> {
        match name {
            "fp32" | "acdc" | "acdc-fp32" => Ok(Policy::fp32()),
            "rtn" | "rtn-q" => Ok(Policy::rtn(checked_format(name, bits)?)),
            "pahq" => Ok(Policy::pahq(checked_format(name, bits)?)),
            full => full.parse(),
        }
    }
}

/// Nominal bit width for a low-precision policy family; rejects widths
/// [`crate::quant::Format::by_bits`] would silently round to FP32.
fn checked_format(family: &str, bits: u32) -> anyhow::Result<Format> {
    match bits {
        4 | 8 | 16 => Ok(Format::by_bits(bits)),
        other => anyhow::bail!(
            "bits: policy family '{family}' supports 4|8|16, got {other}"
        ),
    }
}

/// Writes the canonical policy name (`acdc-fp32` | `rtn-q-<N>b` |
/// `pahq-<N>b`), so `format!("{policy}")` round-trips through
/// [`Policy::from_str`] for every [`Format::by_bits`]-constructed policy.
impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name)
    }
}

/// Parses both the family spellings (`fp32` / `rtn` / `rtn-q` / `pahq`,
/// width defaulting to 8 bits) and the canonical names the policies
/// print (`acdc-fp32`, `rtn-q-4b`, `pahq-16b`, ...).
impl std::str::FromStr for Policy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Policy> {
        // split a trailing `-<N>b` width suffix off the family stem
        let (stem, suffix_bits) = match s.rfind('-') {
            Some(i) => match s[i + 1..].strip_suffix('b').and_then(|n| n.parse::<u32>().ok()) {
                Some(b) => (&s[..i], Some(b)),
                None => (s, None),
            },
            None => (s, None),
        };
        let bits = suffix_bits.unwrap_or(8);
        match stem {
            "fp32" | "acdc" | "acdc-fp32" => {
                // fp32 has no width variants: "fp32-99b" must be loud,
                // not a silently full-width run
                if suffix_bits.is_some() {
                    anyhow::bail!("unknown policy '{s}' (fp32 has no bit-width variants)");
                }
                Ok(Policy::fp32())
            }
            "rtn" | "rtn-q" => Ok(Policy::rtn(checked_format(stem, bits)?)),
            "pahq" => Ok(Policy::pahq(checked_format(stem, bits)?)),
            _ => anyhow::bail!(
                "unknown policy '{s}' (fp32|rtn|pahq, optionally with a -<bits>b suffix)"
            ),
        }
    }
}

/// Nominal bit width of a format — with packed storage this is simply
/// its storage width (fp4 = 4, fp8 = 8, fp16/bf16 = 16, else 32); the
/// old implementation reconstructed it from whole-byte sizes plus an
/// mbits tie-break.
pub(crate) fn nominal_bits(fmt: Format) -> u32 {
    fmt.storage_bits() as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{BF16, FP16, FP32, FP4_E2M1, FP8_E4M3, FP8_E5M2};

    #[test]
    fn nominal_bits_names_and_planes() {
        assert_eq!(nominal_bits(FP4_E2M1), 4);
        assert_eq!(nominal_bits(FP8_E4M3), 8);
        assert_eq!(nominal_bits(FP8_E5M2), 8);
        assert_eq!(nominal_bits(FP16), 16);
        assert_eq!(nominal_bits(BF16), 16);
        assert_eq!(nominal_bits(FP32), 32);
        assert_eq!(Policy::pahq(FP8_E4M3).name, "pahq-8b");
        assert_eq!(Policy::rtn(FP4_E2M1).name, "rtn-q-4b");
        assert_eq!(Policy::pahq(FP8_E4M3).attn_plane(), "p8");
        assert_eq!(Policy::pahq(FP8_E4M3).other_plane(), "p16");
        assert_eq!(Policy::fp32().attn_plane(), "p32");
    }

    #[test]
    fn cache_format_follows_fidelity() {
        assert!(Policy::fp32().cache_format().is_passthrough());
        assert!(Policy::pahq(FP8_E4M3).cache_format().is_passthrough());
        assert_eq!(Policy::rtn(FP8_E4M3).cache_format(), FP8_E4M3);
    }
}
