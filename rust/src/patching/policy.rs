//! Discovery-session precision policies (paper Eq. 3 plus the bf16 rule
//! for non-attention components): which weight plane each component
//! reads, the residual accumulation format, and the fidelity of the
//! session's reference runs and caches.

use crate::quant::{self, Format};

/// A discovery-session precision policy (paper Eq. 3 plus the bf16 rule
/// for non-attention components). `PartialEq` compares the full
/// configuration, not the name — two formats of the same nominal width
/// (fp8_e4m3 vs fp8_e5m2) share a name but are different policies.
#[derive(Clone, Debug, PartialEq)]
pub struct Policy {
    pub name: String,
    /// precision of attention heads that are NOT under investigation
    pub attn_low: Format,
    /// precision of non-attention components (embed/MLP/unembed), the
    /// paper's bf16 rule
    pub other: Format,
    /// residual-stream accumulation format (RTN-Q's downfall)
    pub resid: Format,
    /// keep the corrupted-activation cache and the clean reference
    /// distribution at FP32 (PAHQ/ACDC) or at this policy's precision
    /// (RTN-Q quantizes its whole pipeline)
    pub hi_fidelity_refs: bool,
    /// naive whole-pipeline quantization also quantizes the unembed *output*
    /// (RTN-Q). This is where the paper's section-2 underflow bites
    /// hardest: the FP8 quantum at logit magnitude ~16 is 2.0, so metric
    /// differences below it are truncated to zero and ACDC prunes real
    /// edges. PAHQ/ACDC unify outputs at FP32 (paper Eq. 10).
    pub quantize_logits: bool,
}

impl Policy {
    /// Unquantized ACDC.
    pub fn fp32() -> Policy {
        Policy {
            name: "acdc-fp32".into(),
            attn_low: quant::FP32,
            other: quant::FP32,
            resid: quant::FP32,
            hi_fidelity_refs: true,
            quantize_logits: false,
        }
    }

    /// RTN-Q: everything at the low format, including the residual stream
    /// and the reference runs (paper section 2's failing baseline).
    pub fn rtn(fmt: Format) -> Policy {
        Policy {
            name: format!("rtn-q-{}b", nominal_bits(fmt)),
            attn_low: fmt,
            other: fmt,
            resid: fmt,
            hi_fidelity_refs: false,
            quantize_logits: true,
        }
    }

    /// PAHQ: non-investigated heads at `fmt`, non-attention at bf16,
    /// residual stream unified to FP32 (paper Eq. 10), investigated head
    /// at FP32 via the per-call `hi` override.
    pub fn pahq(fmt: Format) -> Policy {
        Policy {
            name: format!("pahq-{}b", nominal_bits(fmt)),
            attn_low: fmt,
            other: quant::BF16,
            resid: quant::FP32,
            hi_fidelity_refs: true,
            quantize_logits: false,
        }
    }

    pub(crate) fn plane_name(fmt: Format) -> &'static str {
        match nominal_bits(fmt) {
            4 => "p4",
            8 => "p8",
            16 => "p16",
            _ => "p32",
        }
    }

    pub fn attn_plane(&self) -> &'static str {
        Self::plane_name(self.attn_low)
    }

    pub fn other_plane(&self) -> &'static str {
        Self::plane_name(self.other)
    }

    /// Does this policy hold the investigated edge's source at FP32
    /// (the PAHQ per-call `hi` override)? Discovery methods consult this
    /// when building their candidate plans.
    pub fn is_pahq(&self) -> bool {
        self.name.starts_with("pahq")
    }

    /// Storage format of the session's corrupted-activation cache: FP32
    /// for hi-fidelity policies (the patched-in activation is exactly
    /// what the paper keeps at high precision, Eq. 2), the residual
    /// format for RTN-Q (its whole pipeline lives on the low lattice).
    pub fn cache_format(&self) -> Format {
        if self.hi_fidelity_refs { quant::FP32 } else { self.resid }
    }
}

/// Nominal bit width of a format — with packed storage this is simply
/// its storage width (fp4 = 4, fp8 = 8, fp16/bf16 = 16, else 32); the
/// old implementation reconstructed it from whole-byte sizes plus an
/// mbits tie-break.
pub(crate) fn nominal_bits(fmt: Format) -> u32 {
    fmt.storage_bits() as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{BF16, FP16, FP32, FP4_E2M1, FP8_E4M3, FP8_E5M2};

    #[test]
    fn nominal_bits_names_and_planes() {
        assert_eq!(nominal_bits(FP4_E2M1), 4);
        assert_eq!(nominal_bits(FP8_E4M3), 8);
        assert_eq!(nominal_bits(FP8_E5M2), 8);
        assert_eq!(nominal_bits(FP16), 16);
        assert_eq!(nominal_bits(BF16), 16);
        assert_eq!(nominal_bits(FP32), 32);
        assert_eq!(Policy::pahq(FP8_E4M3).name, "pahq-8b");
        assert_eq!(Policy::rtn(FP4_E2M1).name, "rtn-q-4b");
        assert_eq!(Policy::pahq(FP8_E4M3).attn_plane(), "p8");
        assert_eq!(Policy::pahq(FP8_E4M3).other_plane(), "p16");
        assert_eq!(Policy::fp32().attn_plane(), "p32");
    }

    #[test]
    fn cache_format_follows_fidelity() {
        assert!(Policy::fp32().cache_format().is_passthrough());
        assert!(Policy::pahq(FP8_E4M3).cache_format().is_passthrough());
        assert_eq!(Policy::rtn(FP8_E4M3).cache_format(), FP8_E4M3);
    }
}
