//! The patched-forward engine: ACDC's activation patching, executed by
//! chaining the per-layer AOT executables and assembling every channel's
//! residual input in Rust.
//!
//! For a patch set P (edges currently knocked out of the circuit), the
//! input of destination channel c is
//!
//! ```text
//!   input(c) = Σ_{(u→c) ∉ P} out_now(u)  +  Σ_{(u→c) ∈ P} out_corrupt(u)
//! ```
//!
//! where `out_now` are *this* run's node outputs (so patches propagate
//! downstream, exactly like hook-based patching in TransformerLens) and
//! `out_corrupt` are cached node outputs from the corrupted run.
//!
//! Precision is a runtime [`Policy`]: per-head quant parameter rows flow
//! into the attention executable (PAHQ Eq. 3), mixed-precision weight
//! tensors are assembled from the [`WeightStore`]'s packed planes
//! (Eq. 4/9), and the residual accumulation format reproduces RTN-Q's
//! mantissa-loss failure when set below FP32 (paper section 2).
//!
//! Layout (one submodule per concern; the public API re-exports below
//! are the stable surface `acdc`, `baselines`, `scheduler`, and
//! `experiments` compile against):
//!
//! - [`policy`] — session precision policies ([`Policy`]) and the
//!   plane-naming / nominal-bits mapping.
//! - [`assembly`] — [`PatchMask`], the source-group structure, scratch
//!   pool, and the residual-assembly hot loop, which reads the *packed*
//!   corrupted-activation cache ([`crate::tensor::QTensor`]) through
//!   fused decode-accumulate kernels.
//! - [`session`] — [`PatchedForward`]: session state (references,
//!   caches, weight planes), the chained per-layer executable loop, the
//!   damage scoring entry points, and the measured-memory reporting
//!   ([`PatchedForward::measured_footprint`]).
//!
//! [`WeightStore`]: crate::model::WeightStore

pub mod assembly;
pub mod policy;
pub mod session;

pub use assembly::PatchMask;
pub use policy::Policy;
pub use session::PatchedForward;
