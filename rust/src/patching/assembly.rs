//! Residual-stream assembly: patch masks, source groups, reusable
//! scratch, and the hot loop that builds every channel's input from the
//! current node outputs and the *packed* corrupted-activation cache.
//!
//! Hot-path structure (see DESIGN.md §8): per layer the crate-internal
//! `Assembler` computes one `base` = Σ all clean contributions, then
//! derives each channel by patch-delta adjustment — O(|sources|) once
//! plus O(|patched|) per channel instead of O(|sources| · channels). Patch
//! swaps read the corrupted cache through the fused packed kernels
//! ([`crate::tensor::add_sub_assign_packed`]), decoding bytes inline
//! instead of dequantizing whole tensors into scratch first.
//!
//! ## Cache blocking
//!
//! Assembly streams in [`ASM_TILE`]-element tiles (4 KiB of f32), and
//! the multi-channel entry point ([`Assembler::assemble_channels`])
//! walks all destination channels *inside* the tile loop: each packed
//! corrupt plane's words are decoded once per assembly pass and the
//! decoded tile applied to every destination that swaps that source,
//! instead of re-decoding the plane once per destination channel. The
//! per-group corrupt bases get the same treatment
//! ([`Assembler::rebuild_corrupt_base`]): one decode per source plane
//! per tile, accumulated into every group that contains the source.
//! Tiling never reorders arithmetic — per element, each destination
//! sees the same operations in the same source order as the untiled
//! per-channel loop (source lists are ascending by construction, see
//! `Assembler::new`), so results stay bit-identical.

use crate::model::{Graph, Manifest, NodeId};
use crate::quant::accumulate_quantized;
use crate::tensor::{add_assign, add_sub_assign, QTensor, Tensor};

use super::policy::Policy;

/// Elements per assembly tile: 4 KiB of f32 keeps a decoded source
/// tile, a destination tile or three, and the clean plane's span
/// L1-resident together.
const ASM_TILE: usize = 1024;

// ---------------------------------------------------------------------------
// Patch masks

/// Patched-edge set, stored per destination channel as a u128 bitmask over
/// source node ids (n_nodes <= 91 for every model here).
#[derive(Clone, Debug, PartialEq)]
pub struct PatchMask {
    per_channel: Vec<u128>,
}

impl PatchMask {
    pub fn empty(n_channels: usize) -> PatchMask {
        PatchMask { per_channel: vec![0; n_channels] }
    }

    pub fn set(&mut self, chan: usize, src: NodeId, patched: bool) {
        if patched {
            self.per_channel[chan] |= 1u128 << src;
        } else {
            self.per_channel[chan] &= !(1u128 << src);
        }
    }

    pub fn get(&self, chan: usize, src: NodeId) -> bool {
        self.per_channel[chan] >> src & 1 == 1
    }

    pub fn mask(&self, chan: usize) -> u128 {
        self.per_channel[chan]
    }

    pub fn n_channels(&self) -> usize {
        self.per_channel.len()
    }

    pub fn count(&self) -> usize {
        self.per_channel.iter().map(|m| m.count_ones() as usize).sum()
    }
}

// ---------------------------------------------------------------------------
// Scratch

/// Reusable hot-path buffers: channel inputs, assembly bases, and decode
/// targets for the packed weight planes. Allocated once per engine.
pub(crate) struct Scratch {
    /// [H * B*S*D] channel inputs per q/k/v component, head-major
    pub(crate) qkv: [Vec<f32>; 3],
    /// [B*S*D] mlp/final assembly
    pub(crate) chan_in: Vec<f32>,
    /// [B*S*D] shared clean base
    base: Vec<f32>,
    // per-layer attention weights (mixed-precision assembly targets)
    pub(crate) wq: Vec<f32>,
    pub(crate) bq: Vec<f32>,
    pub(crate) wk: Vec<f32>,
    pub(crate) bk: Vec<f32>,
    pub(crate) wv: Vec<f32>,
    pub(crate) bv: Vec<f32>,
    pub(crate) wo: Vec<f32>,
    /// [H * 3] per-head quant parameter rows
    pub(crate) qp: Vec<f32>,
    // decode targets for packed-plane reads of the non-attention params
    pub(crate) wte: Vec<f32>,
    pub(crate) wpe: Vec<f32>,
    pub(crate) w1: Vec<f32>,
    pub(crate) b1: Vec<f32>,
    pub(crate) w2: Vec<f32>,
    pub(crate) b2: Vec<f32>,
    pub(crate) wu: Vec<f32>,
}

impl Scratch {
    fn new(m: &Manifest) -> Scratch {
        let bsd = m.batch * m.seq_len * m.d_model;
        let (h, d, k) = (m.n_head, m.d_model, m.d_head);
        let psize = |name: &str| m.param(name).map(|p| p.size).unwrap_or(0);
        Scratch {
            qkv: [vec![0.0; h * bsd], vec![0.0; h * bsd], vec![0.0; h * bsd]],
            chan_in: vec![0.0; bsd],
            base: vec![0.0; bsd],
            wq: vec![0.0; h * d * k],
            bq: vec![0.0; h * k],
            wk: vec![0.0; h * d * k],
            bk: vec![0.0; h * k],
            wv: vec![0.0; h * d * k],
            bv: vec![0.0; h * k],
            wo: vec![0.0; h * k * d],
            qp: vec![0.0; h * 3],
            wte: vec![0.0; psize("wte")],
            wpe: vec![0.0; psize("wpe")],
            w1: vec![0.0; psize("l0.w1")],
            b1: vec![0.0; psize("l0.b1")],
            w2: vec![0.0; psize("l0.w2")],
            b2: vec![0.0; psize("l0.b2")],
            wu: vec![0.0; psize("wu")],
        }
    }
}

// ---------------------------------------------------------------------------
// Assembler

/// Owns the source-group structure, the per-group corrupt base sums, and
/// the scratch pool; assembles channel inputs against the caller's node
/// outputs and packed corrupt cache.
pub(crate) struct Assembler {
    /// distinct source sets (all head channels of one layer share theirs);
    /// each list is ascending (graph sources are sorted), which is what
    /// lets the tiled passes iterate sources globally without reordering
    /// any group's accumulation
    groups: Vec<Vec<NodeId>>,
    /// channel index -> group id
    chan_group: Vec<usize>,
    /// per source-group Σ corrupt contributions (static per session)
    corrupt_base: Vec<Vec<f32>>,
    pub(crate) scratch: Scratch,
}

impl Assembler {
    pub(crate) fn new(
        manifest: &Manifest,
        graph: &Graph,
        channels: &[crate::model::Channel],
    ) -> Assembler {
        let mut groups: Vec<Vec<NodeId>> = Vec::new();
        let mut chan_group = Vec::with_capacity(channels.len());
        for ch in channels {
            let srcs = graph.sources(*ch);
            debug_assert!(srcs.windows(2).all(|w| w[0] < w[1]), "sources must be ascending");
            let gid = groups.iter().position(|g| *g == srcs).unwrap_or_else(|| {
                groups.push(srcs.clone());
                groups.len() - 1
            });
            chan_group.push(gid);
        }
        Assembler { groups, chan_group, corrupt_base: Vec::new(), scratch: Scratch::new(manifest) }
    }

    pub(crate) fn group_of(&self, ci: usize) -> usize {
        self.chan_group[ci]
    }

    /// Recompute the per-group corrupt base sums from a (packed) cache,
    /// cache-blocked: per tile, each source plane is decoded once and
    /// accumulated into every group containing it. Groups hold ascending
    /// source lists, so the ascending global source walk adds each
    /// group's sources in exactly the order the per-group loop did —
    /// bit-identical sums.
    pub(crate) fn rebuild_corrupt_base(&mut self, cache: &[QTensor]) {
        let bsd = self.scratch.base.len();
        let mut bases = vec![vec![0.0f32; bsd]; self.groups.len()];
        // source -> groups that contain it
        let mut users: Vec<Vec<usize>> = vec![Vec::new(); cache.len()];
        for (gid, srcs) in self.groups.iter().enumerate() {
            for &s in srcs {
                users[s].push(gid);
            }
        }
        let mut tile = [0.0f32; ASM_TILE];
        let mut off = 0;
        while off < bsd {
            let len = (bsd - off).min(ASM_TILE);
            for (s, gids) in users.iter().enumerate() {
                if gids.is_empty() {
                    continue;
                }
                cache[s].decode_range_into(off, &mut tile[..len]);
                for &gid in gids {
                    add_assign(&mut bases[gid][off..off + len], &tile[..len]);
                }
            }
            off += len;
        }
        self.corrupt_base = bases;
    }

    /// Σ of current node outputs over a group's sources into scratch.base
    /// (fast path only; quantized-resid sessions bypass this).
    pub(crate) fn compute_group_base(&mut self, gid: usize, policy: &Policy, node_out: &[Tensor]) {
        if !policy.resid.is_passthrough() {
            return;
        }
        let base = &mut self.scratch.base;
        base.fill(0.0);
        for &src in &self.groups[gid] {
            add_assign(base, &node_out[src].data);
        }
    }

    /// Assemble one channel's input into `dst`.
    pub(crate) fn assemble_channel(
        &self,
        ci: usize,
        patches: &PatchMask,
        policy: &Policy,
        node_out: &[Tensor],
        cache: &[QTensor],
        dst: &mut [f32],
    ) {
        self.assemble_channels(&[ci], patches, policy, node_out, cache, &mut [dst]);
    }

    /// Assemble several channels of ONE source group in a single
    /// cache-blocked pass. All `cis` must share a group, and `dsts`
    /// pairs with `cis`. Per [`ASM_TILE`]-sized tile, each packed source
    /// plane is decoded once and its tile applied to every destination
    /// whose patch mask swaps that source — the plane's words are
    /// touched once per assembly pass, not once per destination.
    ///
    /// Bit-identity with the historical per-channel loop: every
    /// destination still receives, per element, the same start value
    /// (clean or corrupt base) and the same add/sub swaps in the same
    /// ascending source order; only the loop nesting changed.
    pub(crate) fn assemble_channels(
        &self,
        cis: &[usize],
        patches: &PatchMask,
        policy: &Policy,
        node_out: &[Tensor],
        cache: &[QTensor],
        dsts: &mut [&mut [f32]],
    ) {
        debug_assert_eq!(cis.len(), dsts.len());
        if cis.is_empty() {
            return;
        }
        let gid = self.chan_group[cis[0]];
        debug_assert!(cis.iter().all(|&ci| self.chan_group[ci] == gid));
        let srcs = &self.groups[gid];
        let src_bits = srcs.iter().fold(0u128, |m, &s| m | 1 << s);
        let masks: Vec<u128> = cis.iter().map(|&ci| patches.mask(ci) & src_bits).collect();
        let n = dsts.first().map_or(0, |d| d.len());
        debug_assert!(dsts.iter().all(|d| d.len() == n));
        let mut tile = [0.0f32; ASM_TILE];

        if !policy.resid.is_passthrough() {
            // RTN-Q path: sequential quantized accumulation — order
            // matters for mantissa loss, so per destination this mirrors
            // "sum in fp8" faithfully, tile by tile.
            for d in dsts.iter_mut() {
                d.fill(0.0);
            }
            let mut off = 0;
            while off < n {
                let len = (n - off).min(ASM_TILE);
                for &src in srcs {
                    if masks.iter().any(|m| m >> src & 1 == 1) {
                        cache[src].decode_range_into(off, &mut tile[..len]);
                    }
                    for (d, m) in dsts.iter_mut().zip(&masks) {
                        let x: &[f32] = if m >> src & 1 == 1 {
                            &tile[..len]
                        } else {
                            &node_out[src].data[off..off + len]
                        };
                        accumulate_quantized(&mut d[off..off + len], x, policy.resid);
                    }
                }
                off += len;
            }
            return;
        }

        // Fast path: per destination, start from whichever base needs
        // fewer swaps, then splice per-source deltas. `few[i]` chooses
        // the direction exactly as the per-channel loop did.
        let few: Vec<bool> =
            masks.iter().map(|m| (m.count_ones() as usize) * 2 <= srcs.len()).collect();
        let mut off = 0;
        while off < n {
            let len = (n - off).min(ASM_TILE);
            for (d, &fw) in dsts.iter_mut().zip(&few) {
                let from = if fw { &self.scratch.base } else { &self.corrupt_base[gid] };
                d[off..off + len].copy_from_slice(&from[off..off + len]);
            }
            for &src in srcs {
                // a destination swaps this source when it is patched
                // under a few-patched mask (corruption spliced in) or
                // unpatched under a mostly-patched one (clean spliced
                // back) — i.e. when the patch bit equals `few`
                let swaps = |i: usize| (masks[i] >> src & 1 == 1) == few[i];
                if !(0..masks.len()).any(swaps) {
                    continue;
                }
                cache[src].decode_range_into(off, &mut tile[..len]);
                let clean = &node_out[src].data[off..off + len];
                for (i, d) in dsts.iter_mut().enumerate() {
                    if (masks[i] >> src & 1 == 1) != few[i] {
                        continue;
                    }
                    if few[i] {
                        add_sub_assign(&mut d[off..off + len], &tile[..len], clean);
                    } else {
                        add_sub_assign(&mut d[off..off + len], clean, &tile[..len]);
                    }
                }
            }
            off += len;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{FP32, FP8_E4M3};
    use crate::tensor::{
        accumulate_quantized_packed, add_assign_packed, add_sub_assign_packed,
        add_sub_assign_packed_rev,
    };
    use crate::util::rng::Rng;

    /// Untiled reference: the historical per-channel assembly loop,
    /// kept verbatim as the oracle for the cache-blocked pass.
    fn assemble_channel_reference(
        asm: &Assembler,
        ci: usize,
        patches: &PatchMask,
        policy: &Policy,
        node_out: &[Tensor],
        cache: &[QTensor],
        dst: &mut [f32],
    ) {
        let gid = asm.chan_group[ci];
        let srcs = &asm.groups[gid];
        let mask = patches.mask(ci);
        if !policy.resid.is_passthrough() {
            dst.fill(0.0);
            for &src in srcs {
                if mask >> src & 1 == 1 {
                    accumulate_quantized_packed(dst, &cache[src], policy.resid);
                } else {
                    accumulate_quantized(dst, &node_out[src].data, policy.resid);
                }
            }
            return;
        }
        let n_patched = (mask & srcs.iter().fold(0u128, |m, &s| m | 1 << s)).count_ones() as usize;
        if n_patched == 0 {
            dst.copy_from_slice(&asm.scratch.base);
        } else if n_patched * 2 <= srcs.len() {
            dst.copy_from_slice(&asm.scratch.base);
            for &src in srcs {
                if mask >> src & 1 == 1 {
                    add_sub_assign_packed(dst, &cache[src], &node_out[src].data);
                }
            }
        } else {
            dst.copy_from_slice(&asm.corrupt_base[gid]);
            for &src in srcs {
                if mask >> src & 1 != 1 {
                    add_sub_assign_packed_rev(dst, &node_out[src].data, &cache[src]);
                }
            }
        }
    }

    /// Untiled reference for the corrupt bases.
    fn rebuild_corrupt_base_reference(asm: &Assembler, cache: &[QTensor]) -> Vec<Vec<f32>> {
        let bsd = asm.scratch.base.len();
        asm.groups
            .iter()
            .map(|srcs| {
                let mut base = vec![0.0f32; bsd];
                for &s in srcs {
                    add_assign_packed(&mut base, &cache[s]);
                }
                base
            })
            .collect()
    }

    /// A hand-built assembler over synthetic source groups (no Graph
    /// needed — `Manifest` is a plain struct): `bsd`-element planes,
    /// one channel per entry of `chan_group`.
    fn synthetic_assembler(
        bsd: usize,
        groups: Vec<Vec<NodeId>>,
        chan_group: Vec<usize>,
    ) -> Assembler {
        let manifest = Manifest {
            name: "synthetic-asm".into(),
            n_layer: 1,
            n_head: 1,
            d_model: 1,
            d_head: 1,
            d_mlp: 0,
            seq_len: bsd,
            vocab: 1,
            batch: 1,
            n_params: 0,
            params: Vec::new(),
            artifacts: Vec::new(),
            dir: std::path::PathBuf::new(),
        };
        let mut asm = Assembler {
            groups,
            chan_group,
            corrupt_base: Vec::new(),
            scratch: Scratch::new(&manifest),
        };
        assert_eq!(asm.scratch.base.len(), bsd);
        asm.scratch.base.fill(0.0);
        asm
    }

    /// Random clean node outputs plus a corrupt cache mixing every
    /// packed width (fp8 / bf16 / fp4 / f32) across sources.
    fn synthetic_world(r: &mut Rng, bsd: usize, n_src: usize) -> (Vec<Tensor>, Vec<QTensor>) {
        let node_out: Vec<Tensor> = (0..n_src)
            .map(|_| Tensor::from_vec(&[bsd], (0..bsd).map(|_| r.normal()).collect()).unwrap())
            .collect();
        let cache: Vec<QTensor> = (0..n_src)
            .map(|i| {
                let xs: Vec<f32> = (0..bsd).map(|_| r.normal() * 4.0).collect();
                let f = [FP8_E4M3, crate::quant::BF16, crate::quant::FP4_E2M1, FP32][i % 4];
                QTensor::from_slice(&[bsd], &xs, f)
            })
            .collect();
        (node_out, cache)
    }

    #[test]
    fn tiled_corrupt_base_matches_per_group_reference() {
        let mut r = Rng::new(21);
        // lengths below / at / ragged-past the tile size
        for bsd in [5usize, ASM_TILE, ASM_TILE * 2 + 357] {
            let groups = vec![vec![0, 1, 2, 3], vec![1, 3], vec![0, 1, 2, 3, 4, 5]];
            let mut asm = synthetic_assembler(bsd, groups, vec![0, 1, 2]);
            let (_, cache) = synthetic_world(&mut r, bsd, 6);
            asm.rebuild_corrupt_base(&cache);
            let want = rebuild_corrupt_base_reference(&asm, &cache);
            assert_eq!(asm.corrupt_base, want, "bsd={bsd}");
        }
    }

    #[test]
    fn tiled_multi_channel_assembly_matches_per_channel_reference() {
        let mut r = Rng::new(22);
        let bsd = ASM_TILE + 123; // straddles a tile boundary
        let n_chan = 4;
        // all four channels share one deduped source group, as a layer's
        // head channels do in the real session
        let srcs: Vec<NodeId> = (0..6).collect();
        let mut asm = synthetic_assembler(bsd, vec![srcs], vec![0; n_chan]);
        let (node_out, cache) = synthetic_world(&mut r, bsd, 6);
        asm.rebuild_corrupt_base(&cache);
        asm.scratch.base.fill(0.0);
        for s in 0..6 {
            add_assign(&mut asm.scratch.base, &node_out[s].data);
        }
        for policy in [Policy::fp32(), Policy::pahq(FP8_E4M3), Policy::rtn(FP8_E4M3)] {
            // masks spanning empty / few / mostly / all patched
            let mut patches = PatchMask::empty(n_chan);
            for (ci, bits) in [0u128, 0b000010, 0b111011, 0b111111].into_iter().enumerate() {
                for s in 0..6 {
                    patches.set(ci, s, bits >> s & 1 == 1);
                }
            }
            let mut tiled = vec![vec![0.0f32; bsd]; n_chan];
            {
                let mut dsts: Vec<&mut [f32]> =
                    tiled.iter_mut().map(|v| v.as_mut_slice()).collect();
                let cis: Vec<usize> = (0..n_chan).collect();
                asm.assemble_channels(&cis, &patches, &policy, &node_out, &cache, &mut dsts);
            }
            for (ci, got) in tiled.iter().enumerate() {
                let mut want = vec![0.0f32; bsd];
                assemble_channel_reference(
                    &asm, ci, &patches, &policy, &node_out, &cache, &mut want,
                );
                assert_eq!(got, &want, "channel {ci} policy {:?}", policy.resid);
            }
        }
    }
}
