//! Residual-stream assembly: patch masks, source groups, reusable
//! scratch, and the hot loop that builds every channel's input from the
//! current node outputs and the *packed* corrupted-activation cache.
//!
//! Hot-path structure (see DESIGN.md §8): per layer the crate-internal
//! `Assembler` computes one `base` = Σ all clean contributions, then
//! derives each channel by patch-delta adjustment — O(|sources|) once
//! plus O(|patched|) per channel instead of O(|sources| · channels). Patch
//! swaps read the corrupted cache through the fused packed kernels
//! ([`crate::tensor::add_sub_assign_packed`]), decoding bytes inline
//! instead of dequantizing whole tensors into scratch first.

use crate::model::{Graph, Manifest, NodeId};
use crate::tensor::{
    accumulate_quantized_packed, add_assign, add_assign_packed, add_sub_assign_packed,
    add_sub_assign_packed_rev, QTensor, Tensor,
};

use super::policy::Policy;

// ---------------------------------------------------------------------------
// Patch masks

/// Patched-edge set, stored per destination channel as a u128 bitmask over
/// source node ids (n_nodes <= 91 for every model here).
#[derive(Clone, Debug, PartialEq)]
pub struct PatchMask {
    per_channel: Vec<u128>,
}

impl PatchMask {
    pub fn empty(n_channels: usize) -> PatchMask {
        PatchMask { per_channel: vec![0; n_channels] }
    }

    pub fn set(&mut self, chan: usize, src: NodeId, patched: bool) {
        if patched {
            self.per_channel[chan] |= 1u128 << src;
        } else {
            self.per_channel[chan] &= !(1u128 << src);
        }
    }

    pub fn get(&self, chan: usize, src: NodeId) -> bool {
        self.per_channel[chan] >> src & 1 == 1
    }

    pub fn mask(&self, chan: usize) -> u128 {
        self.per_channel[chan]
    }

    pub fn n_channels(&self) -> usize {
        self.per_channel.len()
    }

    pub fn count(&self) -> usize {
        self.per_channel.iter().map(|m| m.count_ones() as usize).sum()
    }
}

// ---------------------------------------------------------------------------
// Scratch

/// Reusable hot-path buffers: channel inputs, assembly bases, and decode
/// targets for the packed weight planes. Allocated once per engine.
pub(crate) struct Scratch {
    /// [H * B*S*D] channel inputs per q/k/v component, head-major
    pub(crate) qkv: [Vec<f32>; 3],
    /// [B*S*D] mlp/final assembly
    pub(crate) chan_in: Vec<f32>,
    /// [B*S*D] shared clean base
    base: Vec<f32>,
    // per-layer attention weights (mixed-precision assembly targets)
    pub(crate) wq: Vec<f32>,
    pub(crate) bq: Vec<f32>,
    pub(crate) wk: Vec<f32>,
    pub(crate) bk: Vec<f32>,
    pub(crate) wv: Vec<f32>,
    pub(crate) bv: Vec<f32>,
    pub(crate) wo: Vec<f32>,
    /// [H * 3] per-head quant parameter rows
    pub(crate) qp: Vec<f32>,
    // decode targets for packed-plane reads of the non-attention params
    pub(crate) wte: Vec<f32>,
    pub(crate) wpe: Vec<f32>,
    pub(crate) w1: Vec<f32>,
    pub(crate) b1: Vec<f32>,
    pub(crate) w2: Vec<f32>,
    pub(crate) b2: Vec<f32>,
    pub(crate) wu: Vec<f32>,
}

impl Scratch {
    fn new(m: &Manifest) -> Scratch {
        let bsd = m.batch * m.seq_len * m.d_model;
        let (h, d, k) = (m.n_head, m.d_model, m.d_head);
        let psize = |name: &str| m.param(name).map(|p| p.size).unwrap_or(0);
        Scratch {
            qkv: [vec![0.0; h * bsd], vec![0.0; h * bsd], vec![0.0; h * bsd]],
            chan_in: vec![0.0; bsd],
            base: vec![0.0; bsd],
            wq: vec![0.0; h * d * k],
            bq: vec![0.0; h * k],
            wk: vec![0.0; h * d * k],
            bk: vec![0.0; h * k],
            wv: vec![0.0; h * d * k],
            bv: vec![0.0; h * k],
            wo: vec![0.0; h * k * d],
            qp: vec![0.0; h * 3],
            wte: vec![0.0; psize("wte")],
            wpe: vec![0.0; psize("wpe")],
            w1: vec![0.0; psize("l0.w1")],
            b1: vec![0.0; psize("l0.b1")],
            w2: vec![0.0; psize("l0.w2")],
            b2: vec![0.0; psize("l0.b2")],
            wu: vec![0.0; psize("wu")],
        }
    }
}

// ---------------------------------------------------------------------------
// Assembler

/// Owns the source-group structure, the per-group corrupt base sums, and
/// the scratch pool; assembles channel inputs against the caller's node
/// outputs and packed corrupt cache.
pub(crate) struct Assembler {
    /// distinct source sets (all head channels of one layer share theirs)
    groups: Vec<Vec<NodeId>>,
    /// channel index -> group id
    chan_group: Vec<usize>,
    /// per source-group Σ corrupt contributions (static per session)
    corrupt_base: Vec<Vec<f32>>,
    pub(crate) scratch: Scratch,
}

impl Assembler {
    pub(crate) fn new(
        manifest: &Manifest,
        graph: &Graph,
        channels: &[crate::model::Channel],
    ) -> Assembler {
        let mut groups: Vec<Vec<NodeId>> = Vec::new();
        let mut chan_group = Vec::with_capacity(channels.len());
        for ch in channels {
            let srcs = graph.sources(*ch);
            let gid = groups.iter().position(|g| *g == srcs).unwrap_or_else(|| {
                groups.push(srcs.clone());
                groups.len() - 1
            });
            chan_group.push(gid);
        }
        Assembler { groups, chan_group, corrupt_base: Vec::new(), scratch: Scratch::new(manifest) }
    }

    pub(crate) fn group_of(&self, ci: usize) -> usize {
        self.chan_group[ci]
    }

    /// Recompute the per-group corrupt base sums from a (packed) cache.
    pub(crate) fn rebuild_corrupt_base(&mut self, cache: &[QTensor]) {
        let bsd = self.scratch.base.len();
        self.corrupt_base = self
            .groups
            .iter()
            .map(|srcs| {
                let mut base = vec![0.0f32; bsd];
                for &s in srcs {
                    add_assign_packed(&mut base, &cache[s]);
                }
                base
            })
            .collect();
    }

    /// Σ of current node outputs over a group's sources into scratch.base
    /// (fast path only; quantized-resid sessions bypass this).
    pub(crate) fn compute_group_base(&mut self, gid: usize, policy: &Policy, node_out: &[Tensor]) {
        if !policy.resid.is_passthrough() {
            return;
        }
        let base = &mut self.scratch.base;
        base.fill(0.0);
        for &src in &self.groups[gid] {
            add_assign(base, &node_out[src].data);
        }
    }

    /// Assemble one channel's input into `dst`.
    pub(crate) fn assemble_channel(
        &self,
        ci: usize,
        patches: &PatchMask,
        policy: &Policy,
        node_out: &[Tensor],
        cache: &[QTensor],
        dst: &mut [f32],
    ) {
        let gid = self.chan_group[ci];
        let srcs = &self.groups[gid];
        let mask = patches.mask(ci);

        if !policy.resid.is_passthrough() {
            // RTN-Q path: sequential quantized accumulation — order matters
            // for mantissa loss, so this mirrors "sum in fp8" faithfully.
            dst.fill(0.0);
            for &src in srcs {
                if mask >> src & 1 == 1 {
                    accumulate_quantized_packed(dst, &cache[src], policy.resid);
                } else {
                    crate::quant::accumulate_quantized(dst, &node_out[src].data, policy.resid);
                }
            }
            return;
        }

        let n_patched = (mask & srcs.iter().fold(0u128, |m, &s| m | 1 << s)).count_ones() as usize;
        if n_patched == 0 {
            dst.copy_from_slice(&self.scratch.base);
        } else if n_patched * 2 <= srcs.len() {
            // few patches: start from the clean base, swap in corruptions
            dst.copy_from_slice(&self.scratch.base);
            for &src in srcs {
                if mask >> src & 1 == 1 {
                    add_sub_assign_packed(dst, &cache[src], &node_out[src].data);
                }
            }
        } else {
            // mostly patched: start from the corrupt base, swap clean back
            dst.copy_from_slice(&self.corrupt_base[gid]);
            for &src in srcs {
                if mask >> src & 1 != 1 {
                    add_sub_assign_packed_rev(dst, &node_out[src].data, &cache[src]);
                }
            }
        }
    }
}
