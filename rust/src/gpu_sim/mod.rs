//! Discrete-event GPU simulator.
//!
//! The paper's runtime/memory claims (Tab. 3, Tab. 4) are measured on an
//! NVIDIA H20 with CUDA streams and native FP8; none of that exists here,
//! so this module simulates the *structure* those claims depend on:
//!
//! - [`sim`]    — the event core: streams with FIFO ordering, ops with
//!                dependencies, makespan = max finish time;
//! - [`cost`]   — a calibrated cost model: GEMM time by precision,
//!                elementwise kernels, launch overhead, and the crucial
//!                host→device transfer model (per-chunk overhead dominates
//!                for the strided per-head row gathers PAHQ performs);
//! - [`arch`]   — the *paper's* model architectures (GPT-2 small/medium/
//!                large/XL, attn-4l, redwood-2l) with their true edge
//!                counts, so simulated totals are at the paper's scale;
//! - [`memory`] — the device-memory model behind Tab. 3's GB column.
//!
//! The simulation is used by [`crate::scheduler`] to predict end-to-end
//! ACDC / RTN-Q / PAHQ runtimes; the Rust runtime's *real* wall-clock on
//! the tiny sim models is reported alongside, never conflated.

pub mod arch;
pub mod cost;
pub mod memory;
pub mod sim;

pub use arch::RealArch;
pub use cost::CostModel;
pub use sim::{EventId, Sim, StreamId};
