//! Calibrated cost model for the simulated H20.
//!
//! Constants approximate the paper's testbed (NVIDIA H20: ~44 TFLOP/s
//! dense FP32, ~148 TFLOP/s BF16, ~296 TFLOP/s FP8; PCIe Gen5 x16). Two
//! modelling decisions matter far more than the absolute throughputs:
//!
//! 1. **Small-GEMM efficiency.** Circuit-discovery batches are tiny
//!    (B·S ≈ 640 tokens), so GEMMs reach only a few percent of peak; we
//!    apply a size-dependent efficiency factor and a fixed launch
//!    overhead per kernel. This is why RTN-Q's 4x flop-rate advantage
//!    buys ~3.5x, not 4x (paper Tab. 3).
//!
//! 2. **Strided host→device gathers.** PAHQ stages *one head's rows* of
//!    W_Q/K/V — a strided slice, not a contiguous buffer — so the
//!    transfer decomposes into one chunk per matrix row with a fixed
//!    per-chunk overhead. This is the mechanism behind the paper's
//!    observation that "the time required for model weight loading is
//!    longer than the high-precision calculation time" (Tab. 4
//!    discussion), and it is what makes the load stream so valuable.
//!
//! `tests::tab4_ordering_robust` asserts the Tab. 4 ablation ordering is
//! stable under ±2x perturbations of every constant (DESIGN.md §8).

use crate::quant::Format;

#[derive(Clone, Debug)]
pub struct CostModel {
    /// peak dense throughputs, FLOP/µs (= MFLOP/ms = TFLOP/s)
    pub tflops_fp32: f64,
    pub tflops_bf16: f64,
    pub tflops_fp8: f64,
    /// kernel launch + driver overhead per op, µs
    pub launch_us: f64,
    /// contiguous PCIe bandwidth, GB/s
    pub pcie_gbps: f64,
    /// fixed overhead per host->device copy chunk, µs (strided gathers)
    pub chunk_us: f64,
    /// elementwise kernel bandwidth, GB/s (quant/dequant, masks, merges)
    pub ew_gbps: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            tflops_fp32: 44.0,
            tflops_bf16: 148.0,
            tflops_fp8: 296.0,
            launch_us: 6.0,
            pcie_gbps: 24.0,
            chunk_us: 3.8,
            // fake-quant is ALU-bound (frexp/round chains), far below copy
            // bandwidth — this is what RTN-Q pays around every GEMM
            ew_gbps: 200.0,
        }
    }
}

impl CostModel {
    fn throughput(&self, fmt: Format) -> f64 {
        match fmt.storage_bits() {
            // fp4 rides the fp8 tensor-core path on the modeled part
            4 | 8 => self.tflops_fp8,
            16 => self.tflops_bf16,
            _ => self.tflops_fp32,
        }
    }

    /// Size-dependent GEMM efficiency: tiny GEMMs are memory/launch bound.
    /// Ramps from ~2% at 1 MFLOP to ~60% at 100 GFLOP.
    fn efficiency(&self, flops: f64) -> f64 {
        let x = (flops / 2.0e9).min(1.0); // saturation point: 2 GFLOP
        0.02 + 0.58 * x.powf(0.5)
    }

    /// Time (µs) of an m x n x k GEMM at a precision.
    pub fn gemm_us(&self, m: usize, n: usize, k: usize, fmt: Format) -> f64 {
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        let peak = self.throughput(fmt) * 1e6; // FLOP/µs
        self.launch_us + flops / (peak * self.efficiency(flops))
    }

    /// Time (µs) of an elementwise kernel touching `bytes`.
    pub fn elementwise_us(&self, bytes: usize) -> f64 {
        self.launch_us + bytes as f64 / (self.ew_gbps * 1e3)
    }

    /// Host->device transfer of `bytes` split into `chunks` strided
    /// pieces (chunks=1 for a contiguous buffer).
    pub fn transfer_us(&self, bytes: usize, chunks: usize) -> f64 {
        chunks as f64 * self.chunk_us + bytes as f64 / (self.pcie_gbps * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{BF16, FP32, FP8_E4M3};

    #[test]
    fn precision_ordering() {
        let c = CostModel::default();
        let f32t = c.gemm_us(4096, 4096, 4096, FP32);
        let bf = c.gemm_us(4096, 4096, 4096, BF16);
        let f8 = c.gemm_us(4096, 4096, 4096, FP8_E4M3);
        assert!(f8 < bf && bf < f32t);
        // at large sizes the ratio approaches the throughput ratio
        assert!(f32t / f8 > 4.0, "ratio {}", f32t / f8);
    }

    #[test]
    fn small_gemms_are_launch_bound() {
        let c = CostModel::default();
        let t = c.gemm_us(64, 64, 64, FP8_E4M3);
        assert!(t < 2.0 * c.launch_us, "tiny GEMM ≈ launch overhead, got {t}");
        // and precision barely matters down here
        let t32 = c.gemm_us(64, 64, 64, FP32);
        assert!(t32 / t < 1.5);
    }

    #[test]
    fn strided_transfers_dominated_by_chunks() {
        let c = CostModel::default();
        let contiguous = c.transfer_us(2 << 20, 1);
        let strided = c.transfer_us(2 << 20, 768);
        assert!(strided > 5.0 * contiguous, "{strided} vs {contiguous}");
    }

    #[test]
    fn monotone_in_size() {
        let c = CostModel::default();
        let mut prev = 0.0;
        for m in [64, 256, 1024, 4096] {
            let t = c.gemm_us(m, 768, 768, FP32);
            assert!(t > prev);
            prev = t;
        }
    }
}
