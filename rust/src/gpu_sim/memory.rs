//! Device-memory accounting, simulated and measured.
//!
//! Two views live here:
//!
//! - [`memory_model`] — the *simulated* footprint of a method at the
//!   paper's real scale (Tab. 3's "Mem (GB)" column), driven by a
//!   [`RealArch`]'s dimensions and a calibrated framework constant.
//! - [`MeasuredFootprint`] — the *measured* footprint of a live engine
//!   session: actual packed-payload bytes summed from
//!   `tensor::QTensor::bytes()` over the session's resident weight
//!   planes and its corrupted-activation cache. Built by
//!   `patching::PatchedForward::measured_footprint` and printed side by
//!   side with the simulated numbers by `pahq run` / `pahq sweep`.
//!
//! Decomposition per simulated method:
//!   total = framework overhead (CUDA context, allocator pools, workspace)
//!         + resident weights at the method's storage precision
//!         + (PAHQ only) FP32 staging area for one head + one W_O
//!         + activation caches (clean + corrupt node outputs) at the
//!           method's activation precision
//!         + transient forward activations (~2 layers' worth at peak).
//!
//! The framework constant is calibrated once against the paper's ACDC
//! row (GPT-2: 6.23 GB) and shared by every method — differences between
//! methods come only from the structural terms, which is what the table
//! is actually about (ACDC > PAHQ ≈ RTN-Q, gap ≈ 1/3).

use super::arch::RealArch;

/// Calibrated PyTorch/CUDA baseline footprint (GB -> bytes).
pub const FRAMEWORK_BYTES: usize = 2_900_000_000;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodKind {
    AcdcFp32,
    RtnQ,
    Pahq,
}

impl MethodKind {
    /// Simulated-memory method of a session policy — derived from the
    /// policy's own structure so the mapping cannot drift from
    /// [`crate::patching::Policy`]'s constructors.
    pub fn of_policy(pol: &crate::patching::Policy) -> MethodKind {
        if pol.attn_low.is_passthrough() && pol.other.is_passthrough() {
            MethodKind::AcdcFp32
        } else if pol.quantize_logits {
            MethodKind::RtnQ
        } else {
            MethodKind::Pahq
        }
    }
}

#[derive(Clone, Debug)]
pub struct MemoryBreakdown {
    pub framework: usize,
    pub weights: usize,
    pub staging: usize,
    pub act_cache: usize,
    pub transient: usize,
}

impl MemoryBreakdown {
    pub fn total(&self) -> usize {
        self.framework + self.weights + self.staging + self.act_cache + self.transient
    }

    pub fn total_gb(&self) -> f64 {
        self.total() as f64 / 1e9
    }
}

/// Measured bytes a live engine session holds resident: per-plane packed
/// weight payloads plus the packed corrupted-activation cache. Unlike
/// [`MemoryBreakdown`] these are real allocation sizes, not a model.
#[derive(Clone, Debug)]
pub struct MeasuredFootprint {
    /// session policy name (e.g. "pahq-8b")
    pub method: String,
    /// (plane name, payload bytes) for every plane the session reads
    pub weight_planes: Vec<(String, usize)>,
    /// packed corrupted-activation cache bytes
    pub act_cache: usize,
}

impl MeasuredFootprint {
    /// Total resident weight-plane bytes.
    pub fn weights(&self) -> usize {
        self.weight_planes.iter().map(|(_, b)| b).sum()
    }

    /// Weights + activation cache.
    pub fn total(&self) -> usize {
        self.weights() + self.act_cache
    }
}

pub fn memory_model(arch: &RealArch, method: MethodKind) -> MemoryBreakdown {
    let (w_bytes, act_bytes) = match method {
        MethodKind::AcdcFp32 => (4, 4),
        MethodKind::RtnQ => (1, 1),
        // PAHQ: FP8 weights resident; activations unified to FP32 only for
        // the layer in flight — caches stay at FP8 (paper stores the
        // low-precision pipeline and re-materializes FP32 per evaluation)
        MethodKind::Pahq => (1, 1),
    };
    let weights = arch.n_params * w_bytes;
    let staging = match method {
        MethodKind::Pahq => arch.head_bytes() + arch.wo_bytes(),
        _ => 0,
    };
    let act_cache = arch.activation_cache_bytes(act_bytes);
    // transient peak: a couple of layers of per-head channel inputs at the
    // storage precision, plus — for PAHQ — ONE layer's unified-FP32
    // attention activations (Eq. 10 re-materializes FP32 per layer in
    // flight, not for the whole network; that is the point of the design)
    let compute_bytes = match method {
        MethodKind::AcdcFp32 => 4,
        _ => 1,
    };
    let mut transient =
        2 * 3 * arch.n_head * arch.batch * arch.seq * arch.d_model * compute_bytes;
    if method == MethodKind::Pahq {
        // one layer's q/k/v at FP32 (D already spans all heads)
        transient += 3 * arch.batch * arch.seq * arch.d_model * 4;
    }
    MemoryBreakdown {
        framework: FRAMEWORK_BYTES,
        weights,
        staging,
        act_cache,
        transient,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tab3_ordering_and_ratio() {
        // Tab. 3 shape: ACDC > PAHQ >= RTN-Q, PAHQ within ~2-5% of RTN-Q,
        // and ACDC -> PAHQ saves ≳ 25% (paper: "nearly 1/3").
        let a = RealArch::by_name("gpt2").unwrap();
        let acdc = memory_model(&a, MethodKind::AcdcFp32).total_gb();
        let rtn = memory_model(&a, MethodKind::RtnQ).total_gb();
        let pahq = memory_model(&a, MethodKind::Pahq).total_gb();
        assert!(acdc > pahq && pahq >= rtn, "{acdc} {pahq} {rtn}");
        let saving = 1.0 - pahq / acdc;
        assert!(saving > 0.2, "PAHQ saves {saving:.2} vs ACDC");
        // PAHQ's staging overhead over RTN-Q is small
        assert!((pahq - rtn) / rtn < 0.05, "{pahq} vs {rtn}");
    }

    #[test]
    fn gpt2_acdc_near_paper_value() {
        // calibration sanity: paper reports 6.23 GB for ACDC on GPT-2
        let a = RealArch::by_name("gpt2").unwrap();
        let gb = memory_model(&a, MethodKind::AcdcFp32).total_gb();
        assert!((4.0..9.0).contains(&gb), "ACDC gpt2 = {gb:.2} GB");
    }

    #[test]
    fn measured_footprint_sums() {
        let fp = MeasuredFootprint {
            method: "pahq-8b".into(),
            weight_planes: vec![("p8".into(), 100), ("p16".into(), 200)],
            act_cache: 50,
        };
        assert_eq!(fp.weights(), 300);
        assert_eq!(fp.total(), 350);
    }

    #[test]
    fn smaller_models_use_less() {
        for m in [MethodKind::AcdcFp32, MethodKind::RtnQ, MethodKind::Pahq] {
            let g = memory_model(&RealArch::by_name("gpt2").unwrap(), m).total();
            let a4 = memory_model(&RealArch::by_name("attn-4l").unwrap(), m).total();
            let r2 = memory_model(&RealArch::by_name("redwood-2l").unwrap(), m).total();
            assert!(g > a4 && a4 > r2);
        }
    }
}
