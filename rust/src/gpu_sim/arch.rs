//! The paper's real model architectures, used to run the simulated
//! experiments at the paper's scale (our runnable sim models are tiny —
//! the DES doesn't care, it only needs dimensions and edge counts).

use crate::model::Graph;

#[derive(Clone, Debug)]
pub struct RealArch {
    pub name: &'static str,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_model: usize,
    pub d_head: usize,
    pub d_mlp: usize,
    /// tokens in flight per edge evaluation (batch x seq of the ACDC run)
    pub batch: usize,
    pub seq: usize,
    pub n_params: usize,
}

impl RealArch {
    pub fn by_name(name: &str) -> Option<RealArch> {
        Some(match name {
            // GPT-2 small: 12L x 12H x 768. Batch 256: ACDC evaluates the
            // metric expectation over a large prompt set per edge.
            "gpt2" | "gpt2s-sim" => arch("gpt2", 12, 12, 768, 64, 3072, 256, 20),
            // attn-4l (Heimersheim & Janiak): 4L x 8H x 512, attention-only
            "attn-4l" | "attn4l-sim" => arch("attn-4l", 4, 8, 512, 64, 0, 256, 20),
            // redwood-2l: 2L x 8H x 256, attention-only
            "redwood-2l" | "redwood2l-sim" => arch("redwood-2l", 2, 8, 256, 32, 0, 256, 20),
            // appendix C scale series
            "gpt2-medium" | "gpt2m-sim" => arch("gpt2-medium", 24, 16, 1024, 64, 4096, 6, 20),
            "gpt2-large" | "gpt2l-sim" => arch("gpt2-large", 36, 20, 1280, 64, 5120, 5, 20),
            "gpt2-xl" | "gpt2xl-sim" => arch("gpt2-xl", 48, 25, 1600, 64, 6400, 4, 20),
            _ => return None,
        })
    }

    pub fn graph(&self) -> Graph {
        Graph { n_layer: self.n_layer, n_head: self.n_head, has_mlp: self.d_mlp > 0 }
    }

    /// Edges ACDC must evaluate (one sweep).
    pub fn n_edges(&self) -> usize {
        self.graph().n_edges()
    }

    pub fn has_mlp(&self) -> bool {
        self.d_mlp > 0
    }

    /// fp32 bytes of all parameters.
    pub fn param_bytes(&self) -> usize {
        self.n_params * 4
    }

    /// fp32 bytes of one attention head's Q/K/V/O weights (the unit PAHQ
    /// stages to the device per edge evaluation).
    pub fn head_bytes(&self) -> usize {
        4 * (4 * self.d_model * self.d_head + 3 * self.d_head)
    }

    /// fp32 bytes of one layer's full W_O (also uploaded per the paper's
    /// Phase 1, Eq. 11).
    pub fn wo_bytes(&self) -> usize {
        4 * self.n_head * self.d_head * self.d_model
    }

    /// Activation-cache bytes per precision byte-width: clean + corrupt
    /// node-output caches. Caches are kept for a bounded reference batch
    /// (implementations stream the rest), capped at CACHE_BATCH.
    pub fn activation_cache_bytes(&self, bytes_per_elem: usize) -> usize {
        const CACHE_BATCH: usize = 128;
        let n_nodes = self.graph().n_nodes();
        2 * n_nodes * self.batch.min(CACHE_BATCH) * self.seq * self.d_model * bytes_per_elem
    }
}

fn arch(
    name: &'static str,
    n_layer: usize,
    n_head: usize,
    d_model: usize,
    d_head: usize,
    d_mlp: usize,
    batch: usize,
    seq: usize,
) -> RealArch {
    // parameter count: embeddings (50257 vocab + 1024 pos for gpt2 family;
    // folded into a single constant per arch) + per-layer attn + mlp
    let vocab = 50257usize;
    let per_layer = 4 * d_model * d_model + 4 * d_model // attn w + b
        + if d_mlp > 0 { 2 * d_model * d_mlp + d_mlp + d_model } else { 0 }
        + 4 * d_model; // ln params
    let n_params = vocab * d_model + 1024 * d_model + n_layer * per_layer;
    RealArch { name, n_layer, n_head, d_model, d_head, d_mlp, batch, seq, n_params }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt2_edge_count_matches_paper_fig3() {
        // paper Fig. 3: the IOI circuit starts from ~35,000 edges
        let a = RealArch::by_name("gpt2").unwrap();
        let e = a.n_edges();
        assert!((30_000..40_000).contains(&e), "gpt2 edges = {e}");
    }

    #[test]
    fn gpt2_params_close_to_124m() {
        let a = RealArch::by_name("gpt2").unwrap();
        assert!((100e6..140e6).contains(&(a.n_params as f64)), "{}", a.n_params);
    }

    #[test]
    fn sim_names_alias_real_archs() {
        for (simname, real) in [
            ("gpt2s-sim", "gpt2"),
            ("attn4l-sim", "attn-4l"),
            ("redwood2l-sim", "redwood-2l"),
        ] {
            assert_eq!(
                RealArch::by_name(simname).unwrap().name,
                RealArch::by_name(real).unwrap().name
            );
        }
    }

    #[test]
    fn scale_series_grows() {
        let e_s = RealArch::by_name("gpt2").unwrap().n_edges();
        let e_m = RealArch::by_name("gpt2-medium").unwrap().n_edges();
        let e_l = RealArch::by_name("gpt2-large").unwrap().n_edges();
        assert!(e_s < e_m && e_m < e_l);
    }
}
