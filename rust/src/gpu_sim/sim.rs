//! Event core of the GPU simulator.
//!
//! CUDA semantics modelled: ops issued to a stream execute in issue order
//! (FIFO); an op additionally waits for its cross-stream dependencies
//! (cudaStreamWaitEvent); op completion is an event others can wait on.
//! Time is f64 microseconds.

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StreamId(pub usize);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct EventId(usize);

#[derive(Clone, Debug)]
struct OpRecord {
    stream: StreamId,
    start: f64,
    finish: f64,
    label: &'static str,
}

/// Discrete-event simulator state.
#[derive(Clone, Debug, Default)]
pub struct Sim {
    stream_ready: Vec<f64>,
    ops: Vec<OpRecord>,
    /// per-stream busy time (for utilization reporting)
    busy: Vec<f64>,
}

impl Sim {
    pub fn new(n_streams: usize) -> Sim {
        Sim { stream_ready: vec![0.0; n_streams], ops: Vec::new(), busy: vec![0.0; n_streams] }
    }

    /// Issue an op of `dur` µs on `stream`, starting no earlier than the
    /// stream's previous op and all `deps`. Returns its completion event.
    pub fn op(
        &mut self,
        stream: StreamId,
        dur: f64,
        deps: &[EventId],
        label: &'static str,
    ) -> EventId {
        debug_assert!(dur >= 0.0);
        let dep_t = deps
            .iter()
            .map(|e| self.ops[e.0].finish)
            .fold(0.0f64, f64::max);
        let start = self.stream_ready[stream.0].max(dep_t);
        let finish = start + dur;
        self.stream_ready[stream.0] = finish;
        self.busy[stream.0] += dur;
        self.ops.push(OpRecord { stream, start, finish, label });
        EventId(self.ops.len() - 1)
    }

    /// Completion time of an event.
    pub fn finish(&self, e: EventId) -> f64 {
        self.ops[e.0].finish
    }

    /// Latest completion across all ops (total simulated runtime).
    pub fn makespan(&self) -> f64 {
        self.ops.iter().map(|o| o.finish).fold(0.0, f64::max)
    }

    /// Busy fraction of a stream over the makespan.
    pub fn utilization(&self, stream: StreamId) -> f64 {
        let m = self.makespan();
        if m == 0.0 {
            0.0
        } else {
            self.busy[stream.0] / m
        }
    }

    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// Timeline rows (start, finish, stream, label) — scheduler_demo
    /// renders these as an ASCII Gantt chart.
    pub fn timeline(&self) -> Vec<(f64, f64, usize, &'static str)> {
        self.ops.iter().map(|o| (o.start, o.finish, o.stream.0, o.label)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_stream() {
        let mut s = Sim::new(1);
        let a = s.op(StreamId(0), 10.0, &[], "a");
        let b = s.op(StreamId(0), 5.0, &[], "b");
        assert_eq!(s.finish(a), 10.0);
        assert_eq!(s.finish(b), 15.0, "b waits for a despite no explicit dep");
    }

    #[test]
    fn parallel_streams_overlap() {
        let mut s = Sim::new(2);
        s.op(StreamId(0), 10.0, &[], "x");
        s.op(StreamId(1), 7.0, &[], "y");
        assert_eq!(s.makespan(), 10.0, "overlap: max, not sum");
    }

    #[test]
    fn cross_stream_dependency() {
        let mut s = Sim::new(2);
        let load = s.op(StreamId(0), 10.0, &[], "load");
        let compute = s.op(StreamId(1), 5.0, &[load], "compute");
        assert_eq!(s.finish(compute), 15.0);
    }

    #[test]
    fn transfer_masking_max_not_sum() {
        // the paper's core scheduling claim: total ≈ max(T_transfer,
        // T_comp_low), not the sum (section 3.2)
        let mut s = Sim::new(3);
        let load = s.op(StreamId(0), 30.0, &[], "load w32");
        let low = s.op(StreamId(1), 50.0, &[], "fp8 gemm");
        let high = s.op(StreamId(2), 10.0, &[load], "fp32 gemm");
        let merge = s.op(StreamId(1), 1.0, &[low, high], "assemble");
        assert_eq!(s.finish(merge), 51.0);
        assert!(s.makespan() < 30.0 + 50.0 + 10.0);
    }

    #[test]
    fn utilization_bounded() {
        let mut s = Sim::new(2);
        s.op(StreamId(0), 10.0, &[], "a");
        s.op(StreamId(1), 4.0, &[], "b");
        assert!((s.utilization(StreamId(0)) - 1.0).abs() < 1e-9);
        assert!((s.utilization(StreamId(1)) - 0.4).abs() < 1e-9);
    }
}
