//! Evaluation: ground-truth circuits, ROC sweeps, and AUC per method.
//!
//! **Ground truth.** The paper scores discovered circuits against
//! manually-identified reference circuits (IOI paper etc.). Those don't
//! exist for our synthetic models, so the reference circuit is defined by
//! the noise-free version of the same experiment: exhaustive single-edge
//! activation patching at FP32. Edge e is in C* iff its standalone
//! ΔL_KL exceeds τ* = max(1e-4, GT_REL · max_e ΔL) — a relative knee
//! that keeps C* at the few-percent sparsity the literature reports.
//! GT_REL is deliberately small: reference circuits (e.g. IOI's backup /
//! negative name-mover heads) contain *weak-but-real* edges one to two
//! orders of magnitude below the dominant ones, and those are exactly
//! the edges FP8 underflow garbles — the contrast Fig. 1 / Tab. 1
//! measures. Computed once per (model, task) and cached under
//! `artifacts/groundtruth/`.
//!
//! **ROC.** Threshold-sweep methods (ACDC / RTN-Q / PAHQ) contribute one
//! (FPR, TPR) point per τ in the paper's 21-value grid; score-based
//! methods (EAP / HISP / SP) sweep their own score thresholds densely.
//! AUC uses the pessimistic Pareto construction (metrics module).

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::acdc::{self, AcdcConfig};
use crate::metrics::{auc_pessimistic, confusion, Objective, RocPoint};
use crate::model::Edge;
use crate::patching::{PatchedForward, Policy};
use crate::util::json::{obj as json_obj, Json};

/// Relative knee for ground-truth membership (see module docs).
pub const GT_REL: f32 = 0.002;

/// Per-edge standalone FP32 ΔL, aligned with `graph.edges()` order.
#[derive(Clone, Debug)]
pub struct GroundTruth {
    pub edges: Vec<Edge>,
    pub delta: Vec<f32>,
    pub tau_star: f32,
    pub member: Vec<bool>,
}

impl GroundTruth {
    pub fn n_members(&self) -> usize {
        self.member.iter().filter(|&&m| m).count()
    }
}

fn gt_cache_path(model: &str, task: &str, obj: Objective) -> PathBuf {
    let tag = match obj {
        Objective::Kl => "kl",
        Objective::LogitDiff => "task",
    };
    crate::artifacts_root()
        .join("groundtruth")
        .join(format!("{model}_{task}_{tag}.json"))
}

/// Compute (or load from cache) the ground-truth circuit.
///
/// The engine must be in an FP32 session (asserted): truth is by
/// definition noise-free.
pub fn ground_truth(
    engine: &mut PatchedForward,
    model: &str,
    task: &str,
    obj: Objective,
) -> Result<GroundTruth> {
    let edges = engine.graph.edges();
    let path = gt_cache_path(model, task, obj);
    if let Ok(j) = Json::parse_file(&path) {
        if let Ok(delta) = j.get("delta").and_then(|d| d.f32_vec()) {
            if delta.len() == edges.len() {
                return Ok(finish(edges, delta));
            }
        }
    }

    assert!(
        engine.session().name == "acdc-fp32",
        "ground truth must be computed under the FP32 session"
    );
    let mut delta = Vec::with_capacity(edges.len());
    let mut patches = engine.empty_patches();
    for e in &edges {
        let ci = engine.chan_index(e.dst);
        patches.set(ci, e.src, true);
        delta.push(engine.damage(&patches, None, obj)?);
        patches.set(ci, e.src, false);
    }

    std::fs::create_dir_all(path.parent().unwrap()).ok();
    let dump = json_obj(vec![
        ("model", Json::from(model)),
        ("task", Json::from(task)),
        ("delta", Json::Arr(delta.iter().map(|&d| Json::Num(d as f64)).collect())),
    ]);
    std::fs::write(&path, dump.dump()).with_context(|| format!("writing {}", path.display()))?;
    Ok(finish(edges, delta))
}

fn finish(edges: Vec<Edge>, delta: Vec<f32>) -> GroundTruth {
    let max = delta.iter().copied().fold(0.0f32, f32::max);
    let tau_star = (GT_REL * max).max(1e-4);
    let member = delta.iter().map(|&d| d >= tau_star).collect();
    GroundTruth { edges, delta, tau_star, member }
}

// ---------------------------------------------------------------------------
// ROC sweeps

#[derive(Clone, Debug)]
pub struct SweepResult {
    pub points: Vec<RocPoint>,
    pub auc: f64,
    /// (tau, kept flags) per threshold — reused by Tab. 2's accuracy rows
    pub circuits: Vec<(f32, Vec<bool>)>,
}

/// Threshold-sweep ROC for an ACDC-family method (policy decides which).
pub fn sweep_acdc(
    engine: &mut PatchedForward,
    policy: Policy,
    obj: Objective,
    truth: &GroundTruth,
    thresholds: &[f32],
) -> Result<SweepResult> {
    engine.set_session(policy)?;
    let mut points = Vec::new();
    let mut circuits = Vec::new();
    for &tau in thresholds {
        let res = acdc::run(engine, &AcdcConfig::new(tau, obj))?;
        points.push(confusion(&res.kept, &truth.member));
        circuits.push((tau, res.kept));
    }
    let auc = auc_pessimistic(&points);
    Ok(SweepResult { points, auc, circuits })
}

/// Score-based ROC (EAP / HISP / SP): edges with score >= threshold are
/// "in circuit"; sweep every distinct score.
pub fn sweep_scores(scores: &[f32], truth: &GroundTruth) -> SweepResult {
    debug_assert_eq!(scores.len(), truth.member.len());
    let mut uniq: Vec<f32> = scores.to_vec();
    uniq.sort_by(|a, b| b.partial_cmp(a).unwrap());
    uniq.dedup();
    let mut points = Vec::new();
    let mut circuits = Vec::new();
    // cap the sweep density: 64 quantile thresholds is plenty for AUC
    let step = (uniq.len() / 64).max(1);
    for th in uniq.iter().step_by(step) {
        let kept: Vec<bool> = scores.iter().map(|&s| s >= *th).collect();
        points.push(confusion(&kept, &truth.member));
        circuits.push((*th, kept));
    }
    let auc = auc_pessimistic(&points);
    SweepResult { points, auc, circuits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::FP8_E4M3;

    fn engine() -> Option<PatchedForward> {
        PatchedForward::new("redwood2l-sim", "ioi").ok()
    }

    #[test]
    fn ground_truth_caches_and_is_sparse() {
        let Some(mut e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let gt = ground_truth(&mut e, "redwood2l-sim", "ioi", Objective::Kl).unwrap();
        assert_eq!(gt.delta.len(), e.graph.n_edges());
        let frac = gt.n_members() as f64 / gt.delta.len() as f64;
        assert!(frac > 0.005 && frac < 0.6, "circuit fraction {frac}");
        // cached second call is near-instant (no forward passes)
        let before = e.forward_count;
        let t1 = std::time::Instant::now();
        let gt2 = ground_truth(&mut e, "redwood2l-sim", "ioi", Objective::Kl).unwrap();
        assert_eq!(e.forward_count, before, "cache hit runs no forwards");
        assert!(t1.elapsed() < std::time::Duration::from_millis(200));
        assert_eq!(gt.member, gt2.member);
    }

    #[test]
    fn fig1_shape_quantization_ordering() {
        // The headline qualitative claim (Fig. 1 / Tab. 1 / Tab. 5):
        // precision ordering of discovery quality. On our build-time
        // models (trained to saturation, unlike pretrained GPT-2) FP8
        // RTN-Q degrades mildly rather than catastrophically; the paper's
        // underflow collapse appears one format level down, at 4 bits,
        // where the quantum exceeds the activation deltas entirely —
        // see EXPERIMENTS.md "Divergences". Asserted shape:
        //   ACDC ≈ PAHQ >= RTN-Q(8b) >> RTN-Q(4b)
        let Some(mut e) = engine() else { return };
        let gt = ground_truth(&mut e, "redwood2l-sim", "ioi", Objective::Kl).unwrap();
        // subsample thresholds for test speed
        let taus: Vec<f32> = acdc::paper_thresholds().into_iter().step_by(4).collect();
        let acdc32 = sweep_acdc(&mut e, Policy::fp32(), Objective::Kl, &gt, &taus).unwrap();
        let rtn8 = sweep_acdc(&mut e, Policy::rtn(FP8_E4M3), Objective::Kl, &gt, &taus).unwrap();
        let rtn4 =
            sweep_acdc(&mut e, Policy::rtn(crate::quant::FP4_E2M1), Objective::Kl, &gt, &taus)
                .unwrap();
        let pahq = sweep_acdc(&mut e, Policy::pahq(FP8_E4M3), Objective::Kl, &gt, &taus).unwrap();
        assert!(
            (acdc32.auc - pahq.auc).abs() < 0.1,
            "PAHQ {:.3} tracks ACDC {:.3}",
            pahq.auc,
            acdc32.auc
        );
        assert!(
            acdc32.auc >= rtn8.auc - 1e-6,
            "ACDC {:.3} >= RTN-Q-8b {:.3}",
            acdc32.auc,
            rtn8.auc
        );
        assert!(
            rtn4.auc < acdc32.auc - 0.2,
            "4-bit collapse: RTN-4b {:.3} vs ACDC {:.3} (paper Tab. 5 / section 2)",
            rtn4.auc,
            acdc32.auc
        );
        assert!(
            pahq.auc > rtn4.auc + 0.2,
            "PAHQ {:.3} >> RTN-4b {:.3}",
            pahq.auc,
            rtn4.auc
        );
    }

    #[test]
    fn score_sweep_is_valid_roc() {
        let truth = GroundTruth {
            edges: vec![],
            delta: vec![0.9, 0.8, 0.0, 0.1, 0.0, 0.0],
            tau_star: 0.5,
            member: vec![true, true, false, false, false, false],
        };
        // perfectly correlated scores -> AUC 1
        let s = sweep_scores(&[0.9, 0.8, 0.0, 0.1, 0.05, 0.0], &truth);
        assert!(s.auc > 0.95, "auc {}", s.auc);
        // anti-correlated scores -> AUC ~0
        let s = sweep_scores(&[0.0, 0.1, 0.9, 0.8, 0.7, 0.6], &truth);
        assert!(s.auc < 0.3, "auc {}", s.auc);
    }
}
