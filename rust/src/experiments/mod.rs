//! The benchmark harness: one function per table and figure of the paper
//! (DESIGN.md §5 maps each to its modules). Every function prints the
//! reproduced artifact and saves a CSV under `results/`.
//!
//! Discovery-driven tables render from [`RunRecord`]s produced by the
//! unified [`crate::discovery`] pipeline — one shared
//! build-session/run/score body (`discover_run`) instead of the ~10
//! hand-rolled engine loops this module used to carry. Threshold-sweep
//! artifacts (ROC / AUC) still drive `eval::sweep_*` directly: a sweep
//! is many circuits, not one record.
//!
//! `quick = true` shrinks sweeps (fewer thresholds, smallest model) so the
//! whole suite runs in `cargo bench` time; `quick = false` regenerates the
//! full-size artifacts recorded in EXPERIMENTS.md.

use anyhow::{bail, Result};

use crate::acdc::{self, AcdcConfig, SweepMode};
use crate::baselines::{eap, edge_pruning, hisp, sp};
use crate::discovery::{self, Discovery, DiscoveryConfig, RunRecord, Session, Task};
use crate::eval::{self, GroundTruth};
use crate::gpu_sim::{CostModel, RealArch};
use crate::metrics::{answer_accuracy, Objective};
use crate::patching::{PatchedForward, Policy};
use crate::quant::{Format, FP32, FP8_E4M3};
use crate::report::{ascii_chart, human_bytes, mmss, results_dir, Table};
use crate::scheduler::{predict_run, predict_sweep, StreamConfig};

pub use crate::discovery::complement_mask;

pub const BASE_MODELS: [&str; 3] = ["gpt2s-sim", "attn4l-sim", "redwood2l-sim"];
pub const SCALE_MODELS: [&str; 3] = ["gpt2m-sim", "gpt2l-sim", "gpt2xl-sim"];
pub const TASKS: [&str; 3] = ["ioi", "greater_than", "docstring"];

fn thresholds(quick: bool) -> Vec<f32> {
    let all = acdc::paper_thresholds();
    if quick {
        all.into_iter().step_by(3).collect()
    } else {
        all
    }
}

fn fp32_gt(model: &str, task: &str, obj: Objective) -> Result<(PatchedForward, GroundTruth)> {
    let mut engine = PatchedForward::new(model, task)?;
    let gt = eval::ground_truth(&mut engine, model, task, obj)?;
    Ok((engine, gt))
}

/// The shared body of every discovery-driven table: build a validated
/// [`crate::api::RunSpec`] and launch it through [`crate::api::run`] —
/// the same entry point the CLI and library embedders use. `faith =
/// Some(..)` scores the circuit against the FP32 ground truth
/// (`Some(true)` additionally computes the Hanna et al. normalized
/// faithfulness), and any faithfulness failure propagates (a table row
/// without its score would render as silently wrong data).
fn discover_run(
    model: &str,
    task: &str,
    method: &str,
    cfg: &DiscoveryConfig,
    faith: Option<bool>,
) -> Result<RunRecord> {
    let spec = crate::api::RunSpec::builder(model, task)
        .method(method.parse()?)
        .policy(cfg.policy.clone())
        .tau(cfg.tau)
        .objective(cfg.objective)
        .sweep(cfg.sweep)
        .trace(cfg.record_trace)
        .sp_steps(cfg.sp_steps)
        .ep_steps(cfg.ep_steps)
        .faithfulness(faith)
        .faith_required(true)
        .substrate(crate::api::Substrate::Real)
        .build()?;
    crate::api::run(&spec)
}

/// The Tab. 1/2/3/6 method triple: label + session policy, ACDC verified.
fn method_policies() -> [(&'static str, Policy); 3] {
    [
        ("acdc", Policy::fp32()),
        ("rtn-q", Policy::rtn(FP8_E4M3)),
        ("pahq", Policy::pahq(FP8_E4M3)),
    ]
}

// ---------------------------------------------------------------------------
// Figure 1 — ROC curves, ACDC vs RTN-Q (vs PAHQ) on IOI

pub fn figure1(quick: bool) -> Result<()> {
    let model = if quick { "redwood2l-sim" } else { "gpt2s-sim" };
    let (mut engine, gt) = fp32_gt(model, "ioi", Objective::Kl)?;
    let taus = thresholds(quick);

    let mut table = Table::new(
        &format!("Figure 1: ROC points, {model} / IOI (KL metric)"),
        &["method", "tau", "fpr", "tpr"],
    );
    let mut series: Vec<(&str, Vec<(f64, f64)>)> = Vec::new();
    for (name, policy) in method_policies() {
        let sweep = eval::sweep_acdc(&mut engine, policy, Objective::Kl, &gt, &taus)?;
        let pts: Vec<(f64, f64)> = sweep.points.iter().map(|p| (p.fpr, p.tpr)).collect();
        for (p, (tau, _)) in sweep.points.iter().zip(&sweep.circuits) {
            table.row(vec![
                name.into(),
                format!("{tau:.4}"),
                format!("{:.4}", p.fpr),
                format!("{:.4}", p.tpr),
            ]);
        }
        println!("{name}: AUC = {:.3}", sweep.auc);
        series.push((name, pts));
    }
    let chart_series: Vec<(&str, &[(f64, f64)])> =
        series.iter().map(|(n, p)| (*n, p.as_slice())).collect();
    println!("{}", ascii_chart("Figure 1: ROC (x=FPR, y=TPR)", &chart_series, 60, 18));
    table.print();
    table.save_csv("figure1_roc")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 1 — AUC-ROC of every method x task x objective

pub fn table1(quick: bool) -> Result<()> {
    let model = if quick { "redwood2l-sim" } else { "gpt2s-sim" };
    let tasks: &[&str] = if quick { &["ioi"] } else { &TASKS };
    let taus = thresholds(quick);

    let mut table = Table::new(
        &format!("Table 1: AUC-ROC, model {model}"),
        &["method", "task", "KL div", "Task"],
    );
    for task in tasks {
        for method in ["acdc", "rtn-q", "hisp", "sp", "eap", "pahq"] {
            let mut cells = vec![method.to_string(), task.to_string()];
            for obj in [Objective::Kl, Objective::LogitDiff] {
                let (mut engine, gt) = fp32_gt(model, task, obj)?;
                let auc = match method {
                    "acdc" => eval::sweep_acdc(&mut engine, Policy::fp32(), obj, &gt, &taus)?.auc,
                    "rtn-q" => {
                        eval::sweep_acdc(&mut engine, Policy::rtn(FP8_E4M3), obj, &gt, &taus)?.auc
                    }
                    "pahq" => {
                        eval::sweep_acdc(&mut engine, Policy::pahq(FP8_E4M3), obj, &gt, &taus)?.auc
                    }
                    "eap" => eval::sweep_scores(&eap::scores(&mut engine, obj)?, &gt).auc,
                    "hisp" => eval::sweep_scores(&hisp::scores(&mut engine, obj)?, &gt).auc,
                    "sp" => {
                        let cfg = sp::SpConfig {
                            steps: if quick { 30 } else { 80 },
                            ..Default::default()
                        };
                        eval::sweep_scores(&sp::scores(&mut engine, &cfg)?, &gt).auc
                    }
                    _ => unreachable!(),
                };
                cells.push(format!("{auc:.2}"));
            }
            table.row(cells);
        }
    }
    table.print();
    table.save_csv("table1_auc")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 2 — edge-classification accuracy across thresholds/models/tasks

pub fn table2(quick: bool) -> Result<()> {
    let models: &[&str] = if quick { &["redwood2l-sim"] } else { &BASE_MODELS };
    let tasks: &[&str] = if quick { &["ioi"] } else { &TASKS };
    let taus = [0.001f32, 0.01, 0.1];

    let mut table = Table::new(
        "Table 2: edge-classification accuracy",
        &["threshold", "method", "metric", "task", "model", "accuracy"],
    );
    for &tau in &taus {
        for (method, policy) in method_policies() {
            for obj in [Objective::Kl, Objective::LogitDiff] {
                for task in tasks {
                    for model in models {
                        let cfg = DiscoveryConfig::new(tau, obj, policy.clone());
                        let rec = discover_run(model, task, "acdc", &cfg, Some(false))?;
                        let acc = rec.faithfulness.as_ref().map(|f| f.accuracy).unwrap_or(0.0);
                        table.row(vec![
                            format!("{tau}"),
                            method.into(),
                            obj.label().into(),
                            task.to_string(),
                            model.to_string(),
                            format!("{acc:.3}"),
                        ]);
                    }
                }
            }
        }
    }
    table.print();
    table.save_csv("table2_accuracy")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 3 — runtime & memory (simulated H20 + real Rust wall-clock)

pub fn table3(quick: bool) -> Result<()> {
    let cost = CostModel::default();
    let mut table = Table::new(
        "Table 3: runtime and memory on IOI (tau=0.001)",
        &[
            "model", "method", "sim time (m:s)", "sim mem (GB)", "real wall (s)", "real evals",
            "real mem (planes+cache)",
        ],
    );
    let models: &[&str] = if quick { &["redwood2l-sim"] } else { &BASE_MODELS };
    for model in models {
        let arch = RealArch::by_name(model).unwrap();
        for (name, policy) in method_policies() {
            let streams =
                if policy.is_pahq() { StreamConfig::FULL } else { StreamConfig::NONE };
            let kind = crate::gpu_sim::memory::MethodKind::of_policy(&policy);
            let sim = predict_run(&arch, &cost, kind, streams);
            // real measurement on the tiny sim model — the record's
            // measured bytes are the real-bytes counterpart of "sim mem"
            let cfg = DiscoveryConfig::new(0.001, Objective::Kl, policy);
            let rec = discover_run(model, "ioi", "acdc", &cfg, None)?;
            table.row(vec![
                arch.name.into(),
                name.to_uppercase(),
                mmss(sim.total_minutes),
                format!("{:.2}", rec.sim_bytes.unwrap_or(0) as f64 / 1e9),
                format!("{:.1}", rec.wall_seconds),
                format!("{}", rec.n_evals),
                human_bytes(rec.measured_total_bytes()),
            ]);
        }
    }
    table.print();
    table.save_csv("table3_runtime_memory")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 4 — scheduler stream ablation

pub fn table4(_quick: bool) -> Result<()> {
    let cost = CostModel::default();
    let arch = RealArch::by_name("gpt2").unwrap();
    let mut table = Table::new(
        "Table 4: scheduler ablation (PAHQ on gpt2 / IOI, simulated)",
        &["weight loading stream", "low/high split", "runtime (m)", "per-edge (us)"],
    );
    for (cfg, load, split) in [
        (StreamConfig::FULL, "yes", "yes"),
        (StreamConfig::LOAD_ONLY, "yes", "no"),
        (StreamConfig::SPLIT_ONLY, "no", "yes"),
        (StreamConfig::NONE, "no", "no"),
    ] {
        let p = predict_run(&arch, &cost, crate::gpu_sim::memory::MethodKind::Pahq, cfg);
        table.row(vec![
            load.into(),
            split.into(),
            format!("{:.0}", p.total_minutes),
            format!("{:.0}", p.per_edge_us),
        ]);
    }
    table.print();
    table.save_csv("table4_scheduler")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 5 — quantization precision ablation (4/8/16 bit)

pub fn table5(quick: bool) -> Result<()> {
    let model = if quick { "redwood2l-sim" } else { "gpt2s-sim" };
    let (mut engine, gt) = fp32_gt(model, "ioi", Objective::Kl)?;
    let taus = thresholds(quick);
    let mut table = Table::new(
        &format!("Table 5: precision ablation, {model} / IOI, tau=0.001"),
        &["precision", "accuracy", "AUC-ROC"],
    );
    for bits in [4u32, 8, 16] {
        let policy = Policy::pahq(Format::by_bits(bits));
        let sweep = eval::sweep_acdc(&mut engine, policy.clone(), Objective::Kl, &gt, &taus)?;
        // task accuracy of the tau=0.001 circuit under the quantized run
        engine.set_session(policy)?;
        let res = acdc::run(&mut engine, &AcdcConfig::new(0.001, Objective::Kl))?;
        let logits = engine.forward(&res.removed, None)?;
        let acc = answer_accuracy(&logits, &engine.examples);
        table.row(vec![
            format!("{bits}-bit"),
            format!("{acc:.2}"),
            format!("{:.2}", sweep.auc),
        ]);
    }
    table.print();
    table.save_csv("table5_precision")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 6 — Hanna et al. faithfulness

pub fn table6(quick: bool) -> Result<()> {
    let model = if quick { "redwood2l-sim" } else { "gpt2s-sim" };
    let tasks: &[&str] = if quick { &["ioi"] } else { &TASKS };
    let mut table = Table::new(
        &format!("Table 6: normalized faithfulness (tau=0.01), {model}"),
        &["method", "ioi", "docstring", "greater_than"],
    );
    let mut rows: Vec<Vec<String>> = vec![
        vec!["ACDC".into()],
        vec!["RTN-Q".into()],
        vec!["PAHQ".into()],
    ];
    let order = ["ioi", "docstring", "greater_than"];
    for task in &order {
        if !tasks.contains(task) {
            for row in rows.iter_mut() {
                row.push("-".into());
            }
            continue;
        }
        for (i, (_, policy)) in method_policies().into_iter().enumerate() {
            let cfg = DiscoveryConfig::new(0.01, Objective::Kl, policy);
            // the discovered circuit is the deliverable; its normalized
            // faithfulness is measured on the FP32 model
            let rec = discover_run(model, task, "acdc", &cfg, Some(true))?;
            let norm = rec
                .faithfulness
                .as_ref()
                .and_then(|f| f.normalized)
                .unwrap_or(f64::NAN);
            rows[i].push(format!("{norm:.2}"));
        }
    }
    for row in rows {
        table.row(row);
    }
    table.print();
    table.save_csv("table6_faithfulness")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 7 — scalability: PAHQ vs EAP on the scale series

pub fn table7(quick: bool) -> Result<()> {
    let models: &[&str] = if quick { &["gpt2m-sim"] } else { &SCALE_MODELS };
    let mut table = Table::new(
        "Table 7: larger models, IOI, tau=0.01 (lower KL is better)",
        &["model", "batch", "KL div (PAHQ)", "KL div (EAP)"],
    );
    for model in models {
        let t = Task::new(model, "ioi");
        let mut session = match Session::new(&t) {
            Ok(s) => s,
            Err(e) => bail!("scale model {model} unavailable: {e}"),
        };
        // PAHQ circuit through the unified pipeline...
        let cfg = DiscoveryConfig::new(0.01, Objective::Kl, Policy::pahq(FP8_E4M3));
        session.configure(&cfg)?;
        let rec = discovery::Acdc.discover(&mut session, &t, &cfg)?;
        let kept_pahq = session.last_kept().unwrap_or(&[]).to_vec();
        // ...and its KL evaluated at FP32, like Tab. 6
        session.engine.set_session(Policy::fp32())?;
        let mask = complement_mask(&session.engine, &kept_pahq);
        let kl_pahq = session.engine.damage(&mask, None, Objective::Kl)?;
        // EAP circuit of the same size
        let engine = &mut session.engine;
        let scores = eap::scores(engine, Objective::Kl)?;
        let mut order: Vec<usize> = (0..scores.len()).collect();
        order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
        let mut kept = vec![false; scores.len()];
        for &i in order.iter().take(rec.n_kept) {
            kept[i] = true;
        }
        let mask = complement_mask(engine, &kept);
        let kl_eap = engine.damage(&mask, None, Objective::Kl)?;
        table.row(vec![
            model.to_string(),
            format!("{}", engine.manifest.batch),
            format!("{kl_pahq:.2}"),
            format!("{kl_eap:.2}"),
        ]);
    }
    table.print();
    table.save_csv("table7_scaling")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 8 — Edge Pruning steps/dataset sweep vs PAHQ

pub fn table8(quick: bool) -> Result<()> {
    let model = if quick { "redwood2l-sim" } else { "gpt2s-sim" };
    let steps: &[usize] = if quick { &[50, 100] } else { &[400, 800, 1600, 3000] };
    let sizes: &[usize] = if quick { &[64] } else { &[200, 400, 1600] };
    let mut table = Table::new(
        &format!("Table 8: Edge Pruning vs PAHQ, {model} / IOI"),
        &["dataset size", "steps", "KL div", "time (s)"],
    );
    for &n in sizes {
        for &st in steps {
            let mut engine = PatchedForward::new(model, "ioi")?;
            let cfg = edge_pruning::EpConfig {
                steps: st,
                dataset_size: n,
                rotate_every: 25,
                ..Default::default()
            };
            let res = edge_pruning::train(&mut engine, &cfg)?;
            // binarize at 0.5 and evaluate the circuit at FP32 (the
            // original method's protocol, deliberately NOT the unified
            // verification sweep — Tab. 8 compares against it)
            let kept: Vec<bool> = res.edge_scores.iter().map(|&v| v >= 0.5).collect();
            let mask = complement_mask(&engine, &kept);
            let kl = engine.damage(&mask, None, Objective::Kl)?;
            table.row(vec![
                format!("{n}"),
                format!("{st}"),
                format!("{kl:.2}"),
                format!("{:.0}", res.wall.as_secs_f64()),
            ]);
        }
    }
    // PAHQ reference row, through the unified pipeline
    let t = Task::new(model, "ioi");
    let cfg = DiscoveryConfig::new(0.01, Objective::Kl, Policy::pahq(FP8_E4M3));
    let mut session = Session::new(&t)?;
    session.configure(&cfg)?;
    let rec = discovery::Acdc.discover(&mut session, &t, &cfg)?;
    let kept = session.last_kept().unwrap_or(&[]).to_vec();
    session.engine.set_session(Policy::fp32())?;
    let mask = complement_mask(&session.engine, &kept);
    let kl = session.engine.damage(&mask, None, Objective::Kl)?;
    table.row(vec![
        "-".into(),
        "PAHQ ACDC".into(),
        format!("{kl:.2}"),
        format!("{:.0}", rec.wall_seconds),
    ]);
    table.print();
    table.save_csv("table8_edge_pruning")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 3 — edge count vs step, ACDC before/after PAHQ

pub fn figure3(quick: bool) -> Result<()> {
    let model = if quick { "redwood2l-sim" } else { "gpt2s-sim" };
    let mut table = Table::new(
        &format!("Figure 3: edge count vs step, {model} / IOI (tau=0.01)"),
        &["method", "step", "edges_remaining"],
    );
    let mut series: Vec<(&str, Vec<(f64, f64)>)> = Vec::new();
    for (name, policy) in [("acdc-fp32", Policy::fp32()), ("pahq", Policy::pahq(FP8_E4M3))] {
        let mut cfg = DiscoveryConfig::new(0.01, Objective::Kl, policy);
        cfg.record_trace = true;
        // the record's sampled trace is the figure's data source
        let rec = discover_run(model, "ioi", "acdc", &cfg, None)?;
        let pts: Vec<(f64, f64)> =
            rec.trace.iter().map(|&(s, e)| (s as f64, e as f64)).collect();
        for &(step, edges) in &rec.trace {
            table.row(vec![name.into(), step.to_string(), edges.to_string()]);
        }
        series.push((name, pts));
    }
    let chart: Vec<(&str, &[(f64, f64)])> =
        series.iter().map(|(n, p)| (*n, p.as_slice())).collect();
    println!("{}", ascii_chart("Figure 3: edges remaining vs step", &chart, 64, 16));
    table.print();
    table.save_csv("figure3_edge_curve")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 4 — incremental quantization strategy comparison

pub fn figure4(quick: bool) -> Result<()> {
    // The paper runs this sweep at FP8 on pretrained GPT-2, whose IOI
    // behaviour is marginal. Our build-time models are trained to
    // saturation and survive E4M3 even on critical heads (EXPERIMENTS.md
    // "Divergences": the collapse sits one format level down), so the
    // incremental sweep uses FP4_E2M1 — same experiment, shifted to the
    // format where this substrate's precision cliff actually lives.
    use crate::quant::FP4_E2M1;
    let model = if quick { "redwood2l-sim" } else { "gpt2s-sim" };
    let (mut engine, gt) = fp32_gt(model, "ioi", Objective::Kl)?;
    let g = engine.graph.clone();
    let (l, h) = (engine.manifest.n_layer, engine.manifest.n_head);

    // critical heads: source heads of ground-truth circuit edges
    let mut critical = vec![false; l * h];
    for (e, &m) in gt.edges.iter().zip(&gt.member) {
        if m {
            if let crate::model::graph::NodeKind::Head { layer, head } = g.node_kind(e.src) {
                critical[layer * h + head] = true;
            }
        }
    }
    // order: non-critical heads first (reverse topological), then critical
    let mut order: Vec<usize> = (0..l * h).filter(|&i| !critical[i]).rev().collect();
    let crit_order: Vec<usize> = (0..l * h).filter(|&i| critical[i]).rev().collect();
    order.extend(&crit_order);
    let n_noncrit = l * h - crit_order.len();

    let patches = engine.empty_patches();
    let mut fmts = vec![FP32; l * h];
    let mut selective = Vec::new();
    let mut table = Table::new(
        &format!("Figure 4: incremental quantization, {model} / IOI"),
        &["strategy", "quantized heads", "phase", "accuracy"],
    );
    // phase 1+2: PAHQ-style selective order
    {
        let logits = engine.forward_headwise(&fmts, &patches)?;
        selective.push((0f64, answer_accuracy(&logits, &engine.examples) as f64));
    }
    for (i, &head) in order.iter().enumerate() {
        fmts[head] = FP4_E2M1;
        let logits = engine.forward_headwise(&fmts, &patches)?;
        let acc = answer_accuracy(&logits, &engine.examples) as f64;
        selective.push(((i + 1) as f64, acc));
        let phase = if i < n_noncrit { "1 (non-critical)" } else { "2 (critical)" };
        table.row(vec![
            "selective".into(),
            format!("{}", i + 1),
            phase.into(),
            format!("{acc:.3}"),
        ]);
    }
    // uniform: quantize all heads at once, report as a flat line
    let uniform_fmts = vec![FP4_E2M1; l * h];
    let logits = engine.forward_headwise(&uniform_fmts, &patches)?;
    let uniform_acc = answer_accuracy(&logits, &engine.examples) as f64;
    let uniform: Vec<(f64, f64)> =
        vec![(0.0, uniform_acc), ((l * h) as f64, uniform_acc)];
    table.row(vec![
        "uniform-4bit".into(),
        format!("{}", l * h),
        "-".into(),
        format!("{uniform_acc:.3}"),
    ]);

    println!(
        "{}",
        ascii_chart(
            "Figure 4: accuracy vs heads quantized (selective order)",
            &[("selective", selective.as_slice()), ("uniform", uniform.as_slice())],
            64,
            14,
        )
    );
    table.print();
    table.save_csv("figure4_quant_strategy")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Sweep scaling — serial vs batched edge evaluation (not a paper table;
// the scaling story the parallel sweep engine adds on top of it)

/// Predicted serial-vs-batched sweep times per architecture, plus — when
/// artifacts are built — a real measured serial-vs-batched ACDC run on
/// the tiny sim model validating the bit-identity contract end to end.
/// The real runs are saved as `RunRecord` JSONs under `results/`.
///
/// `seed` selects the evaluation batch through the shared
/// `matrix::cache::dataset_for` resolution (0 = the exported artifact
/// batch) — the same derivation `pahq run --seed` uses, so identical
/// (task, seed, n) inputs are bit-identical across subcommands.
pub fn sweep_scaling(quick: bool, seed: u64) -> Result<()> {
    let cost = CostModel::default();
    let archs: &[&str] = if quick { &["gpt2"] } else { &["gpt2", "gpt2-medium", "gpt2-large"] };
    // removal rate at practical tau: ACDC prunes most edges
    let removal_rate = 0.9;
    let mut table = Table::new(
        "Sweep scaling: PAHQ batched edge evaluation (simulated H20)",
        &["arch", "sweep", "eval inflation", "time (m:s)", "speedup"],
    );
    for arch_name in archs {
        let arch = RealArch::by_name(arch_name).unwrap();
        let modes = [
            SweepMode::Serial,
            SweepMode::Batched { workers: 2 },
            SweepMode::Batched { workers: 4 },
            SweepMode::Batched { workers: 8 },
            SweepMode::Batched { workers: 16 },
        ];
        for mode in modes {
            let p = predict_sweep(
                &arch,
                &cost,
                crate::gpu_sim::memory::MethodKind::Pahq,
                StreamConfig::FULL,
                mode,
                removal_rate,
            );
            table.row(vec![
                arch.name.into(),
                mode.label(),
                format!("{:.2}x", p.eval_inflation),
                mmss(p.total_minutes),
                format!("{:.2}x", p.speedup),
            ]);
        }
    }
    table.print();
    table.save_csv("sweep_scaling")?;

    // Real measurement when the sim-model artifacts exist: the batched
    // sweep must reproduce the serial circuit bit for bit. Both runs are
    // emitted as RunRecord artifacts for the perf trajectory, and both
    // launch through the one public entry point (`api::run`) on the
    // shared seeded-dataset resolution.
    let serial_spec = crate::api::RunSpec::builder("redwood2l-sim", "ioi")
        .method(crate::api::MethodKind::Acdc)
        .tau(0.01)
        .seed(seed)
        .substrate(crate::api::Substrate::Real)
        .build()?;
    match crate::api::run(&serial_spec) {
        Ok(serial) => {
            let workers =
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            let mut batched_spec = serial_spec.clone();
            batched_spec.sweep = SweepMode::Batched { workers };
            let batched = crate::api::run(&batched_spec)?;
            assert_eq!(
                serial.kept_hash, batched.kept_hash,
                "batched sweep diverged from serial"
            );
            println!(
                "\nreal redwood2l-sim/ioi: serial {:.2}s ({} evals) vs batched[{workers}] \
                 {:.2}s ({} evals) — kept sets identical ({} edges, hash {})",
                serial.wall_seconds,
                serial.n_evals,
                batched.wall_seconds,
                batched.n_evals,
                serial.n_kept,
                serial.kept_hash,
            );
            // measured per-replica footprint: the batched pool pays the
            // packed planes + cache once per worker
            println!(
                "measured per-engine memory ({}): planes {} + cache {} = {} (x{workers} replicas)",
                batched.policy,
                human_bytes(batched.measured_weight_bytes),
                human_bytes(batched.measured_cache_bytes),
                human_bytes(batched.measured_total_bytes()),
            );
            serial.save(&results_dir().join("sweep_serial_record.json"))?;
            batched.save(&results_dir().join("sweep_batched_record.json"))?;
            println!(
                "run records: results/sweep_serial_record.json, \
                 results/sweep_batched_record.json"
            );
        }
        Err(e) => println!("\n(real sweep measurement skipped: {e})"),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Matrix-manifest rollups — tables 2/6/7 re-rendered from one `pahq
// matrix` pass instead of N sequential discovery runs

fn manifest_records(
    path: &std::path::Path,
) -> Result<(crate::matrix::MatrixManifest, Vec<RunRecord>)> {
    let m = crate::matrix::MatrixManifest::load(path)?;
    let recs = m.load_cell_records(path)?.into_iter().map(|(_, r)| r).collect();
    Ok((m, recs))
}

/// Table 2 rollup from a matrix manifest: every faithfulness-scored
/// cell's edge-classification accuracy, one pass over the grid.
pub fn table2_from_manifest(path: &std::path::Path) -> Result<()> {
    let (_, recs) = manifest_records(path)?;
    let mut table = Table::new(
        "Table 2 (from matrix): edge-classification accuracy",
        &["threshold", "method", "policy", "task", "model", "accuracy"],
    );
    for r in &recs {
        let Some(f) = &r.faithfulness else { continue };
        table.row(vec![
            format!("{}", r.tau),
            r.method.clone(),
            r.policy.clone(),
            r.task.clone(),
            r.model.clone(),
            format!("{:.3}", f.accuracy),
        ]);
    }
    if table.rows.is_empty() {
        println!("(no faithfulness-scored records in {})", path.display());
    }
    table.print();
    table.save_csv("table2_accuracy_matrix")?;
    Ok(())
}

/// Table 6 rollup from a matrix manifest: normalized faithfulness per
/// (method, policy) row across the task columns.
pub fn table6_from_manifest(path: &std::path::Path) -> Result<()> {
    let (_, recs) = manifest_records(path)?;
    let order = ["ioi", "docstring", "greater_than"];
    let mut table = Table::new(
        "Table 6 (from matrix): normalized faithfulness",
        &["method", "policy", "ioi", "docstring", "greater_than"],
    );
    let mut rows: std::collections::BTreeMap<(String, String), [Option<f64>; 3]> =
        std::collections::BTreeMap::new();
    for r in &recs {
        let Some(norm) = r.faithfulness.as_ref().and_then(|f| f.normalized) else { continue };
        let Some(col) = order.iter().position(|t| *t == r.task) else { continue };
        rows.entry((r.method.clone(), r.policy.clone())).or_default()[col] = Some(norm);
    }
    let fmt = |v: Option<f64>| v.map(|x| format!("{x:.2}")).unwrap_or_else(|| "-".into());
    for ((method, policy), cols) in rows {
        table.row(vec![method, policy, fmt(cols[0]), fmt(cols[1]), fmt(cols[2])]);
    }
    if table.rows.is_empty() {
        println!("(no normalized-faithfulness records in {})", path.display());
    }
    table.print();
    table.save_csv("table6_faithfulness_matrix")?;
    Ok(())
}

/// Table 7 rollup from a matrix manifest: per model x method x policy,
/// the circuit size and the cost of finding it — the scale comparison
/// rendered from the grid's records in one pass.
pub fn table7_from_manifest(path: &std::path::Path) -> Result<()> {
    let (_, recs) = manifest_records(path)?;
    let mut table = Table::new(
        "Table 7 (from matrix): scale rollup",
        &["model", "task", "method", "policy", "kept", "final metric", "evals", "wall (s)", "mem"],
    );
    for r in &recs {
        table.row(vec![
            r.model.clone(),
            r.task.clone(),
            r.method.clone(),
            r.policy.clone(),
            format!("{}/{}", r.n_kept, r.n_edges),
            format!("{:.4}", r.final_metric),
            r.n_evals.to_string(),
            format!("{:.1}", r.wall_seconds),
            human_bytes(r.measured_total_bytes()),
        ]);
    }
    table.print();
    table.save_csv("table7_scaling_matrix")?;
    Ok(())
}

/// Run everything (the full paper reproduction).
pub fn run_all(quick: bool) -> Result<()> {
    figure1(quick)?;
    table1(quick)?;
    table2(quick)?;
    table3(quick)?;
    table4(quick)?;
    table5(quick)?;
    table6(quick)?;
    table7(quick)?;
    table8(quick)?;
    figure3(quick)?;
    figure4(quick)?;
    Ok(())
}
