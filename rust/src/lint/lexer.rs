//! A lightweight Rust source masker: the lexical layer under every
//! lint rule.
//!
//! Rules in this subsystem are byte-pattern scans (`.unwrap()`,
//! `.lock()`, `thread::spawn`, ...). Scanning raw source would fire
//! inside string literals, doc comments, and char literals — e.g. the
//! very message strings that *describe* a rule. So rules never see raw
//! source: they see the [`Lexed::masked`] buffer, where every byte of
//! comment and literal *content* is replaced by a space (newlines are
//! kept so byte offsets and line numbers survive masking, and string
//! quote delimiters are kept so the code shape stays readable).
//!
//! Handled: line comments, nested block comments, string literals with
//! escapes, raw strings (`r"…"`, `r#"…"#`, any hash depth), byte
//! strings/chars (`b"…"`, `b'…'`, `br#"…"#`), char literals, and the
//! char-literal/lifetime ambiguity (`'x'` masks, `'a` in `&'a str`
//! does not).
//!
//! This is *not* a full lexer — it does not tokenize identifiers or
//! operators — and that is deliberate: the mask pass is ~100 lines,
//! has no dependencies, and is exactly strong enough for the rule set
//! (see `docs/lint_rules.md` § Scope and limits).

/// Masked view of one source file.
pub struct Lexed {
    /// Same length as the input; comment/literal content blanked.
    pub masked: Vec<u8>,
    /// Byte spans `(start, end)` of every comment, including the
    /// `//` / `/*` delimiters. Pragma parsing reads these.
    pub comments: Vec<(usize, usize)>,
}

pub fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Can a raw string start here? True when the previous byte is not an
/// identifier byte, or is a `b` prefix that itself starts a token.
fn raw_ok(b: &[u8], i: usize) -> bool {
    if i == 0 {
        return true;
    }
    let p = b[i - 1];
    if !is_ident(p) {
        return true;
    }
    p == b'b' && (i < 2 || !is_ident(b[i - 2]))
}

/// Mask one source file. See the module docs for what gets blanked.
pub fn analyze(src: &str) -> Lexed {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut comments = Vec::new();

    fn blank(out: &mut [u8], start: usize, end: usize) {
        let end = end.min(out.len());
        for slot in out.iter_mut().take(end).skip(start) {
            if *slot != b'\n' {
                *slot = b' ';
            }
        }
    }

    let mut i = 0;
    while i < n {
        let c = b[i];
        let nxt = if i + 1 < n { b[i + 1] } else { 0 };
        // line comment
        if c == b'/' && nxt == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            comments.push((i, j));
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // block comment (nesting counts, as in Rust)
        if c == b'/' && nxt == b'*' {
            let mut depth = 1;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            comments.push((i, j));
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // raw string r"..." / r#"..."# (possibly after a b prefix)
        if c == b'r' && raw_ok(b, i) {
            let mut j = i + 1;
            let mut hashes = 0;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == b'"' {
                j += 1;
                let mut end = n;
                let mut k = j;
                while k < n {
                    let closes = b[k] == b'"'
                        && k + hashes < n
                        && b[k + 1..k + 1 + hashes].iter().all(|&h| h == b'#');
                    if closes {
                        end = k + 1 + hashes;
                        break;
                    }
                    k += 1;
                }
                blank(&mut out, i + 1, end);
                i = end;
                continue;
            }
        }
        // byte-string / byte-char / raw-byte-string prefix: step over
        // the b, the next iteration handles the literal itself
        let byte_prefix = nxt == b'"' || nxt == b'\'' || nxt == b'r';
        if c == b'b' && byte_prefix && (i == 0 || !is_ident(b[i - 1])) {
            i += 1;
            continue;
        }
        // string literal
        if c == b'"' {
            let mut j = i + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                    continue;
                }
                if b[j] == b'"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            blank(&mut out, i + 1, j.saturating_sub(1));
            i = j;
            continue;
        }
        // char literal vs lifetime
        if c == b'\'' {
            if nxt == b'\\' {
                let mut j = i + 2;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                blank(&mut out, i + 1, j);
                i = j + 1;
                continue;
            }
            if i + 2 < n && b[i + 2] == b'\'' && nxt != b'\'' {
                blank(&mut out, i + 1, i + 2);
                i += 3;
                continue;
            }
            // lifetime ('a, 'static): just skip the quote
            i += 1;
            continue;
        }
        i += 1;
    }
    Lexed { masked: out, comments }
}

/// Byte spans of `#[cfg(test)] mod … { … }` blocks, computed on the
/// masked buffer (so braces inside literals cannot unbalance the
/// match). Rules do not fire inside these spans: test code is allowed
/// to unwrap.
pub fn test_spans(masked: &[u8]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut idx = 0;
    while let Some(a) = find(masked, b"#[cfg(test)]", idx) {
        let Some(m) = find(masked, b"mod ", a) else { break };
        let Some(o) = find(masked, b"{", m) else { break };
        let mut depth = 0usize;
        let mut j = o;
        while j < masked.len() {
            match masked[j] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        let end = (j + 1).min(masked.len());
        spans.push((a, end));
        idx = end.max(a + 1);
    }
    spans
}

pub fn in_spans(pos: usize, spans: &[(usize, usize)]) -> bool {
    spans.iter().any(|&(a, b)| a <= pos && pos < b)
}

/// 1-based line number of a byte offset.
pub fn line_of(src: &[u8], pos: usize) -> usize {
    src.iter().take(pos).filter(|&&b| b == b'\n').count() + 1
}

/// First occurrence of `needle` in `hay` at or after `from`.
pub fn find(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || from >= hay.len() || hay.len() - from < needle.len() {
        return None;
    }
    hay[from..].windows(needle.len()).position(|w| w == needle).map(|p| p + from)
}

/// Every occurrence of `needle` in `hay` (non-overlapping).
pub fn find_all(hay: &[u8], needle: &[u8]) -> Vec<usize> {
    let mut out = Vec::new();
    let mut i = 0;
    while let Some(p) = find(hay, needle, i) {
        out.push(p);
        i = p + needle.len();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn masked(src: &str) -> String {
        String::from_utf8(analyze(src).masked).unwrap()
    }

    #[test]
    fn strings_and_comments_are_blanked() {
        let m = masked("let x = \"call .unwrap() here\"; // .unwrap()\nx.unwrap();\n");
        assert!(!m[..m.find('\n').unwrap()].contains(".unwrap()"));
        assert!(m.ends_with("x.unwrap();\n"));
        assert_eq!(m.len(), "let x = \"call .unwrap() here\"; // .unwrap()\nx.unwrap();\n".len());
    }

    #[test]
    fn block_comments_nest() {
        let m = masked("/* a /* b */ still comment */ code()");
        assert!(m.ends_with(" code()"));
        assert!(!m.contains("still"));
    }

    #[test]
    fn raw_and_byte_strings_are_blanked() {
        let m = masked("let f = br#\"{\"k\": \".unwrap()\"}\"#; f.len()");
        assert!(!m.contains(".unwrap()"));
        assert!(m.contains("f.len()"));
        let m = masked("let r = r\"panic!\"; ok()");
        assert!(!m.contains("panic!"));
    }

    #[test]
    fn char_literals_mask_but_lifetimes_survive() {
        let m = masked("fn f<'a>(s: &'a str) -> char { '!' }");
        assert!(m.contains("<'a>"));
        assert!(m.contains("&'a str"));
        assert!(!m.contains("'!'"));
        let m = masked("let q = '\"'; let s = \"x\"; s.len()");
        // the quote char literal must not open a phantom string
        assert!(m.contains("s.len()"));
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let m = masked("let s = \"a\\\"b.unwrap()c\"; done()");
        assert!(!m.contains(".unwrap()"));
        assert!(m.contains("done()"));
    }

    #[test]
    fn newlines_survive_masking() {
        let src = "// one\n\"two\nthree\"\nfour";
        let m = masked(src);
        assert_eq!(m.matches('\n').count(), src.matches('\n').count());
        assert_eq!(line_of(m.as_bytes(), m.find("four").unwrap()), 4);
    }

    #[test]
    fn test_spans_cover_cfg_test_mods() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n  fn b() { x.unwrap(); }\n}\nfn c() {}\n";
        let lx = analyze(src);
        let spans = test_spans(&lx.masked);
        assert_eq!(spans.len(), 1);
        let pos = src.find(".unwrap()").unwrap();
        assert!(in_spans(pos, &spans));
        assert!(!in_spans(src.find("fn c").unwrap(), &spans));
    }
}
