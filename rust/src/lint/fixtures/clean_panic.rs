// Lint fixture: the panic-free counterpart of bad_panic.rs. Never compiled.
fn careful(xs: &[u32], x: Option<u32>, y: Option<u32>) -> Option<u32> {
    let head = xs.first().copied()?;
    let v = x?;
    let w = y?;
    if head > 3 {
        return None;
    }
    Some(v + w + head)
}
