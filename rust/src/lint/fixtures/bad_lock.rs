// Lint fixture: a poison-propagating lock acquisition. Never compiled.
fn poisoned(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
