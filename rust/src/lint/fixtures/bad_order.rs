// Lint fixture: acquires `inner` (rank 2) before `outer` (rank 1). Never
// compiled; rust/tests/lint.rs runs check_lock_order over it with a
// fixture-local lock table.
fn wrong(t: &Pair) {
    let second = crate::util::sync::lock_recover(&t.inner);
    let first = crate::util::sync::lock_recover(&t.outer);
    let _ = (second, first);
}
