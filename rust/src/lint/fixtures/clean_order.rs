// Lint fixture: nested acquisition in declared rank order (outer=1 then
// inner=2). Never compiled; exercised by rust/tests/lint.rs.
fn right(t: &Pair) {
    let first = crate::util::sync::lock_recover(&t.outer);
    let second = crate::util::sync::lock_recover(&t.inner);
    let _ = (first, second);
}
