// Lint fixture: a justified pragma suppresses the finding. Never compiled.
fn suppressed(x: Option<u32>) -> u32 {
    // pahq-lint: allow(panic-unwrap): fixture proving justified pragmas suppress
    x.unwrap()
}
