// Lint fixture: every panic-surface rule fires here. Never compiled.
fn risky(xs: &[u32], x: Option<u32>, y: Option<u32>) -> u32 {
    let head = xs[0];
    let v = x.unwrap();
    let w = y.expect("present");
    if head > 3 {
        panic!("boom");
    }
    v + w + head
}
