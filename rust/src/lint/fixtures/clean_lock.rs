// Lint fixture: the poison-recovering counterpart of bad_lock.rs. Never compiled.
fn recovered(m: &std::sync::Mutex<u32>) -> u32 {
    *crate::util::sync::lock_recover(m)
}
