// Lint fixture: malformed pragmas must not suppress anything. Never compiled.
fn unjustified(x: Option<u32>) -> u32 {
    // pahq-lint: allow(panic-unwrap)
    x.unwrap()
}

fn misspelled(y: Option<u32>) -> u32 {
    y.unwrap() // pahq-lint: allow(not-a-rule): rule ids must come from the registry
}
