// Lint fixture: scoped threads join at scope exit, so no bare-spawn. Never compiled.
fn scoped() {
    std::thread::scope(|s| {
        s.spawn(|| {
            let _ = 1 + 1;
        });
    });
}
