// Lint fixture: a detached thread outside the allowed directories. Never compiled.
fn detached() {
    std::thread::spawn(|| {
        let _ = 1 + 1;
    });
}
