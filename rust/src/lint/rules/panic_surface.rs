//! Panic-surface rules: every construct that can abort a worker thread
//! in non-test library code.
//!
//! PAHQ's pitch over linear-approximation methods is *exactness* — and
//! an aborted worker silently truncating a sweep is the cheapest way
//! to lose it. These rules are ratcheted (counts in
//! `LINT_baseline.json` may only go down) rather than hard errors:
//! the seed code has hundreds of historical sites, and the ratchet
//! turns them into a monotone burn-down instead of a flag day. See
//! `docs/lint_rules.md` for the per-rule rationale and the hot-path
//! zero policy (serve/load/matrix hold no unsuppressed findings for
//! the non-slice rules).

use super::super::lexer;

/// A raw hit: rule id, byte offset, message.
pub type Hit = (&'static str, usize, String);

const PANIC_MACROS: [&str; 4] = ["panic!", "unreachable!", "todo!", "unimplemented!"];

/// Scan one masked source buffer. Offsets are into the masked buffer,
/// which is byte-for-byte aligned with the raw source.
pub fn scan(masked: &[u8]) -> Vec<Hit> {
    let mut hits = Vec::new();
    for pos in lexer::find_all(masked, b".unwrap()") {
        hits.push(("panic-unwrap", pos, ".unwrap() can panic; bubble a Result or justify".into()));
    }
    for pos in lexer::find_all(masked, b".expect(") {
        let msg = ".expect(..) can panic; bubble a Result or justify".to_string();
        hits.push(("panic-expect", pos, msg));
    }
    for mac in PANIC_MACROS {
        for pos in lexer::find_all(masked, mac.as_bytes()) {
            // `foo_panic!` is not `panic!`
            if pos > 0 && lexer::is_ident(masked[pos - 1]) {
                continue;
            }
            hits.push(("panic-macro", pos, format!("{mac} aborts the thread; return an error")));
        }
    }
    // slice indexing: `[` whose previous non-whitespace byte ends an
    // expression (identifier, `)`, or `]`) — array/type syntax,
    // attributes, and macro brackets do not match
    for pos in lexer::find_all(masked, b"[") {
        let mut j = pos;
        while j > 0 {
            j -= 1;
            match masked[j] {
                b' ' | b'\t' | b'\n' => continue,
                b => {
                    if lexer::is_ident(b) || b == b')' || b == b']' {
                        let msg = "slice/map indexing can panic; prefer .get(..)".to_string();
                        hits.push(("slice-index", pos, msg));
                    }
                    break;
                }
            }
        }
    }
    hits
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_in(src: &str) -> Vec<&'static str> {
        let lx = lexer::analyze(src);
        let mut ids: Vec<&'static str> = scan(&lx.masked).into_iter().map(|h| h.0).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    #[test]
    fn flags_each_family() {
        assert_eq!(rules_in("x.unwrap();"), vec!["panic-unwrap"]);
        assert_eq!(rules_in("x.expect(\"m\");"), vec!["panic-expect"]);
        assert_eq!(rules_in("unreachable!()"), vec!["panic-macro"]);
        assert_eq!(rules_in("let y = xs[0];"), vec!["slice-index"]);
    }

    #[test]
    fn ignores_literals_and_lookalikes() {
        assert!(rules_in("let s = \".unwrap() panic! xs[0]\";").is_empty());
        assert!(rules_in("my_panic!()").is_empty());
        assert!(rules_in("#[derive(Clone)] struct S;").is_empty());
        assert!(rules_in("let a: [u8; 4] = *b;").is_empty());
        assert!(rules_in("x.unwrap_or(0);").is_empty());
    }

    #[test]
    fn chained_index_after_call_or_index() {
        assert_eq!(rules_in("f()[0];"), vec!["slice-index"]);
        assert_eq!(rules_in("g[0][1];"), vec!["slice-index"]);
    }
}
