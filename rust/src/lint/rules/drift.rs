//! Drift rules: places where the same fact lives in two files and CI
//! must prove the copies agree.
//!
//! Three checks, all repo-wide (they read multiple files, so they run
//! once per lint pass rather than per file):
//!
//! - `doc-error-codes` — the error-code table in
//!   `docs/serve_protocol.md` must match `ErrorCode` in
//!   `serve/protocol.rs`, both directions.
//! - `schema-orphan` — every `docs/*.schema.json` must be referenced
//!   by `scripts/check_schema.py`; an orphan schema means CI validates
//!   nothing against it.
//! - `schema-version` — every schema-version constant in source must
//!   equal the version pinned in its schema file.

use std::path::Path;

use anyhow::{Context, Result};

use super::super::lexer;
use super::super::{Finding, Severity};
use crate::util::json::Json;

/// Where each version constant lives and which schema pins it.
struct VersionPin {
    source: &'static str,
    constant: &'static str,
    schema: &'static str,
    /// Dotted path to the pinned value inside the schema JSON; the
    /// final `enum` segment means "first element of that array".
    path: &'static [&'static str],
}

const VERSION_PINS: &[VersionPin] = &[
    VersionPin {
        source: "rust/src/discovery/record.rs",
        constant: "SCHEMA_VERSION",
        schema: "docs/run_record.schema.json",
        path: &["properties", "schema_version", "enum"],
    },
    VersionPin {
        source: "rust/src/matrix/mod.rs",
        constant: "MATRIX_SCHEMA_VERSION",
        schema: "docs/matrix.schema.json",
        path: &["properties", "schema_version", "enum"],
    },
    VersionPin {
        source: "rust/src/matrix/store.rs",
        constant: "STORE_SCHEMA_VERSION",
        schema: "docs/store_manifest.schema.json",
        path: &["properties", "schema_version", "enum"],
    },
    VersionPin {
        source: "rust/src/matrix/store.rs",
        constant: "CODEC_VERSION",
        schema: "docs/store_manifest.schema.json",
        path: &["properties", "codec_version", "enum"],
    },
    VersionPin {
        source: "rust/src/load/snapshot.rs",
        constant: "SNAPSHOT_SCHEMA_VERSION",
        schema: "docs/load_snapshot.schema.json",
        path: &["properties", "schema_version", "enum"],
    },
    VersionPin {
        source: "rust/src/serve/protocol.rs",
        constant: "PROTOCOL_VERSION",
        schema: "docs/serve_protocol.schema.json",
        path: &["protocol_version"],
    },
    VersionPin {
        source: "rust/src/lint/mod.rs",
        constant: "LINT_SCHEMA_VERSION",
        schema: "docs/lint_findings.schema.json",
        path: &["properties", "schema_version", "enum"],
    },
];

fn finding(rule: &'static str, file: &str, line: usize, message: String) -> Finding {
    Finding {
        rule,
        severity: Severity::Error,
        file: file.to_string(),
        line,
        message,
        suppressed: false,
        justification: None,
    }
}

/// Run every drift check against the repo at `root`.
pub fn scan(root: &Path) -> Result<Vec<Finding>> {
    let mut out = Vec::new();
    out.extend(check_error_codes(root)?);
    out.extend(check_schema_orphans(root)?);
    out.extend(check_version_pins(root)?);
    Ok(out)
}

fn read(root: &Path, rel: &str) -> Result<String> {
    std::fs::read_to_string(root.join(rel)).with_context(|| format!("lint: reading {rel}"))
}

// ---------------------------------------------------------------------------
// doc-error-codes

/// `(code, snake_case_name, line)` pairs from the `ErrorCode` enum.
fn enum_codes(src: &str) -> Vec<(u64, String, usize)> {
    let lx = lexer::analyze(src);
    let masked = &lx.masked;
    let Some(start) = lexer::find(masked, b"pub enum ErrorCode", 0) else {
        return Vec::new();
    };
    let Some(open) = lexer::find(masked, b"{", start) else { return Vec::new() };
    let mut depth = 0usize;
    let mut end = open;
    for (k, &b) in masked.iter().enumerate().skip(open) {
        match b {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    end = k;
                    break;
                }
            }
            _ => {}
        }
    }
    let body = std::str::from_utf8(&masked[open..end]).unwrap_or("");
    let mut out = Vec::new();
    let base_line = lexer::line_of(src.as_bytes(), open);
    for (i, raw) in body.lines().enumerate() {
        let line = raw.trim().trim_end_matches(',');
        let Some((name, value)) = line.split_once('=') else { continue };
        let (name, value) = (name.trim(), value.trim());
        let named_ok = !name.is_empty()
            && name.as_bytes()[0].is_ascii_uppercase()
            && name.bytes().all(lexer::is_ident);
        if !named_ok {
            continue;
        }
        let Ok(code) = value.parse::<u64>() else { continue };
        out.push((code, snake_case(name), base_line + i));
    }
    out
}

fn snake_case(name: &str) -> String {
    let mut out = String::new();
    for (i, ch) in name.chars().enumerate() {
        if ch.is_ascii_uppercase() && i > 0 {
            out.push('_');
        }
        out.push(ch.to_ascii_lowercase());
    }
    out
}

/// `(code, name, line)` rows of the markdown error-code table: cells
/// shaped `| <digits> | `name` | … |`.
fn doc_codes(md: &str) -> Vec<(u64, String, usize)> {
    let mut out = Vec::new();
    for (i, raw) in md.lines().enumerate() {
        let line = raw.trim();
        if !line.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = line.split('|').collect();
        if cells.len() < 3 {
            continue;
        }
        let c1 = cells[1].trim();
        let c2 = cells[2].trim();
        if c1.is_empty() || !c1.bytes().all(|b| b.is_ascii_digit()) {
            continue;
        }
        let name = c2.strip_prefix('`').and_then(|s| s.strip_suffix('`'));
        let Some(name) = name else { continue };
        if name.is_empty() || !name.bytes().all(|b| lexer::is_ident(b) && b != b'`') {
            continue;
        }
        let Ok(code) = c1.parse::<u64>() else { continue };
        out.push((code, name.to_string(), i + 1));
    }
    out
}

const PROTOCOL_RS: &str = "rust/src/serve/protocol.rs";
const PROTOCOL_MD: &str = "docs/serve_protocol.md";

fn check_error_codes(root: &Path) -> Result<Vec<Finding>> {
    let enum_side = enum_codes(&read(root, PROTOCOL_RS)?);
    let doc_side = doc_codes(&read(root, PROTOCOL_MD)?);
    let mut out = Vec::new();
    if enum_side.is_empty() {
        out.push(finding(
            "doc-error-codes",
            PROTOCOL_RS,
            1,
            "could not locate the ErrorCode enum (did it move or lose its discriminants?)"
                .to_string(),
        ));
        return Ok(out);
    }
    if doc_side.is_empty() {
        out.push(finding(
            "doc-error-codes",
            PROTOCOL_MD,
            1,
            "could not locate the error-code table (| code | `name` | rows)".to_string(),
        ));
        return Ok(out);
    }
    for (code, name, line) in &enum_side {
        match doc_side.iter().find(|(c, _, _)| c == code) {
            None => out.push(finding(
                "doc-error-codes",
                PROTOCOL_MD,
                1,
                format!("error code {code} (`{name}`) is missing from the table"),
            )),
            Some((_, doc_name, doc_line)) if doc_name != name => out.push(finding(
                "doc-error-codes",
                PROTOCOL_MD,
                *doc_line,
                format!(
                    "error code {code} is `{doc_name}` in the docs but `{name}` in \
                     {PROTOCOL_RS}:{line}"
                ),
            )),
            Some(_) => {}
        }
    }
    for (code, name, line) in &doc_side {
        if !enum_side.iter().any(|(c, _, _)| c == code) {
            out.push(finding(
                "doc-error-codes",
                PROTOCOL_MD,
                *line,
                format!("documents error code {code} (`{name}`) which ErrorCode does not define"),
            ));
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// schema-orphan

const CHECK_SCHEMA_PY: &str = "scripts/check_schema.py";

fn check_schema_orphans(root: &Path) -> Result<Vec<Finding>> {
    let script = read(root, CHECK_SCHEMA_PY)?;
    let mut names: Vec<String> = Vec::new();
    let docs = root.join("docs");
    let entries =
        std::fs::read_dir(&docs).with_context(|| format!("lint: listing {}", docs.display()))?;
    for entry in entries {
        let name = entry?.file_name().to_string_lossy().into_owned();
        if name.ends_with(".schema.json") {
            names.push(name);
        }
    }
    names.sort();
    let mut out = Vec::new();
    for name in names {
        if !script.contains(&name) {
            out.push(finding(
                "schema-orphan",
                &format!("docs/{name}"),
                1,
                format!(
                    "docs/{name} is not referenced by {CHECK_SCHEMA_PY}: CI validates \
                     nothing against it"
                ),
            ));
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// schema-version

/// `pub const <name>: <ty> = <int>;` in masked source.
fn const_value(src: &str, name: &str) -> Option<(u64, usize)> {
    let lx = lexer::analyze(src);
    let pat = format!("pub const {name}:");
    let pos = lexer::find(&lx.masked, pat.as_bytes(), 0)?;
    let rest = &lx.masked[pos + pat.len()..];
    let eq = rest.iter().position(|&b| b == b'=')?;
    let digits: Vec<u8> = rest[eq + 1..]
        .iter()
        .copied()
        .skip_while(|b| b.is_ascii_whitespace())
        .take_while(|b| b.is_ascii_digit())
        .collect();
    let value = std::str::from_utf8(&digits).ok()?.parse().ok()?;
    Some((value, lexer::line_of(src.as_bytes(), pos)))
}

fn pinned_value(schema: &Json, path: &[&str]) -> Result<u64> {
    let mut cur = schema;
    for seg in path {
        cur = cur.get(seg)?;
    }
    if path.last() == Some(&"enum") {
        cur = cur
            .as_arr()?
            .first()
            .ok_or_else(|| anyhow::anyhow!("empty enum in schema version pin"))?;
    }
    Ok(cur.as_f64()? as u64)
}

fn check_version_pins(root: &Path) -> Result<Vec<Finding>> {
    let mut out = Vec::new();
    for pin in VERSION_PINS {
        let src = read(root, pin.source)?;
        let Some((value, line)) = const_value(&src, pin.constant) else {
            out.push(finding(
                "schema-version",
                pin.source,
                1,
                format!(
                    "constant `{}` not found (renamed without updating the lint pin table?)",
                    pin.constant
                ),
            ));
            continue;
        };
        let schema = Json::parse_file(&root.join(pin.schema))
            .with_context(|| format!("lint: parsing {}", pin.schema))?;
        match pinned_value(&schema, pin.path) {
            Err(e) => out.push(finding(
                "schema-version",
                pin.schema,
                1,
                format!("cannot read version pin at {}: {e}", pin.path.join(".")),
            )),
            Ok(pinned) if pinned != value => out.push(finding(
                "schema-version",
                pin.source,
                line,
                format!(
                    "`{}` = {value} but {} pins {pinned} — bump them together",
                    pin.constant, pin.schema
                ),
            )),
            Ok(_) => {}
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enum_and_doc_parsers_agree_on_shapes() {
        let src = "pub enum ErrorCode {\n    BadFrame = 1,\n    ShuttingDown = 7,\n}\n";
        let codes = enum_codes(src);
        assert_eq!(codes.len(), 2);
        assert_eq!(codes[0].0, 1);
        assert_eq!(codes[0].1, "bad_frame");
        assert_eq!(codes[1].1, "shutting_down");

        let md = "| code | name | meaning |\n|---|---|---|\n| 1 | `bad_frame` | x |\n\
                  | 9 | not_ticked | y |\n| 7 | `shutting_down` | z |\n";
        let rows = doc_codes(md);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], (1, "bad_frame".to_string(), 3));
        assert_eq!(rows[1].0, 7);
    }

    #[test]
    fn const_parser_reads_typed_int_consts() {
        let src = "pub const PROTOCOL_VERSION: u16 = 3;\n";
        assert_eq!(const_value(src, "PROTOCOL_VERSION"), Some((3, 1)));
        assert_eq!(const_value(src, "MISSING"), None);
        // a prefixed name must not match
        let src = "pub const STORE_SCHEMA_VERSION: usize = 2;\n";
        assert_eq!(const_value(src, "SCHEMA_VERSION"), None);
    }

    #[test]
    fn snake_case_handles_runs() {
        assert_eq!(snake_case("BadFrame"), "bad_frame");
        assert_eq!(snake_case("Internal"), "internal");
        assert_eq!(snake_case("ShuttingDown"), "shutting_down");
    }
}
