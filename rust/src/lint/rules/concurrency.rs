//! Concurrency-hygiene rules: poison handling, lock ordering, and
//! thread spawning discipline for the hand-rolled concurrent layers
//! (matrix queue/cache/store, serve sessions, load clients).

use super::super::lexer;
use super::panic_surface::Hit;

/// One declared lock in the repo-wide acquisition order.
pub struct LockDecl {
    /// Repo-relative source file holding the `Mutex` field.
    pub file: &'static str,
    /// Field or binding name as it appears at acquisition sites
    /// (`self.<field>.lock()` / `lock_recover(&….<field>)`).
    pub field: &'static str,
    /// Global acquisition rank, outermost-first: while holding a lock
    /// of rank R, only locks with rank > R may be acquired.
    pub rank: usize,
    /// Owning type, for docs and messages.
    pub holder: &'static str,
}

/// The declared lock-ordering table. `docs/lint_rules.md` § lock-order
/// renders this same table; every `Mutex` in the concurrent layers
/// must appear here, and nested acquisitions must descend it.
///
/// Rationale for the order: `Shared.jobs` is the server's registry and
/// may need any downstream structure while held; `Outbound.state` is
/// per-connection; the matrix executor's `results` may push into the
/// queue; `WorkQueue.inner`, the cache map, and the store states are
/// leaves that never call back out while locked.
pub const LOCK_ORDER: &[LockDecl] = &[
    LockDecl { file: "rust/src/serve/server.rs", field: "jobs", rank: 1, holder: "Shared" },
    LockDecl { file: "rust/src/serve/session.rs", field: "state", rank: 2, holder: "Outbound" },
    LockDecl { file: "rust/src/matrix/mod.rs", field: "results", rank: 3, holder: "run_matrix" },
    LockDecl { file: "rust/src/matrix/queue.rs", field: "inner", rank: 4, holder: "WorkQueue" },
    LockDecl { file: "rust/src/matrix/cache.rs", field: "map", rank: 5, holder: "Store" },
    LockDecl { file: "rust/src/matrix/store.rs", field: "map", rank: 6, holder: "MemoryStore" },
    LockDecl { file: "rust/src/matrix/store.rs", field: "state", rank: 7, holder: "DiskStore" },
];

/// Directories whose daemon/harness threads legitimately outlive a
/// scope (reader/writer threads parked on blocking I/O).
const SPAWN_ALLOWED: [&str; 2] = ["rust/src/serve/", "rust/src/load/"];

/// Scan one masked file with every concurrency rule.
pub fn scan(rel: &str, masked: &[u8]) -> Vec<Hit> {
    let mut hits = Vec::new();

    for pos in lexer::find_all(masked, b".lock().unwrap()") {
        let msg = ".lock().unwrap() panics on poison and wedges every later locker; \
                   use util::sync::lock_recover"
            .to_string();
        hits.push(("lock-unwrap", pos, msg));
    }
    for pos in lexer::find_all(masked, b".lock().expect(") {
        let msg = ".lock().expect(..) panics on poison; use util::sync::lock_recover".to_string();
        hits.push(("lock-unwrap", pos, msg));
    }

    if !SPAWN_ALLOWED.iter().any(|d| rel.starts_with(d)) {
        for pos in lexer::find_all(masked, b"thread::spawn") {
            if pos > 0 && lexer::is_ident(masked[pos - 1]) {
                continue;
            }
            let msg = "bare thread::spawn detaches panics; use std::thread::scope \
                       (bare spawns are only allowed under serve/ and load/)"
                .to_string();
            hits.push(("bare-spawn", pos, msg));
        }
    }

    hits.extend(check_lock_order(LOCK_ORDER, rel, masked));
    hits
}

/// One detected lock acquisition in a masked file.
struct Acq {
    /// Offset of the acquisition expression.
    pos: usize,
    /// Offset just past the acquisition expression.
    end: usize,
    /// Offset past which the guard is definitely dead (heuristic).
    span_end: usize,
    /// Resolved lock name (field/binding before `.lock()` or inside
    /// `lock_recover(&…)`), if the receiver is a simple path.
    name: Option<String>,
}

/// Check every acquisition in `rel` against the declared `table`:
/// undeclared locks are flagged, and nested acquisitions must descend
/// the declared rank order. Exposed with an explicit table so fixture
/// tests can exercise the checker against synthetic orders.
pub fn check_lock_order(table: &[LockDecl], rel: &str, masked: &[u8]) -> Vec<Hit> {
    if !table.iter().any(|d| d.file == rel) {
        return Vec::new();
    }
    let mut acqs = Vec::new();
    for pos in lexer::find_all(masked, b".lock()") {
        let end = pos + ".lock()".len();
        let name = ident_before(masked, pos);
        acqs.push(Acq { pos, end, span_end: guard_span_end(masked, pos, end), name });
    }
    for pos in lexer::find_all(masked, b"lock_recover(") {
        if pos > 0 && lexer::is_ident(masked[pos - 1]) {
            continue;
        }
        let open = pos + "lock_recover(".len() - 1;
        let close = match_paren(masked, open);
        let arg = std::str::from_utf8(&masked[open + 1..close]).unwrap_or("");
        let end = (close + 1).min(masked.len());
        let name = resolve_arg(arg);
        acqs.push(Acq { pos, end, span_end: guard_span_end(masked, pos, end), name });
    }
    acqs.sort_by_key(|a| a.pos);

    let rank_of = |name: &Option<String>| -> Option<usize> {
        let n = name.as_deref()?;
        table.iter().find(|d| d.file == rel && d.field == n).map(|d| d.rank)
    };

    let mut hits = Vec::new();
    for a in &acqs {
        if rank_of(&a.name).is_none() {
            let shown = a.name.as_deref().unwrap_or("<unresolved receiver>");
            let msg = format!(
                "acquisition of undeclared lock `{shown}` — declare it in the \
                 lock-ordering table (lint/rules/concurrency.rs) and docs/lint_rules.md"
            );
            hits.push(("lock-order", a.pos, msg));
        }
    }
    for (i, outer) in acqs.iter().enumerate() {
        let (Some(outer_rank), Some(outer_name)) = (rank_of(&outer.name), outer.name.as_deref())
        else {
            continue;
        };
        for inner in acqs.iter().skip(i + 1) {
            if inner.pos < outer.end || inner.pos >= outer.span_end {
                continue;
            }
            let (Some(inner_rank), Some(inner_name)) = (rank_of(&inner.name), inner.name.as_deref())
            else {
                continue;
            };
            if inner_name == outer_name {
                let msg = format!(
                    "nested acquisition of `{inner_name}` while it may still be held \
                     (self-deadlock)"
                );
                hits.push(("lock-order", inner.pos, msg));
            } else if inner_rank <= outer_rank {
                let msg = format!(
                    "lock `{inner_name}` (rank {inner_rank}) acquired while holding \
                     `{outer_name}` (rank {outer_rank}) — violates the declared lock order"
                );
                hits.push(("lock-order", inner.pos, msg));
            }
        }
    }
    hits
}

/// The identifier immediately before `pos` (receiver of `.lock()`).
fn ident_before(masked: &[u8], pos: usize) -> Option<String> {
    let mut j = pos;
    while j > 0 && lexer::is_ident(masked[j - 1]) {
        j -= 1;
    }
    if j == pos {
        return None;
    }
    std::str::from_utf8(&masked[j..pos]).ok().map(str::to_string)
}

/// Resolve a `lock_recover` argument like `&self.state` / `&results`
/// to the final path segment; `None` for anything fancier.
fn resolve_arg(arg: &str) -> Option<String> {
    let arg = arg.trim().trim_start_matches('&').trim();
    let last = arg.rsplit('.').next()?;
    if last.is_empty() || !last.bytes().all(lexer::is_ident) {
        return None;
    }
    let prefix = &arg[..arg.len() - last.len()];
    let prefix_ok = prefix.is_empty()
        || (prefix.ends_with('.') && prefix[..prefix.len() - 1].bytes().all(lexer::is_ident));
    if prefix_ok {
        Some(last.to_string())
    } else {
        None
    }
}

/// Offset of the `)` matching the `(` at `open` (or end of buffer).
fn match_paren(masked: &[u8], open: usize) -> usize {
    let mut depth = 0usize;
    let mut j = open;
    while j < masked.len() {
        match masked[j] {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    masked.len().saturating_sub(1)
}

/// Heuristic end of the guard's live range.
///
/// - `let [mut] g = <acq>;` binds the guard: live to the end of the
///   enclosing block, or to an explicit `drop(g)`.
/// - Anything else treats the guard as a temporary: live to the end of
///   the current statement (`;` at depth 0), or through a trailing
///   block (`match <acq> { … }`) — the first `}` that returns brace
///   depth to 0, or the `}` closing the enclosing block.
///
/// Over-approximates (an `if` condition temp is dropped before the
/// block runs, but we extend through it); for a lint that is the safe
/// direction.
fn guard_span_end(masked: &[u8], pos: usize, acq_end: usize) -> usize {
    let n = masked.len();
    // statement start: byte after the previous `;`, `{`, or `}`
    let mut s = pos;
    while s > 0 && !matches!(masked[s - 1], b';' | b'{' | b'}') {
        s -= 1;
    }
    let stmt = std::str::from_utf8(&masked[s..pos]).unwrap_or("").trim_start();
    let direct_let = stmt.starts_with("let ") && {
        // direct binding only: nothing but whitespace between the
        // acquisition expression and the statement's `;`
        let mut k = acq_end;
        while k < n && (masked[k] == b' ' || masked[k] == b'\t' || masked[k] == b'\n') {
            k += 1;
        }
        k < n && masked[k] == b';'
    };
    if direct_let {
        let name: String = {
            let rest = stmt["let ".len()..].trim_start();
            let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
            rest.bytes().take_while(|&b| lexer::is_ident(b)).map(char::from).collect()
        };
        let drop_pat = format!("drop({name})");
        let mut depth = 0i32;
        let mut j = acq_end;
        while j < n {
            if !name.is_empty() && masked[j..].starts_with(drop_pat.as_bytes()) {
                return j;
            }
            match masked[j] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth < 0 {
                        return j;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        return n;
    }
    // temporary: end of statement or trailing block
    let mut depth = 0i32;
    let mut j = acq_end;
    while j < n {
        match masked[j] {
            b';' if depth == 0 => return j + 1,
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth <= 0 {
                    return j + 1;
                }
            }
            _ => {}
        }
        j += 1;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hits_for(rel: &str, src: &str) -> Vec<(&'static str, String)> {
        let lx = lexer::analyze(src);
        scan(rel, &lx.masked).into_iter().map(|h| (h.0, h.2)).collect()
    }

    #[test]
    fn lock_unwrap_flagged_everywhere() {
        let h = hits_for("rust/src/foo.rs", "let g = m.lock().unwrap();");
        assert!(h.iter().any(|(r, _)| *r == "lock-unwrap"));
        let h = hits_for("rust/src/foo.rs", "let g = m.lock().expect(\"poisoned\");");
        assert!(h.iter().any(|(r, _)| *r == "lock-unwrap"));
    }

    #[test]
    fn bare_spawn_scoped_by_directory() {
        let src = "let h = std::thread::spawn(|| {});";
        assert!(hits_for("rust/src/acdc/sweep.rs", src).iter().any(|(r, _)| *r == "bare-spawn"));
        assert!(hits_for("rust/src/serve/server.rs", src).is_empty());
        assert!(hits_for("rust/src/load/client.rs", src).is_empty());
    }

    const TABLE: &[LockDecl] = &[
        LockDecl { file: "f.rs", field: "outer", rank: 1, holder: "T" },
        LockDecl { file: "f.rs", field: "inner", rank: 2, holder: "T" },
    ];

    #[test]
    fn ordered_nesting_passes_reversed_nesting_fails() {
        let good = "fn ok(t: &T) { let a = lock_recover(&t.outer); \
                    let b = lock_recover(&t.inner); }";
        let lx = lexer::analyze(good);
        assert!(check_lock_order(TABLE, "f.rs", &lx.masked).is_empty());

        let bad = "fn no(t: &T) { let a = lock_recover(&t.inner); \
                   let b = lock_recover(&t.outer); }";
        let lx = lexer::analyze(bad);
        let hits = check_lock_order(TABLE, "f.rs", &lx.masked);
        assert_eq!(hits.len(), 1);
        assert!(hits[0].2.contains("violates the declared lock order"));
    }

    #[test]
    fn undeclared_and_self_nesting_flagged() {
        let src = "fn f(t: &T) { let g = t.mystery.lock(); }";
        let lx = lexer::analyze(src);
        let hits = check_lock_order(TABLE, "f.rs", &lx.masked);
        assert!(hits.iter().any(|h| h.2.contains("undeclared lock `mystery`")));

        let src = "fn f(t: &T) { let a = lock_recover(&t.outer); let b = lock_recover(&t.outer); }";
        let lx = lexer::analyze(src);
        let hits = check_lock_order(TABLE, "f.rs", &lx.masked);
        assert!(hits.iter().any(|h| h.2.contains("self-deadlock")));
    }

    #[test]
    fn statement_temporaries_do_not_nest() {
        // guard dropped at end of statement; the next acquisition is fine
        let src = "fn f(t: &T) { lock_recover(&t.inner).push(1); lock_recover(&t.outer).pop(); }";
        let lx = lexer::analyze(src);
        assert!(check_lock_order(TABLE, "f.rs", &lx.masked).is_empty());
    }

    #[test]
    fn explicit_drop_ends_a_bound_guard() {
        let src = "fn f(t: &T) { let b = lock_recover(&t.inner); drop(b); \
                   let a = lock_recover(&t.outer); a.touch(); }";
        let lx = lexer::analyze(src);
        assert!(check_lock_order(TABLE, "f.rs", &lx.masked).is_empty());
    }
}
