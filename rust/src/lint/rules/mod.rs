//! The rule registry and the per-file rule driver.
//!
//! Three rule families (see `docs/lint_rules.md` for the per-rule
//! contract):
//!
//! - **panic-surface** ([`panic_surface`]) — ratcheted: counts live in
//!   `LINT_baseline.json` and may only go down.
//! - **concurrency** ([`concurrency`]) — hard errors: poison handling,
//!   the declared lock-ordering table, thread-spawn discipline.
//! - **drift** ([`drift`]) — hard errors: docs/schemas/source version
//!   agreement, checked repo-wide rather than per-file.
//!
//! Any rule can be suppressed at a single site with
//! `// pahq-lint: allow(<rule-id>): <justification>` — the
//! justification is mandatory, and a malformed or unknown pragma is
//! itself a `bad-pragma` error.

pub mod concurrency;
pub mod drift;
pub mod panic_surface;

use super::lexer::{self, Lexed};
use super::{Finding, Severity};

/// One registered rule.
pub struct RuleInfo {
    pub id: &'static str,
    pub severity: Severity,
    pub summary: &'static str,
}

/// Every rule the engine knows. `docs/lint_rules.md` has one section
/// per entry (asserted by `rust/tests/lint.rs`).
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "panic-unwrap",
        severity: Severity::Ratchet,
        summary: ".unwrap() in non-test library code",
    },
    RuleInfo {
        id: "panic-expect",
        severity: Severity::Ratchet,
        summary: ".expect(..) in non-test library code",
    },
    RuleInfo {
        id: "panic-macro",
        severity: Severity::Ratchet,
        summary: "panic!/unreachable!/todo!/unimplemented! in non-test library code",
    },
    RuleInfo {
        id: "slice-index",
        severity: Severity::Ratchet,
        summary: "panicking slice/map index in non-test library code",
    },
    RuleInfo {
        id: "lock-unwrap",
        severity: Severity::Error,
        summary: ".lock().unwrap() / .lock().expect(..) poison propagation",
    },
    RuleInfo {
        id: "lock-order",
        severity: Severity::Error,
        summary: "undeclared lock or nested acquisition against the declared order",
    },
    RuleInfo {
        id: "bare-spawn",
        severity: Severity::Error,
        summary: "bare std::thread::spawn outside serve/ and load/",
    },
    RuleInfo {
        id: "doc-error-codes",
        severity: Severity::Error,
        summary: "docs/serve_protocol.md error-code table out of sync with ErrorCode",
    },
    RuleInfo {
        id: "schema-orphan",
        severity: Severity::Error,
        summary: "docs/*.schema.json not referenced by scripts/check_schema.py",
    },
    RuleInfo {
        id: "schema-version",
        severity: Severity::Error,
        summary: "schema-version constant disagrees with the pinned schema file",
    },
    RuleInfo {
        id: "bad-pragma",
        severity: Severity::Error,
        summary: "malformed pahq-lint pragma (unknown rule or missing justification)",
    },
];

pub fn rule(id: &str) -> Option<&'static RuleInfo> {
    RULES.iter().find(|r| r.id == id)
}

/// A well-formed suppression pragma.
pub struct Pragma {
    /// Line whose findings this pragma suppresses (the comment's own
    /// line when trailing code, else the next line carrying code).
    pub target_line: usize,
    /// Line the comment itself sits on.
    pub decl_line: usize,
    pub rule: String,
    pub justification: String,
}

/// Parse every `// pahq-lint:` comment. Well-formed pragmas come back
/// in the first slot; malformed ones surface as `bad-pragma` findings
/// in the second. Pragmas inside `#[cfg(test)]` blocks are ignored,
/// matching the rules they would suppress.
pub fn parse_pragmas(
    rel: &str,
    src: &[u8],
    lexed: &Lexed,
    tspans: &[(usize, usize)],
) -> (Vec<Pragma>, Vec<Finding>) {
    let mut pragmas = Vec::new();
    let mut bad = Vec::new();
    for &(s, e) in &lexed.comments {
        if lexer::in_spans(s, tspans) || !src[s..].starts_with(b"//") {
            continue;
        }
        let text = std::str::from_utf8(&src[s + 2..e]).unwrap_or("").trim();
        let Some(rest) = text.strip_prefix("pahq-lint:") else { continue };
        let decl_line = lexer::line_of(src, s);
        let mut fail = |msg: String| {
            bad.push(Finding {
                rule: "bad-pragma",
                severity: Severity::Error,
                file: rel.to_string(),
                line: decl_line,
                message: msg,
                suppressed: false,
                justification: None,
            });
        };
        let rest = rest.trim();
        let Some(inner) = rest.strip_prefix("allow(") else {
            fail(format!("expected `allow(<rule>): <justification>`, got `{rest}`"));
            continue;
        };
        let Some(close) = inner.find(')') else {
            fail("unclosed `allow(` in pragma".to_string());
            continue;
        };
        let rule_id = inner[..close].trim();
        let after = inner[close + 1..].trim_start();
        let justification = after.strip_prefix(':').map(str::trim).unwrap_or("");
        if rule(rule_id).is_none() {
            fail(format!("unknown rule `{rule_id}` in pragma"));
            continue;
        }
        if justification.is_empty() {
            fail(format!(
                "pragma must carry a justification: `// pahq-lint: allow({rule_id}): <why>`"
            ));
            continue;
        }
        pragmas.push(Pragma {
            target_line: pragma_target_line(&lexed.masked, s, e, decl_line),
            decl_line,
            rule: rule_id.to_string(),
            justification: justification.to_string(),
        });
    }
    (pragmas, bad)
}

/// Trailing pragma (code earlier on the same line) applies to its own
/// line; a standalone pragma applies to the next line carrying code.
fn pragma_target_line(masked: &[u8], start: usize, end: usize, decl_line: usize) -> usize {
    let mut j = start;
    while j > 0 && masked[j - 1] != b'\n' {
        j -= 1;
        if masked[j] != b' ' && masked[j] != b'\t' {
            return decl_line;
        }
    }
    // skip to the end of the comment's line, then find the next line
    // with any code on it
    let mut k = end;
    while k < masked.len() && masked[k] != b'\n' {
        k += 1;
    }
    let mut line = decl_line;
    while k < masked.len() {
        if masked[k] == b'\n' {
            line += 1;
        } else if masked[k] != b' ' && masked[k] != b'\t' {
            return line;
        }
        k += 1;
    }
    decl_line
}

/// Run every per-file rule over one source file. `rel` is the
/// repo-relative path (forward slashes) — directory-scoped rules and
/// the lock-order table key off it.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let lexed = lexer::analyze(src);
    let sb = src.as_bytes();
    let tspans = lexer::test_spans(&lexed.masked);
    let (pragmas, mut findings) = parse_pragmas(rel, sb, &lexed, &tspans);

    let mut hits = panic_surface::scan(&lexed.masked);
    hits.extend(concurrency::scan(rel, &lexed.masked));
    hits.sort_by(|a, b| (a.1, a.0).cmp(&(b.1, b.0)));

    for (rule_id, pos, message) in hits {
        if lexer::in_spans(pos, &tspans) {
            continue;
        }
        let line = lexer::line_of(sb, pos);
        let severity = rule(rule_id).map(|r| r.severity).unwrap_or(Severity::Error);
        let pragma = pragmas.iter().find(|p| p.rule == rule_id && p.target_line == line);
        findings.push(Finding {
            rule: rule_id,
            severity,
            file: rel.to_string(),
            line,
            message,
            suppressed: pragma.is_some(),
            justification: pragma.map(|p| p.justification.clone()),
        });
    }
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_are_unique_and_kebab() {
        for (i, r) in RULES.iter().enumerate() {
            assert!(r.id.bytes().all(|b| b.is_ascii_lowercase() || b == b'-'), "{}", r.id);
            assert!(!RULES[..i].iter().any(|o| o.id == r.id), "duplicate {}", r.id);
        }
    }

    #[test]
    fn trailing_and_standalone_pragmas_target_the_right_line() {
        let src = "// pahq-lint: allow(panic-unwrap): covered by caller check\n\
                   x.unwrap();\n\
                   y.unwrap(); // pahq-lint: allow(panic-unwrap): loop invariant\n\
                   z.unwrap();\n";
        let fs = lint_source("rust/src/x.rs", src);
        let unwraps: Vec<_> = fs.iter().filter(|f| f.rule == "panic-unwrap").collect();
        assert_eq!(unwraps.len(), 3);
        assert!(unwraps[0].suppressed && unwraps[0].line == 2);
        assert!(unwraps[1].suppressed && unwraps[1].line == 3);
        assert!(!unwraps[2].suppressed && unwraps[2].line == 4);
        assert_eq!(unwraps[0].justification.as_deref(), Some("covered by caller check"));
    }

    #[test]
    fn pragma_without_justification_is_rejected_and_does_not_suppress() {
        let src = "// pahq-lint: allow(panic-unwrap)\nx.unwrap();\n";
        let fs = lint_source("rust/src/x.rs", src);
        assert!(fs.iter().any(|f| f.rule == "bad-pragma" && f.line == 1));
        let u = fs.iter().find(|f| f.rule == "panic-unwrap").unwrap();
        assert!(!u.suppressed);
    }

    #[test]
    fn unknown_rule_pragma_is_rejected() {
        let src = "// pahq-lint: allow(no-such-rule): because\nx.unwrap();\n";
        let fs = lint_source("rust/src/x.rs", src);
        assert!(fs.iter().any(|f| f.rule == "bad-pragma"));
        assert!(!fs.iter().find(|f| f.rule == "panic-unwrap").unwrap().suppressed);
    }

    #[test]
    fn test_mod_findings_are_skipped() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        assert!(lint_source("rust/src/x.rs", src).is_empty());
    }
}
