//! `pahq lint` — the in-repo static-analysis subsystem.
//!
//! Three layers:
//!
//! - [`lexer`] — masks comments and literals out of Rust source so
//!   rules scan only code.
//! - [`rules`] — the rule registry: panic-surface (ratcheted),
//!   concurrency hygiene, and doc/code drift, plus the
//!   `// pahq-lint: allow(<rule>): <why>` suppression pragmas.
//! - this module — the engine: the source walk, the ratchet baseline
//!   (`LINT_baseline.json`, counts may only go down; regenerate with
//!   `pahq lint --update-baseline`), the gate, and the JSON findings
//!   artifact (`docs/lint_findings.schema.json`, validated in CI by
//!   `scripts/check_schema.py --lint`).
//!
//! Everything is hand-rolled on `std` + the in-repo `util::json`,
//! matching the vendored-offline constraint; there is deliberately no
//! `syn`-grade parser here (see `docs/lint_rules.md` § Scope and
//! limits for what that buys and costs).

pub mod lexer;
pub mod rules;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{obj, Json};

/// Version of the findings-artifact shape. Mirrored by
/// `docs/lint_findings.schema.json` (the `schema-version` drift rule
/// checks this very pair).
pub const LINT_SCHEMA_VERSION: usize = 1;

/// Ratchet-baseline filename, at the repo root next to Cargo.toml.
pub const BASELINE_NAME: &str = "LINT_baseline.json";

/// Rule severity. `Error` findings fail the gate outright; `Ratchet`
/// findings fail it only when a per-(rule, file) count exceeds the
/// committed baseline.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Severity {
    Error,
    Ratchet,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Ratchet => "ratchet",
        }
    }
}

/// One lint finding.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub severity: Severity,
    /// Repo-relative path, forward slashes.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    pub message: String,
    /// Suppressed by a justified pragma: reported but never gated.
    pub suppressed: bool,
    /// The pragma's justification, when suppressed.
    pub justification: Option<String>,
}

/// Output of one lint pass.
pub struct Report {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
}

impl Report {
    /// Unsuppressed ratchet counts, keyed `(rule, file)`.
    pub fn ratchet_counts(&self) -> BTreeMap<(String, String), usize> {
        let mut counts = BTreeMap::new();
        for f in &self.findings {
            if f.severity == Severity::Ratchet && !f.suppressed {
                *counts.entry((f.rule.to_string(), f.file.clone())).or_insert(0) += 1;
            }
        }
        counts
    }
}

/// Ascend from `start` to the checkout root (the directory holding
/// `rust/src` and `docs`).
pub fn repo_root_from(start: &Path) -> Result<PathBuf> {
    let mut dir = start
        .canonicalize()
        .with_context(|| format!("lint: resolving {}", start.display()))?;
    loop {
        if dir.join("rust/src/lib.rs").is_file() && dir.join("docs").is_dir() {
            return Ok(dir);
        }
        if !dir.pop() {
            bail!(
                "lint: {} is not inside the repo (no rust/src/lib.rs above it); \
                 pass --root explicitly",
                start.display()
            );
        }
    }
}

/// Checkout root for the current process (ascend from the cwd).
pub fn repo_root() -> Result<PathBuf> {
    repo_root_from(Path::new("."))
}

/// Every lintable source file under `rust/src`, repo-relative with
/// forward slashes, sorted. The lint fixtures directory is excluded:
/// its files are deliberately bad and reachable only via `--paths`
/// (that asymmetry is what gives CI its negative-path proof).
pub fn walk_sources(root: &Path) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let base = root.join("rust/src");
    let mut stack = vec![base.clone()];
    while let Some(dir) = stack.pop() {
        let entries =
            std::fs::read_dir(&dir).with_context(|| format!("lint: listing {}", dir.display()))?;
        for entry in entries {
            let path = entry?.path();
            if path.is_dir() {
                if path.file_name().map(|n| n == "fixtures").unwrap_or(false) {
                    continue;
                }
                stack.push(path);
            } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
                let rel = path
                    .strip_prefix(root)
                    .unwrap_or(&path)
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                out.push(rel);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lint the whole repo: every source file plus the repo-wide drift
/// rules.
pub fn lint_repo(root: &Path) -> Result<Report> {
    let files = walk_sources(root)?;
    let mut report = lint_files(root, &files)?;
    report.findings.extend(rules::drift::scan(root)?);
    sort_findings(&mut report.findings);
    Ok(report)
}

/// Lint only `paths` (repo-relative). Drift rules are skipped — this
/// is the fixture/negative-path mode, and partial file sets cannot
/// prove repo-wide properties either way.
pub fn lint_paths(root: &Path, paths: &[String]) -> Result<Report> {
    let mut report = lint_files(root, paths)?;
    sort_findings(&mut report.findings);
    Ok(report)
}

fn lint_files(root: &Path, files: &[String]) -> Result<Report> {
    let mut findings = Vec::new();
    for rel in files {
        let src = std::fs::read_to_string(root.join(rel))
            .with_context(|| format!("lint: reading {rel}"))?;
        findings.extend(rules::lint_source(rel, &src));
    }
    Ok(Report { files_scanned: files.len(), findings })
}

fn sort_findings(findings: &mut [Finding]) {
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
}

// ---------------------------------------------------------------------------
// Ratchet baseline

/// Committed per-(rule, file) counts for ratcheted rules. The gate
/// fails any count above its baseline; counts below baseline pass and
/// are reported as stale (regenerate to tighten the ratchet).
#[derive(Default)]
pub struct Baseline {
    /// rule id -> file -> count.
    pub rules: BTreeMap<String, BTreeMap<String, usize>>,
}

impl Baseline {
    /// Load `LINT_baseline.json`. A missing file is an empty baseline:
    /// every ratchet finding then counts as a regression, which is
    /// exactly right for fixture runs and for a freshly nuked ratchet.
    pub fn load(path: &Path) -> Result<Baseline> {
        if !path.exists() {
            return Ok(Baseline::default());
        }
        let doc = Json::parse_file(path)
            .with_context(|| format!("lint: parsing {}", path.display()))?;
        let kind = doc.get("kind")?.as_str()?;
        if kind != "lint_baseline" {
            bail!("lint: {} has kind {kind:?}, expected \"lint_baseline\"", path.display());
        }
        let version = doc.get("schema_version")?.as_usize()?;
        if version != LINT_SCHEMA_VERSION {
            bail!("lint: baseline schema_version {version} != {LINT_SCHEMA_VERSION}");
        }
        let mut rules = BTreeMap::new();
        for (rule_id, files) in doc.get("rules")?.as_obj()? {
            let mut per_file = BTreeMap::new();
            for (file, count) in files.as_obj()? {
                per_file.insert(file.clone(), count.as_usize()?);
            }
            rules.insert(rule_id.clone(), per_file);
        }
        Ok(Baseline { rules })
    }

    /// Snapshot a report's unsuppressed ratchet counts.
    pub fn from_report(report: &Report) -> Baseline {
        let mut rules: BTreeMap<String, BTreeMap<String, usize>> = BTreeMap::new();
        for ((rule_id, file), count) in report.ratchet_counts() {
            rules.entry(rule_id).or_default().insert(file, count);
        }
        Baseline { rules }
    }

    pub fn to_json(&self) -> Json {
        let rules = Json::Obj(
            self.rules
                .iter()
                .map(|(rule_id, files)| {
                    let files = files
                        .iter()
                        .map(|(f, c)| (f.clone(), Json::Num(*c as f64)))
                        .collect::<BTreeMap<_, _>>();
                    (rule_id.clone(), Json::Obj(files))
                })
                .collect(),
        );
        obj(vec![
            ("kind", Json::Str("lint_baseline".into())),
            ("schema_version", Json::Num(LINT_SCHEMA_VERSION as f64)),
            (
                "comment",
                Json::Str(
                    "Ratchet baseline for pahq lint: per-file counts of ratcheted findings. \
                     Counts may only go down; regenerate with `pahq lint --update-baseline` \
                     after burning sites down. See docs/lint_rules.md."
                        .into(),
                ),
            ),
            ("rules", rules),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().dump() + "\n")
            .with_context(|| format!("lint: writing {}", path.display()))
    }

    fn count(&self, rule_id: &str, file: &str) -> usize {
        self.rules.get(rule_id).and_then(|m| m.get(file)).copied().unwrap_or(0)
    }
}

/// One (rule, file) ratchet comparison.
pub struct RatchetRow {
    pub rule: String,
    pub file: String,
    pub count: usize,
    pub baseline: usize,
}

/// Gate verdict for one report against one baseline.
pub struct GateSummary {
    /// Unsuppressed error-severity findings.
    pub errors: usize,
    /// Suppressed findings (any severity).
    pub suppressed: usize,
    /// Rows with `count > baseline`.
    pub regressions: usize,
    /// Rows with `count < baseline` (ratchet can tighten).
    pub stale: usize,
    /// Every (rule, file) row where either side is nonzero.
    pub rows: Vec<RatchetRow>,
}

impl GateSummary {
    pub fn passed(&self) -> bool {
        self.errors == 0 && self.regressions == 0
    }
}

/// Compare a report against the committed baseline.
pub fn gate(report: &Report, baseline: &Baseline) -> GateSummary {
    let counts = report.ratchet_counts();
    let mut keys: Vec<(String, String)> = counts.keys().cloned().collect();
    for (rule_id, files) in &baseline.rules {
        for file in files.keys() {
            keys.push((rule_id.clone(), file.clone()));
        }
    }
    keys.sort();
    keys.dedup();

    let mut rows = Vec::new();
    let (mut regressions, mut stale) = (0, 0);
    for (rule_id, file) in keys {
        let count = counts.get(&(rule_id.clone(), file.clone())).copied().unwrap_or(0);
        let base = baseline.count(&rule_id, &file);
        if count > base {
            regressions += 1;
        } else if count < base {
            stale += 1;
        }
        rows.push(RatchetRow { rule: rule_id, file, count, baseline: base });
    }
    let errors =
        report.findings.iter().filter(|f| f.severity == Severity::Error && !f.suppressed).count();
    let suppressed = report.findings.iter().filter(|f| f.suppressed).count();
    GateSummary { errors, suppressed, regressions, stale, rows }
}

/// The machine-readable findings artifact
/// (`docs/lint_findings.schema.json`).
pub fn report_json(report: &Report, summary: &GateSummary) -> Json {
    let findings = report
        .findings
        .iter()
        .map(|f| {
            let mut pairs = vec![
                ("rule", Json::Str(f.rule.to_string())),
                ("severity", Json::Str(f.severity.as_str().to_string())),
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("message", Json::Str(f.message.clone())),
                ("suppressed", Json::Bool(f.suppressed)),
            ];
            if let Some(j) = &f.justification {
                pairs.push(("justification", Json::Str(j.clone())));
            }
            obj(pairs)
        })
        .collect();
    let ratchet = summary
        .rows
        .iter()
        .map(|r| {
            obj(vec![
                ("rule", Json::Str(r.rule.clone())),
                ("file", Json::Str(r.file.clone())),
                ("count", Json::Num(r.count as f64)),
                ("baseline", Json::Num(r.baseline as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("kind", Json::Str("lint_findings".into())),
        ("schema_version", Json::Num(LINT_SCHEMA_VERSION as f64)),
        ("files_scanned", Json::Num(report.files_scanned as f64)),
        (
            "summary",
            obj(vec![
                ("findings", Json::Num(report.findings.len() as f64)),
                ("errors", Json::Num(summary.errors as f64)),
                ("suppressed", Json::Num(summary.suppressed as f64)),
                ("regressions", Json::Num(summary.regressions as f64)),
                ("stale_baseline", Json::Num(summary.stale as f64)),
            ]),
        ),
        ("findings", Json::Arr(findings)),
        ("ratchet", Json::Arr(ratchet)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(findings: Vec<Finding>) -> Report {
        Report { files_scanned: 1, findings }
    }

    fn ratchet(rule: &'static str, file: &str, suppressed: bool) -> Finding {
        Finding {
            rule,
            severity: Severity::Ratchet,
            file: file.to_string(),
            line: 1,
            message: "m".into(),
            suppressed,
            justification: None,
        }
    }

    #[test]
    fn gate_regresses_above_baseline_and_stales_below() {
        let report = report_with(vec![
            ratchet("panic-unwrap", "a.rs", false),
            ratchet("panic-unwrap", "a.rs", false),
            ratchet("panic-unwrap", "a.rs", true), // suppressed: not counted
        ]);
        let mut baseline = Baseline::default();
        baseline.rules.entry("panic-unwrap".into()).or_default().insert("a.rs".into(), 2);
        let s = gate(&report, &baseline);
        assert!(s.passed());
        assert_eq!(s.suppressed, 1);

        baseline.rules.get_mut("panic-unwrap").unwrap().insert("a.rs".into(), 1);
        assert!(!gate(&report, &baseline).passed());

        baseline.rules.get_mut("panic-unwrap").unwrap().insert("a.rs".into(), 3);
        let s = gate(&report, &baseline);
        assert!(s.passed());
        assert_eq!(s.stale, 1);
    }

    #[test]
    fn baseline_round_trips_through_json() {
        let report = report_with(vec![
            ratchet("panic-unwrap", "a.rs", false),
            ratchet("slice-index", "b.rs", false),
            ratchet("slice-index", "b.rs", false),
        ]);
        let b = Baseline::from_report(&report);
        let dir = std::env::temp_dir().join("pahq_lint_baseline_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(BASELINE_NAME);
        b.save(&path).unwrap();
        let b2 = Baseline::load(&path).unwrap();
        assert_eq!(b2.count("panic-unwrap", "a.rs"), 1);
        assert_eq!(b2.count("slice-index", "b.rs"), 2);
        assert!(gate(&report, &b2).passed());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_baseline_means_everything_regresses() {
        let report = report_with(vec![ratchet("panic-unwrap", "a.rs", false)]);
        let s = gate(&report, &Baseline::default());
        assert!(!s.passed());
        assert_eq!(s.regressions, 1);
    }
}
