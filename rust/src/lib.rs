//! # PAHQ — Per-Attention-Head Quantization for Automated Circuit Discovery
//!
//! Rust + JAX + Pallas reproduction of *"PAHQ: Accelerating Automated
//! Circuit Discovery through Mixed-Precision Inference Optimization"*
//! (Wang et al., 2025). Three-layer architecture:
//!
//! - **L3 (this crate)** — the coordinator: the ACDC greedy edge sweep, the
//!   PAHQ predictive three-stream scheduler over a discrete-event GPU
//!   simulator, the baselines (RTN-Q / EAP / HISP / SP / Edge-Pruning)
//!   unified behind the [`discovery::Discovery`] trait, the
//!   metrics/evaluation stack, the schema-versioned [`discovery::RunRecord`]
//!   artifacts CI gates on, the work-stealing [`matrix`] grid orchestrator
//!   with its cross-run artifact store, and the table/figure harness.
//!   Everything is launched through the typed [`api`] facade: a validated
//!   [`api::RunSpec`] / [`api::MatrixSpec`] is the one entry point shared
//!   by the CLI, the experiment harness, the tests, library embedders
//!   (see `examples/embed.rs`), and the [`serve`] daemon, which carries
//!   those same specs as wire frames and streams records back to
//!   multiple concurrent clients over one hot artifact store.
//! - **L2 (python/compile/model.py, build-time only)** — the
//!   graph-decomposed transformer, AOT-lowered per layer to HLO text.
//! - **L1 (python/compile/kernels/, build-time only)** — Pallas kernels for
//!   the mixed-precision per-head projection and attention core.
//!
//! At runtime this crate chains the per-layer PJRT executables
//! ([`runtime`]), owns the residual-stream assembly that makes edge-level
//! activation patching possible ([`patching`]), and decides — per edge
//! evaluation — which weight bytes are FP8-resident and which FP32 rows
//! must cross the (simulated) PCIe bus ([`scheduler`], [`gpu_sim`]).
//!
//! Python never runs on the request path: after `make artifacts` the
//! binary is self-contained.

pub mod acdc;
pub mod api;
pub mod baselines;
pub mod discovery;
pub mod eval;
pub mod gpu_sim;
pub mod lint;
pub mod load;
pub mod matrix;
pub mod metrics;
pub mod model;
pub mod patching;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod tasks;
pub mod tensor;
pub mod experiments;
pub mod util;

/// Repository-relative artifacts root, overridable via `PAHQ_ARTIFACTS`.
pub fn artifacts_root() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PAHQ_ARTIFACTS") {
        return p.into();
    }
    // Resolve relative to the crate root so tests/benches/examples work
    // from any CWD inside the repo.
    let mut dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    dir.push("artifacts");
    dir
}
