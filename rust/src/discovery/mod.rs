//! The unified circuit-discovery pipeline: one [`Discovery`] trait for
//! ACDC and every baseline, all running on the shared
//! [`crate::patching::PatchedForward`] session and the batched
//! [`crate::acdc::sweep`] engine.
//!
//! The paper's generality claim — PAHQ "readily integrates with existing
//! edge-based circuit discovery techniques by modifying the attention
//! computation mechanism" — is this module. Every method reduces to:
//!
//! 1. **order** the candidate edges (reverse-topological for ACDC,
//!    attribution-ranked for EAP / HISP / SP / Edge-Pruning, scored at
//!    FP32 exactly as the paper runs the gradient baselines), then
//! 2. **verify** them through the shared greedy sweep: each edge is
//!    tentatively patched with its corrupted activation and pruned for
//!    good when the metric damage increase stays below τ.
//!
//! Because step 2 is `acdc::sweep`, every method inherits the session's
//! precision [`Policy`] (under PAHQ the investigated edge's source runs
//! at FP32 via the per-call `hi` override) *and* the batched
//! multi-worker scoring with its serial-vs-batched bit-identity
//! guarantee — property-tested per method in `tests/discovery.rs`.
//!
//! Every run is packaged as a schema-versioned [`RunRecord`] artifact
//! ([`record`]): the machine-readable trace `pahq run` / `pahq sweep` /
//! `pahq bench --json` emit and CI's perf gate diffs.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::acdc::sweep::{self, Candidate, EnginePool, SweepMode, SweepOutcome};
use crate::acdc::EngineScorer;
use crate::gpu_sim::memory::{memory_model, MethodKind};
use crate::gpu_sim::RealArch;
use crate::metrics::Objective;
use crate::model::{Example, Manifest};
use crate::patching::{PatchMask, PatchedForward, Policy};
use crate::tensor::QTensor;

pub mod record;

pub use record::{kept_hash, CacheStats, Faithfulness, RunRecord, SCHEMA_VERSION};

/// The discovery workload: which model and which task's dataset.
#[derive(Clone, Debug)]
pub struct Task {
    pub model: String,
    pub task: String,
}

impl Task {
    pub fn new(model: &str, task: &str) -> Task {
        Task { model: model.to_string(), task: task.to_string() }
    }
}

/// Method-agnostic discovery configuration: the threshold, objective,
/// precision policy, and evaluation schedule shared by every method,
/// plus the training budgets of the learned baselines.
#[derive(Clone, Debug)]
pub struct DiscoveryConfig {
    pub tau: f32,
    pub objective: Objective,
    /// session precision policy (FP32 / RTN-Q / PAHQ); the verification
    /// sweep of *every* method runs under it
    pub policy: Policy,
    /// evaluation schedule; kept sets are bit-identical across modes
    pub sweep: SweepMode,
    /// record the per-step trace (Fig. 3) into the `RunRecord`
    pub record_trace: bool,
    /// SP gate-training steps
    pub sp_steps: usize,
    /// Edge-Pruning mask-training steps
    pub ep_steps: usize,
}

impl DiscoveryConfig {
    pub fn new(tau: f32, objective: Objective, policy: Policy) -> DiscoveryConfig {
        DiscoveryConfig {
            tau,
            objective,
            policy,
            sweep: SweepMode::Serial,
            record_trace: false,
            sp_steps: 80,
            ep_steps: 60,
        }
    }

    pub fn with_sweep(mut self, mode: SweepMode) -> DiscoveryConfig {
        self.sweep = mode;
        self
    }
}

/// Pre-built artifacts a matrix cell hands a session instead of the
/// session constructing its own — the cross-run reuse that makes a full
/// method x policy x task grid cheaper than its cells run in isolation.
/// Every field is optional; an all-`None` value (the default) reproduces
/// the classic build-everything-yourself session exactly.
#[derive(Clone, Default)]
struct DiscoveryInputs {
    /// evaluation batch (must be exactly `manifest.batch` examples)
    examples: Option<Arc<Vec<Example>>>,
    /// packed corrupted-activation cache, bit-identical to what this
    /// session would compute (same model, examples, and cache format)
    corrupt_cache: Option<Arc<Vec<QTensor>>>,
    /// FP32 attribution scores for the cell's method (graph.edges() order)
    scores: Option<Arc<Vec<f32>>>,
}

/// The one value a matrix cell worker passes between consecutive cells
/// (and between a cell and the shared artifact store): the engine pool,
/// the packed corrupt-activation cache, and the FP32 attribution score
/// vector, bundled. Inbound it seeds a [`SessionBuilder`]; outbound
/// ([`Session::take_handoff`]) it carries the pool onward and any scores
/// the session computed itself for publication.
///
/// Replaces the old four-setter dance
/// (`set_pool`/`take_pool`/`set_session_with_cache`/`take_computed_scores`).
#[derive(Default)]
pub struct Handoff {
    /// batched-sweep engine pool; kept by the next session's `configure`
    /// when model/task/policy/workers/objective match, rebuilt otherwise
    pub pool: Option<EnginePool>,
    /// packed corrupted-activation cache (inbound only; the matrix store
    /// owns the canonical copy, so outbound handoffs leave this `None`)
    pub corrupt_cache: Option<Arc<Vec<QTensor>>>,
    /// FP32 attribution scores in `graph.edges()` order — pre-built
    /// inbound, self-computed outbound
    pub scores: Option<Arc<Vec<f32>>>,
}

/// Staged construction of a [`Session`]: examples, a [`Handoff`], and a
/// [`DiscoveryConfig`] collected up front, one fallible [`build`]
/// producing a fully configured session.
///
/// ```
/// use pahq::discovery::{DiscoveryConfig, Session, Task};
/// use pahq::metrics::Objective;
/// use pahq::patching::Policy;
///
/// # fn demo() -> anyhow::Result<()> {
/// let task = Task::new("redwood2l-sim", "ioi");
/// let cfg = DiscoveryConfig::new(0.01, Objective::Kl, Policy::fp32());
/// let session = Session::builder(&task).config(&cfg).build()?;
/// # let _ = session; Ok(())
/// # }
/// ```
///
/// [`build`]: SessionBuilder::build
pub struct SessionBuilder {
    task: Task,
    examples: Option<Arc<Vec<Example>>>,
    handoff: Handoff,
    config: Option<DiscoveryConfig>,
}

impl SessionBuilder {
    /// Evaluation batch (must be exactly `manifest.batch` examples);
    /// defaults to the task artifact's exported batch.
    pub fn examples(mut self, examples: Arc<Vec<Example>>) -> SessionBuilder {
        self.examples = Some(examples);
        self
    }

    /// Attach pre-built artifacts from a previous cell / the matrix store.
    pub fn handoff(mut self, handoff: Handoff) -> SessionBuilder {
        self.handoff = handoff;
        self
    }

    /// Configure the session as part of [`SessionBuilder::build`] (policy
    /// session + worker pool), instead of a separate `configure` call.
    pub fn config(mut self, cfg: &DiscoveryConfig) -> SessionBuilder {
        self.config = Some(cfg.clone());
        self
    }

    /// Construct the session: engine (on the explicit batch when given),
    /// attached pool, and — when a config was staged — the configured
    /// policy session and worker pool.
    pub fn build(self) -> Result<Session> {
        let inputs = DiscoveryInputs {
            examples: self.examples,
            corrupt_cache: self.handoff.corrupt_cache,
            scores: self.handoff.scores,
        };
        let mut session = Session::with_inputs(&self.task, inputs)?;
        if let Some(pool) = self.handoff.pool {
            session.set_pool(pool);
        }
        if let Some(cfg) = &self.config {
            session.configure(cfg)?;
        }
        Ok(session)
    }
}

/// A configured discovery session: the primary engine plus — for
/// batched multi-worker sweeps — a pool of numerically identical
/// replicas. Owns the state every [`Discovery`] implementation scores
/// against.
pub struct Session {
    pub engine: PatchedForward,
    pool: Option<EnginePool>,
    task: Task,
    /// kept flags of the last `run_plan` (graph.edges() order); the
    /// `RunRecord` stores only their hash, so faithfulness evaluation
    /// reads them from here
    last_kept: Option<Vec<bool>>,
    /// pre-built artifacts (matrix cross-run reuse); all-`None` by default
    inputs: DiscoveryInputs,
    /// which pre-built inputs were actually consumed (lands in the record)
    pub cache_stats: CacheStats,
    /// scores this session computed itself, held for publication into the
    /// matrix store so the next cell of the same (method, task) reuses them
    computed_scores: Option<Arc<Vec<f32>>>,
    /// pool PJRT time at the last `configure` — a re-attached pool carries
    /// time from earlier cells that must not bill against this run
    pool_pjrt_base: Duration,
}

impl Session {
    pub fn new(task: &Task) -> Result<Session> {
        Self::with_inputs(task, DiscoveryInputs::default())
    }

    /// Staged construction: examples + [`Handoff`] + config in one
    /// fallible build (see [`SessionBuilder`]).
    pub fn builder(task: &Task) -> SessionBuilder {
        SessionBuilder {
            task: task.clone(),
            examples: None,
            handoff: Handoff::default(),
            config: None,
        }
    }

    /// Build a session around pre-built inputs: the engine's evaluation
    /// batch comes from `inputs.examples` when given, and `configure`
    /// installs `inputs.corrupt_cache` instead of re-running the
    /// corrupted forward.
    fn with_inputs(task: &Task, inputs: DiscoveryInputs) -> Result<Session> {
        let engine = match &inputs.examples {
            Some(ex) => {
                let manifest = Manifest::by_name(&task.model)?;
                PatchedForward::with_examples(manifest, ex.as_ref().clone())?
            }
            None => PatchedForward::new(&task.model, &task.task)?,
        };
        Ok(Session {
            engine,
            pool: None,
            task: task.clone(),
            last_kept: None,
            inputs,
            cache_stats: CacheStats::default(),
            computed_scores: None,
            pool_pjrt_base: Duration::default(),
        })
    }

    /// Switch the engine to `policy`, handing the attached pre-built
    /// corrupt cache over whenever its packed format matches the
    /// policy's cache format — every policy transition in the session
    /// (configure, the FP32 scoring toggle and its restore, faithfulness
    /// evaluation) reuses the cache instead of re-running the corrupted
    /// forward. Returns whether the handoff happened.
    fn enter_policy(&mut self, policy: &Policy) -> Result<bool> {
        let cache = self.inputs.corrupt_cache.clone();
        self.engine
            .set_session_handoff(policy.clone(), cache.as_ref().map(|c| c.as_slice()))
    }

    /// Apply a config: set the engine's precision session (installing the
    /// pre-built corrupted-activation cache when one was handed in) and
    /// (re)build the worker pool when the sweep schedule asks for one —
    /// keeping an attached pool whose model/task/policy/workers/objective
    /// already match instead of rebuilding its engine replicas.
    pub fn configure(&mut self, cfg: &DiscoveryConfig) -> Result<()> {
        if self.enter_policy(&cfg.policy)? {
            self.cache_stats.corrupt_hit = true;
        }
        let keep = match (&self.pool, &cfg.sweep) {
            (Some(p), SweepMode::Batched { workers }) if *workers > 1 => p.matches(
                &self.task.model,
                &self.task.task,
                &cfg.policy,
                *workers,
                cfg.objective,
            ),
            _ => false,
        };
        if !keep {
            // replicas share the primary engine's exact batch (pooled
            // scoring stays bit-identical to single-engine scoring even
            // on seeded datasets) and inherit the engine's corrupt cache
            // instead of each re-running the corrupted forward
            self.pool = match cfg.sweep {
                SweepMode::Batched { workers } if workers > 1 => Some(EnginePool::with_examples(
                    &self.task.model,
                    &self.task.task,
                    &self.engine.examples,
                    &cfg.policy,
                    workers,
                    cfg.objective,
                    Some(self.engine.corrupt_cache.as_slice()),
                )?),
                _ => None,
            };
            // a freshly built pool's construction time bills this run
            // (classic behavior); only attach-time carryover is excluded
            self.pool_pjrt_base = Duration::default();
        }
        Ok(())
    }

    /// Attach a previously built engine pool (matrix pool sharing across
    /// cells): the next `configure` keeps it when the cell's
    /// model/task/policy/workers/objective match instead of rebuilding
    /// the replicas. PJRT time the pool accrued in earlier cells is
    /// snapshotted here so it never bills against this session's runs.
    fn set_pool(&mut self, pool: EnginePool) {
        self.pool_pjrt_base = pool.pjrt_time();
        self.pool = Some(pool);
    }

    /// Detach everything the next cell (or the artifact store) can
    /// reuse: the engine pool travels to the next session on this
    /// worker, and `scores` carries any attribution vector this session
    /// computed itself (None after a score-cache hit) for publication.
    /// The canonical corrupt cache lives in the matrix store, so the
    /// outbound `corrupt_cache` is always `None`.
    pub fn take_handoff(&mut self) -> Handoff {
        Handoff {
            pool: self.pool.take(),
            corrupt_cache: None,
            scores: self.computed_scores.take(),
        }
    }

    /// Scores this session computed itself (`None` after a score-cache
    /// hit), *without* detaching the pool — [`crate::api::run`]
    /// publishes these into its artifact store while handing the live
    /// session back to the caller.
    pub fn computed_scores(&self) -> Option<Arc<Vec<f32>>> {
        self.computed_scores.clone()
    }

    /// Kept flags of the last discovery run (graph.edges() order).
    pub fn last_kept(&self) -> Option<&[bool]> {
        self.last_kept.as_deref()
    }

    /// Total wall-clock spent inside PJRT (primary engine + pool), net of
    /// any PJRT time an attached pool accumulated before `configure`.
    pub fn pjrt_time(&self) -> std::time::Duration {
        let pool = self.pool.as_ref().map(|p| p.pjrt_time()).unwrap_or_default();
        self.engine.pjrt_time() + pool.saturating_sub(self.pool_pjrt_base)
    }

    /// Drive a candidate plan through the shared sweep machinery —
    /// pooled multi-worker scoring when configured, single-engine
    /// otherwise. The reduction is identical either way.
    fn sweep_over(
        &mut self,
        plan: &[Vec<Candidate>],
        cfg: &DiscoveryConfig,
    ) -> Result<SweepOutcome> {
        let n_channels = self.engine.n_channels();
        match &mut self.pool {
            Some(pool) => {
                if pool.objective() != cfg.objective {
                    bail!(
                        "engine pool scores {:?} but the config asks for {:?}",
                        pool.objective(),
                        cfg.objective
                    );
                }
                sweep::sweep(pool, n_channels, plan, cfg.tau, cfg.record_trace, cfg.sweep)
            }
            None => {
                let mut scorer =
                    EngineScorer { engine: &mut self.engine, objective: cfg.objective };
                sweep::sweep(&mut scorer, n_channels, plan, cfg.tau, cfg.record_trace, cfg.sweep)
            }
        }
    }

    /// Run a method's candidate plan through the verification sweep and
    /// package the outcome as a [`RunRecord`]. `t0` is the method's own
    /// start time so attribution/training cost counts into the wall.
    pub fn run_plan(
        &mut self,
        method: &str,
        cfg: &DiscoveryConfig,
        plan: &[Vec<Candidate>],
        t0: Instant,
    ) -> Result<RunRecord> {
        let out = self.sweep_over(plan, cfg)?;
        let wall = t0.elapsed();
        let edges = self.engine.graph.edges();
        let kept: Vec<bool> = edges
            .iter()
            .map(|e| !out.removed.get(self.engine.chan_index(e.dst), e.src))
            .collect();
        let n_kept = kept.iter().filter(|&&k| k).count();
        let fp = self.engine.measured_footprint();
        let sim_bytes = RealArch::by_name(&self.task.model)
            .map(|arch| memory_model(&arch, MethodKind::of_policy(&cfg.policy)).total());
        let rec = RunRecord {
            schema_version: SCHEMA_VERSION,
            method: method.to_string(),
            policy: cfg.policy.name.clone(),
            model: self.task.model.clone(),
            task: self.task.task.clone(),
            objective: cfg.objective.key().to_string(),
            tau: cfg.tau as f64,
            sweep: cfg.sweep.label(),
            workers: cfg.sweep.workers(),
            n_edges: kept.len(),
            n_kept,
            kept_hash: record::kept_hash(&kept),
            n_evals: out.n_evals,
            final_metric: out.final_metric as f64,
            wall_seconds: wall.as_secs_f64(),
            pjrt_seconds: self.pjrt_time().as_secs_f64(),
            sim_bytes,
            measured_weight_bytes: fp.weights(),
            measured_cache_bytes: fp.act_cache,
            faithfulness: None,
            cache: self.cache_stats.any().then(|| self.cache_stats.clone()),
            trace: sample_trace(&out.trace),
        };
        self.last_kept = Some(kept);
        Ok(rec)
    }

    /// Score the last discovered circuit against the FP32 ground truth
    /// and fill `rec.faithfulness`. `normalized` additionally runs the
    /// clean / fully-corrupted / circuit forwards for the Hanna et al.
    /// normalized faithfulness (two extra forward passes). Restores the
    /// config's session policy before returning.
    ///
    /// The ground truth is an exhaustive per-edge FP32 sweep on first
    /// use, but it is cached on disk per (model, task, objective) —
    /// every later call (and every other table in the harness) reads
    /// the cache.
    pub fn evaluate_faithfulness(
        &mut self,
        cfg: &DiscoveryConfig,
        rec: &mut RunRecord,
        normalized: bool,
    ) -> Result<()> {
        let Some(kept) = self.last_kept.clone() else {
            bail!("no discovery has run in this session yet");
        };
        self.enter_policy(&Policy::fp32())?;
        let gt = crate::eval::ground_truth(
            &mut self.engine,
            &self.task.model,
            &self.task.task,
            cfg.objective,
        )?;
        let p = crate::metrics::confusion(&kept, &gt.member);
        let accuracy = crate::metrics::edge_accuracy(&kept, &gt.member);
        let normalized = if normalized {
            let m_clean =
                crate::metrics::logit_diff(&self.engine.clean_logits, &self.engine.examples);
            let all_corrupt = complement_mask(&self.engine, &vec![false; kept.len()]);
            let corrupt_logits = self.engine.forward(&all_corrupt, None)?;
            let m_corrupt = crate::metrics::logit_diff(&corrupt_logits, &self.engine.examples);
            let circuit_mask = complement_mask(&self.engine, &kept);
            let circuit_logits = self.engine.forward(&circuit_mask, None)?;
            let m_circ = crate::metrics::logit_diff(&circuit_logits, &self.engine.examples);
            Some(crate::metrics::faithfulness(m_circ, m_clean, m_corrupt) as f64)
        } else {
            None
        };
        rec.faithfulness =
            Some(Faithfulness { tpr: p.tpr, fpr: p.fpr, accuracy, normalized });
        self.enter_policy(&cfg.policy)?;
        Ok(())
    }
}

/// A circuit-discovery method: everything `pahq run`, the experiment
/// harness, and CI drive through one interface.
pub trait Discovery {
    /// Stable method name (`acdc`, `eap`, `hisp`, `sp`, `edge-pruning`).
    fn name(&self) -> &'static str;

    /// Discover a circuit on a configured session and report it as a
    /// machine-readable [`RunRecord`].
    fn discover(
        &self,
        session: &mut Session,
        task: &Task,
        cfg: &DiscoveryConfig,
    ) -> Result<RunRecord>;
}

/// ACDC itself through the common interface: the reverse-topological
/// plan of [`crate::acdc::sweep_plan`], verified by the shared sweep.
pub struct Acdc;

impl Discovery for Acdc {
    fn name(&self) -> &'static str {
        "acdc"
    }

    fn discover(
        &self,
        session: &mut Session,
        _task: &Task,
        cfg: &DiscoveryConfig,
    ) -> Result<RunRecord> {
        let t0 = Instant::now();
        let plan = crate::acdc::sweep_plan(&session.engine);
        session.run_plan(self.name(), cfg, &plan, t0)
    }
}

/// Candidate plan of a score-based method: every edge, ordered by
/// ascending attribution score (least-important first — the direction
/// the chain speculation is built for), ties broken by edge index so
/// the order is fully deterministic. The `hi` override follows the
/// session policy exactly as ACDC's plan does.
pub fn ordered_plan(engine: &PatchedForward, scores: &[f32]) -> Vec<Vec<Candidate>> {
    let edges = engine.graph.edges();
    debug_assert_eq!(scores.len(), edges.len());
    let policy = engine.session();
    let mut order: Vec<usize> = (0..edges.len()).collect();
    order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]).then(a.cmp(&b)));
    vec![order
        .into_iter()
        .map(|i| Candidate {
            chan: engine.chan_index(edges[i].dst),
            src: edges[i].src,
            hi: crate::acdc::hi_node_for(policy, edges[i].src),
        })
        .collect()]
}

/// Run a method's attribution scoring at FP32 (the paper's protocol for
/// every gradient baseline), then restore the session policy so the
/// verification sweep runs under it. A no-op toggle when the session is
/// already FP32.
///
/// When the session carries a pre-built score vector
/// ([`Handoff::scores`], matrix cross-run reuse) it is returned
/// directly — no toggle, no scoring pass — and the hit is recorded in
/// the session's [`CacheStats`]. Scores computed here are retained for
/// publication via [`Session::take_handoff`].
pub fn scored_at_fp32<F>(
    session: &mut Session,
    cfg: &DiscoveryConfig,
    score: F,
) -> Result<Vec<f32>>
where
    F: FnOnce(&mut PatchedForward) -> Result<Vec<f32>>,
{
    if let Some(pre) = session.inputs.scores.clone() {
        session.cache_stats.scores_hit = true;
        return Ok(pre.as_ref().clone());
    }
    let toggle = cfg.policy.name != Policy::fp32().name;
    if toggle {
        session.enter_policy(&Policy::fp32())?;
    }
    let scores = score(&mut session.engine);
    if toggle {
        session.enter_policy(&cfg.policy)?;
    }
    let scores = scores?;
    session.computed_scores = Some(Arc::new(scores.clone()));
    Ok(scores)
}

/// Edge labels of a kept set (`graph.edges()` order) — debugging / CLI
/// output for any method's discovered circuit.
pub fn kept_labels(engine: &PatchedForward, kept: &[bool]) -> Vec<String> {
    engine
        .graph
        .edges()
        .iter()
        .zip(kept)
        .filter(|(_, &k)| k)
        .map(|(e, _)| e.label(&engine.graph))
        .collect()
}

/// Build a patch mask that knocks out everything *except* the kept
/// edges (evaluating the discovered circuit, paper Eq. 19).
pub fn complement_mask(engine: &PatchedForward, kept: &[bool]) -> PatchMask {
    let mut m = engine.empty_patches();
    for (e, &k) in engine.graph.edges().iter().zip(kept) {
        if !k {
            m.set(engine.chan_index(e.dst), e.src, true);
        }
    }
    m
}

/// Sample a sweep trace down to ≤64 (step, edges_remaining) points.
fn sample_trace(trace: &[crate::acdc::TraceStep]) -> Vec<(usize, usize)> {
    if trace.is_empty() {
        return Vec::new();
    }
    let step = trace.len().div_ceil(64);
    let mut out: Vec<(usize, usize)> =
        trace.iter().step_by(step).map(|t| (t.step, t.edges_remaining)).collect();
    let last = trace.last().unwrap();
    if out.last() != Some(&(last.step, last.edges_remaining)) {
        out.push((last.step, last.edges_remaining));
    }
    out
}

/// Every registered method name, in the paper's comparison order.
pub const METHOD_NAMES: [&str; 5] = ["acdc", "eap", "hisp", "sp", "edge-pruning"];

/// Look a method up by its CLI name.
pub fn by_name(name: &str) -> Result<Box<dyn Discovery>> {
    Ok(match name {
        "acdc" => Box::new(Acdc),
        "eap" => Box::new(crate::baselines::eap::Eap),
        "hisp" => Box::new(crate::baselines::hisp::Hisp),
        "sp" => Box::new(crate::baselines::sp::Sp),
        "edge-pruning" | "ep" => Box::new(crate::baselines::edge_pruning::EdgePruning),
        other => bail!("unknown discovery method '{other}' ({})", METHOD_NAMES.join("|")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_knows_every_method() {
        for name in METHOD_NAMES {
            let m = by_name(name).unwrap();
            assert_eq!(m.name(), name);
        }
        assert_eq!(by_name("ep").unwrap().name(), "edge-pruning");
        assert!(by_name("pahq").is_err(), "pahq is a policy, not a method");
    }

    #[test]
    fn config_defaults_are_serial() {
        let cfg = DiscoveryConfig::new(0.01, Objective::Kl, Policy::fp32());
        assert_eq!(cfg.sweep, SweepMode::Serial);
        assert!(!cfg.record_trace);
        let cfg = cfg.with_sweep(SweepMode::Batched { workers: 4 });
        assert_eq!(cfg.sweep.workers(), 4);
    }

    #[test]
    fn trace_sampling_keeps_endpoints() {
        let trace: Vec<crate::acdc::TraceStep> = (0..300usize)
            .map(|i| crate::acdc::TraceStep {
                step: i + 1,
                edges_remaining: 300 - i,
                metric: 0.0,
                removed: true,
            })
            .collect();
        let s = sample_trace(&trace);
        assert!(s.len() <= 65);
        assert_eq!(s.first().unwrap(), &(1, 300));
        assert_eq!(s.last().unwrap(), &(300, 1));
        assert!(sample_trace(&[]).is_empty());
    }
}
