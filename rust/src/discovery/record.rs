//! `RunRecord` — the schema-versioned, machine-readable artifact every
//! discovery run emits (`pahq run` / `pahq sweep` / `pahq bench --json`).
//!
//! The record is what CI gates on: `scripts/bench_gate.py` diffs the
//! wall-time / measured-memory fields against the committed
//! `BENCH_baseline.json`, and `scripts/check_schema.py` validates the
//! shape against `docs/run_record.schema.json`. Bump
//! [`SCHEMA_VERSION`] on any breaking field change and update the
//! checked-in schema in the same commit.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{obj, Json};

/// Version of the `RunRecord` JSON shape. Mirrored by
/// `docs/run_record.schema.json`.
pub const SCHEMA_VERSION: usize = 1;

/// Which pre-built artifacts from the matrix store a run consumed
/// instead of constructing its own (`pahq matrix` cross-run reuse).
/// Absent (all-false) for standalone runs that built everything
/// themselves.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// evaluation batch came from the shared (task, seed, n) dataset store
    pub dataset_hit: bool,
    /// packed corrupted-activation cache was handed off, not recomputed
    pub corrupt_hit: bool,
    /// FP32 attribution score vector was reused, not rescored
    pub scores_hit: bool,
}

impl CacheStats {
    pub fn any(&self) -> bool {
        self.dataset_hit || self.corrupt_hit || self.scores_hit
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("dataset_hit", Json::from(self.dataset_hit)),
            ("corrupt_hit", Json::from(self.corrupt_hit)),
            ("scores_hit", Json::from(self.scores_hit)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<CacheStats> {
        Ok(CacheStats {
            dataset_hit: j.get("dataset_hit")?.as_bool()?,
            corrupt_hit: j.get("corrupt_hit")?.as_bool()?,
            scores_hit: j.get("scores_hit")?.as_bool()?,
        })
    }
}

/// Edge-classification quality of a discovered circuit against the FP32
/// ground truth (optional: only when the ground truth is available).
#[derive(Clone, Debug, PartialEq)]
pub struct Faithfulness {
    pub tpr: f64,
    pub fpr: f64,
    /// edge-classification accuracy (Tab. 2)
    pub accuracy: f64,
    /// Hanna et al. normalized faithfulness of the circuit's task metric
    /// (Tab. 6); only computed when the caller asks for the extra
    /// forward passes
    pub normalized: Option<f64>,
}

/// One machine-readable discovery run: method, policy, task, the
/// kept-edge set (as a stable hash), the cost of finding it (evals,
/// wall, PJRT), and both memory views (simulated paper-scale bytes and
/// measured packed bytes).
#[derive(Clone, Debug, PartialEq)]
pub struct RunRecord {
    pub schema_version: usize,
    /// discovery method name (`acdc`, `eap`, `hisp`, `sp`, `edge-pruning`)
    pub method: String,
    /// session policy name (`acdc-fp32`, `rtn-q-8b`, `pahq-8b`, ...)
    pub policy: String,
    pub model: String,
    pub task: String,
    /// objective key (`kl` | `task`)
    pub objective: String,
    pub tau: f64,
    /// sweep schedule label (`serial` | `batched[N]`)
    pub sweep: String,
    pub workers: usize,
    pub n_edges: usize,
    pub n_kept: usize,
    /// FNV-1a-64 hash (16 hex chars) of the kept flags in
    /// `graph.edges()` order — two runs discovered the same circuit iff
    /// the hashes match
    pub kept_hash: String,
    pub n_evals: usize,
    pub final_metric: f64,
    pub wall_seconds: f64,
    pub pjrt_seconds: f64,
    /// simulated footprint at paper scale (`gpu_sim::memory`), when the
    /// model maps to a [`crate::gpu_sim::RealArch`]
    pub sim_bytes: Option<usize>,
    /// measured packed weight-plane bytes this session held resident
    pub measured_weight_bytes: usize,
    /// measured packed corrupted-activation cache bytes
    pub measured_cache_bytes: usize,
    pub faithfulness: Option<Faithfulness>,
    /// which matrix-store artifacts this run consumed (cross-run reuse);
    /// `None` when the run built everything itself
    pub cache: Option<CacheStats>,
    /// sampled (step, edges_remaining) pairs of the sweep trace (Fig. 3);
    /// empty unless the run recorded a trace
    pub trace: Vec<(usize, usize)>,
}

/// Stable hash of a kept-edge set: FNV-1a over the flags in
/// `graph.edges()` order.
pub fn kept_hash(kept: &[bool]) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &k in kept {
        h ^= 1 + k as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("kind", Json::from("run_record")),
            ("schema_version", Json::from(self.schema_version)),
            ("method", Json::from(self.method.clone())),
            ("policy", Json::from(self.policy.clone())),
            ("model", Json::from(self.model.clone())),
            ("task", Json::from(self.task.clone())),
            ("objective", Json::from(self.objective.clone())),
            ("tau", Json::from(self.tau)),
            ("sweep", Json::from(self.sweep.clone())),
            ("workers", Json::from(self.workers)),
            ("n_edges", Json::from(self.n_edges)),
            ("n_kept", Json::from(self.n_kept)),
            ("kept_hash", Json::from(self.kept_hash.clone())),
            ("n_evals", Json::from(self.n_evals)),
            ("final_metric", Json::from(self.final_metric)),
            ("wall_seconds", Json::from(self.wall_seconds)),
            ("pjrt_seconds", Json::from(self.pjrt_seconds)),
            ("measured_weight_bytes", Json::from(self.measured_weight_bytes)),
            ("measured_cache_bytes", Json::from(self.measured_cache_bytes)),
        ];
        if let Some(b) = self.sim_bytes {
            pairs.push(("sim_bytes", Json::from(b)));
        }
        if let Some(f) = &self.faithfulness {
            let mut fp = vec![
                ("tpr", Json::from(f.tpr)),
                ("fpr", Json::from(f.fpr)),
                ("accuracy", Json::from(f.accuracy)),
            ];
            if let Some(n) = f.normalized {
                fp.push(("normalized", Json::from(n)));
            }
            pairs.push(("faithfulness", obj(fp)));
        }
        if let Some(c) = &self.cache {
            pairs.push(("cache", c.to_json()));
        }
        if !self.trace.is_empty() {
            pairs.push((
                "trace",
                Json::Arr(
                    self.trace
                        .iter()
                        .map(|&(s, e)| Json::Arr(vec![Json::from(s), Json::from(e)]))
                        .collect(),
                ),
            ));
        }
        obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<RunRecord> {
        if j.get("kind")?.as_str()? != "run_record" {
            bail!("not a run_record");
        }
        let version = j.get("schema_version")?.as_usize()?;
        if version != SCHEMA_VERSION {
            bail!("run_record schema v{version}, this build reads v{SCHEMA_VERSION}");
        }
        let faithfulness = match j.opt("faithfulness") {
            None => None,
            Some(f) => Some(Faithfulness {
                tpr: f.get("tpr")?.as_f64()?,
                fpr: f.get("fpr")?.as_f64()?,
                accuracy: f.get("accuracy")?.as_f64()?,
                normalized: match f.opt("normalized") {
                    None => None,
                    Some(n) => Some(n.as_f64()?),
                },
            }),
        };
        let trace = match j.opt("trace") {
            None => Vec::new(),
            Some(t) => t
                .as_arr()?
                .iter()
                .map(|p| {
                    let p = p.as_arr()?;
                    if p.len() != 2 {
                        bail!("trace point is not a [step, edges] pair");
                    }
                    Ok((p[0].as_usize()?, p[1].as_usize()?))
                })
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(RunRecord {
            schema_version: version,
            method: j.get("method")?.as_str()?.to_string(),
            policy: j.get("policy")?.as_str()?.to_string(),
            model: j.get("model")?.as_str()?.to_string(),
            task: j.get("task")?.as_str()?.to_string(),
            objective: j.get("objective")?.as_str()?.to_string(),
            tau: j.get("tau")?.as_f64()?,
            sweep: j.get("sweep")?.as_str()?.to_string(),
            workers: j.get("workers")?.as_usize()?,
            n_edges: j.get("n_edges")?.as_usize()?,
            n_kept: j.get("n_kept")?.as_usize()?,
            kept_hash: j.get("kept_hash")?.as_str()?.to_string(),
            n_evals: j.get("n_evals")?.as_usize()?,
            final_metric: j.get("final_metric")?.as_f64()?,
            wall_seconds: j.get("wall_seconds")?.as_f64()?,
            pjrt_seconds: j.get("pjrt_seconds")?.as_f64()?,
            sim_bytes: match j.opt("sim_bytes") {
                None => None,
                Some(b) => Some(b.as_usize()?),
            },
            measured_weight_bytes: j.get("measured_weight_bytes")?.as_usize()?,
            measured_cache_bytes: j.get("measured_cache_bytes")?.as_usize()?,
            faithfulness,
            cache: match j.opt("cache") {
                None => None,
                Some(c) => Some(CacheStats::from_json(c)?),
            },
            trace,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        std::fs::write(path, self.to_json().dump())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<RunRecord> {
        Self::from_json(&Json::parse_file(path)?)
    }

    /// measured weights + cache
    pub fn measured_total_bytes(&self) -> usize {
        self.measured_weight_bytes + self.measured_cache_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunRecord {
        RunRecord {
            schema_version: SCHEMA_VERSION,
            method: "eap".into(),
            policy: "pahq-8b".into(),
            model: "redwood2l-sim".into(),
            task: "ioi".into(),
            objective: "kl".into(),
            tau: 0.01,
            sweep: "batched[4]".into(),
            workers: 4,
            n_edges: 1024,
            n_kept: 37,
            kept_hash: kept_hash(&[true, false, true]),
            n_evals: 1061,
            final_metric: 0.0425,
            wall_seconds: 12.5,
            pjrt_seconds: 9.75,
            sim_bytes: Some(4_210_000_000),
            measured_weight_bytes: 123_456,
            measured_cache_bytes: 7_890,
            faithfulness: Some(Faithfulness {
                tpr: 0.93,
                fpr: 0.02,
                accuracy: 0.97,
                normalized: Some(0.88),
            }),
            cache: Some(CacheStats { dataset_hit: true, corrupt_hit: true, scores_hit: false }),
            trace: vec![(1, 1024), (512, 600), (1024, 37)],
        }
    }

    #[test]
    fn roundtrips_through_json() {
        let r = sample();
        let back = RunRecord::from_json(&r.to_json()).unwrap();
        assert_eq!(r, back);
        // optional fields absent round-trip too
        let mut bare = sample();
        bare.sim_bytes = None;
        bare.faithfulness = None;
        bare.cache = None;
        bare.trace.clear();
        let back = RunRecord::from_json(&bare.to_json()).unwrap();
        assert_eq!(bare, back);
    }

    #[test]
    fn cache_stats_roundtrip_and_any() {
        let c = CacheStats { dataset_hit: false, corrupt_hit: true, scores_hit: false };
        assert_eq!(CacheStats::from_json(&c.to_json()).unwrap(), c);
        assert!(c.any());
        assert!(!CacheStats::default().any());
    }

    #[test]
    fn rejects_wrong_kind_and_version() {
        let r = sample();
        let mut j = r.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("kind".into(), Json::from("bench_snapshot"));
        }
        assert!(RunRecord::from_json(&j).is_err());
        let mut j = r.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema_version".into(), Json::from(999usize));
        }
        assert!(RunRecord::from_json(&j).is_err());
    }

    #[test]
    fn kept_hash_is_order_and_value_sensitive() {
        let a = kept_hash(&[true, false, true]);
        assert_eq!(a.len(), 16);
        assert_eq!(a, kept_hash(&[true, false, true]));
        assert_ne!(a, kept_hash(&[false, true, true]));
        assert_ne!(a, kept_hash(&[true, false]));
        assert_ne!(kept_hash(&[]), kept_hash(&[false]));
    }

    #[test]
    fn save_load_roundtrip() {
        let r = sample();
        let dir = std::env::temp_dir().join("pahq_run_record_test");
        let path = dir.join("rec.json");
        r.save(&path).unwrap();
        assert_eq!(RunRecord::load(&path).unwrap(), r);
        std::fs::remove_file(&path).ok();
    }
}
