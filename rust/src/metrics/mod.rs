//! Loss metrics on logits and circuit-level evaluation curves.
//!
//! - KL divergence against the clean run's answer-position distribution
//!   (ACDC's default objective);
//! - logit difference <logits, ans> − <logits, dis> (the paper's "task
//!   metric"; for Greater-Than the distributions are uniform over digit
//!   sets, making this the mean-logit gap);
//! - ROC/AUC via the pessimistic Pareto line-segment construction the ACDC
//!   paper uses (Fawcett 2006);
//! - the Hanna et al. (2024) normalized faithfulness metric (Tab. 6).

use crate::model::Example;
use crate::tensor::{softmax_rows, Tensor};

/// Answer-position rows [B, V] extracted from logits [B, S, V].
pub fn at_positions(logits: &Tensor, examples: &[Example]) -> Vec<f32> {
    let (b, s, v) = (logits.shape[0], logits.shape[1], logits.shape[2]);
    debug_assert_eq!(b, examples.len());
    let mut out = vec![0.0; b * v];
    for (bi, ex) in examples.iter().enumerate() {
        debug_assert!(ex.pos < s);
        let src = &logits.data[(bi * s + ex.pos) * v..(bi * s + ex.pos + 1) * v];
        out[bi * v..(bi + 1) * v].copy_from_slice(src);
    }
    out
}

/// Softmax distributions [B, V] at the answer positions.
pub fn probs_at_positions(logits: &Tensor, examples: &[Example]) -> Vec<f32> {
    let v = logits.shape[2];
    let mut rows = at_positions(logits, examples);
    softmax_rows(&mut rows, v);
    rows
}

/// Mean KL(ref || softmax(logits[pos])) over the batch.
pub fn kl_divergence(logits: &Tensor, examples: &[Example], ref_probs: &[f32]) -> f32 {
    let v = logits.shape[2];
    let rows = probs_at_positions(logits, examples);
    debug_assert_eq!(rows.len(), ref_probs.len());
    let mut total = 0.0f64;
    for (row, rref) in rows.chunks(v).zip(ref_probs.chunks(v)) {
        let mut kl = 0.0f64;
        for (&p, &r) in row.iter().zip(rref) {
            if r > 1e-9 {
                kl += r as f64 * ((r as f64).ln() - (p.max(1e-9) as f64).ln());
            }
        }
        total += kl;
    }
    (total / examples.len() as f64) as f32
}

/// Mean <logits[pos], ans − dis> over the batch (task metric).
pub fn logit_diff(logits: &Tensor, examples: &[Example]) -> f32 {
    let v = logits.shape[2];
    let rows = at_positions(logits, examples);
    let mut total = 0.0f64;
    for (bi, ex) in examples.iter().enumerate() {
        let row = &rows[bi * v..(bi + 1) * v];
        let mut ld = 0.0f64;
        for &(t, w) in &ex.ans {
            ld += (w * row[t]) as f64;
        }
        for &(t, w) in &ex.dis {
            ld -= (w * row[t]) as f64;
        }
        total += ld;
    }
    (total / examples.len() as f64) as f32
}

/// Which objective drives the discovery sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// KL to the clean reference distribution; circuit damage = KL increase.
    Kl,
    /// Task logit-diff; circuit damage = |ld − ld_clean|.
    LogitDiff,
}

impl Objective {
    pub fn label(&self) -> &'static str {
        match self {
            Objective::Kl => "KL div",
            Objective::LogitDiff => "Task",
        }
    }

    /// Stable machine-readable key, used by the CLI (`--metric kl|task`)
    /// and the `RunRecord` artifact schema.
    pub fn key(&self) -> &'static str {
        match self {
            Objective::Kl => "kl",
            Objective::LogitDiff => "task",
        }
    }

    /// Parse the CLI / `RunRecord` spelling.
    pub fn parse(s: &str) -> anyhow::Result<Objective> {
        match s {
            "kl" => Ok(Objective::Kl),
            "task" => Ok(Objective::LogitDiff),
            other => anyhow::bail!("unknown metric '{other}' (kl|task)"),
        }
    }

    /// Every CLI spelling, in display order (drives the generated help).
    pub const SPELLINGS: [&'static str; 2] = ["kl", "task"];
}

/// Writes the stable machine-readable key ([`Objective::key`]), so
/// `format!("{obj}")` round-trips through [`Objective::from_str`].
impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.key())
    }
}

impl std::str::FromStr for Objective {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Objective> {
        Objective::parse(s)
    }
}

impl Objective {
    /// Scalar "damage" of a patched run vs the clean reference.
    pub fn damage(
        &self,
        logits: &Tensor,
        examples: &[Example],
        ref_probs: &[f32],
        ref_logit_diff: f32,
    ) -> f32 {
        match self {
            Objective::Kl => kl_divergence(logits, examples, ref_probs),
            Objective::LogitDiff => (logit_diff(logits, examples) - ref_logit_diff).abs(),
        }
    }
}

// ---------------------------------------------------------------------------
// ROC / AUC

/// One (false-positive-rate, true-positive-rate) point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RocPoint {
    pub fpr: f64,
    pub tpr: f64,
}

/// TPR/FPR of a predicted edge set against ground truth membership.
pub fn confusion(pred: &[bool], truth: &[bool]) -> RocPoint {
    debug_assert_eq!(pred.len(), truth.len());
    let (mut tp, mut fp, mut p, mut n) = (0u64, 0u64, 0u64, 0u64);
    for (&pr, &tr) in pred.iter().zip(truth) {
        if tr {
            p += 1;
            if pr {
                tp += 1;
            }
        } else {
            n += 1;
            if pr {
                fp += 1;
            }
        }
    }
    RocPoint {
        fpr: if n == 0 { 0.0 } else { fp as f64 / n as f64 },
        tpr: if p == 0 { 1.0 } else { tp as f64 / p as f64 },
    }
}

/// Classification accuracy of a predicted edge set (Tab. 2's accuracy).
pub fn edge_accuracy(pred: &[bool], truth: &[bool]) -> f64 {
    let correct = pred.iter().zip(truth).filter(|(a, b)| a == b).count();
    correct as f64 / pred.len().max(1) as f64
}

/// AUC by the ACDC paper's construction: anchor at (0,0) and (1,1), keep
/// the Pareto frontier of measured points, connect with *pessimistic*
/// (axis-aligned, lower-right) segments, integrate.
pub fn auc_pessimistic(points: &[RocPoint]) -> f64 {
    let mut pts: Vec<RocPoint> = points.to_vec();
    pts.push(RocPoint { fpr: 0.0, tpr: 0.0 });
    pts.push(RocPoint { fpr: 1.0, tpr: 1.0 });
    // sort by fpr asc, tpr desc, keep the upper envelope (max tpr so far
    // must increase as fpr grows)
    pts.sort_by(|a, b| {
        a.fpr
            .partial_cmp(&b.fpr)
            .unwrap()
            .then(b.tpr.partial_cmp(&a.tpr).unwrap())
    });
    let mut frontier: Vec<RocPoint> = Vec::new();
    let mut best_tpr = -1.0;
    for p in pts {
        if p.tpr > best_tpr {
            frontier.push(p);
            best_tpr = p.tpr;
        }
    }
    // close the curve at fpr=1 so a dominant early point (e.g. (0,1))
    // still integrates over the full fpr range
    if frontier.last().map(|p| p.fpr < 1.0).unwrap_or(false) {
        frontier.push(RocPoint { fpr: 1.0, tpr: best_tpr });
    }
    // pessimistic step integration: between consecutive frontier points,
    // assume tpr stays at the left point's value until the right point.
    let mut auc = 0.0;
    for w in frontier.windows(2) {
        auc += (w[1].fpr - w[0].fpr) * w[0].tpr;
    }
    auc
}

/// Top-1 answer accuracy: fraction of examples whose argmax logit at the
/// answer position lies in the answer set (Fig. 4 / Tab. 5's "Accuracy").
pub fn answer_accuracy(logits: &Tensor, examples: &[Example]) -> f32 {
    let v = logits.shape[2];
    let rows = at_positions(logits, examples);
    let mut ok = 0usize;
    for (bi, ex) in examples.iter().enumerate() {
        let row = &rows[bi * v..(bi + 1) * v];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if ex.ans.iter().any(|&(t, w)| t == argmax && w > 0.0) {
            ok += 1;
        }
    }
    ok as f32 / examples.len().max(1) as f32
}

/// Hanna et al. 2024 normalized faithfulness:
/// (m(circuit) − m(corrupt)) / (m(clean) − m(corrupt)), clipped to [0, 1].
/// `m` is the task metric (logit diff). 1 = circuit reproduces the model,
/// 0 = no better than the fully-corrupted run.
pub fn faithfulness(m_circuit: f32, m_clean: f32, m_corrupt: f32) -> f32 {
    let denom = m_clean - m_corrupt;
    if denom.abs() < 1e-9 {
        return 0.0;
    }
    ((m_circuit - m_corrupt) / denom).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(pos: usize, ans: usize, dis: usize) -> Example {
        Example {
            clean: vec![0; 4],
            corrupt: vec![0; 4],
            pos,
            ans: vec![(ans, 1.0)],
            dis: vec![(dis, 1.0)],
            label: ans,
        }
    }

    #[test]
    fn kl_zero_for_self() {
        let logits = Tensor::from_vec(&[1, 4, 3], vec![
            0.0, 0.0, 0.0, 1.0, 2.0, 3.0, 0.5, 0.5, 0.5, 0.0, 0.0, 0.0,
        ])
        .unwrap();
        let examples = vec![ex(1, 2, 0)];
        let ref_probs = probs_at_positions(&logits, &examples);
        assert!(kl_divergence(&logits, &examples, &ref_probs).abs() < 1e-6);
    }

    #[test]
    fn kl_positive_for_shifted() {
        let a = Tensor::from_vec(&[1, 1, 3], vec![3.0, 0.0, 0.0]).unwrap();
        let b = Tensor::from_vec(&[1, 1, 3], vec![0.0, 3.0, 0.0]).unwrap();
        let examples = vec![ex(0, 0, 1)];
        let ref_probs = probs_at_positions(&a, &examples);
        assert!(kl_divergence(&b, &examples, &ref_probs) > 1.0);
    }

    #[test]
    fn logit_diff_signs() {
        let logits = Tensor::from_vec(&[1, 1, 3], vec![2.0, 5.0, 0.0]).unwrap();
        assert_eq!(logit_diff(&logits, &[ex(0, 0, 1)]), -3.0);
        assert_eq!(logit_diff(&logits, &[ex(0, 1, 0)]), 3.0);
    }

    #[test]
    fn soft_distributions() {
        // greater-than style: ans = uniform {1,2}, dis = {0}
        let logits = Tensor::from_vec(&[1, 1, 3], vec![1.0, 2.0, 4.0]).unwrap();
        let e = Example {
            clean: vec![0],
            corrupt: vec![0],
            pos: 0,
            ans: vec![(1, 0.5), (2, 0.5)],
            dis: vec![(0, 1.0)],
            label: 1,
        };
        assert!((logit_diff(&logits, &[e]) - (3.0 - 1.0)).abs() < 1e-6);
    }

    #[test]
    fn confusion_counts() {
        let pred = [true, true, false, false];
        let truth = [true, false, true, false];
        let p = confusion(&pred, &truth);
        assert_eq!(p.tpr, 0.5);
        assert_eq!(p.fpr, 0.5);
        assert_eq!(edge_accuracy(&pred, &truth), 0.5);
    }

    #[test]
    fn auc_perfect_and_random() {
        // perfect classifier: point (0,1) -> AUC 1
        let auc = auc_pessimistic(&[RocPoint { fpr: 0.0, tpr: 1.0 }]);
        assert!((auc - 1.0).abs() < 1e-9);
        // no information beyond anchors: pessimistic AUC 0
        let auc = auc_pessimistic(&[]);
        assert!(auc.abs() < 1e-9);
        // diagonal-ish points
        let auc = auc_pessimistic(&[
            RocPoint { fpr: 0.25, tpr: 0.5 },
            RocPoint { fpr: 0.5, tpr: 0.75 },
        ]);
        assert!(auc > 0.3 && auc < 0.8, "auc={auc}");
    }

    #[test]
    fn auc_monotone_in_dominance() {
        let weak = auc_pessimistic(&[RocPoint { fpr: 0.4, tpr: 0.5 }]);
        let strong = auc_pessimistic(&[RocPoint { fpr: 0.1, tpr: 0.9 }]);
        assert!(strong > weak);
    }

    #[test]
    fn faithfulness_bounds() {
        assert_eq!(faithfulness(3.0, 3.0, 0.0), 1.0);
        assert_eq!(faithfulness(0.0, 3.0, 0.0), 0.0);
        assert_eq!(faithfulness(1.5, 3.0, 0.0), 0.5);
        assert_eq!(faithfulness(9.0, 3.0, 0.0), 1.0, "clipped");
        assert_eq!(faithfulness(1.0, 1.0, 1.0), 0.0, "degenerate denom");
    }
}
