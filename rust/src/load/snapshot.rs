//! `load_snapshot.json` — the schema'd artifact a load run emits.
//!
//! Shape is pinned by `docs/load_snapshot.schema.json` and validated
//! in CI by `scripts/check_schema.py --load`; `scripts/bench_gate.py
//! --load` gates p99/throughput floors from the `load` section of
//! `BENCH_baseline.json` against it. Everything here is derived from
//! the merged [`RunStats`] — the snapshot is a pure serialization, no
//! further measurement happens at emit time.
//!
//! The `saturation` array is the latency-vs-offered-rate curve: one
//! point per stage, x = the stage's offered rate, y = its p99. For
//! multi-stage presets (`saturate`) [`render_curve`] draws it as an
//! ASCII chart for terminals and `EXPERIMENTS.md`.

use crate::util::json::Json;

use super::scenario::Scenario;
use super::stats::{Histogram, RunStats};

/// Schema version of the emitted snapshot (bump on shape changes,
/// mirroring `run_record` / `bench_snapshot` versioning).
pub const SNAPSHOT_SCHEMA_VERSION: u64 = 1;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn int(v: u64) -> Json {
    Json::Num(v as f64)
}

/// Round to 3 decimals for human-diffable rates/walls.
fn round3(v: f64) -> f64 {
    (v * 1000.0).round() / 1000.0
}

fn latency_obj(h: &Histogram) -> Json {
    Json::obj(vec![
        ("count", int(h.count())),
        ("p50", int(h.quantile_us(0.50))),
        ("p90", int(h.quantile_us(0.90))),
        ("p99", int(h.quantile_us(0.99))),
        ("max", int(h.max_us())),
        ("mean", num(round3(h.mean_us()))),
    ])
}

fn per_sec(count: u64, wall: f64) -> f64 {
    if wall > 0.0 { round3(count as f64 / wall) } else { 0.0 }
}

fn scenario_obj(sc: &Scenario) -> Json {
    Json::obj(vec![
        ("name", Json::from(sc.name.as_str())),
        ("spec", Json::from(sc.to_string().as_str())),
        ("clients", int(sc.clients as u64)),
        ("rate", num(sc.rate)),
        ("duration_s", num(sc.duration_s)),
        ("stages", int(sc.stages as u64)),
        ("rate_step", num(sc.rate_step)),
        ("burst", int(sc.burst as u64)),
        ("seed", int(sc.seed)),
        (
            "mix",
            Json::obj(vec![
                ("run", num(sc.mix.run)),
                ("matrix", num(sc.mix.matrix)),
                ("cancel", num(sc.mix.cancel)),
            ]),
        ),
    ])
}

/// Build the full snapshot document.
///
/// `mode` is `"wire"` or `"direct"`; `addr` is the daemon address in
/// wire mode and `"in-process"` in direct mode.
pub fn build(scenario: &Scenario, mode: &str, addr: &str, stats: &RunStats) -> Json {
    let overall = stats.overall_latency();
    let wall = stats.wall_seconds;

    let stages: Vec<Json> = stats
        .stages
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let stage_wall = s.wall_seconds();
            Json::obj(vec![
                ("stage", int(i as u64)),
                ("offered_rate", num(round3(s.offered_rate))),
                ("submitted", int(s.submitted)),
                ("ok", int(s.ok)),
                ("failed", int(s.failed)),
                ("cancelled", int(s.cancelled)),
                ("records", int(s.records)),
                ("wall_seconds", num(round3(stage_wall))),
                ("records_per_sec", num(per_sec(s.records, stage_wall))),
                ("latency_us", latency_obj(&s.latency)),
            ])
        })
        .collect();

    let saturation: Vec<Json> = stats
        .stages
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("offered_rate", num(round3(s.offered_rate))),
                ("p50_us", int(s.latency.quantile_us(0.50))),
                ("p99_us", int(s.latency.quantile_us(0.99))),
            ])
        })
        .collect();

    Json::obj(vec![
        ("kind", Json::from("load_snapshot")),
        ("schema_version", int(SNAPSHOT_SCHEMA_VERSION)),
        ("mode", Json::from(mode)),
        ("addr", Json::from(addr)),
        ("scenario", scenario_obj(scenario)),
        ("wall_seconds", num(round3(wall))),
        (
            "requests",
            Json::obj(vec![
                ("submitted", int(stats.submitted())),
                ("ok", int(stats.ok())),
                ("failed", int(stats.failed())),
                ("cancelled", int(stats.cancelled())),
            ]),
        ),
        (
            "frames",
            Json::obj(vec![
                ("received", int(stats.frames_received)),
                ("records", int(stats.records())),
                ("progress", int(stats.progress_frames)),
                ("coalesced", int(stats.coalesced)),
                ("cell_errors", int(stats.cell_errors)),
                ("errors", int(stats.errors)),
                ("cancel_acks", int(stats.cancel_acks)),
                ("dropped_cells", int(stats.dropped_cells)),
            ]),
        ),
        (
            "throughput",
            Json::obj(vec![
                ("requests_per_sec", num(per_sec(stats.submitted(), wall))),
                ("records_per_sec", num(per_sec(stats.records(), wall))),
                ("frames_per_sec", num(per_sec(stats.frames_received, wall))),
            ]),
        ),
        ("latency_us", latency_obj(&overall)),
        ("stages", Json::Arr(stages)),
        ("saturation", Json::Arr(saturation)),
        (
            "histogram",
            Json::obj(vec![
                ("unit", Json::from("us")),
                (
                    "counts",
                    Json::Arr(overall.bucket_counts().iter().map(|&c| int(c)).collect()),
                ),
            ]),
        ),
    ])
}

/// Render the saturation curve (p99 latency vs offered rate) as an
/// ASCII chart — one row per stage, bar length log-scaled so a 10x
/// latency cliff reads as a visibly longer bar, not an off-screen one.
pub fn render_curve(stats: &RunStats) -> String {
    const WIDTH: usize = 40;
    let points: Vec<(f64, u64)> = stats
        .stages
        .iter()
        .filter(|s| s.latency.count() > 0)
        .map(|s| (s.offered_rate, s.latency.quantile_us(0.99)))
        .collect();
    if points.is_empty() {
        return "saturation: no completed requests\n".to_string();
    }
    let max_log = points
        .iter()
        .map(|&(_, p99)| ((p99.max(1)) as f64).ln())
        .fold(1.0f64, f64::max);
    let w = WIDTH;
    let mut out = String::from("offered req/s   p99\n");
    for (rate, p99) in points {
        let frac = ((p99.max(1)) as f64).ln() / max_log;
        let bar = "#".repeat(((frac * w as f64).round() as usize).clamp(1, w));
        let (value, unit) =
            if p99 >= 1000 { (p99 as f64 / 1000.0, "ms") } else { (p99 as f64, "us") };
        out.push_str(&format!("{rate:>11.1}   {bar:<w$} {value:>8.1} {unit}\n"));
    }
    out
}
