//! Named, repeatable load scenarios.
//!
//! A [`Scenario`] pins everything that shapes a load run — concurrent
//! clients × open-loop arrival rate × spec mix (run/matrix/cancel
//! ratios) × per-stage duration × stage count — so the same name +
//! seed always replays the same request schedule. Four presets cover
//! the common shapes (`smoke`, `steady`, `burst`, `saturate`); the CLI
//! accepts `--scenario name[:key=val,...]` overrides, validated the
//! same field-named way `api` validates specs (errors start with the
//! offending key, e.g. `clients: must be >= 1`).
//!
//! [`Scenario::schedule`] expands the config into a concrete
//! [`Request`] list *before* any traffic flows: per-stage seeded
//! exponential inter-arrivals (open loop — arrival times never depend
//! on server responses), kinds drawn from the mix, clients assigned
//! round-robin. The expansion is a pure function of the scenario, so
//! identical seed + scenario + worker count produce identical
//! schedules (pinned by `rust/tests/load.rs`).

use std::fmt;
use std::str::FromStr;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// The built-in preset names, in help/docs order.
pub const PRESETS: [&str; 4] = ["smoke", "steady", "burst", "saturate"];

/// Override keys accepted by `--scenario name:key=val,...`.
pub const OVERRIDE_KEYS: [&str; 8] =
    ["clients", "rate", "duration", "stages", "rate_step", "burst", "seed", "mix"];

/// Relative run/matrix/cancel weights (raw, not normalized — kept raw
/// so `Display` → `FromStr` round-trips bit-exactly; [`Mix::draw`]
/// normalizes on the fly).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mix {
    pub run: f64,
    pub matrix: f64,
    pub cancel: f64,
}

impl Mix {
    fn validate(&self) -> Result<()> {
        for (key, v) in [("run", self.run), ("matrix", self.matrix), ("cancel", self.cancel)] {
            if !v.is_finite() || v < 0.0 {
                bail!("mix: {key} weight must be finite and >= 0 (got {v})");
            }
        }
        if self.run + self.matrix + self.cancel <= 0.0 {
            bail!("mix: weights must not all be zero");
        }
        Ok(())
    }

    /// Map a uniform `u in [0,1)` to a request kind.
    fn draw(&self, u: f64) -> ReqKind {
        let total = self.run + self.matrix + self.cancel;
        let x = u * total;
        if x < self.run {
            ReqKind::Run
        } else if x < self.run + self.matrix {
            ReqKind::Matrix
        } else {
            ReqKind::Cancel
        }
    }
}

impl fmt::Display for Mix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}/{}", self.run, self.matrix, self.cancel)
    }
}

impl FromStr for Mix {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Mix> {
        let parts: Vec<&str> = s.split('/').collect();
        if parts.len() != 3 {
            bail!("mix: expected RUN/MATRIX/CANCEL weights (e.g. 0.8/0.1/0.1), got '{s}'");
        }
        let mut w = [0.0f64; 3];
        for (i, p) in parts.iter().enumerate() {
            w[i] = p.parse().map_err(|_| {
                anyhow::anyhow!("mix: weight '{p}' is not a number (in '{s}')")
            })?;
        }
        let mix = Mix { run: w[0], matrix: w[1], cancel: w[2] };
        mix.validate()?;
        Ok(mix)
    }
}

/// What one scheduled request submits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqKind {
    /// A single synthetic `submit_run`.
    Run,
    /// A small multi-cell `submit_matrix`.
    Matrix,
    /// A matrix submission cancelled right after it is accepted.
    Cancel,
}

impl ReqKind {
    pub fn label(&self) -> &'static str {
        match self {
            ReqKind::Run => "run",
            ReqKind::Matrix => "matrix",
            ReqKind::Cancel => "cancel",
        }
    }
}

/// One concrete scheduled request (the unit of the open-loop plan).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Request {
    /// When to submit, relative to the run epoch.
    pub at: Duration,
    /// Which stage's accounting this request belongs to.
    pub stage: usize,
    /// Which client connection/thread submits it.
    pub client: usize,
    pub kind: ReqKind,
    /// Seeded per-request variety knob (task choice for run specs).
    pub task_idx: usize,
}

/// A named, repeatable load configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Preset the scenario is based on (always one of [`PRESETS`]).
    pub name: String,
    /// Concurrent client connections (wire) / worker threads (direct).
    pub clients: usize,
    /// Stage-0 offered arrival rate, requests/sec across all clients.
    pub rate: f64,
    /// Seconds per stage.
    pub duration_s: f64,
    /// Open-loop stages; stage `s` offers `rate * rate_step^s`.
    pub stages: usize,
    /// Per-stage rate multiplier (the saturation-curve sweep).
    pub rate_step: f64,
    /// Arrivals per burst: 1 = Poisson-like singles; N>1 sends N
    /// back-to-back with correspondingly longer gaps (same mean rate).
    pub burst: usize,
    pub mix: Mix,
    pub seed: u64,
}

impl Scenario {
    /// Look up a built-in preset by name.
    pub fn preset(name: &str) -> Result<Scenario> {
        let base = Scenario {
            name: name.to_string(),
            clients: 2,
            rate: 6.0,
            duration_s: 4.0,
            stages: 1,
            rate_step: 2.0,
            burst: 1,
            mix: Mix { run: 0.8, matrix: 0.1, cancel: 0.1 },
            seed: 42,
        };
        Ok(match name {
            // quick CI gate: a few seconds, every request kind exercised
            "smoke" => base,
            // sustained mid-rate soak
            "steady" => Scenario { clients: 4, rate: 16.0, duration_s: 10.0, ..base },
            // bursty arrivals stress the outbound queues / coalescing
            "burst" => Scenario { clients: 4, rate: 24.0, duration_s: 6.0, burst: 8, ..base },
            // rate doubles each stage -> latency-vs-offered-rate curve
            "saturate" => Scenario {
                clients: 8,
                rate: 8.0,
                duration_s: 3.0,
                stages: 4,
                mix: Mix { run: 1.0, matrix: 0.0, cancel: 0.0 },
                ..base
            },
            other => bail!(
                "scenario: unknown preset '{other}' (expected {})",
                PRESETS.join(" | ")
            ),
        })
    }

    /// Field-named validation, `api`-builder style.
    pub fn validate(&self) -> Result<()> {
        if self.clients == 0 {
            bail!("clients: must be >= 1");
        }
        if self.clients > 256 {
            bail!("clients: must be <= 256 (got {})", self.clients);
        }
        if !self.rate.is_finite() || self.rate <= 0.0 {
            bail!("rate: must be finite and > 0 (got {})", self.rate);
        }
        if !self.duration_s.is_finite() || self.duration_s <= 0.0 {
            bail!("duration: must be finite and > 0 seconds (got {})", self.duration_s);
        }
        if self.duration_s > 600.0 {
            bail!("duration: must be <= 600 seconds per stage (got {})", self.duration_s);
        }
        if self.stages == 0 {
            bail!("stages: must be >= 1");
        }
        if self.stages > 16 {
            bail!("stages: must be <= 16 (got {})", self.stages);
        }
        if !self.rate_step.is_finite() || self.rate_step <= 0.0 {
            bail!("rate_step: must be finite and > 0 (got {})", self.rate_step);
        }
        if self.burst == 0 {
            bail!("burst: must be >= 1");
        }
        self.mix.validate()
    }

    /// Return a copy with `clients` replaced (the `--workers` CLI
    /// override), re-validated.
    pub fn with_clients(mut self, clients: usize) -> Result<Scenario> {
        self.clients = clients;
        self.validate()?;
        Ok(self)
    }

    /// Offered rate of stage `s` (requests/sec across all clients).
    pub fn stage_rate(&self, s: usize) -> f64 {
        self.rate * self.rate_step.powi(s as i32)
    }

    /// Total scheduled duration across stages.
    pub fn total_seconds(&self) -> f64 {
        self.duration_s * self.stages as f64
    }

    /// Expand into the concrete open-loop request plan.
    ///
    /// Pure function of the scenario: per-stage RNG streams are seeded
    /// from `seed` and the stage index only, inter-arrival gaps are
    /// exponential with mean `burst/rate(stage)` (so the mean offered
    /// rate holds for any burst size), kinds come from [`Mix::draw`],
    /// and clients are assigned round-robin over the whole run.
    pub fn schedule(&self) -> Vec<Request> {
        let mut reqs = Vec::new();
        let mut idx = 0usize;
        for stage in 0..self.stages {
            let rate = self.stage_rate(stage);
            let start = stage as f64 * self.duration_s;
            let end = start + self.duration_s;
            // decorrelate stage streams: splitmix-style odd multiplier
            let stream = (stage as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut rng = Rng::new(self.seed ^ stream);
            let mut t = start;
            loop {
                let u = rng.f64();
                t += -(1.0 - u).ln() * self.burst as f64 / rate;
                if t >= end {
                    break;
                }
                for _ in 0..self.burst {
                    reqs.push(Request {
                        at: Duration::from_secs_f64(t),
                        stage,
                        client: idx % self.clients,
                        kind: self.mix.draw(rng.f64()),
                        task_idx: rng.below(2),
                    });
                    idx += 1;
                }
            }
        }
        reqs
    }
}

impl fmt::Display for Scenario {
    /// Canonical spelling: the preset name plus only the overridden
    /// keys, so `Display ∘ FromStr` and `FromStr ∘ Display` both
    /// round-trip (pinned by `rust/tests/load.rs`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name)?;
        let base = match Scenario::preset(&self.name) {
            Ok(b) => b,
            // non-preset name (builder-made): force every key to emit
            Err(_) => Scenario {
                name: self.name.clone(),
                clients: usize::MAX,
                rate: f64::NAN,
                duration_s: f64::NAN,
                stages: usize::MAX,
                rate_step: f64::NAN,
                burst: usize::MAX,
                mix: Mix { run: f64::NAN, matrix: f64::NAN, cancel: f64::NAN },
                seed: u64::MAX,
            },
        };
        let mut sep = ':';
        let mut emit = |f: &mut fmt::Formatter<'_>, kv: String| -> fmt::Result {
            write!(f, "{sep}{kv}")?;
            sep = ',';
            Ok(())
        };
        if self.clients != base.clients {
            emit(f, format!("clients={}", self.clients))?;
        }
        if self.rate != base.rate {
            emit(f, format!("rate={}", self.rate))?;
        }
        if self.duration_s != base.duration_s {
            emit(f, format!("duration={}", self.duration_s))?;
        }
        if self.stages != base.stages {
            emit(f, format!("stages={}", self.stages))?;
        }
        if self.rate_step != base.rate_step {
            emit(f, format!("rate_step={}", self.rate_step))?;
        }
        if self.burst != base.burst {
            emit(f, format!("burst={}", self.burst))?;
        }
        if self.seed != base.seed {
            emit(f, format!("seed={}", self.seed))?;
        }
        if self.mix != base.mix {
            emit(f, format!("mix={}", self.mix))?;
        }
        Ok(())
    }
}

impl FromStr for Scenario {
    type Err = anyhow::Error;

    /// Parse `name[:key=val,...]` — the `--scenario` grammar.
    fn from_str(s: &str) -> Result<Scenario> {
        let (name, overrides) = match s.split_once(':') {
            Some((n, o)) => (n, Some(o)),
            None => (s, None),
        };
        let mut sc = Scenario::preset(name)?;
        if let Some(overrides) = overrides {
            if overrides.is_empty() {
                bail!("scenario: trailing ':' with no overrides in '{s}'");
            }
            for part in overrides.split(',') {
                let Some((key, val)) = part.split_once('=') else {
                    bail!(
                        "scenario: expected key=value override, got '{part}' (keys: {})",
                        OVERRIDE_KEYS.join(" | ")
                    );
                };
                match key {
                    "clients" => {
                        sc.clients = val
                            .parse()
                            .map_err(|_| anyhow::anyhow!("clients: '{val}' is not an integer"))?
                    }
                    "rate" => {
                        sc.rate = val
                            .parse()
                            .map_err(|_| anyhow::anyhow!("rate: '{val}' is not a number"))?
                    }
                    "duration" => {
                        sc.duration_s = val
                            .parse()
                            .map_err(|_| anyhow::anyhow!("duration: '{val}' is not a number"))?
                    }
                    "stages" => {
                        sc.stages = val
                            .parse()
                            .map_err(|_| anyhow::anyhow!("stages: '{val}' is not an integer"))?
                    }
                    "rate_step" => {
                        sc.rate_step = val
                            .parse()
                            .map_err(|_| anyhow::anyhow!("rate_step: '{val}' is not a number"))?
                    }
                    "burst" => {
                        sc.burst = val
                            .parse()
                            .map_err(|_| anyhow::anyhow!("burst: '{val}' is not an integer"))?
                    }
                    "seed" => {
                        sc.seed = val
                            .parse()
                            .map_err(|_| anyhow::anyhow!("seed: '{val}' is not an integer"))?
                    }
                    "mix" => sc.mix = val.parse()?,
                    other => bail!(
                        "scenario: unknown override key '{other}' (keys: {})",
                        OVERRIDE_KEYS.join(" | ")
                    ),
                }
            }
        }
        sc.validate()?;
        Ok(sc)
    }
}
