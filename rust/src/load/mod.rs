//! `pahq load` — a scenario-driven load/latency harness.
//!
//! Drives a live `pahq serve` daemon over its wire protocol (reusing
//! the [`crate::serve::protocol`] codec) or the in-process run path
//! directly, from a named repeatable [`Scenario`]: concurrent clients
//! × open-loop arrival rate × run/matrix/cancel mix × staged duration.
//! The request schedule is expanded deterministically *before* any
//! traffic flows, per-request latency lands in an exact-count log2
//! [`Histogram`] merged across client threads, and the run emits a
//! schema'd `load_snapshot.json` (p50/p90/p99/max, throughput,
//! error/cancel/coalesce counts, and a latency-vs-offered-rate
//! saturation curve) that `scripts/bench_gate.py --load` gates in CI.
//!
//! Layering: [`scenario`] (config + presets + deterministic schedule)
//! → [`client`] (wire/direct drivers) → [`stats`] (histogram +
//! aggregation) → [`snapshot`] (serialization + curve rendering).

pub mod client;
pub mod scenario;
pub mod snapshot;
pub mod stats;

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

pub use scenario::{Mix, ReqKind, Request, Scenario, OVERRIDE_KEYS, PRESETS};
pub use stats::{Histogram, RunStats};

use crate::util::json::Json;

/// Where the load goes.
pub enum LoadMode {
    /// Drive a live daemon over TCP; `shutdown` asks it to drain and
    /// exit after the run (so smoke scripts can assert a clean exit).
    Wire { addr: String, shutdown: bool },
    /// Execute the same specs in-process (no daemon, no sockets).
    Direct,
}

/// The `pahq load` invocation.
pub struct LoadConfig {
    pub scenario: Scenario,
    pub mode: LoadMode,
    /// Where to write `load_snapshot.json` (stdout summary either way).
    pub json: Option<PathBuf>,
}

/// Run one scenario end to end; returns the snapshot document.
pub fn run(cfg: &LoadConfig) -> Result<Json> {
    let sc = &cfg.scenario;
    sc.validate()?;
    let schedule = sc.schedule();
    if schedule.is_empty() {
        bail!(
            "scenario '{sc}' produced no requests (rate {} x {}s is too sparse)",
            sc.rate,
            sc.duration_s
        );
    }
    let (mode_label, addr_label) = match &cfg.mode {
        LoadMode::Wire { addr, .. } => ("wire", addr.clone()),
        LoadMode::Direct => ("direct", "in-process".to_string()),
    };
    println!(
        "load: scenario '{sc}' -> {} request(s) over {} stage(s), {} client(s), {} ({addr_label})",
        schedule.len(),
        sc.stages,
        sc.clients,
        mode_label,
    );

    let stats = match &cfg.mode {
        LoadMode::Wire { addr, .. } => client::run_wire(sc, &schedule, addr)?,
        LoadMode::Direct => client::run_direct(sc, &schedule)?,
    };

    let overall = stats.overall_latency();
    println!(
        "load: {} submitted, {} ok, {} failed, {} cancelled in {:.2}s",
        stats.submitted(),
        stats.ok(),
        stats.failed(),
        stats.cancelled(),
        stats.wall_seconds,
    );
    println!(
        "load: latency p50 {}us  p90 {}us  p99 {}us  max {}us ({} sample(s))",
        overall.quantile_us(0.50),
        overall.quantile_us(0.90),
        overall.quantile_us(0.99),
        overall.max_us(),
        overall.count(),
    );
    if stats.wall_seconds > 0.0 {
        println!(
            "load: throughput {:.1} records/s, {:.1} frames/s ({} coalesced progress)",
            stats.records() as f64 / stats.wall_seconds,
            stats.frames_received as f64 / stats.wall_seconds,
            stats.coalesced,
        );
    }
    if sc.stages > 1 {
        print!("{}", snapshot::render_curve(&stats));
    }

    if let LoadMode::Wire { addr, shutdown: true } = &cfg.mode {
        client::shutdown_daemon(addr)?;
        println!("load: daemon acknowledged shutdown");
    }

    let doc = snapshot::build(sc, mode_label, &addr_label, &stats);
    if let Some(path) = &cfg.json {
        std::fs::write(path, doc.dump() + "\n")
            .with_context(|| format!("writing {}", path.display()))?;
        println!("load: snapshot -> {}", path.display());
    }
    Ok(doc)
}
