//! The load drivers: wire-mode clients speaking the `pahq serve`
//! protocol, and a direct-mode driver calling the in-process run path.
//!
//! Both modes execute the same pre-expanded [`Request`] schedule.
//! Each client is one thread with private [`RunStats`] (merged by the
//! caller — no locks on the hot path). Wire mode opens one TCP
//! connection per client, reuses the daemon's own
//! [`crate::serve::protocol`] codec, and measures submit→`done`
//! latency per request; direct mode executes the same specs through
//! [`api::run_with_cache`] against one shared [`ArtifactCache`],
//! giving an engine-only latency floor to compare the wire numbers
//! against.
//!
//! Clients synchronize on a barrier *after* connecting/handshaking so
//! the schedule epoch starts with every connection live, then run open
//! loop: submit times come from the schedule alone, never from server
//! responses.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::io::Write;
use std::net::TcpStream;
use std::sync::Barrier;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::scenario::{ReqKind, Request, Scenario};
use super::stats::{Outcome, RunStats};
use crate::api::{self, MatrixSpec, RunSpec, Substrate};
use crate::matrix::cache::ArtifactCache;
use crate::serve::protocol::{encode, Message, PROTOCOL_VERSION};
use crate::serve::{FrameReader, ReadEvent};
use crate::util::json::Json;

/// Read-timeout granularity: bounds how late a due submission can go
/// out while the client is blocked waiting for frames.
const POLL: Duration = Duration::from_millis(5);

/// Extra wall allowed past the scheduled end for in-flight jobs to
/// drain before a client gives up.
const DRAIN_GRACE: Duration = Duration::from_secs(30);

/// Synthetic-substrate tasks the run mix alternates between.
const TASKS: [&str; 2] = ["ioi", "greater_than"];

/// The single-run spec a [`ReqKind::Run`] request submits.
fn run_spec(task_idx: usize) -> Result<RunSpec> {
    RunSpec::builder("redwood2l-sim", TASKS[task_idx % TASKS.len()])
        .method("pahq".parse()?)
        .tau(0.01)
        .substrate(Substrate::Synthetic)
        .build()
}

/// The small multi-cell grid a [`ReqKind::Matrix`] (or
/// [`ReqKind::Cancel`]) request submits — 4 synthetic cells, enough to
/// exercise progress streaming and queued-cell cancellation.
fn matrix_spec() -> Result<MatrixSpec> {
    MatrixSpec::from_wire(&Json::parse(
        r#"{"models": ["redwood2l-sim"], "tasks": ["ioi", "greater_than"],
            "methods": ["acdc", "eap"], "policies": ["pahq"]}"#,
    )?)
}

/// Split the schedule into per-client slices (client ids were assigned
/// round-robin by [`Scenario::schedule`]).
fn per_client(schedule: &[Request], clients: usize) -> Vec<Vec<Request>> {
    let mut out = vec![Vec::new(); clients];
    for r in schedule {
        out[r.client % clients].push(*r);
    }
    out
}

// ---------------------------------------------------------------------------
// Wire mode

struct WireClient {
    stream: TcpStream,
    reader: FrameReader,
}

impl WireClient {
    fn connect(addr: &str) -> Result<WireClient> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(POLL))?;
        Ok(WireClient { stream, reader: FrameReader::new() })
    }

    fn send(&mut self, msg: &Message) -> Result<()> {
        self.stream.write_all(&encode(msg)?).context("sending frame")
    }

    /// One bounded read attempt: `Ok(None)` on timeout.
    fn poll(&mut self) -> Result<Option<Message>> {
        match self.reader.next(&mut self.stream)? {
            ReadEvent::Frame(msg) => Ok(Some(msg)),
            ReadEvent::Pending => Ok(None),
            ReadEvent::Eof => bail!("server closed the connection"),
        }
    }

    /// Block (bounded by `deadline`) until the next frame.
    fn recv(&mut self, deadline: Instant) -> Result<Message> {
        loop {
            if let Some(msg) = self.poll()? {
                return Ok(msg);
            }
            if Instant::now() > deadline {
                bail!("timed out waiting for a frame");
            }
        }
    }

    fn handshake(&mut self) -> Result<()> {
        self.send(&Message::Hello { protocol: PROTOCOL_VERSION })?;
        match self.recv(Instant::now() + Duration::from_secs(10))? {
            Message::HelloAck { .. } => Ok(()),
            other => bail!("expected hello_ack, got '{}'", other.kind()),
        }
    }
}

/// One wire client thread: submit this client's slice of the schedule
/// open-loop, stream responses, account everything into private stats.
fn wire_client_loop(
    addr: &str,
    reqs: &[Request],
    scenario: &Scenario,
    barrier: &Barrier,
) -> Result<RunStats> {
    let mut stats = RunStats::new(scenario);
    let mut client = WireClient::connect(addr)?;
    client.handshake()?;
    barrier.wait();
    let t0 = Instant::now();
    let deadline = t0 + Duration::from_secs_f64(scenario.total_seconds()) + DRAIN_GRACE;

    let mut next = 0usize;
    // submissions whose `accepted` has not arrived yet (the server
    // replies in submission order per connection)
    let mut awaiting: VecDeque<(usize, Instant)> = VecDeque::new();
    // accepted jobs awaiting their terminal `done`
    let mut active: HashMap<u64, (usize, Instant)> = HashMap::new();

    loop {
        let now = Instant::now();
        if now > deadline {
            bail!(
                "client deadline exceeded with {} submission(s) and {} job(s) outstanding",
                awaiting.len(),
                active.len()
            );
        }
        // drain every submission that has come due
        if next < reqs.len() && now.duration_since(t0) >= reqs[next].at {
            let req = reqs[next];
            let msg = match req.kind {
                ReqKind::Run => Message::SubmitRun { spec: run_spec(req.task_idx)? },
                ReqKind::Matrix | ReqKind::Cancel => {
                    Message::SubmitMatrix { spec: matrix_spec()? }
                }
            };
            client.send(&msg)?;
            stats.stages[req.stage].note_submit(t0.elapsed().as_secs_f64());
            awaiting.push_back((next, Instant::now()));
            next += 1;
            continue;
        }
        if next >= reqs.len() && awaiting.is_empty() && active.is_empty() {
            break;
        }
        let Some(msg) = client.poll()? else { continue };
        stats.frames_received += 1;
        match msg {
            Message::Accepted { job_id, .. } => {
                let Some((idx, submitted)) = awaiting.pop_front() else {
                    bail!("accepted frame with no submission outstanding");
                };
                active.insert(job_id, (idx, submitted));
                if reqs[idx].kind == ReqKind::Cancel {
                    client.send(&Message::Cancel { job_id })?;
                }
            }
            Message::Progress { coalesced, .. } => {
                stats.progress_frames += 1;
                stats.coalesced += coalesced as u64;
            }
            Message::Record { job_id, .. } => {
                if let Some(&(idx, _)) = active.get(&job_id) {
                    stats.stages[reqs[idx].stage].records += 1;
                }
            }
            Message::CellError { .. } => stats.cell_errors += 1,
            Message::CancelAck { dropped, .. } => {
                stats.cancel_acks += 1;
                stats.dropped_cells += dropped as u64;
            }
            Message::Done { job_id, failed, cancelled, .. } => {
                let Some((idx, submitted)) = active.remove(&job_id) else {
                    bail!("done frame for unknown job {job_id}");
                };
                let outcome = if failed > 0 {
                    Outcome::Failed
                } else if cancelled > 0 {
                    Outcome::Cancelled
                } else {
                    Outcome::Ok
                };
                stats.stages[reqs[idx].stage].note_done(
                    outcome,
                    submitted.elapsed(),
                    t0.elapsed().as_secs_f64(),
                );
            }
            Message::Error { .. } => {
                stats.errors += 1;
                // a submission-level refusal consumes the oldest
                // outstanding submission; count it as failed
                if let Some((idx, submitted)) = awaiting.pop_front() {
                    stats.stages[reqs[idx].stage].note_done(
                        Outcome::Failed,
                        submitted.elapsed(),
                        t0.elapsed().as_secs_f64(),
                    );
                }
            }
            other => bail!("unexpected frame '{}'", other.kind()),
        }
    }
    Ok(stats)
}

/// Drive the schedule against a live daemon at `addr`. Returns merged
/// stats with `wall_seconds` filled.
pub fn run_wire(scenario: &Scenario, schedule: &[Request], addr: &str) -> Result<RunStats> {
    let slices = per_client(schedule, scenario.clients);
    let barrier = Barrier::new(scenario.clients + 1);
    let (wall, results) = std::thread::scope(|scope| {
        let handles: Vec<_> = slices
            .iter()
            .map(|slice| {
                let barrier = &barrier;
                scope.spawn(move || wire_client_loop(addr, slice, scenario, barrier))
            })
            .collect();
        barrier.wait();
        let t0 = Instant::now();
        let results: Vec<Result<RunStats>> = handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| bail_panic()))
            .collect();
        (t0.elapsed(), results)
    });
    merge_results(scenario, results, wall)
}

/// Ask the daemon to drain and exit (the `--shutdown` flag); used by
/// CI so the smoke script can assert a clean daemon exit code.
pub fn shutdown_daemon(addr: &str) -> Result<()> {
    let mut client = WireClient::connect(addr)?;
    client.handshake()?;
    client.send(&Message::Shutdown)?;
    match client.recv(Instant::now() + Duration::from_secs(30))? {
        Message::ShutdownAck => Ok(()),
        other => bail!("expected shutdown_ack, got '{}'", other.kind()),
    }
}

// ---------------------------------------------------------------------------
// Direct mode

/// One direct-mode thread: execute this client's slice in-process at
/// the scheduled times against the shared cache.
fn direct_client_loop(
    reqs: &[Request],
    scenario: &Scenario,
    cache: &ArtifactCache,
    barrier: &Barrier,
) -> Result<RunStats> {
    let mut stats = RunStats::new(scenario);
    barrier.wait();
    let t0 = Instant::now();
    for req in reqs {
        if let Some(wait) = req.at.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        let stage = &mut stats.stages[req.stage];
        stage.note_submit(t0.elapsed().as_secs_f64());
        let started = Instant::now();
        let outcome = match req.kind {
            ReqKind::Run => match api::run_with_cache(&run_spec(req.task_idx)?, cache) {
                Ok(_) => {
                    stage.records += 1;
                    Outcome::Ok
                }
                Err(_) => Outcome::Failed,
            },
            ReqKind::Matrix => {
                let mut failed = false;
                for (_, spec) in api::matrix_cells(&matrix_spec()?)? {
                    match api::run_with_cache(&spec, cache) {
                        Ok(_) => stage.records += 1,
                        Err(_) => failed = true,
                    }
                }
                if failed { Outcome::Failed } else { Outcome::Ok }
            }
            // no daemon to race a cancel against in-process: account
            // the request as cancelled without executing its cells
            ReqKind::Cancel => Outcome::Cancelled,
        };
        let stage = &mut stats.stages[req.stage];
        stage.note_done(outcome, started.elapsed(), t0.elapsed().as_secs_f64());
    }
    Ok(stats)
}

/// Drive the schedule through the in-process run path (no daemon, no
/// sockets): the engine-only latency floor.
pub fn run_direct(scenario: &Scenario, schedule: &[Request]) -> Result<RunStats> {
    let cache = crate::matrix::open_cache(&api::StoreSpec::Memory, false)?;
    let slices = per_client(schedule, scenario.clients);
    let barrier = Barrier::new(scenario.clients + 1);
    let (wall, results) = std::thread::scope(|scope| {
        let handles: Vec<_> = slices
            .iter()
            .map(|slice| {
                let (barrier, cache) = (&barrier, &cache);
                scope.spawn(move || direct_client_loop(slice, scenario, cache, barrier))
            })
            .collect();
        barrier.wait();
        let t0 = Instant::now();
        let results: Vec<Result<RunStats>> = handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| bail_panic()))
            .collect();
        (t0.elapsed(), results)
    });
    merge_results(scenario, results, wall)
}

fn bail_panic() -> Result<RunStats> {
    Err(anyhow::anyhow!("load client thread panicked"))
}

fn merge_results(
    scenario: &Scenario,
    results: Vec<Result<RunStats>>,
    wall: Duration,
) -> Result<RunStats> {
    let mut merged = RunStats::new(scenario);
    for r in results {
        merged.merge(&r?);
    }
    merged.wall_seconds = wall.as_secs_f64();
    Ok(merged)
}
