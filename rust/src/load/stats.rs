//! Exact-count latency statistics for the load harness.
//!
//! [`Histogram`] is a fixed-bucket log2 histogram over microseconds:
//! bucket `i` counts samples whose value lies in `[2^i, 2^(i+1))`
//! (bucket 0 also absorbs 0 and 1). Every sample lands in exactly one
//! bucket — no sampling, no reservoir — so merging per-client-thread
//! histograms is plain element-wise addition and quantiles are exact to
//! bucket resolution: [`Histogram::quantile_bounds`] brackets the true
//! nearest-rank quantile between the bucket's bounds (clamped to the
//! observed min/max), which `rust/tests/load.rs` pins against a
//! sorted-vector oracle.
//!
//! [`StageStats`] / [`RunStats`] aggregate one scenario stage / one
//! whole run; both merge the same way the histogram does, so each
//! client thread accumulates privately and the harness folds the
//! results together at the end without locks on the hot path.

use std::time::Duration;

use super::scenario::Scenario;

/// Number of log2 buckets — enough for any u64 microsecond value.
pub const BUCKETS: usize = 64;

/// Fixed-bucket log2 histogram over `u64` microsecond samples.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// `floor(log2(v))` for `v >= 2`; 0 and 1 share bucket 0.
fn bucket_of(v: u64) -> usize {
    if v < 2 { 0 } else { 63 - v.leading_zeros() as usize }
}

/// Inclusive lower bound of bucket `i`.
fn bucket_lo(i: usize) -> u64 {
    if i == 0 { 0 } else { 1u64 << i }
}

/// Inclusive upper bound of bucket `i`.
fn bucket_hi(i: usize) -> u64 {
    if i >= 63 { u64::MAX } else { (1u64 << (i + 1)) - 1 }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { counts: [0; BUCKETS], total: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    /// Record one latency sample in microseconds.
    pub fn record_us(&mut self, v: u64) {
        self.counts[bucket_of(v)] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Element-wise merge — the mergeability contract that lets every
    /// client thread keep a private histogram. Associative and
    /// commutative (pinned by `rust/tests/load.rs`).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact observed maximum (`0` when empty).
    pub fn max_us(&self) -> u64 {
        if self.total == 0 { 0 } else { self.max }
    }

    /// Exact observed minimum (`0` when empty).
    pub fn min_us(&self) -> u64 {
        if self.total == 0 { 0 } else { self.min }
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.sum as f64 / self.total as f64 }
    }

    /// Bracket the nearest-rank `q`-quantile (`0 < q <= 1`):
    /// `(lo, hi)` such that `lo <= sorted[ceil(q*n)-1] <= hi`, where the
    /// bounds are the chosen bucket's range clamped to the exact
    /// observed min/max. `None` when empty.
    pub fn quantile_bounds(&self, q: f64) -> Option<(u64, u64)> {
        if self.total == 0 {
            return None;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Some((bucket_lo(i).max(self.min), bucket_hi(i).min(self.max)));
            }
        }
        // pahq-lint: allow(panic-macro): rank < total by construction, the loop must hit it
        unreachable!("cumulative count {cum} never reached rank {rank}");
    }

    /// The reported quantile value: the bracket's upper bound (a
    /// pessimistic-by-at-most-2x estimate, exact for single-valued
    /// buckets and at the extremes).
    pub fn quantile_us(&self, q: f64) -> u64 {
        self.quantile_bounds(q).map(|(_, hi)| hi).unwrap_or(0)
    }

    /// The raw bucket counts (snapshot serialization).
    pub fn bucket_counts(&self) -> &[u64; BUCKETS] {
        &self.counts
    }
}

// ---------------------------------------------------------------------------
// Per-stage and per-run aggregation

/// How one request terminated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Every cell completed with a record.
    Ok,
    /// At least one cell errored.
    Failed,
    /// At least one cell was cancelled (and none failed).
    Cancelled,
}

/// Accounting for one open-loop stage of a scenario.
#[derive(Clone, Debug)]
pub struct StageStats {
    /// Requests/sec this stage *offered* (the saturation-curve x axis).
    pub offered_rate: f64,
    pub submitted: u64,
    pub ok: u64,
    pub failed: u64,
    pub cancelled: u64,
    /// Record frames (wire) / completed cells (direct) for this stage's
    /// requests.
    pub records: u64,
    /// Submit→done latency of this stage's requests.
    pub latency: Histogram,
    /// First submit / last done, seconds relative to the run epoch.
    pub first_submit_s: Option<f64>,
    pub last_done_s: Option<f64>,
}

impl StageStats {
    fn new(offered_rate: f64) -> StageStats {
        StageStats {
            offered_rate,
            submitted: 0,
            ok: 0,
            failed: 0,
            cancelled: 0,
            records: 0,
            latency: Histogram::new(),
            first_submit_s: None,
            last_done_s: None,
        }
    }

    pub fn note_submit(&mut self, at_s: f64) {
        self.submitted += 1;
        self.first_submit_s =
            Some(self.first_submit_s.map_or(at_s, |t| if at_s < t { at_s } else { t }));
    }

    pub fn note_done(&mut self, outcome: Outcome, latency: Duration, at_s: f64) {
        match outcome {
            Outcome::Ok => self.ok += 1,
            Outcome::Failed => self.failed += 1,
            Outcome::Cancelled => self.cancelled += 1,
        }
        self.latency.record(latency);
        self.last_done_s =
            Some(self.last_done_s.map_or(at_s, |t| if at_s > t { at_s } else { t }));
    }

    /// First-submit → last-done span (the stage's achieved wall).
    pub fn wall_seconds(&self) -> f64 {
        match (self.first_submit_s, self.last_done_s) {
            (Some(a), Some(b)) if b > a => b - a,
            _ => 0.0,
        }
    }

    fn merge(&mut self, other: &StageStats) {
        self.submitted += other.submitted;
        self.ok += other.ok;
        self.failed += other.failed;
        self.cancelled += other.cancelled;
        self.records += other.records;
        self.latency.merge(&other.latency);
        self.first_submit_s = match (self.first_submit_s, other.first_submit_s) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.last_done_s = match (self.last_done_s, other.last_done_s) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
    }
}

/// One client thread's (and, after merging, the whole run's) view of a
/// load run.
#[derive(Clone, Debug)]
pub struct RunStats {
    pub stages: Vec<StageStats>,
    /// Every frame received, all types (wire mode; 0 direct).
    pub frames_received: u64,
    pub progress_frames: u64,
    /// Sum of `coalesced` counters across progress frames: how many
    /// snapshots the server's latest-wins coalescing absorbed.
    pub coalesced: u64,
    pub cell_errors: u64,
    /// `error` frames (protocol / refused submissions).
    pub errors: u64,
    pub cancel_acks: u64,
    /// Queued cells the server reported dropped on cancel.
    pub dropped_cells: u64,
    /// Filled by the harness after the run completes.
    pub wall_seconds: f64,
}

impl RunStats {
    pub fn new(scenario: &Scenario) -> RunStats {
        RunStats {
            stages: (0..scenario.stages).map(|s| StageStats::new(scenario.stage_rate(s))).collect(),
            frames_received: 0,
            progress_frames: 0,
            coalesced: 0,
            cell_errors: 0,
            errors: 0,
            cancel_acks: 0,
            dropped_cells: 0,
            wall_seconds: 0.0,
        }
    }

    pub fn merge(&mut self, other: &RunStats) {
        debug_assert_eq!(self.stages.len(), other.stages.len());
        for (a, b) in self.stages.iter_mut().zip(other.stages.iter()) {
            a.merge(b);
        }
        self.frames_received += other.frames_received;
        self.progress_frames += other.progress_frames;
        self.coalesced += other.coalesced;
        self.cell_errors += other.cell_errors;
        self.errors += other.errors;
        self.cancel_acks += other.cancel_acks;
        self.dropped_cells += other.dropped_cells;
    }

    /// All stages' latency folded into one histogram.
    pub fn overall_latency(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in &self.stages {
            h.merge(&s.latency);
        }
        h
    }

    pub fn submitted(&self) -> u64 {
        self.stages.iter().map(|s| s.submitted).sum()
    }

    pub fn ok(&self) -> u64 {
        self.stages.iter().map(|s| s.ok).sum()
    }

    pub fn failed(&self) -> u64 {
        self.stages.iter().map(|s| s.failed).sum()
    }

    pub fn cancelled(&self) -> u64 {
        self.stages.iter().map(|s| s.cancelled).sum()
    }

    pub fn records(&self) -> u64 {
        self.stages.iter().map(|s| s.records).sum()
    }
}
