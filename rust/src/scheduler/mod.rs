//! PAHQ's three-stream predictive scheduler (paper section 3.2) on the
//! discrete-event GPU.
//!
//! Streams: `S_load` (host→device weight staging), `S_low` (all-heads
//! low-precision compute), `S_high` (investigated-head FP32 compute).
//! Per edge evaluation at source layer l*:
//!
//!   Phase 1  S_load:  W_QKV,32[l*,h*] (strided row gather) and
//!                     W_O,32[l*] (contiguous) — issued for edge t+1
//!                     *while edge t computes* (predictive prefetch,
//!                     paper Eq. 5).
//!   Phase 2  S_low:   per layer: fused QKV GEMM (FP8), attention core,
//!                     output projection, MLP (bf16).
//!            S_high:  at l*: three FP32 GEMMs for h*, each gated on its
//!                     staged weights (Sync(S_load, ·), Eq. 13).
//!   Phase 3  merge:   MixedAssembly + unified FP32 attention (Eq. 15-18),
//!                     then the layer barrier.
//!
//! [`StreamConfig`] reproduces Tab. 4's 2x2 ablation: `load_stream` off
//! serializes the staging onto the compute stream; `split_compute` off
//! serializes S_high onto S_low. RTN-Q runs everything single-stream FP8;
//! ACDC single-stream FP32 with no staging (weights already resident).
//!
//! Steady-state per-edge cost is measured by simulating a window of
//! consecutive edge evaluations and differencing the makespan, so
//! cross-edge prefetch overlap is captured naturally.

use crate::acdc::SweepMode;
use crate::gpu_sim::memory::MethodKind;
use crate::gpu_sim::{CostModel, RealArch, Sim, StreamId};
use crate::quant::{BF16, FP32, FP8_E4M3};

pub const S_LOAD: StreamId = StreamId(0);
pub const S_LOW: StreamId = StreamId(1);
pub const S_HIGH: StreamId = StreamId(2);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamConfig {
    /// dedicated weight-loading stream (off -> staging serializes onto
    /// the compute stream)
    pub load_stream: bool,
    /// separate low/high-precision compute streams (off -> high-precision
    /// ops serialize after low-precision ones)
    pub split_compute: bool,
}

impl StreamConfig {
    pub const FULL: StreamConfig = StreamConfig { load_stream: true, split_compute: true };
    pub const LOAD_ONLY: StreamConfig = StreamConfig { load_stream: true, split_compute: false };
    pub const SPLIT_ONLY: StreamConfig = StreamConfig { load_stream: false, split_compute: true };
    pub const NONE: StreamConfig = StreamConfig { load_stream: false, split_compute: false };
}

#[derive(Clone, Debug)]
pub struct RunPrediction {
    pub method: String,
    pub per_edge_us: f64,
    pub n_edges: usize,
    pub total_minutes: f64,
    pub load_utilization: f64,
    pub low_utilization: f64,
}

/// Simulate one edge evaluation's forward pass; returns the completion
/// event. `l_star` is the investigated edge's source layer (None for
/// ACDC / RTN-Q).
///
/// Stream assignment mirrors the paper:
/// - S_LOW:  all-heads FP8 QKV GEMMs, MLP (bf16 for PAHQ, FP8 for RTN-Q);
/// - S_HIGH: everything the paper unifies to FP32 after MixedAssembly
///   (Eq. 10/18): attention core, W_O projection, plus the FP32 GEMMs of
///   the investigated head h* (Eq. 14) and the unembed/metric tail;
/// - S_LOAD: Phase-1 staging (Eq. 11), strided per-row gathers.
///
/// Consecutive edge evaluations are independent forwards (ACDC's
/// accept/reject only edits the patch set, which the predictive scheduler
/// speculates through — the paper's batched edge evaluation does the
/// same), so edges are NOT serialized on one another: with split streams
/// the pipeline's throughput is the busiest stream, not the critical
/// path. With `split_compute` off everything shares S_LOW and serializes,
/// and with `load_stream` off the staging serializes in front of the
/// compute — Tab. 4's four quadrants.
fn edge_eval(
    sim: &mut Sim,
    arch: &RealArch,
    cost: &CostModel,
    method: MethodKind,
    cfg: StreamConfig,
    l_star: Option<usize>,
) -> crate::gpu_sim::EventId {
    let (b, s) = (arch.batch, arch.seq);
    let tokens = b * s;
    let (d, h, dh, f) = (arch.d_model, arch.n_head, arch.d_head, arch.d_mlp);
    let (low_fmt, mlp_fmt, attn_fmt, tail_fmt) = match method {
        MethodKind::AcdcFp32 => (FP32, FP32, FP32, FP32),
        MethodKind::RtnQ => (FP8_E4M3, FP8_E4M3, FP8_E4M3, FP8_E4M3),
        // Eq. 10/18: attention + W_O at FP32; non-attention tail at bf16
        MethodKind::Pahq => (FP8_E4M3, BF16, FP32, BF16),
    };
    let load_stream = if cfg.load_stream { S_LOAD } else { S_LOW };
    let high_stream = if cfg.split_compute { S_HIGH } else { S_LOW };

    // Phase 1: staging for the investigated head (PAHQ only).
    // W_Q/K/V head slices are strided column gathers: d rows per matrix.
    let loads = if method == MethodKind::Pahq {
        let qkv = sim.op(
            load_stream,
            cost.transfer_us(arch.head_bytes(), 3 * d),
            &[],
            "load W_QKV32[h*]",
        );
        let wo =
            sim.op(load_stream, cost.transfer_us(arch.wo_bytes(), 1), &[qkv], "load W_O32[l*]");
        Some((qkv, wo))
    } else {
        None
    };

    let mut barrier: Vec<crate::gpu_sim::EventId> = Vec::new();
    for l in 0..arch.n_layer {
        // fused all-heads QKV projection (low precision)
        let mut qkv = sim.op(S_LOW, cost.gemm_us(tokens, 3 * d, d, low_fmt), &barrier, "qkv low");
        if method == MethodKind::RtnQ {
            // naive RTN fake-quants weights and activations around every
            // GEMM (frexp/round ALU passes, see CostModel::ew_gbps)
            qkv = sim.op(
                S_LOW,
                cost.elementwise_us((tokens * 3 * d + 3 * d * d) * 4),
                &[qkv],
                "rtn quant",
            );
        }
        // high-precision path for the investigated head (Eq. 12-16)
        let mut attn_deps = vec![qkv];
        if method == MethodKind::Pahq && l_star == Some(l) {
            let (lq, lo) = loads.unwrap();
            let mut hdeps = barrier.clone();
            hdeps.push(lq);
            let mut hev = None;
            for _ in 0..3 {
                let e = sim.op(
                    high_stream,
                    cost.gemm_us(tokens, dh, d, FP32),
                    &hdeps,
                    "h* fp32 gemm",
                );
                hev = Some(e);
            }
            // MixedAssembly (Eq. 16)
            let ma = sim.op(
                high_stream,
                cost.elementwise_us(tokens * 3 * d * 4),
                &[hev.unwrap(), qkv],
                "MixedAssembly",
            );
            attn_deps.push(ma);
            attn_deps.push(lo); // W_O,32 must be staged before Eq. 18
        }
        // attention core + output projection: the paper's unified-
        // precision attention (high stream for PAHQ)
        let sc = sim.op(
            high_stream,
            cost.gemm_us(b * h * s, s, dh, attn_fmt),
            &attn_deps,
            "scores",
        );
        let av = sim.op(high_stream, cost.gemm_us(b * h * s, dh, s, attn_fmt), &[sc], "attn·V");
        let mut out = sim.op(
            high_stream,
            cost.gemm_us(tokens, d, d, attn_fmt),
            &[av],
            "out proj",
        );
        if arch.has_mlp() {
            let m1 = sim.op(S_LOW, cost.gemm_us(tokens, f, d, mlp_fmt), &[out], "mlp up");
            out = sim.op(S_LOW, cost.gemm_us(tokens, d, f, mlp_fmt), &[m1], "mlp down");
            if method == MethodKind::RtnQ {
                out = sim.op(
                    S_LOW,
                    cost.elementwise_us((tokens * (d + f) + 2 * d * f) * 4),
                    &[out],
                    "rtn quant",
                );
            }
        }
        barrier = vec![out];
    }
    // metric evaluation (unembed + KL): non-attention tail, low stream
    let um = sim.op(S_LOW, cost.gemm_us(tokens, 50257, d, tail_fmt), &barrier, "unembed");
    sim.op(S_LOW, cost.elementwise_us(b * 50257 * 4), &[um], "metric")
}

/// Steady-state per-edge time under an ideal work-conserving pipeline:
/// consecutive edge evaluations are independent forwards, so sustained
/// throughput is bounded by the busiest stream's per-edge work — the
/// in-order FIFO of a single simulated window would understate the
/// overlap a real multi-edge-in-flight scheduler (the paper's batched
/// evaluation) achieves. We simulate one edge eval per investigated layer
/// to collect per-stream busy time, average over layers, and take the
/// max-stream bound. The returned [`Sim`] (last layer's) also provides
/// the latency/timeline view used by scheduler_demo.
pub fn per_edge_us(
    arch: &RealArch,
    cost: &CostModel,
    method: MethodKind,
    cfg: StreamConfig,
) -> (f64, Sim) {
    let mut busy = [0.0f64; 3];
    let mut last_sim = Sim::new(3);
    let n = arch.n_layer.min(8);
    for i in 0..n {
        let l_star = Some((i * arch.n_layer) / n);
        let mut sim = Sim::new(3);
        edge_eval(&mut sim, arch, cost, method, cfg, l_star);
        for s in 0..3 {
            busy[s] += sim.utilization(StreamId(s)) * sim.makespan();
        }
        last_sim = sim;
    }
    let steady = busy.iter().copied().fold(0.0, f64::max) / n as f64;
    (steady, last_sim)
}

/// Predict a full circuit-discovery run (one exhaustive sweep).
pub fn predict_run(
    arch: &RealArch,
    cost: &CostModel,
    method: MethodKind,
    cfg: StreamConfig,
) -> RunPrediction {
    let (per_edge, sim) = per_edge_us(arch, cost, method, cfg);
    let n_edges = arch.n_edges();
    let total_us = per_edge * n_edges as f64;
    RunPrediction {
        method: format!("{method:?}"),
        per_edge_us: per_edge,
        n_edges,
        total_minutes: total_us / 60e6,
        load_utilization: sim.utilization(S_LOAD),
        low_utilization: sim.utilization(S_LOW),
    }
}

/// Prediction of a full sweep under an `acdc::SweepMode` schedule.
#[derive(Clone, Debug)]
pub struct SweepPrediction {
    pub mode: SweepMode,
    pub n_edges: usize,
    /// scored evaluations / decisions (1.0 = no speculation waste)
    pub eval_inflation: f64,
    pub serial_minutes: f64,
    pub total_minutes: f64,
    pub speedup: f64,
}

/// Predict a sweep under a [`SweepMode`]: `Batched { workers }` models
/// the branch-predicted speculative batching of `acdc::sweep` running on
/// `workers` engine replicas. With window `B = 2·workers` and predictor
/// miss rate `q = min(p, 1−p)` for removal rate `p`, expected eval
/// inflation is `1 + q·(B−1)/2` and throughput scales by `workers`, so
/// predicted time is `serial · inflation / workers` (never better than
/// the one-round-per-decision critical path).
pub fn predict_sweep(
    arch: &RealArch,
    cost: &CostModel,
    method: MethodKind,
    cfg: StreamConfig,
    mode: SweepMode,
    removal_rate: f64,
) -> SweepPrediction {
    let base = predict_run(arch, cost, method, cfg);
    let serial_minutes = base.total_minutes;
    match mode {
        SweepMode::Serial => SweepPrediction {
            mode,
            n_edges: base.n_edges,
            eval_inflation: 1.0,
            serial_minutes,
            total_minutes: serial_minutes,
            speedup: 1.0,
        },
        SweepMode::Batched { workers } => {
            let w = workers.max(1) as f64;
            let p = removal_rate.clamp(0.0, 1.0);
            let q = p.min(1.0 - p);
            let window = 2.0 * w;
            let inflation = 1.0 + q * (window - 1.0) / 2.0;
            // workers scale throughput; a misprediction-free decision
            // chain still needs >= one batch round per window
            let total_minutes = serial_minutes * inflation / w;
            SweepPrediction {
                mode,
                n_edges: base.n_edges,
                eval_inflation: inflation,
                serial_minutes,
                total_minutes,
                speedup: serial_minutes / total_minutes,
            }
        }
    }
}

/// Greedy list-scheduling makespan of a matrix grid on `workers`
/// work-stealing cell workers: longest cell first, each onto the
/// least-loaded worker — the standard LPT bound for the `pahq matrix`
/// job queue. Returns minutes when fed minutes.
pub fn predict_matrix_wall(cell_minutes: &[f64], workers: usize) -> f64 {
    let mut loads = vec![0.0f64; workers.max(1)];
    let mut cells: Vec<f64> = cell_minutes.to_vec();
    cells.sort_by(|a, b| b.total_cmp(a));
    for c in cells {
        let i = loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .expect("workers >= 1");
        loads[i] += c;
    }
    loads.into_iter().fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpt2() -> RealArch {
        RealArch::by_name("gpt2").unwrap()
    }

    #[test]
    fn matrix_wall_is_lpt_makespan() {
        assert_eq!(predict_matrix_wall(&[], 4), 0.0);
        // one worker: the sum
        let cells = [3.0, 1.0, 2.0, 2.0];
        assert!((predict_matrix_wall(&cells, 1) - 8.0).abs() < 1e-12);
        // many workers: the longest cell dominates
        assert!((predict_matrix_wall(&cells, 8) - 3.0).abs() < 1e-12);
        // in between: bounded by both
        let two = predict_matrix_wall(&cells, 2);
        assert!(two >= 4.0 - 1e-12 && two <= 8.0, "makespan {two}");
        // LPT on this instance is optimal: {3,1} and {2,2}
        assert!((two - 4.0).abs() < 1e-12);
        // workers = 0 clamps to 1
        assert!((predict_matrix_wall(&cells, 0) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn tab3_runtime_ordering() {
        // paper Tab. 3: ACDC 99:18 >> RTN-Q 27:47 > PAHQ 20:36
        let c = CostModel::default();
        let acdc = predict_run(&gpt2(), &c, MethodKind::AcdcFp32, StreamConfig::NONE);
        let rtn = predict_run(&gpt2(), &c, MethodKind::RtnQ, StreamConfig::NONE);
        let pahq = predict_run(&gpt2(), &c, MethodKind::Pahq, StreamConfig::FULL);
        assert!(
            acdc.total_minutes > 2.0 * rtn.total_minutes,
            "ACDC {:.1}m vs RTN {:.1}m",
            acdc.total_minutes,
            rtn.total_minutes
        );
        assert!(
            pahq.total_minutes < rtn.total_minutes,
            "PAHQ {:.1}m vs RTN {:.1}m",
            pahq.total_minutes,
            rtn.total_minutes
        );
        // headline: PAHQ cuts ≳ 70% vs ACDC (paper ~80%)
        let cut = 1.0 - pahq.total_minutes / acdc.total_minutes;
        assert!(cut > 0.6, "runtime cut {cut:.2}");
    }

    #[test]
    fn tab4_ablation_ordering() {
        // paper Tab. 4: full(20) < load-only(49) < split-only(72) < none(94)
        let c = CostModel::default();
        let t = |cfg| predict_run(&gpt2(), &c, MethodKind::Pahq, cfg).total_minutes;
        let full = t(StreamConfig::FULL);
        let load_only = t(StreamConfig::LOAD_ONLY);
        let split_only = t(StreamConfig::SPLIT_ONLY);
        let none = t(StreamConfig::NONE);
        assert!(full < load_only, "full {full:.1} < load-only {load_only:.1}");
        assert!(
            load_only < split_only,
            "load-only {load_only:.1} < split-only {split_only:.1} (weight loading \
             outweighs high-precision compute, paper's Tab. 4 discussion)"
        );
        assert!(split_only < none, "split-only {split_only:.1} < none {none:.1}");
        assert!(none / full > 1.5, "scheduler wins {:.2}x", none / full);
    }

    #[test]
    fn tab4_ordering_robust_to_constants() {
        // DESIGN.md §8: the ablation ordering survives ±2x on every constant
        let base = CostModel::default();
        for k in 0..5 {
            for mult in [0.5, 2.0] {
                let mut c = base.clone();
                match k {
                    0 => c.tflops_fp8 *= mult,
                    1 => c.launch_us *= mult,
                    2 => c.pcie_gbps *= mult,
                    3 => c.chunk_us *= mult,
                    _ => c.ew_gbps *= mult,
                }
                let t = |cfg| predict_run(&gpt2(), &c, MethodKind::Pahq, cfg).total_minutes;
                let (full, none) = (t(StreamConfig::FULL), t(StreamConfig::NONE));
                assert!(full < none, "const {k} x{mult}: {full:.1} !< {none:.1}");
            }
        }
    }

    #[test]
    fn transfer_is_masked_when_load_stream_on() {
        let c = CostModel::default();
        let (full, sim) = per_edge_us(&gpt2(), &c, MethodKind::Pahq, StreamConfig::FULL);
        // load stream busy but not the bottleneck
        assert!(sim.utilization(S_LOAD) > 0.0);
        let (none, _) = per_edge_us(&gpt2(), &c, MethodKind::Pahq, StreamConfig::NONE);
        assert!(none > full);
    }

    #[test]
    fn sweep_prediction_scales_with_workers() {
        let c = CostModel::default();
        let arch = gpt2();
        let serial = predict_sweep(
            &arch,
            &c,
            MethodKind::Pahq,
            StreamConfig::FULL,
            SweepMode::Serial,
            0.9,
        );
        let run = predict_run(&arch, &c, MethodKind::Pahq, StreamConfig::FULL);
        assert!((serial.total_minutes - run.total_minutes).abs() < 1e-9);
        assert_eq!(serial.speedup, 1.0);

        let mut prev = serial.total_minutes;
        for workers in [2usize, 4, 8] {
            let p = predict_sweep(
                &arch,
                &c,
                MethodKind::Pahq,
                StreamConfig::FULL,
                SweepMode::Batched { workers },
                0.9,
            );
            assert!(p.eval_inflation >= 1.0);
            assert!(p.speedup <= workers as f64, "speedup bounded by workers");
            assert!(p.total_minutes < prev, "more workers, less time");
            prev = p.total_minutes;
        }
        // a well-predicted sweep at 4 workers is a clear win
        let p4 = predict_sweep(
            &arch,
            &c,
            MethodKind::Pahq,
            StreamConfig::FULL,
            SweepMode::Batched { workers: 4 },
            0.9,
        );
        assert!(p4.speedup > 2.0, "speedup {:.2}", p4.speedup);
    }

    #[test]
    fn scale_series_gets_slower() {
        let c = CostModel::default();
        let t = |n: &str| {
            predict_run(&RealArch::by_name(n).unwrap(), &c, MethodKind::Pahq, StreamConfig::FULL)
                .total_minutes
        };
        assert!(t("gpt2") < t("gpt2-medium"));
        assert!(t("gpt2-medium") < t("gpt2-large"));
    }
}
