//! The `pahq serve` wire protocol: length-prefixed, versioned,
//! checksummed frames carrying JSON message payloads.
//!
//! Documented normatively in `docs/serve_protocol.md`, with the payload
//! shapes mirrored by `docs/serve_protocol.schema.json` (validated by
//! `scripts/check_schema.py`). Bump [`PROTOCOL_VERSION`] on any frame
//! or message shape change and update both documents in the same
//! commit.
//!
//! A frame is a 20-byte header followed by the payload bytes:
//!
//! ```text
//! offset  size  field
//! 0       4     magic "PQWF"
//! 4       2     protocol version, little-endian u16 (currently 1)
//! 6       2     reserved, must be zero
//! 8       4     payload length, little-endian u32 (<= MAX_PAYLOAD)
//! 12      8     FNV-1a-64 checksum of the payload, little-endian u64
//! 20      N     payload: UTF-8 JSON object with a "type" key
//! ```
//!
//! [`decode`] is incremental: it distinguishes *incomplete* input (need
//! more bytes — `Ok(None)`) from *corrupt* input (bad magic / version /
//! reserved bits / oversized length / checksum mismatch / malformed
//! JSON — an error, after which the peer must drop the connection,
//! since byte alignment is lost). Both directions use the same codec.
//!
//! ```
//! use pahq::serve::protocol::{decode, encode, Message};
//!
//! # fn main() -> anyhow::Result<()> {
//! let bytes = encode(&Message::Hello { protocol: 1 })?;
//! let (msg, used) = decode(&bytes)?.expect("complete frame");
//! assert_eq!(used, bytes.len());
//! assert_eq!(msg.to_json().dump(), Message::Hello { protocol: 1 }.to_json().dump());
//! assert!(decode(&bytes[..bytes.len() - 1])?.is_none(), "truncated = incomplete");
//! # Ok(())
//! # }
//! ```

use anyhow::{bail, Result};

use crate::api::{MatrixSpec, RunSpec};
use crate::discovery;
use crate::util::json::{obj, Json};

/// Frame magic: the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"PQWF";
/// Version of the frame layout AND the message payload shapes.
pub const PROTOCOL_VERSION: u16 = 1;
/// Fixed frame-header length in bytes.
pub const HEADER_LEN: usize = 20;
/// Hard ceiling on a frame's payload size. A length field beyond this
/// is corrupt by definition — a reader never buffers unbounded input on
/// the promise of a forged header.
pub const MAX_PAYLOAD: usize = 16 << 20;

/// FNV-1a-64 over raw bytes — the frame checksum (the byte-level analog
/// of the artifact store's key hash).
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Stable numeric error codes carried by [`Message::Error`] frames.
/// Codes are part of the protocol contract (`docs/serve_protocol.md`);
/// never renumber — add.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Frame-level corruption; the server closes the connection.
    BadFrame = 1,
    /// Payload parsed as JSON but is not a well-formed message.
    BadMessage = 2,
    /// A submitted spec failed validation (message names the field).
    InvalidSpec = 3,
    /// `cancel` named a job this connection does not own.
    UnknownJob = 4,
    /// Session state-machine violation (e.g. submit before hello, or a
    /// hello with an unsupported protocol version).
    Protocol = 5,
    /// Unexpected server-side failure.
    Internal = 6,
    /// Submission refused because the server is shutting down.
    ShuttingDown = 7,
}

impl ErrorCode {
    pub fn code(self) -> u32 {
        self as u32
    }

    pub fn from_code(code: u32) -> Result<ErrorCode> {
        Ok(match code {
            1 => ErrorCode::BadFrame,
            2 => ErrorCode::BadMessage,
            3 => ErrorCode::InvalidSpec,
            4 => ErrorCode::UnknownJob,
            5 => ErrorCode::Protocol,
            6 => ErrorCode::Internal,
            7 => ErrorCode::ShuttingDown,
            other => bail!("unknown error code {other}"),
        })
    }
}

/// Every message the protocol carries, both directions. The `type` key
/// of the JSON payload selects the variant; [`Message::to_json`] /
/// [`Message::from_json`] are the one (de)serialization path, so the
/// docs, the schema, and the codec cannot drift apart.
#[derive(Clone)]
pub enum Message {
    // ---- client -> server ------------------------------------------------
    /// Mandatory first message of a session.
    Hello { protocol: u16 },
    /// Submit one discovery run ([`RunSpec::to_wire`] payload).
    SubmitRun { spec: RunSpec },
    /// Submit a method x policy x model x task grid
    /// ([`MatrixSpec::to_wire`] payload); one record streams per cell.
    SubmitMatrix { spec: MatrixSpec },
    /// Stop a job's queued cells (in-flight cells finish and still
    /// stream their records).
    Cancel { job_id: u64 },
    /// Ask the server to stop accepting, drain, and exit.
    Shutdown,
    // ---- server -> client ------------------------------------------------
    /// Handshake reply: the server's protocol and RunRecord schema
    /// versions.
    HelloAck { protocol: u16, record_schema: usize },
    /// A submission was decomposed into `cells` queued jobs under
    /// `job_id`.
    Accepted { job_id: u64, cells: usize },
    /// Per-cell progress. Lossy by contract: a slow reader gets later
    /// frames with `coalesced` counting the superseded ones (see
    /// `docs/serve_protocol.md` § Backpressure).
    Progress { job_id: u64, done: usize, total: usize, cell: String, coalesced: usize },
    /// One completed cell's RunRecord (verbatim `run_record` JSON).
    Record { job_id: u64, cell: String, record: Json },
    /// One cell failed; the rest of the job keeps running.
    CellError { job_id: u64, cell: String, error: String },
    /// Acknowledges a `cancel`: `dropped` cells were still queued and
    /// will be skipped.
    CancelAck { job_id: u64, dropped: usize },
    /// Terminal per-job frame: every cell accounted for.
    Done { job_id: u64, ok: usize, failed: usize, cancelled: usize },
    /// Protocol- or submission-level error (see [`ErrorCode`]).
    Error { code: ErrorCode, message: String },
    /// Acknowledges a `shutdown`; the connection then closes.
    ShutdownAck,
}

impl Message {
    /// The payload's `type` discriminator.
    pub fn kind(&self) -> &'static str {
        match self {
            Message::Hello { .. } => "hello",
            Message::SubmitRun { .. } => "submit_run",
            Message::SubmitMatrix { .. } => "submit_matrix",
            Message::Cancel { .. } => "cancel",
            Message::Shutdown => "shutdown",
            Message::HelloAck { .. } => "hello_ack",
            Message::Accepted { .. } => "accepted",
            Message::Progress { .. } => "progress",
            Message::Record { .. } => "record",
            Message::CellError { .. } => "cell_error",
            Message::CancelAck { .. } => "cancel_ack",
            Message::Done { .. } => "done",
            Message::Error { .. } => "error",
            Message::ShutdownAck => "shutdown_ack",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("type", Json::from(self.kind()))];
        match self {
            Message::Hello { protocol } => {
                pairs.push(("protocol", Json::from(*protocol as usize)));
            }
            Message::SubmitRun { spec } => pairs.push(("spec", spec.to_wire())),
            Message::SubmitMatrix { spec } => pairs.push(("spec", spec.to_wire())),
            Message::Cancel { job_id } => pairs.push(("job_id", Json::from(*job_id as usize))),
            Message::Shutdown | Message::ShutdownAck => {}
            Message::HelloAck { protocol, record_schema } => {
                pairs.push(("protocol", Json::from(*protocol as usize)));
                pairs.push(("record_schema", Json::from(*record_schema)));
            }
            Message::Accepted { job_id, cells } => {
                pairs.push(("job_id", Json::from(*job_id as usize)));
                pairs.push(("cells", Json::from(*cells)));
            }
            Message::Progress { job_id, done, total, cell, coalesced } => {
                pairs.push(("job_id", Json::from(*job_id as usize)));
                pairs.push(("done", Json::from(*done)));
                pairs.push(("total", Json::from(*total)));
                pairs.push(("cell", Json::from(cell.clone())));
                pairs.push(("coalesced", Json::from(*coalesced)));
            }
            Message::Record { job_id, cell, record } => {
                pairs.push(("job_id", Json::from(*job_id as usize)));
                pairs.push(("cell", Json::from(cell.clone())));
                pairs.push(("record", record.clone()));
            }
            Message::CellError { job_id, cell, error } => {
                pairs.push(("job_id", Json::from(*job_id as usize)));
                pairs.push(("cell", Json::from(cell.clone())));
                pairs.push(("error", Json::from(error.clone())));
            }
            Message::CancelAck { job_id, dropped } => {
                pairs.push(("job_id", Json::from(*job_id as usize)));
                pairs.push(("dropped", Json::from(*dropped)));
            }
            Message::Done { job_id, ok, failed, cancelled } => {
                pairs.push(("job_id", Json::from(*job_id as usize)));
                pairs.push(("ok", Json::from(*ok)));
                pairs.push(("failed", Json::from(*failed)));
                pairs.push(("cancelled", Json::from(*cancelled)));
            }
            Message::Error { code, message } => {
                pairs.push(("code", Json::from(code.code() as usize)));
                pairs.push(("message", Json::from(message.clone())));
            }
        }
        obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Message> {
        let job_id = |j: &Json| -> Result<u64> { Ok(j.get("job_id")?.as_f64()? as u64) };
        Ok(match j.get("type")?.as_str()? {
            "hello" => Message::Hello { protocol: j.get("protocol")?.as_usize()? as u16 },
            "submit_run" => Message::SubmitRun { spec: RunSpec::from_wire(j.get("spec")?)? },
            "submit_matrix" => {
                Message::SubmitMatrix { spec: MatrixSpec::from_wire(j.get("spec")?)? }
            }
            "cancel" => Message::Cancel { job_id: job_id(j)? },
            "shutdown" => Message::Shutdown,
            "hello_ack" => Message::HelloAck {
                protocol: j.get("protocol")?.as_usize()? as u16,
                record_schema: j.get("record_schema")?.as_usize()?,
            },
            "accepted" => {
                Message::Accepted { job_id: job_id(j)?, cells: j.get("cells")?.as_usize()? }
            }
            "progress" => Message::Progress {
                job_id: job_id(j)?,
                done: j.get("done")?.as_usize()?,
                total: j.get("total")?.as_usize()?,
                cell: j.get("cell")?.as_str()?.to_string(),
                coalesced: j.get("coalesced")?.as_usize()?,
            },
            "record" => Message::Record {
                job_id: job_id(j)?,
                cell: j.get("cell")?.as_str()?.to_string(),
                record: j.get("record")?.clone(),
            },
            "cell_error" => Message::CellError {
                job_id: job_id(j)?,
                cell: j.get("cell")?.as_str()?.to_string(),
                error: j.get("error")?.as_str()?.to_string(),
            },
            "cancel_ack" => {
                Message::CancelAck { job_id: job_id(j)?, dropped: j.get("dropped")?.as_usize()? }
            }
            "done" => Message::Done {
                job_id: job_id(j)?,
                ok: j.get("ok")?.as_usize()?,
                failed: j.get("failed")?.as_usize()?,
                cancelled: j.get("cancelled")?.as_usize()?,
            },
            "error" => Message::Error {
                code: ErrorCode::from_code(j.get("code")?.as_usize()? as u32)?,
                message: j.get("message")?.as_str()?.to_string(),
            },
            "shutdown_ack" => Message::ShutdownAck,
            other => bail!("unknown message type '{other}'"),
        })
    }
}

/// A [`Message::HelloAck`] for this build.
pub fn hello_ack() -> Message {
    Message::HelloAck { protocol: PROTOCOL_VERSION, record_schema: discovery::SCHEMA_VERSION }
}

/// Encode one message as a complete frame (header + JSON payload).
pub fn encode(msg: &Message) -> Result<Vec<u8>> {
    encode_payload(msg.to_json().dump().as_bytes())
}

/// Frame arbitrary payload bytes — split from [`encode`] so tests can
/// construct frames with payloads the message layer would never emit.
pub fn encode_payload(payload: &[u8]) -> Result<Vec<u8>> {
    if payload.len() > MAX_PAYLOAD {
        bail!("frame payload {} bytes exceeds MAX_PAYLOAD {MAX_PAYLOAD}", payload.len());
    }
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    out.extend_from_slice(&[0, 0]);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&checksum(payload).to_le_bytes());
    out.extend_from_slice(payload);
    Ok(out)
}

/// Try to decode one frame from the front of `buf`.
///
/// - `Ok(None)` — `buf` holds a valid prefix of a frame; read more.
/// - `Ok(Some((msg, consumed)))` — one complete frame; the caller
///   drops `consumed` bytes and decodes again.
/// - `Err(_)` — corrupt input (bad magic / version / reserved bits /
///   oversized length / checksum mismatch / malformed payload). Byte
///   alignment is lost; the connection must be dropped.
pub fn decode(buf: &[u8]) -> Result<Option<(Message, usize)>> {
    match decode_payload(buf)? {
        None => Ok(None),
        Some((payload, consumed)) => {
            let text = std::str::from_utf8(payload)
                .map_err(|e| anyhow::anyhow!("frame payload is not UTF-8: {e}"))?;
            let msg = Message::from_json(&Json::parse(text)?)?;
            Ok(Some((msg, consumed)))
        }
    }
}

/// The frame-layer half of [`decode`]: validate the header and checksum
/// and return the raw payload slice, without interpreting it.
pub fn decode_payload(buf: &[u8]) -> Result<Option<(&[u8], usize)>> {
    // validate the fixed fields as soon as their bytes are present —
    // garbage is rejected without waiting for a full (forged) length
    if !buf.is_empty() && buf[..MAGIC.len().min(buf.len())] != MAGIC[..MAGIC.len().min(buf.len())]
    {
        bail!("bad frame magic (expected {:?})", MAGIC);
    }
    if buf.len() >= 6 {
        let version = u16::from_le_bytes([buf[4], buf[5]]);
        if version != PROTOCOL_VERSION {
            bail!("unsupported protocol version {version} (this build speaks {PROTOCOL_VERSION})");
        }
    }
    if buf.len() >= 8 && (buf[6] != 0 || buf[7] != 0) {
        bail!("nonzero reserved bytes in frame header");
    }
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    let len = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize;
    if len > MAX_PAYLOAD {
        bail!("frame payload length {len} exceeds MAX_PAYLOAD {MAX_PAYLOAD}");
    }
    if buf.len() < HEADER_LEN + len {
        return Ok(None);
    }
    let expect = u64::from_le_bytes([
        buf[12], buf[13], buf[14], buf[15], buf[16], buf[17], buf[18], buf[19],
    ]);
    let payload = &buf[HEADER_LEN..HEADER_LEN + len];
    let got = checksum(payload);
    if got != expect {
        bail!("frame checksum mismatch (header {expect:016x}, payload {got:016x})");
    }
    Ok(Some((payload, HEADER_LEN + len)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checksum_matches_fnv1a_vectors() {
        assert_eq!(checksum(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(checksum(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(checksum(b"ab"), checksum(b"ba"));
    }

    #[test]
    fn error_codes_round_trip() {
        for code in [
            ErrorCode::BadFrame,
            ErrorCode::BadMessage,
            ErrorCode::InvalidSpec,
            ErrorCode::UnknownJob,
            ErrorCode::Protocol,
            ErrorCode::Internal,
            ErrorCode::ShuttingDown,
        ] {
            assert_eq!(ErrorCode::from_code(code.code()).unwrap(), code);
        }
        assert!(ErrorCode::from_code(0).is_err());
        assert!(ErrorCode::from_code(99).is_err());
    }

    #[test]
    fn two_frames_back_to_back_decode_in_order() {
        let mut buf = encode(&Message::Hello { protocol: PROTOCOL_VERSION }).unwrap();
        buf.extend(encode(&Message::ShutdownAck).unwrap());
        let (first, used) = decode(&buf).unwrap().unwrap();
        assert_eq!(first.kind(), "hello");
        let (second, used2) = decode(&buf[used..]).unwrap().unwrap();
        assert_eq!(second.kind(), "shutdown_ack");
        assert_eq!(used + used2, buf.len());
    }

    #[test]
    fn unknown_message_type_is_an_error_not_a_panic() {
        let frame = encode_payload(br#"{"type":"frobnicate"}"#).unwrap();
        assert!(decode(&frame).is_err());
        let frame = encode_payload(br#"[1,2,3]"#).unwrap();
        assert!(decode(&frame).is_err());
        let frame = encode_payload(&[0xff, 0xfe]).unwrap(); // not UTF-8
        assert!(decode(&frame).is_err());
    }
}
