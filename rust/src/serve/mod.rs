//! `pahq serve` — the multi-client discovery daemon.
//!
//! The ROADMAP's service north-star, concretely: a long-running TCP
//! daemon that keeps one [`ArtifactCache`](crate::matrix::cache) hot
//! across requests (corrupt caches, FP32 attribution scores, disk
//! artifacts), so a second submission touching the same (task, policy)
//! pays cache-hit prices instead of cold-starting a whole session. The
//! daemon is std-only — `std::net` TCP plus `std::thread` — consistent
//! with the repo's offline/vendored-dependency constraint.
//!
//! Three layers, one per module:
//!
//! - [`protocol`] — the wire format: length-prefixed, versioned,
//!   checksummed frames whose JSON payloads carry [`Message`] variants.
//!   `docs/serve_protocol.md` is the normative spec;
//!   `docs/serve_protocol.schema.json` mirrors the payload shapes and
//!   CI validates every frame of a live smoke run against it.
//! - [`session`] — per-connection plumbing: the bounded [`Outbound`]
//!   frame queue (slow readers exert backpressure on workers for
//!   record/error frames, while progress frames coalesce latest-wins),
//!   and the incremental [`FrameReader`].
//! - [`server`] — the daemon itself: accept loop, the session state
//!   machine (`hello` → submit → progress/record stream → `done`),
//!   per-job cooperative cancellation, and a worker pool draining one
//!   shared [`WorkQueue`](crate::matrix::queue::WorkQueue) across all
//!   clients. Cells execute through
//!   [`api::run_with_cache`](crate::api), the same body as standalone
//!   [`api::run`](crate::api::run), so streamed records are
//!   bit-identical to what the CLI would produce for the same spec.
//!
//! Quick start (see README § Serving and `examples/serve_client.rs`):
//!
//! ```text
//! pahq serve --addr 127.0.0.1:7341 --workers 4 --store disk
//! cargo run --release --example serve_client -- 127.0.0.1:7341
//! ```

pub mod protocol;
pub mod server;
pub mod session;

pub use protocol::{ErrorCode, Message, PROTOCOL_VERSION};
pub use server::{serve, DrainReport, ServeConfig, Server};
pub use session::{DeliveryStats, FrameReader, Outbound, ReadEvent};
