//! The `pahq serve` daemon: accept loop, session state machine, and the
//! shared worker pool that drains every client's cells from one queue.
//!
//! One [`Server`] owns one [`ArtifactCache`] (fronting the configured
//! [`StoreSpec`] backend) and one [`WorkQueue`] of cell jobs. Every
//! accepted connection runs a reader thread (frame decode + the session
//! state machine) and a writer thread (draining that connection's
//! bounded [`Outbound`]); submissions decompose into per-cell
//! [`RunSpec`]s that workers execute via `api::run_with_cache` — the
//! same body as standalone [`api::run`](crate::api::run), so streamed
//! records are bit-identical to it, with the daemon's cache staying hot
//! across requests. Cancellation is cooperative: a cancelled job's
//! queued cells are skipped when a worker pops them, in-flight cells
//! finish and still stream their record, and the terminal `done` frame
//! accounts for every cell either way.

use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::protocol::{self, ErrorCode, Message, PROTOCOL_VERSION};
use super::session::{DeliveryStats, FrameReader, Outbound, ReadEvent};
use crate::api::{self, RunSpec, StoreSpec};
use crate::matrix::cache::ArtifactCache;
use crate::matrix::queue::WorkQueue;
use crate::util::sync::lock_recover;

/// How often blocked reads wake up to poll the shutdown flag.
const READ_POLL: Duration = Duration::from_millis(200);

/// Daemon configuration (the `pahq serve` flags).
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (tests do this).
    pub addr: String,
    /// Worker threads draining the shared cell queue.
    pub workers: usize,
    /// Artifact-store backend shared across every request.
    pub store: StoreSpec,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { addr: "127.0.0.1:7341".into(), workers: 2, store: StoreSpec::Memory }
    }
}

/// One queued cell: the unit the worker pool executes.
struct CellJob {
    job_id: u64,
    cell: String,
    spec: RunSpec,
}

/// Server-side accounting for one accepted submission.
struct JobState {
    /// The submitting connection's outbound queue.
    out: Arc<Outbound>,
    total: usize,
    cancelled: AtomicBool,
    /// Cells a worker has begun executing (cancel cannot stop these).
    started: AtomicUsize,
    /// Cells fully accounted for (record, error, or skipped-by-cancel).
    done: AtomicUsize,
    ok: AtomicUsize,
    failed: AtomicUsize,
    skipped: AtomicUsize,
}

struct Shared {
    queue: WorkQueue<CellJob>,
    jobs: Mutex<HashMap<u64, Arc<JobState>>>,
    cache: ArtifactCache,
    /// The bound address — a shutdown self-connects here to unblock the
    /// accept loop.
    addr: SocketAddr,
    next_job: AtomicU64,
    /// Stop accepting connections and refuse new submissions.
    shutdown: AtomicBool,
    /// Backlog drained, outbounds closed — reader threads may exit.
    halt: AtomicBool,
    /// Lifetime totals of retired jobs/cells (the drain report).
    jobs_retired: AtomicUsize,
    cells_ok: AtomicUsize,
    cells_failed: AtomicUsize,
    cells_cancelled: AtomicUsize,
}

/// What a daemon did over its lifetime, returned by [`Server::run`]
/// after a graceful drain: every retired job and cell accounted for,
/// plus delivery stats merged across all connections. `pahq serve`
/// prints it on exit; the load harness smoke path asserts a clean one.
#[derive(Clone, Copy, Debug, Default)]
pub struct DrainReport {
    /// Jobs accepted and fully retired (terminal `done` emitted).
    pub jobs: usize,
    pub cells_ok: usize,
    pub cells_failed: usize,
    pub cells_cancelled: usize,
    /// Connections accepted over the daemon's lifetime.
    pub connections: usize,
    /// Frame/progress delivery accounting summed across connections.
    pub delivery: DeliveryStats,
}

/// A bound-but-not-yet-running daemon. [`Server::bind`] then
/// [`Server::run`]; tests grab [`Server::local_addr`] in between.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
    workers: usize,
}

/// Bind and run a daemon until a client sends `shutdown` — the
/// `pahq serve` entry point.
pub fn serve(cfg: ServeConfig) -> Result<()> {
    let server = Server::bind(cfg)?;
    println!("serve: listening on {}", server.local_addr()?);
    let report = server.run()?;
    println!(
        "serve: drained {} job(s) — {} ok / {} failed / {} cancelled cell(s) \
         across {} connection(s)",
        report.jobs,
        report.cells_ok,
        report.cells_failed,
        report.cells_cancelled,
        report.connections,
    );
    println!(
        "serve: delivered {} frame(s) + {} progress snapshot(s) ({} coalesced), \
         max queue delay {:.1}ms",
        report.delivery.frames_sent,
        report.delivery.progress_sent,
        report.delivery.progress_coalesced,
        report.delivery.queued_max.as_secs_f64() * 1000.0,
    );
    Ok(())
}

impl Server {
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("serve: cannot bind {}", cfg.addr))?;
        let cache = crate::matrix::open_cache(&cfg.store, false)?;
        let addr = listener.local_addr()?;
        Ok(Server {
            listener,
            shared: Arc::new(Shared {
                queue: WorkQueue::new(),
                jobs: Mutex::new(HashMap::new()),
                cache,
                addr,
                next_job: AtomicU64::new(1),
                shutdown: AtomicBool::new(false),
                halt: AtomicBool::new(false),
                jobs_retired: AtomicUsize::new(0),
                cells_ok: AtomicUsize::new(0),
                cells_failed: AtomicUsize::new(0),
                cells_cancelled: AtomicUsize::new(0),
            }),
            workers: cfg.workers.max(1),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept clients and drain work until a `shutdown` frame arrives;
    /// then stop accepting, finish the queued backlog, flush every
    /// connection, and return this daemon's [`DrainReport`]. Blocks the
    /// calling thread.
    pub fn run(self) -> Result<DrainReport> {
        let shared = self.shared;
        let mut conns: Vec<Arc<Outbound>> = Vec::new();
        std::thread::scope(|scope| -> Result<()> {
            for _ in 0..self.workers {
                let shared = Arc::clone(&shared);
                scope.spawn(move || worker_loop(&shared));
            }
            for stream in self.listener.incoming() {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break; // the waking connection (or a late client) is dropped
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                let out = Arc::new(Outbound::new());
                conns.push(Arc::clone(&out));
                let shared = Arc::clone(&shared);
                scope.spawn(move || connection(stream, out, &shared));
            }
            // shutdown: refuse new cells, drain the backlog, then let
            // writers flush and readers notice the halt flag
            shared.queue.close();
            while !lock_recover(&shared.jobs).is_empty() {
                std::thread::sleep(Duration::from_millis(10));
            }
            for out in &conns {
                out.close();
            }
            shared.halt.store(true, Ordering::SeqCst);
            Ok(())
        })?;
        // the scope has joined every worker/reader/writer thread, so
        // the per-connection delivery stats are final
        let mut delivery = DeliveryStats::default();
        for out in &conns {
            delivery.merge(&out.delivery_stats());
        }
        Ok(DrainReport {
            jobs: shared.jobs_retired.load(Ordering::SeqCst),
            cells_ok: shared.cells_ok.load(Ordering::SeqCst),
            cells_failed: shared.cells_failed.load(Ordering::SeqCst),
            cells_cancelled: shared.cells_cancelled.load(Ordering::SeqCst),
            connections: conns.len(),
            delivery,
        })
    }
}

/// Unblock a `listener.incoming()` that is parked in `accept` after the
/// shutdown flag is set.
fn wake_accept(addr: SocketAddr) {
    let _ = TcpStream::connect(addr);
}

// ---------------------------------------------------------------------------
// Worker pool

fn worker_loop(shared: &Shared) {
    while let Some(job) = shared.queue.pop_wait() {
        let state = match lock_recover(&shared.jobs).get(&job.job_id) {
            Some(s) => Arc::clone(s),
            None => continue,
        };
        if state.cancelled.load(Ordering::SeqCst) {
            state.skipped.fetch_add(1, Ordering::SeqCst);
            finish_cell(shared, job.job_id, &state);
            continue;
        }
        state.started.fetch_add(1, Ordering::SeqCst);
        match api::run_with_cache(&job.spec, &shared.cache) {
            Ok((record, _session)) => {
                state.ok.fetch_add(1, Ordering::SeqCst);
                state.out.push_frame(Message::Record {
                    job_id: job.job_id,
                    cell: job.cell.clone(),
                    record: record.to_json(),
                });
            }
            Err(e) => {
                state.failed.fetch_add(1, Ordering::SeqCst);
                state.out.push_frame(Message::CellError {
                    job_id: job.job_id,
                    cell: job.cell.clone(),
                    error: format!("{e:#}"),
                });
            }
        }
        let done = finish_cell(shared, job.job_id, &state);
        if !done {
            state.out.push_progress(Message::Progress {
                job_id: job.job_id,
                done: state.done.load(Ordering::SeqCst),
                total: state.total,
                cell: job.cell,
                coalesced: 0,
            });
        }
    }
}

/// Account one cell; when it is the job's last, emit the terminal
/// `done` frame and retire the job. Returns whether the job finished.
fn finish_cell(shared: &Shared, job_id: u64, state: &JobState) -> bool {
    let done = state.done.fetch_add(1, Ordering::SeqCst) + 1;
    if done < state.total {
        return false;
    }
    lock_recover(&shared.jobs).remove(&job_id);
    let (ok, failed, cancelled) = (
        state.ok.load(Ordering::SeqCst),
        state.failed.load(Ordering::SeqCst),
        state.skipped.load(Ordering::SeqCst),
    );
    shared.jobs_retired.fetch_add(1, Ordering::SeqCst);
    shared.cells_ok.fetch_add(ok, Ordering::SeqCst);
    shared.cells_failed.fetch_add(failed, Ordering::SeqCst);
    shared.cells_cancelled.fetch_add(cancelled, Ordering::SeqCst);
    state.out.push_frame(Message::Done { job_id, ok, failed, cancelled });
    true
}

// ---------------------------------------------------------------------------
// Per-connection session

fn connection(stream: TcpStream, out: Arc<Outbound>, shared: &Shared) {
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_nodelay(true);
    let writer = {
        let out = Arc::clone(&out);
        let stream = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        std::thread::spawn(move || writer_loop(stream, &out))
    };
    let my_jobs = reader_loop(stream, &out, shared);
    // the client is gone (or the server halted): its queued cells are
    // dead weight — cancel so workers skip rather than compute into a
    // closed socket
    {
        let jobs = lock_recover(&shared.jobs);
        for id in my_jobs {
            if let Some(state) = jobs.get(&id) {
                state.cancelled.store(true, Ordering::SeqCst);
            }
        }
    }
    out.close();
    let _ = writer.join();
}

fn writer_loop(mut stream: TcpStream, out: &Outbound) {
    while let Some(msg) = out.pop() {
        let bytes = match protocol::encode(&msg) {
            Ok(b) => b,
            Err(_) => continue,
        };
        if stream.write_all(&bytes).is_err() {
            out.mark_dead();
            return;
        }
    }
    let _ = stream.flush();
}

/// The session state machine. Returns the job ids this connection
/// submitted (for disconnect cleanup).
fn reader_loop(mut stream: TcpStream, out: &Arc<Outbound>, shared: &Shared) -> Vec<u64> {
    let mut reader = FrameReader::new();
    let mut hello_done = false;
    let mut my_jobs = Vec::new();
    loop {
        match reader.next(&mut stream) {
            Ok(ReadEvent::Pending) => {
                if shared.halt.load(Ordering::SeqCst) {
                    return my_jobs;
                }
            }
            Ok(ReadEvent::Eof) => return my_jobs,
            Err(e) => {
                // corrupt frames lose byte alignment: best-effort error,
                // then drop the connection (docs/serve_protocol.md)
                out.push_frame(Message::Error {
                    code: ErrorCode::BadFrame,
                    message: format!("corrupt frame ({e:#}); closing connection"),
                });
                return my_jobs;
            }
            Ok(ReadEvent::Frame(msg)) => {
                if !session_step(msg, &mut hello_done, &mut my_jobs, out, shared) {
                    return my_jobs;
                }
            }
        }
    }
}

/// Handle one decoded client frame. Returns `false` when the session
/// must end (protocol violation or shutdown handshake).
fn session_step(
    msg: Message,
    hello_done: &mut bool,
    my_jobs: &mut Vec<u64>,
    out: &Arc<Outbound>,
    shared: &Shared,
) -> bool {
    let violation = |code: ErrorCode, message: String| {
        out.push_frame(Message::Error { code, message });
        false
    };
    match msg {
        Message::Hello { protocol } => {
            if protocol != PROTOCOL_VERSION {
                return violation(
                    ErrorCode::Protocol,
                    format!("protocol {protocol} unsupported (server speaks {PROTOCOL_VERSION})"),
                );
            }
            *hello_done = true;
            out.push_frame(protocol::hello_ack());
            true
        }
        Message::SubmitRun { .. } | Message::SubmitMatrix { .. } | Message::Cancel { .. }
            if !*hello_done =>
        {
            violation(ErrorCode::Protocol, "hello required before any other message".into())
        }
        Message::SubmitRun { spec } => {
            submit(vec![(cell_label(&spec), spec)], my_jobs, out, shared);
            true
        }
        Message::SubmitMatrix { spec } => {
            match api::matrix_cells(&spec) {
                Ok(cells) => submit(cells, my_jobs, out, shared),
                Err(e) => {
                    out.push_frame(Message::Error {
                        code: ErrorCode::InvalidSpec,
                        message: format!("{e:#}"),
                    });
                }
            }
            true
        }
        Message::Cancel { job_id } => {
            let owned = my_jobs.contains(&job_id);
            let state = lock_recover(&shared.jobs).get(&job_id).filter(|_| owned).cloned();
            match state {
                None => {
                    out.push_frame(Message::Error {
                        code: ErrorCode::UnknownJob,
                        message: format!("job {job_id} is not an active job of this connection"),
                    });
                }
                Some(state) => {
                    state.cancelled.store(true, Ordering::SeqCst);
                    let dropped = state
                        .total
                        .saturating_sub(state.started.load(Ordering::SeqCst))
                        .saturating_sub(state.skipped.load(Ordering::SeqCst));
                    out.push_frame(Message::CancelAck { job_id, dropped });
                }
            }
            true
        }
        Message::Shutdown => {
            out.push_frame(Message::ShutdownAck);
            shared.shutdown.store(true, Ordering::SeqCst);
            wake_accept(shared.addr);
            true
        }
        // server->client frames arriving at the server are a client bug
        other => violation(
            ErrorCode::BadMessage,
            format!("'{}' is a server-to-client message", other.kind()),
        ),
    }
}

/// Register a job for `cells` and enqueue them on the shared queue.
fn submit(
    cells: Vec<(String, RunSpec)>,
    my_jobs: &mut Vec<u64>,
    out: &Arc<Outbound>,
    shared: &Shared,
) {
    if shared.shutdown.load(Ordering::SeqCst) {
        out.push_frame(Message::Error {
            code: ErrorCode::ShuttingDown,
            message: "server is shutting down; submission refused".into(),
        });
        return;
    }
    let job_id = shared.next_job.fetch_add(1, Ordering::SeqCst);
    let state = Arc::new(JobState {
        out: Arc::clone(out),
        total: cells.len(),
        cancelled: AtomicBool::new(false),
        started: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        ok: AtomicUsize::new(0),
        failed: AtomicUsize::new(0),
        skipped: AtomicUsize::new(0),
    });
    lock_recover(&shared.jobs).insert(job_id, Arc::clone(&state));
    my_jobs.push(job_id);
    out.push_frame(Message::Accepted { job_id, cells: cells.len() });
    let mut accepted = true;
    for (cell, spec) in cells {
        accepted &= shared.queue.push(CellJob { job_id, cell, spec });
    }
    if !accepted {
        // shutdown raced the submit: cells refused by the closed queue
        // would leave the job forever unfinished — retire it as skipped
        let state2 = lock_recover(&shared.jobs).remove(&job_id);
        if let Some(state) = state2 {
            out.push_frame(Message::Done {
                job_id,
                ok: 0,
                failed: 0,
                cancelled: state.total,
            });
        }
    }
}

fn cell_label(spec: &RunSpec) -> String {
    format!(
        "{}_{}_{}_{}",
        spec.method.discovery_name(),
        spec.policy.name,
        spec.model,
        spec.task
    )
}

