//! Per-connection plumbing: the bounded outbound frame queue (with
//! progress coalescing) and the incremental frame reader.
//!
//! Every accepted connection gets one [`Outbound`] shared between the
//! worker pool (producers) and a dedicated writer thread (the one
//! consumer that owns the socket's write half). The queue is bounded:
//! non-progress frames block the producer when the client reads slowly
//! (backpressure — a worker stalls rather than the server buffering
//! records without limit), while progress frames never block and never
//! accumulate — at most one is pending per job, the latest winning,
//! with a `coalesced` counter telling the client how many snapshots it
//! skipped. `docs/serve_protocol.md` § Backpressure is the normative
//! statement of these semantics.

use std::collections::{BTreeMap, VecDeque};
use std::io::Read;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::protocol::{self, Message};
use crate::util::sync::{lock_recover, wait_recover};

/// Upper bound on queued non-progress frames per connection. Small on
/// purpose: records stream as they finish, so depth beyond a handful
/// only measures how far a slow reader has fallen behind.
pub const OUTBOUND_CAP: usize = 64;

/// Per-connection delivery accounting, snapshotted at drain time into
/// the server's [`super::server::DrainReport`]. Queue-delay fields
/// measure enqueue→dequeue residency; for a coalesced progress entry
/// the clock starts at the *oldest* superseded snapshot, so `queued_max`
/// bounds the staleness of any progress a client ever observed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeliveryStats {
    /// Guaranteed (record/ack/error/done) frames handed to the writer.
    pub frames_sent: u64,
    /// Progress snapshots handed to the writer.
    pub progress_sent: u64,
    /// Superseded progress snapshots absorbed by coalescing.
    pub progress_coalesced: u64,
    /// Summed queue residency across all delivered frames.
    pub queued_total: Duration,
    /// Worst single-frame queue residency.
    pub queued_max: Duration,
}

impl DeliveryStats {
    /// Fold another connection's stats in (drain-report aggregation).
    pub fn merge(&mut self, other: &DeliveryStats) {
        self.frames_sent += other.frames_sent;
        self.progress_sent += other.progress_sent;
        self.progress_coalesced += other.progress_coalesced;
        self.queued_total += other.queued_total;
        self.queued_max = self.queued_max.max(other.queued_max);
    }

    fn note(&mut self, queued: Duration) {
        self.queued_total += queued;
        self.queued_max = self.queued_max.max(queued);
    }
}

struct OutState {
    /// FIFO of record / error / ack frames — bounded at [`OUTBOUND_CAP`],
    /// each stamped at enqueue time so delivery delay is measurable.
    frames: VecDeque<(Message, Instant)>,
    /// At most one pending progress snapshot per job, latest wins; the
    /// stamp is the *earliest* undelivered snapshot's enqueue time.
    progress: BTreeMap<u64, (Message, Instant)>,
    /// Delivery accounting for this connection.
    stats: DeliveryStats,
    /// No more frames will be pushed; writer drains and exits.
    closed: bool,
    /// The socket broke; producers stop blocking and drop frames.
    dead: bool,
}

/// The bounded outbound side of one connection.
pub struct Outbound {
    state: Mutex<OutState>,
    /// Signalled when the writer frees queue space.
    space: Condvar,
    /// Signalled when a producer enqueues or the queue closes.
    ready: Condvar,
}

impl Default for Outbound {
    fn default() -> Self {
        Self::new()
    }
}

impl Outbound {
    pub fn new() -> Outbound {
        Outbound {
            state: Mutex::new(OutState {
                frames: VecDeque::new(),
                progress: BTreeMap::new(),
                stats: DeliveryStats::default(),
                closed: false,
                dead: false,
            }),
            space: Condvar::new(),
            ready: Condvar::new(),
        }
    }

    /// Enqueue a guaranteed-delivery frame, blocking while the queue is
    /// full (this is the backpressure edge: a slow client stalls the
    /// worker that finished its cell, not the whole server's memory).
    /// Returns `false` if the connection is closed or dead — the frame
    /// is dropped and the producer should stop caring about this client.
    pub fn push_frame(&self, msg: Message) -> bool {
        let mut st = lock_recover(&self.state);
        loop {
            if st.closed || st.dead {
                return false;
            }
            if st.frames.len() < OUTBOUND_CAP {
                st.frames.push_back((msg, Instant::now()));
                self.ready.notify_one();
                return true;
            }
            st = wait_recover(&self.space, st);
        }
    }

    /// Enqueue a progress snapshot. Never blocks: an undelivered
    /// snapshot for the same job is replaced, and the replacement's
    /// `coalesced` counter absorbs the superseded one's count plus one.
    pub fn push_progress(&self, msg: Message) {
        let Message::Progress { job_id, done, total, cell, coalesced } = msg else {
            debug_assert!(false, "push_progress takes Message::Progress");
            return;
        };
        let mut st = lock_recover(&self.state);
        if st.closed || st.dead {
            return;
        }
        // keep the oldest superseded snapshot's enqueue stamp: the
        // measured delay then bounds progress staleness, not just the
        // final snapshot's queue residency
        let (absorbed, since) = match st.progress.get(&job_id) {
            Some((Message::Progress { coalesced: prior, .. }, t0)) => (prior + 1, *t0),
            _ => (0, Instant::now()),
        };
        let coalesced = coalesced + absorbed;
        st.progress
            .insert(job_id, (Message::Progress { job_id, done, total, cell, coalesced }, since));
        self.ready.notify_one();
    }

    /// Writer-side pop: guaranteed frames first (FIFO), then pending
    /// progress snapshots. Blocks until something arrives; `None` means
    /// closed-and-drained (or dead) — the writer should exit.
    pub fn pop(&self) -> Option<Message> {
        let mut st = lock_recover(&self.state);
        loop {
            if st.dead {
                return None;
            }
            if let Some((msg, queued_at)) = st.frames.pop_front() {
                st.stats.frames_sent += 1;
                st.stats.note(queued_at.elapsed());
                self.space.notify_one();
                return Some(msg);
            }
            if let Some(&job_id) = st.progress.keys().next() {
                let (msg, queued_at) = st.progress.remove(&job_id)?;
                st.stats.progress_sent += 1;
                if let Message::Progress { coalesced, .. } = &msg {
                    st.stats.progress_coalesced += *coalesced as u64;
                }
                st.stats.note(queued_at.elapsed());
                return Some(msg);
            }
            if st.closed {
                return None;
            }
            st = wait_recover(&self.ready, st);
        }
    }

    /// No further frames; the writer drains what is queued, then exits.
    pub fn close(&self) {
        let mut st = lock_recover(&self.state);
        st.closed = true;
        self.ready.notify_all();
        self.space.notify_all();
    }

    /// The socket write failed: drop everything and unblock producers.
    pub fn mark_dead(&self) {
        let mut st = lock_recover(&self.state);
        st.dead = true;
        st.frames.clear();
        st.progress.clear();
        self.ready.notify_all();
        self.space.notify_all();
    }

    /// Queued guaranteed frames (diagnostics / tests).
    pub fn depth(&self) -> usize {
        lock_recover(&self.state).frames.len()
    }

    /// Snapshot this connection's delivery accounting (drain reports,
    /// load-harness instrumentation).
    pub fn delivery_stats(&self) -> DeliveryStats {
        lock_recover(&self.state).stats
    }
}

/// Incremental frame decoder over any [`Read`] — typically a TcpStream
/// with a read timeout so the owning thread can poll a shutdown flag.
pub struct FrameReader {
    buf: Vec<u8>,
}

/// One poll of [`FrameReader::next`].
pub enum ReadEvent {
    /// A complete, valid frame.
    Frame(Message),
    /// Nothing decodable yet (short read or timeout); poll again.
    Pending,
    /// Peer closed the connection cleanly.
    Eof,
}

impl Default for FrameReader {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader { buf: Vec::new() }
    }

    /// Pull more bytes from `src` and try to decode one frame. Corrupt
    /// input returns `Err` — the caller must drop the connection, since
    /// frame alignment is lost (see [`protocol::decode`]).
    pub fn next(&mut self, src: &mut impl Read) -> Result<ReadEvent> {
        // a prior read may have buffered more than one frame
        if let Some(ev) = self.take_buffered()? {
            return Ok(ev);
        }
        let mut chunk = [0u8; 4096];
        match src.read(&mut chunk) {
            Ok(0) => {
                if self.buf.is_empty() {
                    Ok(ReadEvent::Eof)
                } else {
                    anyhow::bail!("connection closed mid-frame ({} bytes buffered)", self.buf.len())
                }
            }
            Ok(n) => {
                self.buf.extend_from_slice(&chunk[..n]);
                Ok(self.take_buffered()?.unwrap_or(ReadEvent::Pending))
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                Ok(ReadEvent::Pending)
            }
            Err(e) => Err(e.into()),
        }
    }

    fn take_buffered(&mut self) -> Result<Option<ReadEvent>> {
        match protocol::decode(&self.buf)? {
            Some((msg, used)) => {
                self.buf.drain(..used);
                Ok(Some(ReadEvent::Frame(msg)))
            }
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn progress_coalesces_per_job_latest_wins() {
        let out = Outbound::new();
        for done in 1..=5 {
            out.push_progress(Message::Progress {
                job_id: 7,
                done,
                total: 5,
                cell: format!("c{done}"),
                coalesced: 0,
            });
        }
        out.close();
        let Some(Message::Progress { done, coalesced, cell, .. }) = out.pop() else {
            panic!("expected one coalesced progress frame");
        };
        assert_eq!((done, coalesced, cell.as_str()), (5, 4, "c5"));
        assert!(out.pop().is_none());
    }

    #[test]
    fn frames_pop_before_progress_and_fifo_holds() {
        let out = Outbound::new();
        out.push_progress(Message::Progress {
            job_id: 1,
            done: 1,
            total: 2,
            cell: "x".into(),
            coalesced: 0,
        });
        assert!(out.push_frame(Message::Accepted { job_id: 1, cells: 2 }));
        assert!(out.push_frame(Message::Done { job_id: 1, ok: 2, failed: 0, cancelled: 0 }));
        out.close();
        assert_eq!(out.pop().unwrap().kind(), "accepted");
        assert_eq!(out.pop().unwrap().kind(), "done");
        assert_eq!(out.pop().unwrap().kind(), "progress");
        assert!(out.pop().is_none());
    }

    #[test]
    fn full_queue_blocks_producer_until_writer_drains() {
        let out = Arc::new(Outbound::new());
        for _ in 0..OUTBOUND_CAP {
            assert!(out.push_frame(Message::ShutdownAck));
        }
        assert_eq!(out.depth(), OUTBOUND_CAP);
        let producer = {
            let out = Arc::clone(&out);
            std::thread::spawn(move || out.push_frame(Message::ShutdownAck))
        };
        // the producer is parked on the space condvar; one pop frees it
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!producer.is_finished(), "push into a full queue must block");
        assert!(out.pop().is_some());
        assert!(producer.join().unwrap());
    }

    #[test]
    fn dead_connection_drops_frames_and_unblocks() {
        let out = Outbound::new();
        assert!(out.push_frame(Message::ShutdownAck));
        out.mark_dead();
        assert!(!out.push_frame(Message::ShutdownAck));
        assert!(out.pop().is_none());
        assert_eq!(out.depth(), 0);
    }

    #[test]
    fn delivery_stats_account_frames_progress_and_coalescing() {
        let out = Outbound::new();
        assert!(out.push_frame(Message::Accepted { job_id: 1, cells: 2 }));
        assert!(out.push_frame(Message::Done { job_id: 1, ok: 2, failed: 0, cancelled: 0 }));
        for done in 1..=3 {
            out.push_progress(Message::Progress {
                job_id: 1,
                done,
                total: 3,
                cell: format!("c{done}"),
                coalesced: 0,
            });
        }
        out.close();
        while out.pop().is_some() {}
        let stats = out.delivery_stats();
        assert_eq!(stats.frames_sent, 2);
        assert_eq!(stats.progress_sent, 1);
        assert_eq!(stats.progress_coalesced, 2);
        assert!(stats.queued_total >= stats.queued_max);
    }

    #[test]
    fn frame_reader_reassembles_split_frames() {
        let bytes = protocol::encode(&Message::Hello { protocol: protocol::PROTOCOL_VERSION })
            .unwrap();
        let mut rd = FrameReader::new();
        // feed one byte at a time through a cursor; every prefix is Pending
        for cut in 1..bytes.len() {
            let mut src = std::io::Cursor::new(&bytes[cut - 1..cut]);
            match rd.next(&mut src).unwrap() {
                ReadEvent::Pending => {}
                _ => panic!("prefix of {cut} bytes should be Pending"),
            }
        }
        let mut src = std::io::Cursor::new(&bytes[bytes.len() - 1..]);
        match rd.next(&mut src).unwrap() {
            ReadEvent::Frame(msg) => assert_eq!(msg.kind(), "hello"),
            _ => panic!("final byte should complete the frame"),
        }
    }
}
