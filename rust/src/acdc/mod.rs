//! ACDC — Automated Circuit Discovery (Conmy et al. 2023), the algorithm
//! PAHQ accelerates (paper Appendix F gives the integrated version).
//!
//! Greedy reverse-topological sweep: for every destination channel (later
//! layers first) and every incoming edge, tentatively patch the edge with
//! its corrupted activation; if the metric damage increase over the
//! current circuit is below the threshold τ, the edge is pruned for good.
//!
//! PAHQ integration (paper section 3.1): when the session policy is PAHQ,
//! each evaluation passes `hi = src(e)` so the investigated edge's source
//! component — its weights *and* its activations — runs at FP32 while
//! everything else stays quantized. For ACDC-FP32 and RTN-Q the override
//! is absent (it would be a no-op / is deliberately missing).

use anyhow::Result;

use crate::metrics::Objective;
use crate::model::{Edge, NodeId};
use crate::patching::{PatchMask, PatchedForward, Policy};

/// One recorded sweep step (drives Fig. 3's edge-count curve).
#[derive(Clone, Debug)]
pub struct TraceStep {
    pub step: usize,
    pub edges_remaining: usize,
    pub metric: f32,
    pub removed: bool,
}

#[derive(Clone, Debug)]
pub struct AcdcResult {
    /// edges REMOVED from the circuit (patched to corrupt)
    pub removed: PatchMask,
    /// kept[i] aligned with `graph.edges()` order: true = in circuit
    pub kept: Vec<bool>,
    pub n_kept: usize,
    pub n_evals: usize,
    pub trace: Vec<TraceStep>,
    pub final_metric: f32,
    pub wall: std::time::Duration,
}

#[derive(Clone, Debug)]
pub struct AcdcConfig {
    pub tau: f32,
    pub objective: Objective,
    /// record the Fig. 3 trace (tiny overhead)
    pub record_trace: bool,
}

impl AcdcConfig {
    pub fn new(tau: f32, objective: Objective) -> AcdcConfig {
        AcdcConfig { tau, objective, record_trace: false }
    }
}

/// Does this policy investigate edges at high precision (PAHQ)?
fn hi_node_for(policy: &Policy, src: NodeId) -> Option<NodeId> {
    if policy.name.starts_with("pahq") {
        Some(src)
    } else {
        None
    }
}

/// Run ACDC under the engine's current session policy.
pub fn run(engine: &mut PatchedForward, cfg: &AcdcConfig) -> Result<AcdcResult> {
    let t0 = std::time::Instant::now();
    let policy = engine.session().clone();
    let edges = engine.graph.edges();
    let total_edges = edges.len();

    let mut patches = engine.empty_patches();
    let mut m_cur = engine.damage(&patches, None, cfg.objective)?;
    let mut n_evals = 1usize;
    let mut trace = Vec::new();
    let mut removed_count = 0usize;

    // reverse topological order: later channels first, then later sources
    // first within a channel (mirrors the reference implementation)
    let mut channels = engine.channels.clone();
    channels.reverse();
    let mut step = 0usize;
    for ch in channels {
        let ci = engine.chan_index(ch);
        let mut srcs = engine.graph.sources(ch);
        srcs.reverse();
        for src in srcs {
            step += 1;
            patches.set(ci, src, true);
            let hi = hi_node_for(&policy, src);
            let m_new = engine.damage(&patches, hi, cfg.objective)?;
            n_evals += 1;
            let removed = m_new - m_cur < cfg.tau;
            if removed {
                removed_count += 1;
                m_cur = m_new;
            } else {
                patches.set(ci, src, false);
            }
            if cfg.record_trace {
                trace.push(TraceStep {
                    step,
                    edges_remaining: total_edges - removed_count,
                    metric: m_cur,
                    removed,
                });
            }
        }
    }

    let kept: Vec<bool> = edges
        .iter()
        .map(|e| !patches.get(engine.chan_index(e.dst), e.src))
        .collect();
    let n_kept = kept.iter().filter(|&&k| k).count();
    Ok(AcdcResult {
        removed: patches,
        kept,
        n_kept,
        n_evals,
        trace,
        final_metric: m_cur,
        wall: t0.elapsed(),
    })
}

/// The 21 log-spaced thresholds the paper sweeps (0.001 .. 3.16).
pub fn paper_thresholds() -> Vec<f32> {
    let (lo, hi, n) = (0.001f64.ln(), 3.16f64.ln(), 21);
    (0..n)
        .map(|i| (lo + (hi - lo) * i as f64 / (n - 1) as f64).exp() as f32)
        .collect()
}

/// Edge labels of the discovered circuit (debugging / CLI output).
pub fn kept_edge_labels(engine: &PatchedForward, result: &AcdcResult) -> Vec<String> {
    engine
        .graph
        .edges()
        .iter()
        .zip(&result.kept)
        .filter(|(_, &k)| k)
        .map(|(e, _)| e.label(&engine.graph))
        .collect()
}

/// Convenience: kept flags for a caller-supplied edge order.
pub fn kept_flags(engine: &PatchedForward, result: &AcdcResult, edges: &[Edge]) -> Vec<bool> {
    edges
        .iter()
        .map(|e| !result.removed.get(engine.chan_index(e.dst), e.src))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::FP8_E4M3;

    #[test]
    fn thresholds_match_paper() {
        let t = paper_thresholds();
        assert_eq!(t.len(), 21);
        assert!((t[0] - 0.001).abs() < 1e-6);
        assert!((t[20] - 3.16).abs() < 0.01);
        // log-spaced: ratios constant
        let r01 = t[1] / t[0];
        let r19 = t[20] / t[19];
        assert!((r01 - r19).abs() < 1e-3);
    }

    fn engine() -> Option<PatchedForward> {
        PatchedForward::new("redwood2l-sim", "ioi").ok()
    }

    #[test]
    fn tiny_tau_keeps_more_than_huge_tau() {
        let Some(mut e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let strict = run(&mut e, &AcdcConfig::new(1e-5, Objective::Kl)).unwrap();
        let loose = run(&mut e, &AcdcConfig::new(10.0, Objective::Kl)).unwrap();
        assert!(strict.n_kept > loose.n_kept, "{} vs {}", strict.n_kept, loose.n_kept);
        // τ=10 prunes essentially everything
        assert!(loose.n_kept < e.graph.n_edges() / 10);
        // evals = edges + 1 baseline
        assert_eq!(strict.n_evals, e.graph.n_edges() + 1);
    }

    #[test]
    fn trace_is_monotone_decreasing() {
        let Some(mut e) = engine() else { return };
        let mut cfg = AcdcConfig::new(0.05, Objective::Kl);
        cfg.record_trace = true;
        let res = run(&mut e, &cfg).unwrap();
        assert_eq!(res.trace.len(), e.graph.n_edges());
        for w in res.trace.windows(2) {
            assert!(w[1].edges_remaining <= w[0].edges_remaining);
        }
        assert_eq!(
            res.trace.last().unwrap().edges_remaining,
            res.n_kept,
            "trace end equals kept count"
        );
    }

    #[test]
    fn pahq_session_runs_and_finds_nonempty_circuit() {
        let Some(mut e) = engine() else { return };
        e.set_session(Policy::pahq(FP8_E4M3)).unwrap();
        let res = run(&mut e, &AcdcConfig::new(0.01, Objective::Kl)).unwrap();
        assert!(res.n_kept > 0, "circuit is non-empty");
        assert!(res.n_kept < e.graph.n_edges(), "something was pruned");
    }
}
