//! ACDC — Automated Circuit Discovery (Conmy et al. 2023), the algorithm
//! PAHQ accelerates (paper Appendix F gives the integrated version).
//!
//! Greedy reverse-topological sweep: for every destination channel (later
//! layers first) and every incoming edge, tentatively patch the edge with
//! its corrupted activation; if the metric damage increase over the
//! current circuit is below the threshold τ, the edge is pruned for good.
//!
//! PAHQ integration (paper section 3.1): when the session policy is PAHQ,
//! each evaluation passes `hi = src(e)` so the investigated edge's source
//! component — its weights *and* its activations — runs at FP32 while
//! everything else stays quantized. For ACDC-FP32 and RTN-Q the override
//! is absent (it would be a no-op / is deliberately missing).

use anyhow::Result;

use crate::metrics::Objective;
use crate::model::{Edge, NodeId};
use crate::patching::{PatchMask, PatchedForward, Policy};

pub mod sweep;

pub use sweep::{BatchScorer, Candidate, EnginePool, FnScorer, SweepMode, SweepOutcome};

/// One recorded sweep step (drives Fig. 3's edge-count curve).
#[derive(Clone, Debug)]
pub struct TraceStep {
    pub step: usize,
    pub edges_remaining: usize,
    pub metric: f32,
    pub removed: bool,
}

#[derive(Clone, Debug)]
pub struct AcdcResult {
    /// edges REMOVED from the circuit (patched to corrupt)
    pub removed: PatchMask,
    /// kept[i] aligned with `graph.edges()` order: true = in circuit
    pub kept: Vec<bool>,
    pub n_kept: usize,
    pub n_evals: usize,
    pub trace: Vec<TraceStep>,
    pub final_metric: f32,
    pub wall: std::time::Duration,
}

#[derive(Clone, Debug)]
pub struct AcdcConfig {
    pub tau: f32,
    pub objective: Objective,
    /// record the Fig. 3 trace (tiny overhead)
    pub record_trace: bool,
    /// evaluation scheduling; results are bit-identical across modes
    pub sweep: SweepMode,
}

impl AcdcConfig {
    pub fn new(tau: f32, objective: Objective) -> AcdcConfig {
        AcdcConfig { tau, objective, record_trace: false, sweep: SweepMode::Serial }
    }

    pub fn with_sweep(mut self, mode: SweepMode) -> AcdcConfig {
        self.sweep = mode;
        self
    }
}

/// Does this policy investigate edges at high precision (PAHQ)?
pub(crate) fn hi_node_for(policy: &Policy, src: NodeId) -> Option<NodeId> {
    if policy.is_pahq() {
        Some(src)
    } else {
        None
    }
}

/// The sweep plan for an engine's graph under its session policy:
/// reverse topological order — later channels first, then later sources
/// first within a channel (mirrors the reference implementation). Each
/// inner vec is one destination channel's candidate group, the unit the
/// batched sweep scores speculatively.
pub fn sweep_plan(engine: &PatchedForward) -> Vec<Vec<Candidate>> {
    let policy = engine.session();
    let mut channels = engine.channels.clone();
    channels.reverse();
    let mut plan = Vec::with_capacity(channels.len());
    for ch in channels {
        let ci = engine.chan_index(ch);
        let mut srcs = engine.graph.sources(ch);
        srcs.reverse();
        plan.push(
            srcs.into_iter()
                .map(|src| Candidate { chan: ci, src, hi: hi_node_for(policy, src) })
                .collect(),
        );
    }
    plan
}

fn finish_result(
    engine: &PatchedForward,
    out: SweepOutcome,
    t0: std::time::Instant,
) -> AcdcResult {
    let kept: Vec<bool> = engine
        .graph
        .edges()
        .iter()
        .map(|e| !out.removed.get(engine.chan_index(e.dst), e.src))
        .collect();
    let n_kept = kept.iter().filter(|&&k| k).count();
    AcdcResult {
        removed: out.removed,
        kept,
        n_kept,
        n_evals: out.n_evals,
        trace: out.trace,
        final_metric: out.final_metric,
        wall: t0.elapsed(),
    }
}

/// Run ACDC under the engine's current session policy. `cfg.sweep`
/// selects the evaluation schedule; with a single engine, `Batched`
/// still scores speculatively (sharing the per-batch patched-forward
/// setup and reference memoization) but executes on one thread — use
/// [`run_pool`] for true multi-worker scoring.
pub fn run(engine: &mut PatchedForward, cfg: &AcdcConfig) -> Result<AcdcResult> {
    let t0 = std::time::Instant::now();
    let plan = sweep_plan(engine);
    let n_channels = engine.n_channels();
    let outcome = {
        let mut scorer = EngineScorer { engine: &mut *engine, objective: cfg.objective };
        sweep::sweep(&mut scorer, n_channels, &plan, cfg.tau, cfg.record_trace, cfg.sweep)?
    };
    Ok(finish_result(engine, outcome, t0))
}

/// Run ACDC across a pool of replicated engines: each speculative batch
/// fans out over the pool's worker threads. Bit-identical to [`run`]
/// (property-tested); the pool's objective must match `cfg.objective`.
pub fn run_pool(pool: &mut EnginePool, cfg: &AcdcConfig) -> Result<AcdcResult> {
    if pool.objective() != cfg.objective {
        anyhow::bail!(
            "engine pool scores {:?} but the sweep config asks for {:?}",
            pool.objective(),
            cfg.objective
        );
    }
    let t0 = std::time::Instant::now();
    let plan = sweep_plan(pool.primary());
    let n_channels = pool.primary().n_channels();
    let outcome = sweep::sweep(pool, n_channels, &plan, cfg.tau, cfg.record_trace, cfg.sweep)?;
    Ok(finish_result(pool.primary(), outcome, t0))
}

/// [`BatchScorer`] over a single engine: batches score sequentially but
/// share the speculative-mask setup and the per-`hi` reference
/// memoization (see [`PatchedForward::damage_batch`]). Public so the
/// [`crate::discovery`] layer can drive any method's candidate plan
/// through the same machinery.
pub struct EngineScorer<'a> {
    pub engine: &'a mut PatchedForward,
    pub objective: Objective,
}

impl BatchScorer for EngineScorer<'_> {
    fn baseline(&mut self, patches: &PatchMask) -> Result<f32> {
        self.engine.damage(patches, None, self.objective)
    }

    fn score_batch(&mut self, patches: &PatchMask, cands: &[Candidate]) -> Result<Vec<f32>> {
        self.engine.damage_batch(patches, cands, self.objective)
    }
}

/// The 21 log-spaced thresholds the paper sweeps (0.001 .. 3.16).
pub fn paper_thresholds() -> Vec<f32> {
    let (lo, hi, n) = (0.001f64.ln(), 3.16f64.ln(), 21);
    (0..n)
        .map(|i| (lo + (hi - lo) * i as f64 / (n - 1) as f64).exp() as f32)
        .collect()
}

/// Edge labels of the discovered circuit (debugging / CLI output).
pub fn kept_edge_labels(engine: &PatchedForward, result: &AcdcResult) -> Vec<String> {
    crate::discovery::kept_labels(engine, &result.kept)
}

/// Convenience: kept flags for a caller-supplied edge order.
pub fn kept_flags(engine: &PatchedForward, result: &AcdcResult, edges: &[Edge]) -> Vec<bool> {
    edges
        .iter()
        .map(|e| !result.removed.get(engine.chan_index(e.dst), e.src))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::FP8_E4M3;

    #[test]
    fn thresholds_match_paper() {
        let t = paper_thresholds();
        assert_eq!(t.len(), 21);
        assert!((t[0] - 0.001).abs() < 1e-6);
        assert!((t[20] - 3.16).abs() < 0.01);
        // log-spaced: ratios constant
        let r01 = t[1] / t[0];
        let r19 = t[20] / t[19];
        assert!((r01 - r19).abs() < 1e-3);
    }

    fn engine() -> Option<PatchedForward> {
        PatchedForward::new("redwood2l-sim", "ioi").ok()
    }

    #[test]
    fn tiny_tau_keeps_more_than_huge_tau() {
        let Some(mut e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let strict = run(&mut e, &AcdcConfig::new(1e-5, Objective::Kl)).unwrap();
        let loose = run(&mut e, &AcdcConfig::new(10.0, Objective::Kl)).unwrap();
        assert!(strict.n_kept > loose.n_kept, "{} vs {}", strict.n_kept, loose.n_kept);
        // τ=10 prunes essentially everything
        assert!(loose.n_kept < e.graph.n_edges() / 10);
        // evals = edges + 1 baseline
        assert_eq!(strict.n_evals, e.graph.n_edges() + 1);
    }

    #[test]
    fn trace_is_monotone_decreasing() {
        let Some(mut e) = engine() else { return };
        let mut cfg = AcdcConfig::new(0.05, Objective::Kl);
        cfg.record_trace = true;
        let res = run(&mut e, &cfg).unwrap();
        assert_eq!(res.trace.len(), e.graph.n_edges());
        for w in res.trace.windows(2) {
            assert!(w[1].edges_remaining <= w[0].edges_remaining);
        }
        assert_eq!(
            res.trace.last().unwrap().edges_remaining,
            res.n_kept,
            "trace end equals kept count"
        );
    }

    #[test]
    fn pahq_session_runs_and_finds_nonempty_circuit() {
        let Some(mut e) = engine() else { return };
        e.set_session(Policy::pahq(FP8_E4M3)).unwrap();
        let res = run(&mut e, &AcdcConfig::new(0.01, Objective::Kl)).unwrap();
        assert!(res.n_kept > 0, "circuit is non-empty");
        assert!(res.n_kept < e.graph.n_edges(), "something was pruned");
    }

    #[test]
    fn batched_sweep_matches_serial_on_engine() {
        // The bit-identity contract on the real engine: same kept set,
        // same final metric, regardless of schedule (serial vs batched
        // single-engine vs batched pool).
        let Some(mut e) = engine() else { return };
        let cfg = AcdcConfig::new(0.01, Objective::Kl);
        let serial = run(&mut e, &cfg).unwrap();
        let batched =
            run(&mut e, &cfg.clone().with_sweep(SweepMode::Batched { workers: 1 })).unwrap();
        assert_eq!(serial.kept, batched.kept);
        assert_eq!(serial.n_kept, batched.n_kept);
        assert_eq!(serial.final_metric.to_bits(), batched.final_metric.to_bits());
        assert!(batched.n_evals >= serial.n_evals, "rescoring only adds evals");

        let mut pool =
            EnginePool::new("redwood2l-sim", "ioi", &Policy::fp32(), 3, Objective::Kl).unwrap();
        let pooled =
            run_pool(&mut pool, &cfg.with_sweep(SweepMode::Batched { workers: 3 })).unwrap();
        assert_eq!(serial.kept, pooled.kept);
        assert_eq!(serial.final_metric.to_bits(), pooled.final_metric.to_bits());
    }
}
