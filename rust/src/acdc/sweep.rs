//! The sweep engine: batched, thread-parallel scoring of candidate edges
//! with a deterministic reduction that is **bit-identical** to the serial
//! greedy sweep.
//!
//! ACDC's inner loop is a chain of accept/reject decisions, one per edge,
//! each conditioned on the patch state left by every earlier decision —
//! on its face, strictly sequential. The batched engine treats that chain
//! like a speculating processor treats a branch:
//!
//! 1. **Speculative scoring.** A window of still-undecided candidates
//!    from the current destination channel is scored in parallel, under
//!    one of two speculated prefixes:
//!    - **flat** (predict *keep*): every candidate scored against the
//!      current patch state — valid for candidate j as long as no
//!      earlier candidate in the window was removed;
//!    - **chain** (predict *remove*): candidate j scored against the
//!      current state plus candidates `0..j` of the window patched in —
//!      valid as long as every earlier candidate in the window WAS
//!      removed.
//!    A running accept-rate estimate picks the direction per round
//!    (ACDC prunes most edges at practical τ, so the chain direction
//!    dominates in the steady state).
//! 2. **Deterministic reduction.** Candidates are then decided in serial
//!    order, consuming a speculative score only while its validity
//!    condition holds; the first misprediction truncates the window and
//!    the survivors are re-scored against the true state next round.
//!
//! Every decision therefore consumes a score computed against exactly
//! the patch state the serial sweep would have used — same floats, same
//! comparisons, same kept set, same final metric, bit for bit (property-
//! tested in `tests/properties.rs`). The price is extra evaluations on
//! mispredictions: for window size B and miss rate q the expected eval
//! inflation is ≈ `1 + q·(B−1)/2`, so with B = 2·workers the wall-clock
//! speedup approaches `workers / (1 + q·(2·workers−1)/2)` — a clear win
//! whenever the predictor is right more often than not.
//!
//! Threading is a hand-rolled `std::thread::scope` fan-out (the repo
//! vendors no crates): [`FnScorer`] parallelizes any pure scoring
//! function, [`EnginePool`] replicates [`PatchedForward`] engines — one
//! per worker — and splits each batch across them.

use anyhow::{bail, Result};

use crate::metrics::Objective;
use crate::model::NodeId;
use crate::patching::{PatchMask, PatchedForward, Policy};

use super::TraceStep;

/// How the greedy sweep schedules its edge evaluations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SweepMode {
    /// One evaluation at a time, the reference ACDC loop.
    #[default]
    Serial,
    /// Per-channel speculative batches, scored across `workers` threads,
    /// reduced deterministically (see module docs).
    Batched { workers: usize },
}

impl SweepMode {
    /// Parse a CLI spelling (`serial` | `batched`), with the worker count
    /// supplied separately (`--workers`).
    pub fn parse(name: &str, workers: usize) -> Result<SweepMode> {
        match name {
            "serial" => Ok(SweepMode::Serial),
            "batched" => Ok(SweepMode::Batched { workers: workers.max(1) }),
            other => bail!("unknown sweep mode '{other}' (serial|batched)"),
        }
    }

    pub fn workers(&self) -> usize {
        match self {
            SweepMode::Serial => 1,
            SweepMode::Batched { workers } => (*workers).max(1),
        }
    }

    pub fn label(&self) -> String {
        match self {
            SweepMode::Serial => "serial".to_string(),
            SweepMode::Batched { workers } => format!("batched[{workers}]"),
        }
    }

    /// The CLI spellings, in display order (drives the generated help).
    pub const SPELLINGS: [&'static str; 2] = ["serial", "batched"];
}

/// Writes [`SweepMode::label`] (`serial` / `batched[N]`), so
/// `format!("{mode}")` round-trips through [`SweepMode::from_str`].
impl std::fmt::Display for SweepMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// Parses `serial`, `batched` (workers = available parallelism), and the
/// `batched[N]` label form — the full round trip of [`SweepMode::label`].
impl std::str::FromStr for SweepMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<SweepMode> {
        if let Some(n) = s.strip_prefix("batched[").and_then(|r| r.strip_suffix(']')) {
            let workers: usize = n
                .parse()
                .map_err(|_| anyhow::anyhow!("bad worker count in sweep mode '{s}'"))?;
            if workers == 0 {
                bail!("sweep mode '{s}': batched worker count must be >= 1");
            }
            return Ok(SweepMode::Batched { workers });
        }
        let default_workers =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        SweepMode::parse(s, default_workers)
    }
}

/// One candidate edge evaluation: patch source `src` into destination
/// channel `chan`, with the policy's high-precision override `hi`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Candidate {
    pub chan: usize,
    pub src: NodeId,
    pub hi: Option<NodeId>,
}

/// Scores batches of speculative candidates. Implementations MUST be
/// deterministic functions of `(patches, candidates)` — the bit-identity
/// guarantee of the batched sweep rests on it.
pub trait BatchScorer {
    /// Metric damage of the current patch set with no candidate applied.
    fn baseline(&mut self, patches: &PatchMask) -> Result<f32>;

    /// Flat speculation: damage of each candidate applied *individually*
    /// on top of `patches` (candidates do not see each other).
    fn score_batch(&mut self, patches: &PatchMask, cands: &[Candidate]) -> Result<Vec<f32>>;

    /// Chain speculation: damage of candidate `j` with candidates `0..=j`
    /// all patched on top of `patches` (each candidate assumes every
    /// earlier one in the batch was removed). The default runs
    /// sequentially via [`BatchScorer::score_batch`]; threaded scorers
    /// override it with a prefix-mask fan-out.
    fn score_chain(&mut self, patches: &PatchMask, cands: &[Candidate]) -> Result<Vec<f32>> {
        let mut work = patches.clone();
        let mut out = Vec::with_capacity(cands.len());
        for c in cands {
            let s = self.score_batch(&work, std::slice::from_ref(c))?;
            out.push(s[0]);
            work.set(c.chan, c.src, true);
        }
        Ok(out)
    }
}

/// Raw output of a sweep, before graph-aware post-processing.
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub removed: PatchMask,
    pub n_evals: usize,
    pub removed_count: usize,
    pub final_metric: f32,
    pub trace: Vec<TraceStep>,
}

/// Speculation window per round: mild oversubscription smooths worker
/// imbalance without inflating misprediction waste.
const SPEC_OVERSUB: usize = 2;

/// Run the greedy sweep over `plan` (groups of candidates in evaluation
/// order; each group shares one destination channel). `Serial` evaluates
/// one candidate per round; `Batched` evaluates speculative windows with
/// a keep/remove branch predictor (see module docs). Decisions — and
/// therefore the returned kept set, the final metric, and the trace —
/// are identical across modes.
pub fn sweep<S: BatchScorer>(
    scorer: &mut S,
    n_channels: usize,
    plan: &[Vec<Candidate>],
    tau: f32,
    record_trace: bool,
    mode: SweepMode,
) -> Result<SweepOutcome> {
    let total: usize = plan.iter().map(|g| g.len()).sum();
    let mut patches = PatchMask::empty(n_channels);
    let mut m_cur = scorer.baseline(&patches)?;
    let mut n_evals = 1usize;
    let mut trace = Vec::new();
    let mut removed_count = 0usize;
    let mut step = 0usize;
    let window = match mode {
        SweepMode::Serial => 1,
        SweepMode::Batched { workers } => workers.max(1) * SPEC_OVERSUB,
    };
    // Running accept-rate estimate driving the speculation direction
    // (EMA; deterministic). Start neutral: the first rounds pay a few
    // mispredictions while it settles.
    let mut accept_est = 0.5f64;
    for group in plan {
        let mut i = 0usize;
        while i < group.len() {
            let end = i.saturating_add(window).min(group.len());
            let pending = &group[i..end];
            // predict "remove" (chain) when removal has been the majority
            let chain = window > 1 && accept_est >= 0.5;
            let scores = if chain {
                scorer.score_chain(&patches, pending)?
            } else {
                scorer.score_batch(&patches, pending)?
            };
            debug_assert_eq!(scores.len(), pending.len());
            n_evals += pending.len();
            let mut decided = 0usize;
            for (c, &m_new) in pending.iter().zip(&scores) {
                step += 1;
                decided += 1;
                let removed = m_new - m_cur < tau;
                if removed {
                    patches.set(c.chan, c.src, true);
                    m_cur = m_new;
                    removed_count += 1;
                }
                accept_est = 0.9 * accept_est + if removed { 0.1 } else { 0.0 };
                if record_trace {
                    trace.push(TraceStep {
                        step,
                        edges_remaining: total - removed_count,
                        metric: m_cur,
                        removed,
                    });
                }
                // A decision that contradicts the speculated prefix makes
                // the rest of this window's scores stale.
                let mispredicted = removed != chain;
                if mispredicted && decided < pending.len() {
                    break;
                }
            }
            i += decided;
        }
    }
    Ok(SweepOutcome { removed: patches, n_evals, removed_count, final_metric: m_cur, trace })
}

// ---------------------------------------------------------------------------
// Scorers

/// Wraps a pure scoring function `f(patches, candidate) -> damage`
/// (`candidate = None` scores the baseline) and fans batches out over
/// `workers` scoped threads. Used by the property tests and the
/// serial-vs-batched benchmark group; the function must be `Sync`.
pub struct FnScorer<F> {
    pub score: F,
    pub workers: usize,
}

impl<F> BatchScorer for FnScorer<F>
where
    F: Fn(&PatchMask, Option<&Candidate>) -> f32 + Sync,
{
    fn baseline(&mut self, patches: &PatchMask) -> Result<f32> {
        Ok((self.score)(patches, None))
    }

    fn score_batch(&mut self, patches: &PatchMask, cands: &[Candidate]) -> Result<Vec<f32>> {
        let w = self.workers.max(1).min(cands.len().max(1));
        if w <= 1 {
            return Ok(cands.iter().map(|c| (self.score)(patches, Some(c))).collect());
        }
        let mut out = vec![0.0f32; cands.len()];
        let chunk = cands.len().div_ceil(w);
        let score = &self.score;
        std::thread::scope(|s| {
            for (cs, os) in cands.chunks(chunk).zip(out.chunks_mut(chunk)) {
                s.spawn(move || {
                    for (c, o) in cs.iter().zip(os.iter_mut()) {
                        *o = score(patches, Some(c));
                    }
                });
            }
        });
        Ok(out)
    }

    fn score_chain(&mut self, patches: &PatchMask, cands: &[Candidate]) -> Result<Vec<f32>> {
        let w = self.workers.max(1).min(cands.len().max(1));
        if w <= 1 {
            let mut work = patches.clone();
            let mut out = Vec::with_capacity(cands.len());
            for c in cands {
                out.push((self.score)(&work, Some(c)));
                work.set(c.chan, c.src, true);
            }
            return Ok(out);
        }
        // Prefix masks at chunk boundaries are built serially (cheap bit
        // sets); each worker then walks its chunk cumulatively.
        let chunk = cands.len().div_ceil(w);
        let mut starts = Vec::with_capacity(w);
        let mut work = patches.clone();
        for (idx, c) in cands.iter().enumerate() {
            if idx % chunk == 0 {
                starts.push(work.clone());
            }
            work.set(c.chan, c.src, true);
        }
        let mut out = vec![0.0f32; cands.len()];
        let score = &self.score;
        std::thread::scope(|s| {
            for ((cs, os), start) in cands.chunks(chunk).zip(out.chunks_mut(chunk)).zip(starts) {
                s.spawn(move || {
                    let mut mask = start;
                    for (c, o) in cs.iter().zip(os.iter_mut()) {
                        *o = score(&mask, Some(c));
                        mask.set(c.chan, c.src, true);
                    }
                });
            }
        });
        Ok(out)
    }
}

/// A pool of replicated [`PatchedForward`] engines — one per worker —
/// scoring each speculative batch across scoped threads. All engines are
/// built from the same model/task/policy, so they are numerically
/// identical replicas and any of them scoring a candidate produces the
/// same bits (the determinism [`BatchScorer`] requires).
pub struct EnginePool {
    engines: Vec<PatchedForward>,
    objective: Objective,
    model: String,
    task: String,
    policy: Policy,
}

impl EnginePool {
    /// Replicas on the task artifact's default batch (the classic
    /// constructor); delegates to [`EnginePool::with_examples`].
    pub fn new(
        model: &str,
        task: &str,
        policy: &Policy,
        workers: usize,
        objective: Objective,
    ) -> Result<EnginePool> {
        let manifest = crate::model::Manifest::by_name(model)?;
        let examples = crate::model::Dataset::by_task(task)?.batch(manifest.batch)?.to_vec();
        Self::with_examples(model, task, &examples, policy, workers, objective, None)
    }

    /// A pool whose replicas evaluate an explicit batch instead of the
    /// task artifact's default one — required for numerical identity
    /// with a session built on seeded examples (`--seed`): every
    /// replica must score exactly the bits the primary engine holds.
    /// When `corrupt_cache` is given (matrix handoff), each replica
    /// installs it instead of re-running the corrupted forward.
    pub fn with_examples(
        model: &str,
        task: &str,
        examples: &[crate::model::Example],
        policy: &Policy,
        workers: usize,
        objective: Objective,
        corrupt_cache: Option<&[crate::tensor::QTensor]>,
    ) -> Result<EnginePool> {
        let workers = workers.max(1);
        let manifest = crate::model::Manifest::by_name(model)?;
        let mut engines = Vec::with_capacity(workers);
        for _ in 0..workers {
            let mut e = PatchedForward::with_examples(manifest.clone(), examples.to_vec())?;
            match corrupt_cache {
                Some(cc) => e.set_session_with_cache(policy.clone(), cc)?,
                None => e.set_session(policy.clone())?,
            }
            engines.push(e);
        }
        Ok(EnginePool {
            engines,
            objective,
            model: model.to_string(),
            task: task.to_string(),
            policy: policy.clone(),
        })
    }

    /// Can this pool serve a cell with the given configuration as-is?
    /// The matrix orchestrator hands pools between consecutive cells on
    /// one worker; a match skips rebuilding `workers` engine replicas.
    /// Compares the *full* policy, not its name — same-width formats
    /// (fp8_e4m3 vs fp8_e5m2) share a name but score different bits.
    pub fn matches(
        &self,
        model: &str,
        task: &str,
        policy: &Policy,
        workers: usize,
        objective: Objective,
    ) -> bool {
        self.model == model
            && self.task == task
            && self.policy == *policy
            && self.engines.len() == workers
            && self.objective == objective
    }

    pub fn workers(&self) -> usize {
        self.engines.len()
    }

    pub fn objective(&self) -> Objective {
        self.objective
    }

    /// Total wall-clock spent inside PJRT across every engine replica.
    pub fn pjrt_time(&self) -> std::time::Duration {
        self.engines.iter().map(|e| e.pjrt_time()).sum()
    }

    /// The engine callers should use for graph/labels/follow-up metrics.
    pub fn primary(&self) -> &PatchedForward {
        &self.engines[0]
    }

    pub fn primary_mut(&mut self) -> &mut PatchedForward {
        &mut self.engines[0]
    }
}

impl BatchScorer for EnginePool {
    fn baseline(&mut self, patches: &PatchMask) -> Result<f32> {
        let obj = self.objective;
        self.engines[0].damage(patches, None, obj)
    }

    fn score_batch(&mut self, patches: &PatchMask, cands: &[Candidate]) -> Result<Vec<f32>> {
        let obj = self.objective;
        let w = self.engines.len().min(cands.len().max(1));
        if w <= 1 {
            return self.engines[0].damage_batch(patches, cands, obj);
        }
        let chunk = cands.len().div_ceil(w);
        let mut results: Vec<Result<Vec<f32>>> = Vec::new();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (engine, cs) in self.engines.iter_mut().zip(cands.chunks(chunk)) {
                handles.push(s.spawn(move || engine.damage_batch(patches, cs, obj)));
            }
            results = handles
                .into_iter()
                .map(|h| h.join().expect("sweep worker panicked"))
                .collect();
        });
        let mut out = Vec::with_capacity(cands.len());
        for r in results {
            out.extend(r?);
        }
        Ok(out)
    }

    fn score_chain(&mut self, patches: &PatchMask, cands: &[Candidate]) -> Result<Vec<f32>> {
        let obj = self.objective;
        let w = self.engines.len().min(cands.len().max(1));
        if w <= 1 {
            return self.engines[0].damage_chain(patches, cands, obj);
        }
        let chunk = cands.len().div_ceil(w);
        let mut starts = Vec::with_capacity(w);
        let mut work = patches.clone();
        for (idx, c) in cands.iter().enumerate() {
            if idx % chunk == 0 {
                starts.push(work.clone());
            }
            work.set(c.chan, c.src, true);
        }
        let mut results: Vec<Result<Vec<f32>>> = Vec::new();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for ((engine, cs), start) in
                self.engines.iter_mut().zip(cands.chunks(chunk)).zip(starts)
            {
                handles.push(s.spawn(move || engine.damage_chain(&start, cs, obj)));
            }
            results = handles
                .into_iter()
                .map(|h| h.join().expect("sweep worker panicked"))
                .collect();
        });
        let mut out = Vec::with_capacity(cands.len());
        for r in results {
            out.extend(r?);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Synthetic damage surface (tests + benches)

/// A deterministic synthetic edge-damage surface: each `(chan, src)`
/// carries a fixed pseudo-random weight, the damage of a patch set is the
/// weight sum plus a quadratic interaction term, and `hi` overrides
/// perturb the weight by an exact power-of-two factor. The interaction
/// term makes candidate scores depend on the current mask, so the batched
/// sweep's stale-score/rescore path is genuinely exercised.
pub struct SyntheticSurface {
    seed: u64,
    interaction: f32,
}

impl SyntheticSurface {
    pub fn new(seed: u64, interaction: f32) -> SyntheticSurface {
        SyntheticSurface { seed, interaction }
    }

    /// Durable-store wire form: seed (u64 LE) then interaction (f32
    /// bits, LE). The surface is a pure function of these two values,
    /// so the round trip reproduces every damage score bit-identically.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12);
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.interaction.to_bits().to_le_bytes());
        out
    }

    /// Exact inverse of [`SyntheticSurface::to_bytes`].
    pub fn from_bytes(b: &[u8]) -> anyhow::Result<SyntheticSurface> {
        if b.len() != 12 {
            anyhow::bail!("synthetic-surface wire data is {} bytes, expected 12", b.len());
        }
        Ok(SyntheticSurface {
            seed: u64::from_le_bytes(b[..8].try_into().unwrap()),
            interaction: f32::from_bits(u32::from_le_bytes(b[8..12].try_into().unwrap())),
        })
    }

    /// Fixed weight of an edge, in [0, 1) (splitmix64 of (seed, chan, src)).
    pub fn weight(&self, chan: usize, src: NodeId) -> f32 {
        let mut x = self
            .seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add((chan as u64) << 32 | src as u64);
        x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
        x ^= x >> 31;
        (x >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Damage of a patch set, optionally with one speculative candidate.
    pub fn damage(&self, mask: &PatchMask, extra: Option<&Candidate>) -> f32 {
        let mut sum = 0.0f32;
        for chan in 0..mask.n_channels() {
            let bits = mask.mask(chan);
            if bits == 0 {
                continue;
            }
            for src in 0..128usize {
                if bits >> src & 1 == 1 {
                    sum += self.weight(chan, src);
                }
            }
        }
        if let Some(c) = extra {
            let w = self.weight(c.chan, c.src);
            // hi overrides scale by 1 + 2^-10 — exact in f32, so the
            // perturbation is deterministic and non-lossy
            sum += if c.hi.is_some() { w * (1.0 + 1.0 / 1024.0) } else { w };
        }
        sum + self.interaction * sum * sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_3x4() -> Vec<Vec<Candidate>> {
        // 3 channels x 4 sources, alternating hi overrides
        (0..3)
            .map(|chan| {
                (0..4)
                    .map(|src| Candidate {
                        chan,
                        src,
                        hi: if src % 2 == 0 { Some(src) } else { None },
                    })
                    .collect()
            })
            .collect()
    }

    fn outcome(mode: SweepMode, workers: usize, tau: f32) -> SweepOutcome {
        let surface = SyntheticSurface::new(42, 0.02);
        let score = |m: &PatchMask, c: Option<&Candidate>| surface.damage(m, c);
        let mut scorer = FnScorer { score, workers };
        sweep(&mut scorer, 3, &plan_3x4(), tau, true, mode).unwrap()
    }

    #[test]
    fn serial_and_batched_agree_bitwise() {
        for tau in [0.1f32, 0.4, 0.7, 10.0] {
            let a = outcome(SweepMode::Serial, 1, tau);
            for workers in [1usize, 2, 4] {
                let b = outcome(SweepMode::Batched { workers }, workers, tau);
                assert_eq!(a.removed, b.removed, "tau={tau} workers={workers}");
                assert_eq!(a.removed_count, b.removed_count);
                assert_eq!(
                    a.final_metric.to_bits(),
                    b.final_metric.to_bits(),
                    "final metric bit-identical (tau={tau})"
                );
                assert_eq!(a.trace.len(), b.trace.len());
                for (x, y) in a.trace.iter().zip(&b.trace) {
                    assert_eq!(x.removed, y.removed);
                    assert_eq!(x.edges_remaining, y.edges_remaining);
                    assert_eq!(x.metric.to_bits(), y.metric.to_bits());
                }
            }
        }
    }

    #[test]
    fn serial_eval_count_is_exact() {
        let out = outcome(SweepMode::Serial, 1, 0.4);
        assert_eq!(out.n_evals, 12 + 1);
    }

    #[test]
    fn batched_eval_count_bounded_by_misprediction_model() {
        // every misprediction wastes at most (window - 1) evals, and there
        // are at most `total` mispredictions: n_evals <= 1 + total * window
        // (window here clamps to the channel width of 4)
        let out = outcome(SweepMode::Batched { workers: 4 }, 4, 0.4);
        assert!(out.n_evals >= 12 + 1);
        assert!(out.n_evals <= 1 + 12 * 4, "evals {}", out.n_evals);
    }

    #[test]
    fn fn_scorer_parallel_matches_serial() {
        let surface = SyntheticSurface::new(7, 0.05);
        let plan = plan_3x4();
        let cands: Vec<Candidate> = plan.iter().flatten().copied().collect();
        let mask = PatchMask::empty(3);
        let score = |m: &PatchMask, c: Option<&Candidate>| surface.damage(m, c);
        let mut serial = FnScorer { score, workers: 1 };
        let mut threaded = FnScorer { score, workers: 5 };
        let a = serial.score_batch(&mask, &cands).unwrap();
        let b = threaded.score_batch(&mask, &cands).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn sweep_mode_parsing() {
        assert_eq!(SweepMode::parse("serial", 8).unwrap(), SweepMode::Serial);
        assert_eq!(SweepMode::parse("batched", 8).unwrap(), SweepMode::Batched { workers: 8 });
        assert_eq!(SweepMode::parse("batched", 0).unwrap().workers(), 1);
        assert!(SweepMode::parse("speculative", 1).is_err());
        assert_eq!(SweepMode::Batched { workers: 4 }.label(), "batched[4]");
    }

    #[test]
    fn surface_is_deterministic_and_mask_sensitive() {
        let s = SyntheticSurface::new(3, 0.1);
        let mut m = PatchMask::empty(2);
        let c = Candidate { chan: 1, src: 5, hi: None };
        let d0 = s.damage(&m, Some(&c));
        assert_eq!(d0.to_bits(), s.damage(&m, Some(&c)).to_bits());
        m.set(0, 2, true);
        let d1 = s.damage(&m, Some(&c));
        assert!(d1 > d0, "interaction term responds to the mask");
        let hi = Candidate { chan: 1, src: 5, hi: Some(5) };
        assert_ne!(s.damage(&m, Some(&hi)).to_bits(), d1.to_bits(), "hi perturbs");
    }
}
