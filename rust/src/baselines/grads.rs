//! Typed view over the `grads.hlo.txt` output tuple.
//!
//! Tuple order (see aot.export_grads):
//!   attn-only: metric, embed [B,S,D], attn [L,H,B,S,D],
//!              gq, gk, gv, ghout [L,H,B,S,D], gfinal [B,S,D]
//!   with MLP:  metric, embed, attn, mlp [L,B,S,D],
//!              gq, gk, gv, ghout, gmlp [L,B,S,D], gfinal
//!
//! Per-head tensors are head-major, so every node's [B,S,D] block is a
//! contiguous slice.

use anyhow::{bail, Result};

use crate::model::{Channel, Graph, Manifest, NodeId};
use crate::tensor::Tensor;

pub struct GradBundle {
    pub metric: f32,
    outs: Vec<Tensor>,
    has_mlp: bool,
    bsd: usize,
    pub n_layer: usize,
    n_head: usize,
}

impl GradBundle {
    pub fn new(m: &Manifest, outs: Vec<Tensor>) -> Result<GradBundle> {
        let want = if m.has_mlp() { 10 } else { 8 };
        if outs.len() != want {
            bail!("grads artifact returned {} outputs, expected {want}", outs.len());
        }
        Ok(GradBundle {
            metric: outs[0].data[0],
            bsd: m.batch * m.seq_len * m.d_model,
            has_mlp: m.has_mlp(),
            n_layer: m.n_layer,
            n_head: m.n_head,
            outs,
        })
    }

    fn idx(&self, name: &str) -> usize {
        // attn-only: [metric, embed, attn, gq, gk, gv, ghout, gfinal]
        // mlp:       [metric, embed, attn, mlp, gq, gk, gv, ghout, gmlp, gfinal]
        let base: &[&str] = if self.has_mlp {
            &["metric", "embed", "attn", "mlp", "gq", "gk", "gv", "ghout", "gmlp", "gfinal"]
        } else {
            &["metric", "embed", "attn", "gq", "gk", "gv", "ghout", "gfinal"]
        };
        base.iter().position(|&n| n == name).unwrap()
    }

    fn head_slice<'a>(&'a self, name: &str, layer: usize, head: usize) -> &'a [f32] {
        let t = &self.outs[self.idx(name)];
        let off = (layer * self.n_head + head) * self.bsd;
        &t.data[off..off + self.bsd]
    }

    fn layer_slice<'a>(&'a self, name: &str, layer: usize) -> &'a [f32] {
        let t = &self.outs[self.idx(name)];
        &t.data[layer * self.bsd..(layer + 1) * self.bsd]
    }

    /// Activation of a node's output ([B,S,D] flat).
    pub fn node_act(&self, g: &Graph, node: NodeId) -> &[f32] {
        match g.node_kind(node) {
            crate::model::graph::NodeKind::Embed => &self.outs[self.idx("embed")].data,
            crate::model::graph::NodeKind::Head { layer, head } => {
                self.head_slice("attn", layer, head)
            }
            crate::model::graph::NodeKind::Mlp { layer } => self.layer_slice("mlp", layer),
        }
    }

    /// dL/d(channel input) for a destination channel ([B,S,D] flat).
    pub fn chan_grad(&self, ch: Channel) -> &[f32] {
        match ch {
            Channel::Head { layer, head, comp } => {
                let name = ["gq", "gk", "gv"][comp as usize];
                self.head_slice(name, layer, head)
            }
            Channel::Mlp { layer } => self.layer_slice("gmlp", layer),
            Channel::Final => &self.outs[self.idx("gfinal")].data,
        }
    }

    /// dL/d(head output) — HISP's importance signal.
    pub fn head_out_grad(&self, layer: usize, head: usize) -> &[f32] {
        self.head_slice("ghout", layer, head)
    }
}
