//! Edge Pruning (Bhaskar et al. 2024): learn a continuous mask per edge
//! by gradient descent, interpolating each edge between its clean and
//! corrupted contribution, then binarize.
//!
//! Objective:  KL(clean_ref || model(M)) + λ Σ M    (M in [0,1]^|E|)
//!
//! optimized with Adam on the AOT `edge_mask_grads` artifact. The Tab. 8
//! comparison sweeps training steps {400, 800, 1600, 3000} and dataset
//! sizes: like the original implementation, the step budget is *fixed
//! regardless of dataset size* (the point the paper's appendix D makes),
//! with batches rotating through a pool of `dataset_size` examples.

use anyhow::{bail, Result};

use crate::discovery::{self, Discovery, DiscoveryConfig, RunRecord, Session, Task};
use crate::model::Graph;
use crate::patching::PatchedForward;
use crate::runtime::Input;
use crate::tasks::Vocab;
use crate::util::rng::Rng;

pub struct EpConfig {
    pub steps: usize,
    pub lr: f32,
    pub lambda: f32,
    /// examples in the training pool (paper Tab. 8's "dataset size");
    /// 0 = just the engine's fixed evaluation batch
    pub dataset_size: usize,
    /// rotate the batch every `rotate_every` steps (0 = never)
    pub rotate_every: usize,
    pub seed: u64,
}

impl Default for EpConfig {
    fn default() -> Self {
        EpConfig { steps: 400, lr: 0.05, lambda: 0.01, dataset_size: 0, rotate_every: 0, seed: 7 }
    }
}

pub struct EpResult {
    /// learned masks per edge, aligned with `graph.edges()` order
    pub edge_scores: Vec<f32>,
    pub final_kl: f32,
    pub steps_run: usize,
    pub wall: std::time::Duration,
}

struct Masks {
    mq: Vec<f32>, // [L,H,N]
    mk: Vec<f32>,
    mv: Vec<f32>,
    mm: Vec<f32>, // [L,N]
    mf: Vec<f32>, // [N]
}

struct Adam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: i32,
}

impl Adam {
    fn new(n: usize) -> Adam {
        Adam { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        self.t += 1;
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let bc1 = 1.0 - b1.powi(self.t);
        let bc2 = 1.0 - b2.powi(self.t);
        let lr_t = lr * bc2.sqrt() / bc1;
        for i in 0..params.len() {
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * grads[i];
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * grads[i] * grads[i];
            params[i] = (params[i] - lr_t * self.m[i] / (self.v[i].sqrt() + eps)).clamp(0.0, 1.0);
        }
    }
}

/// Decode (packed) corrupted node outputs into the artifact's [N,B,S,D]
/// layout.
fn corrupt_nodes(engine: &PatchedForward) -> (Vec<f32>, Vec<usize>) {
    let m = &engine.manifest;
    let n = engine.graph.n_nodes();
    let bsd = m.batch * m.seq_len * m.d_model;
    let mut out = vec![0.0f32; n * bsd];
    for node in 0..n {
        engine.corrupt_cache[node].decode_into(&mut out[node * bsd..(node + 1) * bsd]);
    }
    (out, vec![n, m.batch, m.seq_len, m.d_model])
}

pub fn train(engine: &mut PatchedForward, cfg: &EpConfig) -> Result<EpResult> {
    let t0 = std::time::Instant::now();
    let m = engine.manifest.clone();
    if !m.artifacts.iter().any(|a| a == "edge_mask_grads.hlo.txt") {
        bail!("{}: edge_mask_grads artifact not exported", m.name);
    }
    let g = engine.graph.clone();
    let (l, h, n) = (m.n_layer, m.n_head, g.n_nodes());

    let mut masks = Masks {
        mq: vec![1.0; l * h * n],
        mk: vec![1.0; l * h * n],
        mv: vec![1.0; l * h * n],
        mm: vec![1.0; l.max(1) * n],
        mf: vec![1.0; n],
    };
    let mut opt = [
        Adam::new(masks.mq.len()),
        Adam::new(masks.mk.len()),
        Adam::new(masks.mv.len()),
        Adam::new(masks.mm.len()),
        Adam::new(masks.mf.len()),
    ];

    // dataset pool for batch rotation
    let pool = if cfg.dataset_size > 0 {
        let vocab = Vocab::load()?;
        Some(vocab.make_dataset(&engine.examples_task_guess(), cfg.dataset_size, cfg.seed)?)
    } else {
        None
    };
    let mut rng = Rng::new(cfg.seed ^ 0xabcdef);

    let (mut c_nodes, c_shape) = corrupt_nodes(engine);
    let mut final_kl = 0.0;
    for step in 0..cfg.steps {
        if let (Some(pool), true) = (
            &pool,
            cfg.rotate_every > 0 && step > 0 && step % cfg.rotate_every == 0,
        ) {
            // rotate the evaluation batch through the pool
            let batch: Vec<_> = (0..m.batch)
                .map(|_| pool[rng.below(pool.len())].clone())
                .collect();
            engine.set_examples(batch)?;
            let packed = corrupt_nodes(engine);
            c_nodes = packed.0;
        }
        let sh_q = [l, h, n];
        let sh_m = [l.max(1), n];
        let sh_f = [n];
        let outs = {
            let extras = [
                Input::new(&c_shape, &c_nodes),
                Input::new(&sh_q, &masks.mq),
                Input::new(&sh_q, &masks.mk),
                Input::new(&sh_q, &masks.mv),
                Input::new(&sh_m, &masks.mm),
                Input::new(&sh_f, &masks.mf),
            ];
            engine.run_grad_artifact("edge_mask_grads.hlo.txt", false, false, &extras)?
        };
        final_kl = outs[0].data[0];
        // grads + λ, only on causally-valid entries (invalid stay at 1)
        let lam = cfg.lambda;
        let apply = |params: &mut [f32], grads: &[f32], opt: &mut Adam| {
            let gl: Vec<f32> = grads.iter().map(|&d| d + lam).collect();
            opt.step(params, &gl, cfg.lr);
        };
        apply(&mut masks.mq, &outs[1].data, &mut opt[0]);
        apply(&mut masks.mk, &outs[2].data, &mut opt[1]);
        apply(&mut masks.mv, &outs[3].data, &mut opt[2]);
        apply(&mut masks.mm, &outs[4].data, &mut opt[3]);
        apply(&mut masks.mf, &outs[5].data, &mut opt[4]);
        reset_invalid(&g, &mut masks);
    }

    // per-edge scores from the learned masks
    let mut edge_scores = Vec::with_capacity(g.n_edges());
    for e in g.edges() {
        let v = match e.dst {
            crate::model::Channel::Head { layer, head, comp } => {
                let base = (layer * h + head) * n + e.src;
                match comp {
                    0 => masks.mq[base],
                    1 => masks.mk[base],
                    _ => masks.mv[base],
                }
            }
            crate::model::Channel::Mlp { layer } => masks.mm[layer * n + e.src],
            crate::model::Channel::Final => masks.mf[e.src],
        };
        edge_scores.push(v);
    }
    Ok(EpResult { edge_scores, final_kl, steps_run: cfg.steps, wall: t0.elapsed() })
}

/// Entries for causally-invalid (non-)edges must stay pinned at 1 so they
/// keep contributing the clean activation (they receive spurious zero
/// gradients plus λ pressure otherwise).
fn reset_invalid(g: &Graph, masks: &mut Masks) {
    let n = g.n_nodes();
    let h = g.n_head;
    for layer in 0..g.n_layer {
        let valid = g.sources(crate::model::Channel::Head { layer, head: 0, comp: 0 });
        for head in 0..h {
            for src in 0..n {
                if !valid.contains(&src) {
                    let base = (layer * h + head) * n + src;
                    masks.mq[base] = 1.0;
                    masks.mk[base] = 1.0;
                    masks.mv[base] = 1.0;
                }
            }
        }
        if g.has_mlp {
            let valid = g.sources(crate::model::Channel::Mlp { layer });
            for src in 0..n {
                if !valid.contains(&src) {
                    masks.mm[layer * n + src] = 1.0;
                }
            }
        }
    }
}

/// Edge Pruning through the unified [`Discovery`] interface: masks
/// trained at FP32 (`cfg.ep_steps` Adam steps, fixed evaluation batch)
/// order the candidates by learned mask value; the shared sweep
/// verifies them under the session policy — replacing the fixed 0.5
/// binarization with the same damage-thresholded decision every other
/// method uses.
pub struct EdgePruning;

impl Discovery for EdgePruning {
    fn name(&self) -> &'static str {
        "edge-pruning"
    }

    fn discover(
        &self,
        session: &mut Session,
        _task: &Task,
        cfg: &DiscoveryConfig,
    ) -> Result<RunRecord> {
        let t0 = std::time::Instant::now();
        let ep_cfg = EpConfig { steps: cfg.ep_steps, ..Default::default() };
        let s =
            discovery::scored_at_fp32(session, cfg, |e| Ok(train(e, &ep_cfg)?.edge_scores))?;
        let plan = discovery::ordered_plan(&session.engine, &s);
        session.run_plan(self.name(), cfg, &plan, t0)
    }
}

impl PatchedForward {
    /// Best-effort task name of the current examples (pool regeneration).
    /// The engine doesn't persist the task string; infer from prompt
    /// template length/structure via the dataset artifacts.
    pub fn examples_task_guess(&self) -> String {
        // IOI answer position 14, docstring 17, greater-than 10 (template
        // constants shared with python's tasks.py)
        match self.examples.first().map(|e| e.pos) {
            Some(14) => "ioi",
            Some(17) => "docstring",
            Some(10) => "greater_than",
            _ => "ioi",
        }
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_training_reduces_kl_and_sparsifies() {
        let Ok(mut e) = PatchedForward::new("redwood2l-sim", "ioi") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let cfg = EpConfig { steps: 25, lr: 0.08, lambda: 0.02, ..Default::default() };
        let res = train(&mut e, &cfg).unwrap();
        assert_eq!(res.edge_scores.len(), e.graph.n_edges());
        assert!(res.edge_scores.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(res.final_kl.is_finite());
        // λ pressure pushed some masks down
        assert!(res.edge_scores.iter().any(|&v| v < 0.9));
    }

    #[test]
    fn task_guess_matches_loaded_dataset() {
        let Ok(e) = PatchedForward::new("redwood2l-sim", "greater_than") else { return };
        assert_eq!(e.examples_task_guess(), "greater_than");
    }
}
