//! SP — Subnetwork Probing (Cao et al. 2021), adapted to circuit
//! discovery as in the ACDC paper's comparison.
//!
//! Learns a gate g_v in [0,1] per node; a gated node's output
//! interpolates between its clean computation (g=1) and the cached
//! corrupted activation (g=0). The objective is
//!
//!   KL(clean_ref || model(gates)) + λ Σ_v g_v
//!
//! minimized by projected gradient descent, gradients supplied by the AOT
//! `gate_grads` artifact. λ sweeps produce the sparsity/faithfulness
//! trade-off; per-edge scores are the source node's learned gate.

use anyhow::{bail, Result};

use crate::discovery::{self, Discovery, DiscoveryConfig, RunRecord, Session, Task};
use crate::model::Graph;
use crate::patching::PatchedForward;
use crate::runtime::Input;
use crate::tensor::Tensor;

pub struct SpConfig {
    pub steps: usize,
    pub lr: f32,
    pub lambda: f32,
}

impl Default for SpConfig {
    fn default() -> Self {
        SpConfig { steps: 80, lr: 0.08, lambda: 0.02 }
    }
}

/// Decode the engine's (packed) corrupted node caches into the
/// artifact's [L,H,B,S,D] (head-major) + [L,B,S,D] layouts.
fn corrupt_caches(engine: &PatchedForward) -> (Vec<f32>, Vec<f32>, Vec<usize>, Vec<usize>) {
    let m = &engine.manifest;
    let g = &engine.graph;
    let bsd = m.batch * m.seq_len * m.d_model;
    let mut attn = vec![0.0f32; m.n_layer * m.n_head * bsd];
    for l in 0..m.n_layer {
        for h in 0..m.n_head {
            let node = g.head_node(l, h);
            let off = (l * m.n_head + h) * bsd;
            engine.corrupt_cache[node].decode_into(&mut attn[off..off + bsd]);
        }
    }
    let attn_shape = vec![m.n_layer, m.n_head, m.batch, m.seq_len, m.d_model];
    if m.has_mlp() {
        let mut mlp = vec![0.0f32; m.n_layer * bsd];
        for l in 0..m.n_layer {
            let node = g.mlp_node(l);
            engine.corrupt_cache[node].decode_into(&mut mlp[l * bsd..(l + 1) * bsd]);
        }
        (attn, mlp, attn_shape, vec![m.n_layer, m.batch, m.seq_len, m.d_model])
    } else {
        (attn, vec![0.0; m.n_layer], attn_shape, vec![m.n_layer, 1, 1, 1])
    }
}

/// One SP training run; returns (gates, final KL).
pub fn train_gates(engine: &mut PatchedForward, cfg: &SpConfig) -> Result<(Vec<f32>, f32)> {
    let m = engine.manifest.clone();
    if !m.artifacts.iter().any(|a| a == "gate_grads.hlo.txt") {
        bail!("{}: gate_grads artifact not exported (scale models skip SP)", m.name);
    }
    let n = engine.graph.n_nodes();
    let (attn_c, mlp_c, attn_shape, mlp_shape) = corrupt_caches(engine);
    let mut gates = vec![1.0f32; n];
    let mut last_metric = 0.0;
    for _ in 0..cfg.steps {
        let sh_n = [n];
        let outs = {
            let extras = [
                Input::new(&sh_n, &gates),
                Input::new(&attn_shape, &attn_c),
                Input::new(&mlp_shape, &mlp_c),
            ];
            engine.run_grad_artifact("gate_grads.hlo.txt", false, false, &extras)?
        };
        let (metric, dg): (&Tensor, &Tensor) = (&outs[0], &outs[1]);
        last_metric = metric.data[0];
        for i in 0..n {
            gates[i] = (gates[i] - cfg.lr * (dg.data[i] + cfg.lambda)).clamp(0.0, 1.0);
        }
        // embed anchors the stream: never gated off
        gates[Graph::EMBED] = 1.0;
    }
    Ok((gates, last_metric))
}

/// Per-edge scores: the learned gate of the edge's source node.
pub fn scores(engine: &mut PatchedForward, cfg: &SpConfig) -> Result<Vec<f32>> {
    let (gates, _) = train_gates(engine, cfg)?;
    let g = engine.graph.clone();
    Ok(g.edges().iter().map(|e| gates[e.src]).collect())
}

/// SP through the unified [`Discovery`] interface: gates trained at
/// FP32 (`cfg.sp_steps` projected-gradient steps) order the candidates
/// by the source node's learned gate; the shared sweep verifies them
/// under the session policy.
pub struct Sp;

impl Discovery for Sp {
    fn name(&self) -> &'static str {
        "sp"
    }

    fn discover(
        &self,
        session: &mut Session,
        _task: &Task,
        cfg: &DiscoveryConfig,
    ) -> Result<RunRecord> {
        let t0 = std::time::Instant::now();
        let sp_cfg = SpConfig { steps: cfg.sp_steps, ..Default::default() };
        let s = discovery::scored_at_fp32(session, cfg, |e| scores(e, &sp_cfg))?;
        let plan = discovery::ordered_plan(&session.engine, &s);
        session.run_plan(self.name(), cfg, &plan, t0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gates_sparsify_under_lambda() {
        let Ok(mut e) = PatchedForward::new("redwood2l-sim", "ioi") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let cfg = SpConfig { steps: 70, lr: 0.15, lambda: 0.08 };
        let (gates, kl) = train_gates(&mut e, &cfg).unwrap();
        assert_eq!(gates.len(), e.graph.n_nodes());
        assert!(gates.iter().all(|&g| (0.0..=1.0).contains(&g)));
        assert_eq!(gates[Graph::EMBED], 1.0);
        // λ pressure turned some gates down...
        assert!(gates.iter().any(|&g| g < 0.5), "some node gated off");
        // ...but not all: the KL term defends the circuit
        assert!(gates.iter().any(|&g| g > 0.5), "some node kept");
        assert!(kl.is_finite());
    }
}
