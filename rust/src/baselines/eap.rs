//! EAP — Edge Attribution Patching (Syed et al. 2023).
//!
//! First-order approximation of every edge's patching effect from a single
//! forward+backward pair (paper Eq. 22):
//!
//!   score(u -> c) = | (z_corrupt_u - z_clean_u) · dL/d input_c |
//!
//! For the task metric, gradients are taken on the clean run (standard
//! EAP). For the KL metric, the clean run sits at the KL minimum (zero
//! gradient), so gradients are taken at the corrupted input — the
//! convention of Hanna et al. 2024's KL-EAP.
//!
//! This is O(1) model executions vs ACDC's O(|E|); its weakness — the
//! linear approximation degrading through multi-layer nonlinearities — is
//! visible in Tab. 1 exactly as the paper reports (EAP trails ACDC/PAHQ on
//! IOI).

use anyhow::Result;

use crate::discovery::{self, Discovery, DiscoveryConfig, RunRecord, Session, Task};
use crate::metrics::Objective;
use crate::patching::PatchedForward;
use crate::tensor::dot;

use super::grads::GradBundle;

/// Per-edge attribution scores aligned with `graph.edges()` order.
pub fn scores(engine: &mut PatchedForward, obj: Objective) -> Result<Vec<f32>> {
    let sel = obj == Objective::LogitDiff;
    let m = engine.manifest.clone();
    let clean = GradBundle::new(&m, engine.run_grads(false, sel)?)?;
    let corrupt = GradBundle::new(&m, engine.run_grads(true, sel)?)?;
    // gradient run: clean for the task metric, corrupt for KL (see docs)
    let grad_run = match obj {
        Objective::LogitDiff => &clean,
        Objective::Kl => &corrupt,
    };
    let g = engine.graph.clone();
    let mut out = Vec::with_capacity(g.n_edges());
    for e in g.edges() {
        let zc = clean.node_act(&g, e.src);
        let zx = corrupt.node_act(&g, e.src);
        let grad = grad_run.chan_grad(e.dst);
        // (z' - z) · g without materializing the difference
        let s = dot(zx, grad) - dot(zc, grad);
        out.push(s.abs());
    }
    Ok(out)
}

/// EAP through the unified [`Discovery`] interface: attribution scores
/// from one FP32 forward+backward pair order the candidates, then the
/// shared verification sweep prunes them under the session policy —
/// giving EAP the PAHQ mixed-precision evaluations and the batched
/// multi-worker scoring ACDC already has.
pub struct Eap;

impl Discovery for Eap {
    fn name(&self) -> &'static str {
        "eap"
    }

    fn discover(
        &self,
        session: &mut Session,
        _task: &Task,
        cfg: &DiscoveryConfig,
    ) -> Result<RunRecord> {
        let t0 = std::time::Instant::now();
        let obj = cfg.objective;
        let s = discovery::scored_at_fp32(session, cfg, |e| scores(e, obj))?;
        let plan = discovery::ordered_plan(&session.engine, &s);
        session.run_plan(self.name(), cfg, &plan, t0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scores_align_with_single_edge_patching() {
        // EAP is a first-order approximation of the exact per-edge ΔL —
        // rank correlation with the exhaustive ground truth should be
        // clearly positive (it's the method's entire premise).
        let Ok(mut e) = PatchedForward::new("redwood2l-sim", "ioi") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let s = scores(&mut e, Objective::LogitDiff).unwrap();
        assert_eq!(s.len(), e.graph.n_edges());
        assert!(s.iter().any(|&v| v > 0.0), "some edges matter");
        let gt = crate::eval::ground_truth(&mut e, "redwood2l-sim", "ioi", Objective::Kl).unwrap();
        // Spearman-ish check: mean score of true-circuit edges exceeds
        // mean score of non-circuit edges by a solid factor
        let (mut in_c, mut out_c, mut n_in, mut n_out) = (0.0f64, 0.0f64, 0, 0);
        for (i, &m) in gt.member.iter().enumerate() {
            if m {
                in_c += s[i] as f64;
                n_in += 1;
            } else {
                out_c += s[i] as f64;
                n_out += 1;
            }
        }
        let (mi, mo) = (in_c / n_in.max(1) as f64, out_c / n_out.max(1) as f64);
        assert!(mi > 2.0 * mo, "circuit edges score higher: {mi:.4} vs {mo:.4}");
    }
}
