//! Baseline circuit-discovery methods the paper compares against
//! (Tab. 1 / Tab. 8): EAP, HISP, SP, Edge Pruning. RTN-Q needs no code of
//! its own — it is ACDC under [`crate::patching::Policy::rtn`].
//!
//! All gradient-based baselines consume AOT gradient artifacts (lowered by
//! `aot.py` from the pure-jnp reference path) executed through PJRT; the
//! Rust side owns the optimization loops and scoring.
//!
//! Each baseline also implements [`crate::discovery::Discovery`]
//! (`Eap` / `Hisp` / `Sp` / `EdgePruning`): attribution scores order the
//! candidate edges, and the shared `acdc::sweep` verification sweep —
//! with the session's PAHQ precision policy and batched multi-worker
//! scoring — decides the kept set.

pub mod eap;
pub mod edge_pruning;
pub mod grads;
pub mod hisp;
pub mod sp;

pub use eap::Eap;
pub use edge_pruning::EdgePruning;
pub use grads::GradBundle;
pub use hisp::Hisp;
pub use sp::Sp;
