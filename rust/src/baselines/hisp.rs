//! HISP — Head Importance Score Pruning (Michel et al. 2019, "Are sixteen
//! heads really better than one?").
//!
//! Head importance I_h = | Σ A_h ⊙ dL/dA_h | (activation × gradient at
//! the head's output, the first-order effect of gating the head off).
//! HISP prunes *heads*, not edges, so every edge inherits its source
//! head's importance; edges sourced at embed / MLP nodes (which HISP
//! cannot prune) receive the maximum score and are always kept.

use anyhow::Result;

use crate::discovery::{self, Discovery, DiscoveryConfig, RunRecord, Session, Task};
use crate::metrics::Objective;
use crate::patching::PatchedForward;
use crate::tensor::dot;

use super::grads::GradBundle;

/// Per-head importance scores [L][H].
pub fn head_importance(engine: &mut PatchedForward, obj: Objective) -> Result<Vec<Vec<f32>>> {
    let sel = obj == Objective::LogitDiff;
    let m = engine.manifest.clone();
    // gradients at the corrupted input for KL (clean sits at the minimum)
    let run_corrupt = obj == Objective::Kl;
    let bundle = GradBundle::new(&m, engine.run_grads(run_corrupt, sel)?)?;
    let g = engine.graph.clone();
    let mut out = Vec::with_capacity(m.n_layer);
    for l in 0..m.n_layer {
        let mut row = Vec::with_capacity(m.n_head);
        for h in 0..m.n_head {
            let act = bundle.node_act(&g, g.head_node(l, h));
            let grad = bundle.head_out_grad(l, h);
            row.push(dot(act, grad).abs());
        }
        out.push(row);
    }
    Ok(out)
}

/// Per-edge scores: source head's importance; non-head sources -> +max.
pub fn scores(engine: &mut PatchedForward, obj: Objective) -> Result<Vec<f32>> {
    let imp = head_importance(engine, obj)?;
    let max = imp
        .iter()
        .flatten()
        .copied()
        .fold(0.0f32, f32::max)
        .max(1e-9);
    let g = engine.graph.clone();
    Ok(g.edges()
        .iter()
        .map(|e| match g.node_kind(e.src) {
            crate::model::graph::NodeKind::Head { layer, head } => imp[layer][head],
            _ => max * 2.0, // embed / MLP sources are never pruned by HISP
        })
        .collect())
}

/// HISP through the unified [`Discovery`] interface: head-importance
/// scores (at FP32) order the candidates, the shared sweep verifies
/// them under the session policy. Embed / MLP sources carry +max
/// importance, so they are verified last — HISP cannot prune them
/// cheaply, matching the method's head-only semantics.
pub struct Hisp;

impl Discovery for Hisp {
    fn name(&self) -> &'static str {
        "hisp"
    }

    fn discover(
        &self,
        session: &mut Session,
        _task: &Task,
        cfg: &DiscoveryConfig,
    ) -> Result<RunRecord> {
        let t0 = std::time::Instant::now();
        let obj = cfg.objective;
        let s = discovery::scored_at_fp32(session, cfg, |e| scores(e, obj))?;
        let plan = discovery::ordered_plan(&session.engine, &s);
        session.run_plan(self.name(), cfg, &plan, t0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn importance_nonnegative_and_informative() {
        let Ok(mut e) = PatchedForward::new("redwood2l-sim", "ioi") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let imp = head_importance(&mut e, Objective::LogitDiff).unwrap();
        assert_eq!(imp.len(), e.manifest.n_layer);
        let flat: Vec<f32> = imp.iter().flatten().copied().collect();
        assert!(flat.iter().all(|&v| v >= 0.0));
        let max = flat.iter().copied().fold(0.0f32, f32::max);
        let min = flat.iter().copied().fold(f32::MAX, f32::min);
        assert!(max > 5.0 * (min + 1e-9), "heads differentiate: {min} .. {max}");
        let s = scores(&mut e, Objective::LogitDiff).unwrap();
        assert_eq!(s.len(), e.graph.n_edges());
    }
}
