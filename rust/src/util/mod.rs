//! Offline-friendly substrates: this box has no crates.io access beyond the
//! vendored `xla`/`anyhow`, so JSON, RNG, CLI parsing and the bench harness
//! are built in-repo.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
