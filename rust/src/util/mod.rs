//! Offline-friendly substrates: this box has no crates.io access beyond
//! the crates vendored under `vendor/`, so JSON, RNG, and CLI parsing are
//! built in-repo (benchmarks use the vendored criterion shim).

pub mod cli;
pub mod json;
pub mod rng;
pub mod sync;
