//! Minimal JSON parser/emitter (no serde offline).
//!
//! Covers the full JSON grammar the artifact pipeline emits (objects,
//! arrays, strings with escapes, numbers, bool, null). Numbers are stored
//! as f64 — token ids and offsets all fit exactly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    // ---- emission --------------------------------------------------------

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at offset {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected character at offset {}", self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| anyhow!("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| anyhow!("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs: enough for our ASCII manifests
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c => {
                    // re-decode UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let bytes = &self.b[start..(start + len).min(self.b.len())];
                        s.push_str(std::str::from_utf8(bytes)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [1.5, -2e3, true, null], "c": {"d": "x\ny"}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_str().unwrap(), "x\ny");
        // dump -> parse -> equal
        let again = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn parses_unicode_and_escapes() {
        let v = Json::parse(r#"["A", "ü", "\t"]"#).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr[0].as_str().unwrap(), "A");
        assert_eq!(arr[1].as_str().unwrap(), "ü");
        assert_eq!(arr[2].as_str().unwrap(), "\t");
    }

    #[test]
    fn numbers_precise() {
        let v = Json::parse("[0, -7, 3.25, 1e-3, 16777216]").unwrap();
        let xs = v.f32_vec().unwrap();
        assert_eq!(xs, vec![0.0, -7.0, 3.25, 0.001, 16777216.0]);
    }

    #[test]
    fn builder() {
        let v = obj(vec![("x", Json::from(3usize)), ("s", Json::from("hi"))]);
        assert_eq!(v.dump(), r#"{"s":"hi","x":3}"#);
    }
}
