//! Micro-benchmark harness (no criterion offline).
//!
//! Warmup + repeated timed batches, reporting median / p10 / p90 of
//! per-iteration time. Used by `cargo bench` targets (`harness = false`).

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl BenchResult {
    pub fn throughput_label(&self, bytes_per_iter: Option<u64>) -> String {
        match bytes_per_iter {
            Some(b) => {
                let gbps = b as f64 / self.median_ns;
                format!(" ({gbps:.2} GB/s)")
            }
            None => String::new(),
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Time `f`, auto-scaling the iteration count to fill ~`budget` and
/// reporting batch-level percentiles.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration: find iters/batch so one batch is ~10ms.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as u64;
    let per_batch = (10_000_000 / once).clamp(1, 1_000_000);

    let n_batches = (budget.as_nanos() as u64 / (once * per_batch).max(1)).clamp(5, 200);
    let mut samples = Vec::with_capacity(n_batches as usize);
    for _ in 0..n_batches {
        let t = Instant::now();
        for _ in 0..per_batch {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / per_batch as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    let r = BenchResult {
        name: name.to_string(),
        iters: per_batch * n_batches,
        median_ns: pick(0.5),
        p10_ns: pick(0.1),
        p90_ns: pick(0.9),
    };
    println!(
        "bench {:<44} {:>12} median  [{} .. {}]  ({} iters)",
        r.name,
        fmt_ns(r.median_ns),
        fmt_ns(r.p10_ns),
        fmt_ns(r.p90_ns),
        r.iters
    );
    r
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-ish", Duration::from_millis(50), || {
            black_box((0..100u64).sum::<u64>());
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
    }
}
