//! Tiny CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positionals, with
//! typed accessors and an auto-generated usage string.

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Present-or-absent integer option — `None` when the flag was not
    /// given at all. The spec builders ([`crate::api`]) use this for
    /// flags whose *presence* changes validation (`--workers` is only
    /// legal with a batched sweep), where a default would erase the
    /// distinction.
    pub fn usize_opt(&self, name: &str) -> Result<Option<usize>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Seed-sized integer option (`--seed S` and friends).
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Comma-separated list option.
    pub fn list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }

    /// `--json PATH` — where a command writes its machine-readable
    /// artifact (a `RunRecord` for `pahq run`, a bench snapshot for
    /// `pahq bench`). `None` means the command's default path under
    /// `rust/results/`.
    pub fn json_path(&self) -> Option<&str> {
        self.get("json")
    }

    /// The sweep schedule from `--sweep serial|batched [--workers N]`.
    /// `--workers N` sets the scoring threads for the batched schedule
    /// and defaults to the machine's available parallelism; results are
    /// bit-identical to `--sweep serial` at any worker count.
    pub fn sweep_mode(&self) -> Result<crate::acdc::SweepMode> {
        let default_workers =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        let workers = self.usize_or("workers", default_workers)?;
        crate::acdc::SweepMode::parse(self.get_or("sweep", "serial"), workers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        let a = parse("table 3 --model gpt2s-sim --tau=0.01 --verbose --seed 7");
        assert_eq!(a.positional, vec!["table", "3"]);
        assert_eq!(a.get("model"), Some("gpt2s-sim"));
        assert_eq!(a.get("tau"), Some("0.01"));
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_or("seed", 0).unwrap(), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--quick");
        assert!(a.flag("quick"));
        assert!(a.get("quick").is_none());
    }

    #[test]
    fn typed_errors() {
        let a = parse("--n abc");
        assert!(a.usize_or("n", 1).is_err());
        assert_eq!(a.usize_or("m", 5).unwrap(), 5);
        assert_eq!(a.f64_or("x", 1.5).unwrap(), 1.5);
        assert!(a.u64_or("n", 1).is_err());
        assert_eq!(a.u64_or("seed", 9).unwrap(), 9);
        assert_eq!(parse("--seed 7").u64_or("seed", 0).unwrap(), 7);
    }

    #[test]
    fn optional_integers_track_presence() {
        assert_eq!(parse("--workers 6").usize_opt("workers").unwrap(), Some(6));
        assert_eq!(parse("run").usize_opt("workers").unwrap(), None);
        assert!(parse("--workers six").usize_opt("workers").is_err());
    }

    #[test]
    fn lists() {
        let a = parse("--models a,b , --x 1");
        assert_eq!(a.list("models").unwrap(), vec!["a", "b"]);
    }

    #[test]
    fn json_path_passthrough() {
        assert_eq!(parse("bench --json out.json").json_path(), Some("out.json"));
        assert_eq!(parse("bench").json_path(), None);
    }

    #[test]
    fn sweep_modes() {
        use crate::acdc::SweepMode;
        assert_eq!(parse("run").sweep_mode().unwrap(), SweepMode::Serial);
        assert_eq!(
            parse("run --sweep batched --workers 6").sweep_mode().unwrap(),
            SweepMode::Batched { workers: 6 }
        );
        assert!(matches!(
            parse("run --sweep batched").sweep_mode().unwrap(),
            SweepMode::Batched { workers } if workers >= 1
        ));
        assert!(parse("run --sweep turbo").sweep_mode().is_err());
    }
}
