//! Poison-recovering lock helpers.
//!
//! `Mutex::lock().unwrap()` turns one panicking worker into a wedged
//! process: every later locker sees `PoisonError` and panics too, which
//! for `pahq serve` means connected clients hang instead of getting an
//! `internal` error frame. All the state guarded by mutexes in this
//! crate is kept consistent *before* any code that can panic runs (the
//! guards protect plain maps/queues whose invariants hold between
//! statements), so recovering the guard from a poison error is safe.
//!
//! Policy (enforced by `pahq lint`, rule `lock-unwrap`): library code
//! never calls `.lock().unwrap()` / `.lock().expect(..)`; it calls
//! [`lock_recover`] (and [`wait_recover`] for `Condvar` waits) instead.
//! See `docs/lint_rules.md` § `lock-unwrap`.

use std::sync::{Condvar, Mutex, MutexGuard};

/// Acquire `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Block on `cv` releasing `guard`, recovering the guard on poison.
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(0u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.is_poisoned());
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 1);
    }

    #[test]
    fn wait_recover_wakes_after_poisoned_notify() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let _ = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut flag = m.lock().unwrap();
            *flag = true;
            cv.notify_all();
            panic!("poison while holding the lock");
        })
        .join();
        let (m, cv) = &*pair;
        let mut flag = lock_recover(m);
        while !*flag {
            flag = wait_recover(cv, flag);
        }
        assert!(*flag);
    }
}
