//! xoshiro256** PRNG — deterministic, seedable, no external crates.
//!
//! Used for task generation, property tests, and workload sampling.
//! (Algorithm: Blackman & Vigna, public-domain reference constants.)

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the state vector.
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n) without modulo bias (rejection sampling).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut pool: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(9);
        let mut lo = f32::MAX;
        let mut hi = f32::MIN;
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
            lo = lo.min(v);
            hi = hi.max(v);
        }
        assert!(lo < 0.05 && hi > 0.95, "spread looks uniform-ish");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(3);
        for _ in 0..50 {
            let picks = r.choose_distinct(8, 5);
            let mut sorted = picks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 5);
            assert!(picks.iter().all(|&p| p < 8));
        }
    }
}
