//! Fake-quantization codecs — the Rust mirror of `python/compile/quantize.py`.
//!
//! The two implementations must agree **bit-for-bit**: the python side is
//! baked into the AOT HLO (activations quantize inside the kernels), the
//! Rust side prepares weight residency (FP8-resident copies vs FP32 master
//! rows) and emulates low-precision residual accumulation for the RTN-Q
//! baseline. `tests::vectors_match_python` replays the vectors exported by
//! `aot.py` (`artifacts/testvectors/fq_cases.json`).
//!
//! Algorithm (saturate-then-round, FTZ below 2^-126 quanta): see the long
//! comment in quantize.py — identical steps, identical rounding
//! (`round_ties_even`), identical quantum construction via exponent bit
//! placement.
//!
//! [`accumulate_quantized`] is the RTN lattice walk the packed kernels
//! must respect: each step is `acc = fq(acc + fq(x), f)` in element
//! order, so any vectorization of the packed variant
//! (`tensor::accumulate_quantized_packed`) may only batch the *decode*
//! of `x` — the accumulation itself is a sequential data dependence
//! through `fq` and is re-run here verbatim on each decoded tile.

use crate::tensor::Tensor;

/// A fake-quantization format: `(mbits, emin, maxv)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Format {
    /// mantissa bits; >= 23 means passthrough (FP32 sentinel)
    pub mbits: f32,
    /// minimum unbiased exponent of a normal value
    pub emin: f32,
    /// saturation bound
    pub maxv: f32,
}

pub const FP32: Format = Format { mbits: 99.0, emin: -126.0, maxv: 3.4e38 };
pub const FP16: Format = Format { mbits: 10.0, emin: -14.0, maxv: 65504.0 };
pub const BF16: Format = Format { mbits: 7.0, emin: -126.0, maxv: 3.39e38 };
pub const FP8_E4M3: Format = Format { mbits: 3.0, emin: -6.0, maxv: 448.0 };
pub const FP8_E5M2: Format = Format { mbits: 2.0, emin: -14.0, maxv: 57344.0 };
pub const FP4_E2M1: Format = Format { mbits: 1.0, emin: 0.0, maxv: 6.0 };

impl Format {
    pub fn by_name(name: &str) -> Option<Format> {
        Some(match name {
            "fp32" => FP32,
            "fp16" => FP16,
            "bf16" => BF16,
            "fp8_e4m3" => FP8_E4M3,
            "fp8_e5m2" => FP8_E5M2,
            "fp4_e2m1" => FP4_E2M1,
            _ => return None,
        })
    }

    /// Storage bits per element in the *emulated* format — drives packed
    /// [`crate::tensor::QTensor`] payload selection, the memory accounting
    /// (Tab. 3), and transfer sizes. fp4 reports its true packed cost of
    /// 4 bits (two elements per byte); byte totals come from
    /// [`Format::bytes_for`], which divides at the call site.
    pub fn storage_bits(&self) -> usize {
        if self.is_passthrough() {
            32
        } else if *self == FP16 || *self == BF16 {
            16
        } else if *self == FP8_E4M3 || *self == FP8_E5M2 {
            8
        } else if *self == FP4_E2M1 {
            4
        } else {
            // unknown custom formats are emulated in full f32 words
            32
        }
    }

    /// Packed bytes of `n` elements at this format (fp4: 0.5 bytes per
    /// element, rounded up to a whole byte at the end).
    pub fn bytes_for(&self, n: usize) -> usize {
        (n * self.storage_bits()).div_ceil(8)
    }

    pub fn is_passthrough(&self) -> bool {
        self.mbits >= 23.0
    }

    /// The paper's Tab. 5 sweep: nominal bit width -> format.
    pub fn by_bits(bits: u32) -> Format {
        match bits {
            4 => FP4_E2M1,
            8 => FP8_E4M3,
            16 => FP16,
            _ => FP32,
        }
    }

    /// As the (mbits, emin, maxv) triple the AOT HLOs take as input rows.
    pub fn as_qp(&self) -> [f32; 3] {
        [self.mbits, self.emin, self.maxv]
    }
}

/// Exact 2^e for integer e in [-126, 127], by exponent bit placement
/// (mirrors quantize._pow2 — never a transcendental). Crate-visible:
/// the packed codec in `tensor::qtensor` is built from the same exact
/// power-of-two arithmetic.
#[inline]
pub(crate) fn pow2(e: f32) -> f32 {
    let e = e.clamp(-126.0, 127.0) as i32;
    f32::from_bits(((e + 127) as u32) << 23)
}

/// floor(log2|x|) via the IEEE exponent field (exact; frexp equivalent).
#[inline]
pub(crate) fn floor_log2(ax: f32) -> f32 {
    debug_assert!(ax > 0.0);
    if ax >= f32::MIN_POSITIVE {
        ((ax.to_bits() >> 23) as i32 - 127) as f32
    } else {
        // subnormal: normalize by scaling up by 2^64 (exact)
        let scaled = ax * pow2(64.0);
        ((scaled.to_bits() >> 23) as i32 - 127 - 64) as f32
    }
}

/// Fake-quantize one value. Bit-exact counterpart of
/// `quantize.fake_quant`.
#[inline]
pub fn fq(x: f32, f: Format) -> f32 {
    if f.is_passthrough() {
        return x;
    }
    fq_fast(x, f)
}

/// `fq` without the passthrough check — the hot loop for slices that
/// already know the format is real. Division by the (power-of-two)
/// quantum is a multiplication by its exact reciprocal; both q and 1/q
/// are normal f32 by construction (the -126 exponent floor), so this is
/// bit-identical to the division form.
#[inline(always)]
fn fq_fast(x: f32, f: Format) -> f32 {
    let xc = x.clamp(-f.maxv, f.maxv);
    let ax = xc.abs();
    if ax < f32::MIN_POSITIVE {
        // subnormal (or zero) input: flush to a sign-preserving zero —
        // matches the explicit bitwise-FTZ in quantize.fake_quant (XLA
        // CPU flushes subnormals in comparisons, so the python side
        // cannot reliably do better, and the two must agree bit-for-bit)
        return x * 0.0;
    }
    let e = floor_log2(ax).max(f.emin);
    let qe = (e - f.mbits).clamp(-126.0, 126.0);
    let q = pow2(qe);
    let qinv = pow2(-qe);
    let y = (xc * qinv).round_ties_even() * q;
    y.clamp(-f.maxv, f.maxv)
}

/// Fake-quantize a slice in place.
pub fn fq_slice(xs: &mut [f32], f: Format) {
    if f.is_passthrough() {
        return;
    }
    for x in xs {
        *x = fq_fast(*x, f);
    }
}

/// Fake-quantize into a new tensor.
pub fn fq_tensor(t: &Tensor, f: Format) -> Tensor {
    let mut out = t.clone();
    fq_slice(&mut out.data, f);
    out
}

/// Low-precision accumulation: `acc = fq(acc + fq(x))` per element.
///
/// This is where the paper's *mantissa loss* (section 2) lives: summing
/// residual-stream contributions at FP8 discards any addend whose exponent
/// trails the running sum by more than `mbits` — so the activation delta
/// introduced by a patched edge can vanish before it reaches the logits.
/// PAHQ keeps the stream at FP32 (paper Eq. 10), RTN-Q does not.
pub fn accumulate_quantized(acc: &mut [f32], x: &[f32], f: Format) {
    debug_assert_eq!(acc.len(), x.len());
    if f.is_passthrough() {
        crate::tensor::add_assign(acc, x);
        return;
    }
    for i in 0..acc.len() {
        acc[i] = fq_fast(acc[i] + fq_fast(x[i], f), f);
    }
}

/// Integer RTN quantize-dequantize, paper Eq. (23):
/// `Q(w) = delta * round(w/delta)`, `delta = max|w| / 2^(N-1)`.
pub fn rtn_int(xs: &mut [f32], nbits: u32) {
    let maxab = xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if maxab == 0.0 {
        return;
    }
    let delta = maxab / (1u64 << (nbits - 1)) as f32;
    for x in xs.iter_mut() {
        *x = delta * (*x / delta).round_ties_even();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use crate::util::rng::Rng;

    #[test]
    fn e4m3_anchors() {
        assert_eq!(fq(448.0, FP8_E4M3), 448.0);
        assert_eq!(fq(1000.0, FP8_E4M3), 448.0);
        assert_eq!(fq(1.0, FP8_E4M3), 1.0);
        assert_eq!(fq(1.0625, FP8_E4M3), 1.0); // ties-to-even down
        assert_eq!(fq(2f32.powi(-9), FP8_E4M3), 2f32.powi(-9)); // min subnormal
        assert_eq!(fq(2f32.powi(-10), FP8_E4M3), 0.0); // underflow
        assert_eq!(fq(0.0, FP8_E4M3), 0.0);
        assert_eq!(fq(-0.0, FP8_E4M3), -0.0);
    }

    #[test]
    fn idempotent_and_monotonic() {
        let mut r = Rng::new(1);
        for f in [FP8_E4M3, FP8_E5M2, FP4_E2M1, BF16, FP16] {
            let mut xs: Vec<f32> = (0..2000)
                .map(|_| {
                    let mag = pow2((r.f32() * 280.0 - 140.0).round());
                    let sign = if r.f32() < 0.5 { -1.0 } else { 1.0 };
                    sign * mag * (1.0 + r.f32())
                })
                .collect();
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let ys: Vec<f32> = xs.iter().map(|&x| fq(x, f)).collect();
            for w in ys.windows(2) {
                assert!(w[0] <= w[1], "monotonic {f:?}");
            }
            for &y in &ys {
                assert_eq!(fq(y, f), y, "idempotent {f:?}");
            }
        }
    }

    #[test]
    fn underflow_paper_s2() {
        // contrasts below the binade quantum vanish (paper section 2)
        assert_eq!(fq(1.0, FP8_E4M3), fq(1.05, FP8_E4M3));
    }

    #[test]
    fn mantissa_loss_paper_s2() {
        // exponent gap >= 4 under E4M3 loses the small addend entirely
        let mut acc = vec![8.0f32];
        accumulate_quantized(&mut acc, &[0.4], FP8_E4M3);
        assert_eq!(acc[0], 8.0);
        // ...while FP32 accumulation keeps it
        let mut acc32 = vec![8.0f32];
        accumulate_quantized(&mut acc32, &[0.4], FP32);
        assert!((acc32[0] - 8.4).abs() < 1e-6);
    }

    #[test]
    fn vectors_match_python() {
        // Bit-exactness against the jnp implementation baked into the HLO.
        let path = crate::artifacts_root().join("testvectors/fq_cases.json");
        if !path.exists() {
            eprintln!("skipping: {} not built (run `make artifacts`)", path.display());
            return;
        }
        let v = Json::parse_file(&path).unwrap();
        let xs = v.get("x").unwrap().f32_vec().unwrap();
        for name in ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1", "bf16", "fp16"] {
            let want = v.get(name).unwrap().f32_vec().unwrap();
            let f = Format::by_name(name).unwrap();
            let mut mismatches = 0;
            for (i, (&x, &w)) in xs.iter().zip(&want).enumerate() {
                let got = fq(x, f);
                if got.to_bits() != w.to_bits() {
                    mismatches += 1;
                    if mismatches < 5 {
                        eprintln!("{name}[{i}]: fq({x:e}) = {got:e}, python {w:e}");
                    }
                }
            }
            assert_eq!(mismatches, 0, "{name}: {mismatches}/{} mismatches", xs.len());
        }
    }

    #[test]
    fn rtn_int_eq23() {
        let mut w = vec![-1.0f32, -0.4, 0.0, 0.3, 0.8];
        rtn_int(&mut w, 4);
        let delta = 1.0 / 8.0;
        for &q in &w {
            let k = q / delta;
            assert!((k - k.round()).abs() < 1e-6);
        }
    }

    #[test]
    fn storage_bits_and_packed_bytes() {
        assert_eq!(FP32.storage_bits(), 32);
        assert_eq!(FP16.storage_bits(), 16);
        assert_eq!(BF16.storage_bits(), 16);
        assert_eq!(FP8_E4M3.storage_bits(), 8);
        assert_eq!(FP8_E5M2.storage_bits(), 8);
        assert_eq!(FP4_E2M1.storage_bits(), 4);
        // fp4 packs two elements per byte; odd counts round up
        assert_eq!(FP4_E2M1.bytes_for(4), 2);
        assert_eq!(FP4_E2M1.bytes_for(3), 2);
        assert_eq!(FP8_E4M3.bytes_for(5), 5);
        assert_eq!(BF16.bytes_for(2), 4);
        assert_eq!(FP32.bytes_for(2), 8);
    }

    #[test]
    fn by_bits_table5() {
        assert_eq!(Format::by_bits(4), FP4_E2M1);
        assert_eq!(Format::by_bits(8), FP8_E4M3);
        assert_eq!(Format::by_bits(16), FP16);
        assert!(Format::by_bits(32).is_passthrough());
    }
}
