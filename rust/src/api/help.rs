//! Generated CLI help — assembled from the same enum spellings, default
//! constants, and model/task registries the spec builders parse with,
//! so the text cannot drift from what the parsers accept (the old
//! hand-maintained `USAGE` string drifted across PRs 3–4).

use super::{DEFAULT_BITS, DEFAULT_MODEL, DEFAULT_TASK, DEFAULT_TAU, MethodKind, StoreSpec};
use crate::acdc::SweepMode;
use crate::experiments::{BASE_MODELS, SCALE_MODELS, TASKS};
use crate::metrics::Objective;
use crate::patching::Policy;

/// `acdc|rtn-q|pahq|eap|hisp|sp|edge-pruning` — every [`MethodKind`].
pub fn method_spellings() -> String {
    MethodKind::ALL.map(|m| m.as_str()).join("|")
}

/// `fp32|rtn|pahq` — the [`Policy::FAMILIES`].
pub fn policy_spellings() -> String {
    Policy::FAMILIES.join("|")
}

/// `kl|task` — the [`Objective::SPELLINGS`].
pub fn objective_spellings() -> String {
    Objective::SPELLINGS.join("|")
}

/// `serial|batched` — the [`SweepMode::SPELLINGS`].
pub fn sweep_spellings() -> String {
    SweepMode::SPELLINGS.join("|")
}

/// `mem|disk|disk:PATH` — the [`StoreSpec::SPELLINGS`].
pub fn store_spellings() -> String {
    StoreSpec::SPELLINGS.join("|")
}

/// Every model name the artifact registry knows.
pub fn model_names() -> String {
    BASE_MODELS.iter().chain(SCALE_MODELS.iter()).copied().collect::<Vec<_>>().join(" ")
}

/// Every task name.
pub fn task_names() -> String {
    TASKS.join(" ")
}

/// (name, one-line synopsis) of every subcommand, in display order.
pub fn subcommands() -> Vec<(&'static str, &'static str)> {
    vec![
        ("run", "one circuit-discovery run; emits a RunRecord JSON"),
        ("matrix", "the full method x policy x task grid, work-stealing + resumable"),
        ("table", "regenerate paper Table N (1..8)"),
        ("figure", "regenerate paper Figure N (1, 3, 4)"),
        ("all", "regenerate every table and figure"),
        ("sweep", "serial-vs-batched sweep scaling (predicted + measured)"),
        ("groundtruth", "compute/cache the FP32 reference circuit"),
        ("sim", "DES runtime/memory prediction for a method on real arches"),
        ("bench", "deterministic perf snapshot for CI's perf gate"),
        ("store", "inspect / garbage-collect the durable artifact store"),
        ("serve", "multi-client discovery daemon (docs/serve_protocol.md)"),
        ("load", "scenario-driven load/latency harness against `pahq serve` or in-process"),
        ("lint", "in-repo static analysis: panic ratchets, lock hygiene, doc drift"),
        ("info", "model/artifact inventory"),
        ("help", "this overview, or `pahq help <subcommand>` for flags"),
    ]
}

fn render(cmd: &str, synopsis: &str, flags: &[(String, String)]) -> String {
    let mut out = format!("pahq {cmd} — {synopsis}\n");
    if flags.is_empty() {
        return out;
    }
    out.push_str("\nFlags:\n");
    let w = flags.iter().map(|(n, _)| n.len()).max().unwrap_or(0);
    for (name, help) in flags {
        out.push_str(&format!("  {name:<w$}  {help}\n"));
    }
    out
}

fn run_flags() -> Vec<(String, String)> {
    vec![
        ("--model M".into(), format!("model name (default {DEFAULT_MODEL}; see Models)")),
        ("--task T".into(), format!("task name (default {DEFAULT_TASK}; see Tasks)")),
        (
            "--method M".into(),
            format!(
                "{} (default pahq; acdc|rtn-q|pahq imply their policy)",
                method_spellings()
            ),
        ),
        (
            "--policy P".into(),
            format!(
                "explicit session policy: {} at --bits, or a full name like \
                 pahq-4b. Only --method acdc and the baselines accept an \
                 override; rtn-q/pahq imply theirs and reject a contradiction",
                policy_spellings()
            ),
        ),
        (
            "--bits N".into(),
            format!("nominal width of the low-precision policy, 4|8|16 (default {DEFAULT_BITS})"),
        ),
        ("--tau X".into(), format!("ACDC threshold (default {DEFAULT_TAU})")),
        ("--metric O".into(), format!("{} (default kl)", objective_spellings())),
        (
            "--sweep S".into(),
            format!(
                "{} or batched[N] (default serial; kept sets are bit-identical)",
                sweep_spellings()
            ),
        ),
        (
            "--workers N".into(),
            "scoring threads; only with --sweep batched (default: available parallelism)".into(),
        ),
        (
            "--seed S".into(),
            "dataset seed through the shared (task, seed, n) resolution \
             (default 0 = the python-exported artifact batch)"
                .into(),
        ),
        ("--trace".into(), "record the per-step sweep trace into the record (Fig. 3)".into()),
        ("--no-faith".into(), "skip scoring against the FP32 ground truth".into()),
        store_flag(),
        gc_horizon_flag(),
        (
            "--json PATH".into(),
            "where the RunRecord lands (default \
             rust/results/run_<method>_<policy>_<model>_<task>.json)"
                .into(),
        ),
    ]
}

/// The `--store` flag, shared verbatim by `run`, `matrix`, and `store`.
fn store_flag() -> (String, String) {
    (
        "--store S".into(),
        format!(
            "artifact store backend: {} (default mem; disk is the durable \
             content-addressed store at rust/results/store or PATH, shared \
             across processes)",
            store_spellings()
        ),
    )
}

/// The `--gc-horizon` flag, shared by `run`, `matrix`, and `store gc`.
fn gc_horizon_flag() -> (String, String) {
    (
        "--gc-horizon N".into(),
        "collect disk-store entries unused for N generations (>= 1); \
         only with --store disk"
            .into(),
    )
}

fn matrix_flags() -> Vec<(String, String)> {
    vec![
        ("--models A,B".into(), "model axis (default redwood2l-sim)".into()),
        ("--tasks T1,T2".into(), format!("task axis (default {})", task_names())),
        (
            "--methods M1,M2".into(),
            "discovery-method axis (default acdc,eap,hisp,sp,edge-pruning; \
             rtn-q/pahq belong on --policies)"
                .into(),
        ),
        (
            "--policies P1,P2".into(),
            format!("policy axis: {} at --bits (default fp32,pahq)", policy_spellings()),
        ),
        ("--bits N".into(), format!("nominal policy width, 4|8|16 (default {DEFAULT_BITS})")),
        ("--tau X".into(), format!("ACDC threshold (default {DEFAULT_TAU})")),
        ("--metric O".into(), format!("{} (default kl)", objective_spellings())),
        ("--workers N".into(), "concurrent grid cells (default: available parallelism)".into()),
        (
            "--sweep S".into(),
            format!("per-cell schedule: {} or batched[N] (default serial)", sweep_spellings()),
        ),
        (
            "--pool-workers K".into(),
            "per-cell batched-sweep pool size; only with --sweep batched (default 2)".into(),
        ),
        ("--seed S".into(), "dataset seed, shared with `pahq run` (default 0)".into()),
        ("--quick".into(), "the small acceptance grid".into()),
        (
            "--resume".into(),
            "skip cells whose valid record already exists (files stay byte-identical)".into(),
        ),
        ("--no-faith".into(), "skip scoring against the FP32 ground truth".into()),
        ("--out DIR".into(), "where per-cell records land (default rust/results/matrix)".into()),
        ("--json PATH".into(), "manifest path (default <out>/matrix.json)".into()),
        store_flag(),
        gc_horizon_flag(),
    ]
}

fn store_cmd_flags() -> Vec<(String, String)> {
    vec![
        (
            "--store S".into(),
            format!("which store to operate on: {} (default disk)", store_spellings()),
        ),
        (
            "--gc-horizon N".into(),
            "gc: collect entries unused for N generations (default 2)".into(),
        ),
    ]
}

fn serve_flags() -> Vec<(String, String)> {
    vec![
        (
            "--addr A".into(),
            "bind address (default 127.0.0.1:7341; port 0 picks an ephemeral port)".into(),
        ),
        (
            "--workers N".into(),
            "worker threads draining the shared cell queue across all clients (default 2)".into(),
        ),
        store_flag(),
        gc_horizon_flag(),
    ]
}

/// `smoke|steady|burst|saturate` — the load-scenario presets.
pub fn scenario_spellings() -> String {
    crate::load::PRESETS.join("|")
}

fn load_flags() -> Vec<(String, String)> {
    vec![
        (
            "--scenario S".into(),
            format!(
                "named preset with overrides: {}[:key=val,...] (default smoke; \
                 keys: {})",
                scenario_spellings(),
                crate::load::OVERRIDE_KEYS.join("|"),
            ),
        ),
        (
            "--addr A".into(),
            "wire mode: drive the live `pahq serve` daemon at HOST:PORT".into(),
        ),
        (
            "--direct".into(),
            "direct mode: execute the same specs in-process (the engine-only \
             latency floor; mutually exclusive with --addr)"
                .into(),
        ),
        (
            "--workers N".into(),
            "override the scenario's client/thread count".into(),
        ),
        (
            "--shutdown".into(),
            "after the run, ask the daemon to drain and exit (wire mode only)".into(),
        ),
        (
            "--json PATH".into(),
            "where load_snapshot.json lands (schema: docs/load_snapshot.schema.json)".into(),
        ),
    ]
}

fn lint_flags() -> Vec<(String, String)> {
    vec![
        (
            "--json PATH".into(),
            "where the findings artifact lands (schema: docs/lint_findings.schema.json)".into(),
        ),
        (
            "--update-baseline".into(),
            "regenerate LINT_baseline.json from the current ratchet counts \
             (full-repo pass only)"
                .into(),
        ),
        (
            "--paths A,B".into(),
            "lint only these repo-relative files (skips the repo-wide drift \
             rules; how tests and CI reach the known-bad fixtures)"
                .into(),
        ),
        (
            "--root DIR".into(),
            "checkout root (default: ascend from the working directory)".into(),
        ),
    ]
}

fn sim_flags() -> Vec<(String, String)> {
    vec![
        ("--arch A".into(), "real architecture to simulate (default gpt2)".into()),
        (
            "--method M".into(),
            format!(
                "{} (default pahq; the baselines verify through the ACDC \
                 sweep under their policy, so they share PAHQ's cost model)",
                method_spellings()
            ),
        ),
        ("--streams S".into(), "full|load|split|none (default full)".into()),
        ("--sweep S".into(), format!("{} (default serial)", sweep_spellings())),
        ("--workers N".into(), "batched sweep width for the prediction (default: cores)".into()),
        ("--removal-rate P".into(), "assumed edge-removal rate (default 0.9)".into()),
    ]
}

/// Full per-subcommand help. `None` for unknown names.
pub fn subcommand(name: &str) -> Option<String> {
    let synopsis = |n: &str| {
        subcommands()
            .into_iter()
            .find(|(s, _)| *s == n)
            .map(|(_, syn)| syn.to_string())
            .unwrap_or_default()
    };
    let text = match name {
        "run" => render("run", &synopsis("run"), &run_flags()),
        "matrix" => render("matrix", &synopsis("matrix"), &matrix_flags()),
        "table" => render(
            "table <1..8>",
            &synopsis("table"),
            &[
                ("--quick".to_string(), "smaller models / fewer thresholds".to_string()),
                (
                    "--from PATH".to_string(),
                    "tables 2/6/7: render from a matrix manifest in one pass".to_string(),
                ),
            ],
        ),
        "figure" => render(
            "figure <1|3|4>",
            &synopsis("figure"),
            &[("--quick".to_string(), "smaller models / fewer thresholds".to_string())],
        ),
        "all" => render(
            "all",
            &synopsis("all"),
            &[("--quick".to_string(), "smaller models / fewer thresholds".to_string())],
        ),
        "sweep" => render(
            "sweep",
            &synopsis("sweep"),
            &[
                ("--quick".to_string(), "fewer architectures".to_string()),
                ("--seed S".to_string(), "dataset seed, shared with `pahq run`".to_string()),
            ],
        ),
        "groundtruth" => render(
            "groundtruth",
            &synopsis("groundtruth"),
            &[
                ("--model M".to_string(), format!("model name (default {DEFAULT_MODEL})")),
                ("--task T".to_string(), format!("task name (default {DEFAULT_TASK})")),
                ("--metric O".to_string(), format!("{} (default kl)", objective_spellings())),
            ],
        ),
        "sim" => render("sim", &synopsis("sim"), &sim_flags()),
        "bench" => render(
            "bench",
            &synopsis("bench"),
            &[
                ("--quick".to_string(), "fewer repetitions".to_string()),
                (
                    "--json PATH".to_string(),
                    "snapshot path (default rust/results/bench.json)".to_string(),
                ),
            ],
        ),
        "store" => render("store <ls|gc>", &synopsis("store"), &store_cmd_flags()),
        "serve" => render("serve", &synopsis("serve"), &serve_flags()),
        "load" => render("load", &synopsis("load"), &load_flags()),
        "lint" => render("lint", &synopsis("lint"), &lint_flags()),
        "info" => render("info", &synopsis("info"), &[]),
        _ => return None,
    };
    Some(text)
}

/// The top-level overview (`pahq` / `pahq help`).
pub fn usage() -> String {
    let mut out = String::from(
        "pahq — PAHQ: accelerating automated circuit discovery (paper reproduction)\n\n\
         USAGE: pahq <subcommand> [flags]   (pahq help <subcommand> or \
         pahq <subcommand> --help for flags)\n\nSubcommands:\n",
    );
    let subs = subcommands();
    let w = subs.iter().map(|(n, _)| n.len()).max().unwrap_or(0);
    for (name, synopsis) in &subs {
        out.push_str(&format!("  {name:<w$}  {synopsis}\n"));
    }
    out.push_str(&format!(
        "\nMethods:  {}\nPolicies: {} (at --bits 4|8|16)\nModels:   {}\nTasks:    {}\n",
        method_spellings(),
        policy_spellings(),
        model_names(),
        task_names(),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_lists_every_spelling_and_subcommand() {
        let u = usage();
        for m in MethodKind::ALL {
            assert!(u.contains(m.as_str()), "usage misses method {m}");
        }
        for fam in Policy::FAMILIES {
            assert!(u.contains(fam), "usage misses policy family {fam}");
        }
        for (name, _) in subcommands() {
            assert!(u.contains(name), "usage misses subcommand {name}");
        }
        for model in BASE_MODELS.iter().chain(SCALE_MODELS.iter()) {
            assert!(u.contains(model), "usage misses model {model}");
        }
    }

    #[test]
    fn every_subcommand_has_help() {
        for (name, _) in subcommands() {
            if name == "help" {
                continue;
            }
            let h = subcommand(name).unwrap_or_else(|| panic!("no help for {name}"));
            assert!(h.starts_with(&format!("pahq {name}")), "{name}: {h}");
        }
        assert!(subcommand("frobnicate").is_none());
    }

    #[test]
    fn run_help_covers_every_flag_the_parser_reads() {
        // anti-drift: every flag RunSpec::from_cli consults appears in
        // the generated help (and vice versa is by construction)
        let h = subcommand("run").unwrap();
        for flag in [
            "--model", "--task", "--method", "--policy", "--bits", "--tau", "--metric",
            "--sweep", "--workers", "--seed", "--trace", "--no-faith", "--store",
            "--gc-horizon", "--json",
        ] {
            assert!(h.contains(flag), "run help misses {flag}");
        }
        let m = subcommand("matrix").unwrap();
        for flag in [
            "--models", "--tasks", "--methods", "--policies", "--bits", "--tau", "--metric",
            "--workers", "--sweep", "--pool-workers", "--seed", "--quick", "--resume",
            "--no-faith", "--out", "--json", "--store", "--gc-horizon",
        ] {
            assert!(m.contains(flag), "matrix help misses {flag}");
        }
        let s = subcommand("store").unwrap();
        for flag in ["--store", "--gc-horizon"] {
            assert!(s.contains(flag), "store help misses {flag}");
        }
        // every flag cmd_serve consults appears in the serve help
        let v = subcommand("serve").unwrap();
        for flag in ["--addr", "--workers", "--store", "--gc-horizon"] {
            assert!(v.contains(flag), "serve help misses {flag}");
        }
        // every flag cmd_load consults appears in the load help, plus
        // every scenario preset and override key
        let l = subcommand("load").unwrap();
        for flag in ["--scenario", "--addr", "--direct", "--workers", "--shutdown", "--json"] {
            assert!(l.contains(flag), "load help misses {flag}");
        }
        for preset in crate::load::PRESETS {
            assert!(l.contains(preset), "load help misses preset {preset}");
        }
        for key in crate::load::OVERRIDE_KEYS {
            assert!(l.contains(key), "load help misses override key {key}");
        }
        // every flag cmd_lint consults appears in the lint help
        let t = subcommand("lint").unwrap();
        for flag in ["--json", "--update-baseline", "--paths", "--root"] {
            assert!(t.contains(flag), "lint help misses {flag}");
        }
        // the --store value spellings come from the StoreSpec list
        for spelling in StoreSpec::SPELLINGS {
            assert!(h.contains(spelling), "run help misses store spelling {spelling}");
        }
    }
}
