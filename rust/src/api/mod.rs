//! The typed public facade: one validated [`RunSpec`] / [`MatrixSpec`]
//! entry point for the CLI, the matrix orchestrator, the experiment
//! harness, the integration tests, and library embedders.
//!
//! The paper's pitch is that PAHQ "readily integrates with existing
//! edge-based circuit discovery techniques"; this module is where a
//! downstream tool integrates with *us*. Instead of four call sites
//! re-deriving method/policy/sweep semantics from strings, everything
//! funnels through two launch functions:
//!
//! - [`run`] — one discovery run from a validated [`RunSpec`], returning
//!   (and optionally writing) its schema-versioned
//!   [`RunRecord`](crate::discovery::RunRecord);
//! - [`matrix`] — a full method x policy x task grid from a validated
//!   [`MatrixSpec`], returning the manifest.
//!
//! Specs are built with [`RunSpecBuilder`] / [`MatrixSpecBuilder`],
//! which validate cross-field constraints up front (a `rtn-q` method
//! implies the rtn policy family, `workers` is only meaningful with a
//! batched sweep, a matrix `methods` axis never carries policy
//! spellings, ...) with errors that name the offending field. Every
//! enum in a spec ([`MethodKind`], [`Policy`], [`SweepMode`],
//! [`Objective`]) implements `FromStr`/`Display`, so the CLI parsers
//! ([`RunSpec::from_cli`] / [`MatrixSpec::from_cli`]) and the generated
//! help text ([`help`]) share one source of spellings and cannot drift.
//!
//! A spec resolves its substrate like `pahq matrix` always has: real
//! engine artifacts when they are built, the deterministic synthetic
//! grid when none exist (so CI and artifact-less embedders still get a
//! schema-complete record), and a loud error on partial availability.

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::acdc::SweepMode;
use crate::discovery::{self, CacheStats, DiscoveryConfig, RunRecord, Session, Task};
use crate::gpu_sim::memory;
use crate::matrix::{self, Cell, MatrixConfig, MatrixOutcome};
use crate::metrics::Objective;
use crate::patching::Policy;
use crate::report::results_dir;
use crate::util::cli::Args;
use crate::util::json::{obj, Json};

pub mod help;

// a library embedder pointing two tools at one store only needs the
// facade: the backend trait (and its two implementations) re-export
// here next to the `StoreSpec` that selects between them
pub use crate::matrix::cache::{ArtifactStore, DiskStore, MemoryStore};

/// Default model of `pahq run` (shared by the CLI and the help text).
pub const DEFAULT_MODEL: &str = "gpt2s-sim";
/// Default task of `pahq run`.
pub const DEFAULT_TASK: &str = "ioi";
/// Default ACDC threshold.
pub const DEFAULT_TAU: f32 = 0.01;
/// Default nominal bit width of the low-precision policy families.
pub const DEFAULT_BITS: u32 = 8;

// ---------------------------------------------------------------------------
// MethodKind

/// Every method spelling the CLI accepts, typed. The classic spellings
/// `acdc` / `rtn-q` / `pahq` all verify with the ACDC sweep under their
/// implied precision policy; the baselines score attribution first and
/// verify the ranked plan through the same sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodKind {
    /// ACDC under an explicit policy (FP32 by default).
    Acdc,
    /// ACDC under the whole-pipeline RTN quantization baseline.
    RtnQ,
    /// ACDC under the paper's mixed-precision policy.
    Pahq,
    /// Edge Attribution Patching (gradient baseline).
    Eap,
    /// Head Importance Score Pruning (gradient baseline).
    Hisp,
    /// Subnetwork Probing (learned gates).
    Sp,
    /// Edge Pruning (learned edge masks).
    EdgePruning,
}

impl MethodKind {
    /// Every method, in the CLI's display order.
    pub const ALL: [MethodKind; 7] = [
        MethodKind::Acdc,
        MethodKind::RtnQ,
        MethodKind::Pahq,
        MethodKind::Eap,
        MethodKind::Hisp,
        MethodKind::Sp,
        MethodKind::EdgePruning,
    ];

    /// Canonical CLI spelling (what [`std::fmt::Display`] writes).
    pub fn as_str(self) -> &'static str {
        match self {
            MethodKind::Acdc => "acdc",
            MethodKind::RtnQ => "rtn-q",
            MethodKind::Pahq => "pahq",
            MethodKind::Eap => "eap",
            MethodKind::Hisp => "hisp",
            MethodKind::Sp => "sp",
            MethodKind::EdgePruning => "edge-pruning",
        }
    }

    /// The [`crate::discovery`] registry name this method runs as:
    /// the classic spellings are all ACDC under an implied policy.
    pub fn discovery_name(self) -> &'static str {
        match self {
            MethodKind::Acdc | MethodKind::RtnQ | MethodKind::Pahq => "acdc",
            other => other.as_str(),
        }
    }

    /// Is this spelling really an (ACDC, policy) pair? Those belong on
    /// a matrix's *policies* axis, not its methods axis.
    pub fn is_policy_spelling(self) -> bool {
        matches!(self, MethodKind::RtnQ | MethodKind::Pahq)
    }

    /// The precision policy this method implies when none is given
    /// explicitly: its own for the classic spellings, PAHQ for the
    /// baselines (that integration is what this repo exists to show).
    pub fn implied_policy(self, bits: u32) -> Result<Policy> {
        match self {
            MethodKind::Acdc => Ok(Policy::fp32()),
            MethodKind::RtnQ => Policy::by_name("rtn", bits),
            _ => Policy::by_name("pahq", bits),
        }
    }

    /// The DES cost-model kind `pahq sim` predicts with. The baselines
    /// verify through the same ACDC sweep under their (PAHQ-default)
    /// policy, so they share PAHQ's per-edge cost model.
    pub fn sim_kind(self) -> memory::MethodKind {
        match self {
            MethodKind::Acdc => memory::MethodKind::AcdcFp32,
            MethodKind::RtnQ => memory::MethodKind::RtnQ,
            _ => memory::MethodKind::Pahq,
        }
    }
}

impl std::fmt::Display for MethodKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Parses every canonical spelling plus the `rtn` / `ep` aliases.
impl std::str::FromStr for MethodKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<MethodKind> {
        Ok(match s {
            "acdc" => MethodKind::Acdc,
            "rtn-q" | "rtn" => MethodKind::RtnQ,
            "pahq" => MethodKind::Pahq,
            "eap" => MethodKind::Eap,
            "hisp" => MethodKind::Hisp,
            "sp" => MethodKind::Sp,
            "edge-pruning" | "ep" => MethodKind::EdgePruning,
            other => bail!("unknown method '{other}' ({})", help::method_spellings()),
        })
    }
}

// ---------------------------------------------------------------------------
// Substrate / output sink

/// Which substrate a spec runs on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Substrate {
    /// Real engine artifacts when they are built; the deterministic
    /// synthetic surface when *none* exist (CI, artifact-less
    /// embedders). Partial availability errors loudly.
    #[default]
    Auto,
    /// Real engine artifacts or an error — never pseudo-score.
    Real,
    /// The deterministic synthetic surface, unconditionally.
    Synthetic,
}

impl Substrate {
    /// The spellings the wire protocol and the docs share.
    pub const SPELLINGS: [&'static str; 3] = ["auto", "real", "synthetic"];

    pub fn as_str(self) -> &'static str {
        match self {
            Substrate::Auto => "auto",
            Substrate::Real => "real",
            Substrate::Synthetic => "synthetic",
        }
    }
}

impl std::fmt::Display for Substrate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Substrate {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Substrate> {
        Ok(match s {
            "auto" => Substrate::Auto,
            "real" => Substrate::Real,
            "synthetic" => Substrate::Synthetic,
            other => {
                bail!("unknown substrate '{other}' (expected {})", Substrate::SPELLINGS.join(" | "))
            }
        })
    }
}

/// Where [`run`] writes the resulting [`RunRecord`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum OutputSink {
    /// Keep the record in memory only (library default).
    #[default]
    Memory,
    /// The CLI's default location:
    /// `rust/results/run_<method>_<policy>_<model>_<task>.json`.
    Default,
    /// An explicit path.
    Path(PathBuf),
}

impl OutputSink {
    /// Resolve where a record lands (`None` = memory only).
    pub fn path_for(&self, rec: &RunRecord) -> Option<PathBuf> {
        match self {
            OutputSink::Memory => None,
            OutputSink::Path(p) => Some(p.clone()),
            OutputSink::Default => Some(results_dir().join(format!(
                "run_{}_{}_{}_{}.json",
                rec.method, rec.policy, rec.model, rec.task
            ))),
        }
    }
}

// ---------------------------------------------------------------------------
// StoreSpec

/// Which artifact-store backend a spec's launch opens: the in-process
/// memory backend (classic behavior, artifacts die with the process),
/// or the durable content-addressed disk store
/// ([`DiskStore`](crate::matrix::cache::DiskStore)) shared across
/// processes and grids.
///
/// Parses from the CLI spellings `--store mem` / `--store disk` /
/// `--store disk:PATH` ([`std::fmt::Display`] writes them back), with
/// the optional `--gc-horizon N` generation horizon folded into the
/// `Disk` variant by [`RunSpecBuilder::build`] /
/// [`MatrixSpecBuilder::build`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum StoreSpec {
    /// In-process only (the default).
    #[default]
    Memory,
    /// The durable on-disk store rooted at `root`; `gc_horizon` opts
    /// into a generation-GC sweep when the store is opened.
    Disk {
        root: PathBuf,
        /// entries last used more than this many generations ago are
        /// collected at open (`None` = never sweep); >= 1 so two
        /// concurrent grids never collect each other's live artifacts
        gc_horizon: Option<u64>,
    },
}

impl StoreSpec {
    /// The CLI spellings `--store` accepts (shared with the generated
    /// help, like every other spec enum).
    pub const SPELLINGS: [&'static str; 3] = ["mem", "disk", "disk:PATH"];

    /// Where a bare `--store disk` lands: `<results>/store`.
    pub fn default_disk_root() -> PathBuf {
        results_dir().join("store")
    }

    /// The configured disk root, when this spec is disk-backed.
    pub fn disk_root(&self) -> Option<&PathBuf> {
        match self {
            StoreSpec::Memory => None,
            StoreSpec::Disk { root, .. } => Some(root),
        }
    }
}

impl std::str::FromStr for StoreSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<StoreSpec> {
        match s {
            "mem" | "memory" => Ok(StoreSpec::Memory),
            "disk" => Ok(StoreSpec::Disk { root: StoreSpec::default_disk_root(), gc_horizon: None }),
            other => match other.strip_prefix("disk:") {
                Some(path) if !path.is_empty() => {
                    Ok(StoreSpec::Disk { root: PathBuf::from(path), gc_horizon: None })
                }
                _ => bail!(
                    "store: unknown spelling '{other}' (expected {})",
                    StoreSpec::SPELLINGS.join(" | ")
                ),
            },
        }
    }
}

impl std::fmt::Display for StoreSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreSpec::Memory => write!(f, "mem"),
            StoreSpec::Disk { root, .. } => write!(f, "disk:{}", root.display()),
        }
    }
}

// ---------------------------------------------------------------------------
// RunSpec

/// One validated discovery run: everything `pahq run`, a matrix cell's
/// standalone comparator, `experiments`, and a library embedder need to
/// launch work, in one typed value. Construct with [`RunSpec::builder`]
/// (cross-field validation with field-naming errors) or parse CLI flags
/// with [`RunSpec::from_cli`]; launch with [`run`].
///
/// ```
/// use pahq::api::RunSpec;
///
/// # fn main() -> anyhow::Result<()> {
/// let spec = RunSpec::builder("gpt2s-sim", "ioi")
///     .method("eap".parse()?)
///     .tau(0.05)
///     .build()?;
/// assert_eq!(spec.method.discovery_name(), "eap");
/// assert_eq!(spec.policy.name, "pahq-8b"); // baselines imply PAHQ
/// # Ok(())
/// # }
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    pub model: String,
    pub task: String,
    pub method: MethodKind,
    /// session precision policy (defaults to the method's implied one)
    pub policy: Policy,
    pub tau: f32,
    pub objective: Objective,
    /// evaluation schedule; kept sets are bit-identical across modes
    pub sweep: SweepMode,
    /// dataset seed through the shared (task, seed, n) resolution
    /// (0 = the python-exported artifact batch)
    pub seed: u64,
    /// record the per-step sweep trace into the record (Fig. 3)
    pub record_trace: bool,
    /// score the circuit against the FP32 ground truth; the bool asks
    /// for the extra normalized-faithfulness forwards. `None` skips.
    pub faithfulness: Option<bool>,
    /// propagate faithfulness errors instead of skipping with a notice
    pub faith_required: bool,
    pub substrate: Substrate,
    /// SP gate-training steps
    pub sp_steps: usize,
    /// Edge-Pruning mask-training steps
    pub ep_steps: usize,
    /// where the record lands
    pub sink: OutputSink,
    /// which artifact-store backend the launch opens (dataset, corrupt
    /// cache, attribution scores — reused on hit, published on miss)
    pub store: StoreSpec,
}

impl RunSpec {
    /// Start a spec for `model`/`task` with every other field at its
    /// documented default.
    pub fn builder(model: &str, task: &str) -> RunSpecBuilder {
        RunSpecBuilder {
            model: model.to_string(),
            task: task.to_string(),
            method: MethodKind::Pahq,
            policy: None,
            bits: DEFAULT_BITS,
            tau: DEFAULT_TAU,
            objective: Objective::Kl,
            sweep: SweepMode::Serial,
            workers: None,
            seed: 0,
            record_trace: false,
            faithfulness: None,
            faith_required: false,
            substrate: Substrate::Auto,
            sp_steps: 80,
            ep_steps: 60,
            sink: OutputSink::Memory,
            store: StoreSpec::Memory,
            gc_horizon: None,
        }
    }

    /// Parse `pahq run` flags into a validated spec — the CLI is a thin
    /// shell over this, so a flag set and the equivalent builder chain
    /// produce identical records by construction.
    pub fn from_cli(args: &Args) -> Result<RunSpec> {
        let bits = args.usize_or("bits", DEFAULT_BITS as usize)? as u32;
        let mut b = RunSpec::builder(
            args.get_or("model", DEFAULT_MODEL),
            args.get_or("task", DEFAULT_TASK),
        )
        .method(args.get_or("method", "pahq").parse()?)
        .bits(bits)
        .tau(args.f64_or("tau", DEFAULT_TAU as f64)? as f32)
        .objective(args.get_or("metric", "kl").parse()?)
        .sweep(args.get_or("sweep", "serial").parse()?)
        .seed(args.u64_or("seed", 0)?)
        .trace(args.flag("trace"));
        if let Some(p) = args.get("policy") {
            b = b.policy(Policy::by_name(p, bits)?);
        }
        if let Some(w) = args.usize_opt("workers")? {
            b = b.workers(w);
        }
        if !args.flag("no-faith") {
            b = b.faithfulness(Some(false));
        }
        if let Some(s) = args.get("store") {
            b = b.store(s.parse()?);
        }
        if args.get("gc-horizon").is_some() {
            b = b.gc_horizon(args.u64_or("gc-horizon", 0)?);
        }
        b = b.sink(match args.json_path() {
            Some(p) => OutputSink::Path(PathBuf::from(p)),
            None => OutputSink::Default,
        });
        b.build()
    }

    /// The method-agnostic [`DiscoveryConfig`] this spec configures its
    /// session with.
    pub fn discovery_config(&self) -> DiscoveryConfig {
        let mut cfg = DiscoveryConfig::new(self.tau, self.objective, self.policy.clone());
        cfg.sweep = self.sweep;
        cfg.record_trace = self.record_trace;
        cfg.sp_steps = self.sp_steps;
        cfg.ep_steps = self.ep_steps;
        cfg
    }

    /// Cross-field validation; every error names the offending field.
    /// [`RunSpecBuilder::build`] runs this, and [`run`] re-runs it so a
    /// hand-constructed spec cannot bypass it.
    pub fn validate(&self) -> Result<()> {
        if self.model.is_empty() {
            bail!("model: must not be empty");
        }
        if self.task.is_empty() {
            bail!("task: must not be empty");
        }
        if !self.tau.is_finite() || self.tau < 0.0 {
            bail!("tau: must be a finite non-negative threshold, got {}", self.tau);
        }
        // match the variant directly: SweepMode::workers() clamps to 1,
        // so a zero hiding in a hand-built spec would pass a clamped check
        if matches!(self.sweep, SweepMode::Batched { workers: 0 }) {
            bail!("sweep: batched worker count must be >= 1");
        }
        if self.sp_steps == 0 {
            bail!("sp_steps: must be >= 1");
        }
        if self.ep_steps == 0 {
            bail!("ep_steps: must be >= 1");
        }
        if let StoreSpec::Disk { gc_horizon: Some(0), .. } = &self.store {
            bail!("gc_horizon: must be >= 1 (a zero horizon could collect live artifacts)");
        }
        // the classic policy-carrying spellings must not contradict an
        // explicit policy; `acdc` is the generic verifier and accepts any
        let family = memory::MethodKind::of_policy(&self.policy);
        match self.method {
            MethodKind::RtnQ if family != memory::MethodKind::RtnQ => bail!(
                "policy: method 'rtn-q' implies the rtn policy family, got '{}' — \
                 use method 'acdc' for an explicit policy override",
                self.policy.name
            ),
            MethodKind::Pahq if family != memory::MethodKind::Pahq => bail!(
                "policy: method 'pahq' implies the pahq policy family, got '{}' — \
                 use method 'acdc' for an explicit policy override",
                self.policy.name
            ),
            _ => Ok(()),
        }
    }

    /// Serialize this spec as the `pahq serve` wire payload (the
    /// `submit_run` frame's `spec` object, `docs/serve_protocol.md`).
    /// Every client-settable field is emitted with its canonical
    /// spelling; the server-owned fields (`sink`, `store`) never travel
    /// — [`RunSpec::from_wire`] rejects them by name.
    ///
    /// ```
    /// use pahq::api::RunSpec;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let spec = RunSpec::builder("gpt2s-sim", "ioi").tau(0.05).build()?;
    /// let back = RunSpec::from_wire(&spec.to_wire())?;
    /// assert_eq!(spec, back);
    /// # Ok(())
    /// # }
    /// ```
    pub fn to_wire(&self) -> Json {
        obj(vec![
            ("model", Json::from(self.model.clone())),
            ("task", Json::from(self.task.clone())),
            ("method", Json::from(self.method.as_str())),
            ("policy", Json::from(self.policy.name.clone())),
            ("tau", Json::from(self.tau as f64)),
            ("metric", Json::from(self.objective.key())),
            ("sweep", Json::from(self.sweep.label())),
            ("seed", Json::from(self.seed as usize)),
            ("trace", Json::from(self.record_trace)),
            (
                "faithfulness",
                Json::from(match self.faithfulness {
                    None => "off",
                    Some(false) => "score",
                    Some(true) => "normalized",
                }),
            ),
            ("faith_required", Json::from(self.faith_required)),
            ("substrate", Json::from(self.substrate.as_str())),
            ("sp_steps", Json::from(self.sp_steps)),
            ("ep_steps", Json::from(self.ep_steps)),
        ])
    }

    /// Parse a `submit_run` wire payload into a validated spec — the
    /// exact dual of [`RunSpec::to_wire`]. Only `model` and `task` are
    /// required; everything else keeps the builder defaults. Unknown
    /// keys are errors (a typo'd field must not silently run with its
    /// default), and the server-owned `sink`/`store` keys are rejected
    /// by name. The resulting spec always carries
    /// [`OutputSink::Memory`] and [`StoreSpec::Memory`]: where records
    /// land and which artifact store backs the run belong to the
    /// server, not the submission.
    pub fn from_wire(j: &Json) -> Result<RunSpec> {
        const KNOWN: [&str; 15] = [
            "model", "task", "method", "policy", "bits", "tau", "metric", "sweep", "seed",
            "trace", "faithfulness", "faith_required", "substrate", "sp_steps", "ep_steps",
        ];
        for key in j.as_obj()?.keys() {
            if matches!(key.as_str(), "sink" | "store" | "gc_horizon" | "out" | "json") {
                bail!("spec: key '{key}' is server-owned and not accepted on the wire");
            }
            if !KNOWN.contains(&key.as_str()) {
                bail!("spec: unknown key '{key}'");
            }
        }
        let bits = match j.opt("bits") {
            None => DEFAULT_BITS,
            Some(b) => b.as_usize()? as u32,
        };
        let mut b =
            RunSpec::builder(j.get("model")?.as_str()?, j.get("task")?.as_str()?).bits(bits);
        if let Some(m) = j.opt("method") {
            b = b.method(m.as_str()?.parse()?);
        }
        if let Some(p) = j.opt("policy") {
            b = b.policy(Policy::by_name(p.as_str()?, bits)?);
        }
        if let Some(t) = j.opt("tau") {
            b = b.tau(t.as_f64()? as f32);
        }
        if let Some(m) = j.opt("metric") {
            b = b.objective(m.as_str()?.parse()?);
        }
        if let Some(s) = j.opt("sweep") {
            b = b.sweep(s.as_str()?.parse()?);
        }
        if let Some(s) = j.opt("seed") {
            b = b.seed(wire_seed(s)?);
        }
        if let Some(t) = j.opt("trace") {
            b = b.trace(t.as_bool()?);
        }
        if let Some(f) = j.opt("faithfulness") {
            b = b.faithfulness(match f.as_str()? {
                "off" => None,
                "score" => Some(false),
                "normalized" => Some(true),
                other => {
                    bail!("faithfulness: unknown spelling '{other}' (off | score | normalized)")
                }
            });
        }
        if let Some(f) = j.opt("faith_required") {
            b = b.faith_required(f.as_bool()?);
        }
        if let Some(s) = j.opt("substrate") {
            b = b.substrate(s.as_str()?.parse()?);
        }
        if let Some(s) = j.opt("sp_steps") {
            b = b.sp_steps(s.as_usize()?);
        }
        if let Some(s) = j.opt("ep_steps") {
            b = b.ep_steps(s.as_usize()?);
        }
        b.build()
    }
}

/// Wire seeds ride a JSON number (f64): non-negative integers up to
/// 2^53 round-trip exactly, anything else is refused loudly.
fn wire_seed(j: &Json) -> Result<u64> {
    let x = j.as_f64()?;
    if x.fract() != 0.0 || !(0.0..=(1u64 << 53) as f64).contains(&x) {
        bail!("seed: must be a non-negative integer <= 2^53, got {x}");
    }
    Ok(x as u64)
}

/// Builder for [`RunSpec`]. Unset fields keep the documented defaults;
/// [`build`](RunSpecBuilder::build) resolves the implied policy and
/// runs the cross-field validation.
///
/// ```
/// use pahq::api::RunSpec;
///
/// # fn main() -> anyhow::Result<()> {
/// // workers only mean something under a batched sweep:
/// let err = RunSpec::builder("gpt2s-sim", "ioi").workers(4).build();
/// assert!(err.unwrap_err().to_string().starts_with("workers:"));
///
/// let spec = RunSpec::builder("gpt2s-sim", "ioi")
///     .sweep("batched".parse()?)
///     .workers(4)
///     .build()?;
/// assert_eq!(spec.sweep.workers(), 4);
/// # Ok(())
/// # }
/// ```
#[derive(Clone, Debug)]
pub struct RunSpecBuilder {
    model: String,
    task: String,
    method: MethodKind,
    policy: Option<Policy>,
    bits: u32,
    tau: f32,
    objective: Objective,
    sweep: SweepMode,
    workers: Option<usize>,
    seed: u64,
    record_trace: bool,
    faithfulness: Option<bool>,
    faith_required: bool,
    substrate: Substrate,
    sp_steps: usize,
    ep_steps: usize,
    sink: OutputSink,
    store: StoreSpec,
    gc_horizon: Option<u64>,
}

impl RunSpecBuilder {
    pub fn method(mut self, method: MethodKind) -> Self {
        self.method = method;
        self
    }

    /// Explicit session policy (otherwise the method's implied one at
    /// [`RunSpecBuilder::bits`]).
    pub fn policy(mut self, policy: Policy) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Nominal bit width of the *implied* policy (ignored when an
    /// explicit [`RunSpecBuilder::policy`] is set).
    pub fn bits(mut self, bits: u32) -> Self {
        self.bits = bits;
        self
    }

    pub fn tau(mut self, tau: f32) -> Self {
        self.tau = tau;
        self
    }

    pub fn objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    pub fn sweep(mut self, sweep: SweepMode) -> Self {
        self.sweep = sweep;
        self
    }

    /// Scoring threads for the batched sweep. Only meaningful with
    /// `sweep=batched` — [`RunSpecBuilder::build`] rejects it otherwise.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Record the per-step sweep trace into the record (Fig. 3).
    pub fn trace(mut self, record_trace: bool) -> Self {
        self.record_trace = record_trace;
        self
    }

    /// Score against the FP32 ground truth; the bool asks for the extra
    /// normalized-faithfulness forward passes.
    pub fn faithfulness(mut self, normalized: Option<bool>) -> Self {
        self.faithfulness = normalized;
        self
    }

    /// Propagate faithfulness errors instead of skipping with a notice.
    pub fn faith_required(mut self, required: bool) -> Self {
        self.faith_required = required;
        self
    }

    pub fn substrate(mut self, substrate: Substrate) -> Self {
        self.substrate = substrate;
        self
    }

    /// SP gate-training steps (baseline budget).
    pub fn sp_steps(mut self, steps: usize) -> Self {
        self.sp_steps = steps;
        self
    }

    /// Edge-Pruning mask-training steps (baseline budget).
    pub fn ep_steps(mut self, steps: usize) -> Self {
        self.ep_steps = steps;
        self
    }

    pub fn sink(mut self, sink: OutputSink) -> Self {
        self.sink = sink;
        self
    }

    /// Artifact-store backend ([`StoreSpec::Memory`] by default).
    pub fn store(mut self, store: StoreSpec) -> Self {
        self.store = store;
        self
    }

    /// Generation horizon for the disk store's GC sweep at open. Only
    /// meaningful with a disk store —
    /// [`build`](RunSpecBuilder::build) rejects it otherwise.
    pub fn gc_horizon(mut self, horizon: u64) -> Self {
        self.gc_horizon = Some(horizon);
        self
    }

    /// Resolve the implied policy and validate every cross-field
    /// constraint (errors name the offending field).
    pub fn build(self) -> Result<RunSpec> {
        let store = resolve_store(self.store, self.gc_horizon)?;
        let mut sweep = self.sweep;
        if let Some(w) = self.workers {
            if w == 0 {
                bail!("workers: must be >= 1");
            }
            match sweep {
                SweepMode::Batched { .. } => sweep = SweepMode::Batched { workers: w },
                SweepMode::Serial => {
                    bail!("workers: only meaningful with sweep=batched (got sweep=serial)")
                }
            }
        }
        let policy = match self.policy {
            Some(p) => p,
            None => self.method.implied_policy(self.bits)?,
        };
        let spec = RunSpec {
            model: self.model,
            task: self.task,
            method: self.method,
            policy,
            tau: self.tau,
            objective: self.objective,
            sweep,
            seed: self.seed,
            record_trace: self.record_trace,
            faithfulness: self.faithfulness,
            faith_required: self.faith_required,
            substrate: self.substrate,
            sp_steps: self.sp_steps,
            ep_steps: self.ep_steps,
            sink: self.sink,
            store,
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// Fold a builder's `--gc-horizon` into its store (an explicit horizon
/// wins over one already carried by a hand-built `Disk` variant) — and
/// reject the flag when there is no disk store for it to govern.
fn resolve_store(store: StoreSpec, gc_horizon: Option<u64>) -> Result<StoreSpec> {
    match (store, gc_horizon) {
        (StoreSpec::Memory, Some(_)) => {
            bail!("gc_horizon: only meaningful with --store disk[:PATH] (got --store mem)")
        }
        (StoreSpec::Disk { root, gc_horizon: carried }, h) => {
            Ok(StoreSpec::Disk { root, gc_horizon: h.or(carried) })
        }
        (s, None) => Ok(s),
    }
}

// ---------------------------------------------------------------------------
// MatrixSpec

/// A validated method x policy x model x task grid. Construct with
/// [`MatrixSpec::builder`] or [`MatrixSpec::from_cli`]; launch with
/// [`matrix`]. The underlying [`MatrixConfig`] is private, so every
/// grid that runs has passed the axis validation.
#[derive(Clone)]
pub struct MatrixSpec {
    config: MatrixConfig,
}

impl MatrixSpec {
    /// Start from the acceptance grid's defaults (every registered
    /// discovery method x {fp32, pahq-8b} on every task of the smallest
    /// model). The method axis derives from
    /// [`discovery::METHOD_NAMES`](crate::discovery::METHOD_NAMES), so
    /// registering a sixth method automatically lands in the default
    /// grid (and the CI matrix gate).
    pub fn builder() -> MatrixSpecBuilder {
        let d = MatrixConfig::quick();
        MatrixSpecBuilder {
            methods: discovery::METHOD_NAMES
                .iter()
                .map(|m| m.parse().expect("registry names parse as MethodKind"))
                .collect(),
            policies: d.policies,
            models: d.models,
            tasks: d.tasks,
            tau: d.tau,
            objective: d.objective,
            sweep: d.sweep,
            pool_workers: None,
            workers: d.workers,
            seed: d.seed,
            resume: false,
            quick: false,
            faithfulness: d.faithfulness,
            out_dir: d.out_dir,
            json_path: None,
            store: d.store,
            gc_horizon: None,
        }
    }

    /// Parse `pahq matrix` flags into a validated spec.
    pub fn from_cli(args: &Args) -> Result<MatrixSpec> {
        let bits = args.usize_or("bits", DEFAULT_BITS as usize)? as u32;
        let mut b = MatrixSpec::builder().quick(args.flag("quick")).resume(args.flag("resume"));
        if let Some(models) = args.list("models") {
            b = b.models(&models);
        }
        if let Some(tasks) = args.list("tasks") {
            b = b.tasks(&tasks);
        }
        if let Some(methods) = args.list("methods") {
            b = b.methods(
                methods.iter().map(|m| m.parse()).collect::<Result<Vec<MethodKind>>>()?,
            );
        }
        if let Some(policies) = args.list("policies") {
            b = b.policies(
                policies.iter().map(|p| Policy::by_name(p, bits)).collect::<Result<Vec<_>>>()?,
            );
        }
        if args.get("tau").is_some() {
            b = b.tau(args.f64_or("tau", DEFAULT_TAU as f64)? as f32);
        }
        if let Some(m) = args.get("metric") {
            b = b.objective(m.parse()?);
        }
        if let Some(w) = args.usize_opt("workers")? {
            b = b.workers(w);
        }
        b = b.seed(args.u64_or("seed", 0)?);
        // every sweep spelling `pahq run` accepts parses here too; the
        // bare `batched` defaults the per-cell pool to 2 replicas, and
        // an explicit --pool-workers overrides the count (a validation
        // error under a serial sweep)
        let pool_workers = args.usize_opt("pool-workers")?;
        let sweep = match args.get_or("sweep", "serial") {
            "batched" => SweepMode::Batched { workers: pool_workers.unwrap_or(2).max(1) },
            other => other.parse()?,
        };
        b = b.sweep(sweep);
        if let Some(k) = pool_workers {
            b = b.pool_workers(k);
        }
        if args.flag("no-faith") {
            b = b.faithfulness(false);
        }
        if let Some(out) = args.get("out") {
            b = b.out_dir(PathBuf::from(out));
        }
        if let Some(j) = args.json_path() {
            b = b.json_path(PathBuf::from(j));
        }
        if let Some(s) = args.get("store") {
            b = b.store(s.parse()?);
        }
        if args.get("gc-horizon").is_some() {
            b = b.gc_horizon(args.u64_or("gc-horizon", 0)?);
        }
        b.build()
    }

    /// The validated grid configuration (read-only).
    pub fn config(&self) -> &MatrixConfig {
        &self.config
    }

    /// The grid in its stable evaluation order.
    pub fn cells(&self) -> Vec<Cell> {
        matrix::grid(&self.config)
    }

    /// Serialize the grid axes as the `pahq serve` wire payload (the
    /// `submit_matrix` frame's `spec` object). Only the axes and the
    /// per-cell knobs travel; orchestration fields (`workers`, `out`,
    /// `resume`, `store`, ...) are the server's — the daemon runs every
    /// submission through its own queue, workers, and artifact store.
    pub fn to_wire(&self) -> Json {
        let c = &self.config;
        obj(vec![
            ("models", Json::from(c.models.clone())),
            ("tasks", Json::from(c.tasks.clone())),
            ("methods", Json::from(c.methods.clone())),
            (
                "policies",
                Json::Arr(c.policies.iter().map(|p| Json::from(p.name.clone())).collect()),
            ),
            ("tau", Json::from(c.tau as f64)),
            ("metric", Json::from(c.objective.key())),
            ("sweep", Json::from(c.sweep.label())),
            ("seed", Json::from(c.seed as usize)),
            ("faithfulness", Json::from(c.faithfulness)),
        ])
    }

    /// Parse a `submit_matrix` wire payload into a validated spec — the
    /// dual of [`MatrixSpec::to_wire`], through the same axis validation
    /// as [`MatrixSpec::builder`]. Every key is optional (the default is
    /// the acceptance grid); unknown and server-owned keys are errors.
    pub fn from_wire(j: &Json) -> Result<MatrixSpec> {
        const KNOWN: [&str; 10] = [
            "models", "tasks", "methods", "policies", "bits", "tau", "metric", "sweep", "seed",
            "faithfulness",
        ];
        for key in j.as_obj()?.keys() {
            if matches!(
                key.as_str(),
                "workers" | "pool_workers" | "out" | "json" | "store" | "gc_horizon" | "resume"
                    | "quick"
            ) {
                bail!("spec: key '{key}' is server-owned and not accepted on the wire");
            }
            if !KNOWN.contains(&key.as_str()) {
                bail!("spec: unknown key '{key}'");
            }
        }
        let str_vec = |j: &Json| -> Result<Vec<String>> {
            j.as_arr()?.iter().map(|s| Ok(s.as_str()?.to_string())).collect()
        };
        let bits = match j.opt("bits") {
            None => DEFAULT_BITS,
            Some(b) => b.as_usize()? as u32,
        };
        let mut b = MatrixSpec::builder();
        if let Some(m) = j.opt("models") {
            b = b.models(&str_vec(m)?);
        }
        if let Some(t) = j.opt("tasks") {
            b = b.tasks(&str_vec(t)?);
        }
        if let Some(m) = j.opt("methods") {
            b = b.methods(
                m.as_arr()?
                    .iter()
                    .map(|s| s.as_str()?.parse())
                    .collect::<Result<Vec<MethodKind>>>()?,
            );
        }
        if let Some(p) = j.opt("policies") {
            b = b.policies(
                p.as_arr()?
                    .iter()
                    .map(|s| Policy::by_name(s.as_str()?, bits))
                    .collect::<Result<Vec<_>>>()?,
            );
        }
        if let Some(t) = j.opt("tau") {
            b = b.tau(t.as_f64()? as f32);
        }
        if let Some(m) = j.opt("metric") {
            b = b.objective(m.as_str()?.parse()?);
        }
        if let Some(s) = j.opt("sweep") {
            b = b.sweep(s.as_str()?.parse()?);
        }
        if let Some(s) = j.opt("seed") {
            b = b.seed(wire_seed(s)?);
        }
        if let Some(f) = j.opt("faithfulness") {
            b = b.faithfulness(f.as_bool()?);
        }
        b.build()
    }
}

/// Builder for [`MatrixSpec`] — the grid axes plus orchestration knobs,
/// validated as a whole by [`build`](MatrixSpecBuilder::build).
#[derive(Clone)]
pub struct MatrixSpecBuilder {
    methods: Vec<MethodKind>,
    policies: Vec<Policy>,
    models: Vec<String>,
    tasks: Vec<String>,
    tau: f32,
    objective: Objective,
    sweep: SweepMode,
    pool_workers: Option<usize>,
    workers: usize,
    seed: u64,
    resume: bool,
    quick: bool,
    faithfulness: bool,
    out_dir: PathBuf,
    json_path: Option<PathBuf>,
    store: StoreSpec,
    gc_horizon: Option<u64>,
}

impl MatrixSpecBuilder {
    pub fn methods(mut self, methods: Vec<MethodKind>) -> Self {
        self.methods = methods;
        self
    }

    pub fn policies(mut self, policies: Vec<Policy>) -> Self {
        self.policies = policies;
        self
    }

    pub fn models(mut self, models: &[String]) -> Self {
        self.models = models.to_vec();
        self
    }

    pub fn tasks(mut self, tasks: &[String]) -> Self {
        self.tasks = tasks.to_vec();
        self
    }

    pub fn tau(mut self, tau: f32) -> Self {
        self.tau = tau;
        self
    }

    pub fn objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// Per-cell evaluation schedule; batched enables pool sharing
    /// between consecutive cells on one worker.
    pub fn sweep(mut self, sweep: SweepMode) -> Self {
        self.sweep = sweep;
        self
    }

    /// Per-cell batched-sweep pool size. Only meaningful with
    /// `sweep=batched` — [`MatrixSpecBuilder::build`] rejects it
    /// otherwise.
    pub fn pool_workers(mut self, workers: usize) -> Self {
        self.pool_workers = Some(workers);
        self
    }

    /// Concurrent cell workers draining the grid's job queue.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Skip cells whose valid record already exists on disk.
    pub fn resume(mut self, resume: bool) -> Self {
        self.resume = resume;
        self
    }

    pub fn quick(mut self, quick: bool) -> Self {
        self.quick = quick;
        self
    }

    /// Score each circuit against the FP32 ground truth (real substrate).
    pub fn faithfulness(mut self, faithfulness: bool) -> Self {
        self.faithfulness = faithfulness;
        self
    }

    /// Where per-cell records land.
    pub fn out_dir(mut self, out_dir: PathBuf) -> Self {
        self.out_dir = out_dir;
        self
    }

    /// Where the manifest lands (default: `<out_dir>/matrix.json`).
    pub fn json_path(mut self, json_path: PathBuf) -> Self {
        self.json_path = Some(json_path);
        self
    }

    /// Artifact-store backend every cell shares ([`StoreSpec::Memory`]
    /// by default; `Disk` makes the grid's seeding durable, so a cold
    /// `--resume` re-runs only the missing cells).
    pub fn store(mut self, store: StoreSpec) -> Self {
        self.store = store;
        self
    }

    /// Generation horizon for the disk store's GC sweep at startup.
    /// Only meaningful with a disk store —
    /// [`build`](MatrixSpecBuilder::build) rejects it otherwise.
    pub fn gc_horizon(mut self, horizon: u64) -> Self {
        self.gc_horizon = Some(horizon);
        self
    }

    /// Validate the grid axes and orchestration knobs (errors name the
    /// offending field) and freeze the configuration.
    pub fn build(self) -> Result<MatrixSpec> {
        fn no_dupes(field: &str, names: &[String]) -> Result<()> {
            if names.is_empty() {
                bail!("{field}: at least one entry required");
            }
            let mut seen = std::collections::BTreeSet::new();
            for n in names {
                if n.is_empty() {
                    bail!("{field}: entries must not be empty");
                }
                if !seen.insert(n.clone()) {
                    bail!("{field}: duplicate '{n}' (cell record filenames would collide)");
                }
            }
            Ok(())
        }
        for m in &self.methods {
            if m.is_policy_spelling() {
                bail!(
                    "methods: '{m}' is acdc under its implied policy — put it on the \
                     policies axis instead (e.g. policies=[{}])",
                    if *m == MethodKind::RtnQ { "rtn" } else { "pahq" }
                );
            }
        }
        let method_names: Vec<String> =
            self.methods.iter().map(|m| m.discovery_name().to_string()).collect();
        no_dupes("methods", &method_names)?;
        let policy_names: Vec<String> =
            self.policies.iter().map(|p| p.name.clone()).collect();
        no_dupes("policies", &policy_names)?;
        no_dupes("models", &self.models)?;
        no_dupes("tasks", &self.tasks)?;
        if !self.tau.is_finite() || self.tau < 0.0 {
            bail!("tau: must be a finite non-negative threshold, got {}", self.tau);
        }
        if self.workers < 1 {
            bail!("workers: at least one cell worker required");
        }
        let mut sweep = self.sweep;
        if let Some(k) = self.pool_workers {
            if k == 0 {
                bail!("pool_workers: must be >= 1");
            }
            match sweep {
                SweepMode::Batched { .. } => sweep = SweepMode::Batched { workers: k },
                SweepMode::Serial => {
                    bail!("pool_workers: only meaningful with sweep=batched (got sweep=serial)")
                }
            }
        }
        if matches!(sweep, SweepMode::Batched { workers: 0 }) {
            bail!("sweep: batched worker count must be >= 1");
        }
        // the manifest stores the seed through an f64 JSON number; beyond
        // 2^53 it would round and silently disable --resume
        if self.seed > (1u64 << 53) {
            bail!("seed: must fit in 53 bits (manifest round-trip), got {}", self.seed);
        }
        let store = resolve_store(self.store, self.gc_horizon)?;
        if let StoreSpec::Disk { gc_horizon: Some(0), .. } = &store {
            bail!("gc_horizon: must be >= 1 (a zero horizon could collect live artifacts)");
        }
        // a resume that would open a store written by a different store
        // schema cannot reuse its artifacts — fail by name up front
        // instead of silently recomputing the whole grid
        if self.resume {
            if let StoreSpec::Disk { root, .. } = &store {
                if let Some(v) = matrix::store::manifest_schema_at(root)? {
                    if v != matrix::store::STORE_SCHEMA_VERSION {
                        bail!(
                            "store: --resume against {} found store-manifest schema v{v}, but \
                             this build writes v{} — point --store at a fresh root",
                            root.display(),
                            matrix::store::STORE_SCHEMA_VERSION
                        );
                    }
                }
            }
        }
        let mut config = MatrixConfig::quick();
        config.methods = method_names;
        config.policies = self.policies;
        config.models = self.models;
        config.tasks = self.tasks;
        config.tau = self.tau;
        config.objective = self.objective;
        config.sweep = sweep;
        config.workers = self.workers;
        config.seed = self.seed;
        config.resume = self.resume;
        config.quick = self.quick;
        config.faithfulness = self.faithfulness;
        config.out_dir = self.out_dir;
        config.json_path = self.json_path;
        config.store = store;
        Ok(MatrixSpec { config })
    }
}

// ---------------------------------------------------------------------------
// Launch

/// Run one discovery from a validated spec — THE way a single run is
/// launched, whether by `pahq run`, a matrix cell's standalone
/// comparator, the experiment harness, or a library embedder.
///
/// ```no_run
/// use pahq::api::{self, OutputSink, RunSpec};
///
/// # fn main() -> anyhow::Result<()> {
/// let spec = RunSpec::builder("redwood2l-sim", "ioi")
///     .method("pahq".parse()?)
///     .faithfulness(Some(false))
///     .sink(OutputSink::Memory)
///     .build()?;
/// let rec = api::run(&spec)?;
/// println!("kept {} of {} edges", rec.n_kept, rec.n_edges);
/// # Ok(())
/// # }
/// ```
pub fn run(spec: &RunSpec) -> Result<RunRecord> {
    run_with_session(spec).map(|(rec, _)| rec)
}

/// [`run`], additionally handing back the live [`Session`] (real
/// substrate only) for callers that inspect the engine afterwards —
/// measured footprints, kept-edge labels, extra forwards. The CLI's
/// pretty-printing is built on this.
pub fn run_with_session(spec: &RunSpec) -> Result<(RunRecord, Option<Session>)> {
    spec.validate()?;
    // The spec's artifact store fronts every launch: in-memory (fresh,
    // classic behavior) or the durable disk store a grid seeded —
    // dataset/corrupt-cache/score reuse on hit, publish-back on miss.
    let store = matrix::open_cache(&spec.store, false)?;
    run_with_cache(spec, &store)
}

/// [`run_with_session`] against an externally-owned [`ArtifactCache`]
/// — how the `pahq serve` daemon keeps ONE shared store (and its
/// decoded-artifact front) hot across submissions instead of opening a
/// backend per request. `spec.store` is ignored here: the caller
/// already opened and owns the backend. Results are bit-identical to
/// [`run`] by construction — same body, same substrate resolution; only
/// the cache-hit provenance in `rec.cache` reflects the sharing.
pub(crate) fn run_with_cache(
    spec: &RunSpec,
    store: &crate::matrix::cache::ArtifactCache,
) -> Result<(RunRecord, Option<Session>)> {
    spec.validate()?;
    // Substrate resolution mirrors the matrix orchestrator: real when
    // the artifacts resolve AND the engine comes up, synthetic when
    // nothing resolves (or the engine cannot build under Auto), a loud
    // error on partial availability. The availability probe is cheap —
    // whether the engine itself comes up is decided by constructing the
    // actual session, so a run never builds a throwaway probe engine.
    let try_real = match spec.substrate {
        Substrate::Synthetic => false,
        Substrate::Real => true,
        // (the probe re-parses two small artifact metadata files that
        // seeded_examples loads again — a deliberate, once-per-run cost
        // that keeps the partial-availability error class intact)
        Substrate::Auto => matrix::artifacts_available(
            std::slice::from_ref(&spec.model),
            std::slice::from_ref(&spec.task),
        )?,
    };
    if try_real {
        let task = Task::new(&spec.model, &spec.task);
        let cfg = spec.discovery_config();
        let keys = matrix::store_keys(
            spec.method.discovery_name(),
            &spec.model,
            &spec.task,
            &spec.policy,
            spec.seed,
            spec.objective.key(),
        );
        // Engine *bring-up* (dataset resolution + weights + PJRT
        // executables) is the only failure class that may degrade to
        // the synthetic surface under Auto — the same class the matrix
        // probe tests. Everything after a live engine (configure,
        // discovery, faithfulness) is a real error and propagates.
        let built = matrix::seeded_examples_cached(&store, &task, spec.seed).and_then(
            |(ex, dataset_hit)| {
                let inbound = matrix::store_handoff(&store, &keys);
                Session::builder(&task)
                    .examples(ex)
                    .handoff(inbound)
                    .build()
                    .map(|s| (s, dataset_hit))
            },
        );
        match built {
            Ok((mut session, dataset_hit)) => {
                session.configure(&cfg)?;
                session.cache_stats.dataset_hit = dataset_hit;
                let method = discovery::by_name(spec.method.discovery_name())?;
                let mut rec = method.discover(&mut session, &task, &cfg)?;
                if let Some(normalized) = spec.faithfulness {
                    match session.evaluate_faithfulness(&cfg, &mut rec, normalized) {
                        Ok(()) => {}
                        Err(e) if spec.faith_required => return Err(e),
                        Err(e) => eprintln!("faithfulness skipped: {e}"),
                    }
                }
                // publish-back: a freshly packed corrupt cache and any
                // self-computed attribution scores land in the store, so
                // the next process (or a grid) starts warm
                if !session.cache_stats.corrupt_hit
                    && store.peek_corrupt(&keys.corrupt).is_none()
                {
                    store.put_corrupt(
                        &keys.corrupt,
                        std::sync::Arc::new(session.engine.corrupt_cache.clone()),
                    );
                }
                if let (Some(k), Some(s)) = (&keys.scores, session.computed_scores()) {
                    store.put_scores(k, s);
                }
                // store reuse lands in the record like a grid cell's
                // (absent when nothing hit, so memory-store records are
                // byte-identical to the pre-store format)
                if session.cache_stats.any() {
                    rec.cache = Some(session.cache_stats.clone());
                }
                write_record(spec, &rec)?;
                return Ok((rec, Some(session)));
            }
            // engine bring-up failing under Real is the caller's error;
            // under Auto it degrades to the synthetic surface exactly
            // like the matrix's engine-unavailable path
            Err(e) if spec.substrate == Substrate::Real => return Err(e),
            Err(e) => eprintln!("engine unavailable ({e}); running the synthetic surface"),
        }
    }
    // a caller that declared faithfulness mandatory cannot be handed a
    // synthetic record that silently lacks it
    if spec.faith_required && spec.faithfulness.is_some() {
        bail!(
            "faithfulness: required, but the synthetic substrate has no FP32 ground \
             truth to score against — build the engine artifacts or drop faith_required"
        );
    }
    let cell = Cell {
        method: spec.method.discovery_name().to_string(),
        policy: spec.policy.clone(),
        model: spec.model.clone(),
        task: spec.task.clone(),
    };
    let (surface, surface_hit) =
        matrix::synthetic_surface_cached(&store, &spec.model, &spec.task, spec.seed);
    let mut rec =
        matrix::synthetic_cell_record(&cell, spec.tau, spec.sweep, spec.seed, &surface, None)?;
    if surface_hit {
        // record the store hit like a synthetic grid cell would
        let mut stats = CacheStats::default();
        stats.corrupt_hit = true;
        rec.cache = Some(stats);
    }
    write_record(spec, &rec)?;
    Ok((rec, None))
}

fn write_record(spec: &RunSpec, rec: &RunRecord) -> Result<()> {
    if let Some(path) = spec.sink.path_for(rec) {
        rec.save(&path)?;
    }
    Ok(())
}

/// Decompose a matrix spec into per-cell [`RunSpec`]s, mirroring
/// [`crate::matrix::standalone_cell`]'s derivation so each cell is
/// bit-identical to a standalone [`run`] of the same spec. Shared by
/// the `serve` daemon (wire submissions) and the `load` harness's
/// direct mode, so both decompose a grid identically.
pub(crate) fn matrix_cells(spec: &MatrixSpec) -> Result<Vec<(String, RunSpec)>> {
    let cfg = spec.config();
    spec.cells()
        .into_iter()
        .map(|cell| {
            let spec = RunSpec::builder(&cell.model, &cell.task)
                .method(cell.method.parse()?)
                .policy(cell.policy.clone())
                .tau(cfg.tau)
                .objective(cfg.objective)
                .sweep(cfg.sweep)
                .seed(cfg.seed)
                .build()?;
            Ok((cell.id(), spec))
        })
        .collect()
}

/// Run a full grid from a validated spec — THE way a matrix is
/// launched. Returns the manifest plus where it was written.
pub fn matrix(spec: &MatrixSpec) -> Result<MatrixOutcome> {
    matrix::run(&spec.config)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_spellings_round_trip() {
        for m in MethodKind::ALL {
            assert_eq!(m.as_str().parse::<MethodKind>().unwrap(), m);
        }
        assert_eq!("rtn".parse::<MethodKind>().unwrap(), MethodKind::RtnQ);
        assert_eq!("ep".parse::<MethodKind>().unwrap(), MethodKind::EdgePruning);
        assert!("turbo".parse::<MethodKind>().is_err());
    }

    #[test]
    fn implied_policies_follow_the_paper() {
        assert_eq!(MethodKind::Acdc.implied_policy(8).unwrap().name, "acdc-fp32");
        assert_eq!(MethodKind::RtnQ.implied_policy(4).unwrap().name, "rtn-q-4b");
        assert_eq!(MethodKind::Pahq.implied_policy(8).unwrap().name, "pahq-8b");
        assert_eq!(MethodKind::Eap.implied_policy(8).unwrap().name, "pahq-8b");
        assert!(MethodKind::Pahq.implied_policy(7).is_err());
    }

    #[test]
    fn sim_kinds_cover_every_method() {
        assert_eq!(MethodKind::Acdc.sim_kind(), memory::MethodKind::AcdcFp32);
        assert_eq!(MethodKind::RtnQ.sim_kind(), memory::MethodKind::RtnQ);
        for m in [MethodKind::Pahq, MethodKind::Eap, MethodKind::Hisp, MethodKind::Sp] {
            assert_eq!(m.sim_kind(), memory::MethodKind::Pahq);
        }
    }

    #[test]
    fn sink_paths_resolve() {
        let spec = RunSpec::builder("m", "t").build().unwrap();
        let rec_path = |sink: OutputSink| {
            let mut s = spec.clone();
            s.sink = sink;
            let cell = Cell {
                method: "acdc".into(),
                policy: Policy::fp32(),
                model: "m".into(),
                task: "t".into(),
            };
            let surface = matrix::synthetic_surface("m", "t", 0);
            let rec =
                matrix::synthetic_cell_record(&cell, 0.01, SweepMode::Serial, 0, &surface, None)
                    .unwrap();
            s.sink.path_for(&rec)
        };
        assert_eq!(rec_path(OutputSink::Memory), None);
        assert_eq!(
            rec_path(OutputSink::Path(PathBuf::from("x.json"))),
            Some(PathBuf::from("x.json"))
        );
        let def = rec_path(OutputSink::Default).unwrap();
        assert!(def.to_string_lossy().ends_with("run_acdc_acdc-fp32_m_t.json"));
    }
}
