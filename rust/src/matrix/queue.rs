//! The shared work-stealing job queue behind the grid executor and the
//! `pahq serve` daemon.
//!
//! The grid executor (`matrix::run`) drains a pre-filled queue to completion inside one
//! `thread::scope` (phase A combo seeding, phase B cell execution) —
//! workers [`try_pop`](WorkQueue::try_pop) until empty and exit. The
//! serve daemon keeps the *same* queue alive across submissions:
//! connection handlers [`push`](WorkQueue::push) cells from any client,
//! a long-lived worker pool blocks on [`pop_wait`](WorkQueue::pop_wait),
//! and [`close`](WorkQueue::close) drains the backlog then releases the
//! workers for a clean shutdown. One queue type, two intake patterns —
//! a grid is just the special case where everything is enqueued before
//! the first pop.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::util::sync::{lock_recover, wait_recover};

/// An unbounded multi-producer multi-consumer FIFO with a close
/// handshake. Items pushed before [`close`](WorkQueue::close) are
/// always drained; after close, pushes are refused and blocked poppers
/// wake up with `None` once the backlog is empty.
pub struct WorkQueue<T> {
    inner: Mutex<Inner<T>>,
    ready: Condvar,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Default for WorkQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> WorkQueue<T> {
    pub fn new() -> WorkQueue<T> {
        WorkQueue { inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }), ready: Condvar::new() }
    }

    /// Enqueue one item. Returns `false` (dropping the item) when the
    /// queue is already closed.
    pub fn push(&self, item: T) -> bool {
        let mut inner = lock_recover(&self.inner);
        if inner.closed {
            return false;
        }
        inner.items.push_back(item);
        self.ready.notify_one();
        true
    }

    /// Non-blocking pop — the drain-until-empty pattern of a pre-filled
    /// grid queue.
    pub fn try_pop(&self) -> Option<T> {
        lock_recover(&self.inner).items.pop_front()
    }

    /// Blocking pop — the daemon worker pattern. Returns `None` only
    /// after [`close`](WorkQueue::close) once the backlog is drained.
    pub fn pop_wait(&self) -> Option<T> {
        let mut inner = lock_recover(&self.inner);
        loop {
            if let Some(item) = inner.items.pop_front() {
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = wait_recover(&self.ready, inner);
        }
    }

    /// Refuse further pushes and wake every blocked popper. Items
    /// already queued are still handed out before poppers see `None`.
    pub fn close(&self) {
        lock_recover(&self.inner).closed = true;
        self.ready.notify_all();
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.inner).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_and_try_pop_drain() {
        let q = WorkQueue::new();
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop(), Some(1));
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn close_drains_backlog_then_releases() {
        let q = WorkQueue::new();
        q.push("a");
        q.close();
        assert!(!q.push("b"), "push after close must be refused");
        assert_eq!(q.pop_wait(), Some("a"), "backlog drains before None");
        assert_eq!(q.pop_wait(), None);
    }

    #[test]
    fn pop_wait_blocks_until_push_or_close() {
        let q = std::sync::Arc::new(WorkQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(x) = q2.pop_wait() {
                got.push(x);
            }
            got
        });
        q.push(10);
        q.push(20);
        q.close();
        assert_eq!(h.join().unwrap(), vec![10, 20]);
    }
}
