//! The matrix's keyed artifact store — the cross-run reuse that makes a
//! full method x policy x task grid cheaper than its cells run in
//! isolation.
//!
//! Three artifact classes are memoized, each under a canonical string
//! key derived from exactly the inputs that determine its bits:
//!
//! - **datasets** per (task, seed, n) — the evaluation batch; `seed 0`
//!   is the python-exported artifact batch, any other seed routes
//!   through the shared [`dataset_seed`] derivation into the Rust
//!   generator. Every launch path — [`crate::api::run`] (and therefore
//!   `pahq run` / `pahq sweep` / library embedders) and every matrix
//!   cell — resolves examples through [`dataset_for`], so identical
//!   (task, seed, n) inputs are bit-identical across entry points.
//! - **corrupt caches** per (model, task, seed, cache tag) — the packed
//!   corrupted-activation cache all five methods' runs on one task
//!   share (hi-fidelity policies share one FP32 cache; RTN-Q tags by
//!   its own policy name because its cache lives on the low lattice).
//! - **scores** per (method, model, task, seed, objective) — the FP32
//!   attribution score vector EAP / HISP / SP / Edge-Pruning each
//!   compute once per task and reuse across precision policies.
//!
//! Stores are thread-safe (the work-stealing cell workers share one
//! [`ArtifactCache`]) and count hits/misses; the manifest's
//! cache-effectiveness rollup and CI's reuse floor read those counters.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::acdc::sweep::SyntheticSurface;
use crate::model::{Dataset, Example};
use crate::tasks::Vocab;
use crate::tensor::QTensor;

/// FNV-1a-64 over a string (the same constants `record::kept_hash` uses).
pub fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The one dataset-seed derivation every subcommand shares: fold the
/// task name into the user's base seed so different tasks never draw
/// the same generator stream at the same base.
pub fn dataset_seed(task: &str, base: u64) -> u64 {
    fnv64(task) ^ base.wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

/// Cache key of an evaluation dataset.
pub fn dataset_key(task: &str, seed: u64, n: usize) -> String {
    format!("dataset/{task}/{seed}/{n}")
}

/// Cache key of a packed corrupted-activation cache. `cache_tag` is
/// `"fp32"` for hi-fidelity policies (they all share one FP32 cache) and
/// the policy name for low-fidelity ones (RTN-Q packs on its own lattice).
pub fn corrupt_key(model: &str, task: &str, seed: u64, cache_tag: &str) -> String {
    format!("corrupt/{model}/{task}/{seed}/{cache_tag}")
}

/// Cache key of a method's FP32 attribution score vector.
pub fn scores_key(method: &str, model: &str, task: &str, seed: u64, objective: &str) -> String {
    format!("scores/{method}/{model}/{task}/{seed}/{objective}")
}

/// Cache key of a synthetic-substrate damage surface (the corrupt-cache
/// analog when engine artifacts are absent).
pub fn surface_key(model: &str, task: &str, seed: u64) -> String {
    format!("surface/{model}/{task}/{seed}")
}

/// Resolve the evaluation examples for (task, seed, n): seed 0 is the
/// python-exported artifact batch; any other seed routes through
/// [`dataset_seed`] into the shared Rust generator. This is the single
/// dataset entry point behind [`crate::api::run`] and `pahq matrix`.
pub fn dataset_for(task: &str, seed: u64, n: usize) -> Result<Vec<Example>> {
    if seed == 0 {
        return Ok(Dataset::by_task(task)?.batch(n)?.to_vec());
    }
    Vocab::load()?.make_dataset(task, n, dataset_seed(task, seed))
}

/// One typed store: keyed, thread-safe, hit/miss counted. Values are
/// deterministic functions of their key, so first-writer-wins insertion
/// is value-safe under concurrency.
pub struct Store<V> {
    map: Mutex<HashMap<String, Arc<V>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl<V> Default for Store<V> {
    fn default() -> Self {
        Store {
            map: Mutex::new(HashMap::new()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }
}

impl<V> Store<V> {
    /// Counted lookup — the cell-facing entry point.
    pub fn get(&self, key: &str) -> Option<Arc<V>> {
        let got = self.map.lock().unwrap().get(key).cloned();
        if got.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        got
    }

    /// Uncounted lookup — the seeding phase peeks without skewing the
    /// cell-facing hit/miss statistics.
    pub fn peek(&self, key: &str) -> Option<Arc<V>> {
        self.map.lock().unwrap().get(key).cloned()
    }

    /// Insert; the first writer wins (values are deterministic per key).
    pub fn put(&self, key: &str, v: Arc<V>) {
        self.map.lock().unwrap().entry(key.to_string()).or_insert(v);
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }
}

/// The matrix's shared artifact store: one [`Store`] per reusable
/// artifact class (see module docs), plus the synthetic-substrate
/// surfaces whose hits count as corrupt-cache hits (they are the
/// corrupt-cache analog).
#[derive(Default)]
pub struct ArtifactCache {
    pub datasets: Store<Vec<Example>>,
    pub corrupt: Store<Vec<QTensor>>,
    pub scores: Store<Vec<f32>>,
    pub surfaces: Store<SyntheticSurface>,
}

impl ArtifactCache {
    /// Corrupt-cache hits across both substrates.
    pub fn corrupt_hits(&self) -> usize {
        self.corrupt.hits() + self.surfaces.hits()
    }

    /// Total counted misses across every store.
    pub fn misses(&self) -> usize {
        self.datasets.misses()
            + self.corrupt.misses()
            + self.scores.misses()
            + self.surfaces.misses()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn keys_are_collision_free_across_inputs() {
        let keys = [
            dataset_key("ioi", 0, 32),
            dataset_key("ioi", 1, 32),
            dataset_key("ioi", 0, 64),
            dataset_key("docstring", 0, 32),
            corrupt_key("gpt2s-sim", "ioi", 0, "fp32"),
            corrupt_key("gpt2s-sim", "ioi", 1, "fp32"),
            corrupt_key("gpt2s-sim", "ioi", 0, "rtn-q-8b"),
            corrupt_key("gpt2s-sim", "docstring", 0, "fp32"),
            corrupt_key("redwood2l-sim", "ioi", 0, "fp32"),
            scores_key("eap", "gpt2s-sim", "ioi", 0, "kl"),
            scores_key("hisp", "gpt2s-sim", "ioi", 0, "kl"),
            scores_key("eap", "gpt2s-sim", "ioi", 0, "task"),
            scores_key("eap", "gpt2s-sim", "ioi", 7, "kl"),
            scores_key("eap", "gpt2s-sim", "docstring", 0, "kl"),
            surface_key("gpt2s-sim", "ioi", 0),
            surface_key("gpt2s-sim", "ioi", 7),
        ];
        let uniq: HashSet<&String> = keys.iter().collect();
        assert_eq!(uniq.len(), keys.len(), "every key distinct");
    }

    #[test]
    fn dataset_seed_separates_tasks_and_bases() {
        assert_ne!(dataset_seed("ioi", 1), dataset_seed("docstring", 1));
        assert_ne!(dataset_seed("ioi", 1), dataset_seed("ioi", 2));
        assert_eq!(dataset_seed("ioi", 3), dataset_seed("ioi", 3));
    }

    #[test]
    fn store_counts_hits_and_misses() {
        let s: Store<usize> = Store::default();
        assert!(s.get("a").is_none());
        assert_eq!((s.hits(), s.misses()), (0, 1));
        s.put("a", Arc::new(7));
        assert_eq!(*s.get("a").unwrap(), 7);
        assert_eq!((s.hits(), s.misses()), (1, 1));
        // peek never counts; first writer wins
        assert_eq!(*s.peek("a").unwrap(), 7);
        s.put("a", Arc::new(9));
        assert_eq!(*s.peek("a").unwrap(), 7);
        assert_eq!((s.hits(), s.misses()), (1, 1));
    }

    #[test]
    fn fnv64_matches_reference_vector() {
        // FNV-1a 64 of the empty string is the offset basis
        assert_eq!(fnv64(""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv64("ioi"), fnv64("docstring"));
    }
}
