//! The matrix's keyed artifact store — the cross-run reuse that makes a
//! full method x policy x task grid cheaper than its cells run in
//! isolation.
//!
//! Three artifact classes are memoized, each under a canonical string
//! key derived from exactly the inputs that determine its bits:
//!
//! - **datasets** per (task, seed, n) — the evaluation batch; `seed 0`
//!   is the python-exported artifact batch, any other seed routes
//!   through the shared [`dataset_seed`] derivation into the Rust
//!   generator. Every launch path — [`crate::api::run`] (and therefore
//!   `pahq run` / `pahq sweep` / library embedders) and every matrix
//!   cell — resolves examples through [`dataset_for`], so identical
//!   (task, seed, n) inputs are bit-identical across entry points.
//! - **corrupt caches** per (model, task, seed, cache tag) — the packed
//!   corrupted-activation cache all five methods' runs on one task
//!   share (hi-fidelity policies share one FP32 cache; RTN-Q tags by
//!   its own policy name because its cache lives on the low lattice).
//! - **scores** per (method, model, task, seed, objective) — the FP32
//!   attribution score vector EAP / HISP / SP / Edge-Pruning each
//!   compute once per task and reuse across precision policies.
//!
//! Stores are thread-safe (the work-stealing cell workers share one
//! [`ArtifactCache`]) and count hits/misses; the manifest's
//! cache-effectiveness rollup and CI's reuse floor read those counters.
//!
//! Since PR 6 the cache fronts a byte-level [`ArtifactStore`] backend
//! (in-memory or the durable on-disk store in [`super::store`]): every
//! typed accessor decodes through the bit-identical value codecs below,
//! so a cold process pointed at a populated disk store resumes with
//! exactly the artifacts a warm one computed.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::sync::lock_recover;

use anyhow::{bail, Result};

use crate::acdc::sweep::SyntheticSurface;
use crate::model::{Dataset, Example};
use crate::tasks::Vocab;
use crate::tensor::QTensor;

pub use super::store::{
    address, ArtifactStore, DiskStore, GcReport, MemoryStore, CODEC_VERSION,
    STORE_SCHEMA_VERSION,
};

/// FNV-1a-64 over a string (the same constants `record::kept_hash` uses).
pub fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The one dataset-seed derivation every subcommand shares: fold the
/// task name into the user's base seed so different tasks never draw
/// the same generator stream at the same base.
pub fn dataset_seed(task: &str, base: u64) -> u64 {
    fnv64(task) ^ base.wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

/// Cache key of an evaluation dataset.
pub fn dataset_key(task: &str, seed: u64, n: usize) -> String {
    format!("dataset/{task}/{seed}/{n}")
}

/// Cache key of a packed corrupted-activation cache. `cache_tag` is
/// `"fp32"` for hi-fidelity policies (they all share one FP32 cache) and
/// the policy name for low-fidelity ones (RTN-Q packs on its own lattice).
pub fn corrupt_key(model: &str, task: &str, seed: u64, cache_tag: &str) -> String {
    format!("corrupt/{model}/{task}/{seed}/{cache_tag}")
}

/// Cache key of a method's FP32 attribution score vector.
pub fn scores_key(method: &str, model: &str, task: &str, seed: u64, objective: &str) -> String {
    format!("scores/{method}/{model}/{task}/{seed}/{objective}")
}

/// Cache key of a synthetic-substrate damage surface (the corrupt-cache
/// analog when engine artifacts are absent).
pub fn surface_key(model: &str, task: &str, seed: u64) -> String {
    format!("surface/{model}/{task}/{seed}")
}

/// Resolve the evaluation examples for (task, seed, n): seed 0 is the
/// python-exported artifact batch; any other seed routes through
/// [`dataset_seed`] into the shared Rust generator. This is the single
/// dataset entry point behind [`crate::api::run`] and `pahq matrix`.
pub fn dataset_for(task: &str, seed: u64, n: usize) -> Result<Vec<Example>> {
    if seed == 0 {
        return Ok(Dataset::by_task(task)?.batch(n)?.to_vec());
    }
    Vocab::load()?.make_dataset(task, n, dataset_seed(task, seed))
}

/// One typed store: keyed, thread-safe, hit/miss counted. Values are
/// deterministic functions of their key, so first-writer-wins insertion
/// is value-safe under concurrency.
pub struct Store<V> {
    map: Mutex<HashMap<String, Arc<V>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl<V> Default for Store<V> {
    fn default() -> Self {
        Store {
            map: Mutex::new(HashMap::new()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }
}

impl<V> Store<V> {
    /// Counted lookup — the cell-facing entry point.
    pub fn get(&self, key: &str) -> Option<Arc<V>> {
        let got = lock_recover(&self.map).get(key).cloned();
        if got.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        got
    }

    /// Uncounted lookup — the seeding phase peeks without skewing the
    /// cell-facing hit/miss statistics.
    pub fn peek(&self, key: &str) -> Option<Arc<V>> {
        lock_recover(&self.map).get(key).cloned()
    }

    /// Insert; the first writer wins (values are deterministic per key).
    pub fn put(&self, key: &str, v: Arc<V>) {
        lock_recover(&self.map).entry(key.to_string()).or_insert(v);
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    fn count_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    fn count_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Value codecs — the typed artifact classes to/from durable bytes.
// Every codec is length-prefixed little-endian with f32 carried as raw
// bits, so decode(encode(x)) is bit-identical (property-tested in
// tests/properties.rs). Bumping any layout here bumps
// [`CODEC_VERSION`], which re-addresses every stored artifact.

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, x: f32) {
    out.extend_from_slice(&x.to_bits().to_le_bytes());
}

/// Bounds-checked little-endian reader over an encoded artifact.
struct Rd<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Rd<'a> {
        Rd { b, at: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.at + n > self.b.len() {
            bail!("artifact bytes truncated at {} (need {n} more)", self.at);
        }
        let s = &self.b[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        // pahq-lint: allow(panic-unwrap): bytes(8) returned exactly 8 bytes
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn len(&mut self) -> Result<usize> {
        let n = self.u64()?;
        // cheap sanity bound: no artifact holds more elements than bytes
        if n > self.b.len() as u64 {
            bail!("artifact length {n} exceeds payload size");
        }
        Ok(n as usize)
    }

    fn f32(&mut self) -> Result<f32> {
        // pahq-lint: allow(panic-unwrap): bytes(4) returned exactly 4 bytes
        Ok(f32::from_bits(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap())))
    }

    fn done(&self) -> Result<()> {
        if self.at != self.b.len() {
            bail!("artifact has {} trailing bytes", self.b.len() - self.at);
        }
        Ok(())
    }
}

/// FP32 score vector: u64 count + raw f32 bits per element.
pub fn encode_scores(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + 4 * v.len());
    put_u64(&mut out, v.len() as u64);
    for &x in v {
        put_f32(&mut out, x);
    }
    out
}

/// Exact inverse of [`encode_scores`].
pub fn decode_scores(b: &[u8]) -> Result<Vec<f32>> {
    let mut r = Rd::new(b);
    let n = r.len()?;
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        v.push(r.f32()?);
    }
    r.done()?;
    Ok(v)
}

/// Packed corrupt-activation cache: u64 plane count, then each plane as
/// a u64-length-prefixed [`QTensor::to_bytes`] record (the packed-plane
/// byte layout from PR 2, carried verbatim).
pub fn encode_corrupt(v: &[QTensor]) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, v.len() as u64);
    for q in v {
        let b = q.to_bytes();
        put_u64(&mut out, b.len() as u64);
        out.extend_from_slice(&b);
    }
    out
}

/// Exact inverse of [`encode_corrupt`].
pub fn decode_corrupt(b: &[u8]) -> Result<Vec<QTensor>> {
    let mut r = Rd::new(b);
    let n = r.len()?;
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        let len = r.len()?;
        v.push(QTensor::from_bytes(r.bytes(len)?)?);
    }
    r.done()?;
    Ok(v)
}

fn put_sparse(out: &mut Vec<u8>, v: &[(usize, f32)]) {
    put_u64(out, v.len() as u64);
    for &(tok, w) in v {
        put_u64(out, tok as u64);
        put_f32(out, w);
    }
}

fn read_sparse(r: &mut Rd) -> Result<Vec<(usize, f32)>> {
    let n = r.len()?;
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        let tok = r.u64()? as usize;
        v.push((tok, r.f32()?));
    }
    Ok(v)
}

/// Evaluation batch: u64 example count, then per example the clean and
/// corrupt token streams, answer position, sparse answer/distractor
/// distributions (weights as raw f32 bits), and label.
pub fn encode_examples(v: &[Example]) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, v.len() as u64);
    for ex in v {
        for stream in [&ex.clean, &ex.corrupt] {
            put_u64(&mut out, stream.len() as u64);
            for &t in stream {
                put_u64(&mut out, t as u64);
            }
        }
        put_u64(&mut out, ex.pos as u64);
        put_sparse(&mut out, &ex.ans);
        put_sparse(&mut out, &ex.dis);
        put_u64(&mut out, ex.label as u64);
    }
    out
}

/// Exact inverse of [`encode_examples`].
pub fn decode_examples(b: &[u8]) -> Result<Vec<Example>> {
    let mut r = Rd::new(b);
    let n = r.len()?;
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        let mut streams = [Vec::new(), Vec::new()];
        for stream in &mut streams {
            let len = r.len()?;
            stream.reserve(len);
            for _ in 0..len {
                stream.push(r.u64()? as usize);
            }
        }
        let [clean, corrupt] = streams;
        let pos = r.u64()? as usize;
        let ans = read_sparse(&mut r)?;
        let dis = read_sparse(&mut r)?;
        let label = r.u64()? as usize;
        v.push(Example { clean, corrupt, pos, ans, dis, label });
    }
    r.done()?;
    Ok(v)
}

/// The matrix's shared artifact store: one decoded [`Store`] front per
/// reusable artifact class (see module docs) — the synthetic-substrate
/// surfaces' hits count as corrupt-cache hits, they are the
/// corrupt-cache analog — over one byte-level [`ArtifactStore`]
/// backend. The typed accessors below are the only mutation path: a
/// counted `get` consults the front, then the backend (decoding through
/// the bit-identical codecs); a `put` populates both. The `peek`
/// variants are the seeding phase's uncounted lookups, so cell-facing
/// hit/miss statistics stay exactly what they were in-memory.
pub struct ArtifactCache {
    datasets: Store<Vec<Example>>,
    corrupt: Store<Vec<QTensor>>,
    scores: Store<Vec<f32>>,
    surfaces: Store<SyntheticSurface>,
    backend: Arc<dyn ArtifactStore>,
}

impl Default for ArtifactCache {
    fn default() -> Self {
        Self::in_memory()
    }
}

/// Counted or peeking read-through: front first, then the backend
/// (decoded values warm the front). Backend read/decode failures
/// degrade to a miss with a notice — the cell recomputes.
fn read_through<V>(
    front: &Store<V>,
    backend: &Arc<dyn ArtifactStore>,
    key: &str,
    counted: bool,
    decode: impl Fn(&[u8]) -> Result<V>,
) -> Option<Arc<V>> {
    if let Some(v) = front.peek(key) {
        if counted {
            front.count_hit();
        }
        return Some(v);
    }
    let fetched = match backend.get(key) {
        Ok(Some(bytes)) => match decode(&bytes) {
            Ok(v) => Some(Arc::new(v)),
            Err(e) => {
                eprintln!("store: decoding '{key}' failed ({e}); recomputing");
                None
            }
        },
        Ok(None) => None,
        Err(e) => {
            eprintln!("store: reading '{key}' failed ({e}); recomputing");
            None
        }
    };
    match fetched {
        Some(v) => {
            front.put(key, v.clone());
            if counted {
                front.count_hit();
            }
            Some(v)
        }
        None => {
            if counted {
                front.count_miss();
            }
            None
        }
    }
}

/// Write-through: the front keeps the decoded `Arc`, the backend gets
/// the encoded bytes. A durable-write failure keeps the run alive
/// in-memory (the store is an optimization, not a correctness
/// dependency) but is reported, since a later cold resume would
/// recompute.
fn write_through<V>(
    front: &Store<V>,
    backend: &Arc<dyn ArtifactStore>,
    key: &str,
    v: Arc<V>,
    encode: impl Fn(&V) -> Vec<u8>,
) {
    if let Err(e) = backend.put(key, &encode(&v)) {
        eprintln!("store: writing '{key}' failed ({e}); artifact stays in-memory only");
    }
    front.put(key, v);
}

impl ArtifactCache {
    /// Process-local cache over the in-memory backend — dies with the
    /// process, exactly the pre-PR-6 behavior.
    pub fn in_memory() -> Self {
        Self::with_backend(Arc::new(MemoryStore::default()))
    }

    /// Cache over an explicit backend (the durable [`DiskStore`], a
    /// test double, …).
    pub fn with_backend(backend: Arc<dyn ArtifactStore>) -> Self {
        ArtifactCache {
            datasets: Store::default(),
            corrupt: Store::default(),
            scores: Store::default(),
            surfaces: Store::default(),
            backend,
        }
    }

    /// The byte-level backend (shared; GC sweeps go through here).
    pub fn backend(&self) -> Arc<dyn ArtifactStore> {
        self.backend.clone()
    }

    // -- datasets ----------------------------------------------------------

    /// Counted dataset lookup — the cell-facing entry point.
    pub fn dataset(&self, key: &str) -> Option<Arc<Vec<Example>>> {
        read_through(&self.datasets, &self.backend, key, true, decode_examples)
    }

    /// Uncounted dataset lookup for the seeding phase.
    pub fn peek_dataset(&self, key: &str) -> Option<Arc<Vec<Example>>> {
        read_through(&self.datasets, &self.backend, key, false, decode_examples)
    }

    pub fn put_dataset(&self, key: &str, v: Arc<Vec<Example>>) {
        write_through(&self.datasets, &self.backend, key, v, |v| encode_examples(v));
    }

    // -- corrupt-activation caches ----------------------------------------

    /// Counted corrupt-cache lookup.
    pub fn corrupt(&self, key: &str) -> Option<Arc<Vec<QTensor>>> {
        read_through(&self.corrupt, &self.backend, key, true, decode_corrupt)
    }

    /// Uncounted corrupt-cache lookup for the seeding phase.
    pub fn peek_corrupt(&self, key: &str) -> Option<Arc<Vec<QTensor>>> {
        read_through(&self.corrupt, &self.backend, key, false, decode_corrupt)
    }

    pub fn put_corrupt(&self, key: &str, v: Arc<Vec<QTensor>>) {
        write_through(&self.corrupt, &self.backend, key, v, |v| encode_corrupt(v));
    }

    // -- attribution score vectors -----------------------------------------

    /// Counted score-vector lookup.
    pub fn scores(&self, key: &str) -> Option<Arc<Vec<f32>>> {
        read_through(&self.scores, &self.backend, key, true, decode_scores)
    }

    /// Uncounted score-vector lookup for the seeding phase.
    pub fn peek_scores(&self, key: &str) -> Option<Arc<Vec<f32>>> {
        read_through(&self.scores, &self.backend, key, false, decode_scores)
    }

    pub fn put_scores(&self, key: &str, v: Arc<Vec<f32>>) {
        write_through(&self.scores, &self.backend, key, v, |v| encode_scores(v));
    }

    // -- synthetic surfaces ------------------------------------------------

    /// Counted surface lookup (the synthetic corrupt-cache analog).
    pub fn surface(&self, key: &str) -> Option<Arc<SyntheticSurface>> {
        read_through(&self.surfaces, &self.backend, key, true, SyntheticSurface::from_bytes)
    }

    /// Uncounted surface lookup for the seeding phase.
    pub fn peek_surface(&self, key: &str) -> Option<Arc<SyntheticSurface>> {
        read_through(&self.surfaces, &self.backend, key, false, SyntheticSurface::from_bytes)
    }

    pub fn put_surface(&self, key: &str, v: Arc<SyntheticSurface>) {
        write_through(&self.surfaces, &self.backend, key, v, |s| s.to_bytes());
    }

    // -- counters ----------------------------------------------------------

    /// Counted dataset hits.
    pub fn dataset_hits(&self) -> usize {
        self.datasets.hits()
    }

    /// Corrupt-cache hits across both substrates.
    pub fn corrupt_hits(&self) -> usize {
        self.corrupt.hits() + self.surfaces.hits()
    }

    /// Counted attribution-score hits.
    pub fn scores_hits(&self) -> usize {
        self.scores.hits()
    }

    /// Total counted misses across every store.
    pub fn misses(&self) -> usize {
        self.datasets.misses()
            + self.corrupt.misses()
            + self.scores.misses()
            + self.surfaces.misses()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn keys_are_collision_free_across_inputs() {
        let keys = [
            dataset_key("ioi", 0, 32),
            dataset_key("ioi", 1, 32),
            dataset_key("ioi", 0, 64),
            dataset_key("docstring", 0, 32),
            corrupt_key("gpt2s-sim", "ioi", 0, "fp32"),
            corrupt_key("gpt2s-sim", "ioi", 1, "fp32"),
            corrupt_key("gpt2s-sim", "ioi", 0, "rtn-q-8b"),
            corrupt_key("gpt2s-sim", "docstring", 0, "fp32"),
            corrupt_key("redwood2l-sim", "ioi", 0, "fp32"),
            scores_key("eap", "gpt2s-sim", "ioi", 0, "kl"),
            scores_key("hisp", "gpt2s-sim", "ioi", 0, "kl"),
            scores_key("eap", "gpt2s-sim", "ioi", 0, "task"),
            scores_key("eap", "gpt2s-sim", "ioi", 7, "kl"),
            scores_key("eap", "gpt2s-sim", "docstring", 0, "kl"),
            surface_key("gpt2s-sim", "ioi", 0),
            surface_key("gpt2s-sim", "ioi", 7),
        ];
        let uniq: HashSet<&String> = keys.iter().collect();
        assert_eq!(uniq.len(), keys.len(), "every key distinct");
    }

    #[test]
    fn dataset_seed_separates_tasks_and_bases() {
        assert_ne!(dataset_seed("ioi", 1), dataset_seed("docstring", 1));
        assert_ne!(dataset_seed("ioi", 1), dataset_seed("ioi", 2));
        assert_eq!(dataset_seed("ioi", 3), dataset_seed("ioi", 3));
    }

    #[test]
    fn store_counts_hits_and_misses() {
        let s: Store<usize> = Store::default();
        assert!(s.get("a").is_none());
        assert_eq!((s.hits(), s.misses()), (0, 1));
        s.put("a", Arc::new(7));
        assert_eq!(*s.get("a").unwrap(), 7);
        assert_eq!((s.hits(), s.misses()), (1, 1));
        // peek never counts; first writer wins
        assert_eq!(*s.peek("a").unwrap(), 7);
        s.put("a", Arc::new(9));
        assert_eq!(*s.peek("a").unwrap(), 7);
        assert_eq!((s.hits(), s.misses()), (1, 1));
    }

    #[test]
    fn fnv64_matches_reference_vector() {
        // FNV-1a 64 of the empty string is the offset basis
        assert_eq!(fnv64(""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv64("ioi"), fnv64("docstring"));
    }
}
