//! `pahq matrix` — the work-stealing grid orchestrator.
//!
//! A matrix run executes the full method x policy x task grid as one job
//! queue drained by a pool of cell workers inside one process, instead
//! of one `pahq run` process per cell. Three things make the grid
//! cheaper than its cells run in isolation:
//!
//! 1. **Cross-run reuse** ([`cache`]): a keyed artifact store memoizes
//!    per-(task, seed) evaluation datasets and packed corrupt-activation
//!    caches, and per-(method, task) FP32 attribution score vectors —
//!    the five methods' runs on one task share one corrupt cache, and
//!    EAP / HISP / SP / Edge-Pruning each score once per task and reuse
//!    the vector across precision policies. A seeding phase builds every
//!    shared artifact exactly once; the cell phase then runs all-hit.
//! 2. **Pool sharing**: with a batched sweep schedule, each worker hands
//!    its [`EnginePool`] to the next cell it steals — one [`Handoff`]
//!    value in, one out ([`Session::take_handoff`]) — so consecutive
//!    cells with matching model/task/policy skip rebuilding the engine
//!    replicas.
//! 3. **Resumability**: every cell emits its schema-versioned
//!    [`RunRecord`]; the `matrix.json` manifest records per-cell record
//!    path, status, wall time, and cache hits, and `--resume` skips
//!    cells whose valid record already exists, leaving their files
//!    byte-identical.
//!
//! Cells consume the shared artifacts through a [`Handoff`] staged into
//! the cell's [`crate::discovery::SessionBuilder`], so a matrix cell
//! and a standalone [`crate::api::run`] produce bit-identical kept-edge
//! sets — the contract `tests/matrix.rs` pins at 1 and 4 workers. Grids
//! are launched exclusively through [`crate::api::matrix`] on a
//! validated [`crate::api::MatrixSpec`].
//!
//! When the engine artifacts are absent (CI runs `pahq matrix --quick`
//! with no `make artifacts`), the grid falls back to a deterministic
//! synthetic substrate: per-(task, seed) damage surfaces stand in for
//! corrupt caches and splitmix pseudo-attributions for scoring passes,
//! exercising the same queue, store, manifest, and resume machinery.

pub mod cache;
pub mod queue;
pub mod store;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::acdc::sweep::{self, Candidate, FnScorer, SweepMode, SyntheticSurface};
use crate::baselines::{eap, edge_pruning, hisp, sp};
use crate::discovery::{
    self, CacheStats, DiscoveryConfig, Handoff, RunRecord, Session, Task,
};
use crate::eval;
use crate::gpu_sim::memory::MethodKind;
use crate::gpu_sim::{CostModel, RealArch};
use crate::metrics::Objective;
use crate::model::{Graph, Manifest};
use crate::patching::{PatchMask, PatchedForward, Policy};
use crate::quant::FP8_E4M3;
use crate::report::{mmss, results_dir, Table};
use crate::scheduler::{predict_matrix_wall, predict_run, StreamConfig};
use crate::util::json::{obj, Json};
use crate::util::sync::lock_recover;

use crate::api::StoreSpec;
use cache::ArtifactCache;
use store::DiskStore;

/// Version of the `matrix.json` manifest shape. Mirrored by
/// `docs/matrix.schema.json`; bump both together.
pub const MATRIX_SCHEMA_VERSION: usize = 1;

/// Grid configuration for the grid executor (`run`, launched via
/// [`crate::api::matrix`]).
#[derive(Clone)]
pub struct MatrixConfig {
    pub methods: Vec<String>,
    pub policies: Vec<Policy>,
    pub models: Vec<String>,
    pub tasks: Vec<String>,
    pub tau: f32,
    pub objective: Objective,
    /// per-cell evaluation schedule; batched enables pool sharing
    pub sweep: SweepMode,
    /// concurrent cells drained from the job queue
    pub workers: usize,
    /// dataset seed (0 = the python-exported artifact batch)
    pub seed: u64,
    /// skip cells whose valid record already exists on disk
    pub resume: bool,
    pub quick: bool,
    /// score each circuit against the FP32 ground truth (real substrate)
    pub faithfulness: bool,
    /// where per-cell records land
    pub out_dir: PathBuf,
    /// where the manifest lands (default: `<out_dir>/matrix.json`)
    pub json_path: Option<PathBuf>,
    /// which [`cache::ArtifactStore`] backend the grid's artifact cache
    /// sits on (in-memory, or the durable disk store with optional
    /// startup GC)
    pub store: StoreSpec,
}

impl MatrixConfig {
    /// The acceptance grid: all five methods x {fp32, pahq} on every
    /// task of the smallest model.
    pub fn quick() -> MatrixConfig {
        MatrixConfig {
            methods: discovery::METHOD_NAMES.iter().map(|s| s.to_string()).collect(),
            policies: vec![Policy::fp32(), Policy::pahq(FP8_E4M3)],
            models: vec!["redwood2l-sim".into()],
            tasks: crate::experiments::TASKS.iter().map(|s| s.to_string()).collect(),
            tau: 0.01,
            objective: Objective::Kl,
            sweep: SweepMode::Serial,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            seed: 0,
            resume: false,
            quick: true,
            faithfulness: true,
            out_dir: results_dir().join("matrix"),
            json_path: None,
            store: StoreSpec::Memory,
        }
    }

    fn manifest_path(&self) -> PathBuf {
        self.json_path.clone().unwrap_or_else(|| self.out_dir.join("matrix.json"))
    }
}

/// One grid cell: a (method, policy, model, task) discovery run.
#[derive(Clone)]
pub struct Cell {
    pub method: String,
    pub policy: Policy,
    pub model: String,
    pub task: String,
}

impl Cell {
    pub fn id(&self) -> String {
        format!("{}_{}_{}_{}", self.method, self.policy.name, self.model, self.task)
    }

    pub fn record_name(&self) -> String {
        format!("run_{}.json", self.id())
    }
}

/// The grid in its stable evaluation order: model, task, method, policy.
pub fn grid(cfg: &MatrixConfig) -> Vec<Cell> {
    let mut out = Vec::new();
    for model in &cfg.models {
        for task in &cfg.tasks {
            for method in &cfg.methods {
                for policy in &cfg.policies {
                    out.push(Cell {
                        method: method.clone(),
                        policy: policy.clone(),
                        model: model.clone(),
                        task: task.clone(),
                    });
                }
            }
        }
    }
    out
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellStatus {
    /// ran in this invocation
    Ok,
    /// valid record already on disk (`--resume`), left byte-identical
    Cached,
    Error,
}

impl CellStatus {
    pub fn as_str(self) -> &'static str {
        match self {
            CellStatus::Ok => "ok",
            CellStatus::Cached => "cached",
            CellStatus::Error => "error",
        }
    }

    pub fn parse(s: &str) -> Result<CellStatus> {
        Ok(match s {
            "ok" => CellStatus::Ok,
            "cached" => CellStatus::Cached,
            "error" => CellStatus::Error,
            other => bail!("unknown cell status '{other}'"),
        })
    }
}

/// One manifest row: where a cell's record lives and what it cost.
#[derive(Clone, Debug)]
pub struct CellEntry {
    pub method: String,
    pub policy: String,
    pub model: String,
    pub task: String,
    pub status: CellStatus,
    /// record path relative to the manifest file
    pub record: Option<String>,
    /// wall seconds this invocation spent on the cell (0 when cached)
    pub wall_seconds: f64,
    pub n_evals: Option<usize>,
    pub kept_hash: Option<String>,
    pub cache: Option<CacheStats>,
    pub error: Option<String>,
}

impl CellEntry {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("method", Json::from(self.method.clone())),
            ("policy", Json::from(self.policy.clone())),
            ("model", Json::from(self.model.clone())),
            ("task", Json::from(self.task.clone())),
            ("status", Json::from(self.status.as_str())),
            ("wall_seconds", Json::from(self.wall_seconds)),
        ];
        if let Some(r) = &self.record {
            pairs.push(("record", Json::from(r.clone())));
        }
        if let Some(n) = self.n_evals {
            pairs.push(("n_evals", Json::from(n)));
        }
        if let Some(h) = &self.kept_hash {
            pairs.push(("kept_hash", Json::from(h.clone())));
        }
        if let Some(c) = &self.cache {
            pairs.push(("cache", c.to_json()));
        }
        if let Some(e) = &self.error {
            pairs.push(("error", Json::from(e.clone())));
        }
        obj(pairs)
    }

    fn from_json(j: &Json) -> Result<CellEntry> {
        Ok(CellEntry {
            method: j.get("method")?.as_str()?.to_string(),
            policy: j.get("policy")?.as_str()?.to_string(),
            model: j.get("model")?.as_str()?.to_string(),
            task: j.get("task")?.as_str()?.to_string(),
            status: CellStatus::parse(j.get("status")?.as_str()?)?,
            record: j.opt("record").and_then(|r| r.as_str().ok()).map(str::to_string),
            wall_seconds: j.get("wall_seconds")?.as_f64()?,
            n_evals: match j.opt("n_evals") {
                None => None,
                Some(n) => Some(n.as_usize()?),
            },
            kept_hash: j.opt("kept_hash").and_then(|h| h.as_str().ok()).map(str::to_string),
            cache: match j.opt("cache") {
                None => None,
                Some(c) => Some(CacheStats::from_json(c)?),
            },
            error: j.opt("error").and_then(|e| e.as_str().ok()).map(str::to_string),
        })
    }
}

/// Grid-level rollups: completion, evaluation and wall totals, cache
/// effectiveness, and the memory / faithfulness aggregates.
#[derive(Clone, Debug, Default)]
pub struct Aggregate {
    pub n_cells: usize,
    pub n_ok: usize,
    pub n_cached: usize,
    pub n_error: usize,
    pub n_evals_total: usize,
    pub wall_seconds_total: f64,
    pub dataset_cache_hits: usize,
    pub corrupt_cache_hits: usize,
    pub scores_cache_hits: usize,
    pub cache_misses: usize,
    pub measured_bytes_peak: usize,
    pub faithfulness_accuracy_mean: Option<f64>,
}

impl Aggregate {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("n_cells", Json::from(self.n_cells)),
            ("n_ok", Json::from(self.n_ok)),
            ("n_cached", Json::from(self.n_cached)),
            ("n_error", Json::from(self.n_error)),
            ("n_evals_total", Json::from(self.n_evals_total)),
            ("wall_seconds_total", Json::from(self.wall_seconds_total)),
            ("dataset_cache_hits", Json::from(self.dataset_cache_hits)),
            ("corrupt_cache_hits", Json::from(self.corrupt_cache_hits)),
            ("scores_cache_hits", Json::from(self.scores_cache_hits)),
            ("cache_misses", Json::from(self.cache_misses)),
            ("measured_bytes_peak", Json::from(self.measured_bytes_peak)),
        ];
        if let Some(f) = self.faithfulness_accuracy_mean {
            pairs.push(("faithfulness_accuracy_mean", Json::from(f)));
        }
        obj(pairs)
    }

    fn from_json(j: &Json) -> Result<Aggregate> {
        Ok(Aggregate {
            n_cells: j.get("n_cells")?.as_usize()?,
            n_ok: j.get("n_ok")?.as_usize()?,
            n_cached: j.get("n_cached")?.as_usize()?,
            n_error: j.get("n_error")?.as_usize()?,
            n_evals_total: j.get("n_evals_total")?.as_usize()?,
            wall_seconds_total: j.get("wall_seconds_total")?.as_f64()?,
            dataset_cache_hits: j.get("dataset_cache_hits")?.as_usize()?,
            corrupt_cache_hits: j.get("corrupt_cache_hits")?.as_usize()?,
            scores_cache_hits: j.get("scores_cache_hits")?.as_usize()?,
            cache_misses: j.get("cache_misses")?.as_usize()?,
            measured_bytes_peak: j.get("measured_bytes_peak")?.as_usize()?,
            faithfulness_accuracy_mean: match j.opt("faithfulness_accuracy_mean") {
                None => None,
                Some(f) => Some(f.as_f64()?),
            },
        })
    }
}

/// The `matrix.json` artifact: per-cell record paths, statuses, wall
/// times and cache hits, plus the grid rollups. What `--resume` and the
/// CI matrix gate read, and what tables 2/6/7 re-render from.
#[derive(Clone, Debug)]
pub struct MatrixManifest {
    pub schema_version: usize,
    pub tau: f64,
    pub objective: String,
    pub sweep: String,
    pub workers: usize,
    pub seed: u64,
    pub quick: bool,
    pub synthetic: bool,
    pub cells: Vec<CellEntry>,
    pub aggregate: Aggregate,
}

impl MatrixManifest {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("kind", Json::from("matrix_manifest")),
            ("schema_version", Json::from(self.schema_version)),
            ("tau", Json::from(self.tau)),
            ("objective", Json::from(self.objective.clone())),
            ("sweep", Json::from(self.sweep.clone())),
            ("workers", Json::from(self.workers)),
            ("seed", Json::from(self.seed as usize)),
            ("quick", Json::from(self.quick)),
            ("synthetic", Json::from(self.synthetic)),
            ("cells", Json::Arr(self.cells.iter().map(|c| c.to_json()).collect())),
            ("aggregate", self.aggregate.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<MatrixManifest> {
        if j.get("kind")?.as_str()? != "matrix_manifest" {
            bail!("not a matrix_manifest");
        }
        let version = j.get("schema_version")?.as_usize()?;
        if version != MATRIX_SCHEMA_VERSION {
            bail!("matrix manifest schema v{version}, this build reads v{MATRIX_SCHEMA_VERSION}");
        }
        Ok(MatrixManifest {
            schema_version: version,
            tau: j.get("tau")?.as_f64()?,
            objective: j.get("objective")?.as_str()?.to_string(),
            sweep: j.get("sweep")?.as_str()?.to_string(),
            workers: j.get("workers")?.as_usize()?,
            seed: j.get("seed")?.as_usize()? as u64,
            quick: j.get("quick")?.as_bool()?,
            synthetic: j.get("synthetic")?.as_bool()?,
            cells: j
                .get("cells")?
                .as_arr()?
                .iter()
                .map(CellEntry::from_json)
                .collect::<Result<Vec<_>>>()?,
            aggregate: Aggregate::from_json(j.get("aggregate")?)?,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        std::fs::write(path, self.to_json().dump())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<MatrixManifest> {
        Self::from_json(&Json::parse_file(path)?)
    }

    /// Load every cell's RunRecord (paths are manifest-relative). A
    /// completed cell whose record file is missing or unreadable is an
    /// error — a silently partial grid would read as a complete one.
    pub fn load_cell_records(&self, manifest_path: &Path) -> Result<Vec<(usize, RunRecord)>> {
        let dir = manifest_path.parent().unwrap_or_else(|| Path::new(""));
        let mut out = Vec::new();
        for (i, c) in self.cells.iter().enumerate() {
            if let Some(rel) = &c.record {
                let rec = RunRecord::load(&dir.join(rel)).with_context(|| {
                    format!("cell {}/{}/{}/{}: record {rel}", c.method, c.policy, c.model, c.task)
                })?;
                out.push((i, rec));
            }
        }
        Ok(out)
    }
}

/// What the grid executor hands back through [`crate::api::matrix`]:
/// the manifest plus where it was written.
pub struct MatrixOutcome {
    pub manifest: MatrixManifest,
    pub manifest_path: PathBuf,
}

// ---------------------------------------------------------------------------
// Shared dataset / session resolution (also the `pahq run` / `pahq sweep`
// entry points — satellite: both subcommands route through one derivation)

/// Resolve a task's evaluation batch through the shared (task, seed, n)
/// dataset resolution ([`cache::dataset_for`]). [`crate::api::run`] and
/// every matrix cell route through this, so identical (task, seed, n)
/// inputs are bit-identical across entry points.
pub fn seeded_examples(task: &Task, seed: u64) -> Result<Arc<Vec<crate::model::Example>>> {
    let manifest = Manifest::by_name(&task.model)?;
    Ok(Arc::new(cache::dataset_for(&task.task, seed, manifest.batch)?))
}

/// Build a discovery session on the shared seeded batch.
pub fn seeded_session(task: &Task, seed: u64) -> Result<Session> {
    Session::builder(task).examples(seeded_examples(task, seed)?).build()
}

/// [`seeded_examples`] through an [`ArtifactCache`]: read-through on
/// the shared dataset key, publishing on miss — so a disk-backed
/// `pahq run` resolves the exact batch a grid seeded (and vice versa).
/// Returns the batch plus whether it was a cache hit.
pub(crate) fn seeded_examples_cached(
    store: &ArtifactCache,
    task: &Task,
    seed: u64,
) -> Result<(Arc<Vec<crate::model::Example>>, bool)> {
    let manifest = Manifest::by_name(&task.model)?;
    let dkey = cache::dataset_key(&task.task, seed, manifest.batch);
    match store.dataset(&dkey) {
        Some(e) => Ok((e, true)),
        None => {
            let e = Arc::new(cache::dataset_for(&task.task, seed, manifest.batch)?);
            store.put_dataset(&dkey, e.clone());
            Ok((e, false))
        }
    }
}

/// The store keys one (method, model, task, policy, seed, objective)
/// cell reads and publishes — the same derivation `run_cell_real`
/// uses, exposed so [`crate::api::run`] shares artifacts with grids.
pub(crate) struct StoreKeys {
    pub corrupt: String,
    /// `None` for acdc (it scores nothing up front)
    pub scores: Option<String>,
}

pub(crate) fn store_keys(
    method: &str,
    model: &str,
    task: &str,
    policy: &Policy,
    seed: u64,
    objective_key: &str,
) -> StoreKeys {
    StoreKeys {
        corrupt: cache::corrupt_key(model, task, seed, &cache_tag(policy)),
        scores: (method != "acdc")
            .then(|| cache::scores_key(method, model, task, seed, objective_key)),
    }
}

/// The inbound [`Handoff`] a single run pulls from the store: the
/// cell's corrupt-cache variant plus the method's attribution scores,
/// when present (both counted hits/misses, like a grid cell).
pub(crate) fn store_handoff(store: &ArtifactCache, keys: &StoreKeys) -> Handoff {
    Handoff {
        pool: None,
        corrupt_cache: store.corrupt(&keys.corrupt),
        scores: keys.scores.as_ref().and_then(|k| store.scores(k)),
    }
}

// ---------------------------------------------------------------------------
// Synthetic substrate

/// Fixed grid substrate when engine artifacts are absent (CI): a small
/// attn+mlp graph whose damage comes from a deterministic per-(model,
/// task, seed) synthetic surface — the corrupt-cache analog.
pub fn synthetic_graph() -> Graph {
    Graph { n_layer: 3, n_head: 4, has_mlp: true }
}

/// [`synthetic_surface`] through an [`ArtifactCache`] (read-through,
/// publish on miss) — the synthetic analog of the corrupt cache, so
/// single synthetic runs exercise a disk store too. Returns the
/// surface plus whether it was a cache hit.
pub(crate) fn synthetic_surface_cached(
    store: &ArtifactCache,
    model: &str,
    task: &str,
    seed: u64,
) -> (Arc<SyntheticSurface>, bool) {
    let key = cache::surface_key(model, task, seed);
    match store.surface(&key) {
        Some(s) => (s, true),
        None => {
            let s = Arc::new(synthetic_surface(model, task, seed));
            store.put_surface(&key, s.clone());
            (s, false)
        }
    }
}

/// The per-(model, task, seed) damage surface of the synthetic substrate.
pub fn synthetic_surface(model: &str, task: &str, seed: u64) -> SyntheticSurface {
    let s = cache::fnv64(model)
        ^ cache::fnv64(task).rotate_left(17)
        ^ seed.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    SyntheticSurface::new(s, 0.001)
}

/// Deterministic pseudo-attribution scores (a splitmix64 stream keyed by
/// method/model/task/seed) standing in for a method's FP32 scoring pass
/// on the synthetic substrate.
pub fn synthetic_scores(
    method: &str,
    model: &str,
    task: &str,
    seed: u64,
    n_edges: usize,
) -> Vec<f32> {
    let mut x = cache::fnv64(method)
        ^ cache::fnv64(model).rotate_left(11)
        ^ cache::fnv64(task).rotate_left(29)
        ^ seed;
    (0..n_edges)
        .map(|_| {
            x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^= z >> 31;
            (z >> 40) as f32 / (1u64 << 24) as f32
        })
        .collect()
}

/// One synthetic-substrate cell with explicit inputs — also the
/// substrate [`crate::api::run`] falls back to, so a synthetic matrix
/// cell and a standalone synthetic run are bit-identical by
/// construction.
pub fn synthetic_cell_record(
    cell: &Cell,
    tau: f32,
    sweep_mode: SweepMode,
    seed: u64,
    surface: &SyntheticSurface,
    scores: Option<&[f32]>,
) -> Result<RunRecord> {
    let t0 = Instant::now();
    let g = synthetic_graph();
    let channels = g.channels();
    // pahq-lint: allow(panic-unwrap): cells only name channels drawn from this graph
    let chan_of = |ch: &crate::model::Channel| channels.iter().position(|c| c == ch).unwrap();
    let plan: Vec<Vec<Candidate>> = if cell.method == "acdc" {
        // reverse-topological channel groups, mirroring acdc::sweep_plan
        let mut order = channels.clone();
        order.reverse();
        order
            .iter()
            .map(|ch| {
                let ci = chan_of(ch);
                let mut srcs = g.sources(*ch);
                srcs.reverse();
                srcs.into_iter()
                    .map(|src| Candidate {
                        chan: ci,
                        src,
                        hi: crate::acdc::hi_node_for(&cell.policy, src),
                    })
                    .collect()
            })
            .collect()
    } else {
        // ascending-score single group, mirroring discovery::ordered_plan
        let own;
        let s: &[f32] = match scores {
            Some(s) => s,
            None => {
                own = synthetic_scores(&cell.method, &cell.model, &cell.task, seed, g.n_edges());
                own.as_slice()
            }
        };
        let edges = g.edges();
        let mut order: Vec<usize> = (0..edges.len()).collect();
        order.sort_by(|&a, &b| s[a].total_cmp(&s[b]).then(a.cmp(&b)));
        vec![order
            .into_iter()
            .map(|i| Candidate {
                chan: chan_of(&edges[i].dst),
                src: edges[i].src,
                hi: crate::acdc::hi_node_for(&cell.policy, edges[i].src),
            })
            .collect()]
    };
    let score = |m: &PatchMask, c: Option<&Candidate>| surface.damage(m, c);
    let mut scorer = FnScorer { score, workers: sweep_mode.workers() };
    let out = sweep::sweep(&mut scorer, channels.len(), &plan, tau, false, sweep_mode)?;
    let kept: Vec<bool> =
        g.edges().iter().map(|e| !out.removed.get(chan_of(&e.dst), e.src)).collect();
    Ok(RunRecord {
        schema_version: discovery::SCHEMA_VERSION,
        method: cell.method.clone(),
        policy: cell.policy.name.clone(),
        model: cell.model.clone(),
        task: cell.task.clone(),
        objective: "synthetic".into(),
        tau: tau as f64,
        sweep: sweep_mode.label(),
        workers: sweep_mode.workers(),
        n_edges: kept.len(),
        n_kept: kept.iter().filter(|&&k| k).count(),
        kept_hash: discovery::kept_hash(&kept),
        n_evals: out.n_evals,
        final_metric: out.final_metric as f64,
        wall_seconds: t0.elapsed().as_secs_f64(),
        pjrt_seconds: 0.0,
        sim_bytes: None,
        measured_weight_bytes: 0,
        measured_cache_bytes: 0,
        faithfulness: None,
        cache: None,
        trace: Vec::new(),
    })
}

/// Open the [`ArtifactCache`] a [`StoreSpec`] describes: the
/// in-process memory backend, or the durable [`DiskStore`] — with the
/// opt-in generation GC sweep when a horizon is configured. Shared by
/// the grid executor and [`crate::api::run`], so `--store disk` means
/// the same artifacts everywhere.
pub(crate) fn open_cache(spec: &StoreSpec, verbose: bool) -> Result<ArtifactCache> {
    match spec {
        StoreSpec::Memory => Ok(ArtifactCache::in_memory()),
        StoreSpec::Disk { root, gc_horizon } => {
            let disk = Arc::new(DiskStore::open(root)?);
            if verbose {
                println!(
                    "store: durable artifacts at {} (generation {})",
                    root.display(),
                    disk.generation()
                );
            }
            if let Some(h) = gc_horizon {
                let r = disk.gc(*h)?;
                if verbose {
                    println!(
                        "store: gc horizon {h} — {} live, {} collected ({} B freed), \
                         {} missing row(s) dropped",
                        r.live, r.collected, r.bytes_freed, r.missing
                    );
                }
            }
            Ok(ArtifactCache::with_backend(disk))
        }
    }
}

/// Run one cell standalone — fresh session, no cross-run cache — the
/// reference the matrix's bit-identity contract is tested against.
/// Routes through the public [`crate::api::run`] entry point with the
/// same substrate-resolution rules the grid executor uses, so the
/// comparison is
/// literally "grid cell vs the public API" — apples-to-apples even with
/// partially exported artifacts.
pub fn standalone_cell(cell: &Cell, cfg: &MatrixConfig) -> Result<RunRecord> {
    let spec = crate::api::RunSpec::builder(&cell.model, &cell.task)
        .method(cell.method.parse()?)
        .policy(cell.policy.clone())
        .tau(cfg.tau)
        .objective(cfg.objective)
        .sweep(cfg.sweep)
        .seed(cfg.seed)
        .build()?;
    crate::api::run(&spec)
}

// ---------------------------------------------------------------------------
// The orchestrator

fn base_config(cfg: &MatrixConfig, policy: &Policy) -> DiscoveryConfig {
    DiscoveryConfig::new(cfg.tau, cfg.objective, policy.clone()).with_sweep(cfg.sweep)
}

/// Which corrupt cache a policy reads: hi-fidelity policies share one
/// FP32 cache; low-fidelity ones (RTN-Q) pack on their own lattice.
fn cache_tag(policy: &Policy) -> String {
    if policy.hi_fidelity_refs {
        "fp32".to_string()
    } else {
        policy.name.clone()
    }
}

/// Compute a method's FP32 attribution scores on an engine whose session
/// is already FP32 — exactly the pass `discovery::scored_at_fp32` runs,
/// so the seeded vector is bit-identical to what the cell would compute.
fn attribution_scores(
    engine: &mut PatchedForward,
    method: &str,
    cfg: &MatrixConfig,
) -> Result<Vec<f32>> {
    let dcfg = base_config(cfg, &Policy::fp32());
    match method {
        "eap" => eap::scores(engine, cfg.objective),
        "hisp" => hisp::scores(engine, cfg.objective),
        "sp" => sp::scores(engine, &sp::SpConfig { steps: dcfg.sp_steps, ..Default::default() }),
        "edge-pruning" | "ep" => {
            let ep_cfg = edge_pruning::EpConfig { steps: dcfg.ep_steps, ..Default::default() };
            Ok(edge_pruning::train(engine, &ep_cfg)?.edge_scores)
        }
        other => bail!("method '{other}' has no attribution scorer"),
    }
}

/// Seed every shared artifact of one (model, task) combo exactly once:
/// the dataset, each required corrupt-cache variant, the FP32 ground
/// truth (when faithfulness is on), and every attribution method's
/// score vector — one engine, one pass over the artifact classes.
fn seed_combo_real(
    cfg: &MatrixConfig,
    store: &ArtifactCache,
    model: &str,
    task: &str,
) -> Result<()> {
    let manifest = Manifest::by_name(model)?;
    let n = manifest.batch;
    let dkey = cache::dataset_key(task, cfg.seed, n);
    let examples = match store.peek_dataset(&dkey) {
        Some(e) => e,
        None => {
            let e = Arc::new(cache::dataset_for(task, cfg.seed, n)?);
            store.put_dataset(&dkey, e.clone());
            e
        }
    };
    let mut engine = PatchedForward::with_examples(manifest, examples.as_ref().clone())?;
    // low-fidelity caches first (each lives on its own lattice)...
    for policy in &cfg.policies {
        if policy.hi_fidelity_refs {
            continue;
        }
        let ckey = cache::corrupt_key(model, task, cfg.seed, &cache_tag(policy));
        if store.peek_corrupt(&ckey).is_none() {
            engine.set_session(policy.clone())?;
            store.put_corrupt(&ckey, Arc::new(engine.corrupt_cache.clone()));
        }
    }
    // ...then the FP32 session: the shared hi-fidelity cache, the ground
    // truth (exhaustive FP32 reference sweep, disk-cached per model/task/
    // objective — computed here once so concurrent cells only read), and
    // every attribution method's FP32 scoring pass
    engine.set_session(Policy::fp32())?;
    if cfg.policies.iter().any(|p| p.hi_fidelity_refs) {
        let ckey = cache::corrupt_key(model, task, cfg.seed, "fp32");
        if store.peek_corrupt(&ckey).is_none() {
            store.put_corrupt(&ckey, Arc::new(engine.corrupt_cache.clone()));
        }
    }
    if cfg.faithfulness {
        eval::ground_truth(&mut engine, model, task, cfg.objective)?;
    }
    for method in &cfg.methods {
        if method == "acdc" {
            continue;
        }
        let skey = cache::scores_key(method, model, task, cfg.seed, cfg.objective.key());
        if store.peek_scores(&skey).is_some() {
            continue;
        }
        match attribution_scores(&mut engine, method, cfg) {
            Ok(s) => store.put_scores(&skey, Arc::new(s)),
            // best-effort: the cell recomputes (and publishes) on miss
            Err(e) => eprintln!("matrix: {model}/{task}/{method} score seeding failed: {e}"),
        }
    }
    Ok(())
}

fn seed_combo_synthetic(cfg: &MatrixConfig, store: &ArtifactCache, model: &str, task: &str) {
    let skey = cache::surface_key(model, task, cfg.seed);
    if store.peek_surface(&skey).is_none() {
        store.put_surface(&skey, Arc::new(synthetic_surface(model, task, cfg.seed)));
    }
    let n_edges = synthetic_graph().n_edges();
    for method in &cfg.methods {
        if method == "acdc" {
            continue;
        }
        let key = cache::scores_key(method, model, task, cfg.seed, "synthetic");
        if store.peek_scores(&key).is_none() {
            let s = synthetic_scores(method, model, task, cfg.seed, n_edges);
            store.put_scores(&key, Arc::new(s));
        }
    }
}

fn run_cell_real(
    cfg: &MatrixConfig,
    store: &ArtifactCache,
    cell: &Cell,
    slot: &mut Handoff,
) -> Result<(RunRecord, CacheStats)> {
    let task = Task::new(&cell.model, &cell.task);
    let manifest = Manifest::by_name(&cell.model)?;
    let dkey = cache::dataset_key(&cell.task, cfg.seed, manifest.batch);
    let (examples, dataset_hit) = match store.dataset(&dkey) {
        Some(e) => (e, true),
        // every cell resolves its batch through the shared derivation,
        // cached or not — a seeding failure never silently changes data
        None => (Arc::new(cache::dataset_for(&cell.task, cfg.seed, manifest.batch)?), false),
    };
    let ckey = cache::corrupt_key(&cell.model, &cell.task, cfg.seed, &cache_tag(&cell.policy));
    let skey = (cell.method != "acdc").then(|| {
        cache::scores_key(&cell.method, &cell.model, &cell.task, cfg.seed, cfg.objective.key())
    });
    // ONE value in: the previous cell's pool plus this cell's store
    // artifacts (pool sharing: configure keeps the pool on a full
    // match, else rebuilds its replicas)
    let inbound = Handoff {
        pool: slot.pool.take(),
        corrupt_cache: store.corrupt(&ckey),
        scores: skey.as_ref().and_then(|k| store.scores(k)),
    };
    let dcfg = base_config(cfg, &cell.policy);
    let mut session =
        Session::builder(&task).examples(examples).handoff(inbound).config(&dcfg).build()?;
    session.cache_stats.dataset_hit = dataset_hit;
    let method = discovery::by_name(&cell.method)?;
    let mut rec = method.discover(&mut session, &task, &dcfg)?;
    if cfg.faithfulness {
        if let Err(e) = session.evaluate_faithfulness(&dcfg, &mut rec, true) {
            eprintln!("matrix: {} faithfulness skipped: {e}", cell.id());
        }
    }
    let stats = session.cache_stats.clone();
    // ONE value out: the pool travels to the next cell on this worker,
    // self-computed scores publish into the store
    let outbound = session.take_handoff();
    if let (Some(k), Some(s)) = (&skey, &outbound.scores) {
        store.put_scores(k, s.clone());
    }
    *slot = outbound;
    Ok((rec, stats))
}

fn run_cell_synthetic(
    cfg: &MatrixConfig,
    store: &ArtifactCache,
    cell: &Cell,
) -> Result<(RunRecord, CacheStats)> {
    let mut stats = CacheStats::default();
    let skey = cache::surface_key(&cell.model, &cell.task, cfg.seed);
    let surface = match store.surface(&skey) {
        Some(s) => {
            stats.corrupt_hit = true;
            s
        }
        None => Arc::new(synthetic_surface(&cell.model, &cell.task, cfg.seed)),
    };
    let scores = if cell.method == "acdc" {
        None
    } else {
        let key = cache::scores_key(&cell.method, &cell.model, &cell.task, cfg.seed, "synthetic");
        match store.scores(&key) {
            Some(s) => {
                stats.scores_hit = true;
                Some(s)
            }
            None => None,
        }
    };
    let mut rec = synthetic_cell_record(
        cell,
        cfg.tau,
        cfg.sweep,
        cfg.seed,
        &surface,
        scores.as_ref().map(|s| s.as_slice()),
    )?;
    rec.cache = stats.any().then(|| stats.clone());
    Ok((rec, stats))
}

struct CellOutcome {
    status: CellStatus,
    rec: Option<RunRecord>,
    stats: CacheStats,
    wall: f64,
    error: Option<String>,
}

/// Does an on-disk record belong to this cell under this config?
/// `RunRecord` carries no seed field, so seed compatibility is
/// established once per resume by [`resume_context_matches`] against
/// the previous manifest (which does record the seed).
fn record_matches(rec: &RunRecord, cell: &Cell, cfg: &MatrixConfig, expected_obj: &str) -> bool {
    rec.method == cell.method
        && rec.policy == cell.policy.name
        && rec.model == cell.model
        && rec.task == cell.task
        && rec.objective == expected_obj
        && (rec.tau - cfg.tau as f64).abs() < 1e-12
        // the kept set is schedule-invariant but n_evals is not
        // (speculation overhead), so a record from a different sweep
        // schedule would corrupt the manifest's eval trajectory
        && rec.sweep == cfg.sweep.label()
}

/// `--resume` trusts on-disk records only when the previous manifest
/// ran the same seed / tau / objective / substrate — the identity a
/// bare record cannot carry. No readable manifest means no resume
/// (records alone could alias a different seed's grid).
fn resume_context_matches(manifest_path: &Path, cfg: &MatrixConfig, synthetic: bool) -> bool {
    match MatrixManifest::load(manifest_path) {
        Ok(m) => {
            m.seed == cfg.seed
                && m.synthetic == synthetic
                && m.objective == cfg.objective.key()
                && (m.tau - cfg.tau as f64).abs() < 1e-12
        }
        Err(_) => false,
    }
}

/// `path` relative to `dir`, with `..` segments when `path` is not
/// under `dir` — the manifest's record-path contract holds wherever
/// `--out` and `--json` point.
fn rel_to(dir: &Path, path: &Path) -> String {
    fn absolute(p: &Path) -> PathBuf {
        if p.is_absolute() {
            p.to_path_buf()
        } else {
            std::env::current_dir().unwrap_or_default().join(p)
        }
    }
    let (dir, path) = (absolute(dir), absolute(path));
    let d: Vec<_> = dir.components().collect();
    let p: Vec<_> = path.components().collect();
    let common = d.iter().zip(&p).take_while(|(a, b)| a == b).count();
    let mut out = PathBuf::new();
    for _ in common..d.len() {
        out.push("..");
    }
    for c in &p[common..] {
        out.push(c);
    }
    out.to_string_lossy().into_owned()
}

/// Substrate decision shared by the grid executor (`run`) and every
/// single-run entry point ([`crate::api::run`] under
/// [`crate::api::Substrate::Auto`]), so a cell and its standalone
/// comparator always agree:
///
/// - no model manifest and no task dataset resolves → synthetic (the
///   artifact-less environment the fallback exists for, e.g. CI);
/// - *some* resolve and some don't → error — partial availability is a
///   typo'd `--models`/`--tasks` or a half-built artifact tree, and
///   silently pseudo-scoring it into a green grid would be worse;
/// - everything resolves → real, unless the engine itself cannot build
///   (the vendored PJRT stub), which degrades to synthetic with notice.
pub fn substrate_probe(models: &[String], tasks: &[String], verbose: bool) -> Result<bool> {
    if !artifacts_available(models, tasks)? {
        if verbose {
            println!("matrix: no model/task artifacts found; running the synthetic grid");
        }
        return Ok(true);
    }
    let (Some(model0), Some(task0)) = (models.first(), tasks.first()) else {
        return Ok(true);
    };
    match PatchedForward::new(model0, task0) {
        Ok(_) => Ok(false),
        Err(e) => {
            if verbose {
                println!("matrix: engine unavailable ({e}); running the synthetic grid");
            }
            Ok(true)
        }
    }
}

/// The cheap half of the substrate decision — no engine construction:
/// `Ok(true)` when every named model manifest and task dataset
/// resolves, `Ok(false)` when *none* do (the synthetic fallback's
/// environment), and the partial-availability error otherwise.
/// [`crate::api::run`] uses this so a single run probes without
/// building a throwaway engine; whether the engine itself comes up is
/// then decided by actually constructing the session.
pub fn artifacts_available(models: &[String], tasks: &[String]) -> Result<bool> {
    let mut available = 0usize;
    let mut failures: Vec<String> = Vec::new();
    for model in models {
        match Manifest::by_name(model) {
            Ok(_) => available += 1,
            Err(e) => failures.push(format!("model {model}: {e}")),
        }
    }
    for task in tasks {
        match crate::model::Dataset::by_task(task) {
            Ok(_) => available += 1,
            Err(e) => failures.push(format!("task {task}: {e}")),
        }
    }
    if available == 0 {
        return Ok(false);
    }
    if !failures.is_empty() {
        bail!(
            "substrate: partial artifact availability — refusing to silently fall back \
             to the synthetic surface:\n  {}",
            failures.join("\n  ")
        );
    }
    Ok(true)
}

/// Execute the grid: seed the shared artifact store (phase A, one job
/// per (model, task) combo), then drain the cell queue with
/// work-stealing workers (phase B), then assemble, save, and print the
/// manifest. Deterministic at any worker count: only wall times vary.
///
/// Crate-private on purpose: grids are launched through
/// [`crate::api::matrix`] on a validated [`crate::api::MatrixSpec`],
/// which has already checked the axes up front.
pub(crate) fn run(cfg: &MatrixConfig) -> Result<MatrixOutcome> {
    if cfg.methods.is_empty() || cfg.policies.is_empty() || cfg.models.is_empty()
        || cfg.tasks.is_empty()
    {
        bail!("matrix grid is empty (methods/policies/models/tasks all required)");
    }
    // validate method names up front: the synthetic substrate would
    // otherwise happily pseudo-score a typo'd method into a green cell
    for method in &cfg.methods {
        discovery::by_name(method)?;
    }
    // the manifest stores the seed through an f64 JSON number; beyond
    // 2^53 it would round and silently disable --resume
    if cfg.seed > (1u64 << 53) {
        bail!("--seed must fit in 53 bits (manifest round-trip), got {}", cfg.seed);
    }
    std::fs::create_dir_all(&cfg.out_dir)
        .with_context(|| format!("creating {}", cfg.out_dir.display()))?;
    let t_run = Instant::now();
    let cells = grid(cfg);
    println!(
        "matrix: {} cells ({} methods x {} policies x {} models x {} tasks), {} workers",
        cells.len(),
        cfg.methods.len(),
        cfg.policies.len(),
        cfg.models.len(),
        cfg.tasks.len(),
        cfg.workers
    );

    // substrate probe: partial artifact availability errors out loudly
    let synthetic = substrate_probe(&cfg.models, &cfg.tasks, true)?;
    let expected_obj = if synthetic { "synthetic" } else { cfg.objective.key() };

    // resume: the previous manifest must match this config's identity
    // (seed/tau/objective/substrate), then a valid on-disk record with
    // matching cell identity keeps its cell
    let manifest_path = cfg.manifest_path();
    let resume = cfg.resume && resume_context_matches(&manifest_path, cfg, synthetic);
    if cfg.resume && !resume {
        println!(
            "matrix: --resume ignored ({} missing or from a different config)",
            manifest_path.display()
        );
    }
    let mut outcomes: Vec<Option<CellOutcome>> = Vec::with_capacity(cells.len());
    for cell in &cells {
        let cached = if resume {
            RunRecord::load(&cfg.out_dir.join(cell.record_name()))
                .ok()
                .filter(|r| record_matches(r, cell, cfg, expected_obj))
        } else {
            None
        };
        outcomes.push(cached.map(|rec| CellOutcome {
            status: CellStatus::Cached,
            stats: rec.cache.clone().unwrap_or_default(),
            rec: Some(rec),
            wall: 0.0,
            error: None,
        }));
    }
    let pending: Vec<usize> = (0..cells.len()).filter(|&i| outcomes[i].is_none()).collect();

    // paper-scale ETA for the real substrate (greedy-makespan bound of
    // the work-stealing queue)
    if !synthetic && !pending.is_empty() {
        let cost = CostModel::default();
        let minutes: Vec<f64> = pending
            .iter()
            .filter_map(|&i| {
                let cell = &cells[i];
                RealArch::by_name(&cell.model).map(|arch| {
                    let kind = MethodKind::of_policy(&cell.policy);
                    let streams =
                        if cell.policy.is_pahq() { StreamConfig::FULL } else { StreamConfig::NONE };
                    predict_run(&arch, &cost, kind, streams).total_minutes
                })
            })
            .collect();
        if minutes.len() == pending.len() {
            println!(
                "matrix: predicted paper-scale grid wall on {} workers: {} (m:s)",
                cfg.workers,
                mmss(predict_matrix_wall(&minutes, cfg.workers))
            );
        }
    }

    let store = open_cache(&cfg.store, true)?;
    if !pending.is_empty() {
        // phase A: seed every shared artifact exactly once per combo
        let combos: BTreeSet<(String, String)> = pending
            .iter()
            .map(|&i| (cells[i].model.clone(), cells[i].task.clone()))
            .collect();
        let seed_queue = queue::WorkQueue::new();
        combos.into_iter().for_each(|c| {
            seed_queue.push(c);
        });
        std::thread::scope(|s| {
            for _ in 0..cfg.workers.max(1) {
                s.spawn(|| loop {
                    let Some((model, task)) = seed_queue.try_pop() else { break };
                    if synthetic {
                        seed_combo_synthetic(cfg, &store, &model, &task);
                    } else if let Err(e) = seed_combo_real(cfg, &store, &model, &task) {
                        eprintln!("matrix: seeding {model}/{task} failed: {e}");
                    }
                });
            }
        });

        // phase B: work-stealing cell drain; each worker hands its engine
        // pool to the next cell it steals
        let cell_queue = queue::WorkQueue::new();
        pending.iter().for_each(|&i| {
            cell_queue.push(i);
        });
        let results: Mutex<Vec<Option<CellOutcome>>> =
            Mutex::new((0..cells.len()).map(|_| None).collect());
        std::thread::scope(|s| {
            for _ in 0..cfg.workers.max(1).min(pending.len()) {
                s.spawn(|| {
                    // the ONE value consecutive cells on this worker pass
                    // between each other (pool + publishable artifacts)
                    let mut slot = Handoff::default();
                    loop {
                        let Some(i) = cell_queue.try_pop() else { break };
                        let cell = &cells[i];
                        let t0 = Instant::now();
                        let out = if synthetic {
                            run_cell_synthetic(cfg, &store, cell)
                        } else {
                            run_cell_real(cfg, &store, cell, &mut slot)
                        };
                        let wall = t0.elapsed().as_secs_f64();
                        let outcome = match out.and_then(|(rec, stats)| {
                            rec.save(&cfg.out_dir.join(cell.record_name()))?;
                            Ok((rec, stats))
                        }) {
                            Ok((rec, stats)) => CellOutcome {
                                status: CellStatus::Ok,
                                rec: Some(rec),
                                stats,
                                wall,
                                error: None,
                            },
                            Err(e) => CellOutcome {
                                status: CellStatus::Error,
                                rec: None,
                                stats: CacheStats::default(),
                                wall,
                                error: Some(e.to_string()),
                            },
                        };
                        lock_recover(&results)[i] = Some(outcome);
                    }
                });
            }
        });
        let merged = results.into_inner().unwrap_or_else(|e| e.into_inner());
        for (i, slot) in merged.into_iter().enumerate() {
            if let Some(o) = slot {
                outcomes[i] = Some(o);
            }
        }
    }

    // manifest assembly + rollups
    let manifest_dir = manifest_path.parent().map(Path::to_path_buf).unwrap_or_default();
    let mut entries = Vec::with_capacity(cells.len());
    let (mut n_ok, mut n_cached, mut n_error) = (0usize, 0usize, 0usize);
    let (mut evals_total, mut wall_total) = (0usize, 0.0f64);
    let (mut d_hits, mut c_hits, mut s_hits) = (0usize, 0usize, 0usize);
    let mut bytes_peak = 0usize;
    let (mut faith_sum, mut faith_n) = (0.0f64, 0usize);
    let mut summary = Table::new(
        "matrix grid",
        &["cell", "status", "kept", "evals", "wall (s)", "cache d/c/s"],
    );
    for (cell, outcome) in cells.iter().zip(&outcomes) {
        // pahq-lint: allow(panic-expect): the scope above joined every worker, all slots filled
        let o = outcome.as_ref().expect("every cell has an outcome");
        match o.status {
            CellStatus::Ok => n_ok += 1,
            CellStatus::Cached => n_cached += 1,
            CellStatus::Error => n_error += 1,
        }
        wall_total += o.wall;
        d_hits += o.stats.dataset_hit as usize;
        c_hits += o.stats.corrupt_hit as usize;
        s_hits += o.stats.scores_hit as usize;
        let (mut kept, mut evals) = ("-".to_string(), "-".to_string());
        if let Some(rec) = &o.rec {
            evals_total += rec.n_evals;
            bytes_peak = bytes_peak.max(rec.measured_total_bytes());
            if let Some(f) = &rec.faithfulness {
                faith_sum += f.accuracy;
                faith_n += 1;
            }
            kept = format!("{}/{}", rec.n_kept, rec.n_edges);
            evals = rec.n_evals.to_string();
        }
        summary.row(vec![
            cell.id(),
            o.status.as_str().to_string(),
            kept,
            evals,
            format!("{:.2}", o.wall),
            format!(
                "{}/{}/{}",
                o.stats.dataset_hit as u8, o.stats.corrupt_hit as u8, o.stats.scores_hit as u8
            ),
        ]);
        entries.push(CellEntry {
            method: cell.method.clone(),
            policy: cell.policy.name.clone(),
            model: cell.model.clone(),
            task: cell.task.clone(),
            status: o.status,
            record: o
                .rec
                .is_some()
                .then(|| rel_to(&manifest_dir, &cfg.out_dir.join(cell.record_name()))),
            wall_seconds: o.wall,
            n_evals: o.rec.as_ref().map(|r| r.n_evals),
            kept_hash: o.rec.as_ref().map(|r| r.kept_hash.clone()),
            cache: o.stats.any().then(|| o.stats.clone()),
            error: o.error.clone(),
        });
    }
    let aggregate = Aggregate {
        n_cells: cells.len(),
        n_ok,
        n_cached,
        n_error,
        n_evals_total: evals_total,
        wall_seconds_total: wall_total,
        dataset_cache_hits: d_hits,
        corrupt_cache_hits: c_hits,
        scores_cache_hits: s_hits,
        cache_misses: store.misses(),
        measured_bytes_peak: bytes_peak,
        faithfulness_accuracy_mean: match faith_n {
            0 => None,
            n => Some(faith_sum / n as f64),
        },
    };
    let manifest = MatrixManifest {
        schema_version: MATRIX_SCHEMA_VERSION,
        tau: cfg.tau as f64,
        objective: cfg.objective.key().to_string(),
        sweep: cfg.sweep.label(),
        workers: cfg.workers,
        seed: cfg.seed,
        quick: cfg.quick,
        synthetic,
        cells: entries,
        aggregate,
    };
    manifest.save(&manifest_path)?;
    summary.print();
    let a = &manifest.aggregate;
    println!(
        "matrix: {} ok / {} cached / {} error, {} evals, cache hits d/c/s {}/{}/{} \
         ({} misses), {:.1}s total",
        a.n_ok,
        a.n_cached,
        a.n_error,
        a.n_evals_total,
        a.dataset_cache_hits,
        a.corrupt_cache_hits,
        a.scores_cache_hits,
        a.cache_misses,
        t_run.elapsed().as_secs_f64()
    );
    println!("matrix manifest: {}", manifest_path.display());
    Ok(MatrixOutcome { manifest, manifest_path })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_stable_and_complete() {
        let mut cfg = MatrixConfig::quick();
        cfg.models = vec!["m".into()];
        cfg.tasks = vec!["a".into(), "b".into()];
        let cells = grid(&cfg);
        assert_eq!(cells.len(), 5 * 2 * 2);
        // stable order: model, task, method, policy
        assert_eq!(cells[0].task, "a");
        assert_eq!(cells[0].method, "acdc");
        assert_eq!(cells[0].policy.name, "acdc-fp32");
        assert_eq!(cells[1].policy.name, "pahq-8b");
        // ids are unique (record filenames collide otherwise)
        let ids: std::collections::HashSet<String> = cells.iter().map(|c| c.id()).collect();
        assert_eq!(ids.len(), cells.len());
    }

    #[test]
    fn cell_status_roundtrip() {
        for s in [CellStatus::Ok, CellStatus::Cached, CellStatus::Error] {
            assert_eq!(CellStatus::parse(s.as_str()).unwrap(), s);
        }
        assert!(CellStatus::parse("running").is_err());
    }

    #[test]
    fn synthetic_substrate_is_deterministic_and_method_sensitive() {
        let s1 = synthetic_scores("eap", "m", "t", 0, 32);
        assert_eq!(s1, synthetic_scores("eap", "m", "t", 0, 32));
        assert_ne!(s1, synthetic_scores("hisp", "m", "t", 0, 32));
        assert_ne!(s1, synthetic_scores("eap", "m", "t", 1, 32));
        let cell = Cell {
            method: "eap".into(),
            policy: Policy::pahq(FP8_E4M3),
            model: "m".into(),
            task: "t".into(),
        };
        let surface = synthetic_surface("m", "t", 0);
        let a = synthetic_cell_record(&cell, 0.01, SweepMode::Serial, 0, &surface, None).unwrap();
        let b =
            synthetic_cell_record(&cell, 0.01, SweepMode::Serial, 0, &surface, Some(&s1)).unwrap();
        assert_eq!(a.kept_hash, b.kept_hash, "explicit scores equal derived scores");
        assert!(a.n_evals > 0);
        assert_eq!(a.n_edges, synthetic_graph().n_edges());
    }

    #[test]
    fn cache_tag_splits_fidelity_classes() {
        assert_eq!(cache_tag(&Policy::fp32()), "fp32");
        assert_eq!(cache_tag(&Policy::pahq(FP8_E4M3)), "fp32");
        assert_eq!(cache_tag(&Policy::rtn(FP8_E4M3)), "rtn-q-8b");
    }
}
