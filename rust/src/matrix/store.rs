//! Durable content-addressed artifact storage behind [`ArtifactStore`].
//!
//! The grid's reusable artifacts (datasets, corrupt-activation caches,
//! attribution score vectors, synthetic surfaces — see
//! [`super::cache`]) are deterministic functions of their logical cache
//! key, so a byte store addressed by a hash of that key is safe to
//! share across processes: two writers racing on one address write the
//! same bytes. Two backends implement the trait:
//!
//! - [`MemoryStore`] — the in-process map the matrix always had; dies
//!   with the process.
//! - [`DiskStore`] — one file per artifact under a sharded
//!   `store/ab/cdef…` layout with atomic tmp-file+rename writes, a
//!   schema'd `store-manifest.json` carrying per-entry
//!   generation/last-used stamps, and checksum verification that
//!   *quarantines* corrupt entries (moves them aside and reports a
//!   miss) instead of panicking.
//!
//! ## Addressing
//!
//! `address(key)` folds the store schema version and the value-codec
//! version into the hash, so a codec change maps every artifact to a
//! fresh address instead of mis-decoding stale bytes.
//!
//! ## Generation-based, coordination-free GC
//!
//! Every process that opens a [`DiskStore`] bumps the manifest's
//! generation counter and stamps the entries it touches with its own
//! generation. [`DiskStore::gc`] collects only entries whose
//! `last_used` is more than `horizon` generations behind the current
//! one, and re-reads + merges the on-disk manifest (max-stamp wins)
//! right before collecting — so two concurrent grids on one store
//! never block on each other and never collect each other's live
//! artifacts as long as the horizon covers the concurrent-open window
//! (any `horizon >= 1` does for two processes). There are no lock
//! files and no daemons: a missed merge can only *delay* a collection,
//! never lose live data, because a live entry's re-`put` recreates it.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::json::{obj, Json};
use crate::util::sync::lock_recover;

/// Version of the on-disk layout (file header + manifest shape).
pub const STORE_SCHEMA_VERSION: usize = 1;
/// Version of the typed value codecs ([`super::cache`] encode/decode).
pub const CODEC_VERSION: usize = 1;

/// Artifact-file magic; the trailing byte is the schema version.
const MAGIC: &[u8; 8] = b"PAHQART1";
const MANIFEST_NAME: &str = "store-manifest.json";

/// FNV-1a-64 over raw bytes (the string variant lives in
/// [`super::cache::fnv64`]; checksums here run over encoded payloads).
pub fn fnv64_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Content address of a logical cache key: 32 hex chars from two salted
/// FNV-1a-64 passes, with the store schema and codec versions folded in
/// so incompatible layouts never alias.
pub fn address(key: &str) -> String {
    let salted = format!("pahq-store/s{STORE_SCHEMA_VERSION}/c{CODEC_VERSION}/{key}");
    let lo = super::cache::fnv64(&salted);
    let hi = super::cache::fnv64(&format!("{salted}#hi"));
    format!("{hi:016x}{lo:016x}")
}

/// Byte-level keyed storage over content-addressed artifacts. Values
/// are deterministic per key (see module docs), so `put` is
/// first-writer-wins and concurrent duplicate writes are benign.
pub trait ArtifactStore: Send + Sync {
    /// Fetch the bytes under `key`; `Ok(None)` on a miss (including a
    /// quarantined corrupt entry).
    fn get(&self, key: &str) -> Result<Option<Vec<u8>>>;
    /// Durably store `bytes` under `key`.
    fn put(&self, key: &str, bytes: &[u8]) -> Result<()>;
    /// Does `key` currently resolve (without touching its GC stamp)?
    fn contains(&self, key: &str) -> Result<bool>;
    /// Logical keys of every live entry.
    fn list(&self) -> Result<Vec<String>>;
    /// Drop `key`; `Ok(true)` when an entry existed.
    fn remove(&self, key: &str) -> Result<bool>;
}

/// The in-process backend: a plain keyed byte map.
#[derive(Default)]
pub struct MemoryStore {
    map: Mutex<HashMap<String, Vec<u8>>>,
}

impl ArtifactStore for MemoryStore {
    fn get(&self, key: &str) -> Result<Option<Vec<u8>>> {
        Ok(lock_recover(&self.map).get(key).cloned())
    }

    fn put(&self, key: &str, bytes: &[u8]) -> Result<()> {
        lock_recover(&self.map).entry(key.to_string()).or_insert_with(|| bytes.to_vec());
        Ok(())
    }

    fn contains(&self, key: &str) -> Result<bool> {
        Ok(lock_recover(&self.map).contains_key(key))
    }

    fn list(&self) -> Result<Vec<String>> {
        let mut keys: Vec<String> = lock_recover(&self.map).keys().cloned().collect();
        keys.sort();
        Ok(keys)
    }

    fn remove(&self, key: &str) -> Result<bool> {
        Ok(lock_recover(&self.map).remove(key).is_some())
    }
}

/// One manifest row: where an artifact came from and when it was last
/// touched, in store generations.
#[derive(Clone, Debug)]
pub struct StoreEntry {
    /// Logical cache key (`dataset/…`, `corrupt/…`, …).
    pub key: String,
    /// Generation that first wrote the entry.
    pub created: u64,
    /// Generation that last read or wrote it — the GC stamp.
    pub last_used: u64,
    /// Encoded payload size.
    pub bytes: usize,
}

/// What one [`DiskStore::gc`] sweep did.
#[derive(Clone, Copy, Debug, Default)]
pub struct GcReport {
    /// Entries kept (stamped within the horizon).
    pub live: usize,
    /// Entries collected (file + manifest row removed).
    pub collected: usize,
    /// Manifest rows dropped because their file had vanished.
    pub missing: usize,
    /// Payload bytes freed by the collected entries.
    pub bytes_freed: usize,
}

/// The durable backend. See the module docs for layout and GC model.
pub struct DiskStore {
    root: PathBuf,
    /// This process's generation (manifest counter + 1 at open).
    generation: u64,
    state: Mutex<StoreState>,
}

#[derive(Default)]
struct StoreState {
    entries: BTreeMap<String, StoreEntry>,
    /// Addresses this handle removed/collected/quarantined — the
    /// merge-on-write persist must not resurrect their manifest rows
    /// from a stale on-disk copy.
    dead: std::collections::HashSet<String>,
}

/// Parse `store-manifest.json`, strictly on identity fields.
fn parse_manifest(path: &Path) -> Result<(u64, BTreeMap<String, StoreEntry>)> {
    let j = Json::parse_file(path)?;
    let schema = j.get("schema_version")?.as_usize()?;
    if schema != STORE_SCHEMA_VERSION {
        bail!(
            "store: manifest {} has schema v{schema}, this build reads v{STORE_SCHEMA_VERSION} \
             — point --store at a fresh directory or delete the stale store",
            path.display()
        );
    }
    let generation = j.get("generation")?.as_usize()? as u64;
    let mut entries = BTreeMap::new();
    for e in j.get("entries")?.as_arr()? {
        entries.insert(
            e.get("address")?.as_str()?.to_string(),
            StoreEntry {
                key: e.get("key")?.as_str()?.to_string(),
                created: e.get("created")?.as_usize()? as u64,
                last_used: e.get("last_used")?.as_usize()? as u64,
                bytes: e.get("bytes")?.as_usize()?,
            },
        );
    }
    Ok((generation, entries))
}

/// The store-manifest schema version at `root`, if a manifest exists.
/// The spec builders use this to fail `--resume` against an
/// incompatible store *by field name* instead of silently recomputing.
pub fn manifest_schema_at(root: &Path) -> Result<Option<usize>> {
    let path = root.join(MANIFEST_NAME);
    if !path.exists() {
        return Ok(None);
    }
    Ok(Some(Json::parse_file(&path)?.get("schema_version")?.as_usize()?))
}

impl DiskStore {
    /// Open (creating if needed) the store at `root` and bump the
    /// generation counter — this process's uses stamp entries with the
    /// new generation.
    pub fn open(root: &Path) -> Result<DiskStore> {
        std::fs::create_dir_all(root.join("tmp"))
            .with_context(|| format!("store: creating {}", root.display()))?;
        let manifest = root.join(MANIFEST_NAME);
        let (disk_gen, entries) = if manifest.exists() {
            parse_manifest(&manifest)?
        } else {
            (0, BTreeMap::new())
        };
        let store = DiskStore {
            root: root.to_path_buf(),
            generation: disk_gen + 1,
            state: Mutex::new(StoreState { entries, dead: Default::default() }),
        };
        store.persist()?;
        Ok(store)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The generation this handle stamps entries with.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    fn shard_path(&self, address: &str) -> PathBuf {
        self.root.join(&address[..2]).join(&address[2..])
    }

    /// Merge-on-write manifest persistence: re-read the on-disk
    /// manifest, merge stamps (max wins), write tmp + rename. Keeps
    /// concurrent handles from erasing each other's GC stamps.
    fn persist(&self) -> Result<()> {
        let mut state = lock_recover(&self.state);
        let manifest = self.root.join(MANIFEST_NAME);
        let mut generation = self.generation;
        if let Ok((disk_gen, disk_entries)) = parse_manifest(&manifest) {
            generation = generation.max(disk_gen);
            for (addr, theirs) in disk_entries {
                if state.dead.contains(&addr) {
                    continue;
                }
                state
                    .entries
                    .entry(addr)
                    .and_modify(|ours| {
                        ours.last_used = ours.last_used.max(theirs.last_used);
                        ours.created = ours.created.min(theirs.created);
                    })
                    .or_insert(theirs);
            }
        }
        let rows: Vec<Json> = state
            .entries
            .iter()
            .map(|(addr, e)| {
                obj(vec![
                    ("address", Json::from(addr.clone())),
                    ("key", Json::from(e.key.clone())),
                    ("created", Json::from(e.created as usize)),
                    ("last_used", Json::from(e.last_used as usize)),
                    ("bytes", Json::from(e.bytes)),
                ])
            })
            .collect();
        let doc = obj(vec![
            ("kind", Json::from("store_manifest")),
            ("schema_version", Json::from(STORE_SCHEMA_VERSION)),
            ("codec_version", Json::from(CODEC_VERSION)),
            ("generation", Json::from(generation as usize)),
            ("entries", Json::Arr(rows)),
        ]);
        self.write_atomic(&manifest, doc.dump().as_bytes())
    }

    /// tmp-file + rename; the only way bytes land under `root`.
    fn write_atomic(&self, dest: &Path, bytes: &[u8]) -> Result<()> {
        let tmp = self.root.join("tmp").join(format!(
            "{}.{}",
            dest.file_name().unwrap_or_default().to_string_lossy(),
            std::process::id()
        ));
        std::fs::write(&tmp, bytes).with_context(|| format!("store: writing {}", tmp.display()))?;
        if let Some(dir) = dest.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::rename(&tmp, dest)
            .with_context(|| format!("store: publishing {}", dest.display()))
    }

    /// Move a failed-verification file aside (never panic, never
    /// delete evidence) and drop its manifest row.
    fn quarantine(&self, address: &str, why: &str) {
        let from = self.shard_path(address);
        let qdir = self.root.join("quarantine");
        let moved = std::fs::create_dir_all(&qdir)
            .and_then(|_| std::fs::rename(&from, qdir.join(address)));
        eprintln!(
            "store: quarantined corrupt entry {address} ({why}){}",
            if moved.is_err() { " — move failed, treating as miss" } else { "" }
        );
        let mut state = lock_recover(&self.state);
        state.entries.remove(address);
        state.dead.insert(address.to_string());
        drop(state);
        self.persist().ok();
    }

    /// Stamp an entry as used at this handle's generation.
    fn touch(&self, address: &str, key: &str, bytes: usize) -> Result<()> {
        {
            let mut state = lock_recover(&self.state);
            state.dead.remove(address);
            let gen = self.generation;
            let e = state.entries.entry(address.to_string()).or_insert(StoreEntry {
                key: key.to_string(),
                created: gen,
                last_used: gen,
                bytes,
            });
            e.last_used = e.last_used.max(gen);
            e.bytes = bytes;
        }
        self.persist()
    }

    /// Every manifest entry (merged view), keyed by address.
    pub fn entries(&self) -> BTreeMap<String, StoreEntry> {
        self.persist().ok();
        lock_recover(&self.state).entries.clone()
    }

    /// Collect entries whose `last_used` stamp is more than `horizon`
    /// generations behind this handle's generation. Quarantined files
    /// live outside the shard tree and are never touched.
    pub fn gc(&self, horizon: u64) -> Result<GcReport> {
        // merge the freshest stamps from disk before deciding anything
        self.persist()?;
        let mut report = GcReport::default();
        let mut state = lock_recover(&self.state);
        let mut doomed: Vec<String> = Vec::new();
        for (addr, e) in state.entries.iter() {
            if !self.shard_path(addr).exists() {
                report.missing += 1;
                doomed.push(addr.clone());
            } else if e.last_used.saturating_add(horizon) < self.generation {
                report.collected += 1;
                report.bytes_freed += e.bytes;
                doomed.push(addr.clone());
            } else {
                report.live += 1;
            }
        }
        for addr in &doomed {
            std::fs::remove_file(self.shard_path(addr)).ok();
            state.entries.remove(addr);
            state.dead.insert(addr.clone());
        }
        drop(state);
        self.persist()?;
        Ok(report)
    }
}

/// Artifact file wire form: magic, schema/codec (u32 LE each), logical
/// key (u32 length + utf8), payload (u64 length + bytes), then an
/// FNV-1a-64 checksum of the payload. Verification failures quarantine.
fn encode_file(key: &str, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + 4 + 4 + 4 + key.len() + 8 + payload.len() + 8);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(STORE_SCHEMA_VERSION as u32).to_le_bytes());
    out.extend_from_slice(&(CODEC_VERSION as u32).to_le_bytes());
    out.extend_from_slice(&(key.len() as u32).to_le_bytes());
    out.extend_from_slice(key.as_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&fnv64_bytes(payload).to_le_bytes());
    out
}

/// Inverse of [`encode_file`]; any structural or checksum mismatch is
/// an error (the caller quarantines).
fn decode_file(key: &str, b: &[u8]) -> Result<Vec<u8>> {
    if b.len() < 8 + 4 + 4 + 4 || &b[..8] != MAGIC {
        bail!("bad magic");
    }
    // pahq-lint: allow(panic-unwrap): 4-byte subslice, length checked above
    let schema = u32::from_le_bytes(b[8..12].try_into().unwrap()) as usize;
    // pahq-lint: allow(panic-unwrap): 4-byte subslice, length checked above
    let codec = u32::from_le_bytes(b[12..16].try_into().unwrap()) as usize;
    if schema != STORE_SCHEMA_VERSION || codec != CODEC_VERSION {
        bail!("schema/codec v{schema}/v{codec}, expected v{STORE_SCHEMA_VERSION}/v{CODEC_VERSION}");
    }
    // pahq-lint: allow(panic-unwrap): 4-byte subslice, length checked above
    let klen = u32::from_le_bytes(b[16..20].try_into().unwrap()) as usize;
    if b.len() < 20 + klen + 8 + 8 {
        bail!("truncated header");
    }
    let stored_key = std::str::from_utf8(&b[20..20 + klen]).map_err(|_| {
        anyhow::anyhow!("non-utf8 key")
    })?;
    if stored_key != key {
        bail!("address collision: file holds key '{stored_key}'");
    }
    let at = 20 + klen;
    // pahq-lint: allow(panic-unwrap): 8-byte subslice, length checked above
    let plen = u64::from_le_bytes(b[at..at + 8].try_into().unwrap()) as usize;
    if b.len() != at + 8 + plen + 8 {
        bail!("payload length mismatch");
    }
    let payload = &b[at + 8..at + 8 + plen];
    // pahq-lint: allow(panic-unwrap): trailing 8-byte checksum, length checked above
    let sum = u64::from_le_bytes(b[at + 8 + plen..].try_into().unwrap());
    if sum != fnv64_bytes(payload) {
        bail!("checksum mismatch");
    }
    Ok(payload.to_vec())
}

impl ArtifactStore for DiskStore {
    fn get(&self, key: &str) -> Result<Option<Vec<u8>>> {
        let addr = address(key);
        let path = self.shard_path(&addr);
        let raw = match std::fs::read(&path) {
            Ok(raw) => raw,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).with_context(|| format!("store: reading {}", path.display())),
        };
        match decode_file(key, &raw) {
            Ok(payload) => {
                self.touch(&addr, key, payload.len())?;
                Ok(Some(payload))
            }
            Err(why) => {
                self.quarantine(&addr, &why.to_string());
                Ok(None)
            }
        }
    }

    fn put(&self, key: &str, bytes: &[u8]) -> Result<()> {
        let addr = address(key);
        let path = self.shard_path(&addr);
        if !path.exists() {
            self.write_atomic(&path, &encode_file(key, bytes))?;
        }
        self.touch(&addr, key, bytes.len())
    }

    fn contains(&self, key: &str) -> Result<bool> {
        Ok(self.shard_path(&address(key)).exists())
    }

    fn list(&self) -> Result<Vec<String>> {
        self.persist()?;
        Ok(lock_recover(&self.state).entries.values().map(|e| e.key.clone()).collect())
    }

    fn remove(&self, key: &str) -> Result<bool> {
        let addr = address(key);
        let existed = std::fs::remove_file(self.shard_path(&addr)).is_ok();
        let mut state = lock_recover(&self.state);
        let had_entry = state.entries.remove(&addr).is_some();
        state.dead.insert(addr);
        drop(state);
        self.persist()?;
        Ok(existed || had_entry)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_root(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("pahq_store_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    #[test]
    fn addresses_are_versioned_and_sharded() {
        let a = address("dataset/ioi/0/32");
        assert_eq!(a.len(), 32);
        assert!(a.bytes().all(|b| b.is_ascii_hexdigit()));
        assert_ne!(a, address("dataset/ioi/1/32"));
    }

    #[test]
    fn memory_store_round_trips_the_trait() {
        let s = MemoryStore::default();
        assert!(s.get("k").unwrap().is_none());
        s.put("k", b"abc").unwrap();
        assert_eq!(s.get("k").unwrap().unwrap(), b"abc");
        assert!(s.contains("k").unwrap());
        assert_eq!(s.list().unwrap(), vec!["k".to_string()]);
        // first writer wins (deterministic values per key)
        s.put("k", b"zzz").unwrap();
        assert_eq!(s.get("k").unwrap().unwrap(), b"abc");
        assert!(s.remove("k").unwrap());
        assert!(!s.remove("k").unwrap());
    }

    #[test]
    fn disk_store_round_trips_and_survives_reopen() {
        let root = tmp_root("roundtrip");
        let s = DiskStore::open(&root).unwrap();
        s.put("scores/eap/m/t/0/kl", b"\x01\x02\x03").unwrap();
        assert_eq!(s.get("scores/eap/m/t/0/kl").unwrap().unwrap(), b"\x01\x02\x03");
        drop(s);
        let s2 = DiskStore::open(&root).unwrap();
        assert_eq!(s2.generation(), 2, "each open bumps the generation");
        assert_eq!(s2.get("scores/eap/m/t/0/kl").unwrap().unwrap(), b"\x01\x02\x03");
        assert_eq!(s2.list().unwrap(), vec!["scores/eap/m/t/0/kl".to_string()]);
        assert!(s2.remove("scores/eap/m/t/0/kl").unwrap());
        assert!(s2.get("scores/eap/m/t/0/kl").unwrap().is_none());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn file_codec_rejects_tampering() {
        let enc = encode_file("k", b"payload");
        assert_eq!(decode_file("k", &enc).unwrap(), b"payload");
        let mut bad = enc.clone();
        let n = bad.len();
        bad[n - 9] ^= 0x40; // flip a payload bit
        assert!(decode_file("k", &bad).is_err());
        assert!(decode_file("other", &enc).is_err(), "key mismatch detected");
        assert!(decode_file("k", &enc[..10]).is_err(), "truncation detected");
    }
}
