//! PJRT runtime: load the AOT HLO-text artifacts, compile them once on the
//! CPU PJRT client, and execute them from the L3 hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: interchange is HLO **text**
//! (xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id protos; the text
//! parser reassigns ids). All artifacts are lowered with
//! `return_tuple=True`, so every execution returns one tuple literal that
//! we decompose.
//!
//! The [`Engine`] owns one compiled executable per artifact (compiled
//! lazily, cached) — one attention executable serves all layers of a model
//! because weights are runtime inputs and shapes are layer-invariant.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::tensor::Tensor;

/// A compiled artifact plus its execution statistics.
struct CachedExe {
    exe: xla::PjRtLoadedExecutable,
    calls: u64,
    total: Duration,
}

/// Cumulative per-artifact execution statistics (perf accounting).
#[derive(Clone, Debug, Default)]
pub struct ExeStats {
    pub calls: u64,
    pub total: Duration,
}

/// The L3-side PJRT runtime: one CPU client plus a lazily-compiled,
/// per-artifact executable cache with cumulative timing.
pub struct Engine {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, CachedExe>,
}

impl Engine {
    pub fn new() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client, cache: HashMap::new() })
    }

    /// Compile (or fetch from cache) the executable for an HLO-text file.
    fn executable(&mut self, path: &Path) -> Result<&mut CachedExe> {
        if !self.cache.contains_key(path) {
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
            eprintln!(
                "[runtime] compiled {} in {:.2}s",
                path.file_name().unwrap_or_default().to_string_lossy(),
                t0.elapsed().as_secs_f64()
            );
            self.cache.insert(
                path.to_path_buf(),
                CachedExe { exe, calls: 0, total: Duration::ZERO },
            );
        }
        Ok(self.cache.get_mut(path).unwrap())
    }

    /// Pre-compile an artifact (so first-call latency doesn't pollute
    /// timing runs).
    pub fn warm(&mut self, path: &Path) -> Result<()> {
        self.executable(path).map(|_| ())
    }

    /// Execute an artifact on flat-f32 inputs, returning the decomposed
    /// output tuple as [`Tensor`]s.
    pub fn run(&mut self, path: &Path, inputs: &[Input]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|i| i.to_literal())
            .collect::<Result<_>>()?;
        let cached = self.executable(path)?;
        let t0 = Instant::now();
        let result = cached
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {}: {e:?}", path.display()))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        cached.calls += 1;
        cached.total += t0.elapsed();
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("decomposing tuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit
                    .array_shape()
                    .map_err(|e| anyhow!("output shape: {e:?}"))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("output data: {e:?}"))?;
                Tensor::from_vec(&dims, data)
            })
            .collect()
    }

    /// Per-artifact timing, keyed by file name.
    pub fn stats(&self) -> HashMap<String, ExeStats> {
        self.cache
            .iter()
            .map(|(p, c)| {
                (
                    p.file_name().unwrap_or_default().to_string_lossy().into_owned(),
                    ExeStats { calls: c.calls, total: c.total },
                )
            })
            .collect()
    }

    /// Total wall-clock spent inside PJRT execution (all artifacts).
    pub fn total_exec_time(&self) -> Duration {
        self.cache.values().map(|c| c.total).sum()
    }

    pub fn reset_stats(&mut self) {
        for c in self.cache.values_mut() {
            c.calls = 0;
            c.total = Duration::ZERO;
        }
    }
}

/// A borrowed flat-f32 input with a shape: avoids cloning the big
/// activation buffers on every call.
pub struct Input<'a> {
    pub shape: &'a [usize],
    pub data: &'a [f32],
}

impl<'a> Input<'a> {
    pub fn new(shape: &'a [usize], data: &'a [f32]) -> Input<'a> {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Input { shape, data }
    }

    pub fn from_tensor(t: &'a Tensor) -> Input<'a> {
        Input { shape: &t.shape, data: &t.data }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(self.data);
        if self.shape.len() == 1 {
            return Ok(lit);
        }
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        lit.reshape(&dims).map_err(|e| anyhow!("reshape to {:?}: {e:?}", self.shape))
    }
}

/// Owned variant for small constructed inputs (qp rows, scalars).
pub struct OwnedInput {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl OwnedInput {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> OwnedInput {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        OwnedInput { shape, data }
    }

    pub fn scalar(v: f32) -> OwnedInput {
        OwnedInput { shape: vec![], data: vec![v] }
    }

    pub fn as_input(&self) -> Input<'_> {
        Input { shape: &self.shape, data: &self.data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end PJRT check against a tiny artifact: the embed HLO of any
    /// built model computes onehot @ wte + wpe, which we verify in Rust.
    #[test]
    fn embed_artifact_matches_manual() {
        let Ok(m) = crate::model::Manifest::by_name("redwood2l-sim") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let ws = crate::model::WeightStore::load(&m).unwrap();
        let mut eng = Engine::new().unwrap();
        let (b, s, v, d) = (m.batch, m.seq_len, m.vocab, m.d_model);

        // batch of token 3 at every position except position 1 -> token 5
        let mut onehot = vec![0.0f32; b * s * v];
        for bi in 0..b {
            for si in 0..s {
                let tok = if si == 1 { 5 } else { 3 };
                onehot[(bi * s + si) * v + tok] = 1.0;
            }
        }
        let wte = ws.master_param("wte").unwrap();
        let wpe = ws.master_param("wpe").unwrap();
        let outs = eng
            .run(
                &m.hlo_path("embed.hlo.txt"),
                &[
                    Input::new(&[b, s, v], &onehot),
                    Input::new(&[v, d], wte),
                    Input::new(&[s, d], wpe),
                ],
            )
            .unwrap();
        assert_eq!(outs.len(), 1);
        let out = &outs[0];
        assert_eq!(out.shape, vec![b, s, d]);
        for bi in 0..b {
            for si in 0..s {
                let tok = if si == 1 { 5 } else { 3 };
                for di in 0..d {
                    let want = wte[tok * d + di] + wpe[si * d + di];
                    let got = out.data[(bi * s + si) * d + di];
                    assert!((want - got).abs() < 1e-6, "b{bi} s{si} d{di}");
                }
            }
        }
        // stats recorded
        let stats = eng.stats();
        assert_eq!(stats["embed.hlo.txt"].calls, 1);
    }
}
