//! The computational graph that circuit discovery operates on.
//!
//! Nodes (shared ordering with python's `model.node_index` and the AOT
//! gradient artifacts):
//!   0                      embed
//!   1 + l*H + h            attention head h of layer l
//!   1 + L*H + l            MLP of layer l (models with MLPs)
//!
//! Channels are the *inputs* edges point into: each head has Q/K/V
//! channels, each MLP one, plus the final residual read by the unembed.
//! An edge (src node -> dst channel) exists iff src's output is causally
//! upstream of the channel's assembly point.

use anyhow::Result;

use super::config::Manifest;

pub type NodeId = usize;

/// A destination input-channel of the graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Channel {
    /// (layer, head, 0=Q 1=K 2=V)
    Head { layer: usize, head: usize, comp: u8 },
    Mlp { layer: usize },
    Final,
}

impl Channel {
    pub fn layer(&self) -> usize {
        match self {
            Channel::Head { layer, .. } | Channel::Mlp { layer } => *layer,
            Channel::Final => usize::MAX, // after every layer
        }
    }

    pub fn label(&self) -> String {
        match self {
            Channel::Head { layer, head, comp } => {
                format!("a{layer}.h{head}.{}", ["q", "k", "v"][*comp as usize])
            }
            Channel::Mlp { layer } => format!("m{layer}"),
            Channel::Final => "final".to_string(),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Edge {
    pub src: NodeId,
    pub dst: Channel,
}

impl Edge {
    pub fn label(&self, g: &Graph) -> String {
        format!("{} -> {}", g.node_label(self.src), self.dst.label())
    }
}

#[derive(Clone, Debug)]
pub struct Graph {
    pub n_layer: usize,
    pub n_head: usize,
    pub has_mlp: bool,
}

impl Graph {
    pub fn from_manifest(m: &Manifest) -> Graph {
        Graph { n_layer: m.n_layer, n_head: m.n_head, has_mlp: m.has_mlp() }
    }

    pub const EMBED: NodeId = 0;

    pub fn n_nodes(&self) -> usize {
        1 + self.n_layer * self.n_head + if self.has_mlp { self.n_layer } else { 0 }
    }

    pub fn head_node(&self, layer: usize, head: usize) -> NodeId {
        1 + layer * self.n_head + head
    }

    pub fn mlp_node(&self, layer: usize) -> NodeId {
        debug_assert!(self.has_mlp);
        1 + self.n_layer * self.n_head + layer
    }

    /// Inverse of the node numbering.
    pub fn node_kind(&self, id: NodeId) -> NodeKind {
        if id == 0 {
            NodeKind::Embed
        } else if id < 1 + self.n_layer * self.n_head {
            let r = id - 1;
            NodeKind::Head { layer: r / self.n_head, head: r % self.n_head }
        } else {
            NodeKind::Mlp { layer: id - 1 - self.n_layer * self.n_head }
        }
    }

    pub fn node_label(&self, id: NodeId) -> String {
        match self.node_kind(id) {
            NodeKind::Embed => "embed".to_string(),
            NodeKind::Head { layer, head } => format!("a{layer}.h{head}"),
            NodeKind::Mlp { layer } => format!("m{layer}"),
        }
    }

    /// Source nodes causally upstream of a channel, in node-id order.
    /// Heads read the stream *before* their layer; the MLP of layer l reads
    /// it after layer l's heads; Final reads everything.
    pub fn sources(&self, ch: Channel) -> Vec<NodeId> {
        let mut out = vec![Self::EMBED];
        let (head_layers, mlp_layers) = match ch {
            Channel::Head { layer, .. } => (layer, layer),
            Channel::Mlp { layer } => (layer + 1, layer),
            Channel::Final => (self.n_layer, self.n_layer),
        };
        for l in 0..head_layers {
            for h in 0..self.n_head {
                out.push(self.head_node(l, h));
            }
        }
        if self.has_mlp {
            for l in 0..mlp_layers {
                out.push(self.mlp_node(l));
            }
        }
        out.sort_unstable();
        out
    }

    /// Every destination channel, in evaluation order (reverse-topological
    /// over layers is what ACDC sweeps; we expose forward order and let
    /// the sweep reverse it).
    pub fn channels(&self) -> Vec<Channel> {
        let mut out = Vec::new();
        for layer in 0..self.n_layer {
            for head in 0..self.n_head {
                for comp in 0..3u8 {
                    out.push(Channel::Head { layer, head, comp });
                }
            }
            if self.has_mlp {
                out.push(Channel::Mlp { layer });
            }
        }
        out.push(Channel::Final);
        out
    }

    /// The full edge set.
    pub fn edges(&self) -> Vec<Edge> {
        let mut out = Vec::new();
        for ch in self.channels() {
            for src in self.sources(ch) {
                out.push(Edge { src, dst: ch });
            }
        }
        out
    }

    pub fn n_edges(&self) -> usize {
        self.edges().len()
    }

    /// Validate a (src, channel) pair is a real edge.
    pub fn is_edge(&self, e: &Edge) -> bool {
        self.sources(e.dst).contains(&e.src)
    }

    /// Parse an edge label of the form "a0.h1 -> a2.h3.q" (inverse of
    /// [`Edge::label`]) — used by the CLI.
    pub fn parse_edge(&self, s: &str) -> Result<Edge> {
        let (src_s, dst_s) = s
            .split_once("->")
            .ok_or_else(|| anyhow::anyhow!("edge must look like 'src -> dst'"))?;
        let src = self.parse_node(src_s.trim())?;
        let dst = self.parse_channel(dst_s.trim())?;
        let e = Edge { src, dst };
        if !self.is_edge(&e) {
            anyhow::bail!("'{s}' is not a causally-valid edge");
        }
        Ok(e)
    }

    fn parse_node(&self, s: &str) -> Result<NodeId> {
        if s == "embed" {
            return Ok(Self::EMBED);
        }
        if let Some(rest) = s.strip_prefix('m') {
            return Ok(self.mlp_node(rest.parse()?));
        }
        let (l, h) = s
            .strip_prefix('a')
            .and_then(|r| r.split_once(".h"))
            .ok_or_else(|| anyhow::anyhow!("bad node '{s}'"))?;
        Ok(self.head_node(l.parse()?, h.parse()?))
    }

    fn parse_channel(&self, s: &str) -> Result<Channel> {
        if s == "final" {
            return Ok(Channel::Final);
        }
        if let Some(rest) = s.strip_prefix('m') {
            return Ok(Channel::Mlp { layer: rest.parse()? });
        }
        let parts: Vec<&str> = s.split('.').collect();
        if parts.len() == 3 {
            let layer = parts[0].strip_prefix('a').unwrap_or("").parse()?;
            let head = parts[1].strip_prefix('h').unwrap_or("").parse()?;
            let comp = match parts[2] {
                "q" => 0u8,
                "k" => 1,
                "v" => 2,
                _ => anyhow::bail!("bad component '{}'", parts[2]),
            };
            return Ok(Channel::Head { layer, head, comp });
        }
        anyhow::bail!("bad channel '{s}'")
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NodeKind {
    Embed,
    Head { layer: usize, head: usize },
    Mlp { layer: usize },
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g_mlp() -> Graph {
        Graph { n_layer: 4, n_head: 8, has_mlp: true }
    }

    fn g_ao() -> Graph {
        Graph { n_layer: 2, n_head: 4, has_mlp: false }
    }

    #[test]
    fn node_numbering_roundtrips() {
        let g = g_mlp();
        assert_eq!(g.n_nodes(), 1 + 32 + 4);
        for id in 0..g.n_nodes() {
            let k = g.node_kind(id);
            let back = match k {
                NodeKind::Embed => 0,
                NodeKind::Head { layer, head } => g.head_node(layer, head),
                NodeKind::Mlp { layer } => g.mlp_node(layer),
            };
            assert_eq!(back, id);
        }
    }

    #[test]
    fn sources_respect_causality() {
        let g = g_mlp();
        // layer-0 head channels see only embed
        assert_eq!(g.sources(Channel::Head { layer: 0, head: 3, comp: 0 }), vec![0]);
        // layer-0 MLP sees embed + layer-0 heads
        let s = g.sources(Channel::Mlp { layer: 0 });
        assert_eq!(s.len(), 1 + 8);
        assert!(s.contains(&g.head_node(0, 7)));
        assert!(!s.contains(&g.mlp_node(0)), "no self-loop");
        // layer-1 heads see embed + layer-0 heads + layer-0 mlp
        let s = g.sources(Channel::Head { layer: 1, head: 0, comp: 2 });
        assert_eq!(s.len(), 1 + 8 + 1);
        assert!(s.contains(&g.mlp_node(0)));
        // final sees everything
        assert_eq!(g.sources(Channel::Final).len(), g.n_nodes());
    }

    #[test]
    fn edge_count_formula() {
        // gpt2s-sim-shaped: per layer-l head channel: (1 + 9l) sources x 24
        // channels; mlp_l: 1 + 8(l+1) + l; final: n_nodes.
        let g = g_mlp();
        let mut want = 0;
        for l in 0..4 {
            want += 24 * (1 + 9 * l);
            want += 1 + 8 * (l + 1) + l;
        }
        want += g.n_nodes();
        assert_eq!(g.n_edges(), want);
        let ao = g_ao();
        // attn-only: per layer-l channel: (1 + 4l) x 12; final 1 + 8
        assert_eq!(ao.n_edges(), 12 * 1 + 12 * 5 + 9);
    }

    #[test]
    fn edges_are_unique_and_valid() {
        let g = g_ao();
        let mut edges = g.edges();
        let n = edges.len();
        edges.sort();
        edges.dedup();
        assert_eq!(edges.len(), n);
        for e in &edges {
            assert!(g.is_edge(e));
        }
    }

    #[test]
    fn label_parse_roundtrip() {
        let g = g_mlp();
        for e in g.edges().iter().step_by(37) {
            let s = e.label(&g);
            let back = g.parse_edge(&s).unwrap();
            assert_eq!(&back, e, "{s}");
        }
        assert!(g.parse_edge("a3.h0 -> a0.h0.q").is_err(), "anti-causal");
        assert!(g.parse_edge("garbage").is_err());
    }
}
