//! Evaluation datasets: the seeded clean/corrupt pairs exported by
//! `aot.py` (`artifacts/datasets/<task>.json`), plus conversion into the
//! dense batched buffers the AOT executables take as inputs.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct Example {
    pub clean: Vec<usize>,
    pub corrupt: Vec<usize>,
    pub pos: usize,
    /// sparse answer distribution (token, weight), weights sum to 1
    pub ans: Vec<(usize, f32)>,
    pub dis: Vec<(usize, f32)>,
    pub label: usize,
}

#[derive(Clone, Debug)]
pub struct Dataset {
    pub task: String,
    pub seq_len: usize,
    pub examples: Vec<Example>,
}

impl Dataset {
    pub fn load(path: &Path) -> Result<Dataset> {
        let j = Json::parse_file(path)?;
        let seq_len = j.get("seq_len")?.as_usize()?;
        let examples = j
            .get("examples")?
            .as_arr()?
            .iter()
            .map(|e| parse_example(e, seq_len))
            .collect::<Result<Vec<_>>>()?;
        Ok(Dataset {
            task: j.get("task")?.as_str()?.to_string(),
            seq_len,
            examples,
        })
    }

    pub fn by_task(task: &str) -> Result<Dataset> {
        let path = crate::artifacts_root().join("datasets").join(format!("{task}.json"));
        Self::load(&path).with_context(|| format!("loading dataset '{task}'"))
    }

    /// First `n` examples as a fixed evaluation batch.
    pub fn batch(&self, n: usize) -> Result<&[Example]> {
        if self.examples.len() < n {
            bail!("dataset has {} examples, need {n}", self.examples.len());
        }
        Ok(&self.examples[..n])
    }

    /// Dense one-hot token batch [B, S, V] (flat).
    pub fn onehot(examples: &[Example], corrupt: bool, vocab: usize) -> Vec<f32> {
        let s = examples[0].clean.len();
        let mut out = vec![0.0; examples.len() * s * vocab];
        for (b, ex) in examples.iter().enumerate() {
            let toks = if corrupt { &ex.corrupt } else { &ex.clean };
            for (i, &t) in toks.iter().enumerate() {
                out[(b * s + i) * vocab + t] = 1.0;
            }
        }
        out
    }

    /// Dense position one-hots [B, S].
    pub fn pos_onehot(examples: &[Example], seq_len: usize) -> Vec<f32> {
        let mut out = vec![0.0; examples.len() * seq_len];
        for (b, ex) in examples.iter().enumerate() {
            out[b * seq_len + ex.pos] = 1.0;
        }
        out
    }

    /// Dense answer/distractor distributions [B, V].
    pub fn dist(examples: &[Example], vocab: usize, distractor: bool) -> Vec<f32> {
        let mut out = vec![0.0; examples.len() * vocab];
        for (b, ex) in examples.iter().enumerate() {
            let d = if distractor { &ex.dis } else { &ex.ans };
            for &(t, w) in d {
                out[b * vocab + t] = w;
            }
        }
        out
    }
}

fn parse_example(e: &Json, seq_len: usize) -> Result<Example> {
    let dist = |key: &str| -> Result<Vec<(usize, f32)>> {
        e.get(key)?
            .as_arr()?
            .iter()
            .map(|p| {
                let pair = p.as_arr()?;
                Ok((pair[0].as_usize()?, pair[1].as_f64()? as f32))
            })
            .collect()
    };
    let ex = Example {
        clean: e.get("clean")?.usize_vec()?,
        corrupt: e.get("corrupt")?.usize_vec()?,
        pos: e.get("pos")?.as_usize()?,
        ans: dist("ans")?,
        dis: dist("dis")?,
        label: e.get("label")?.as_usize()?,
    };
    if ex.clean.len() != seq_len || ex.corrupt.len() != seq_len {
        bail!("example length != seq_len");
    }
    if ex.pos >= seq_len {
        bail!("answer position out of range");
    }
    Ok(ex)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_all_tasks() {
        for task in ["ioi", "greater_than", "docstring"] {
            let Ok(d) = Dataset::by_task(task) else {
                eprintln!("skipping: artifacts not built");
                return;
            };
            assert!(d.examples.len() >= 64, "{task}");
            for ex in &d.examples {
                assert_eq!(ex.clean.len(), d.seq_len);
                let ws: f32 = ex.ans.iter().map(|&(_, w)| w).sum();
                assert!((ws - 1.0).abs() < 1e-5);
                let diff = ex
                    .clean
                    .iter()
                    .zip(&ex.corrupt)
                    .filter(|(a, b)| a != b)
                    .count();
                assert!((1..=3).contains(&diff), "{task}: minimal contrast");
            }
        }
    }

    #[test]
    fn dense_builders() {
        let Ok(d) = Dataset::by_task("ioi") else { return };
        let b = d.batch(4).unwrap();
        let vocab = 52;
        let oh = Dataset::onehot(b, false, vocab);
        assert_eq!(oh.len(), 4 * d.seq_len * vocab);
        // each row sums to exactly 1
        for row in oh.chunks(vocab) {
            assert_eq!(row.iter().sum::<f32>(), 1.0);
        }
        let pos = Dataset::pos_onehot(b, d.seq_len);
        assert_eq!(pos.iter().sum::<f32>(), 4.0);
        let ans = Dataset::dist(b, vocab, false);
        assert!((ans.iter().sum::<f32>() - 4.0).abs() < 1e-4);
    }
}
