//! Model artifacts: manifest (config + parameter layout), the weight store
//! with per-precision residency, the computational graph (nodes / channels
//! / edges) that circuit discovery operates on, and dataset loading.

pub mod config;
pub mod dataset;
pub mod graph;
pub mod weights;

pub use config::{Manifest, ParamEntry};
pub use dataset::{Dataset, Example};
pub use graph::{Channel, Edge, Graph, NodeId};
pub use weights::WeightStore;
