//! The weight store — PAHQ's memory hierarchy in miniature.
//!
//! Mirrors the paper's setup (section 3.1, "Hierarchical Weight
//! Scheduling"): the FP32 master copy of every weight lives in **host**
//! memory; the **device** holds a low-precision (FP8-emulated) resident
//! copy of everything, plus a small staging area into which the FP32 rows
//! of the head under investigation are "transferred" per edge evaluation.
//! The byte counts of those structures drive the simulated GPU memory
//! accounting (Tab. 3) and the transfer sizes the DES charges (Tab. 4).
//!
//! All actual numerics are f32 in host RAM — "FP8-resident" means the
//! values have been pushed onto the FP8 lattice by [`crate::quant::fq`],
//! exactly like the values the real system would dequantize on the fly.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::quant::{fq_slice, Format};

use super::config::Manifest;

/// One precision-plane of the full parameter vector.
struct Plane {
    format: Format,
    data: Vec<f32>,
}

pub struct WeightStore {
    manifest: Manifest,
    /// FP32 master (paper: host/CPU memory).
    master: Vec<f32>,
    /// Low-precision resident planes keyed by format name (paper: GPU).
    planes: HashMap<&'static str, Plane>,
    index: HashMap<String, (usize, usize)>, // name -> (offset, size)
}

impl WeightStore {
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        let path = manifest.dir.join("weights.bin");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != manifest.n_params * 4 {
            bail!(
                "{}: expected {} bytes, found {}",
                path.display(),
                manifest.n_params * 4,
                bytes.len()
            );
        }
        let master: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let index = manifest
            .params
            .iter()
            .map(|p| (p.name.clone(), (p.offset, p.size)))
            .collect();
        Ok(WeightStore { manifest: manifest.clone(), master, planes: HashMap::new(), index })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Materialize (once) the resident plane for `format` — e.g. the FP8
    /// copy of every weight the paper keeps on-GPU.
    pub fn ensure_plane(&mut self, name: &'static str, format: Format) {
        self.planes.entry(name).or_insert_with(|| {
            let mut data = self.master.clone();
            fq_slice(&mut data, format);
            Plane { format, data }
        });
    }

    /// FP32 master slice of a named parameter.
    pub fn master_param(&self, name: &str) -> Result<&[f32]> {
        let &(off, size) = self
            .index
            .get(name)
            .with_context(|| format!("unknown param '{name}'"))?;
        Ok(&self.master[off..off + size])
    }

    /// Resident low-precision slice of a named parameter.
    pub fn plane_param(&self, plane: &str, name: &str) -> Result<&[f32]> {
        let p = self
            .planes
            .get(plane)
            .with_context(|| format!("plane '{plane}' not materialized"))?;
        let &(off, size) = self
            .index
            .get(name)
            .with_context(|| format!("unknown param '{name}'"))?;
        Ok(&p.data[off..off + size])
    }

    pub fn plane_format(&self, plane: &str) -> Option<Format> {
        self.planes.get(plane).map(|p| p.format)
    }

    /// Parameter slice at an explicit precision policy: FP32 master when
    /// `hi` is true, the named plane otherwise.
    pub fn param_at(&self, name: &str, plane: &str, hi: bool) -> Result<&[f32]> {
        if hi {
            self.master_param(name)
        } else {
            self.plane_param(plane, name)
        }
    }

    /// Assemble a *mixed-precision* per-head weight tensor for one layer
    /// and component: rows of `hi_head` come from the FP32 master, all
    /// other heads from the low-precision plane. This is exactly the
    /// paper's Eq. 4/Eq. 9 weight-side selection, and the buffer it fills
    /// is what gets fed to the AOT attention executable.
    ///
    /// `out` must have the full parameter length ([H, D, K] flattened).
    pub fn mixed_head_param(
        &self,
        name: &str,
        plane: &str,
        hi_head: Option<usize>,
        out: &mut [f32],
    ) -> Result<()> {
        let lo = self.plane_param(plane, name)?;
        out.copy_from_slice(lo);
        if let Some(h) = hi_head {
            let hi = self.master_param(name)?;
            let per_head = hi.len() / self.manifest.n_head;
            let a = h * per_head;
            out[a..a + per_head].copy_from_slice(&hi[a..a + per_head]);
        }
        Ok(())
    }

    /// Assemble a per-head weight tensor with an *arbitrary* precision per
    /// head (`planes[h]` names the plane for head h; "master" = FP32).
    /// Generalizes [`Self::mixed_head_param`] for the Fig. 4 incremental
    /// quantization experiment.
    pub fn assemble_heads(&self, name: &str, planes: &[&str], out: &mut [f32]) -> Result<()> {
        let per_head = out.len() / planes.len();
        for (h, plane) in planes.iter().enumerate() {
            let src = if *plane == "master" {
                self.master_param(name)?
            } else {
                self.plane_param(plane, name)?
            };
            let a = h * per_head;
            out[a..a + per_head].copy_from_slice(&src[a..a + per_head]);
        }
        Ok(())
    }

    /// Bytes of device-resident weights at the plane's precision —
    /// the memory-model input for Tab. 3.
    pub fn resident_bytes(&self, plane: &str) -> usize {
        self.planes
            .get(plane)
            .map(|p| p.data.len() * p.format.storage_bytes())
            .unwrap_or(0)
    }

    pub fn n_params(&self) -> usize {
        self.master.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fq, FP8_E4M3};

    fn store() -> Option<WeightStore> {
        let m = Manifest::by_name("redwood2l-sim").ok()?;
        WeightStore::load(&m).ok()
    }

    #[test]
    fn loads_and_indexes() {
        let Some(s) = store() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let wte = s.master_param("wte").unwrap();
        assert_eq!(wte.len(), s.manifest().vocab * s.manifest().d_model);
        assert!(s.master_param("nope").is_err());
    }

    #[test]
    fn plane_is_on_lattice() {
        let Some(mut s) = store() else { return };
        s.ensure_plane("fp8", FP8_E4M3);
        let lo = s.plane_param("fp8", "l0.wq").unwrap();
        for &v in lo.iter().take(500) {
            assert_eq!(v, fq(v, FP8_E4M3), "resident values are fixed points");
        }
        // fp8 differs from master somewhere (weights aren't all on-lattice)
        let hi = s.master_param("l0.wq").unwrap();
        assert!(lo.iter().zip(hi).any(|(a, b)| a != b));
    }

    #[test]
    fn mixed_head_selects_rows() {
        let Some(mut s) = store() else { return };
        s.ensure_plane("fp8", FP8_E4M3);
        let hi = s.master_param("l0.wq").unwrap().to_vec();
        let lo = s.plane_param("fp8", "l0.wq").unwrap().to_vec();
        let n_head = s.manifest().n_head;
        let per_head = hi.len() / n_head;
        let mut out = vec![0.0; hi.len()];
        s.mixed_head_param("l0.wq", "fp8", Some(1), &mut out).unwrap();
        assert_eq!(&out[per_head..2 * per_head], &hi[per_head..2 * per_head]);
        assert_eq!(&out[..per_head], &lo[..per_head]);
        assert_eq!(&out[2 * per_head..], &lo[2 * per_head..]);
        // no high head -> identical to plane
        s.mixed_head_param("l0.wq", "fp8", None, &mut out).unwrap();
        assert_eq!(out, lo);
    }

    #[test]
    fn resident_bytes_scale_with_format() {
        let Some(mut s) = store() else { return };
        s.ensure_plane("fp8", FP8_E4M3);
        assert_eq!(s.resident_bytes("fp8"), s.n_params());
        assert_eq!(s.resident_bytes("missing"), 0);
    }
}
