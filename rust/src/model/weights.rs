//! The weight store — PAHQ's memory hierarchy in miniature.
//!
//! Mirrors the paper's setup (section 3.1, "Hierarchical Weight
//! Scheduling"): the FP32 master copy of every weight lives in **host**
//! memory; the **device** holds a low-precision resident copy of
//! everything, plus a small staging area into which the FP32 rows of the
//! head under investigation are "transferred" per edge evaluation.
//!
//! Resident planes are stored *packed* ([`QTensor`]): the fp8 plane
//! really occupies one byte per parameter, bf16 two, fp4 half — so the
//! byte counts reported by [`WeightStore::resident_bytes`] are measured
//! allocations, not billed estimates. Decoding a packed plane yields
//! exactly the [`crate::quant::fq`] lattice values the old f32 copies
//! held (the codec is bit-identical by construction), so numerics are
//! unchanged. Passthrough (FP32) planes are never materialized: the
//! master vector *is* the full-precision copy, and duplicating it — as
//! the pre-packing implementation did — bought nothing; FP32 sessions
//! are billed the master bytes as their device-resident footprint.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::quant::Format;
use crate::tensor::QTensor;

use super::config::Manifest;

pub struct WeightStore {
    manifest: Manifest,
    /// FP32 master (paper: host/CPU memory). Doubles as the resident
    /// copy of passthrough planes ("p32"/"master").
    master: Vec<f32>,
    /// Packed low-precision resident planes keyed by name (paper: GPU).
    planes: HashMap<&'static str, QTensor>,
    index: HashMap<String, (usize, usize)>, // name -> (offset, size)
}

/// Plane names that read straight from the FP32 master.
fn is_master_plane(name: &str) -> bool {
    name == "master" || name == "p32"
}

impl WeightStore {
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        let path = manifest.dir.join("weights.bin");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != manifest.n_params * 4 {
            bail!(
                "{}: expected {} bytes, found {}",
                path.display(),
                manifest.n_params * 4,
                bytes.len()
            );
        }
        let master: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let index = manifest
            .params
            .iter()
            .map(|p| (p.name.clone(), (p.offset, p.size)))
            .collect();
        Ok(WeightStore { manifest: manifest.clone(), master, planes: HashMap::new(), index })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Materialize (once) the packed resident plane for `format` — e.g.
    /// the byte-per-parameter FP8 copy the paper keeps on-GPU. A no-op
    /// for passthrough formats: those reads alias the master.
    pub fn ensure_plane(&mut self, name: &'static str, format: Format) {
        if format.is_passthrough() {
            return;
        }
        let master = &self.master;
        let n = master.len();
        self.planes
            .entry(name)
            .or_insert_with(|| QTensor::from_slice(&[n], master, format));
    }

    /// FP32 master slice of a named parameter.
    pub fn master_param(&self, name: &str) -> Result<&[f32]> {
        let &(off, size) = self
            .index
            .get(name)
            .with_context(|| format!("unknown param '{name}'"))?;
        Ok(&self.master[off..off + size])
    }

    /// Parameter slice at an explicit precision policy, zero-copy where
    /// possible: master reads (`hi` override, or a passthrough plane)
    /// borrow straight from the FP32 master — the forward hot path pays
    /// no copy, exactly like the pre-packing implementation — while
    /// packed planes decode into `scratch` and return it.
    pub fn param_at<'a>(
        &'a self,
        name: &str,
        plane: &str,
        hi: bool,
        scratch: &'a mut [f32],
    ) -> Result<&'a [f32]> {
        let &(off, size) = self
            .index
            .get(name)
            .with_context(|| format!("unknown param '{name}'"))?;
        if hi || is_master_plane(plane) {
            return Ok(&self.master[off..off + size]);
        }
        if scratch.len() != size {
            bail!("param '{name}': scratch holds {} of {size} elements", scratch.len());
        }
        self.plane(plane)?.decode_range_into(off, scratch);
        Ok(scratch)
    }

    /// Decode a named parameter at an explicit precision policy into
    /// `out`: the FP32 master when `hi` is true or the plane is a
    /// passthrough alias, the packed resident plane otherwise.
    pub fn param_into(&self, name: &str, plane: &str, hi: bool, out: &mut [f32]) -> Result<()> {
        let &(off, size) = self
            .index
            .get(name)
            .with_context(|| format!("unknown param '{name}'"))?;
        if out.len() != size {
            bail!("param '{name}': buffer holds {} of {size} elements", out.len());
        }
        if hi || is_master_plane(plane) {
            out.copy_from_slice(&self.master[off..off + size]);
            return Ok(());
        }
        self.plane(plane)?.decode_range_into(off, out);
        Ok(())
    }

    fn plane(&self, name: &str) -> Result<&QTensor> {
        self.planes
            .get(name)
            .with_context(|| format!("plane '{name}' not materialized"))
    }

    /// Assemble a *mixed-precision* per-head weight tensor for one layer
    /// and component: rows of `hi_head` come from the FP32 master, all
    /// other heads decode from the packed low-precision plane. This is
    /// exactly the paper's Eq. 4/Eq. 9 weight-side selection, and the
    /// buffer it fills is what gets fed to the AOT attention executable.
    ///
    /// `out` must have the full parameter length ([H, D, K] flattened).
    pub fn mixed_head_param(
        &self,
        name: &str,
        plane: &str,
        hi_head: Option<usize>,
        out: &mut [f32],
    ) -> Result<()> {
        self.param_into(name, plane, false, out)?;
        if let Some(h) = hi_head {
            let hi = self.master_param(name)?;
            let per_head = hi.len() / self.manifest.n_head;
            let a = h * per_head;
            out[a..a + per_head].copy_from_slice(&hi[a..a + per_head]);
        }
        Ok(())
    }

    /// Assemble a per-head weight tensor with an *arbitrary* precision per
    /// head (`planes[h]` names the plane for head h; "master" = FP32).
    /// Generalizes [`Self::mixed_head_param`] for the Fig. 4 incremental
    /// quantization experiment.
    pub fn assemble_heads(&self, name: &str, planes: &[&str], out: &mut [f32]) -> Result<()> {
        let &(off, size) = self
            .index
            .get(name)
            .with_context(|| format!("unknown param '{name}'"))?;
        if out.len() != size {
            bail!("param '{name}': buffer holds {} of {size} elements", out.len());
        }
        let per_head = size / planes.len();
        for (h, plane) in planes.iter().enumerate() {
            let a = h * per_head;
            if is_master_plane(plane) {
                out[a..a + per_head].copy_from_slice(&self.master[off + a..off + a + per_head]);
            } else {
                self.plane(plane)?.decode_range_into(off + a, &mut out[a..a + per_head]);
            }
        }
        Ok(())
    }

    /// *Measured* bytes of device-resident weights for a plane: the
    /// packed payload size, or the master bytes for the FP32 alias
    /// planes (an FP32 session's device copy is full-width by
    /// definition). Unknown planes occupy nothing.
    pub fn resident_bytes(&self, plane: &str) -> usize {
        if is_master_plane(plane) {
            return self.master.len() * 4;
        }
        self.planes.get(plane).map(|p| p.bytes()).unwrap_or(0)
    }

    pub fn n_params(&self) -> usize {
        self.master.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fq, FP4_E2M1, FP8_E4M3};

    fn store() -> Option<WeightStore> {
        let m = Manifest::by_name("redwood2l-sim").ok()?;
        WeightStore::load(&m).ok()
    }

    #[test]
    fn loads_and_indexes() {
        let Some(s) = store() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let wte = s.master_param("wte").unwrap();
        assert_eq!(wte.len(), s.manifest().vocab * s.manifest().d_model);
        assert!(s.master_param("nope").is_err());
    }

    #[test]
    fn plane_is_on_lattice() {
        let Some(mut s) = store() else { return };
        s.ensure_plane("fp8", FP8_E4M3);
        let hi = s.master_param("l0.wq").unwrap().to_vec();
        let mut lo = vec![0.0; hi.len()];
        s.param_into("l0.wq", "fp8", false, &mut lo).unwrap();
        for (&l, &h) in lo.iter().zip(&hi).take(500) {
            assert_eq!(l, fq(h, FP8_E4M3), "decoded plane values are fq(master)");
        }
        // fp8 differs from master somewhere (weights aren't all on-lattice)
        assert!(lo.iter().zip(&hi).any(|(a, b)| a != b));
        // hi=true and the p32 alias both read the master verbatim
        let mut back = vec![0.0; hi.len()];
        s.param_into("l0.wq", "fp8", true, &mut back).unwrap();
        assert_eq!(back, hi);
        s.param_into("l0.wq", "p32", false, &mut back).unwrap();
        assert_eq!(back, hi);
    }

    #[test]
    fn mixed_head_selects_rows() {
        let Some(mut s) = store() else { return };
        s.ensure_plane("fp8", FP8_E4M3);
        let hi = s.master_param("l0.wq").unwrap().to_vec();
        let mut lo = vec![0.0; hi.len()];
        s.param_into("l0.wq", "fp8", false, &mut lo).unwrap();
        let n_head = s.manifest().n_head;
        let per_head = hi.len() / n_head;
        let mut out = vec![0.0; hi.len()];
        s.mixed_head_param("l0.wq", "fp8", Some(1), &mut out).unwrap();
        assert_eq!(&out[per_head..2 * per_head], &hi[per_head..2 * per_head]);
        assert_eq!(&out[..per_head], &lo[..per_head]);
        assert_eq!(&out[2 * per_head..], &lo[2 * per_head..]);
        // no high head -> identical to plane
        s.mixed_head_param("l0.wq", "fp8", None, &mut out).unwrap();
        assert_eq!(out, lo);
    }

    #[test]
    fn resident_bytes_are_measured_packed_sizes() {
        let Some(mut s) = store() else { return };
        s.ensure_plane("fp8", FP8_E4M3);
        s.ensure_plane("fp4", FP4_E2M1);
        assert_eq!(s.resident_bytes("fp8"), s.n_params());
        assert_eq!(s.resident_bytes("fp4"), s.n_params().div_ceil(2));
        assert_eq!(s.resident_bytes("missing"), 0);
        // the FP32 "plane" is the master itself — billed at full width,
        // never duplicated
        assert_eq!(s.resident_bytes("p32"), s.n_params() * 4);
        s.ensure_plane("p32", crate::quant::FP32);
        assert_eq!(s.resident_bytes("p32"), s.n_params() * 4);
    }
}
