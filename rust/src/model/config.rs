//! Manifest loading: every model directory under `artifacts/` carries a
//! `manifest.json` written by `aot.py` describing the shape family, the
//! flat parameter layout of `weights.bin`, and which HLO artifacts exist.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_model: usize,
    pub d_head: usize,
    pub d_mlp: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub batch: usize,
    pub n_params: usize,
    pub params: Vec<ParamEntry>,
    pub artifacts: Vec<String>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(model_dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&model_dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", model_dir.display()))?;
        let params = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p.get("shape")?.usize_vec()?,
                    offset: p.get("offset")?.as_usize()?,
                    size: p.get("size")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let m = Manifest {
            name: j.get("name")?.as_str()?.to_string(),
            n_layer: j.get("n_layer")?.as_usize()?,
            n_head: j.get("n_head")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            d_head: j.get("d_head")?.as_usize()?,
            d_mlp: j.get("d_mlp")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            batch: j.get("batch")?.as_usize()?,
            n_params: j.get("n_params")?.as_usize()?,
            params,
            artifacts: j
                .get("artifacts")?
                .as_arr()?
                .iter()
                .map(|a| Ok(a.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            dir: model_dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    /// Load a model by name from the artifacts root.
    pub fn by_name(name: &str) -> Result<Manifest> {
        let dir = crate::artifacts_root().join(name);
        if !dir.exists() {
            bail!(
                "model '{name}' not found under {} — run `make artifacts`",
                crate::artifacts_root().display()
            );
        }
        Self::load(&dir)
    }

    fn validate(&self) -> Result<()> {
        let mut expect_off = 0usize;
        for p in &self.params {
            if p.offset != expect_off {
                bail!("param {} offset {} != expected {}", p.name, p.offset, expect_off);
            }
            if p.size != p.shape.iter().product::<usize>() {
                bail!("param {} size mismatch", p.name);
            }
            expect_off += p.size;
        }
        if expect_off != self.n_params {
            bail!("n_params {} != sum of params {}", self.n_params, expect_off);
        }
        Ok(())
    }

    pub fn has_mlp(&self) -> bool {
        self.d_mlp > 0
    }

    pub fn hlo_path(&self, artifact: &str) -> PathBuf {
        self.dir.join(artifact)
    }

    pub fn param(&self, name: &str) -> Result<&ParamEntry> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .with_context(|| format!("param '{name}' not in manifest"))
    }

    /// Total parameter count per attention head of one layer (Q+K+V+O rows
    /// + biases) — the unit PAHQ moves across the simulated PCIe bus.
    pub fn head_param_count(&self) -> usize {
        // wq,wk,wv rows: 3 * D * K; biases 3 * K; wo rows: K * D
        3 * self.d_model * self.d_head + 3 * self.d_head + self.d_head * self.d_model
    }

    /// W_O for a whole layer (the paper also uploads W_O,32 per layer).
    pub fn wo_param_count(&self) -> usize {
        self.n_head * self.d_head * self.d_model
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn any_model() -> Option<Manifest> {
        for name in ["redwood2l-sim", "attn4l-sim", "gpt2s-sim"] {
            if let Ok(m) = Manifest::by_name(name) {
                return Some(m);
            }
        }
        None
    }

    #[test]
    fn loads_and_validates() {
        let Some(m) = any_model() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(m.n_layer >= 2);
        assert!(m.n_params > 0);
        assert!(m.params.iter().any(|p| p.name == "wte"));
        assert!(m.params.iter().any(|p| p.name == "lnf_g"));
        // layout is contiguous (validate() passed), weights.bin matches
        let wlen = std::fs::metadata(m.dir.join("weights.bin")).unwrap().len();
        assert_eq!(wlen as usize, m.n_params * 4);
    }

    #[test]
    fn head_param_count_sane() {
        let Some(m) = any_model() else { return };
        assert_eq!(
            m.head_param_count(),
            4 * m.d_model * m.d_head + 3 * m.d_head
        );
    }

    #[test]
    fn missing_model_errors() {
        assert!(Manifest::by_name("no-such-model").is_err());
    }
}
