//! Packed low-precision tensor storage.
//!
//! [`QTensor`] holds quantized values in their *native* widths — `u16`
//! words for fp16/bf16, `u8` bytes for fp8, two-per-byte nibbles for
//! fp4_e2m1 — instead of the fake-quantized f32 copies the engine used to
//! carry. Decoding is proven bit-identical to [`crate::quant::fq`]
//! (property-tested in `tests/properties.rs`): `decode(encode(x)) ==
//! fq(x)` for every format, including ±0, format subnormals, saturation
//! bounds, and ties-to-even cases. That makes the payload byte count a
//! *measured* memory footprint, not a billed one, while every consumer
//! keeps seeing exactly the f32 lattice values it saw before.
//!
//! The fused kernels ([`add_assign_packed`] and friends) decode inline
//! inside the accumulation loop, so the residual-assembly hot path reads
//! packed bytes directly instead of dequantizing into scratch first.
//! Passthrough (f32) payloads delegate to the plain [`crate::tensor`]
//! primitives, keeping the FP32 path bit-for-bit what it always was.
//!
//! ## Bit layout
//!
//! Per element: `sign | exponent | mantissa`, with `mbits` mantissa bits
//! from the [`Format`] and the exponent field sized to fill the storage
//! width (fp16 → 1/5/10 and bf16 → 1/8/7, i.e. the IEEE/bfloat layouts;
//! fp8_e4m3 → 1/4/3; fp8_e5m2 → 1/5/2; fp4_e2m1 → 1/2/1). Exponent code
//! 0 holds zeros and format subnormals (`mant * 2^(emin - mbits)`), code
//! `k > 0` the normal binade `emin + k - 1` — exactly the value set `fq`
//! projects onto, so the codec is total on fq's range by construction.

use crate::quant::{self, floor_log2, fq, pow2, Format};
use crate::tensor::Tensor;

/// Storage for one packed tensor. Private: consumers go through the
/// decode/kernel API, which is what guarantees the fq bit-identity.
#[derive(Clone, Debug, PartialEq)]
enum Payload {
    /// Passthrough (and unknown-width) formats: plain f32 words.
    F32(Vec<f32>),
    /// fp16 / bf16: one 16-bit word per element.
    U16(Vec<u16>),
    /// fp8_e4m3 / fp8_e5m2: one byte per element.
    U8(Vec<u8>),
    /// fp4_e2m1: two elements per byte, low nibble = even index; an odd
    /// element count leaves the final high nibble zero.
    U4(Vec<u8>),
}

/// A shape-tagged tensor stored at its format's native width.
#[derive(Clone, Debug, PartialEq)]
pub struct QTensor {
    shape: Vec<usize>,
    len: usize,
    format: Format,
    payload: Payload,
}

/// Sign/exponent/mantissa field geometry for one non-passthrough format.
#[derive(Clone, Copy)]
struct Codec {
    /// mantissa bits
    m: u32,
    /// exponent field bits (storage width minus sign minus mantissa)
    ebits: u32,
    /// unbiased exponent of the smallest normal (biased code 1)
    emin: i32,
}

impl Codec {
    fn new(f: Format) -> Codec {
        let m = f.mbits as u32;
        Codec { m, ebits: f.storage_bits() as u32 - 1 - m, emin: f.emin as i32 }
    }

    /// Pack a value already on the format's lattice (i.e. an output of
    /// `fq`). Lattice values are normal f32 with at most `m` significant
    /// mantissa bits, so the mantissa field reads straight off the IEEE
    /// bits (exact at every binade, including e = 127 where a 2^-e
    /// rescale would leave [`pow2`]'s clamped range); the subnormal
    /// index uses exact power-of-two scaling.
    #[inline]
    fn encode(&self, y: f32) -> u32 {
        let sign = (y.to_bits() >> 31) << (self.ebits + self.m);
        let ay = y.abs();
        if ay == 0.0 {
            return sign; // preserves -0.0 via the sign bit
        }
        let e = floor_log2(ay) as i32;
        if e < self.emin {
            // format subnormal: ay = k * 2^(emin - m), k in 1..2^m
            let k = (ay * pow2((self.m as i32 - self.emin) as f32)) as u32;
            sign | k
        } else {
            // normal: ay = (2^m + mant) * 2^(e - m); the low f32 mantissa
            // bits are zero on the lattice
            let bits = ay.to_bits();
            debug_assert_eq!(bits & ((1 << (23 - self.m)) - 1), 0, "off-lattice encode");
            let mant = (bits >> (23 - self.m)) & ((1 << self.m) - 1);
            sign | (((e - self.emin + 1) as u32) << self.m) | mant
        }
    }

    /// Exact inverse of [`Codec::encode`].
    #[inline]
    fn decode(&self, bits: u32) -> f32 {
        let mant = bits & ((1 << self.m) - 1);
        let exp_code = (bits >> self.m) & ((1 << self.ebits) - 1);
        let neg = bits >> (self.ebits + self.m) & 1 == 1;
        let mag = if exp_code == 0 {
            mant as f32 * pow2((self.emin - self.m as i32) as f32)
        } else {
            // split into fraction-in-[1,2) times 2^e so the intermediate
            // stays a normal f32 even at e = emin = -126 (bf16)
            let frac = ((1u32 << self.m) + mant) as f32 * pow2(-(self.m as f32));
            frac * pow2((self.emin + exp_code as i32 - 1) as f32)
        };
        if neg { -mag } else { mag }
    }
}

impl QTensor {
    /// Quantize (`fq`) and pack a slice. The stored values are exactly
    /// `fq(x, format)` — packing an already-quantized slice is lossless
    /// because `fq` is idempotent.
    pub fn from_slice(shape: &[usize], xs: &[f32], format: Format) -> QTensor {
        debug_assert_eq!(shape.iter().product::<usize>(), xs.len());
        let payload = if format.is_passthrough() {
            Payload::F32(xs.to_vec())
        } else {
            let c = Codec::new(format);
            match format.storage_bits() {
                16 => Payload::U16(xs.iter().map(|&x| c.encode(fq(x, format)) as u16).collect()),
                8 => Payload::U8(xs.iter().map(|&x| c.encode(fq(x, format)) as u8).collect()),
                4 => {
                    let mut v = vec![0u8; xs.len().div_ceil(2)];
                    for (i, &x) in xs.iter().enumerate() {
                        v[i / 2] |= (c.encode(fq(x, format)) as u8 & 0x0f) << ((i % 2) * 4);
                    }
                    Payload::U4(v)
                }
                // custom formats with no packed width: keep fq'd f32
                _ => Payload::F32(xs.iter().map(|&x| fq(x, format)).collect()),
            }
        };
        QTensor { shape: shape.to_vec(), len: xs.len(), format, payload }
    }

    pub fn from_tensor(t: &Tensor, format: Format) -> QTensor {
        QTensor::from_slice(&t.shape, &t.data, format)
    }

    pub fn format(&self) -> Format {
        self.format
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes the payload actually occupies — the *measured* counterpart
    /// of the simulated accounting in `gpu_sim::memory`.
    pub fn bytes(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len() * 4,
            Payload::U16(v) => v.len() * 2,
            Payload::U8(v) | Payload::U4(v) => v.len(),
        }
    }

    /// Decode one element (bounds-checked; index-heavy callers should
    /// prefer the bulk/fused entry points).
    pub fn get(&self, i: usize) -> f32 {
        assert!(i < self.len);
        match &self.payload {
            Payload::F32(v) => v[i],
            Payload::U16(v) => Codec::new(self.format).decode(v[i] as u32),
            Payload::U8(v) => Codec::new(self.format).decode(v[i] as u32),
            Payload::U4(v) => {
                Codec::new(self.format).decode((v[i / 2] >> ((i % 2) * 4) & 0x0f) as u32)
            }
        }
    }

    /// Visit elements `start..start + n` in order, decoded to f32; the
    /// callback receives indices relative to `start`. This is the one
    /// decode loop every bulk/fused operation below is built on.
    #[inline]
    fn for_each_decoded<F: FnMut(usize, f32)>(&self, start: usize, n: usize, mut f: F) {
        debug_assert!(start + n <= self.len);
        match &self.payload {
            Payload::F32(v) => {
                for (j, &x) in v[start..start + n].iter().enumerate() {
                    f(j, x);
                }
            }
            Payload::U16(v) => {
                let c = Codec::new(self.format);
                for (j, &b) in v[start..start + n].iter().enumerate() {
                    f(j, c.decode(b as u32));
                }
            }
            Payload::U8(v) => {
                let c = Codec::new(self.format);
                for (j, &b) in v[start..start + n].iter().enumerate() {
                    f(j, c.decode(b as u32));
                }
            }
            Payload::U4(v) => {
                let c = Codec::new(self.format);
                for j in 0..n {
                    let i = start + j;
                    f(j, c.decode((v[i / 2] >> ((i % 2) * 4) & 0x0f) as u32));
                }
            }
        }
    }

    /// Decode the whole tensor into `out` (same length).
    pub fn decode_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.len);
        self.decode_range_into(0, out);
    }

    /// Decode elements `start..start + out.len()` into `out`. Handles
    /// odd nibble offsets, so packed planes can be read per-parameter.
    pub fn decode_range_into(&self, start: usize, out: &mut [f32]) {
        if let Payload::F32(v) = &self.payload {
            out.copy_from_slice(&v[start..start + out.len()]);
            return;
        }
        self.for_each_decoded(start, out.len(), |j, x| out[j] = x);
    }

    /// Decode into a fresh [`Tensor`] (test/baseline convenience).
    pub fn to_tensor(&self) -> Tensor {
        let mut out = Tensor::zeros(&self.shape);
        self.decode_into(&mut out.data);
        out
    }

    /// Serialize to the durable-store wire layout. The payload words go
    /// out verbatim in their packed widths (the same bytes [`bytes`]
    /// measures), so `from_bytes(to_bytes(q)) == q` is bit-identical by
    /// construction — no re-quantization round trip. Layout (all
    /// little-endian): payload tag `u8`, format triple `3 x f32` bits,
    /// element count `u64`, rank `u32`, dims `u64` each, payload byte
    /// count `u64`, payload words.
    pub fn to_bytes(&self) -> Vec<u8> {
        let (tag, payload): (u8, Vec<u8>) = match &self.payload {
            Payload::F32(v) => (0, v.iter().flat_map(|x| x.to_bits().to_le_bytes()).collect()),
            Payload::U16(v) => (1, v.iter().flat_map(|x| x.to_le_bytes()).collect()),
            Payload::U8(v) => (2, v.clone()),
            Payload::U4(v) => (3, v.clone()),
        };
        let mut out = Vec::with_capacity(1 + 12 + 8 + 4 + 8 * self.shape.len() + 8 + payload.len());
        out.push(tag);
        for f in [self.format.mbits, self.format.emin, self.format.maxv] {
            out.extend_from_slice(&f.to_bits().to_le_bytes());
        }
        out.extend_from_slice(&(self.len as u64).to_le_bytes());
        out.extend_from_slice(&(self.shape.len() as u32).to_le_bytes());
        for &d in &self.shape {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Exact inverse of [`QTensor::to_bytes`]. Errors (never panics) on
    /// truncated or structurally inconsistent input — the durable store
    /// quarantines such entries and recomputes.
    pub fn from_bytes(b: &[u8]) -> anyhow::Result<QTensor> {
        use anyhow::bail;
        let mut at = 0usize;
        let mut take = |n: usize| -> anyhow::Result<&[u8]> {
            if at + n > b.len() {
                bail!("qtensor wire data truncated at byte {at} (need {n} more)");
            }
            let s = &b[at..at + n];
            at += n;
            Ok(s)
        };
        let tag = take(1)?[0];
        let mut f32_at = |s: &[u8]| f32::from_bits(u32::from_le_bytes(s.try_into().unwrap()));
        let format = Format {
            mbits: f32_at(take(4)?),
            emin: f32_at(take(4)?),
            maxv: f32_at(take(4)?),
        };
        let len = u64::from_le_bytes(take(8)?.try_into().unwrap()) as usize;
        let rank = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(u64::from_le_bytes(take(8)?.try_into().unwrap()) as usize);
        }
        if shape.iter().product::<usize>() != len {
            bail!("qtensor wire shape {shape:?} does not cover {len} elements");
        }
        let n_payload = u64::from_le_bytes(take(8)?.try_into().unwrap()) as usize;
        let words = take(n_payload)?;
        let expect = |n: usize, have: usize| -> anyhow::Result<()> {
            if n != have {
                bail!("qtensor wire payload holds {have} elements, header says {n}");
            }
            Ok(())
        };
        let payload = match tag {
            0 => {
                expect(len * 4, n_payload)?;
                Payload::F32(
                    words
                        .chunks_exact(4)
                        .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
                        .collect(),
                )
            }
            1 => {
                expect(len * 2, n_payload)?;
                Payload::U16(
                    words
                        .chunks_exact(2)
                        .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            2 => {
                expect(len, n_payload)?;
                Payload::U8(words.to_vec())
            }
            3 => {
                expect(len.div_ceil(2), n_payload)?;
                Payload::U4(words.to_vec())
            }
            t => bail!("qtensor wire payload tag {t} unknown"),
        };
        if at != b.len() {
            bail!("qtensor wire data has {} trailing bytes", b.len() - at);
        }
        Ok(QTensor { shape, len, format, payload })
    }
}

/// `dst += src`, decoding packed bytes inline (no scratch buffer). The
/// f32 payload delegates to [`crate::tensor::add_assign`], so passthrough
/// sessions keep their exact historical bit pattern.
pub fn add_assign_packed(dst: &mut [f32], src: &QTensor) {
    debug_assert_eq!(dst.len(), src.len());
    if let Payload::F32(v) = &src.payload {
        crate::tensor::add_assign(dst, v);
        return;
    }
    src.for_each_decoded(0, dst.len(), |i, x| dst[i] += x);
}

/// `dst += a - b` with the *added* term packed (patch swap: splice a
/// packed corrupted contribution in over a clean f32 one).
pub fn add_sub_assign_packed(dst: &mut [f32], a: &QTensor, b: &[f32]) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    if let Payload::F32(v) = &a.payload {
        crate::tensor::add_sub_assign(dst, v, b);
        return;
    }
    a.for_each_decoded(0, dst.len(), |i, x| dst[i] += x - b[i]);
}

/// `dst += a - b` with the *subtracted* term packed (the reverse swap:
/// splice a clean f32 contribution back in over a packed corrupted one).
pub fn add_sub_assign_packed_rev(dst: &mut [f32], a: &[f32], b: &QTensor) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    if let Payload::F32(v) = &b.payload {
        crate::tensor::add_sub_assign(dst, a, v);
        return;
    }
    b.for_each_decoded(0, dst.len(), |i, x| dst[i] += a[i] - x);
}

/// Packed counterpart of [`crate::quant::accumulate_quantized`]:
/// `acc = fq(acc + fq(x))` per element with `x` decoded from `src`.
/// Decoded values already sit on their storage lattice, and `fq` is
/// idempotent, so this is bit-identical to accumulating the f32 copy the
/// cache used to hold.
pub fn accumulate_quantized_packed(acc: &mut [f32], src: &QTensor, f: Format) {
    debug_assert_eq!(acc.len(), src.len());
    if f.is_passthrough() {
        add_assign_packed(acc, src);
        return;
    }
    if let Payload::F32(v) = &src.payload {
        quant::accumulate_quantized(acc, v, f);
        return;
    }
    src.for_each_decoded(0, acc.len(), |i, x| acc[i] = fq(acc[i] + fq(x, f), f));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{BF16, FP16, FP32, FP4_E2M1, FP8_E4M3, FP8_E5M2};
    use crate::util::rng::Rng;

    const FORMATS: [Format; 5] = [FP16, BF16, FP8_E4M3, FP8_E5M2, FP4_E2M1];

    #[test]
    fn payload_widths_are_native() {
        let xs = [1.0f32; 10];
        assert_eq!(QTensor::from_slice(&[10], &xs, FP32).bytes(), 40);
        assert_eq!(QTensor::from_slice(&[10], &xs, BF16).bytes(), 20);
        assert_eq!(QTensor::from_slice(&[10], &xs, FP16).bytes(), 20);
        assert_eq!(QTensor::from_slice(&[10], &xs, FP8_E4M3).bytes(), 10);
        assert_eq!(QTensor::from_slice(&[10], &xs, FP4_E2M1).bytes(), 5);
        // odd fp4 length rounds up to a whole byte
        assert_eq!(QTensor::from_slice(&[7], &xs[..7], FP4_E2M1).bytes(), 4);
    }

    #[test]
    fn roundtrip_equals_fq_on_anchors() {
        // hand-picked anchors per format; the exhaustive randomized sweep
        // lives in tests/properties.rs
        let mut cases = vec![0.0f32, -0.0, 1.0, -1.0, 0.5, 448.0, -448.0, 1000.0, 65504.0];
        cases.extend([3.4e38, 1e-9, -1e-9, 1e-40, 6.0, 7.0, 1.0625]);
        cases.push(2f32.powi(-9));
        cases.push(2f32.powi(-24));
        cases.push(2f32.powi(-126));
        for f in FORMATS {
            let qt = QTensor::from_slice(&[cases.len()], &cases, f);
            for (i, &x) in cases.iter().enumerate() {
                let want = fq(x, f);
                let got = qt.get(i);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{f:?}: decode(encode({x:e})) = {got:e}, fq = {want:e}"
                );
            }
        }
    }

    #[test]
    fn decode_range_handles_odd_nibble_offsets() {
        let mut r = Rng::new(11);
        let xs: Vec<f32> = (0..33).map(|_| r.normal() * 4.0).collect();
        let qt = QTensor::from_slice(&[33], &xs, FP4_E2M1);
        let mut full = vec![0.0f32; 33];
        qt.decode_into(&mut full);
        for start in [0usize, 1, 2, 7, 32] {
            let n = (33 - start).min(9);
            let mut part = vec![0.0f32; n];
            qt.decode_range_into(start, &mut part);
            assert_eq!(&part[..], &full[start..start + n], "start={start}");
        }
    }

    #[test]
    fn fused_kernels_match_decode_then_plain_ops() {
        let mut r = Rng::new(12);
        for f in [FP32, BF16, FP8_E4M3, FP4_E2M1] {
            let n = 257; // odd: exercises the nibble tail
            let src: Vec<f32> = (0..n).map(|_| r.normal() * 8.0).collect();
            let other: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let base: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let qt = QTensor::from_slice(&[n], &src, f);
            let mut dec = vec![0.0f32; n];
            qt.decode_into(&mut dec);

            let mut a = base.clone();
            add_assign_packed(&mut a, &qt);
            let mut want = base.clone();
            crate::tensor::add_assign(&mut want, &dec);
            assert_eq!(a, want, "add_assign_packed {f:?}");

            let mut b = base.clone();
            add_sub_assign_packed(&mut b, &qt, &other);
            let mut want = base.clone();
            crate::tensor::add_sub_assign(&mut want, &dec, &other);
            assert_eq!(b, want, "add_sub_assign_packed {f:?}");

            let mut c = base.clone();
            add_sub_assign_packed_rev(&mut c, &other, &qt);
            let mut want = base.clone();
            crate::tensor::add_sub_assign(&mut want, &other, &dec);
            assert_eq!(c, want, "add_sub_assign_packed_rev {f:?}");

            let mut d = base.clone();
            accumulate_quantized_packed(&mut d, &qt, FP8_E4M3);
            let mut want = base.clone();
            quant::accumulate_quantized(&mut want, &dec, FP8_E4M3);
            assert_eq!(d, want, "accumulate_quantized_packed {f:?}");
        }
    }

    #[test]
    fn to_tensor_roundtrips_f32() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 0.25, 0.0, -0.0, 9.5]).unwrap();
        let qt = QTensor::from_tensor(&t, FP32);
        assert_eq!(qt.to_tensor(), t);
        assert_eq!(qt.shape(), &[2, 3]);
        assert_eq!(qt.len(), 6);
        assert!(!qt.is_empty());
    }
}
