//! Flat f32 tensors and packed low-precision storage for the L3 hot path.
//!
//! The residual-stream assembly (sum of upstream node outputs per channel)
//! is the coordinator's inner loop: for every edge evaluation it performs
//! O(n_predecessors) vector adds over [B,S,D] buffers per node. Everything
//! here is allocation-free on the hot path — buffers are reused via
//! [`Tensor::fill`] / [`add_assign`] and a caller-owned pool.
//!
//! Working buffers stay f32 ([`Tensor`]); at-rest low-precision data
//! (weight planes, corrupted-activation caches) lives in format-native
//! packed storage ([`QTensor`], see [`qtensor`]) with fused
//! decode-accumulate kernels so the assembly loop reads packed bytes
//! directly. The kernels decode word-parallel — 64-bit payload words
//! expanded through per-format LUTs or the u16 bit rebase (see the
//! [`qtensor`] module doc) — and stay bit-identical to the scalar
//! decode they replaced.

pub mod qtensor;

pub use qtensor::{
    accumulate_quantized_packed, add_assign_packed, add_sub_assign_packed,
    add_sub_assign_packed_rev, QTensor,
};

use anyhow::{bail, Result};

/// Dense row-major f32 tensor with a shape tag.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        if shape.iter().product::<usize>() != data.len() {
            bail!("shape {:?} does not match {} elements", shape, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    pub fn copy_from(&mut self, src: &Tensor) {
        debug_assert_eq!(self.shape, src.shape);
        self.data.copy_from_slice(&src.data);
    }

    /// Bytes this (always-f32) tensor occupies. Low-precision sizes are a
    /// property of packed storage — ask [`QTensor::bytes`] or derive them
    /// from a format via [`crate::quant::Format::bytes_for`]; the old
    /// `bytes_at(bytes_per_elem)` entry point silently mis-billed fp4 and
    /// is gone.
    pub fn bytes(&self) -> usize {
        self.len() * 4
    }
}

/// `dst += src` (the assembly primitive). Manually unrolled by 8; with
/// `-C opt-level=3` this autovectorizes to AVX on the test machine.
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    let n = dst.len();
    let chunks = n / 8;
    // Unrolled main loop over exact chunks keeps the autovectorizer honest.
    for c in 0..chunks {
        let i = c * 8;
        let d = &mut dst[i..i + 8];
        let s = &src[i..i + 8];
        d[0] += s[0];
        d[1] += s[1];
        d[2] += s[2];
        d[3] += s[3];
        d[4] += s[4];
        d[5] += s[5];
        d[6] += s[6];
        d[7] += s[7];
    }
    for i in chunks * 8..n {
        dst[i] += src[i];
    }
}

/// `dst += a - b` in one pass (patch swap: replace a clean contribution
/// with a corrupted one without materializing the difference).
pub fn add_sub_assign(dst: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    for i in 0..dst.len() {
        dst[i] += a[i] - b[i];
    }
}

/// `dst = x` then `dst += each of srcs` — fused reset+accumulate.
pub fn assign_sum<'a>(dst: &mut [f32], base: &[f32], srcs: impl Iterator<Item = &'a [f32]>) {
    dst.copy_from_slice(base);
    for s in srcs {
        add_assign(dst, s);
    }
}

/// Dot product (metrics, EAP scores).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Max |a - b| — test helper.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Row-major softmax over the last axis of a [rows, cols] buffer,
/// in place. Numerically stable (max-subtraction).
pub fn softmax_rows(data: &mut [f32], cols: usize) {
    assert!(cols > 0 && data.len() % cols == 0);
    for row in data.chunks_mut(cols) {
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn zeros_and_fill() {
        let mut t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        t.fill(2.5);
        assert!(t.data.iter().all(|&v| v == 2.5));
        assert_eq!(t.bytes(), 24);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn add_assign_matches_scalar_loop() {
        let mut r = Rng::new(5);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let a: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let b: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let mut fast = a.clone();
            add_assign(&mut fast, &b);
            let slow: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
            assert_eq!(fast, slow, "n={n}");
        }
    }

    #[test]
    fn add_sub_is_patch_swap() {
        let mut r = Rng::new(6);
        let n = 100;
        let base: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let clean: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let corrupt: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        // sum with clean, then swap clean->corrupt
        let mut swapped = base.clone();
        add_assign(&mut swapped, &clean);
        add_sub_assign(&mut swapped, &corrupt, &clean);
        // direct sum with corrupt
        let mut direct = base.clone();
        add_assign(&mut direct, &corrupt);
        assert!(max_abs_diff(&swapped, &direct) < 1e-5);
    }

    #[test]
    fn assign_sum_accumulates() {
        let base = vec![1.0f32; 4];
        let s1 = vec![2.0f32; 4];
        let s2 = vec![3.0f32; 4];
        let mut dst = vec![0.0f32; 4];
        assign_sum(&mut dst, &base, [s1.as_slice(), s2.as_slice()].into_iter());
        assert_eq!(dst, vec![6.0; 4]);
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut data = vec![1.0, 2.0, 3.0, 1000.0, 1000.0, 1000.0];
        softmax_rows(&mut data, 3);
        let r1: f32 = data[..3].iter().sum();
        let r2: f32 = data[3..].iter().sum();
        assert!((r1 - 1.0).abs() < 1e-6);
        assert!((r2 - 1.0).abs() < 1e-6, "stable under large inputs");
        assert!(data[2] > data[1] && data[1] > data[0]);
    }
}
