//! Rust-side task generators — mirrors of `python/compile/tasks.py`.
//!
//! The evaluation datasets shipped in `artifacts/datasets/` are generated
//! by python (they must match the training distribution exactly); these
//! generators exist for *workload scaling*: Tab. 8's dataset-size sweep,
//! property tests, and bench harnesses need arbitrarily many fresh
//! clean/corrupt pairs without touching python. The shared vocabulary and
//! token groups come from `artifacts/vocab.json`, and
//! `tests::mirrors_python_templates` pins the template structure against
//! the exported datasets.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::model::Example;
use crate::util::json::Json;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Vocab {
    pub tokens: Vec<String>,
    pub pad: usize,
    pub bos: usize,
    pub seq_len: usize,
    pub names: Vec<usize>,
    pub args: Vec<usize>,
    pub funcs: Vec<usize>,
    pub digits: Vec<usize>,
    pub words: BTreeMap<String, usize>,
}

impl Vocab {
    pub fn load() -> Result<Vocab> {
        let path = crate::artifacts_root().join("vocab.json");
        let j = Json::parse_file(&path).context("loading vocab.json (run `make artifacts`)")?;
        let g = j.get("groups")?;
        let words = g
            .get("words")?
            .as_obj()?
            .iter()
            .map(|(k, v)| Ok((k.clone(), v.as_usize()?)))
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(Vocab {
            tokens: j
                .get("vocab")?
                .as_arr()?
                .iter()
                .map(|t| Ok(t.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            pad: j.get("pad")?.as_usize()?,
            bos: j.get("bos")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            names: g.get("names")?.usize_vec()?,
            args: g.get("args")?.usize_vec()?,
            funcs: g.get("funcs")?.usize_vec()?,
            digits: g.get("digits")?.usize_vec()?,
            words,
        })
    }

    pub fn size(&self) -> usize {
        self.tokens.len()
    }

    fn w(&self, word: &str) -> usize {
        self.words[word]
    }

    fn pad_to(&self, mut toks: Vec<usize>) -> Vec<usize> {
        debug_assert!(toks.len() <= self.seq_len);
        toks.resize(self.seq_len, self.pad);
        toks
    }

    // ---- generators (templates identical to tasks.py) ---------------------

    /// IOI: "when X and Y went to the store , S gave a gift to" -> the
    /// non-duplicated name. The duplicated subject S is uniformly either
    /// X or Y (ABBA/BABA mix — without this the answer is position-
    /// predictable and patching finds nothing). ABC corruption replaces
    /// the duplicate with a third name C. Mirrors tasks.py exactly.
    pub fn gen_ioi(&self, rng: &mut Rng) -> Example {
        let picks = rng.choose_distinct(self.names.len(), 3);
        let (na, nb, nc) = (self.names[picks[0]], self.names[picks[1]], self.names[picks[2]]);
        let (subj, ans) = if rng.below(2) == 0 { (na, nb) } else { (nb, na) };
        let head = vec![self.bos, self.w("when"), na, self.w("and"), nb];
        let mid = vec![self.w("went"), self.w("to"), self.w("the"), self.w("store"), self.w(",")];
        let tail = vec![self.w("gave"), self.w("a"), self.w("gift"), self.w("to")];
        let mut clean = head.clone();
        clean.extend(&mid);
        clean.push(subj);
        clean.extend(&tail);
        let mut corrupt = head;
        corrupt.extend(&mid);
        corrupt.push(nc);
        corrupt.extend(&tail);
        let pos = clean.len() - 1;
        Example {
            clean: self.pad_to(clean),
            corrupt: self.pad_to(corrupt),
            pos,
            ans: vec![(ans, 1.0)],
            dis: vec![(subj, 1.0)],
            label: ans,
        }
    }

    /// Greater-Than: "the war lasted from year 17 D to year 17" -> digit > D.
    pub fn gen_greater_than(&self, rng: &mut Rng) -> Example {
        let d = 2 + rng.below(7); // 2..=8
        let pre = vec![
            self.bos, self.w("the"), self.w("war"), self.w("lasted"),
            self.w("from"), self.w("year"), self.w("17"),
        ];
        let post = vec![self.w("to"), self.w("year"), self.w("17")];
        let mut clean = pre.clone();
        clean.push(self.digits[d]);
        clean.extend(&post);
        let mut corrupt = pre;
        corrupt.push(self.digits[0]);
        corrupt.extend(&post);
        let pos = clean.len() - 1;
        let greater: Vec<usize> = ((d + 1)..10).map(|k| self.digits[k]).collect();
        let lesseq: Vec<usize> = (0..=d).map(|k| self.digits[k]).collect();
        let gw = 1.0 / greater.len() as f32;
        let lw = 1.0 / lesseq.len() as f32;
        let label = greater[rng.below(greater.len())];
        Example {
            clean: self.pad_to(clean),
            corrupt: self.pad_to(corrupt),
            pos,
            ans: greater.into_iter().map(|t| (t, gw)).collect(),
            dis: lesseq.into_iter().map(|t| (t, lw)).collect(),
            label,
        }
    }

    /// Docstring: "def F ( A1 , A2 , A3 ) : param A1 : param A2 : param" -> A3.
    pub fn gen_docstring(&self, rng: &mut Rng) -> Example {
        let f = self.funcs[rng.below(self.funcs.len())];
        let picks = rng.choose_distinct(self.args.len(), 6);
        let a: Vec<usize> = picks[..3].iter().map(|&i| self.args[i]).collect();
        let b: Vec<usize> = picks[3..].iter().map(|&i| self.args[i]).collect();
        let stub = |args: &[usize]| -> Vec<usize> {
            vec![
                self.bos, self.w("def"), f, self.w("("), args[0], self.w(","),
                args[1], self.w(","), args[2], self.w(")"), self.w(":"),
                self.w("param"), a[0], self.w(":"), self.w("param"), a[1],
                self.w(":"), self.w("param"),
            ]
        };
        let clean = stub(&a);
        let corrupt = stub(&b);
        let pos = clean.len() - 1;
        Example {
            clean: self.pad_to(clean),
            corrupt: self.pad_to(corrupt),
            pos,
            ans: vec![(a[2], 1.0)],
            dis: vec![(a[0], 1.0)],
            label: a[2],
        }
    }

    pub fn generate(&self, task: &str, rng: &mut Rng) -> Result<Example> {
        Ok(match task {
            "ioi" => self.gen_ioi(rng),
            "greater_than" => self.gen_greater_than(rng),
            "docstring" => self.gen_docstring(rng),
            _ => bail!("unknown task '{task}'"),
        })
    }

    pub fn make_dataset(&self, task: &str, n: usize, seed: u64) -> Result<Vec<Example>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| self.generate(task, &mut rng)).collect()
    }
}

pub const TASKS: [&str; 3] = ["ioi", "greater_than", "docstring"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Dataset;

    fn vocab() -> Option<Vocab> {
        Vocab::load().ok()
    }

    #[test]
    fn generators_produce_valid_examples() {
        let Some(v) = vocab() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rng = Rng::new(0);
        for task in TASKS {
            for _ in 0..100 {
                let e = v.generate(task, &mut rng).unwrap();
                assert_eq!(e.clean.len(), v.seq_len);
                assert_eq!(e.corrupt.len(), v.seq_len);
                assert!(e.pos < v.seq_len);
                assert!(e.clean[..=e.pos].iter().all(|&t| t != v.pad));
                let ws: f32 = e.ans.iter().map(|&(_, w)| w).sum();
                assert!((ws - 1.0).abs() < 1e-5);
                let ndiff = e.clean.iter().zip(&e.corrupt).filter(|(a, b)| a != b).count();
                assert!((1..=3).contains(&ndiff), "{task} contrast is minimal");
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let Some(v) = vocab() else { return };
        let a = v.make_dataset("ioi", 8, 9).unwrap();
        let b = v.make_dataset("ioi", 8, 9).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.clean, y.clean);
            assert_eq!(x.corrupt, y.corrupt);
        }
    }

    #[test]
    fn mirrors_python_templates() {
        // The python-exported datasets and the Rust generators must share
        // template structure: same prompt length (pre-padding), same
        // positions of clean/corrupt divergence, same answer position.
        let Some(v) = vocab() else { return };
        for task in TASKS {
            let Ok(d) = Dataset::by_task(task) else { return };
            let py = &d.examples[0];
            let mut rng = Rng::new(123);
            let rs = v.generate(task, &mut rng).unwrap();
            assert_eq!(py.pos, rs.pos, "{task}: answer position");
            let py_diff: Vec<usize> = py
                .clean
                .iter()
                .zip(&py.corrupt)
                .enumerate()
                .filter(|(_, (a, b))| a != b)
                .map(|(i, _)| i)
                .collect();
            let rs_diff: Vec<usize> = rs
                .clean
                .iter()
                .zip(&rs.corrupt)
                .enumerate()
                .filter(|(_, (a, b))| a != b)
                .map(|(i, _)| i)
                .collect();
            assert_eq!(py_diff, rs_diff, "{task}: corruption positions");
            // fixed template tokens match exactly
            for i in 0..py.pos {
                if !py_diff.contains(&i) {
                    let py_is_slot = v.names.contains(&py.clean[i])
                        || v.args.contains(&py.clean[i])
                        || v.funcs.contains(&py.clean[i])
                        || v.digits.contains(&py.clean[i]);
                    if !py_is_slot {
                        assert_eq!(py.clean[i], rs.clean[i], "{task}: template token {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn greater_than_sets_cover_digits() {
        let Some(v) = vocab() else { return };
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let e = v.gen_greater_than(&mut rng);
            assert_eq!(e.ans.len() + e.dis.len(), 10, "partition of digits");
        }
    }
}
