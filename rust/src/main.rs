//! `pahq` — the coordinator CLI: a thin flag-parsing shell over the
//! typed [`pahq::api`] facade.
//!
//! Every subcommand parses its flags into a validated spec
//! ([`RunSpec::from_cli`] / [`MatrixSpec::from_cli`]) and launches it
//! through [`api::run`] / [`api::matrix`] — the same two entry points
//! the experiment harness, the integration tests, and library embedders
//! use — so a CLI invocation and the equivalent builder chain produce
//! identical records by construction. Help text is generated from the
//! same spec builders ([`pahq::api::help`]), so it cannot drift from
//! the flags the parsers accept.
//!
//! Subcommands:
//!   run         one circuit-discovery run (model/task/method/tau/metric);
//!               every run emits a machine-readable RunRecord JSON
//!   matrix      the full method x policy x task grid as one work-stealing
//!               job queue with cross-run reuse; emits a matrix.json
//!               manifest plus one RunRecord per cell, resumable
//!   table N     regenerate paper Table N (1..8)
//!   figure N    regenerate paper Figure N (1, 3, 4)
//!   all         regenerate every table and figure
//!   groundtruth compute/cache the FP32 reference circuit
//!   sim         DES runtime/memory prediction for a method on real arches
//!   bench       deterministic perf snapshot (sweep hot path, packed
//!               memory, word-parallel packed-kernel throughput) for
//!               CI's perf gate — see scripts/bench_gate.py
//!   store       inspect (`ls`) / garbage-collect (`gc`) the durable
//!               content-addressed artifact store backing --store disk
//!   serve       multi-client discovery daemon: RunSpec/MatrixSpec frames
//!               in, streamed progress + RunRecord frames out, one hot
//!               artifact store across requests (docs/serve_protocol.md)
//!   load        scenario-driven load/latency harness: drives a live
//!               `pahq serve` daemon (or the in-process run path) from a
//!               named preset and emits a schema'd load_snapshot.json
//!               that CI's load-gate diffs (scripts/bench_gate.py --load)
//!   lint        in-repo static analysis: panic-surface ratchets,
//!               concurrency hygiene (poison handling, lock order,
//!               spawn discipline), doc/schema drift; emits a schema'd
//!               findings JSON that CI's static-analysis job gates on
//!   info        model/artifact inventory
//!   help        generated overview; `pahq help <sub>` / `--help` for flags

use std::hint::black_box;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use pahq::acdc::sweep::SyntheticSurface;
use pahq::acdc::{self, Candidate, FnScorer, SweepMode};
use pahq::api::{self, help, MatrixSpec, MethodKind, RunSpec, Substrate};
use pahq::discovery::{self, RunRecord};
use pahq::experiments;
use pahq::gpu_sim::memory::{memory_model, MethodKind as SimMethod};
use pahq::gpu_sim::{CostModel, RealArch};
use pahq::metrics::Objective;
use pahq::model::{Graph, Manifest};
use pahq::patching::{PatchMask, PatchedForward};
use pahq::quant::{BF16, Format, FP4_E2M1, FP8_E4M3};
use pahq::report::{human_bytes, mmss, results_dir, Table};
use pahq::scheduler::{predict_run, predict_sweep, StreamConfig};
use pahq::tensor::{self, QTensor};
use pahq::util::cli::Args;
use pahq::util::json::{obj, Json};
use pahq::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    if cmd == "help" {
        let topic = args.positional.get(1).map(String::as_str);
        match topic.and_then(help::subcommand) {
            Some(h) => print!("{h}"),
            None => print!("{}", help::usage()),
        }
        return Ok(());
    }
    if args.flag("help") {
        if let Some(h) = help::subcommand(cmd) {
            print!("{h}");
            return Ok(());
        }
    }
    match cmd {
        "run" => cmd_run(&args),
        "matrix" => cmd_matrix(&args),
        "table" => cmd_table(&args),
        "figure" => cmd_figure(&args),
        "all" => experiments::run_all(args.flag("quick")),
        "sweep" => experiments::sweep_scaling(args.flag("quick"), args.u64_or("seed", 0)?),
        "groundtruth" => cmd_groundtruth(&args),
        "sim" => cmd_sim(&args),
        "bench" => cmd_bench(&args),
        "store" => cmd_store(&args),
        "serve" => cmd_serve(&args),
        "load" => cmd_load(&args),
        "lint" => cmd_lint(&args),
        "info" => cmd_info(),
        _ => {
            print!("{}", help::usage());
            Ok(())
        }
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let spec = RunSpec::from_cli(args)?;
    println!(
        "discovering circuit: {} / {} / {} / {} / tau={} / {} / sweep={}",
        spec.model,
        spec.task,
        spec.method.discovery_name(),
        spec.policy,
        spec.tau,
        spec.objective.label(),
        spec.sweep
    );

    let (rec, session) = api::run_with_session(&spec)?;

    println!(
        "\ncircuit: {} / {} edges kept ({} evals, {:.1}s wall, {:.1}s in PJRT)",
        rec.n_kept, rec.n_edges, rec.n_evals, rec.wall_seconds, rec.pjrt_seconds,
    );
    println!("final metric damage: {:.4}", rec.final_metric);
    println!("kept-set hash: {}", rec.kept_hash);

    // simulated (paper-scale) vs measured (this process) memory, side by
    // side: the packed planes + cache make the low-precision savings real
    // bytes, not billed estimates.
    if let Some(sim) = rec.sim_bytes {
        println!(
            "memory (simulated, {} @ paper scale): {:.2} GB",
            spec.model,
            sim as f64 / 1e9
        );
    }
    match &session {
        None => println!("(synthetic substrate: no engine memory / edge labels to report)"),
        Some(session) => {
            let fp = session.engine.measured_footprint();
            let fp32_ref = session.engine.measured_fp32_footprint();
            let planes = fp
                .weight_planes
                .iter()
                .map(|(n, b)| format!("{n} {}", human_bytes(*b)))
                .collect::<Vec<_>>()
                .join(" + ");
            // a batched run replicates planes + cache once per pool
            // worker; the measured line reports one engine and says so
            let replica_note = match spec.sweep {
                SweepMode::Batched { workers } if workers > 1 => {
                    format!(" per engine (x{workers} pool replicas)")
                }
                _ => String::new(),
            };
            println!(
                "memory (measured, {}): planes [{planes}] + cache {} = {}{replica_note}",
                fp.method,
                human_bytes(fp.act_cache),
                human_bytes(fp.total()),
            );
            let saved = 100.0 * (1.0 - fp.total() as f64 / fp32_ref.total() as f64);
            println!(
                "memory (measured, acdc-fp32 same session): {} ({})",
                human_bytes(fp32_ref.total()),
                if fp.total() < fp32_ref.total() {
                    format!("packed saves {saved:.1}%")
                } else {
                    "no packed saving at fp32".to_string()
                },
            );

            let kept = session.last_kept().unwrap_or(&[]).to_vec();
            let labels = discovery::kept_labels(&session.engine, &kept);
            println!("\nkept edges (first 40):");
            for l in labels.iter().take(40) {
                println!("  {l}");
            }
            if labels.len() > 40 {
                println!("  ... and {} more", labels.len() - 40);
            }
        }
    }

    // ground-truth comparison (computed by api::run unless --no-faith)
    if let Some(f) = &rec.faithfulness {
        println!(
            "\nvs FP32 ground truth: TPR={:.3} FPR={:.3} acc={:.3}",
            f.tpr, f.fpr, f.accuracy
        );
    }

    if let Some(path) = spec.sink.path_for(&rec) {
        println!("run record: {}", path.display());
    }
    Ok(())
}

fn cmd_matrix(args: &Args) -> Result<()> {
    let spec = MatrixSpec::from_cli(args)?;
    let outcome = api::matrix(&spec)?;
    if outcome.manifest.aggregate.n_error > 0 {
        bail!(
            "{} matrix cell(s) failed — see {}",
            outcome.manifest.aggregate.n_error,
            outcome.manifest_path.display()
        );
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let n: usize = args
        .positional
        .get(1)
        .context("usage: pahq table <1..8>")?
        .parse()?;
    // one-pass rollups from a matrix manifest instead of N sequential runs
    if let Some(p) = args.get("from") {
        let path = std::path::Path::new(p);
        return match n {
            2 => experiments::table2_from_manifest(path),
            6 => experiments::table6_from_manifest(path),
            7 => experiments::table7_from_manifest(path),
            _ => bail!("--from renders tables 2, 6, and 7 (got {n})"),
        };
    }
    let quick = args.flag("quick");
    match n {
        1 => experiments::table1(quick),
        2 => experiments::table2(quick),
        3 => experiments::table3(quick),
        4 => experiments::table4(quick),
        5 => experiments::table5(quick),
        6 => experiments::table6(quick),
        7 => experiments::table7(quick),
        8 => experiments::table8(quick),
        _ => bail!("no table {n} in the paper (1..8)"),
    }
}

fn cmd_figure(args: &Args) -> Result<()> {
    let n: usize = args
        .positional
        .get(1)
        .context("usage: pahq figure <1|3|4>")?
        .parse()?;
    let quick = args.flag("quick");
    match n {
        1 => experiments::figure1(quick),
        3 => experiments::figure3(quick),
        4 => experiments::figure4(quick),
        _ => bail!("figure {n} is not an evaluation figure (1, 3, 4)"),
    }
}

fn cmd_groundtruth(args: &Args) -> Result<()> {
    let model = args.get_or("model", api::DEFAULT_MODEL);
    let task = args.get_or("task", api::DEFAULT_TASK);
    let obj: Objective = args.get_or("metric", "kl").parse()?;
    let mut engine = PatchedForward::new(model, task)?;
    let gt = pahq::eval::ground_truth(&mut engine, model, task, obj)?;
    println!(
        "{model}/{task}: {} edges, tau*={:.5}, |C*|={} ({:.1}%)",
        gt.delta.len(),
        gt.tau_star,
        gt.n_members(),
        100.0 * gt.n_members() as f64 / gt.delta.len() as f64
    );
    let mut top: Vec<(usize, f32)> = gt.delta.iter().copied().enumerate().collect();
    top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top edges by FP32 ΔL:");
    for (i, d) in top.into_iter().take(15) {
        println!("  {:<28} {d:.5}", gt.edges[i].label(&engine.graph));
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let arch_name = args.get_or("arch", "gpt2");
    let arch = RealArch::by_name(arch_name).context("unknown arch")?;
    // every method spelling is accepted: the baselines verify through
    // the same ACDC sweep under their (PAHQ-default) policy, so they
    // share PAHQ's DES cost model — said out loud rather than silently
    let method: MethodKind = args.get_or("method", "pahq").parse()?;
    let kind = method.sim_kind();
    if method.discovery_name() != "acdc" {
        println!(
            "sim: '{method}' orders edges by attribution, then verifies through the \
             ACDC sweep under the PAHQ policy — predicting that sweep ({kind:?})"
        );
    }
    let streams = match args.get_or("streams", "full") {
        "full" => StreamConfig::FULL,
        "load" => StreamConfig::LOAD_ONLY,
        "split" => StreamConfig::SPLIT_ONLY,
        _ => StreamConfig::NONE,
    };
    let cost = CostModel::default();
    let p = predict_run(&arch, &cost, kind, streams);
    let mem = memory_model(&arch, kind);
    println!("arch {}: {} edges", arch.name, p.n_edges);
    println!(
        "{:?} {streams:?}: per-edge {:.0} µs, total {} (m:s), mem {:.2} GB",
        kind,
        p.per_edge_us,
        mmss(p.total_minutes),
        mem.total_gb()
    );
    println!(
        "stream utilization: load {:.2}, low {:.2}",
        p.load_utilization, p.low_utilization
    );
    let sweep = args.sweep_mode()?;
    if let SweepMode::Batched { .. } = sweep {
        let removal = args.f64_or("removal-rate", 0.9)?;
        let sp = predict_sweep(&arch, &cost, kind, streams, sweep, removal);
        println!(
            "sweep {}: eval inflation {:.2}x, total {} (m:s), speedup {:.2}x",
            sweep.label(),
            sp.eval_inflation,
            mmss(sp.total_minutes),
            sp.speedup
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// pahq bench — the deterministic perf snapshot CI's perf gate diffs

/// The fixed spin emulating one evaluation's PJRT cost on the synthetic
/// sweep hot path. Shared by the scorer AND the calibration loop so the
/// gate's wall-time normalization cancels machine speed out
/// (`scripts/bench_gate.py` compares `wall / n_evals / calibration`).
#[inline(never)]
fn bench_spin(x: f32) -> f32 {
    let mut y = x + 1.0;
    for _ in 0..100_000u32 {
        y = y * 1.000_000_1 + 1e-7;
    }
    y
}

/// One packed fused-kernel measurement: best-of-reps wall for the
/// word-parallel `add_assign_packed` and for the retained scalar
/// reference (`decode_range_into_scalar` + f32 add) on the same
/// payload. Returns `(wide_bytes_per_sec, scalar_bytes_per_sec)`;
/// bytes count the decoded f32 output (`n * 4`) so formats are
/// comparable, and the wide/scalar ratio is machine-independent —
/// that ratio is what the perf gate pins (scripts/bench_gate.py).
fn bench_packed_kernel(ks: &[f32], fmt: Format, reps: usize) -> (f64, f64) {
    let n = ks.len();
    let qt = QTensor::from_slice(&[n], ks, fmt);
    let mut dst = ks.to_vec();
    let mut scratch = vec![0.0f32; n];
    let mut best_wide = f64::MAX;
    let mut best_scalar = f64::MAX;
    for _ in 0..reps {
        let t = Instant::now();
        tensor::add_assign_packed(&mut dst, &qt);
        best_wide = best_wide.min(t.elapsed().as_secs_f64());
        let t = Instant::now();
        qt.decode_range_into_scalar(0, &mut scratch);
        tensor::add_assign(&mut dst, &scratch);
        best_scalar = best_scalar.min(t.elapsed().as_secs_f64());
    }
    black_box(&dst);
    let bytes = (n * 4) as f64;
    (bytes / best_wide, bytes / best_scalar)
}

/// The attn-4l-shaped synthetic sweep plan (mirrors
/// `benches/hot_paths.rs`): reverse-topological channels, PAHQ-style
/// `hi` overrides.
fn bench_plan(graph: &Graph) -> (usize, Vec<Vec<Candidate>>) {
    let channels = graph.channels();
    let mut order = channels.clone();
    order.reverse();
    let mut plan = Vec::new();
    for ch in order {
        let ci = channels.iter().position(|c| *c == ch).unwrap();
        let mut srcs = graph.sources(ch);
        srcs.reverse();
        plan.push(
            srcs.into_iter()
                .map(|src| Candidate { chan: ci, src, hi: Some(src) })
                .collect(),
        );
    }
    (channels.len(), plan)
}

fn cmd_bench(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let reps = if quick { 3 } else { 10 };
    let t_total = Instant::now();

    // calibration: per-spin seconds on this machine, same code path as
    // the scorer below
    let calib_iters = if quick { 64 } else { 256 };
    let t0 = Instant::now();
    for i in 0..calib_iters {
        black_box(bench_spin(i as f32));
    }
    let calibration_seconds = t0.elapsed().as_secs_f64() / calib_iters as f64;

    // sweep hot path: the batched engine against its serial reference on
    // a deterministic damage surface with a realistic per-eval cost
    let graph = Graph { n_layer: 4, n_head: 8, has_mlp: true };
    let (n_channels, plan) = bench_plan(&graph);
    let surface = SyntheticSurface::new(7, 0.001);
    let score = |m: &PatchMask, cand: Option<&Candidate>| {
        let d = surface.damage(m, cand);
        let y = bench_spin(d);
        d + (black_box(y) - y)
    };
    let tau = 0.9f32; // ~90% removal, the chain-speculation regime

    // deterministic measured-memory probe: real packed payload bytes of
    // a PAHQ-shaped session (fp8 attention plane + bf16 other plane +
    // fp32 corrupt cache) vs the fp32 baseline
    let n_w = 1usize << 20;
    let mut rng = Rng::new(9);
    let ws: Vec<f32> = (0..n_w).map(|_| rng.normal()).collect();
    let w_p8 = QTensor::from_slice(&[n_w], &ws, FP8_E4M3).bytes();
    let w_p16 = QTensor::from_slice(&[n_w], &ws, BF16).bytes();
    let w_fp32 = n_w * 4;
    let cache_elems = graph.n_nodes() * 4 * 16 * 64; // nodes x B*S*D
    let cs: Vec<f32> = (0..cache_elems).map(|_| rng.normal()).collect();
    let cache_fp32 = QTensor::from_slice(&[cache_elems], &cs, pahq::quant::FP32).bytes();
    let cache_fp8 = QTensor::from_slice(&[cache_elems], &cs, FP8_E4M3).bytes();
    let measured_weight_bytes = w_p8 + w_p16;
    let measured_total = measured_weight_bytes + cache_fp32;

    let mut table = Table::new(
        "bench: synthetic sweep hot path (deterministic surface + fixed spin)",
        &["mode", "wall (s)", "evals", "per-eval (µs)", "normalized", "kept hash"],
    );
    let mut sweep_rows: Vec<Json> = Vec::new();
    let mut records: Vec<RunRecord> = Vec::new();
    let mut serial_hash = String::new();
    for workers in [1usize, 2, 4, 8] {
        let mode = if workers == 1 {
            SweepMode::Serial
        } else {
            SweepMode::Batched { workers }
        };
        let mut best = f64::MAX;
        let mut outcome = None;
        for _ in 0..reps {
            let mut scorer = FnScorer { score, workers };
            let t = Instant::now();
            let out = pahq::acdc::sweep::sweep(
                &mut scorer,
                n_channels,
                &plan,
                tau,
                false,
                mode,
            )?;
            best = best.min(t.elapsed().as_secs_f64());
            outcome = Some(out);
        }
        let out = outcome.unwrap();
        let channels = graph.channels();
        let kept: Vec<bool> = graph
            .edges()
            .iter()
            .map(|e| {
                let ci = channels.iter().position(|c| *c == e.dst).unwrap();
                !out.removed.get(ci, e.src)
            })
            .collect();
        let hash = discovery::kept_hash(&kept);
        if workers == 1 {
            serial_hash = hash.clone();
        }
        let per_eval = best / out.n_evals as f64;
        let normalized = per_eval / calibration_seconds;
        table.row(vec![
            mode.label(),
            format!("{best:.3}"),
            out.n_evals.to_string(),
            format!("{:.1}", per_eval * 1e6),
            format!("{normalized:.3}"),
            hash.clone(),
        ]);
        sweep_rows.push(obj(vec![
            ("mode", Json::from(mode.label())),
            ("workers", Json::from(workers)),
            ("wall_seconds", Json::from(best)),
            ("n_evals", Json::from(out.n_evals)),
            ("normalized_per_eval", Json::from(normalized)),
            ("kept_hash", Json::from(hash.clone())),
        ]));
        records.push(RunRecord {
            schema_version: discovery::SCHEMA_VERSION,
            method: "acdc".into(),
            policy: "synthetic".into(),
            model: "synthetic-attn4l".into(),
            task: "synthetic-surface".into(),
            objective: "synthetic".into(),
            tau: tau as f64,
            sweep: mode.label(),
            workers,
            n_edges: kept.len(),
            n_kept: kept.iter().filter(|&&k| k).count(),
            kept_hash: hash,
            n_evals: out.n_evals,
            final_metric: out.final_metric as f64,
            wall_seconds: best,
            pjrt_seconds: 0.0,
            sim_bytes: None,
            measured_weight_bytes,
            measured_cache_bytes: cache_fp32,
            faithfulness: None,
            cache: None,
            trace: Vec::new(),
        });
    }
    table.print();
    for r in &records {
        assert_eq!(
            r.kept_hash, serial_hash,
            "batched sweep diverged from serial on the bench surface"
        );
    }

    // DES predictions (deterministic): the simulated headline numbers
    let arch = RealArch::by_name("gpt2").unwrap();
    let cost = CostModel::default();
    let p_pahq = predict_run(&arch, &cost, SimMethod::Pahq, StreamConfig::FULL);
    let p_acdc = predict_run(&arch, &cost, SimMethod::AcdcFp32, StreamConfig::NONE);
    let sp8 = predict_sweep(
        &arch,
        &cost,
        SimMethod::Pahq,
        StreamConfig::FULL,
        SweepMode::Batched { workers: 8 },
        0.9,
    );
    println!(
        "\nmemory probe: fp32 {} vs packed planes {} + fp32 cache {} = {}",
        human_bytes(w_fp32 + cache_fp32),
        human_bytes(measured_weight_bytes),
        human_bytes(cache_fp32),
        human_bytes(measured_total),
    );
    println!(
        "DES gpt2: pahq {:.0} µs/edge vs acdc {:.0} µs/edge; batched[8] speedup {:.2}x",
        p_pahq.per_edge_us, p_acdc.per_edge_us, sp8.speedup
    );

    // packed-kernel probe: word-parallel fused decode-accumulate vs the
    // retained scalar reference on the gated fp8/fp4 formats
    let ks: Vec<f32> = (0..1usize << 18).map(|_| rng.normal()).collect();
    let kernel_reps = if quick { 5 } else { 20 };
    let (fp8_bps, fp8_scalar_bps) = bench_packed_kernel(&ks, FP8_E4M3, kernel_reps);
    let (fp4_bps, fp4_scalar_bps) = bench_packed_kernel(&ks, FP4_E2M1, kernel_reps);
    let fp8_speedup = fp8_bps / fp8_scalar_bps;
    let fp4_speedup = fp4_bps / fp4_scalar_bps;
    println!(
        "packed kernels: fp8 {:.2} GB/s ({fp8_speedup:.2}x scalar), fp4 {:.2} GB/s \
         ({fp4_speedup:.2}x scalar)",
        fp8_bps / 1e9,
        fp4_bps / 1e9
    );

    // real-engine record when the artifacts are built (optional: CI has
    // no artifacts, the local dev loop does) — the one launch path,
    // pinned to the real substrate so a synthetic stand-in can never
    // sneak into the perf-gate snapshot
    let spec = RunSpec::builder("redwood2l-sim", "ioi")
        .method(MethodKind::Pahq)
        .tau(0.01)
        .substrate(Substrate::Real)
        .build()?;
    match api::run(&spec) {
        Ok(rec) => {
            println!(
                "real engine: acdc/pahq-8b kept {} of {} ({:.1}s)",
                rec.n_kept, rec.n_edges, rec.wall_seconds
            );
            records.push(rec);
        }
        Err(e) => println!("(real engine section skipped: {e})"),
    }

    let snapshot = obj(vec![
        ("kind", Json::from("bench_snapshot")),
        ("schema_version", Json::from(discovery::SCHEMA_VERSION)),
        ("quick", Json::from(quick)),
        ("calibration_seconds", Json::from(calibration_seconds)),
        ("sweep_hot_path", Json::Arr(sweep_rows)),
        (
            "memory",
            obj(vec![
                ("weights_fp32_bytes", Json::from(w_fp32)),
                ("weights_packed_bytes", Json::from(measured_weight_bytes)),
                ("cache_fp32_bytes", Json::from(cache_fp32)),
                ("cache_fp8_bytes", Json::from(cache_fp8)),
                ("measured_total_bytes", Json::from(measured_total)),
            ]),
        ),
        (
            "des",
            obj(vec![
                ("arch", Json::from("gpt2")),
                ("pahq_per_edge_us", Json::from(p_pahq.per_edge_us)),
                ("acdc_per_edge_us", Json::from(p_acdc.per_edge_us)),
                ("batched8_speedup", Json::from(sp8.speedup)),
            ]),
        ),
        (
            "packed_kernels",
            obj(vec![
                ("elems", Json::from(ks.len())),
                ("fp8_bytes_per_sec", Json::from(fp8_bps)),
                ("fp8_scalar_bytes_per_sec", Json::from(fp8_scalar_bps)),
                ("fp8_speedup", Json::from(fp8_speedup)),
                ("fp4_bytes_per_sec", Json::from(fp4_bps)),
                ("fp4_scalar_bytes_per_sec", Json::from(fp4_scalar_bps)),
                ("fp4_speedup", Json::from(fp4_speedup)),
            ]),
        ),
        (
            "records",
            Json::Arr(records.iter().map(|r| r.to_json()).collect()),
        ),
    ]);
    let path = match args.json_path() {
        Some(p) => PathBuf::from(p),
        None => results_dir().join("bench.json"),
    };
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    std::fs::write(&path, snapshot.dump())
        .with_context(|| format!("writing {}", path.display()))?;
    println!(
        "\nbench snapshot: {} ({:.1}s total)",
        path.display(),
        t_total.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `pahq store <ls|gc>` — inspect or garbage-collect the durable
/// content-addressed artifact store that `--store disk` runs share.
/// `gc` is generation-based: opening the store bumps its generation,
/// and only entries last used more than `--gc-horizon` generations ago
/// are collected, so concurrent grids never collect each other's live
/// artifacts.
fn cmd_store(args: &Args) -> Result<()> {
    let action = args.positional.get(1).map(String::as_str).unwrap_or("ls");
    let spec: api::StoreSpec = args.get_or("store", "disk").parse()?;
    let root = match spec.disk_root() {
        Some(root) => root.clone(),
        None => bail!("store: `pahq store` operates on the disk store (--store disk[:PATH])"),
    };
    let store = pahq::matrix::cache::DiskStore::open(&root)?;
    match action {
        "ls" => {
            let entries = store.entries();
            println!(
                "store {} — generation {}, {} entries (schema v{}, codec v{})",
                root.display(),
                store.generation(),
                entries.len(),
                pahq::matrix::cache::STORE_SCHEMA_VERSION,
                pahq::matrix::cache::CODEC_VERSION,
            );
            for (addr, e) in entries {
                println!(
                    "  {}  {:>10}  used gen {:<5} {}",
                    &addr[..8],
                    human_bytes(e.bytes),
                    e.last_used,
                    e.key
                );
            }
            Ok(())
        }
        "gc" => {
            let horizon = args.u64_or("gc-horizon", 2)?;
            if horizon == 0 {
                bail!("gc_horizon: must be >= 1 (a zero horizon could collect live artifacts)");
            }
            let r = store.gc(horizon)?;
            println!(
                "gc horizon {horizon}: {} live, {} collected ({} freed), {} missing row(s) \
                 dropped",
                r.live,
                r.collected,
                human_bytes(r.bytes_freed),
                r.missing
            );
            Ok(())
        }
        other => bail!("store: unknown action '{other}' (expected ls | gc)"),
    }
}

/// `pahq serve` — run the multi-client discovery daemon until a client
/// sends a `shutdown` frame. The wire protocol is documented in
/// `docs/serve_protocol.md`; `examples/serve_client.rs` is a complete
/// client.
fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = pahq::serve::ServeConfig {
        addr: args.get_or("addr", "127.0.0.1:7341").to_string(),
        ..Default::default()
    };
    if let Some(w) = args.usize_opt("workers")? {
        cfg.workers = w;
    }
    if let Some(s) = args.get("store") {
        cfg.store = s.parse()?;
    }
    if args.get("gc-horizon").is_some() {
        let horizon = args.u64_or("gc-horizon", 0)?;
        match &mut cfg.store {
            api::StoreSpec::Disk { gc_horizon, .. } => *gc_horizon = Some(horizon),
            api::StoreSpec::Memory => {
                bail!("gc_horizon: only meaningful with --store disk[:PATH]")
            }
        }
    }
    pahq::serve::serve(cfg)
}

/// `pahq load` — drive a scenario against a live daemon (`--addr`) or
/// the in-process run path (`--direct`) and emit a schema'd
/// `load_snapshot.json`. Scenarios are named presets with
/// `name[:key=val,...]` overrides; see `pahq help load`.
fn cmd_load(args: &Args) -> Result<()> {
    let mut scenario: pahq::load::Scenario = args.get_or("scenario", "smoke").parse()?;
    if let Some(w) = args.usize_opt("workers")? {
        scenario = scenario.with_clients(w)?;
    }
    let mode = match (args.get("addr"), args.flag("direct")) {
        (Some(_), true) => bail!("mode: --addr and --direct are mutually exclusive"),
        (Some(addr), false) => pahq::load::LoadMode::Wire {
            addr: addr.to_string(),
            shutdown: args.flag("shutdown"),
        },
        (None, true) => {
            if args.flag("shutdown") {
                bail!("shutdown: only meaningful with --addr (wire mode)");
            }
            pahq::load::LoadMode::Direct
        }
        (None, false) => bail!("mode: pass --addr HOST:PORT (wire) or --direct (in-process)"),
    };
    let cfg = pahq::load::LoadConfig {
        scenario,
        mode,
        json: args.json_path().map(PathBuf::from),
    };
    pahq::load::run(&cfg).map(|_| ())
}

fn cmd_lint(args: &Args) -> Result<()> {
    use pahq::lint::{self, Severity};

    let root = match args.get("root") {
        Some(r) => PathBuf::from(r),
        None => lint::repo_root()?,
    };
    let paths = args.list("paths").unwrap_or_default();
    let report = if paths.is_empty() {
        lint::lint_repo(&root)?
    } else {
        lint::lint_paths(&root, &paths)?
    };
    let baseline_path = root.join(lint::BASELINE_NAME);

    if args.flag("update-baseline") {
        if !paths.is_empty() {
            bail!("lint: --update-baseline needs a full-repo pass; drop --paths");
        }
        let baseline = lint::Baseline::from_report(&report);
        baseline.save(&baseline_path)?;
        let sites: usize = baseline.rules.values().flat_map(|m| m.values()).sum();
        println!(
            "lint: wrote {} ({} ratcheted sites across {} files scanned)",
            baseline_path.display(),
            sites,
            report.files_scanned
        );
        return Ok(());
    }

    let baseline = lint::Baseline::load(&baseline_path)?;
    let summary = lint::gate(&report, &baseline);
    if let Some(p) = args.json_path() {
        let body = lint::report_json(&report, &summary).dump() + "\n";
        std::fs::write(p, body).with_context(|| format!("lint: writing {p}"))?;
    }

    for f in &report.findings {
        if f.severity == Severity::Error && !f.suppressed {
            println!("error[{}] {}:{}: {}", f.rule, f.file, f.line, f.message);
        }
    }
    for row in &summary.rows {
        if row.count > row.baseline {
            println!(
                "regression[{}] {}: {} findings vs baseline {} — fix them or justify with \
                 a pragma (see docs/lint_rules.md)",
                row.rule, row.file, row.count, row.baseline
            );
        }
    }
    println!(
        "lint: {} files, {} findings ({} suppressed), {} errors, {} ratchet regressions, \
         {} stale baseline rows",
        report.files_scanned,
        report.findings.len(),
        summary.suppressed,
        summary.errors,
        summary.regressions,
        summary.stale
    );
    if !summary.passed() {
        bail!(
            "lint: gate failed ({} errors, {} ratchet regressions)",
            summary.errors,
            summary.regressions
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let root = pahq::artifacts_root();
    println!("artifacts root: {}", root.display());
    let mut t = Table::new(
        "models",
        &["name", "layers", "heads", "d_model", "mlp", "params", "edges", "artifacts"],
    );
    for name in experiments::BASE_MODELS.iter().chain(experiments::SCALE_MODELS.iter()) {
        match Manifest::by_name(name) {
            Ok(m) => {
                let g = pahq::model::Graph::from_manifest(&m);
                t.row(vec![
                    m.name.clone(),
                    m.n_layer.to_string(),
                    m.n_head.to_string(),
                    m.d_model.to_string(),
                    if m.has_mlp() { "yes".into() } else { "no".into() },
                    m.n_params.to_string(),
                    g.n_edges().to_string(),
                    m.artifacts.len().to_string(),
                ]);
            }
            Err(_) => t.row(vec![
                name.to_string(),
                "-".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into(),
                "missing".into(),
            ]),
        }
    }
    t.print();
    println!("\nDES cost model: {:?}", CostModel::default());
    println!("discovery methods: {}", discovery::METHOD_NAMES.join(", "));
    println!("paper thresholds: {:?}", acdc::paper_thresholds());
    Ok(())
}
