//! `pahq` — the coordinator CLI.
//!
//! Subcommands:
//!   run         one circuit-discovery run (model/task/method/tau/metric)
//!   table N     regenerate paper Table N (1..8)
//!   figure N    regenerate paper Figure N (1, 3, 4)
//!   all         regenerate every table and figure
//!   groundtruth compute/cache the FP32 reference circuit
//!   sim         DES runtime/memory prediction for a method on real arches
//!   info        model/artifact inventory

use anyhow::{bail, Context, Result};

use pahq::acdc::{self, AcdcConfig, EnginePool, SweepMode};
use pahq::eval;
use pahq::experiments;
use pahq::gpu_sim::memory::{memory_model, MethodKind};
use pahq::gpu_sim::{CostModel, RealArch};
use pahq::metrics::Objective;
use pahq::model::Manifest;
use pahq::patching::{PatchedForward, Policy};
use pahq::quant::Format;
use pahq::report::{human_bytes, mmss, Table};
use pahq::scheduler::{predict_run, predict_sweep, StreamConfig};
use pahq::util::cli::Args;

const USAGE: &str = "\
pahq — PAHQ: accelerating automated circuit discovery (paper reproduction)

USAGE:
  pahq run [--model M] [--task T] [--method acdc|rtn-q|pahq] [--tau X]
           [--metric kl|task] [--bits 4|8|16] [--trace]
           [--sweep serial|batched] [--workers N]
  pahq table <1|2|3|4|5|6|7|8> [--quick]
  pahq figure <1|3|4> [--quick]
  pahq all [--quick]
  pahq groundtruth [--model M] [--task T] [--metric kl|task]
  pahq sim [--arch gpt2] [--method acdc|rtn-q|pahq] [--streams full|load|split|none]
           [--sweep serial|batched] [--workers N] [--removal-rate P]
  pahq sweep [--quick]
  pahq info

Defaults: --model gpt2s-sim --task ioi --method pahq --tau 0.01 --metric kl
          --sweep serial --workers <available parallelism>
Models: redwood2l-sim attn4l-sim gpt2s-sim gpt2m-sim gpt2l-sim gpt2xl-sim
Tasks:  ioi greater_than docstring
";

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "run" => cmd_run(&args),
        "table" => cmd_table(&args),
        "figure" => cmd_figure(&args),
        "all" => experiments::run_all(args.flag("quick")),
        "sweep" => experiments::sweep_scaling(args.flag("quick")),
        "groundtruth" => cmd_groundtruth(&args),
        "sim" => cmd_sim(&args),
        "info" => cmd_info(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn objective(args: &Args) -> Result<Objective> {
    Ok(match args.get_or("metric", "kl") {
        "kl" => Objective::Kl,
        "task" => Objective::LogitDiff,
        other => bail!("unknown metric '{other}' (kl|task)"),
    })
}

fn policy(args: &Args) -> Result<Policy> {
    let bits = args.usize_or("bits", 8)? as u32;
    Ok(match args.get_or("method", "pahq") {
        "acdc" => Policy::fp32(),
        "rtn-q" | "rtn" => Policy::rtn(Format::by_bits(bits)),
        "pahq" => Policy::pahq(Format::by_bits(bits)),
        other => bail!("unknown method '{other}' (acdc|rtn-q|pahq)"),
    })
}

/// Simulated-memory method of a session policy — derived from the policy
/// itself so the mapping cannot drift from [`policy`].
fn method_kind(pol: &Policy) -> MethodKind {
    if pol.attn_low.is_passthrough() && pol.other.is_passthrough() {
        MethodKind::AcdcFp32
    } else if pol.quantize_logits {
        MethodKind::RtnQ
    } else {
        MethodKind::Pahq
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let model = args.get_or("model", "gpt2s-sim");
    let task = args.get_or("task", "ioi");
    let tau = args.f64_or("tau", 0.01)? as f32;
    let obj = objective(args)?;
    let pol = policy(args)?;
    let sweep = args.sweep_mode()?;
    println!(
        "discovering circuit: {model} / {task} / {} / tau={tau} / {} / sweep={}",
        pol.name,
        obj.label(),
        sweep.label()
    );

    let mut engine = PatchedForward::new(model, task)?;
    engine.set_session(pol.clone())?;
    let mut cfg = AcdcConfig::new(tau, obj);
    cfg.record_trace = args.flag("trace");
    cfg.sweep = sweep;
    let (res, pjrt) = match sweep {
        SweepMode::Batched { workers } if workers > 1 => {
            // replicate the engine per worker; the reduction keeps the
            // result bit-identical to the serial sweep
            let mut pool = EnginePool::new(model, task, &pol, workers, obj)?;
            let res = acdc::run_pool(&mut pool, &cfg)?;
            let pjrt = pool.pjrt_time();
            (res, pjrt)
        }
        _ => {
            let res = acdc::run(&mut engine, &cfg)?;
            (res, engine.pjrt_time())
        }
    };

    println!(
        "\ncircuit: {} / {} edges kept ({} evals, {:.1}s wall, {:.1}s in PJRT)",
        res.n_kept,
        engine.graph.n_edges(),
        res.n_evals,
        res.wall.as_secs_f64(),
        pjrt.as_secs_f64(),
    );
    println!("final metric damage: {:.4}", res.final_metric);

    // simulated (paper-scale) vs measured (this process) memory, side by
    // side: the packed planes + cache make the low-precision savings real
    // bytes, not billed estimates.
    let fp = engine.measured_footprint();
    let fp32_ref = engine.measured_fp32_footprint();
    if let Some(arch) = RealArch::by_name(model) {
        println!(
            "memory (simulated, {} @ paper scale): {:.2} GB",
            arch.name,
            memory_model(&arch, method_kind(&pol)).total_gb()
        );
    }
    let planes = fp
        .weight_planes
        .iter()
        .map(|(n, b)| format!("{n} {}", human_bytes(*b)))
        .collect::<Vec<_>>()
        .join(" + ");
    // a batched run replicates planes + cache once per pool worker; the
    // measured line reports one engine and says so
    let replica_note = match sweep {
        SweepMode::Batched { workers } if workers > 1 => {
            format!(" per engine (x{workers} pool replicas)")
        }
        _ => String::new(),
    };
    println!(
        "memory (measured, {}): planes [{planes}] + cache {} = {}{replica_note}",
        fp.method,
        human_bytes(fp.act_cache),
        human_bytes(fp.total()),
    );
    let saved = 100.0 * (1.0 - fp.total() as f64 / fp32_ref.total() as f64);
    println!(
        "memory (measured, acdc-fp32 same session): {} ({})",
        human_bytes(fp32_ref.total()),
        if fp.total() < fp32_ref.total() {
            format!("packed saves {saved:.1}%")
        } else {
            "no packed saving at fp32".to_string()
        },
    );

    let labels = acdc::kept_edge_labels(&engine, &res);
    println!("\nkept edges (first 40):");
    for l in labels.iter().take(40) {
        println!("  {l}");
    }
    if labels.len() > 40 {
        println!("  ... and {} more", labels.len() - 40);
    }
    // compare against ground truth when available
    engine.set_session(Policy::fp32())?;
    if let Ok(gt) = eval::ground_truth(&mut engine, model, task, obj) {
        let p = pahq::metrics::confusion(&res.kept, &gt.member);
        println!(
            "\nvs FP32 ground truth (|C*|={}): TPR={:.3} FPR={:.3} acc={:.3}",
            gt.n_members(),
            p.tpr,
            p.fpr,
            pahq::metrics::edge_accuracy(&res.kept, &gt.member)
        );
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let n: usize = args
        .positional
        .get(1)
        .context("usage: pahq table <1..8>")?
        .parse()?;
    let quick = args.flag("quick");
    match n {
        1 => experiments::table1(quick),
        2 => experiments::table2(quick),
        3 => experiments::table3(quick),
        4 => experiments::table4(quick),
        5 => experiments::table5(quick),
        6 => experiments::table6(quick),
        7 => experiments::table7(quick),
        8 => experiments::table8(quick),
        _ => bail!("no table {n} in the paper (1..8)"),
    }
}

fn cmd_figure(args: &Args) -> Result<()> {
    let n: usize = args
        .positional
        .get(1)
        .context("usage: pahq figure <1|3|4>")?
        .parse()?;
    let quick = args.flag("quick");
    match n {
        1 => experiments::figure1(quick),
        3 => experiments::figure3(quick),
        4 => experiments::figure4(quick),
        _ => bail!("figure {n} is not an evaluation figure (1, 3, 4)"),
    }
}

fn cmd_groundtruth(args: &Args) -> Result<()> {
    let model = args.get_or("model", "gpt2s-sim");
    let task = args.get_or("task", "ioi");
    let obj = objective(args)?;
    let mut engine = PatchedForward::new(model, task)?;
    let gt = eval::ground_truth(&mut engine, model, task, obj)?;
    println!(
        "{model}/{task}: {} edges, tau*={:.5}, |C*|={} ({:.1}%)",
        gt.delta.len(),
        gt.tau_star,
        gt.n_members(),
        100.0 * gt.n_members() as f64 / gt.delta.len() as f64
    );
    let mut top: Vec<(usize, f32)> = gt.delta.iter().copied().enumerate().collect();
    top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top edges by FP32 ΔL:");
    for (i, d) in top.into_iter().take(15) {
        println!("  {:<28} {d:.5}", gt.edges[i].label(&engine.graph));
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let arch_name = args.get_or("arch", "gpt2");
    let arch = RealArch::by_name(arch_name).context("unknown arch")?;
    let method = match args.get_or("method", "pahq") {
        "acdc" => MethodKind::AcdcFp32,
        "rtn-q" | "rtn" => MethodKind::RtnQ,
        _ => MethodKind::Pahq,
    };
    let streams = match args.get_or("streams", "full") {
        "full" => StreamConfig::FULL,
        "load" => StreamConfig::LOAD_ONLY,
        "split" => StreamConfig::SPLIT_ONLY,
        _ => StreamConfig::NONE,
    };
    let cost = CostModel::default();
    let p = predict_run(&arch, &cost, method, streams);
    let mem = memory_model(&arch, method);
    println!("arch {}: {} edges", arch.name, p.n_edges);
    println!(
        "{:?} {streams:?}: per-edge {:.0} µs, total {} (m:s), mem {:.2} GB",
        method,
        p.per_edge_us,
        mmss(p.total_minutes),
        mem.total_gb()
    );
    println!(
        "stream utilization: load {:.2}, low {:.2}",
        p.load_utilization, p.low_utilization
    );
    let sweep = args.sweep_mode()?;
    if let SweepMode::Batched { .. } = sweep {
        let removal = args.f64_or("removal-rate", 0.9)?;
        let sp = predict_sweep(&arch, &cost, method, streams, sweep, removal);
        println!(
            "sweep {}: eval inflation {:.2}x, total {} (m:s), speedup {:.2}x",
            sweep.label(),
            sp.eval_inflation,
            mmss(sp.total_minutes),
            sp.speedup
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let root = pahq::artifacts_root();
    println!("artifacts root: {}", root.display());
    let mut t = Table::new(
        "models",
        &["name", "layers", "heads", "d_model", "mlp", "params", "edges", "artifacts"],
    );
    for name in experiments::BASE_MODELS.iter().chain(experiments::SCALE_MODELS.iter()) {
        match Manifest::by_name(name) {
            Ok(m) => {
                let g = pahq::model::Graph::from_manifest(&m);
                t.row(vec![
                    m.name.clone(),
                    m.n_layer.to_string(),
                    m.n_head.to_string(),
                    m.d_model.to_string(),
                    if m.has_mlp() { "yes".into() } else { "no".into() },
                    m.n_params.to_string(),
                    g.n_edges().to_string(),
                    m.artifacts.len().to_string(),
                ]);
            }
            Err(_) => t.row(vec![
                name.to_string(),
                "-".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into(),
                "missing".into(),
            ]),
        }
    }
    t.print();
    println!("\nDES cost model: {:?}", CostModel::default());
    println!("paper thresholds: {:?}", acdc::paper_thresholds());
    Ok(())
}
