//! Property tests for the unified discovery pipeline.
//!
//! Two layers:
//!
//! - **Synthetic** (always runs): the attribution-ordered candidate
//!   plans the baselines produce — a single score-sorted group, unlike
//!   ACDC's reverse-topological channel groups — must keep the sweep
//!   engine's serial-vs-batched bit-identity, per method-shaped
//!   ordering.
//! - **Engine-backed** (skips when `make artifacts` has not run): every
//!   registered method through the public [`pahq::api::run`] entry
//!   point on a validated spec — batched kept set identical to serial,
//!   and (the paper's core claim) the kept-edge set identical under the
//!   FP32 and PAHQ policies on the seeded synthetic tasks.

use pahq::acdc::sweep::{self, Candidate, FnScorer, SweepMode, SweepOutcome, SyntheticSurface};
use pahq::api::{self, RunSpec, Substrate};
use pahq::discovery::{self, DiscoveryConfig, RunRecord, Task};
use pahq::metrics::Objective;
use pahq::model::{Channel, Graph};
use pahq::patching::{PatchMask, Policy};
use pahq::quant::FP8_E4M3;
use pahq::util::rng::Rng;

/// Every engine-backed test launches through the one public entry
/// point, pinned to the real substrate so "artifacts missing" skips
/// instead of silently running the synthetic surface.
fn discover(method: &str, task: &Task, cfg: &DiscoveryConfig) -> anyhow::Result<RunRecord> {
    let spec = RunSpec::builder(&task.model, &task.task)
        .method(method.parse()?)
        .policy(cfg.policy.clone())
        .tau(cfg.tau)
        .objective(cfg.objective)
        .sweep(cfg.sweep)
        .substrate(Substrate::Real)
        .build()?;
    api::run(&spec)
}

/// Deterministic pseudo-attribution scores shaped like each baseline's
/// output: EAP/SP/EP score per edge; HISP scores per source node with
/// non-head sources pinned to +max (never pruned cheaply).
fn method_scores(flavor: &str, g: &Graph, rng: &mut Rng) -> Vec<f32> {
    let edges = g.edges();
    match flavor {
        "hisp" => {
            let node_scores: Vec<f32> = (0..g.n_nodes()).map(|_| rng.f32()).collect();
            let max = node_scores.iter().copied().fold(0.0f32, f32::max).max(1e-9);
            edges
                .iter()
                .map(|e| match g.node_kind(e.src) {
                    pahq::model::graph::NodeKind::Head { .. } => node_scores[e.src],
                    _ => max * 2.0,
                })
                .collect()
        }
        // sp scores repeat per source node (the gate), eap/ep are per edge
        "sp" => {
            let gates: Vec<f32> = (0..g.n_nodes()).map(|_| rng.f32()).collect();
            edges.iter().map(|e| gates[e.src]).collect()
        }
        _ => edges.iter().map(|_| rng.f32()).collect(),
    }
}

/// The ordered single-group plan `discovery::ordered_plan` builds:
/// ascending score, index tiebreak, optional PAHQ-style `hi`.
fn ordered_plan(
    g: &Graph,
    channels: &[Channel],
    scores: &[f32],
    pahq_like: bool,
) -> Vec<Vec<Candidate>> {
    let edges = g.edges();
    let mut order: Vec<usize> = (0..edges.len()).collect();
    order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]).then(a.cmp(&b)));
    vec![order
        .into_iter()
        .map(|i| Candidate {
            chan: channels.iter().position(|c| *c == edges[i].dst).unwrap(),
            src: edges[i].src,
            hi: if pahq_like { Some(edges[i].src) } else { None },
        })
        .collect()]
}

fn assert_same(a: &SweepOutcome, b: &SweepOutcome, what: &str) {
    assert_eq!(a.removed, b.removed, "{what}: removed mask");
    assert_eq!(a.removed_count, b.removed_count, "{what}: removed count");
    assert_eq!(
        a.final_metric.to_bits(),
        b.final_metric.to_bits(),
        "{what}: final metric bits"
    );
    assert_eq!(a.trace.len(), b.trace.len(), "{what}: trace length");
    for (x, y) in a.trace.iter().zip(&b.trace) {
        assert_eq!(x.removed, y.removed, "{what}: decision");
        assert_eq!(x.metric.to_bits(), y.metric.to_bits(), "{what}: metric bits");
    }
}

#[test]
fn ordered_plans_keep_serial_batched_bit_identity_per_method() {
    // Every baseline's plan shape (score-sorted single group) through
    // the shared sweep engine: batched must equal serial bit for bit,
    // with and without the PAHQ hi override, across random graphs and
    // thresholds.
    let mut rng = Rng::new(4242);
    for round in 0..8u64 {
        let g = Graph {
            n_layer: 1 + rng.below(5),
            n_head: 1 + rng.below(10),
            has_mlp: rng.below(2) == 1,
        };
        let channels = g.channels();
        let surface = SyntheticSurface::new(9000 + round, 0.01);
        let tau = [0.05f32, 0.3, 0.7, 0.95][rng.below(4)];
        for flavor in ["eap", "hisp", "sp", "edge-pruning"] {
            let scores = method_scores(flavor, &g, &mut rng);
            let pahq_like = round % 2 == 0;
            let plan = ordered_plan(&g, &channels, &scores, pahq_like);
            let score = |m: &PatchMask, c: Option<&Candidate>| surface.damage(m, c);
            let run = |mode: SweepMode, workers: usize| {
                let mut scorer = FnScorer { score, workers };
                sweep::sweep(&mut scorer, channels.len(), &plan, tau, true, mode).unwrap()
            };
            let serial = run(SweepMode::Serial, 1);
            // one decision per edge regardless of ordering
            assert_eq!(serial.trace.len(), g.n_edges(), "{flavor}: all edges decided");
            for workers in [2usize, 4, 8] {
                let batched = run(SweepMode::Batched { workers }, workers);
                assert_same(
                    &serial,
                    &batched,
                    &format!("round {round} {flavor} workers {workers} tau {tau}"),
                );
                assert!(batched.n_evals >= serial.n_evals, "{flavor}: rescoring only adds");
            }
        }
    }
}

#[test]
fn plans_cover_every_edge_exactly_once() {
    let mut rng = Rng::new(777);
    for _ in 0..10 {
        let g = Graph {
            n_layer: 1 + rng.below(4),
            n_head: 1 + rng.below(8),
            has_mlp: rng.below(2) == 1,
        };
        let channels = g.channels();
        let scores = method_scores("eap", &g, &mut rng);
        let plan = ordered_plan(&g, &channels, &scores, true);
        let mut seen: Vec<(usize, usize)> =
            plan.iter().flatten().map(|c| (c.chan, c.src)).collect();
        assert_eq!(seen.len(), g.n_edges());
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), g.n_edges(), "no duplicate candidates");
    }
}

// ---------------------------------------------------------------------------
// Engine-backed properties (skip when artifacts are not built)

fn engine_task() -> Task {
    Task::new("redwood2l-sim", "ioi")
}

#[test]
fn every_method_serial_equals_batched_on_engine() {
    let task = engine_task();
    for method in discovery::METHOD_NAMES {
        let cfg = DiscoveryConfig::new(0.01, Objective::Kl, Policy::pahq(FP8_E4M3));
        let serial = match discover(method, &task, &cfg) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("skipping {method}: {e}");
                continue;
            }
        };
        let batched =
            discover(method, &task, &cfg.clone().with_sweep(SweepMode::Batched { workers: 3 }))
                .unwrap();
        assert_eq!(serial.kept_hash, batched.kept_hash, "{method}: kept set");
        assert_eq!(serial.n_kept, batched.n_kept, "{method}: kept count");
        assert_eq!(
            serial.final_metric.to_bits(),
            batched.final_metric.to_bits(),
            "{method}: final metric bits"
        );
        assert!(batched.n_evals >= serial.n_evals, "{method}: rescoring only adds evals");
        assert_eq!(serial.n_edges, batched.n_edges);
    }
}

#[test]
fn baseline_kept_sets_identical_under_fp32_and_pahq() {
    // The paper's integration claim, asserted per baseline on the
    // seeded synthetic tasks: attribution runs at FP32 either way, and
    // PAHQ's mixed-precision verification (investigated source at FP32)
    // reproduces the FP32 verification's kept-edge set.
    let task = engine_task();
    for method in discovery::METHOD_NAMES {
        let fp32_cfg = DiscoveryConfig::new(0.01, Objective::Kl, Policy::fp32());
        let fp32 = match discover(method, &task, &fp32_cfg) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("skipping {method}: {e}");
                continue;
            }
        };
        let pahq_cfg = DiscoveryConfig::new(0.01, Objective::Kl, Policy::pahq(FP8_E4M3));
        let pahq = discover(method, &task, &pahq_cfg).unwrap();
        assert_eq!(
            fp32.kept_hash, pahq.kept_hash,
            "{method}: PAHQ preserves the FP32 kept-edge set ({} vs {} kept)",
            fp32.n_kept, pahq.n_kept
        );
        // and the PAHQ session is measurably smaller
        assert!(
            pahq.measured_weight_bytes < fp32.measured_weight_bytes,
            "{method}: packed planes below fp32"
        );
    }
}

#[test]
fn run_record_from_engine_is_schema_complete() {
    // A record produced by a real engine run has every required field
    // populated (the shape `docs/run_record.schema.json` pins).
    let task = engine_task();
    let cfg = DiscoveryConfig::new(0.01, Objective::Kl, Policy::pahq(FP8_E4M3));
    let rec = match discover("acdc", &task, &cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    assert_eq!(rec.schema_version, discovery::SCHEMA_VERSION);
    assert_eq!(rec.method, "acdc");
    assert_eq!(rec.policy, "pahq-8b");
    assert_eq!(rec.kept_hash.len(), 16);
    assert!(rec.n_kept <= rec.n_edges);
    assert!(rec.n_evals > rec.n_edges, "evals = edges + baseline at least");
    assert!(rec.wall_seconds > 0.0);
    assert!(rec.measured_weight_bytes > 0 && rec.measured_cache_bytes > 0);
    // round-trips through the JSON artifact bit-exactly
    let back = discovery::RunRecord::from_json(&rec.to_json()).unwrap();
    assert_eq!(rec, back);
}
