//! Tests for the `pahq serve` subsystem, pinning the acceptance
//! criteria of the serve PR:
//!
//! - frame-codec round trips, plus corruption tests (truncated,
//!   oversized, bad-checksum, bit-flipped frames are rejected as errors
//!   — never panics, never bogus decodes);
//! - wire round trips for `RunSpec` / `MatrixSpec` payloads, including
//!   rejection of server-owned and unknown keys;
//! - server-vs-`api::run` record bit-identity on the synthetic
//!   substrate (the contract the daemon inherits from matrix cells);
//! - two concurrent clients interleaving on one daemon, with one
//!   client's cancellation never dropping the other's job.

use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

use pahq::api::{self, MatrixSpec, RunSpec, Substrate};
use pahq::discovery::RunRecord;
use pahq::serve::protocol::{
    checksum, decode, encode, encode_payload, Message, HEADER_LEN, MAGIC, MAX_PAYLOAD,
    PROTOCOL_VERSION,
};
use pahq::serve::{FrameReader, ReadEvent, ServeConfig, Server};
use pahq::util::json::Json;

fn quick_spec() -> RunSpec {
    RunSpec::builder("redwood2l-sim", "ioi")
        .method("pahq".parse().unwrap())
        .tau(0.01)
        .substrate(Substrate::Synthetic)
        .build()
        .unwrap()
}

/// The bit-identity fingerprint the matrix contract pins: kept set,
/// eval count, and final metric — not wall times or cache provenance.
fn fingerprint(rec: &RunRecord) -> (String, usize, usize, usize, String) {
    (
        rec.kept_hash.clone(),
        rec.n_evals,
        rec.n_edges,
        rec.n_kept,
        format!("{:.9}", rec.final_metric),
    )
}

// ---------------------------------------------------------------------------
// Frame codec

#[test]
fn every_message_variant_round_trips_through_the_codec() {
    let variants = vec![
        Message::Hello { protocol: PROTOCOL_VERSION },
        Message::HelloAck { protocol: PROTOCOL_VERSION, record_schema: 1 },
        Message::SubmitRun { spec: quick_spec() },
        Message::SubmitMatrix { spec: MatrixSpec::builder().build().unwrap() },
        Message::Accepted { job_id: 3, cells: 8 },
        Message::Cancel { job_id: 3 },
        Message::CancelAck { job_id: 3, dropped: 5 },
        Message::Progress { job_id: 3, done: 2, total: 8, cell: "c".into(), coalesced: 1 },
        Message::Record { job_id: 3, cell: "c".into(), record: Json::parse("{\"x\":1}").unwrap() },
        Message::CellError { job_id: 3, cell: "c".into(), error: "boom".into() },
        Message::Done { job_id: 3, ok: 6, failed: 1, cancelled: 1 },
        Message::Error {
            code: pahq::serve::ErrorCode::InvalidSpec,
            message: "policy: nope".into(),
        },
        Message::Shutdown,
        Message::ShutdownAck,
    ];
    for msg in variants {
        let bytes = encode(&msg).unwrap();
        let (back, used) = decode(&bytes).unwrap().expect("complete frame decodes");
        assert_eq!(used, bytes.len(), "{}", msg.kind());
        // Message carries specs without PartialEq; canonical JSON is the
        // equality the wire cares about anyway
        assert_eq!(back.to_json().dump(), msg.to_json().dump(), "{}", msg.kind());
    }
}

#[test]
fn every_truncation_is_incomplete_not_an_error() {
    let bytes = encode(&Message::Accepted { job_id: 42, cells: 7 }).unwrap();
    for cut in 0..bytes.len() {
        match decode(&bytes[..cut]) {
            Ok(None) => {}
            Ok(Some(_)) => panic!("prefix of {cut} bytes decoded as a whole frame"),
            Err(e) => panic!("prefix of {cut} bytes rejected as corrupt: {e}"),
        }
    }
}

#[test]
fn corrupt_frames_are_errors_not_panics() {
    let good = encode(&Message::Cancel { job_id: 1 }).unwrap();

    // bad magic — rejected from the very first byte
    let mut bad = good.clone();
    bad[0] = b'X';
    assert!(decode(&bad).is_err(), "bad magic");
    assert!(decode(&bad[..1]).is_err(), "bad magic, one byte in");

    // unsupported version
    let mut bad = good.clone();
    bad[4] = 99;
    assert!(decode(&bad).is_err(), "bad version");

    // nonzero reserved bytes
    let mut bad = good.clone();
    bad[6] = 1;
    assert!(decode(&bad).is_err(), "reserved bytes");

    // oversized length field: rejected from the header alone, without
    // waiting to buffer the forged payload
    let mut bad = good.clone();
    bad[8..12].copy_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
    assert!(decode(&bad[..HEADER_LEN]).is_err(), "oversized length");

    // every single-bit flip in the payload breaks the checksum
    for byte in HEADER_LEN..good.len() {
        let mut bad = good.clone();
        bad[byte] ^= 0x40;
        assert!(decode(&bad).is_err(), "flipped payload byte {byte} slipped through");
    }

    // valid frame, nonsense payloads: error, not panic
    for payload in [&b"not json"[..], b"[1,2]", br#"{"type":"nope"}"#, &[0xff, 0xfe][..]] {
        let framed = encode_payload(payload).unwrap();
        assert!(decode(&framed).is_err(), "payload {payload:?}");
    }

    assert!(encode_payload(&vec![0u8; MAX_PAYLOAD + 1]).is_err(), "oversized encode");
}

#[test]
fn checksum_is_fnv1a64_and_position_sensitive() {
    assert_eq!(checksum(b""), 0xcbf2_9ce4_8422_2325);
    assert_ne!(checksum(b"ab"), checksum(b"ba"));
    assert_eq!(MAGIC, *b"PQWF");
}

// ---------------------------------------------------------------------------
// Wire spec payloads

#[test]
fn run_spec_wire_round_trips_and_rejects_bad_keys() {
    let spec = quick_spec();
    assert_eq!(RunSpec::from_wire(&spec.to_wire()).unwrap(), spec);

    // minimal payload: builder defaults fill everything else
    let min = RunSpec::from_wire(
        &Json::parse(r#"{"model": "redwood2l-sim", "task": "ioi"}"#).unwrap(),
    )
    .unwrap();
    assert_eq!(min.policy.name, "pahq-8b");

    let err = RunSpec::from_wire(
        &Json::parse(r#"{"model": "redwood2l-sim", "task": "ioi", "store": "disk"}"#).unwrap(),
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("server-owned"), "{err}");

    let err = RunSpec::from_wire(
        &Json::parse(r#"{"model": "redwood2l-sim", "task": "ioi", "tua": 0.1}"#).unwrap(),
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("unknown key 'tua'"), "{err}");

    // a wire seed must be an exact non-negative integer
    for bad in ["-1", "0.5", "1e300"] {
        let payload = format!(r#"{{"model": "redwood2l-sim", "task": "ioi", "seed": {bad}}}"#);
        assert!(
            RunSpec::from_wire(&Json::parse(&payload).unwrap()).is_err(),
            "seed {bad} accepted"
        );
    }
}

#[test]
fn matrix_spec_wire_round_trips_and_rejects_bad_keys() {
    let spec = MatrixSpec::builder().build().unwrap();
    let back = MatrixSpec::from_wire(&spec.to_wire()).unwrap();
    let ids = |s: &MatrixSpec| {
        s.cells().iter().map(|c| c.id()).collect::<Vec<_>>()
    };
    assert_eq!(ids(&spec), ids(&back), "wire round trip changed the grid");

    // `{}` is the acceptance grid
    assert!(!ids(&MatrixSpec::from_wire(&Json::parse("{}").unwrap()).unwrap()).is_empty());

    let err = MatrixSpec::from_wire(&Json::parse(r#"{"workers": 4}"#).unwrap())
        .unwrap_err()
        .to_string();
    assert!(err.contains("server-owned"), "{err}");
    assert!(MatrixSpec::from_wire(&Json::parse(r#"{"modles": []}"#).unwrap()).is_err());
}

// ---------------------------------------------------------------------------
// Live-server helpers

struct TestClient {
    stream: TcpStream,
    reader: FrameReader,
}

impl TestClient {
    fn connect(addr: std::net::SocketAddr) -> TestClient {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        TestClient { stream, reader: FrameReader::new() }
    }

    fn send(&mut self, msg: &Message) {
        self.stream.write_all(&encode(msg).unwrap()).unwrap();
    }

    fn recv(&mut self) -> Message {
        let deadline = std::time::Instant::now() + Duration::from_secs(60);
        loop {
            match self.reader.next(&mut self.stream).unwrap() {
                ReadEvent::Frame(msg) => return msg,
                ReadEvent::Pending => {
                    assert!(std::time::Instant::now() < deadline, "no frame within 60s");
                }
                ReadEvent::Eof => panic!("server closed the connection"),
            }
        }
    }

    fn handshake(&mut self) {
        self.send(&Message::Hello { protocol: PROTOCOL_VERSION });
        let ack = self.recv();
        assert!(matches!(ack, Message::HelloAck { .. }), "got '{}'", ack.kind());
    }

    fn submit_accepted(&mut self, msg: &Message) -> (u64, usize) {
        self.send(msg);
        match self.recv() {
            Message::Accepted { job_id, cells } => (job_id, cells),
            other => panic!("expected accepted, got '{}'", other.kind()),
        }
    }

    /// Drain one job to `done`, returning (records, ok, failed, cancelled).
    fn stream_to_done(&mut self, job_id: u64) -> (Vec<RunRecord>, usize, usize, usize) {
        let mut records = Vec::new();
        loop {
            match self.recv() {
                Message::Record { job_id: j, record, .. } => {
                    assert_eq!(j, job_id);
                    records.push(RunRecord::from_json(&record).expect("schema-valid record"));
                }
                Message::Progress { job_id: j, .. } | Message::CancelAck { job_id: j, .. } => {
                    assert_eq!(j, job_id);
                }
                Message::CellError { error, .. } => panic!("cell failed: {error}"),
                Message::Done { job_id: j, ok, failed, cancelled } => {
                    assert_eq!(j, job_id);
                    return (records, ok, failed, cancelled);
                }
                other => panic!("unexpected '{}'", other.kind()),
            }
        }
    }
}

fn start_server(workers: usize) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers,
        ..Default::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        server.run().unwrap();
    });
    (addr, handle)
}

fn shutdown(addr: std::net::SocketAddr) {
    let mut c = TestClient::connect(addr);
    c.handshake();
    c.send(&Message::Shutdown);
    loop {
        if matches!(c.recv(), Message::ShutdownAck) {
            break;
        }
    }
}

// ---------------------------------------------------------------------------
// Live-server behavior

#[test]
fn served_record_is_bit_identical_to_standalone_api_run() {
    let spec = quick_spec();
    let standalone = api::run(&spec).unwrap();

    let (addr, handle) = start_server(2);
    let mut client = TestClient::connect(addr);
    client.handshake();
    let (job_id, cells) = client.submit_accepted(&Message::SubmitRun { spec: quick_spec() });
    assert_eq!(cells, 1);
    let (records, ok, failed, cancelled) = client.stream_to_done(job_id);
    assert_eq!((ok, failed, cancelled), (1, 0, 0));
    assert_eq!(records.len(), 1);
    assert_eq!(
        fingerprint(&records[0]),
        fingerprint(&standalone),
        "served record diverged from api::run"
    );

    // second submission on the same connection: the shared store is warm
    // now, and the kept set must not move (the matrix cache contract)
    let (job2, _) = client.submit_accepted(&Message::SubmitRun { spec: quick_spec() });
    let (records2, ..) = client.stream_to_done(job2);
    assert_eq!(fingerprint(&records2[0]), fingerprint(&standalone), "warm cache moved the circuit");

    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn two_clients_interleave_and_one_cancel_never_drops_the_other() {
    let (addr, handle) = start_server(2);

    let mut a = TestClient::connect(addr);
    let mut b = TestClient::connect(addr);
    a.handshake();
    b.handshake();

    // client A submits the full default grid (many cells), then cancels;
    // client B submits one run that must complete untouched
    let (job_a, cells_a) =
        a.submit_accepted(&Message::SubmitMatrix { spec: MatrixSpec::builder().build().unwrap() });
    assert!(cells_a > 2, "grid should have several cells, got {cells_a}");
    a.send(&Message::Cancel { job_id: job_a });
    let (job_b, _) = b.submit_accepted(&Message::SubmitRun { spec: quick_spec() });
    let (_, ok_a, failed_a, cancelled_a) = a.stream_to_done(job_a);
    assert_eq!(ok_a + failed_a + cancelled_a, cells_a, "every cell accounted for");
    assert!(cancelled_a > 0, "cancel arrived first; some cells must have been dropped");
    assert_eq!(failed_a, 0);

    let (records_b, ok_b, failed_b, cancelled_b) = b.stream_to_done(job_b);
    assert_eq!(
        (ok_b, failed_b, cancelled_b),
        (1, 0, 0),
        "client A's cancel must never touch client B's job"
    );
    assert_eq!(records_b.len(), 1);

    // job ids are server-global and distinct across connections
    assert_ne!(job_a, job_b);

    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn protocol_violations_are_reported_per_session() {
    let (addr, handle) = start_server(1);

    // submit before hello is a protocol error
    let mut c = TestClient::connect(addr);
    c.send(&Message::SubmitRun { spec: quick_spec() });
    match c.recv() {
        Message::Error { code, .. } => assert_eq!(code, pahq::serve::ErrorCode::Protocol),
        other => panic!("expected error, got '{}'", other.kind()),
    }

    // cancelling another client's (or an unknown) job is refused
    let mut c = TestClient::connect(addr);
    c.handshake();
    c.send(&Message::Cancel { job_id: 999 });
    match c.recv() {
        Message::Error { code, .. } => assert_eq!(code, pahq::serve::ErrorCode::UnknownJob),
        other => panic!("expected error, got '{}'", other.kind()),
    }

    shutdown(addr);
    handle.join().unwrap();
}
