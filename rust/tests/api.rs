//! Tests for the typed `pahq::api` facade: FromStr/Display round trips
//! for every spec enum, cross-field spec validation (every invalid
//! combination produces an error naming the offending field), and
//! CLI-vs-API identity (a record produced via `api::run` is byte
//! identical to one from the `pahq run` flag path with the same seed).

use pahq::acdc::SweepMode;
use pahq::api::{self, MatrixSpec, MethodKind, OutputSink, RunSpec, Substrate};
use pahq::discovery::RunRecord;
use pahq::matrix::{self, Cell};
use pahq::metrics::Objective;
use pahq::patching::Policy;
use pahq::quant::Format;
use pahq::util::cli::Args;

fn args(line: &str) -> Args {
    Args::parse(line.split_whitespace().map(String::from))
}

/// A record with its timing fields zeroed — everything else in a
/// deterministic run must be byte-identical across invocations.
fn normalized_dump(mut rec: RunRecord) -> String {
    rec.wall_seconds = 0.0;
    rec.pjrt_seconds = 0.0;
    rec.to_json().dump()
}

// ---------------------------------------------------------------------------
// FromStr / Display round trips

#[test]
fn method_kind_round_trips_and_aliases() {
    for m in MethodKind::ALL {
        assert_eq!(m.to_string().parse::<MethodKind>().unwrap(), m, "{m}");
    }
    assert_eq!("rtn".parse::<MethodKind>().unwrap(), MethodKind::RtnQ);
    assert_eq!("ep".parse::<MethodKind>().unwrap(), MethodKind::EdgePruning);
    let err = "turbo".parse::<MethodKind>().unwrap_err().to_string();
    assert!(err.contains("edge-pruning"), "error lists the spellings: {err}");
}

#[test]
fn policy_round_trips_for_every_constructor() {
    let mut policies = vec![Policy::fp32()];
    for bits in [4u32, 8, 16] {
        policies.push(Policy::rtn(Format::by_bits(bits)));
        policies.push(Policy::pahq(Format::by_bits(bits)));
    }
    for p in policies {
        let back: Policy = p.to_string().parse().unwrap();
        assert_eq!(back, p, "round trip of '{p}'");
    }
    // family spellings resolve at an explicit width
    assert_eq!(Policy::by_name("pahq", 4).unwrap().name, "pahq-4b");
    assert_eq!(Policy::by_name("rtn", 16).unwrap().name, "rtn-q-16b");
    assert_eq!(Policy::by_name("rtn-q", 8).unwrap().name, "rtn-q-8b");
    assert_eq!(Policy::by_name("acdc", 8).unwrap().name, "acdc-fp32");
    assert_eq!(Policy::by_name("pahq-16b", 4).unwrap().name, "pahq-16b");
    // invalid widths and names are loud
    assert!(Policy::by_name("pahq", 7).unwrap_err().to_string().contains("bits:"));
    assert!("turbo".parse::<Policy>().is_err());
    assert!("pahq-3b".parse::<Policy>().is_err());
    // fp32 has no width variants — a bogus suffix must not silently
    // produce a full-width run
    assert!("fp32-99b".parse::<Policy>().is_err());
    assert!("acdc-4b".parse::<Policy>().is_err());
}

#[test]
fn sweep_mode_round_trips() {
    for mode in [
        SweepMode::Serial,
        SweepMode::Batched { workers: 1 },
        SweepMode::Batched { workers: 2 },
        SweepMode::Batched { workers: 7 },
        SweepMode::Batched { workers: 16 },
    ] {
        assert_eq!(mode.to_string().parse::<SweepMode>().unwrap(), mode, "{mode}");
    }
    // the bare spelling defaults the worker count to the machine
    assert!("batched".parse::<SweepMode>().unwrap().workers() >= 1);
    assert!("batched[x]".parse::<SweepMode>().is_err());
    assert!("batched[0]".parse::<SweepMode>().is_err(), "zero workers is loud, not clamped");
    assert!("turbo".parse::<SweepMode>().is_err());
}

#[test]
fn objective_round_trips() {
    for obj in [Objective::Kl, Objective::LogitDiff] {
        assert_eq!(obj.to_string().parse::<Objective>().unwrap(), obj);
    }
    assert_eq!(Objective::SPELLINGS, ["kl", "task"]);
    assert!("speed".parse::<Objective>().is_err());
}

// ---------------------------------------------------------------------------
// Spec validation: every invalid combination names the offending field

fn run_err(build: impl FnOnce() -> anyhow::Result<RunSpec>) -> String {
    build().unwrap_err().to_string()
}

#[test]
fn run_spec_validation_names_the_field() {
    assert!(run_err(|| RunSpec::builder("", "ioi").build()).starts_with("model:"));
    assert!(run_err(|| RunSpec::builder("m", "").build()).starts_with("task:"));
    assert!(run_err(|| RunSpec::builder("m", "t").tau(f32::NAN).build()).starts_with("tau:"));
    assert!(run_err(|| RunSpec::builder("m", "t").tau(-0.5).build()).starts_with("tau:"));
    assert!(run_err(|| RunSpec::builder("m", "t").workers(4).build()).starts_with("workers:"));
    assert!(run_err(|| {
        RunSpec::builder("m", "t").sweep(SweepMode::Batched { workers: 2 }).workers(0).build()
    })
    .starts_with("workers:"));
    assert!(run_err(|| RunSpec::builder("m", "t").bits(7).build()).starts_with("bits:"));
    assert!(run_err(|| RunSpec::builder("m", "t").sp_steps(0).build()).starts_with("sp_steps:"));
    assert!(run_err(|| RunSpec::builder("m", "t").ep_steps(0).build()).starts_with("ep_steps:"));
    // the classic policy-carrying spellings reject a contradicting policy
    let e = run_err(|| {
        RunSpec::builder("m", "t").method(MethodKind::Pahq).policy(Policy::fp32()).build()
    });
    assert!(e.starts_with("policy:"), "{e}");
    let e = run_err(|| {
        RunSpec::builder("m", "t")
            .method(MethodKind::RtnQ)
            .policy(Policy::pahq(Format::by_bits(8)))
            .build()
    });
    assert!(e.starts_with("policy:"), "{e}");
    // acdc is the generic verifier: any explicit policy is fine
    let spec = RunSpec::builder("m", "t")
        .method(MethodKind::Acdc)
        .policy(Policy::pahq(Format::by_bits(8)))
        .build()
        .unwrap();
    assert_eq!(spec.policy.name, "pahq-8b");
    // a hand-mutated spec cannot sneak past validation at launch
    let mut bad = RunSpec::builder("m", "t").build().unwrap();
    bad.tau = f32::INFINITY;
    assert!(api::run(&bad).unwrap_err().to_string().starts_with("tau:"));
}

#[test]
fn run_spec_builder_resolves_implied_policies() {
    let spec = RunSpec::builder("m", "t").build().unwrap();
    assert_eq!(spec.method, MethodKind::Pahq);
    assert_eq!(spec.policy.name, "pahq-8b");
    let spec = RunSpec::builder("m", "t").method(MethodKind::RtnQ).bits(4).build().unwrap();
    assert_eq!(spec.policy.name, "rtn-q-4b");
    let spec = RunSpec::builder("m", "t").method(MethodKind::Acdc).build().unwrap();
    assert_eq!(spec.policy.name, "acdc-fp32");
    let spec = RunSpec::builder("m", "t").method(MethodKind::Hisp).build().unwrap();
    assert_eq!(spec.policy.name, "pahq-8b", "baselines imply the PAHQ policy");
    // workers land in the sweep schedule
    let spec = RunSpec::builder("m", "t")
        .sweep(SweepMode::Batched { workers: 1 })
        .workers(6)
        .build()
        .unwrap();
    assert_eq!(spec.sweep, SweepMode::Batched { workers: 6 });
}

#[test]
fn matrix_spec_validation_names_the_field() {
    let err = |b: api::MatrixSpecBuilder| b.build().unwrap_err().to_string();
    let b = MatrixSpec::builder;
    assert!(err(b().methods(vec![])).starts_with("methods:"));
    let e = err(b().methods(vec![MethodKind::RtnQ]));
    assert!(e.starts_with("methods:") && e.contains("policies"), "{e}");
    assert!(err(b().methods(vec![MethodKind::Pahq])).starts_with("methods:"));
    assert!(
        err(b().methods(vec![MethodKind::Acdc, MethodKind::Acdc])).contains("duplicate"),
        "duplicate methods"
    );
    assert!(err(b().policies(vec![])).starts_with("policies:"));
    assert!(
        err(b().policies(vec![Policy::fp32(), Policy::fp32()])).starts_with("policies:"),
        "duplicate policies collide on record filenames"
    );
    assert!(err(b().models(&[])).starts_with("models:"));
    assert!(err(b().models(&["m".into(), "m".into()])).starts_with("models:"));
    assert!(err(b().tasks(&["".into()])).starts_with("tasks:"));
    assert!(err(b().tau(f32::NAN)).starts_with("tau:"));
    assert!(err(b().workers(0)).starts_with("workers:"));
    assert!(err(b().seed(1 << 54)).starts_with("seed:"));
    // pool workers only mean something under a batched sweep, and zero
    // is loud rather than clamped
    assert!(err(b().pool_workers(2)).starts_with("pool_workers:"));
    assert!(err(b().sweep(SweepMode::Batched { workers: 2 }).pool_workers(0))
        .starts_with("pool_workers:"));
    let spec = b()
        .sweep(SweepMode::Batched { workers: 1 })
        .pool_workers(3)
        .build()
        .unwrap();
    assert_eq!(spec.config().sweep, SweepMode::Batched { workers: 3 });
    // the default grid is the five discovery methods x {fp32, pahq-8b}
    let spec = b().build().unwrap();
    assert_eq!(spec.cells().len(), 5 * 2 * 3);
}

// ---------------------------------------------------------------------------
// CLI flag parsing == typed builder, and record byte-identity

#[test]
fn cli_flags_and_builder_produce_the_same_spec() {
    let parsed = RunSpec::from_cli(&args(
        "run --model synthetic-m --task alpha --method eap --tau 0.25 --metric task \
         --sweep batched --workers 3 --seed 9 --trace --json out.json",
    ))
    .unwrap();
    let built = RunSpec::builder("synthetic-m", "alpha")
        .method(MethodKind::Eap)
        .tau(0.25)
        .objective(Objective::LogitDiff)
        .sweep(SweepMode::Batched { workers: 3 })
        .seed(9)
        .trace(true)
        .faithfulness(Some(false))
        .sink(OutputSink::Path("out.json".into()))
        .build()
        .unwrap();
    assert_eq!(parsed, built);

    // policy family + bits compose; --no-faith clears the default
    let parsed = RunSpec::from_cli(&args(
        "run --method acdc --policy pahq --bits 4 --no-faith",
    ))
    .unwrap();
    assert_eq!(parsed.policy.name, "pahq-4b");
    assert_eq!(parsed.faithfulness, None);
    assert_eq!(parsed.sink, OutputSink::Default);

    // invalid combinations surface the same field-naming errors
    let e = RunSpec::from_cli(&args("run --workers 4")).unwrap_err().to_string();
    assert!(e.starts_with("workers:"), "{e}");
    let e = MatrixSpec::from_cli(&args("matrix --pool-workers 4")).unwrap_err().to_string();
    assert!(e.starts_with("pool_workers:"), "{e}");
    let e = MatrixSpec::from_cli(&args("matrix --methods acdc,rtn-q"))
        .unwrap_err()
        .to_string();
    assert!(e.starts_with("methods:"), "{e}");
}

#[test]
fn run_and_matrix_accept_the_same_sweep_spellings() {
    // `batched[N]` is one spelling, not two: both subcommands parse it
    let r = RunSpec::from_cli(&args("run --sweep batched[4]")).unwrap();
    assert_eq!(r.sweep, SweepMode::Batched { workers: 4 });
    let m = MatrixSpec::from_cli(&args("matrix --sweep batched[4]")).unwrap();
    assert_eq!(m.config().sweep, SweepMode::Batched { workers: 4 });
    // the bare spelling keeps the classic per-cell pool default of 2
    let m = MatrixSpec::from_cli(&args("matrix --sweep batched")).unwrap();
    assert_eq!(m.config().sweep, SweepMode::Batched { workers: 2 });
    // ...and --pool-workers overrides either form
    let m = MatrixSpec::from_cli(&args("matrix --sweep batched[4] --pool-workers 3")).unwrap();
    assert_eq!(m.config().sweep, SweepMode::Batched { workers: 3 });
}

// ---------------------------------------------------------------------------
// StoreSpec: spellings, horizon folding, and resume-schema validation

#[test]
fn store_spec_round_trips_and_rejects_unknown_spellings() {
    use pahq::api::StoreSpec;
    assert_eq!("mem".parse::<StoreSpec>().unwrap(), StoreSpec::Memory);
    assert_eq!("memory".parse::<StoreSpec>().unwrap(), StoreSpec::Memory, "alias");
    assert_eq!(StoreSpec::Memory.to_string(), "mem");
    let d: StoreSpec = "disk:/x/y".parse().unwrap();
    assert_eq!(d, StoreSpec::Disk { root: "/x/y".into(), gc_horizon: None });
    assert_eq!(d.to_string(), "disk:/x/y");
    assert_eq!(d.to_string().parse::<StoreSpec>().unwrap(), d, "display round-trips");
    let bare: StoreSpec = "disk".parse().unwrap();
    assert_eq!(bare.disk_root(), Some(&StoreSpec::default_disk_root()));
    assert_eq!(StoreSpec::Memory.disk_root(), None);
    for bad in ["turbo", "disk:"] {
        let e = bad.parse::<StoreSpec>().unwrap_err().to_string();
        assert!(e.starts_with("store:") && e.contains("disk:PATH"), "{e}");
    }
}

#[test]
fn store_flags_validate_by_field_name() {
    use pahq::api::StoreSpec;
    // --gc-horizon without a disk store to govern is loud on both specs
    let e = run_err(|| RunSpec::builder("m", "t").gc_horizon(2).build());
    assert!(e.starts_with("gc_horizon:"), "{e}");
    let e = MatrixSpec::builder().gc_horizon(2).build().unwrap_err().to_string();
    assert!(e.starts_with("gc_horizon:"), "{e}");
    // a zero horizon could collect live artifacts — rejected, not clamped
    let e = run_err(|| {
        RunSpec::builder("m", "t")
            .store(StoreSpec::Disk { root: "/x".into(), gc_horizon: None })
            .gc_horizon(0)
            .build()
    });
    assert!(e.starts_with("gc_horizon:"), "{e}");
    // an explicit flag wins over a horizon carried by a hand-built Disk
    let spec = RunSpec::builder("m", "t")
        .store(StoreSpec::Disk { root: "/x".into(), gc_horizon: Some(9) })
        .gc_horizon(3)
        .build()
        .unwrap();
    assert_eq!(spec.store, StoreSpec::Disk { root: "/x".into(), gc_horizon: Some(3) });
    // the CLI spellings land in exactly the same place
    let parsed = RunSpec::from_cli(&args("run --store disk:/x --gc-horizon 3")).unwrap();
    assert_eq!(parsed.store, spec.store);
    let e = RunSpec::from_cli(&args("run --gc-horizon 2")).unwrap_err().to_string();
    assert!(e.starts_with("gc_horizon:"), "{e}");
    let e = MatrixSpec::from_cli(&args("matrix --store mem --gc-horizon 2"))
        .unwrap_err()
        .to_string();
    assert!(e.starts_with("gc_horizon:"), "{e}");
    // the default stays exactly what it always was: in-memory
    assert_eq!(RunSpec::builder("m", "t").build().unwrap().store, StoreSpec::Memory);
    assert_eq!(MatrixSpec::builder().build().unwrap().config().store, StoreSpec::Memory);
}

#[test]
fn matrix_resume_rejects_an_incompatible_store_schema() {
    use pahq::api::StoreSpec;
    let root = std::env::temp_dir().join(format!("pahq_api_schema_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all(&root).unwrap();
    std::fs::write(
        root.join("store-manifest.json"),
        r#"{"kind": "store_manifest", "schema_version": 99, "codec_version": 1, "generation": 4, "entries": []}"#,
    )
    .unwrap();
    let disk = StoreSpec::Disk { root: root.clone(), gc_horizon: None };
    let e = MatrixSpec::builder()
        .store(disk.clone())
        .resume(true)
        .build()
        .unwrap_err()
        .to_string();
    assert!(e.starts_with("store:") && e.contains("v99"), "{e}");
    // without --resume there is nothing to reuse, so the spec builds
    // (the stale store itself still refuses to open at run time)
    assert!(MatrixSpec::builder().store(disk).build().is_ok());
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn required_faithfulness_never_silently_synthesizes() {
    // a spec that declares faithfulness mandatory must error on the
    // synthetic substrate (it has no FP32 ground truth), not hand back
    // a record that silently lacks the score
    let spec = RunSpec::builder("synthetic-m", "alpha")
        .faithfulness(Some(false))
        .faith_required(true)
        .build()
        .unwrap();
    let e = api::run(&spec).unwrap_err().to_string();
    assert!(e.starts_with("faithfulness:"), "{e}");
    // without the requirement the synthetic record comes back (sans score)
    let mut relaxed = spec;
    relaxed.faith_required = false;
    let rec = api::run(&relaxed).unwrap();
    assert!(rec.faithfulness.is_none());
}

#[test]
fn cli_and_api_records_are_byte_identical_synthetic() {
    // Always runs: made-up model/task names resolve to the synthetic
    // substrate under Substrate::Auto, exactly like `pahq matrix` in CI.
    let mut spec = RunSpec::from_cli(&args(
        "run --model synthetic-m --task alpha --method eap --tau 0.4 --seed 3",
    ))
    .unwrap();
    spec.sink = OutputSink::Memory;
    let a = api::run(&spec).unwrap();
    let b = api::run(&spec).unwrap();
    assert_eq!(normalized_dump(a.clone()), normalized_dump(b), "api::run is deterministic");

    // ...and identical to the matrix's standalone comparator for the
    // same cell under the same grid config
    let grid = MatrixSpec::builder()
        .models(&["synthetic-m".to_string()])
        .tasks(&["alpha".to_string()])
        .tau(0.4)
        .seed(3)
        .build()
        .unwrap();
    let cell = Cell {
        method: "eap".into(),
        policy: spec.policy.clone(),
        model: spec.model.clone(),
        task: spec.task.clone(),
    };
    let standalone = matrix::standalone_cell(&cell, grid.config()).unwrap();
    assert_eq!(
        normalized_dump(a),
        normalized_dump(standalone),
        "api::run equals the grid's standalone comparator"
    );
}

#[test]
fn cli_and_api_records_are_byte_identical_on_engine() {
    // Engine-backed (skips without artifacts): the `pahq run` flag path
    // and a hand-built spec with the same seed produce byte-identical
    // records (timing normalized).
    if pahq::patching::PatchedForward::new("redwood2l-sim", "ioi").is_err() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut parsed = RunSpec::from_cli(&args(
        "run --model redwood2l-sim --task ioi --method pahq --tau 0.01 --seed 7 --no-faith",
    ))
    .unwrap();
    parsed.sink = OutputSink::Memory;
    let built = RunSpec::builder("redwood2l-sim", "ioi")
        .method(MethodKind::Pahq)
        .tau(0.01)
        .seed(7)
        .substrate(Substrate::Real)
        .build()
        .unwrap();
    let a = api::run(&parsed).unwrap();
    let b = api::run(&built).unwrap();
    assert_eq!(normalized_dump(a), normalized_dump(b), "CLI flags vs typed builder");
}
