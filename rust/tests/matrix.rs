//! Property tests for the `pahq matrix` grid orchestrator, driven
//! through the public [`pahq::api`] facade (grids launch only via
//! [`pahq::api::matrix`] on a validated [`MatrixSpec`]).
//!
//! The synthetic-substrate tests use made-up model/task names so they
//! run identically with or without `make artifacts` (the probe falls
//! back to the synthetic grid either way); the engine-backed tests skip
//! gracefully when artifacts are absent.

use std::collections::HashMap;
use std::path::PathBuf;

use pahq::acdc::SweepMode;
use pahq::api::{self, MatrixSpec, MatrixSpecBuilder, MethodKind};
use pahq::discovery::{RunRecord, Task};
use pahq::matrix::{self, cache};
use pahq::patching::Policy;
use pahq::quant::FP8_E4M3;

/// A synthetic-substrate grid builder writing into a unique temp dir.
fn test_builder(tag: &str, workers: usize) -> MatrixSpecBuilder {
    let out_dir =
        std::env::temp_dir().join(format!("pahq_matrix_{tag}_{}", std::process::id()));
    MatrixSpec::builder()
        .models(&["synthetic-m".to_string()])
        .tasks(&["alpha".to_string(), "beta".to_string()])
        .workers(workers)
        .faithfulness(false)
        .json_path(out_dir.join("matrix.json"))
        .out_dir(out_dir)
}

fn cleanup(spec: &MatrixSpec) {
    std::fs::remove_dir_all(&spec.config().out_dir).ok();
}

fn record_paths(spec: &MatrixSpec) -> Vec<PathBuf> {
    spec.cells().iter().map(|c| spec.config().out_dir.join(c.record_name())).collect()
}

#[test]
fn matrix_matches_standalone_at_1_and_4_workers() {
    // (a) every cell's kept-edge hash from the matrix equals the
    // standalone (cache-free) run through the public api::run, at 1 and
    // at 4 workers — and the two worker counts agree with each other.
    let mut by_workers: Vec<HashMap<String, String>> = Vec::new();
    for workers in [1usize, 4] {
        let spec = test_builder(&format!("bitid{workers}"), workers).build().unwrap();
        cleanup(&spec);
        let out = api::matrix(&spec).unwrap();
        assert_eq!(out.manifest.aggregate.n_error, 0, "no failed cells");
        assert!(out.manifest.synthetic, "made-up models force the synthetic substrate");
        let cells = spec.cells();
        assert_eq!(cells.len(), out.manifest.cells.len());
        let mut hashes = HashMap::new();
        for (cell, entry) in cells.iter().zip(&out.manifest.cells) {
            let standalone = matrix::standalone_cell(cell, spec.config()).unwrap();
            assert_eq!(
                entry.kept_hash.as_deref(),
                Some(standalone.kept_hash.as_str()),
                "{} at {workers} workers: matrix vs standalone kept set",
                cell.id()
            );
            // the saved record agrees bit-for-bit on the sweep outcome
            let rec =
                RunRecord::load(&spec.config().out_dir.join(cell.record_name())).unwrap();
            assert_eq!(rec.kept_hash, standalone.kept_hash, "{}", cell.id());
            assert_eq!(rec.n_kept, standalone.n_kept);
            assert_eq!(rec.n_evals, standalone.n_evals);
            assert_eq!(
                rec.final_metric.to_bits(),
                standalone.final_metric.to_bits(),
                "{}: final metric bits",
                cell.id()
            );
            hashes.insert(cell.id(), rec.kept_hash);
        }
        by_workers.push(hashes);
        cleanup(&spec);
    }
    assert_eq!(by_workers[0], by_workers[1], "1-worker and 4-worker grids agree");
}

#[test]
fn resume_reruns_only_missing_cells() {
    // (b) --resume leaves completed cells' records byte-identical and
    // re-runs only the missing ones.
    let builder = test_builder("resume", 2);
    let spec = builder.clone().build().unwrap();
    cleanup(&spec);
    let first = api::matrix(&spec).unwrap();
    assert_eq!(first.manifest.aggregate.n_error, 0);
    let paths = record_paths(&spec);
    let before: Vec<Vec<u8>> = paths.iter().map(|p| std::fs::read(p).unwrap()).collect();
    let missing = [1usize, paths.len() - 2];
    for &i in &missing {
        std::fs::remove_file(&paths[i]).unwrap();
    }
    let spec2 = builder.resume(true).build().unwrap();
    let second = api::matrix(&spec2).unwrap();
    assert_eq!(second.manifest.aggregate.n_error, 0);
    assert_eq!(second.manifest.aggregate.n_ok, missing.len(), "only missing cells re-ran");
    assert_eq!(second.manifest.aggregate.n_cached, paths.len() - missing.len());
    for (i, path) in paths.iter().enumerate() {
        let now = std::fs::read(path).unwrap();
        if missing.contains(&i) {
            // re-run: same discovery outcome (hash), timing may differ
            let a = RunRecord::load(path).unwrap();
            let b = RunRecord::from_json(
                &pahq::util::json::Json::parse(std::str::from_utf8(&before[i]).unwrap()).unwrap(),
            )
            .unwrap();
            assert_eq!(a.kept_hash, b.kept_hash, "re-run cell {i} rediscovers the circuit");
            assert_eq!(second.manifest.cells[i].status.as_str(), "ok");
        } else {
            assert_eq!(now, before[i], "cached cell {i} left byte-identical");
            assert_eq!(second.manifest.cells[i].status.as_str(), "cached");
        }
    }
    cleanup(&spec);
}

#[test]
fn manifest_reports_reuse_and_roundtrips() {
    // The acceptance contract on the manifest itself: schema-complete
    // cells, nonzero evals, and >= 1 corrupt-cache and >= 1 score-cache
    // hit from cross-run reuse.
    let spec = test_builder("shape", 2).build().unwrap();
    cleanup(&spec);
    let out = api::matrix(&spec).unwrap();
    let m = &out.manifest;
    assert_eq!(m.schema_version, 1);
    assert!(m.synthetic);
    assert_eq!(m.cells.len(), 5 * 2 * 2);
    for entry in &m.cells {
        assert_eq!(entry.status.as_str(), "ok");
        assert!(entry.record.is_some(), "{}: record path", entry.method);
        assert!(entry.n_evals.unwrap() > 0, "nonzero evals");
        assert_eq!(entry.kept_hash.as_ref().unwrap().len(), 16);
        let stats = entry.cache.as_ref().expect("every cell reuses something");
        assert!(stats.corrupt_hit, "phase B always hits the seeded corrupt analog");
        assert_eq!(stats.scores_hit, entry.method != "acdc", "score hits per method");
    }
    let a = &m.aggregate;
    assert_eq!(a.n_ok, m.cells.len());
    assert!(a.corrupt_cache_hits >= 1, "corrupt-cache reuse floor");
    assert!(a.scores_cache_hits >= 1, "score-cache reuse floor");
    assert!(a.n_evals_total > 0);
    // the manifest round-trips through its JSON artifact
    let back = matrix::MatrixManifest::load(&out.manifest_path).unwrap();
    assert_eq!(back.cells.len(), m.cells.len());
    assert_eq!(back.aggregate.corrupt_cache_hits, a.corrupt_cache_hits);
    assert_eq!(back.synthetic, m.synthetic);
    assert_eq!(back.seed, m.seed);
    // and the records it points at validate as run_records
    let recs = back.load_cell_records(&out.manifest_path).unwrap();
    assert_eq!(recs.len(), m.cells.len());
    cleanup(&spec);
}

#[test]
fn cache_keys_collide_nowhere_across_the_grid() {
    // (c) cache-key collision test across tasks/seeds: every (kind,
    // inputs) combination the quick grid touches maps to a distinct key.
    let mut keys = Vec::new();
    for task in ["ioi", "greater_than", "docstring"] {
        for seed in [0u64, 1, 7] {
            keys.push(cache::dataset_key(task, seed, 32));
            keys.push(cache::corrupt_key("redwood2l-sim", task, seed, "fp32"));
            keys.push(cache::corrupt_key("redwood2l-sim", task, seed, "rtn-q-8b"));
            keys.push(cache::surface_key("redwood2l-sim", task, seed));
            for method in ["eap", "hisp", "sp", "edge-pruning"] {
                keys.push(cache::scores_key(method, "redwood2l-sim", task, seed, "kl"));
                keys.push(cache::scores_key(method, "redwood2l-sim", task, seed, "task"));
            }
        }
    }
    let uniq: std::collections::HashSet<&String> = keys.iter().collect();
    assert_eq!(uniq.len(), keys.len(), "no key collisions");
    // and the seed derivation separates tasks at the same base
    assert_ne!(cache::dataset_seed("ioi", 3), cache::dataset_seed("docstring", 3));
}

#[test]
fn run_and_sweep_share_the_dataset_resolution() {
    // Regression (satellite): every entry point resolves its batch
    // through cache::dataset_for — identical (task, seed, n) inputs are
    // bit-identical across subcommands.
    let Ok(a) = cache::dataset_for("ioi", 7, 8) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let b = cache::dataset_for("ioi", 7, 8).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.clean, y.clean);
        assert_eq!(x.corrupt, y.corrupt);
        assert_eq!(x.pos, y.pos);
    }
    // a different seed draws a different stream
    let c = cache::dataset_for("ioi", 8, 8).unwrap();
    assert!(a.iter().zip(&c).any(|(x, y)| x.clean != y.clean), "seed changes the batch");
    // the session entry point api::run uses agrees with itself
    let task = Task::new("redwood2l-sim", "ioi");
    let Ok(s1) = matrix::seeded_session(&task, 7) else {
        eprintln!("skipping: engine substrate unavailable");
        return;
    };
    let s2 = matrix::seeded_session(&task, 7).unwrap();
    assert_eq!(s1.engine.examples.len(), s2.engine.examples.len());
    for (x, y) in s1.engine.examples.iter().zip(&s2.engine.examples) {
        assert_eq!(x.clean, y.clean);
        assert_eq!(x.corrupt, y.corrupt);
    }
}

#[test]
fn real_grid_smoke_with_pool_sharing() {
    // Engine-backed (skips without artifacts): a tiny real grid under a
    // batched sweep — consecutive cells on one worker hand the engine
    // pool over in one Handoff value — still matches the standalone
    // serial result.
    if pahq::patching::PatchedForward::new("redwood2l-sim", "ioi").is_err() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let spec = test_builder("real", 1)
        .models(&["redwood2l-sim".to_string()])
        .tasks(&["ioi".to_string()])
        .methods(vec![MethodKind::Acdc])
        .policies(vec![Policy::fp32(), Policy::pahq(FP8_E4M3)])
        .sweep(SweepMode::Batched { workers: 2 })
        .build()
        .unwrap();
    cleanup(&spec);
    let out = api::matrix(&spec).unwrap();
    assert_eq!(out.manifest.aggregate.n_error, 0);
    assert!(!out.manifest.synthetic);
    let mut serial_cfg = spec.config().clone();
    serial_cfg.sweep = SweepMode::Serial;
    for (cell, entry) in spec.cells().iter().zip(&out.manifest.cells) {
        let standalone = matrix::standalone_cell(cell, &serial_cfg).unwrap();
        assert_eq!(
            entry.kept_hash.as_deref(),
            Some(standalone.kept_hash.as_str()),
            "{}: batched pooled matrix vs serial standalone",
            cell.id()
        );
        // cross-run reuse was real: the corrupt cache was handed off
        assert!(entry.cache.as_ref().unwrap().corrupt_hit, "{}", cell.id());
    }
    cleanup(&spec);
}
